"""Kernel micro-benchmarks: Pallas (interpret on CPU) vs the jnp oracle,
plus the solver-throughput benchmarks.

``solver_moves`` is the headline: it measures Metropolis/coordinate solver
moves per second through three evaluation paths -- legacy full
``objective_batch`` per proposal, the incremental delta engine
(core.power), and the fused Pallas annealing kernel -- at paper scale
(R=10 VSRs on the paper topology), and writes the machine-readable
``BENCH_solver.json`` so later PRs can track the trajectory.

On CPU the Pallas timings measure the interpreter (not TPU perf); the
numbers that matter here are (a) correctness-at-scale and (b) the
delta-vs-full factor, which carries to TPU.
"""
from __future__ import annotations

import csv
import json
import time
from pathlib import Path
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dynamic, power, solvers, topology, vsr
from repro.kernels import ops, ref

OUT = Path("experiments/benchmarks")
# Machine-readable BENCH_*.json land at the repo root ONLY (the canonical
# location trackers read); CSVs land under experiments/benchmarks/.
BENCH_SOLVER_JSON = Path("BENCH_solver.json")
BENCH_ONLINE_JSON = Path("BENCH_online.json")


def _write(name: str, rows: List[Dict]) -> None:
    OUT.mkdir(parents=True, exist_ok=True)
    with (OUT / f"{name}.csv").open("w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0]))
        w.writeheader()
        w.writerows(rows)


def _time(fn, *args, reps=3) -> float:
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps


def placement_throughput() -> List[Dict]:
    topo = topology.paper_topology()
    vs = vsr.random_vsrs(10, rng=0, source_nodes=[0])
    prob = power.build_problem(topo, vs)
    rows = []
    for B in (64, 512, 4096):
        Xb = jax.random.randint(jax.random.PRNGKey(0),
                                (B, prob.R, prob.V), 0, prob.P, jnp.int32)
        t_batch = _time(lambda X: power.objective_batch(prob, X), Xb)
        t_kernel = _time(
            lambda X: ops.placement_objective(prob, X), Xb)
        # per-candidate python loop baseline (small B only)
        if B <= 64:
            t0 = time.time()
            for i in range(B):
                jax.block_until_ready(power.objective(prob, Xb[i]))
            t_loop = (time.time() - t0)
        else:
            t_loop = float("nan")
        rows.append(dict(batch=B,
                         batched_evals_per_s=round(B / t_batch, 1),
                         kernel_evals_per_s=round(B / t_kernel, 1),
                         loop_evals_per_s=(round(B / t_loop, 1)
                                           if t_loop == t_loop else "n/a")))
    _write("placement_throughput", rows)
    return rows


def _best_time(fn, reps: int = 5) -> float:
    """Min-of-reps wall time (compile excluded); robust to a noisy box."""
    jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(reps):
        t0 = time.time()
        jax.block_until_ready(fn())
        best = min(best, time.time() - t0)
    return best


def solver_moves(n_vsrs: int = 10, n_steps: int = 300,
                 chains_full: int = 4096, chains_delta: int = 16384,
                 chains_fused: int = 64) -> Dict:
    """Solver moves/second: full objective_batch vs delta vs fused kernel.

    Paper scale: R=10 VSRs, paper topology.  Each path runs the identical
    Metropolis proposal stream at its own best chain count (the full path
    saturates its flops around 4k chains; the delta path, which carries only
    [P]+[N] state per chain, keeps scaling); the coordinate sweep comparison
    scores the same (position, destination) move set through
    `objective_batch` broadcasting vs `delta_sweep`.  Writes
    BENCH_solver.json.
    """
    topo = topology.paper_topology()
    vs = vsr.random_vsrs(n_vsrs, rng=0, source_nodes=[0])
    prob = power.build_problem(topo, vs)
    aux = power.build_aux(prob)
    key = jax.random.PRNGKey(0)
    rng = np.random.default_rng(0)
    temps = jnp.asarray(
        50.0 * (0.05 / 50.0) ** (np.arange(n_steps) / (n_steps - 1)),
        jnp.float32)

    def chain_inputs(C):
        X0 = jnp.asarray(rng.integers(0, prob.P, size=(C, prob.R, prob.V)),
                         jnp.int32)
        Xc = jax.vmap(lambda x: power.apply_pins(prob, x))(X0)
        fi, p_prop, u_prop = solvers._anneal_proposals(
            key, aux, n_steps, C, prob.P)
        return Xc, aux.free_flat[fi], p_prop, u_prop

    # -- annealing hot loop ------------------------------------------------
    Xc, jp, pp_, u_ = chain_inputs(chains_full)
    t_full = _best_time(
        lambda: solvers._anneal_scan_full(prob, Xc, jp, pp_, u_, temps))
    full_mps = chains_full * n_steps / t_full

    Xc, jp, pp_, u_ = chain_inputs(chains_delta)
    t_delta = _best_time(
        lambda: solvers._anneal_scan_delta(prob, aux, Xc, jp, pp_, u_, temps))
    delta_mps = chains_delta * n_steps / t_delta

    Xc, jp, pp_, u_ = chain_inputs(chains_fused)
    t_fused = _best_time(
        lambda: ops.fused_anneal(prob, aux, Xc, jp.T, pp_.T, u_.T, temps))
    fused_mps = chains_fused * n_steps / t_fused

    # -- coordinate sweep: score every (free VM, destination) move ---------
    X0 = jnp.asarray(rng.integers(0, prob.P, size=(prob.R, prob.V)),
                     jnp.int32)
    positions = jnp.asarray(np.asarray(aux.free_pos))
    M, P = positions.shape[0], prob.P
    state = power.init_state(prob, X0)

    @jax.jit
    def legacy_sweep(problem, X, positions):
        def body(X, pos):
            r, v = pos[0], pos[1]
            cand = jnp.broadcast_to(X, (P,) + X.shape)
            cand = cand.at[:, r, v].set(jnp.arange(P, dtype=X.dtype))
            obj = power.objective_batch(problem, cand)
            best = jnp.argmin(obj)
            return X.at[r, v].set(best.astype(X.dtype)), obj[best]
        return jax.lax.scan(body, X, positions)

    t_sw_old = _best_time(lambda: legacy_sweep(prob, X0, positions))
    t_sw_new = _best_time(lambda: solvers._sweep(prob, aux, state, positions))
    sweep_old_sps = M * P / t_sw_old
    sweep_new_sps = M * P / t_sw_new

    backend = jax.default_backend()
    out = dict(
        scenario=dict(topology="paper", n_vsrs=n_vsrs, P=int(prob.P),
                      N=int(prob.N), R=int(prob.R), V=int(prob.V),
                      n_steps=n_steps, backend=backend),
        anneal=dict(
            full_moves_per_s=round(full_mps, 1),
            delta_moves_per_s=round(delta_mps, 1),
            fused_moves_per_s=round(fused_mps, 1),
            chains=dict(full=chains_full, delta=chains_delta,
                        fused=chains_fused),
            speedup_delta_vs_full=round(delta_mps / full_mps, 2),
            speedup_fused_vs_full=round(fused_mps / full_mps, 2),
            note=("fused kernel runs in Pallas interpret mode on non-TPU "
                  "backends; its CPU number measures the interpreter"
                  if backend != "tpu" else "fused kernel compiled via Mosaic"),
        ),
        coordinate_sweep=dict(
            legacy_scores_per_s=round(sweep_old_sps, 1),
            delta_scores_per_s=round(sweep_new_sps, 1),
            speedup_delta_vs_full=round(t_sw_old / t_sw_new, 2),
        ),
    )
    out["max_delta_speedup_vs_full"] = max(
        out["anneal"]["speedup_delta_vs_full"],
        out["coordinate_sweep"]["speedup_delta_vs_full"])
    BENCH_SOLVER_JSON.write_text(json.dumps(out, indent=2) + "\n")
    return out


def online_resolve(n_steady: int = 20, n_events: int = 12,
                   reps: int = 3) -> Dict:
    """Online re-embedding under churn: incremental vs from-scratch.

    Paper-scale steady state (``n_steady`` live VSRs on the paper topology)
    perturbed by alternating single departure / arrival events.  Every
    event is re-solved twice: by the online engine
    (``solvers.resolve_incremental`` via ``dynamic.OnlineEmbedder``,
    defrag disabled so the numbers are pure-incremental) and from scratch
    by the full portfolio (``solvers.solve_cfn``).  Both paths are timed
    min-of-``reps`` on compile-warmed shapes (the box is timing-noisy;
    the incremental event is replayed on engine clones), and the objective
    gap is recorded per event, plus a defrag sweep showing gap
    accumulation vs defrag interval.  Writes BENCH_online.json.
    """
    topo = topology.paper_topology()
    make = lambda sid: vsr.random_vsrs(1, rng=10_000 + sid, source_nodes=[0])
    key = jax.random.PRNGKey(0)

    def run_trace(defrag_every: int, n_ev: int, measure: bool):
        eng = dynamic.OnlineEmbedder(topo, defrag_every=defrag_every,
                                     key=jax.random.PRNGKey(7))
        events = dynamic.churn_trace(n_steady, n_ev, rng=3)
        eng.bootstrap([make(e.sid) for e in events[:n_steady]],
                      sids=[e.sid for e in events[:n_steady]])
        warmed: set = set()
        recs = []
        for ev in events[n_steady:]:
            def apply(engine):
                if ev.kind == "arrive":
                    return engine.add(make(ev.sid), sid=ev.sid)
                return engine.remove(ev.sid)

            t_inc = float("inf")
            if measure:
                for _ in range(reps):   # replay on throwaway clones
                    t0 = time.time()
                    apply(eng.clone())
                    t_inc = min(t_inc, time.time() - t0)
            t0 = time.time()
            res = apply(eng)
            t_inc = min(t_inc, time.time() - t0)
            rec = dict(event=ev.kind, n_live=eng.n_live,
                       inc_s=round(t_inc, 4), inc_obj=res.objective,
                       method=res.method)
            if measure:
                prob = eng.problem
                if eng.n_live not in warmed:   # exclude compile time
                    solvers.solve_cfn(prob, topo, key)
                    warmed.add(eng.n_live)
                t_s, r_s = float("inf"), None
                for _ in range(reps):
                    t0 = time.time()
                    r_s = solvers.solve_cfn(prob, topo, key)
                    t_s = min(t_s, time.time() - t0)
                rec.update(scratch_s=round(t_s, 4),
                           scratch_obj=r_s.objective,
                           gap=(res.objective - r_s.objective)
                           / r_s.objective)
            recs.append(rec)
        return recs

    # warm every shape on a throwaway trace (R oscillates n_steady +/- 1)
    run_trace(0, 2, measure=False)
    recs = run_trace(0, n_events, measure=True)
    # cold-warm caveat: the first measured events may still hit residual
    # compiles; summarize on the median, not the mean
    inc = sorted(r["inc_s"] for r in recs)
    scr = sorted(r["scratch_s"] for r in recs)
    med = lambda xs: xs[len(xs) // 2]
    gaps = [r["gap"] for r in recs]
    summary = dict(
        median_incremental_s=round(med(inc), 4),
        median_scratch_s=round(med(scr), 4),
        speedup_vs_scratch=round(med(scr) / med(inc), 2),
        mean_gap=round(sum(gaps) / len(gaps), 5),
        max_gap=round(max(gaps), 5),
        sustainable_events_per_s=dict(
            incremental=round(1.0 / med(inc), 1),
            scratch=round(1.0 / med(scr), 1)),
    )
    # gap accumulation vs defrag interval (churn tolerance): pure
    # incremental drifts; periodic defrag re-packs
    defrag_sweep = []
    for interval in (0, 8, 4):
        rr = run_trace(interval, n_events, measure=True)
        gg = [r["gap"] for r in rr]
        defrag_sweep.append(dict(
            defrag_every=interval,
            mean_gap=round(sum(gg) / len(gg), 5),
            max_gap=round(max(gg), 5),
            mean_event_s=round(sum(r["inc_s"] for r in rr) / len(rr), 4)))
    out = dict(
        scenario=dict(topology="paper", n_steady=n_steady,
                      n_events=n_events, backend=jax.default_backend(),
                      note=("alternating single departure/arrival events at "
                            "paper scale; scratch = solve_cfn portfolio, "
                            "min-of-reps, compile-warmed")),
        events=recs, summary=summary, defrag_sweep=defrag_sweep)
    BENCH_ONLINE_JSON.write_text(json.dumps(out, indent=2) + "\n")
    return out


def flash_cases() -> List[Dict]:
    rows = []
    for (B, H, KH, S, D) in [(1, 8, 2, 256, 64), (2, 4, 4, 512, 64)]:
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, H, S, D), jnp.float32)
        k = jax.random.normal(ks[1], (B, KH, S, D), jnp.float32)
        v = jax.random.normal(ks[2], (B, KH, S, D), jnp.float32)
        t_ref = _time(lambda a, b, c: ref.flash_attention_ref(a, b, c),
                      q, k, v)
        got = ops.flash_attention(q, k, v)
        want = ref.flash_attention_ref(q, k, v)
        err = float(jnp.max(jnp.abs(got - want)))
        flops = 4.0 * B * H * S * S * D / 2
        rows.append(dict(shape=f"B{B}H{H}KH{KH}S{S}D{D}",
                         ref_ms=round(t_ref * 1e3, 2),
                         ref_gflops=round(flops / t_ref / 1e9, 1),
                         kernel_max_err=f"{err:.1e}"))
    _write("flash_attention", rows)
    return rows
