"""Kernel micro-benchmarks: Pallas (interpret on CPU) vs the jnp oracle,
plus the solver-throughput benchmark (candidate evaluations / second) that
quantifies the batched-objective speedup over per-candidate evaluation.

On CPU the Pallas timings measure the interpreter (not TPU perf); the
numbers that matter here are (a) correctness-at-scale and (b) the jnp
batched-vs-loop factor, which carries to TPU.
"""
from __future__ import annotations

import csv
import time
from pathlib import Path
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import power, topology, vsr
from repro.kernels import ops, ref

OUT = Path("experiments/benchmarks")


def _write(name: str, rows: List[Dict]) -> None:
    OUT.mkdir(parents=True, exist_ok=True)
    with (OUT / f"{name}.csv").open("w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0]))
        w.writeheader()
        w.writerows(rows)


def _time(fn, *args, reps=3) -> float:
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps


def placement_throughput() -> List[Dict]:
    topo = topology.paper_topology()
    vs = vsr.random_vsrs(10, rng=0, source_nodes=[0])
    prob = power.build_problem(topo, vs)
    rows = []
    for B in (64, 512, 4096):
        Xb = jax.random.randint(jax.random.PRNGKey(0),
                                (B, prob.R, prob.V), 0, prob.P, jnp.int32)
        t_batch = _time(lambda X: power.objective_batch(prob, X), Xb)
        t_kernel = _time(
            lambda X: ops.placement_objective(prob, X), Xb)
        # per-candidate python loop baseline (small B only)
        if B <= 64:
            t0 = time.time()
            for i in range(B):
                jax.block_until_ready(power.objective(prob, Xb[i]))
            t_loop = (time.time() - t0)
        else:
            t_loop = float("nan")
        rows.append(dict(batch=B,
                         batched_evals_per_s=round(B / t_batch, 1),
                         kernel_evals_per_s=round(B / t_kernel, 1),
                         loop_evals_per_s=(round(B / t_loop, 1)
                                           if t_loop == t_loop else "n/a")))
    _write("placement_throughput", rows)
    return rows


def flash_cases() -> List[Dict]:
    rows = []
    for (B, H, KH, S, D) in [(1, 8, 2, 256, 64), (2, 4, 4, 512, 64)]:
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, H, S, D), jnp.float32)
        k = jax.random.normal(ks[1], (B, KH, S, D), jnp.float32)
        v = jax.random.normal(ks[2], (B, KH, S, D), jnp.float32)
        t_ref = _time(lambda a, b, c: ref.flash_attention_ref(a, b, c),
                      q, k, v)
        got = ops.flash_attention(q, k, v)
        want = ref.flash_attention_ref(q, k, v)
        err = float(jnp.max(jnp.abs(got - want)))
        flops = 4.0 * B * H * S * S * D / 2
        rows.append(dict(shape=f"B{B}H{H}KH{KH}S{S}D{D}",
                         ref_ms=round(t_ref * 1e3, 2),
                         ref_gflops=round(flops / t_ref / 1e9, 1),
                         kernel_max_err=f"{err:.1e}"))
    _write("flash_attention", rows)
    return rows
