"""Kernel micro-benchmarks: Pallas (interpret on CPU) vs the jnp oracle,
plus the solver-throughput benchmarks.

``solver_moves`` is the headline: it measures Metropolis/coordinate solver
moves per second through three evaluation paths -- legacy full
``objective_batch`` per proposal, the incremental delta engine
(core.power), and the fused Pallas annealing kernel -- at paper scale
(R=10 VSRs on the paper topology), and writes the machine-readable
``BENCH_solver.json`` so later PRs can track the trajectory.

On CPU the Pallas timings measure the interpreter (not TPU perf); the
numbers that matter here are (a) correctness-at-scale and (b) the
delta-vs-full factor, which carries to TPU.
"""
from __future__ import annotations

import csv
import json
import time
from pathlib import Path
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import api, dynamic, federation, power, solvers, topology, vsr
from repro.kernels import ops, ref

OUT = Path("experiments/benchmarks")
# Machine-readable BENCH_*.json land at the repo root ONLY (the canonical
# location trackers read); CSVs land under experiments/benchmarks/.
BENCH_SOLVER_JSON = Path("BENCH_solver.json")
BENCH_ONLINE_JSON = Path("BENCH_online.json")
BENCH_SPARSE_JSON = Path("BENCH_sparse.json")
BENCH_QUALITY_JSON = Path("BENCH_quality.json")
BENCH_FEDERATED_JSON = Path("BENCH_federated.json")
BENCH_FAULT_JSON = Path("BENCH_fault.json")
BENCH_CHURN_JSON = Path("BENCH_churn.json")
BENCH_OBS_JSON = Path("BENCH_obs.json")


def _write(name: str, rows: List[Dict]) -> None:
    OUT.mkdir(parents=True, exist_ok=True)
    with (OUT / f"{name}.csv").open("w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0]))
        w.writeheader()
        w.writerows(rows)


def _time(fn, *args, reps=3) -> float:
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps


def placement_throughput() -> List[Dict]:
    topo = topology.paper_topology()
    vs = vsr.random_vsrs(10, rng=0, source_nodes=[0])
    prob = power.build_problem(topo, vs)
    rows = []
    for B in (64, 512, 4096):
        Xb = jax.random.randint(jax.random.PRNGKey(0),
                                (B, prob.R, prob.V), 0, prob.P, jnp.int32)
        t_batch = _time(lambda X: power.objective_batch(prob, X), Xb)
        t_kernel = _time(
            lambda X: ops.placement_objective(prob, X), Xb)
        # per-candidate python loop baseline (small B only)
        if B <= 64:
            t0 = time.time()
            for i in range(B):
                jax.block_until_ready(power.objective(prob, Xb[i]))
            t_loop = (time.time() - t0)
        else:
            t_loop = float("nan")
        rows.append(dict(batch=B,
                         batched_evals_per_s=round(B / t_batch, 1),
                         kernel_evals_per_s=round(B / t_kernel, 1),
                         loop_evals_per_s=(round(B / t_loop, 1)
                                           if t_loop == t_loop else "n/a")))
    _write("placement_throughput", rows)
    return rows


def _best_time(fn, reps: int = 5, warmed: bool = False) -> float:
    """Min-of-reps wall time (compile excluded); robust to a noisy box.
    ``warmed=True`` skips the initial compile call (the caller already ran
    fn once, e.g. to capture its result)."""
    if not warmed:
        jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(reps):
        t0 = time.time()
        jax.block_until_ready(fn())
        best = min(best, time.time() - t0)
    return best


def solver_moves(n_vsrs: int = 10, n_steps: int = 300,
                 chains_full: int = 4096, chains_delta: int = 16384,
                 chains_fused: int = 64) -> Dict:
    """Solver moves/second: full objective_batch vs delta vs fused kernel.

    Paper scale: R=10 VSRs, paper topology.  Each path runs the identical
    Metropolis proposal stream at its own best chain count (the full path
    saturates its flops around 4k chains; the delta path, which carries only
    [P]+[N] state per chain, keeps scaling); the coordinate sweep comparison
    scores the same (position, destination) move set through
    `objective_batch` broadcasting vs `delta_sweep`.  Writes
    BENCH_solver.json.
    """
    topo = topology.paper_topology()
    vs = vsr.random_vsrs(n_vsrs, rng=0, source_nodes=[0])
    prob = power.build_problem(topo, vs)
    aux = power.build_aux(prob)
    key = jax.random.PRNGKey(0)
    rng = np.random.default_rng(0)
    temps = jnp.asarray(
        50.0 * (0.05 / 50.0) ** (np.arange(n_steps) / (n_steps - 1)),
        jnp.float32)

    def chain_inputs(C):
        X0 = jnp.asarray(rng.integers(0, prob.P, size=(C, prob.R, prob.V)),
                         jnp.int32)
        Xc = jax.vmap(lambda x: power.apply_pins(prob, x))(X0)
        fi, p_prop, u_prop = solvers._anneal_proposals(
            key, aux, n_steps, C, prob.P)
        return Xc, aux.free_flat[fi], p_prop, u_prop

    # -- annealing hot loop ------------------------------------------------
    Xc, jp, pp_, u_ = chain_inputs(chains_full)
    t_full = _best_time(
        lambda: solvers._anneal_scan_full(prob, Xc, jp, pp_, u_, temps))
    full_mps = chains_full * n_steps / t_full

    Xc, jp, pp_, u_ = chain_inputs(chains_delta)
    t_delta = _best_time(
        lambda: solvers._anneal_scan_delta(prob, aux, Xc, jp, pp_, u_, temps))
    delta_mps = chains_delta * n_steps / t_delta

    Xc, jp, pp_, u_ = chain_inputs(chains_fused)
    t_fused = _best_time(
        lambda: ops.fused_anneal(prob, aux, Xc, jp.T, pp_.T, u_.T, temps))
    fused_mps = chains_fused * n_steps / t_fused

    # -- coordinate sweep: score every (free VM, destination) move ---------
    X0 = jnp.asarray(rng.integers(0, prob.P, size=(prob.R, prob.V)),
                     jnp.int32)
    positions = jnp.asarray(np.asarray(aux.free_pos))
    M, P = positions.shape[0], prob.P
    state = power.init_state(prob, X0)

    @jax.jit
    def legacy_sweep(problem, X, positions):
        def body(X, pos):
            r, v = pos[0], pos[1]
            cand = jnp.broadcast_to(X, (P,) + X.shape)
            cand = cand.at[:, r, v].set(jnp.arange(P, dtype=X.dtype))
            obj = power.objective_batch(problem, cand)
            best = jnp.argmin(obj)
            return X.at[r, v].set(best.astype(X.dtype)), obj[best]
        return jax.lax.scan(body, X, positions)

    t_sw_old = _best_time(lambda: legacy_sweep(prob, X0, positions))
    t_sw_new = _best_time(lambda: solvers._sweep(prob, aux, state, positions))
    sweep_old_sps = M * P / t_sw_old
    sweep_new_sps = M * P / t_sw_new

    backend = jax.default_backend()
    out = dict(
        scenario=dict(topology="paper", n_vsrs=n_vsrs, P=int(prob.P),
                      N=int(prob.N), R=int(prob.R), V=int(prob.V),
                      n_steps=n_steps, backend=backend),
        anneal=dict(
            full_moves_per_s=round(full_mps, 1),
            delta_moves_per_s=round(delta_mps, 1),
            fused_moves_per_s=round(fused_mps, 1),
            chains=dict(full=chains_full, delta=chains_delta,
                        fused=chains_fused),
            speedup_delta_vs_full=round(delta_mps / full_mps, 2),
            speedup_fused_vs_full=round(fused_mps / full_mps, 2),
            note=("fused kernel runs in Pallas interpret mode on non-TPU "
                  "backends; its CPU number measures the interpreter"
                  if backend != "tpu" else "fused kernel compiled via Mosaic"),
        ),
        coordinate_sweep=dict(
            legacy_scores_per_s=round(sweep_old_sps, 1),
            delta_scores_per_s=round(sweep_new_sps, 1),
            speedup_delta_vs_full=round(t_sw_old / t_sw_new, 2),
        ),
    )
    out["max_delta_speedup_vs_full"] = max(
        out["anneal"]["speedup_delta_vs_full"],
        out["coordinate_sweep"]["speedup_delta_vs_full"])
    BENCH_SOLVER_JSON.write_text(json.dumps(out, indent=2) + "\n")
    return out


def _delta_sweep_dense(problem, aux, state, r, v, path_flat):
    """The PRE-CSR delta_sweep, verbatim: candidate route loads gathered as
    [P, D, N] rows of the dense [P*P, N] incidence table.  Kept here as the
    benchmark baseline the sparse production path is raced against."""
    p = problem
    P, N = p.P, p.N
    j = r * p.V + v
    X_flat = state.X.reshape(-1)
    p_old = X_flat[j]
    F_j = p.F.reshape(-1)[j]
    h = aux.inc_h[j]
    is_src = aux.inc_src[j]
    other = aux.inc_other[j]
    is_self = other == j
    q = X_flat[other]
    q_rm = jnp.where(is_self, p_old, q)
    h_ns = jnp.where(is_self, 0.0, h)
    h_s = jnp.where(is_self, h, 0.0)
    e_po = jax.nn.one_hot(p_old, P, dtype=jnp.float32)
    oh_qr = jax.nn.one_hot(q_rm, P, dtype=jnp.float32)
    same_r = (q_rm == p_old).astype(jnp.float32)
    omega_r = state.omega - F_j * e_po
    theta_r = state.theta - (h.sum() - (h * same_r).sum()) * e_po \
        - (h[:, None] * oh_qr).sum(0)
    idx_rm = jnp.where(is_src, p_old * P + q_rm, q_rm * P + p_old)
    lam_r = state.lam - (h[:, None] * path_flat[idx_rm]).sum(0)
    eye = jnp.eye(P, dtype=jnp.float32)
    omega_c = omega_r[None, :] + F_j * eye
    add_q = (h_ns[:, None] * jax.nn.one_hot(q, P, dtype=jnp.float32)).sum(0)
    diag_add = h_ns.sum() - add_q + h_s.sum()
    theta_c = theta_r[None, :] + add_q[None, :] + eye * diag_add[:, None]
    path3 = path_flat.reshape(P, P, N)
    rt_src = path3[:, q, :]
    rt_dst = jnp.swapaxes(path3[q, :, :], 0, 1)
    rt = jnp.where(is_src[None, :, None], rt_src, rt_dst)
    lam_c = lam_r[None, :] + jnp.einsum("d,pdn->pn", h_ns, rt)
    omega_c = power._snap(omega_c, power.SNAP_GFLOPS)
    theta_c = power._snap(theta_c, power.SNAP_MBPS)
    lam_c = power._snap(lam_c, power.SNAP_MBPS)
    return power._objective_from_loads(p, omega_c, lam_c, theta_c)


@jax.jit
def _sweep_dense(problem, aux, state, positions, path_flat):
    """Dense-reference coordinate sweep (same scan as solvers._sweep)."""
    def body(state, pos):
        r, v = pos[0], pos[1]
        obj_all = _delta_sweep_dense(problem, aux, state, r, v, path_flat)
        best = jnp.argmin(obj_all)
        state = power.apply_move(problem, aux, state, r, v,
                                 best.astype(state.X.dtype))
        return state, obj_all[best]
    state, objs = jax.lax.scan(body, state, positions)
    return state, objs[-1]


def sparse_routes(n_vsrs: int = 20, reps: int = 5) -> Dict:
    """CSR route table vs dense [P, P, N] incidence on the sweep hot path.

    For paper scale and two city_scale substrates (P >= 128), time one full
    coordinate sweep (`solvers._sweep`, production CSR path) against the
    pre-CSR dense-gather sweep kept above, and model the per-sweep memory
    traffic of the route lookups (the tensors each formulation must read).
    At paper scale both sweeps' final placements are scored by the float64
    oracle both on the sparse form and on a dense-form reference -- the gap
    must be 0.  Writes BENCH_sparse.json.
    """
    scenarios = [
        ("paper", topology.paper_topology()),
        ("city_p140", topology.city_scale(n_olt=8, onus_per_olt=4,
                                          iot_per_onu=4)),
        ("city_p252", topology.city_scale()),
        ("city_p468", topology.city_scale(n_olt=16, onus_per_olt=4,
                                          iot_per_onu=7)),
    ]
    rows = []
    parity = None
    for name, topo in scenarios:
        vs = vsr.random_vsrs(n_vsrs, rng=0, source_nodes=[0])
        prob = power.build_problem(topo, vs)
        aux = power.build_aux(prob)
        P, N, K, D = prob.P, prob.N, prob.K, int(aux.inc_h.shape[1])
        rng = np.random.default_rng(0)
        X0 = jnp.asarray(power.apply_pins(prob, jnp.asarray(
            rng.integers(0, P, size=(prob.R, prob.V)), jnp.int32)))
        state = power.init_state(prob, X0)
        positions = jnp.asarray(np.asarray(aux.free_pos))
        M = int(positions.shape[0])
        path_flat = jnp.asarray(
            topo.dense_path_nodes().reshape(P * P, N))

        # first runs double as compile warmup AND capture the final
        # placements for the parity check below
        st_csr, _ = solvers._sweep(prob, aux, state, positions)
        st_dense, _ = _sweep_dense(prob, aux, state, positions, path_flat)
        jax.block_until_ready((st_csr.X, st_dense.X))
        t_csr = _best_time(
            lambda: solvers._sweep(prob, aux, state, positions),
            reps=reps, warmed=True)
        t_dense = _best_time(
            lambda: _sweep_dense(prob, aux, state, positions, path_flat),
            reps=reps, warmed=True)

        # route-lookup traffic per sweep (bytes actually addressed by the
        # insertion scoring): dense gathers [P, D, N] f32 rows per position;
        # CSR gathers [P, D, K] i32 ids per position
        dense_traffic = M * P * D * N * 4
        csr_traffic = M * P * D * K * 4
        rows.append(dict(
            scenario=name, P=P, N=N, K=K, R=int(prob.R), M_free=M,
            sweep_s_csr=round(t_csr, 5), sweep_s_dense=round(t_dense, 5),
            speedup_csr_vs_dense=round(t_dense / t_csr, 2),
            table_bytes_dense=P * P * N * 4, table_bytes_csr=P * P * K * 4,
            table_shrink=round(N / K, 2),
            sweep_traffic_bytes_dense=dense_traffic,
            sweep_traffic_bytes_csr=csr_traffic,
            traffic_reduction=round(dense_traffic / csr_traffic, 2),
            same_argmin_placement=bool(
                np.array_equal(np.asarray(st_csr.X),
                               np.asarray(st_dense.X))),
        ))
        if name == "paper":
            # f64 parity on the solved placement: the sparse oracle vs the
            # SAME f64 term assembly on the dense incidence form, both for
            # lambda and for the end-to-end objective
            from repro.kernels import ref as kref
            Xs = np.asarray(st_csr.X)
            dense = topo.dense_path_nodes().astype(np.float64)
            obj_sparse = kref.placement_objective_f64(prob, Xs)
            obj_dense = kref.placement_objective_f64(prob, Xs,
                                                     path_dense=dense)
            st_f = power.init_state(prob, jnp.asarray(Xs))
            tm = np.asarray(st_f.tm, np.float64)
            lam_dense = np.einsum("pq,pqn->n", tm, dense)
            lam_sparse = kref.lam_f64_sparse(prob, tm)
            parity = dict(
                objective_f64_sparse=obj_sparse,
                objective_f64_dense=obj_dense,
                lam_max_abs_gap=float(np.max(np.abs(lam_dense
                                                    - lam_sparse))),
                objective_gap=abs(obj_sparse - obj_dense),
            )

    out = dict(
        scenario=dict(n_vsrs=n_vsrs, backend=jax.default_backend(),
                      note=("one coordinate sweep over all free VMs; "
                            "dense = pre-CSR [P,P,N] incidence gathers "
                            "(reconstructed from the CSR table for the "
                            "baseline only), min-of-reps wall clock")),
        sweeps=rows, f64_parity_paper_scale=parity)
    BENCH_SPARSE_JSON.write_text(json.dumps(out, indent=2) + "\n")
    return out


def online_resolve(n_steady: int = 20, n_events: int = 12,
                   reps: int = 3) -> Dict:
    """Online re-embedding under churn: incremental vs from-scratch.

    Paper-scale steady state (``n_steady`` live VSRs on the paper topology)
    perturbed by alternating single departure / arrival events.  Every
    event is re-solved twice: by the online engine
    (``solvers.resolve_incremental`` via ``dynamic.OnlineEmbedder``,
    defrag disabled so the numbers are pure-incremental) and from scratch
    by the full portfolio (``solvers.solve_cfn``).  Both paths are timed
    min-of-``reps`` on compile-warmed shapes (the box is timing-noisy;
    the incremental event is replayed on engine clones), and the objective
    gap is recorded per event, plus a defrag sweep showing gap
    accumulation vs defrag interval.  Writes BENCH_online.json.
    """
    topo = topology.paper_topology()
    make = lambda sid: vsr.random_vsrs(1, rng=10_000 + sid, source_nodes=[0])
    key = jax.random.PRNGKey(0)

    def run_trace(defrag_every: int, n_ev: int, measure: bool):
        eng = dynamic.OnlineEmbedder(topo, defrag_every=defrag_every,
                                     key=jax.random.PRNGKey(7))
        events = dynamic.churn_trace(n_steady, n_ev, rng=3)
        eng.bootstrap([make(e.sid) for e in events[:n_steady]],
                      sids=[e.sid for e in events[:n_steady]])
        warmed: set = set()
        recs = []
        for ev in events[n_steady:]:
            def apply(engine):
                if ev.kind == "arrive":
                    return engine.add(make(ev.sid), sid=ev.sid)
                return engine.remove(ev.sid)

            t_inc = float("inf")
            if measure:
                for _ in range(reps):   # replay on throwaway clones
                    t0 = time.time()
                    apply(eng.clone())
                    t_inc = min(t_inc, time.time() - t0)
            t0 = time.time()
            res = apply(eng)
            t_inc = min(t_inc, time.time() - t0)
            rec = dict(event=ev.kind, n_live=eng.n_live,
                       inc_s=round(t_inc, 4), inc_obj=res.objective,
                       method=res.method)
            if measure:
                prob = eng.problem
                if eng.n_live not in warmed:   # exclude compile time
                    solvers.solve_cfn(prob, topo, key)
                    warmed.add(eng.n_live)
                t_s, r_s = float("inf"), None
                for _ in range(reps):
                    t0 = time.time()
                    r_s = solvers.solve_cfn(prob, topo, key)
                    t_s = min(t_s, time.time() - t0)
                rec.update(scratch_s=round(t_s, 4),
                           scratch_obj=r_s.objective,
                           gap=(res.objective - r_s.objective)
                           / r_s.objective)
            recs.append(rec)
        return recs

    # warm every shape on a throwaway trace (R oscillates n_steady +/- 1)
    run_trace(0, 2, measure=False)
    recs = run_trace(0, n_events, measure=True)
    # cold-warm caveat: the first measured events may still hit residual
    # compiles; summarize on the median, not the mean
    inc = sorted(r["inc_s"] for r in recs)
    scr = sorted(r["scratch_s"] for r in recs)
    med = lambda xs: xs[len(xs) // 2]
    gaps = [r["gap"] for r in recs]
    summary = dict(
        median_incremental_s=round(med(inc), 4),
        median_scratch_s=round(med(scr), 4),
        speedup_vs_scratch=round(med(scr) / med(inc), 2),
        mean_gap=round(sum(gaps) / len(gaps), 5),
        max_gap=round(max(gaps), 5),
        sustainable_events_per_s=dict(
            incremental=round(1.0 / med(inc), 1),
            scratch=round(1.0 / med(scr), 1)),
    )
    # gap accumulation vs defrag interval (churn tolerance): pure
    # incremental drifts; periodic defrag re-packs
    defrag_sweep = []
    for interval in (0, 8, 4):
        rr = run_trace(interval, n_events, measure=True)
        gg = [r["gap"] for r in rr]
        defrag_sweep.append(dict(
            defrag_every=interval,
            mean_gap=round(sum(gg) / len(gg), 5),
            max_gap=round(max(gg), 5),
            mean_event_s=round(sum(r["inc_s"] for r in rr) / len(rr), 4)))
    out = dict(
        scenario=dict(topology="paper", n_steady=n_steady,
                      n_events=n_events, backend=jax.default_backend(),
                      note=("alternating single departure/arrival events at "
                            "paper scale; scratch = solve_cfn portfolio, "
                            "min-of-reps, compile-warmed")),
        events=recs, summary=summary, defrag_sweep=defrag_sweep)
    BENCH_ONLINE_JSON.write_text(json.dumps(out, indent=2) + "\n")
    return out


def solver_quality(n_vsrs: int = 20, efforts=("quick", "standard"),
                   ref_steps: int = 12000, ref_chains: int = 8) -> Dict:
    """City-scale portfolio quality vs a long-anneal reference.

    The ROADMAP open item: coordinate/anneal quality at P ~ 250 was
    unvalidated (exhaustive is infeasible there; efforts were tuned at
    paper scale).  For two city_scale substrates, run the spec-driven
    portfolio at each effort tier and report its objective gap to a
    much longer Metropolis reference (``ref_steps`` steps from the best
    portfolio warm start) plus wall-clock.  Gap <= 0 means the portfolio
    already matches/beats the long anneal.  Writes BENCH_quality.json.
    """
    from repro.api import PlacementSpec
    scenarios = [
        ("city_p140", topology.city_scale(n_olt=8, onus_per_olt=4,
                                          iot_per_onu=4)),
        ("city_p252", topology.city_scale()),
    ]
    key = jax.random.PRNGKey(0)
    rows = []
    for name, topo in scenarios:
        vs = vsr.random_vsrs(n_vsrs, rng=0, source_nodes=[0])
        prob = power.build_problem(topo, vs)
        per_effort = {}
        best_X, best_obj = None, float("inf")
        for effort in efforts:
            key, k = jax.random.split(key)
            spec = PlacementSpec(effort=effort)
            t0 = time.time()
            res = solvers.solve_portfolio(prob, topo, spec, k)
            dt = time.time() - t0
            per_effort[effort] = dict(objective=res.objective,
                                      power_w=res.power,
                                      feasible=res.feasible,
                                      wall_s=round(dt, 2),
                                      method=res.method)
            if res.objective < best_obj:
                best_obj, best_X = res.objective, res.X
        # long-anneal reference from the best portfolio incumbent: the
        # strong baseline exhaustive() cannot provide at this scale
        key, k = jax.random.split(key)
        t0 = time.time()
        ref_res = solvers.anneal(prob, k, best_X, n_chains=ref_chains,
                                 n_steps=ref_steps, t0=10.0, t1=0.02,
                                 backend="delta")
        ref_wall = time.time() - t0
        ref_obj = min(ref_res.objective, best_obj)
        for effort in efforts:
            e = per_effort[effort]
            e["gap_vs_reference"] = round(
                (e["objective"] - ref_obj) / max(abs(ref_obj), 1e-9), 5)
        rows.append(dict(scenario=name, P=int(prob.P), N=int(prob.N),
                         K=int(prob.K), R=int(prob.R),
                         reference=dict(objective=ref_obj,
                                        steps=ref_steps,
                                        chains=ref_chains,
                                        wall_s=round(ref_wall, 2)),
                         efforts=per_effort))
    out = dict(
        scenario=dict(n_vsrs=n_vsrs, backend=jax.default_backend(),
                      note=("portfolio objective vs a long Metropolis "
                            "reference warm-started from the best "
                            "portfolio incumbent; gaps <= 0 mean the "
                            "portfolio already matches the reference")),
        quality=rows)
    BENCH_QUALITY_JSON.write_text(json.dumps(out, indent=2) + "\n")
    return out


def federated_solve(n_vsrs: int = 16, reps: int = 3,
                    n_regions: int = 4, n_olt: int = 3,
                    onus_per_olt: int = 3, iot_per_onu: int = 4) -> Dict:
    """Federated vmapped solving vs the flat merged-substrate portfolio.

    On a 4-region ``federated_scale`` (defaults: 41 processing nodes per
    region, P = 164 merged): wall-clock and objective of
    ``FederatedSession.solve`` (per-region portfolios under ONE vmapped
    compile + exact coordinator accounting) against ``solve_portfolio``
    on the merged flat problem, same effort.  The flat sweep cost grows
    superlinearly in P while the federation solves G small regions, which
    is the past-the-single-substrate-ceiling scaling move; the objective
    ratio reports the fidelity cost of the region decomposition.  Also
    records the compile count and the federated-vs-oracle conservation
    gap.  Writes BENCH_federated.json.
    """
    from repro.api import FederatedSession, PlacementSpec
    from repro.kernels import ref as kref
    topo = topology.federated_scale(n_regions=n_regions, n_olt=n_olt,
                                    onus_per_olt=onus_per_olt,
                                    iot_per_onu=iot_per_onu)
    part = federation.RegionPartition.from_topology(topo)
    srcs = [int(r.proc_ids[0]) for r in part.regions]
    vs = vsr.random_vsrs(n_vsrs, rng=0, source_nodes=srcs)
    spec = PlacementSpec(effort="quick")
    prob_flat = power.build_problem(topo, vs)

    # flat baseline (compile-warmed, min of reps)
    key = jax.random.PRNGKey(0)
    solvers.solve_portfolio(prob_flat, topo, spec, key)   # warm
    t_flat, flat_res = float("inf"), None
    for _ in range(reps):
        t0 = time.time()
        flat_res = solvers.solve_portfolio(prob_flat, topo, spec, key)
        t_flat = min(t_flat, time.time() - t0)

    # federated: first solve pays the one vmapped compile; re-solves of
    # fresh same-bucket sessions measure the warm path
    before = solvers.TRACE_COUNTS.get("solve_regions", 0)
    t0 = time.time()
    res = FederatedSession(topo, spec).solve(vs)
    t_cold = time.time() - t0
    traces = solvers.TRACE_COUNTS.get("solve_regions", 0) - before
    t_fed = float("inf")
    for _ in range(reps):
        t0 = time.time()
        res = FederatedSession(topo, spec).solve(vs)
        t_fed = min(t_fed, time.time() - t0)
    traces_total = solvers.TRACE_COUNTS.get("solve_regions", 0) - before

    oracle = kref.placement_objective_f64(prob_flat, res.X)
    out = dict(
        scenario=dict(topology="federated_scale", P=int(topo.P),
                      G=part.G, n_vsrs=n_vsrs, effort=spec.effort,
                      backend=jax.default_backend(),
                      note=("flat = solve_portfolio on the merged "
                            "substrate (an unconstrained relaxation: it "
                            "may pack services across region borders); "
                            "federated = per-region portfolios vmapped "
                            "under one compile + exact coordinator "
                            "accounting, min-of-reps wall clock.  On this "
                            "CPU box the vmapped region lanes serialize; "
                            "the structural wins measured here are the "
                            "single compile, the exact conservation, and "
                            "the bounded per-region problem size -- the "
                            "region axis parallelizes on multi-core/TPU "
                            "backends")),
        flat=dict(wall_s=round(t_flat, 3), objective=flat_res.objective),
        federated=dict(
            wall_cold_s=round(t_cold, 3), wall_s=round(t_fed, 3),
            objective=res.breakdown.objective,
            regional_w=[round(float(w), 2)
                        for w in res.breakdown.regional_w],
            inter_region_w=round(res.breakdown.inter_region_w, 3),
            compiles_first_solve=traces,
            compiles_total=traces_total,
            conservation_gap=abs(oracle - res.breakdown.objective)),
        speedup_vs_flat=round(t_flat / t_fed, 2),
        objective_ratio_fed_vs_flat=round(
            res.breakdown.objective / flat_res.objective, 4))
    BENCH_FEDERATED_JSON.write_text(json.dumps(out, indent=2) + "\n")
    return out


def fault_storm(n_services: int = 10, n_olt: int = 3, onus_per_olt: int = 3,
                iot_per_onu: int = 3) -> Dict:
    """Closed-loop failure storms: availability, recovery latency, watts.

    Two storm presets (``single_node``, ``rack_storm``) replay against a
    ``city_scale`` substrate carrying ``n_services`` live services, plus a
    region-blackout evacuation on ``federated_scale``.  Per storm:

      * availability -- 1 - stranded-service-seconds / (horizon * services),
        measured on the timeline clock through the PlacementMonitor
        strand/unstrand windows;
      * recovery latency -- events from the first failure until every
        admitted service is live again (queue drained);
      * watts overhead -- peak degraded watts per live service vs the
        healthy baseline (the price of packing the survivors onto less
        substrate);
      * conservation -- |f64 oracle - engine objective| on the DEGRADED
        problem at maximum degradation (failed elements zeroed, same
        shapes);
      * compile stability -- a first storm warms the masked solver
        variants; the measured storm must replay with ZERO fresh traces
        (fail/recover events are value-only, never shape-changing).

    Writes BENCH_fault.json.
    """
    from repro.api import CFNSession, FederatedSession, PlacementSpec
    from repro.fault.monitor import PlacementMonitor
    from repro.kernels import ref as kref

    spec = PlacementSpec(effort="quick", defrag_every=0)

    def run_storm(preset: str) -> Dict:
        topo = topology.city_scale(n_olt=n_olt, onus_per_olt=onus_per_olt,
                                   iot_per_onu=iot_per_onu)
        iot = topo.layer_indices("iot")
        svcs = [vsr.random_vsrs(1, rng=np.random.default_rng(i), n_vms=3,
                                source_nodes=iot[:max(4, len(iot) // 3)])
                for i in range(n_services)]
        # aim the storm at nodes that actually host VMs (a probe session;
        # placement is deterministic, so the measured runs land the same
        # way) -- failing idle substrate would measure nothing
        probe = CFNSession(topo, spec)
        for i, sv in enumerate(svcs):
            probe.add(sv, sid=i)
        srcs = {int(sv.src[0]) for sv in svcs}
        cnt: Dict[int, int] = {}
        Xp = np.asarray(probe.X)
        for r in range(probe.n_live):
            for x in Xp[r, :probe.engine._vsrs[r].V]:
                if int(x) not in srcs:
                    cnt[int(x)] = cnt.get(int(x), 0) + 1
        hot = [n for n, _ in sorted(cnt.items(), key=lambda kv: -kv[1])]
        if preset == "single_node":
            events = dynamic.fault_preset(
                preset, topo, node=hot[0] if hot else None)
        else:
            # three busy hosts plus one pinned source: the rack storm
            # exercises both mass re-embedding AND stranding
            nodes = hot[:3] + [int(svcs[0].src[0])]
            events = dynamic.fault_preset(preset, topo, nodes=nodes)
        horizon = max(e.t for e in events) + 1.0
        last_fail = max(i for i, e in enumerate(events)
                        if e.kind.startswith("fail"))

        def one_run() -> Dict:
            mon = PlacementMonitor()
            s = CFNSession(topo, spec, monitor=mon)
            for i, sv in enumerate(svcs):
                s.add(sv, sid=i)
            healthy_w = float(s.result.breakdown.total)
            first_fail = last_degraded = None
            peak = (healthy_w, n_services)
            gap = 0.0
            for i, ev in enumerate(events):
                s.tick(ev.t)
                s.apply_fault(ev)
                if first_fail is None and ev.kind.startswith("fail"):
                    first_fail = i
                queued = len(s.engine._queue)
                if first_fail is not None and (s.n_live < n_services
                                               or queued):
                    last_degraded = i
                if s.result is not None:
                    w = float(s.result.breakdown.total)
                    if w / max(s.n_live, 1) > peak[0] / max(peak[1], 1):
                        peak = (w, s.n_live)
                if i == last_fail and s.result is not None:
                    # f64 conservation on the degraded substrate
                    vs = s.engine._vsrs[0]
                    for b in s.engine._vsrs[1:]:
                        vs = vs.concat(b)
                    prob = s.health.degrade(power.build_problem(topo, vs))
                    X = np.asarray(s.X)[:vs.R, :vs.V]
                    oracle = kref.placement_objective_f64(prob, X)
                    gap = abs(oracle - s.objective())
            mon.close_strands(horizon)
            return dict(
                availability=mon.availability(horizon, n_services),
                stranded_service_s=round(mon.stranded_service_s, 3),
                n_stranded=mon.get("service_stranded"),
                n_re_embedded=mon.get("re_embedded"),
                recovery_latency_events=(
                    None if first_fail is None else
                    0 if last_degraded is None else
                    last_degraded + 1 - first_fail),
                healthy_w=round(healthy_w, 2),
                degraded_peak_w=round(peak[0], 2),
                overhead_per_live_service=round(
                    (peak[0] / max(peak[1], 1))
                    / (healthy_w / n_services) - 1.0, 4),
                conservation_gap_degraded=gap)

        one_run()                                  # warm the masked variants
        before = dict(solvers.TRACE_COUNTS)
        out = one_run()                            # measured storm
        fresh = sum(solvers.TRACE_COUNTS.get(k, 0) - before.get(k, 0)
                    for k in solvers.TRACE_COUNTS)
        out["fresh_compiles_measured_run"] = fresh
        out["n_events"] = len(events)
        return out

    def run_evacuation() -> Dict:
        ftopo = topology.federated_scale(n_regions=3, n_olt=2,
                                         onus_per_olt=2, iot_per_onu=2,
                                         n_core=6)
        mon = PlacementMonitor()
        fed = FederatedSession(ftopo, spec, monitor=mon)
        srcs = [int(r.proc_ids[0]) for r in fed.partition.regions]
        sid = 0
        for g in range(3):
            for j in range(2):
                fed.add(vsr.random_vsrs(1,
                                        rng=np.random.default_rng(10 * g + j),
                                        n_vms=3, source_nodes=[srcs[g]]),
                        sid=sid)
                sid += 1
        # cross-host two region-0 services into region 1: the blackout
        # must EVACUATE them, not just strand the locals
        for j in range(2):
            fed.add(vsr.random_vsrs(1, rng=np.random.default_rng(100 + j),
                                    n_vms=3, source_nodes=[srcs[0]]),
                    sid=sid, region=1)
            sid += 1
        healthy_w = sum(float(w) for w in fed.breakdown().regional_w)
        fed.tick(1.0)
        n_evac = fed.fail_region(1)
        bd = fed.breakdown()
        vs = fed._plans[fed._order[0]].vsr
        for s2 in fed._order[1:]:
            vs = vs.concat(fed._plans[s2].vsr)
        oracle = kref.placement_objective_f64(
            power.build_problem(ftopo, vs),
            np.asarray(fed.X)[:vs.R, :vs.V])
        gap = abs(oracle - bd.objective)
        fed.tick(3.0)
        n_back = fed.recover_region(1)
        mon.close_strands(4.0)
        return dict(
            n_services=sid, n_evacuated=n_evac,
            n_stranded=mon.get("service_stranded"),
            n_readmitted=n_back,
            availability=mon.availability(4.0, sid),
            stranded_service_s=round(mon.stranded_service_s, 3),
            healthy_fleet_w=round(healthy_w, 2),
            degraded_fleet_w=round(
                sum(float(w) for w in bd.regional_w), 2),
            dark_region_w=round(float(bd.regional_w[1]), 3),
            conservation_gap_degraded=gap)

    out = dict(
        scenario=dict(topology="city_scale", n_olt=n_olt,
                      onus_per_olt=onus_per_olt, iot_per_onu=iot_per_onu,
                      n_services=n_services, effort=spec.effort,
                      backend=jax.default_backend(),
                      note=("storms replay fault_preset timelines against "
                            "a live online engine; the federated run "
                            "blacks out one region of a 3-region "
                            "federated_scale and measures evacuation + "
                            "exact conservation on the survivors")),
        storms={name: run_storm(name)
                for name in ("single_node", "rack_storm")},
        federated=run_evacuation())
    BENCH_FAULT_JSON.write_text(json.dumps(out, indent=2) + "\n")
    return out


def churn_waves(n_live: int = 1024, wave_size: int = 64, n_waves: int = 2,
                n_olt: int = 16, onus_per_olt: int = 4,
                iot_per_onu: int = 7,
                defrag_rows_per_tick: int = 8) -> Dict:
    """Wave-batched churn throughput: ``apply_wave`` vs per-event churn.

    A ``city_scale`` substrate carries ``n_live`` steady services
    (bootstrapped by adopting a load-balanced greedy placement, settled
    once by untimed defrag passes shared by both engines -- otherwise the
    per-event baseline's 64 incidental full polish sweeps per wave keep
    paying off bootstrap debt and the gap metric stops measuring churn
    resolution).  The ``flash_crowd_trace`` preset then drives
    ``n_waves`` replace waves of ``wave_size`` same-tick events
    (half departures, half arrivals, so the live count -- and the compile
    bucket -- never moves).  Two engines replay the SAME waves:

      * ``wave``      -- one ``apply_wave`` per wave: fused detach, one
        targeted sweep over the pow2-padded changed rows, ONE full polish
        pass per wave;
      * ``per_event`` -- the PR-2 baseline: one ``add``/``remove`` per
        event, each paying its own full polish.

    Both paths warm on wave 0; the measured waves must then replay with
    ZERO fresh solver traces (asserted).  Quality is scored with the f64
    oracle after each measured wave -- ``objective_gap`` is the mean
    relative gap of the wave path vs the per-event end state.  The
    amortized background defrag (``defrag_rows_per_tick`` rows per tick)
    runs AFTER the timed section of every wave and is reported
    separately -- it never sits on the per-event latency path.

    Writes BENCH_churn.json.
    """
    from repro.kernels import ref as kref

    topo = topology.city_scale(n_olt=n_olt, onus_per_olt=onus_per_olt,
                               iot_per_onu=iot_per_onu)
    iot = topo.layer_indices("iot")
    spec_kw = dict(effort="quick", anneal_steps=0, defrag_every=0,
                   polish_sweeps=1)
    mk = lambda sid: vsr.random_vsrs(
        1, rng=np.random.default_rng(sid), n_vms=3,
        source_nodes=iot[:max(8, len(iot) // 4)])

    # the flash-crowd preset IS the workload: wave 0 is the bootstrap
    # burst (adopted below, not replayed), waves 1.. are replace waves
    events = dynamic.flash_crowd_trace(n_live, n_waves + 1, wave_size,
                                       rng=0, replace=True)
    groups = list(dynamic.iter_waves(events))
    warm_wave, measured = groups[1], groups[2:]
    services = [mk(sid) for sid in range(n_live)]

    # load-balanced greedy start: spread VMs over the serving tiers by
    # accumulated GFLOPS so the steady state is settled, not pathological
    hosts = [p for layer in ("mf", "af", "cdc")
             for p in topo.layer_indices(layer)]
    load = {p: 0.0 for p in hosts}
    X0 = np.zeros((n_live, 3), np.int32)
    for r, sv in enumerate(services):
        for v in range(3):
            p = min(hosts, key=load.get)
            X0[r, v] = p
            load[p] += float(sv.F[0, v])

    def fresh_engine():
        eng = dynamic.OnlineEmbedder(
            topo, spec=api.PlacementSpec(
                defrag_rows_per_tick=defrag_rows_per_tick, **spec_kw),
            key=jax.random.PRNGKey(0))
        eng.bootstrap(services, X0=X0)
        return eng

    def split(group):
        deps = [ev.sid for ev in group if ev.kind == "depart"]
        arrs = [(mk(ev.sid), ev.sid) for ev in group
                if ev.kind == "arrive"]
        return arrs, deps

    def oracle(eng) -> float:
        vs = eng._vsrs[0]
        for b in eng._vsrs[1:]:
            vs = vs.concat(b)
        prob = power.build_problem(topo, vs)
        X = np.asarray(eng._X)[:vs.R, :vs.V]
        return float(kref.placement_objective_f64(prob, X))

    # settle the greedy start once (untimed, shared): never-regressing
    # full defrag passes until the portfolio stops improving, so both
    # paths inherit the SAME near-converged placement and the gap metric
    # isolates how each path resolves the churn itself
    settle = dynamic.OnlineEmbedder(
        topo, spec=api.PlacementSpec(**spec_kw), key=jax.random.PRNGKey(0))
    settle.bootstrap(services, X0=X0)
    prev_obj = oracle(settle)
    for _ in range(6):
        settle.defrag()
        cur_obj = oracle(settle)
        if prev_obj - cur_obj <= 5e-4 * abs(prev_obj):
            break
        prev_obj = cur_obj
    X0 = np.asarray(settle._X)[:n_live, :3].astype(np.int32)

    # -- wave path --------------------------------------------------------
    eng_w = fresh_engine()
    arrs, deps = split(warm_wave)
    eng_w.apply_wave(arrs, deps)               # warmup: compiles the bucket
    eng_w.defrag_tick()                        # ... and the defrag slice
    before = dict(solvers.TRACE_COUNTS)
    wave_s, defrag_s, wave_obj = [], [], []
    for group in measured:
        arrs, deps = split(group)
        t0 = time.time()
        wr = eng_w.apply_wave(arrs, deps)
        jax.block_until_ready(wr.result.X)
        wave_s.append(time.time() - t0)
        t0 = time.time()                       # off the event latency path
        eng_w.defrag_tick()
        defrag_s.append(time.time() - t0)
        wave_obj.append(oracle(eng_w))
    fresh = sum(solvers.TRACE_COUNTS.get(k, 0) - before.get(k, 0)
                for k in solvers.TRACE_COUNTS)
    assert fresh == 0, \
        f"measured waves must not retrace solver kernels ({fresh} fresh)"

    # -- per-event baseline ----------------------------------------------
    eng_e = fresh_engine()
    for group in (warm_wave,):                 # same warmup exposure
        arrs, deps = split(group)
        for sid in deps:
            eng_e.remove(sid)
        for sv, sid in arrs:
            eng_e.add(sv, sid=sid)
    event_s, event_obj = [], []
    for group in measured:
        arrs, deps = split(group)
        t0 = time.time()
        for sid in deps:
            eng_e.remove(sid)
        for sv, sid in arrs:
            eng_e.add(sv, sid=sid)
        jax.block_until_ready(eng_e._X)
        event_s.append(time.time() - t0)
        event_obj.append(oracle(eng_e))

    n_ev = float(wave_size)
    wave_eps = n_ev * len(measured) / sum(wave_s)
    event_eps = n_ev * len(measured) / sum(event_s)
    gaps = [(w - e) / abs(e) for w, e in zip(wave_obj, event_obj)]
    out = dict(
        scenario=dict(topology=f"city_p{topo.P}", P=topo.P, R=n_live,
                      wave_size=wave_size, n_waves=len(measured),
                      effort=spec_kw["effort"],
                      anneal_steps=spec_kw["anneal_steps"],
                      polish_sweeps=spec_kw["polish_sweeps"],
                      defrag_rows_per_tick=defrag_rows_per_tick,
                      backend=jax.default_backend(),
                      note=("flash_crowd_trace replace waves; both paths "
                            "warm on wave 0; defrag ticks excluded from "
                            "the timed event sections")),
        wave=dict(events_per_s=round(wave_eps, 3),
                  mean_wave_s=round(float(np.mean(wave_s)), 4),
                  mean_event_ms=round(1e3 * float(np.mean(wave_s)) / n_ev,
                                      3),
                  fresh_compiles_measured=fresh),
        per_event=dict(events_per_s=round(event_eps, 3),
                       mean_event_ms=round(
                           1e3 * float(np.mean(event_s)) / n_ev, 3)),
        speedup_wave_vs_per_event=round(wave_eps / event_eps, 2),
        objective_gap=dict(mean=round(float(np.mean(gaps)), 5),
                           max=round(float(np.max(gaps)), 5),
                           per_wave=[round(g, 5) for g in gaps]),
        defrag=dict(mean_tick_s=round(float(np.mean(defrag_s)), 4),
                    rows_per_tick=defrag_rows_per_tick,
                    note="runs after the timed wave section: amortized "
                         "background work, not per-event latency"))
    BENCH_CHURN_JSON.write_text(json.dumps(out, indent=2) + "\n")
    return out


def telemetry_overhead(n_live: int = 1024, wave_size: int = 64,
                       n_waves: int = 4, n_olt: int = 16,
                       onus_per_olt: int = 4, iot_per_onu: int = 7,
                       runs: int = 2) -> Dict:
    """Observability cost: the churn-wave workload with telemetry OFF
    vs ON.

    The same ``city_scale`` substrate, steady fleet, and
    ``flash_crowd_trace`` replace waves as ``churn_waves``, replayed
    through two engines built from the SAME PRNG key: one with no
    telemetry attached (the disabled path -- every instrumentation site
    is a ``None`` check) and one with a ``repro.telemetry.Telemetry``
    streaming JSONL to disk with spans, the energy ledger, and compile
    attribution all live.  Each variant replays ``runs`` times on a
    fresh engine and keeps its best total (noise damping); both replay
    the measured waves with ZERO fresh solver traces (asserted) and must
    end bit-identical -- telemetry may observe the placement math, never
    perturb it (asserted).  A micro section prices the primitives
    (counter inc, histogram observe, span enter/exit) per call.

    Writes BENCH_obs.json; the < 2% overhead acceptance gate lives in
    ``benchmarks.run.run_obs`` (full scale only -- at smoke scale the
    waves are milliseconds and timer noise dominates).
    """
    import os
    import tempfile

    from repro.telemetry import Telemetry, load_events

    topo = topology.city_scale(n_olt=n_olt, onus_per_olt=onus_per_olt,
                               iot_per_onu=iot_per_onu)
    iot = topo.layer_indices("iot")
    spec_kw = dict(effort="quick", anneal_steps=0, defrag_every=0,
                   polish_sweeps=1)
    mk = lambda sid: vsr.random_vsrs(
        1, rng=np.random.default_rng(sid), n_vms=3,
        source_nodes=iot[:max(8, len(iot) // 4)])

    events = dynamic.flash_crowd_trace(n_live, n_waves + 1, wave_size,
                                       rng=0, replace=True)
    groups = list(dynamic.iter_waves(events))
    warm_wave, measured = groups[1], groups[2:]
    services = [mk(sid) for sid in range(n_live)]

    hosts = [p for layer in ("mf", "af", "cdc")
             for p in topo.layer_indices(layer)]
    load = {p: 0.0 for p in hosts}
    X0 = np.zeros((n_live, 3), np.int32)
    for r, sv in enumerate(services):
        for v in range(3):
            p = min(hosts, key=load.get)
            X0[r, v] = p
            load[p] += float(sv.F[0, v])

    def split(group):
        deps = [ev.sid for ev in group if ev.kind == "depart"]
        arrs = [(mk(ev.sid), ev.sid) for ev in group
                if ev.kind == "arrive"]
        return arrs, deps

    def replay(tel):
        """Fresh engine -> warmup wave -> timed measured waves."""
        eng = dynamic.OnlineEmbedder(
            topo, spec=api.PlacementSpec(**spec_kw),
            key=jax.random.PRNGKey(0), telemetry=tel)
        eng.bootstrap(services, X0=X0)
        arrs, deps = split(warm_wave)
        eng.apply_wave(arrs, deps)
        before = dict(solvers.TRACE_COUNTS)
        times = []
        for group in measured:
            arrs, deps = split(group)
            t0 = time.time()
            wr = eng.apply_wave(arrs, deps)
            jax.block_until_ready(wr.result.X)
            times.append(time.time() - t0)
        fresh = sum(solvers.TRACE_COUNTS.get(k, 0) - before.get(k, 0)
                    for k in solvers.TRACE_COUNTS)
        assert fresh == 0, \
            f"measured waves must not retrace solver kernels ({fresh})"
        return eng, times

    # interleave off/on replays so drift (thermal, page cache) hits both
    tmp = tempfile.mkdtemp(prefix="bench_obs_")
    off_times, on_times = [], []
    eng_off = eng_on = tel = None
    jsonl_bytes = n_events = 0
    for i in range(runs):
        eng_off, t = replay(None)
        off_times.append(sum(t))
        path = os.path.join(tmp, f"run{i}.jsonl")
        tel = Telemetry(jsonl_path=path, attribution_every=8)
        eng_on, t = replay(tel)
        on_times.append(sum(t))
        tel.close()
        jsonl_bytes = os.path.getsize(path)
        n_events = len(load_events(path))

    X_off = np.asarray(eng_off._X)
    X_on = np.asarray(eng_on._X)
    identical = bool(np.array_equal(X_off, X_on))
    assert identical, \
        "telemetry must not perturb placements (PRNG/solver paths differ)"

    # micro: per-call cost of the primitives on a live in-memory registry
    micro_tel = Telemetry()
    reps = 20000
    t0 = time.time()
    for _ in range(reps):
        micro_tel.inc("bench.counter")
    inc_ns = (time.time() - t0) / reps * 1e9
    t0 = time.time()
    for _ in range(reps):
        micro_tel.observe("bench.lat_ms", 1.5)
    observe_ns = (time.time() - t0) / reps * 1e9
    t0 = time.time()
    for _ in range(reps):
        with micro_tel.span("bench"):
            pass
    span_ns = (time.time() - t0) / reps * 1e9

    n_ev = float(wave_size) * len(measured)
    off_s, on_s = min(off_times), min(on_times)
    overhead = (on_s - off_s) / off_s
    out = dict(
        scenario=dict(topology=f"city_p{topo.P}", P=topo.P, R=n_live,
                      wave_size=wave_size, n_waves=len(measured),
                      runs=runs, effort=spec_kw["effort"],
                      backend=jax.default_backend(),
                      note=("churn_waves workload replayed with telemetry "
                            "off vs on (spans + energy ledger + compile "
                            "attribution + JSONL stream); best-of-runs "
                            "totals, interleaved")),
        off=dict(events_per_s=round(n_ev / off_s, 3),
                 total_s=round(off_s, 4),
                 runs_s=[round(s, 4) for s in off_times]),
        on=dict(events_per_s=round(n_ev / on_s, 3),
                total_s=round(on_s, 4),
                runs_s=[round(s, 4) for s in on_times],
                events_emitted=n_events, jsonl_bytes=jsonl_bytes),
        overhead_pct=round(100.0 * overhead, 3),
        identical_placements=identical,
        fresh_compiles_measured=0,
        micro_ns_per_call=dict(counter_inc=round(inc_ns, 1),
                               histogram_observe=round(observe_ns, 1),
                               span=round(span_ns, 1)))
    BENCH_OBS_JSON.write_text(json.dumps(out, indent=2) + "\n")
    return out


def flash_cases() -> List[Dict]:
    rows = []
    for (B, H, KH, S, D) in [(1, 8, 2, 256, 64), (2, 4, 4, 512, 64)]:
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, H, S, D), jnp.float32)
        k = jax.random.normal(ks[1], (B, KH, S, D), jnp.float32)
        v = jax.random.normal(ks[2], (B, KH, S, D), jnp.float32)
        t_ref = _time(lambda a, b, c: ref.flash_attention_ref(a, b, c),
                      q, k, v)
        got = ops.flash_attention(q, k, v)
        want = ref.flash_attention_ref(q, k, v)
        err = float(jnp.max(jnp.abs(got - want)))
        flops = 4.0 * B * H * S * S * D / 2
        rows.append(dict(shape=f"B{B}H{H}KH{KH}S{S}D{D}",
                         ref_ms=round(t_ref * 1e3, 2),
                         ref_gflops=round(flops / t_ref / 1e9, 1),
                         kernel_max_err=f"{err:.1e}"))
    _write("flash_attention", rows)
    return rows
