"""Kernel micro-benchmarks: Pallas (interpret on CPU) vs the jnp oracle,
plus the solver-throughput benchmarks.

``solver_moves`` is the headline: it measures Metropolis/coordinate solver
moves per second through three evaluation paths -- legacy full
``objective_batch`` per proposal, the incremental delta engine
(core.power), and the fused Pallas annealing kernel -- at paper scale
(R=10 VSRs on the paper topology), and writes the machine-readable
``BENCH_solver.json`` so later PRs can track the trajectory.

On CPU the Pallas timings measure the interpreter (not TPU perf); the
numbers that matter here are (a) correctness-at-scale and (b) the
delta-vs-full factor, which carries to TPU.
"""
from __future__ import annotations

import csv
import json
import time
from pathlib import Path
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import power, solvers, topology, vsr
from repro.kernels import ops, ref

OUT = Path("experiments/benchmarks")
BENCH_SOLVER_JSON = Path("BENCH_solver.json")


def _write(name: str, rows: List[Dict]) -> None:
    OUT.mkdir(parents=True, exist_ok=True)
    with (OUT / f"{name}.csv").open("w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0]))
        w.writeheader()
        w.writerows(rows)


def _time(fn, *args, reps=3) -> float:
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps


def placement_throughput() -> List[Dict]:
    topo = topology.paper_topology()
    vs = vsr.random_vsrs(10, rng=0, source_nodes=[0])
    prob = power.build_problem(topo, vs)
    rows = []
    for B in (64, 512, 4096):
        Xb = jax.random.randint(jax.random.PRNGKey(0),
                                (B, prob.R, prob.V), 0, prob.P, jnp.int32)
        t_batch = _time(lambda X: power.objective_batch(prob, X), Xb)
        t_kernel = _time(
            lambda X: ops.placement_objective(prob, X), Xb)
        # per-candidate python loop baseline (small B only)
        if B <= 64:
            t0 = time.time()
            for i in range(B):
                jax.block_until_ready(power.objective(prob, Xb[i]))
            t_loop = (time.time() - t0)
        else:
            t_loop = float("nan")
        rows.append(dict(batch=B,
                         batched_evals_per_s=round(B / t_batch, 1),
                         kernel_evals_per_s=round(B / t_kernel, 1),
                         loop_evals_per_s=(round(B / t_loop, 1)
                                           if t_loop == t_loop else "n/a")))
    _write("placement_throughput", rows)
    return rows


def _best_time(fn, reps: int = 5) -> float:
    """Min-of-reps wall time (compile excluded); robust to a noisy box."""
    jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(reps):
        t0 = time.time()
        jax.block_until_ready(fn())
        best = min(best, time.time() - t0)
    return best


def solver_moves(n_vsrs: int = 10, n_steps: int = 300,
                 chains_full: int = 4096, chains_delta: int = 16384,
                 chains_fused: int = 64) -> Dict:
    """Solver moves/second: full objective_batch vs delta vs fused kernel.

    Paper scale: R=10 VSRs, paper topology.  Each path runs the identical
    Metropolis proposal stream at its own best chain count (the full path
    saturates its flops around 4k chains; the delta path, which carries only
    [P]+[N] state per chain, keeps scaling); the coordinate sweep comparison
    scores the same (position, destination) move set through
    `objective_batch` broadcasting vs `delta_sweep`.  Writes
    BENCH_solver.json.
    """
    topo = topology.paper_topology()
    vs = vsr.random_vsrs(n_vsrs, rng=0, source_nodes=[0])
    prob = power.build_problem(topo, vs)
    aux = power.build_aux(prob)
    key = jax.random.PRNGKey(0)
    rng = np.random.default_rng(0)
    temps = jnp.asarray(
        50.0 * (0.05 / 50.0) ** (np.arange(n_steps) / (n_steps - 1)),
        jnp.float32)

    def chain_inputs(C):
        X0 = jnp.asarray(rng.integers(0, prob.P, size=(C, prob.R, prob.V)),
                         jnp.int32)
        Xc = jax.vmap(lambda x: power.apply_pins(prob, x))(X0)
        fi, p_prop, u_prop = solvers._anneal_proposals(
            key, aux, n_steps, C, prob.P)
        return Xc, aux.free_flat[fi], p_prop, u_prop

    # -- annealing hot loop ------------------------------------------------
    Xc, jp, pp_, u_ = chain_inputs(chains_full)
    t_full = _best_time(
        lambda: solvers._anneal_scan_full(prob, Xc, jp, pp_, u_, temps))
    full_mps = chains_full * n_steps / t_full

    Xc, jp, pp_, u_ = chain_inputs(chains_delta)
    t_delta = _best_time(
        lambda: solvers._anneal_scan_delta(prob, aux, Xc, jp, pp_, u_, temps))
    delta_mps = chains_delta * n_steps / t_delta

    Xc, jp, pp_, u_ = chain_inputs(chains_fused)
    t_fused = _best_time(
        lambda: ops.fused_anneal(prob, aux, Xc, jp.T, pp_.T, u_.T, temps))
    fused_mps = chains_fused * n_steps / t_fused

    # -- coordinate sweep: score every (free VM, destination) move ---------
    X0 = jnp.asarray(rng.integers(0, prob.P, size=(prob.R, prob.V)),
                     jnp.int32)
    positions = jnp.asarray(np.asarray(aux.free_pos))
    M, P = positions.shape[0], prob.P
    state = power.init_state(prob, X0)

    @jax.jit
    def legacy_sweep(problem, X, positions):
        def body(X, pos):
            r, v = pos[0], pos[1]
            cand = jnp.broadcast_to(X, (P,) + X.shape)
            cand = cand.at[:, r, v].set(jnp.arange(P, dtype=X.dtype))
            obj = power.objective_batch(problem, cand)
            best = jnp.argmin(obj)
            return X.at[r, v].set(best.astype(X.dtype)), obj[best]
        return jax.lax.scan(body, X, positions)

    t_sw_old = _best_time(lambda: legacy_sweep(prob, X0, positions))
    t_sw_new = _best_time(lambda: solvers._sweep(prob, aux, state, positions))
    sweep_old_sps = M * P / t_sw_old
    sweep_new_sps = M * P / t_sw_new

    backend = jax.default_backend()
    out = dict(
        scenario=dict(topology="paper", n_vsrs=n_vsrs, P=int(prob.P),
                      N=int(prob.N), R=int(prob.R), V=int(prob.V),
                      n_steps=n_steps, backend=backend),
        anneal=dict(
            full_moves_per_s=round(full_mps, 1),
            delta_moves_per_s=round(delta_mps, 1),
            fused_moves_per_s=round(fused_mps, 1),
            chains=dict(full=chains_full, delta=chains_delta,
                        fused=chains_fused),
            speedup_delta_vs_full=round(delta_mps / full_mps, 2),
            speedup_fused_vs_full=round(fused_mps / full_mps, 2),
            note=("fused kernel runs in Pallas interpret mode on non-TPU "
                  "backends; its CPU number measures the interpreter"
                  if backend != "tpu" else "fused kernel compiled via Mosaic"),
        ),
        coordinate_sweep=dict(
            legacy_scores_per_s=round(sweep_old_sps, 1),
            delta_scores_per_s=round(sweep_new_sps, 1),
            speedup_delta_vs_full=round(t_sw_old / t_sw_new, 2),
        ),
    )
    out["max_delta_speedup_vs_full"] = max(
        out["anneal"]["speedup_delta_vs_full"],
        out["coordinate_sweep"]["speedup_delta_vs_full"])
    BENCH_SOLVER_JSON.write_text(json.dumps(out, indent=2) + "\n")
    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / "BENCH_solver.json").write_text(json.dumps(out, indent=2) + "\n")
    return out


def flash_cases() -> List[Dict]:
    rows = []
    for (B, H, KH, S, D) in [(1, 8, 2, 256, 64), (2, 4, 4, 512, 64)]:
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, H, S, D), jnp.float32)
        k = jax.random.normal(ks[1], (B, KH, S, D), jnp.float32)
        v = jax.random.normal(ks[2], (B, KH, S, D), jnp.float32)
        t_ref = _time(lambda a, b, c: ref.flash_attention_ref(a, b, c),
                      q, k, v)
        got = ops.flash_attention(q, k, v)
        want = ref.flash_attention_ref(q, k, v)
        err = float(jnp.max(jnp.abs(got - want)))
        flops = 4.0 * B * H * S * S * D / 2
        rows.append(dict(shape=f"B{B}H{H}KH{KH}S{S}D{D}",
                         ref_ms=round(t_ref * 1e3, 2),
                         ref_gflops=round(flops / t_ref / 1e9, 1),
                         kernel_max_err=f"{err:.1e}"))
    _write("flash_attention", rows)
    return rows
