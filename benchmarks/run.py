"""Benchmark driver: one entry per paper table/figure + framework benches.

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run fig3 gap   # subset

Outputs CSVs under experiments/benchmarks/, machine-readable BENCH_*.json
at the repo root (the single canonical location), and prints name,value
summaries.
"""
from __future__ import annotations

import sys
import time

from . import kernel_bench, paper_figures, roofline_table


def run_fig3():
    rows = paper_figures.fig3()
    stats = rows[-1]
    print(f"fig3: CFN-vs-CDC savings avg={stats['saving_vs_cdc']:.2%} "
          f"min={stats['saving_min']:.2%} max={stats['saving_max']:.2%} "
          f"(paper: 68% / 19% / 91%)")
    spill = [r for r in rows[:-1] if "cdc" in str(r["layers_used"])]
    print(f"fig3: CDC spill at n_vsrs={[r['n_vsrs'] for r in spill]} "
          "(paper: spike at 20)")
    fog = [r for r in rows[:-1]
           if "af" in str(r["layers_used"]).split("+")
           or "mf" in str(r["layers_used"]).split("+")]
    print(f"fig3: AF/MF selected in {len(fog)}/20 runs (paper: never)")


def run_fig4():
    rows = paper_figures.fig4()
    for r in rows:
        print(f"fig4: {r['policy']:9s} net={r['net_w']:9.1f}W "
              f"proc={r['proc_w']:9.1f}W total={r['total_w']:9.1f}W")


def run_gap():
    rows = paper_figures.solver_gap()
    import statistics
    for m in ("coordinate", "anneal", "genetic", "relax", "cfn-milp"):
        gaps = [r[f"{m}_gap"] for r in rows]
        print(f"gap: {m:11s} mean={statistics.mean(gaps):.4%} "
              f"max={max(gaps):.4%}")


def run_placement():
    rows = kernel_bench.placement_throughput()
    for r in rows:
        print(f"placement-throughput: B={r['batch']:5d} "
              f"batched={r['batched_evals_per_s']}/s "
              f"kernel(interp)={r['kernel_evals_per_s']}/s "
              f"loop={r['loop_evals_per_s']}/s")


def run_solver():
    out = kernel_bench.solver_moves()
    a, c = out["anneal"], out["coordinate_sweep"]
    print(f"solver-moves: anneal full={a['full_moves_per_s']:,.0f}/s "
          f"delta={a['delta_moves_per_s']:,.0f}/s "
          f"fused={a['fused_moves_per_s']:,.0f}/s "
          f"(delta {a['speedup_delta_vs_full']}x)")
    print(f"solver-moves: sweep legacy={c['legacy_scores_per_s']:,.0f}/s "
          f"delta={c['delta_scores_per_s']:,.0f}/s "
          f"({c['speedup_delta_vs_full']}x) -> BENCH_solver.json")


def run_sparse():
    out = kernel_bench.sparse_routes()
    for r in out["sweeps"]:
        print(f"sparse-routes: {r['scenario']:10s} P={r['P']:4d} "
              f"N={r['N']:3d} K={r['K']:2d} "
              f"csr={r['sweep_s_csr']*1e3:.1f}ms "
              f"dense={r['sweep_s_dense']*1e3:.1f}ms "
              f"({r['speedup_csr_vs_dense']}x) "
              f"traffic {r['traffic_reduction']}x lower")
    par = out["f64_parity_paper_scale"]
    print(f"sparse-routes: f64 lam gap={par['lam_max_abs_gap']:.2e} "
          f"objective_gap={par['objective_gap']} -> BENCH_sparse.json")


def run_online():
    out = kernel_bench.online_resolve()
    s = out["summary"]
    print(f"online-resolve: incremental={s['median_incremental_s']*1e3:.1f}ms"
          f"/event scratch={s['median_scratch_s']*1e3:.1f}ms/event "
          f"({s['speedup_vs_scratch']}x) gap mean={s['mean_gap']:.3%} "
          f"max={s['max_gap']:.3%}")
    for d in out["defrag_sweep"]:
        print(f"online-resolve: defrag_every={d['defrag_every']:2d} "
              f"mean_gap={d['mean_gap']:.3%} max_gap={d['max_gap']:.3%} "
              f"mean_event={d['mean_event_s']*1e3:.1f}ms -> BENCH_online.json")


def run_quality():
    out = kernel_bench.solver_quality()
    for row in out["quality"]:
        r = row["reference"]
        print(f"quality: {row['scenario']:10s} P={row['P']:4d} "
              f"reference obj={r['objective']:.1f} "
              f"({r['steps']} steps, {r['wall_s']}s)")
        for effort, e in row["efforts"].items():
            print(f"quality:   {effort:9s} obj={e['objective']:.1f} "
                  f"gap={e['gap_vs_reference']:+.3%} "
                  f"wall={e['wall_s']}s ({e['method']})"
                  f" -> BENCH_quality.json")


def run_federated():
    out = kernel_bench.federated_solve()
    f, d = out["flat"], out["federated"]
    print(f"federated: flat={f['wall_s']*1e3:.0f}ms "
          f"obj={f['objective']:.1f} | "
          f"federated={d['wall_s']*1e3:.0f}ms "
          f"(cold {d['wall_cold_s']*1e3:.0f}ms, "
          f"{d['compiles_first_solve']} compile) "
          f"obj={d['objective']:.1f} "
          f"({out['speedup_vs_flat']}x, "
          f"ratio {out['objective_ratio_fed_vs_flat']})")
    print(f"federated: regional W={d['regional_w']} "
          f"inter={d['inter_region_w']}W "
          f"conservation_gap={d['conservation_gap']:.2e} "
          f"-> BENCH_federated.json")


def run_fault():
    out = kernel_bench.fault_storm()
    for name, s in out["storms"].items():
        lat = s["recovery_latency_events"]
        print(f"fault-storm: {name:12s} availability={s['availability']:.4f} "
              f"stranded={s['stranded_service_s']:.1f}svc-h "
              f"recovery={'n/a' if lat is None else lat}ev "
              f"watts {s['healthy_w']:.1f}->{s['degraded_peak_w']:.1f}W "
              f"(overhead/live {s['overhead_per_live_service']:+.2%})")
        print(f"fault-storm: {name:12s} "
              f"conservation_gap={s['conservation_gap_degraded']:.2e} "
              f"fresh_compiles={s['fresh_compiles_measured_run']}")
    f = out["federated"]
    print(f"fault-storm: region-evac evacuated={f['n_evacuated']} "
          f"stranded={f['n_stranded']} readmitted={f['n_readmitted']} "
          f"availability={f['availability']:.4f} "
          f"dark_region={f['dark_region_w']}W")
    print(f"fault-storm: region-evac fleet "
          f"{f['healthy_fleet_w']:.1f}->{f['degraded_fleet_w']:.1f}W "
          f"conservation_gap={f['conservation_gap_degraded']:.2e} "
          f"-> BENCH_fault.json")


def _print_churn(out) -> None:
    s, w, e = out["scenario"], out["wave"], out["per_event"]
    print(f"churn-waves: {s['topology']} R={s['R']} "
          f"wave_size={s['wave_size']} x{s['n_waves']}")
    print(f"churn-waves: wave={w['events_per_s']:.1f} ev/s "
          f"({w['mean_event_ms']:.2f}ms/ev) "
          f"per_event={e['events_per_s']:.1f} ev/s "
          f"({e['mean_event_ms']:.2f}ms/ev) "
          f"speedup={out['speedup_wave_vs_per_event']}x")
    g, d = out["objective_gap"], out["defrag"]
    print(f"churn-waves: gap mean={g['mean']:.3%} max={g['max']:.3%} "
          f"fresh_compiles={w['fresh_compiles_measured']} "
          f"defrag_tick={d['mean_tick_s']*1e3:.1f}ms "
          f"({d['rows_per_tick']} rows, off the event path) "
          f"-> BENCH_churn.json")


def run_churn():
    out = kernel_bench.churn_waves()
    _print_churn(out)
    assert out["speedup_wave_vs_per_event"] >= 3.0, \
        "acceptance: >= 3x events/s vs the per-event baseline"
    assert abs(out["objective_gap"]["mean"]) <= 0.01, \
        "acceptance: mean objective gap <= 1% vs per-event resolution"


def run_churn_smoke():
    _print_churn(kernel_bench.churn_waves(
        n_live=32, wave_size=8, n_waves=2, n_olt=2, onus_per_olt=2,
        iot_per_onu=3, defrag_rows_per_tick=4))


def _print_obs(out) -> None:
    s = out["scenario"]
    print(f"obs: {s['topology']} R={s['R']} wave_size={s['wave_size']} "
          f"x{s['n_waves']} (best of {s['runs']})")
    print(f"obs: off={out['off']['events_per_s']:.1f} ev/s "
          f"on={out['on']['events_per_s']:.1f} ev/s "
          f"overhead={out['overhead_pct']:+.2f}% "
          f"identical_placements={out['identical_placements']}")
    m = out["micro_ns_per_call"]
    print(f"obs: micro inc={m['counter_inc']:.0f}ns "
          f"observe={m['histogram_observe']:.0f}ns span={m['span']:.0f}ns "
          f"jsonl={out['on']['jsonl_bytes']}B/"
          f"{out['on']['events_emitted']}ev -> BENCH_obs.json")


def run_obs():
    out = kernel_bench.telemetry_overhead()
    _print_obs(out)
    assert out["identical_placements"], \
        "acceptance: telemetry must not perturb placements"
    assert out["overhead_pct"] < 2.0, \
        "acceptance: enabled telemetry < 2% on the churn-wave bench"


def run_obs_smoke():
    # CI scale: the identity/zero-retrace asserts still run inside the
    # bench; the 2% timing gate is full-scale-only (ms waves = timer noise)
    _print_obs(kernel_bench.telemetry_overhead(
        n_live=32, wave_size=8, n_waves=2, n_olt=2, onus_per_olt=2,
        iot_per_onu=3, runs=1))


def run_flash():
    rows = kernel_bench.flash_cases()
    for r in rows:
        print(f"flash: {r['shape']} ref={r['ref_ms']}ms "
              f"({r['ref_gflops']} GF/s cpu) kernel_err={r['kernel_max_err']}")


def run_roofline():
    rows = roofline_table.write_table()
    n = len(rows)
    fits = sum(1 for r in rows if r["fits_16gb"])
    doms = {}
    for r in rows:
        doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
    print(f"roofline: {n} cells aggregated -> experiments/benchmarks/"
          f"roofline.csv ; fits-16GB {fits}/{n} ; dominant={doms}")


BENCHES = dict(fig3=run_fig3, fig4=run_fig4, gap=run_gap,
               placement=run_placement, solver=run_solver,
               sparse=run_sparse, online=run_online, quality=run_quality,
               federated=run_federated, fault=run_fault, churn=run_churn,
               obs=run_obs, flash=run_flash, roofline=run_roofline)
BENCHES["churn-smoke"] = run_churn_smoke
BENCHES["obs-smoke"] = run_obs_smoke
_SMOKE = ("churn-smoke", "obs-smoke")


def main() -> None:
    # the -smoke names are CI-scale variants: they would overwrite their
    # BENCH_*.json with test-scale numbers, so only run them by name
    names = sys.argv[1:] or [n for n in BENCHES if n not in _SMOKE]
    for name in names:
        t0 = time.time()
        print(f"== {name} ==", flush=True)
        BENCHES[name]()
        print(f"== {name} done in {time.time() - t0:.1f}s ==", flush=True)


if __name__ == "__main__":
    main()
