"""Paper-table reproductions.

  fig3  -- Total power for CDC / AF / MF / CFN-MILP at 1..20 VSRs, plus the
           headline savings stats (paper: avg 68 %, min 19 %, max 91 %).
  fig4  -- Network vs processing decomposition per policy.
  gap   -- Solver optimality-gap table vs exhaustive enumeration.

Each function returns rows (list of dicts) and writes a CSV next to the
run log; benchmarks/run.py drives all of them.
"""
from __future__ import annotations

import csv
import time
from pathlib import Path
from typing import Dict, List

import jax
import numpy as np

from repro.core import embed, power, solvers, topology, vsr

OUT = Path("experiments/benchmarks")

POLICIES = ("cdc", "af", "mf", "cfn-milp")


def _write(name: str, rows: List[Dict]) -> None:
    OUT.mkdir(parents=True, exist_ok=True)
    path = OUT / f"{name}.csv"
    if rows:
        with path.open("w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=list(rows[0]))
            w.writeheader()
            w.writerows(rows)


def fig3(max_vsrs: int = 20, seed: int = 0) -> List[Dict]:
    """Total power vs #VSRs for the four placement policies."""
    topo = topology.paper_topology()
    rows = []
    savings = []
    # one draw of max_vsrs requests; the n-VSR scenario is its prefix (the
    # paper's growing-workload sweep), so the IoT layer saturates at the end
    all_vs = vsr.random_vsrs(max_vsrs, rng=seed, source_nodes=[0])
    for n in range(1, max_vsrs + 1):
        vs = vsr.VSRBatch(F=all_vs.F[:n], H=all_vs.H[:n],
                          src=all_vs.src[:n], input_vm=all_vs.input_vm[:n])
        problem = power.build_problem(topo, vs)
        rec: Dict = dict(n_vsrs=n)
        for pol in POLICIES:
            res = embed.embed(topo, vs, pol, problem=problem,
                              key=jax.random.PRNGKey(n))
            rec[f"{pol}_w"] = round(res.power, 2)
            rec[f"{pol}_feasible"] = res.feasible
        rec["saving_vs_cdc"] = round(1 - rec["cfn-milp_w"] / rec["cdc_w"], 4)
        savings.append(rec["saving_vs_cdc"])
        # which layers the optimizer used (paper: IoT only, CDC spill at 20)
        res = embed.embed(topo, vs, "cfn-milp", problem=problem,
                          key=jax.random.PRNGKey(n))
        layers = sorted({topo.proc_layer[p] for p in res.X.reshape(-1)})
        rec["layers_used"] = "+".join(layers)
        rows.append(rec)
    _write("fig3_total_power", rows)
    stats = dict(rows[0])   # summary row appended AFTER the csv write
    stats.update(n_vsrs=-1, layers_used="STATS",
                 saving_vs_cdc=round(float(np.mean(savings)), 4),
                 saving_min=round(float(np.min(savings)), 4),
                 saving_max=round(float(np.max(savings)), 4))
    rows.append(stats)
    return rows


def fig4(n_vsrs: int = 10, seed: int = 0) -> List[Dict]:
    """Network vs processing power decomposition (paper Fig. 4)."""
    topo = topology.paper_topology()
    vs = vsr.random_vsrs(n_vsrs, rng=seed, source_nodes=[0])
    problem = power.build_problem(topo, vs)
    rows = []
    for pol in POLICIES:
        res = embed.embed(topo, vs, pol, problem=problem)
        summary = power.summarize(problem, topo, res.X)
        rows.append(dict(policy=pol, net_w=round(summary["net_w"], 2),
                         proc_w=round(summary["proc_w"], 2),
                         total_w=round(summary["total_w"], 2),
                         gflops_iot=round(summary["gflops_iot"], 1),
                         gflops_af=round(summary["gflops_af"], 1),
                         gflops_mf=round(summary["gflops_mf"], 1),
                         gflops_cdc=round(summary["gflops_cdc"], 1)))
    _write("fig4_decomposition", rows)
    return rows


def solver_gap(seeds=(0, 1, 2, 3, 4)) -> List[Dict]:
    """Optimality gap of every solver vs exhaustive (small instances)."""
    rows = []
    topo = topology.paper_topology(n_iot=4, n_zones=2)
    for seed in seeds:
        vs = vsr.random_vsrs(2, rng=seed, n_vms=2, source_nodes=[0])
        problem = power.build_problem(topo, vs)
        t0 = time.time()
        best = solvers.exhaustive(problem)
        t_ex = time.time() - t0
        rec = dict(seed=seed, exhaustive_w=round(best.power, 3),
                   exhaustive_s=round(t_ex, 2))
        for method in ("coordinate", "anneal", "genetic", "relax",
                       "cfn-milp"):
            t0 = time.time()
            res = embed.embed(topo, vs, method, problem=problem,
                              key=jax.random.PRNGKey(seed))
            rec[f"{method}_gap"] = round(
                (res.objective - best.objective)
                / max(best.objective, 1e-9), 5)
            rec[f"{method}_s"] = round(time.time() - t0, 2)
        rows.append(rec)
    _write("solver_gap", rows)
    return rows
