"""Aggregate the dry-run artifacts into the §Roofline table.

Reads experiments/dryrun/*.json (written by repro.launch.dryrun) and emits
one row per (arch x shape x mesh): the three roofline terms, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPs and the per-device memory figure.
"""
from __future__ import annotations

import csv
import json
from pathlib import Path
from typing import Dict, List

DRYRUN = Path("experiments/dryrun")
OUT = Path("experiments/benchmarks")


def rows_from_dryrun() -> List[Dict]:
    rows = []
    for p in sorted(DRYRUN.glob("*.json")):
        rec = json.loads(p.read_text())
        if rec.get("skipped"):
            continue
        r = rec["roofline"]
        h = rec["hlo"]
        rows.append(dict(
            arch=rec["arch"], shape=rec["shape"],
            mesh="x".join(str(s) for s in rec["mesh"]["shape"]),
            compile_s=rec.get("compile_s"),
            perdev_gb=round(rec["memory"]["peak_per_device_bytes"] / 1e9, 2),
            fits_16gb=rec["memory"]["fits_16gb"],
            compute_s=round(r["compute_s"], 4),
            memory_s=round(r["memory_s"], 4),
            collective_s=round(r["collective_s"], 4),
            dominant=r["dominant"],
            compute_fraction=round(r["compute_fraction"], 4),
            useful_ratio=round(rec.get("useful_flops_ratio", 0.0), 3),
            dot_tflops_dev=round(h["dot_flops"] / 1e12, 2),
            wire_gb_dev=round(h["collective_wire_bytes"] / 1e9, 2),
        ))
    return rows


def write_table() -> List[Dict]:
    rows = rows_from_dryrun()
    OUT.mkdir(parents=True, exist_ok=True)
    if rows:
        with (OUT / "roofline.csv").open("w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=list(rows[0]))
            w.writeheader()
            w.writerows(rows)
    return rows


def markdown_table(rows: List[Dict], mesh: str = "16x16") -> str:
    sel = [r for r in rows if r["mesh"] == mesh]
    hdr = ("| arch | shape | compute_s | memory_s | collective_s | "
           "dominant | useful | GB/dev |")
    sep = "|" + "---|" * 8
    lines = [hdr, sep]
    for r in sel:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']} | "
            f"{r['memory_s']} | {r['collective_s']} | {r['dominant']} | "
            f"{r['useful_ratio']} | {r['perdev_gb']} |")
    return "\n".join(lines)
