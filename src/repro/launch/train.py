"""Training driver (CPU-runnable end-to-end; the same step scales by mesh).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch olmoe-1b-7b --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --smoke \
      --steps 100 --resume

Training energy accounting: --report-energy embeds the model (as a VSR via
core.vsr.from_architecture) into the datacenter-scale CFN preset and prints
the optimized placement power next to the CDC baseline -- the paper's
technique as a first-class feature of the trainer.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Optional

import jax
import numpy as np

from .. import configs
from ..core import embed as cfn_embed
from ..core import topology as cfn_topology
from ..core import vsr as cfn_vsr
from ..data.pipeline import DataConfig, make_batch
from ..fault.runner import ResilientTrainer
from ..models.config import ArchConfig
from ..optim import adamw
from ..train.step import init_state, make_train_step


def build(arch: str, smoke: bool, lr: float, accum: int):
    cfg = configs.get_smoke(arch) if smoke else configs.get(arch)
    opt_cfg = adamw.AdamWConfig(lr=lr)
    step = jax.jit(make_train_step(cfg, opt_cfg, accum=accum),
                   donate_argnums=(0,))
    return cfg, step


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmoe-1b-7b",
                    choices=list(configs.ARCH_IDS))
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--report-energy", action="store_true")
    args = ap.parse_args(argv)

    cfg, step = build(args.arch, args.smoke, args.lr, args.accum)
    dcfg = DataConfig(seed=args.seed, batch=args.batch, seq_len=args.seq)
    init_fn = lambda: init_state(cfg, jax.random.PRNGKey(args.seed))[0]

    if args.ckpt_dir:
        trainer = ResilientTrainer(cfg, dcfg, step, init_fn,
                                   args.ckpt_dir, args.ckpt_every)
        report = trainer.run(args.steps)
        losses = report.losses
    else:
        state = init_fn()
        losses = []
        t0 = time.time()
        for i in range(args.steps):
            batch = make_batch(cfg, dcfg, i)
            state, metrics = step(state, batch)
            losses.append(float(metrics["loss"]))
            if i % 10 == 0 or i == args.steps - 1:
                dt = time.time() - t0
                print(f"step {i:5d} loss {losses[-1]:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"({dt:.1f}s)", flush=True)
    print(json.dumps(dict(arch=cfg.name, steps=args.steps,
                          first_loss=losses[0], last_loss=losses[-1],
                          improved=bool(losses[-1] < losses[0]))))

    if args.report_energy:
        topo = cfn_topology.datacenter_topology()
        vs = cfn_vsr.from_architecture(configs.get(args.arch),
                                       tokens_per_s=1000.0)
        saving = cfn_embed.savings_vs_baseline(topo, vs, baseline="cdc")
        print(json.dumps(dict(
            placement_baseline_w=round(saving["baseline_w"], 1),
            placement_optimized_w=round(saving["optimized_w"], 1),
            saving_frac=round(saving["saving_frac"], 4))))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
