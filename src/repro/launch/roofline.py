"""Trip-count-aware HLO analysis: FLOPs, bytes and collective traffic.

``compiled.cost_analysis()`` counts while-loop bodies ONCE, which silently
drops ~(repeats-1)/repeats of the compute of a scanned-layer model (verified
empirically: a 4-step scan reports 1/4 the flops of its unrolled twin).  The
dry-run therefore parses the partitioned HLO text, builds the computation
call graph, extracts while trip counts (the loop-bound constant in the
condition computation) and multiplies every op's cost by its execution
count.  All shapes in the partitioned module are PER-DEVICE shapes, so the
totals are per-device numbers -- exactly what the roofline terms need.

Collective wire-bytes model (ring algorithms, g = group size):
  all-gather:          result_bytes * (g-1)/g      received per device
  all-reduce:          2 * bytes * (g-1)/g         (reduce-scatter + gather)
  reduce-scatter:      result_bytes * (g-1)
  all-to-all:          bytes * (g-1)/g
  collective-permute:  bytes
The task-spec "operand bytes" sum is also reported (operand = result/g for
all-gather, result*g for reduce-scatter, result otherwise).
"""
from __future__ import annotations

import math
import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[^=]*?\)?)\s*"
    r"([\w\-]+)\((.*)$")
_CALLED_RE = re.compile(r"(?:to_apply|calls|condition|body)=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# Ops that pass buffers through without writing new data: excluded from the
# HBM-traffic proxy (a while's result is its aliased carry tuple -- counting
# it per iteration would bill every stacked parameter once per layer).
NON_WRITING = frozenset({
    "while", "conditional", "call", "tuple", "get-tuple-element",
    "parameter", "constant", "bitcast", "after-all", "opt-barrier"})


def shape_bytes(type_str: str) -> int:
    """Bytes of an HLO type string (handles tuples by summing parts)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dtype]
    return total


def shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    rest: str


@dataclass
class Computation:
    name: str
    ops: List[Op] = field(default_factory=list)
    symbols: Dict[str, str] = field(default_factory=dict)  # name -> type str


def parse_computations(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    comment_re = re.compile(r"/\*.*?\*/")
    for line in text.splitlines():
        line = comment_re.sub("", line)
        stripped = line.strip()
        # computation header: "%name (args...) -> type {".  Args may nest
        # parens / contain /*index=k*/ comments, so detect "no ' = ' before
        # the first '('" rather than trying to match the whole arg list.
        if stripped.endswith("{") and "->" in stripped:
            header = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(", stripped)
            paren = stripped.find("(")
            if header and " = " not in stripped[:paren]:
                cur = Computation(name=header.group(1))
                comps[cur.name] = cur
                continue
        if stripped.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, type_str, opcode, rest = m.groups()
        cur.ops.append(Op(name, type_str.strip(), opcode, rest))
        cur.symbols[name] = type_str.strip()
    return comps


def while_trip_count(cond: Computation) -> int:
    """Loop bound from the condition computation's constant (scan pattern)."""
    consts = []
    for op in cond.ops:
        if op.opcode == "constant":
            m = re.search(r"constant\((-?\d+)\)", "constant(" + op.rest)
            if m:
                consts.append(int(m.group(1)))
    return max(consts) if consts else 1


def execution_counts(comps: Dict[str, Computation],
                     entry: str) -> Dict[str, float]:
    """Times each computation executes (entry = 1; while bodies x trip)."""
    mult: Dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    order = [entry]
    seen = {entry}
    # BFS over call graph accumulating multipliers (call graph is a DAG)
    i = 0
    while i < len(order):
        cname = order[i]
        i += 1
        comp = comps.get(cname)
        if comp is None:
            continue
        for op in comp.ops:
            callees = [m.group(1) for m in _CALLED_RE.finditer(op.rest)]
            bm = _BRANCHES_RE.search(op.rest)
            if bm:
                callees += [c.strip().lstrip("%")
                            for c in bm.group(1).split(",")]
            factor = 1.0
            if op.opcode == "while":
                tm = _TRIP_RE.search(op.rest)
                if tm:
                    factor = max(1, int(tm.group(1)))
                else:
                    cond_m = re.search(r"condition=%?([\w.\-]+)", op.rest)
                    if cond_m and cond_m.group(1) in comps:
                        factor = max(
                            1, while_trip_count(comps[cond_m.group(1)]))
            for callee in callees:
                if not callee or callee not in comps:
                    continue
                mult[callee] += mult[cname] * factor
                if callee not in seen:
                    seen.add(callee)
                    order.append(callee)
    return dict(mult)


def _group_size(rest: str, n_devices: int) -> int:
    m = _GROUPS_RE.search(rest)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(rest)
    if m:
        return int(m.group(2))
    return n_devices


@dataclass
class HLOAnalysis:
    dot_flops: float = 0.0                  # per device, trip-corrected
    bytes_written: float = 0.0              # sum of op result bytes
    collective_wire_bytes: float = 0.0      # ring-model bytes per device
    collective_operand_bytes: float = 0.0   # task-spec operand-sum
    per_collective: Dict[str, float] = field(default_factory=dict)
    per_group_size: Dict[int, float] = field(default_factory=dict)
    n_collective_ops: int = 0

    def merged(self) -> Dict:
        return dict(dot_flops=self.dot_flops, bytes_written=self.bytes_written,
                    collective_wire_bytes=self.collective_wire_bytes,
                    collective_operand_bytes=self.collective_operand_bytes,
                    per_collective=dict(self.per_collective),
                    per_group_size={str(k): v
                                    for k, v in self.per_group_size.items()},
                    n_collective_ops=self.n_collective_ops)


def analyze_hlo(text: str, n_devices: int) -> HLOAnalysis:
    comps = parse_computations(text)
    entry = None
    m = re.search(r"ENTRY\s+%?([\w.\-]+)", text)
    if m:
        entry = m.group(1)
    if entry not in comps:
        entry = next(iter(comps))
    counts = execution_counts(comps, entry)
    # computations reached via a fusion op's calls= are fused bodies: their
    # internal ops produce no HBM traffic (the fusion's result is counted in
    # the caller), but dots inside them still count as compute.
    fusion_bodies = set()
    for comp in comps.values():
        for op in comp.ops:
            if op.opcode == "fusion":
                for m in _CALLED_RE.finditer(op.rest):
                    fusion_bodies.add(m.group(1))
    out = HLOAnalysis()
    for cname, comp in comps.items():
        mult = counts.get(cname, 0.0)
        if mult <= 0:
            continue
        fused = cname in fusion_bodies
        for op in comp.ops:
            rbytes = shape_bytes(op.type_str)
            if op.opcode not in NON_WRITING and not fused:
                out.bytes_written += rbytes * mult
            if op.opcode == "dot":
                dims = shape_dims(op.type_str)
                res = math.prod(dims) if dims else 0
                cm = _CONTRACT_RE.search(op.rest)
                contracted = 1
                if cm:
                    # lhs operand name is the first argument
                    arg = re.match(r"\s*%?([\w.\-]+)", op.rest)
                    lhs_shape = comp.symbols.get(arg.group(1), "") if arg else ""
                    ldims = shape_dims(lhs_shape)
                    for ci in cm.group(1).split(","):
                        if ci and ldims and int(ci) < len(ldims):
                            contracted *= ldims[int(ci)]
                out.dot_flops += 2.0 * res * contracted * mult
            elif op.opcode in COLLECTIVES:
                g = _group_size(op.rest, n_devices)
                if op.opcode == "all-gather":
                    wire = rbytes * (g - 1) / max(g, 1)
                    operand = rbytes / max(g, 1)
                elif op.opcode == "all-reduce":
                    wire = 2.0 * rbytes * (g - 1) / max(g, 1)
                    operand = rbytes
                elif op.opcode == "reduce-scatter":
                    wire = rbytes * (g - 1)
                    operand = rbytes * g
                elif op.opcode == "all-to-all":
                    wire = rbytes * (g - 1) / max(g, 1)
                    operand = rbytes
                else:  # collective-permute
                    wire = rbytes
                    operand = rbytes
                out.collective_wire_bytes += wire * mult
                out.collective_operand_bytes += operand * mult
                out.per_collective[op.opcode] = \
                    out.per_collective.get(op.opcode, 0.0) + wire * mult
                out.per_group_size[g] = \
                    out.per_group_size.get(g, 0.0) + wire * mult
                out.n_collective_ops += 1
    return out


def roofline_terms(dot_flops_per_dev: float, bytes_per_dev: float,
                   wire_bytes_per_dev: float, *,
                   peak_flops: float, hbm_bw: float, ici_bw: float) -> Dict:
    compute_s = dot_flops_per_dev / peak_flops
    memory_s = bytes_per_dev / hbm_bw
    collective_s = wire_bytes_per_dev / ici_bw
    total = max(compute_s, memory_s, collective_s)
    dominant = ("compute" if total == compute_s else
                "memory" if total == memory_s else "collective")
    return dict(compute_s=compute_s, memory_s=memory_s,
                collective_s=collective_s, dominant=dominant,
                bound_s=total,
                compute_fraction=compute_s / total if total else 0.0)
