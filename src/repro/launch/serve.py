"""Serving driver: batched prefill+decode with energy-aware placement.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --smoke \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from .. import configs
from ..core import topology as cfn_topology
from ..models import model as M
from ..serve import cache as C
from ..serve import engine
from ..serve.scheduler import EnergyAwareScheduler, Service


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b",
                    choices=list(configs.ARCH_IDS))
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.get_smoke(args.arch)
    params, _ = M.init_model(cfg, jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    B, S = args.batch, args.prompt_len
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (B, S)), jnp.int32)}
    if cfg.is_encoder_decoder:
        batch["frames"] = jnp.asarray(
            0.1 * rng.standard_normal((B, S, cfg.d_model)), jnp.float32)
    if cfg.vision_prefix_tokens:
        batch["patches"] = jnp.asarray(
            0.1 * rng.standard_normal(
                (B, cfg.vision_prefix_tokens, cfg.d_model)), jnp.float32)

    max_len = S + args.gen + (cfg.vision_prefix_tokens or 0) + 8
    cache = C.zeros(C.cache_spec(
        cfg, B, max_len, enc_len=S if cfg.is_encoder_decoder else 0))
    t0 = time.time()
    seq, _ = engine.greedy_generate(params, cfg, batch, cache, args.gen)
    dt = time.time() - t0
    print("generated token ids (first row):",
          np.asarray(seq[0]).tolist())
    print(f"{B} requests x {args.gen} tokens in {dt:.2f}s "
          f"({B * args.gen / dt:.1f} tok/s on CPU)")

    # energy-aware placement of this service on the CFN (paper technique)
    sched = EnergyAwareScheduler(cfn_topology.datacenter_topology())
    sched.add_service(Service(name=args.arch, arch=configs.get(args.arch),
                              tokens_per_s=B * args.gen / dt))
    placements = sched.solve()
    for p in placements:
        print(json.dumps(dict(service=p.service, stages=p.layers,
                              nodes=p.stage_nodes,
                              power_w=round(p.power_w, 2))))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
