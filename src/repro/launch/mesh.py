"""Production mesh construction.

Defined as FUNCTIONS so importing this module never touches jax device
state; the dry-run sets XLA_FLAGS before calling.  Axes:

  pod    -- data parallelism between pods (slow DCN axis; gradients only)
  data   -- FSDP/ZeRO: params + optimizer state sharded, batch sharded
  model  -- tensor parallelism (heads / ffn / experts / vocab)
"""
from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    # jax.sharding.AxisType landed after 0.4.x; older jax defaults to Auto.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(tuple(shape), tuple(axes))
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests, smoke dry-runs on few host devices)."""
    return _make_mesh(shape, axes)


# TPU v5e-class hardware constants (roofline denominators).
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link
