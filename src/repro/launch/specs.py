"""ShapeDtypeStruct input specs for every (architecture x shape) cell.

No device allocation happens here: everything is abstract (eval_shape for
parameters, TSpec trees for caches), which is what lets the 236B configs
lower on a CPU container.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .. import configs
from ..models import model as M
from ..models.config import ArchConfig
from ..optim import adamw
from ..serve import cache as C
from ..train.step import TrainState

SDS = jax.ShapeDtypeStruct


def dec_len(cfg: ArchConfig, seq_len: int) -> int:
    """Decoder-side token count for a given assigned seq_len."""
    if cfg.is_encoder_decoder:
        return max(64, int(seq_len * cfg.decoder_frac))
    if cfg.vision_prefix_tokens:
        return seq_len - cfg.vision_prefix_tokens
    return seq_len


def abstract_model(cfg: ArchConfig, dtype: Optional[Any] = None
                   ) -> Tuple[Any, Dict]:
    """(abstract params, logical axes) without allocating anything."""
    box = {}

    def f(key):
        p, axes = M.init_model(cfg, key)
        box["axes"] = axes
        return p

    shapes = jax.eval_shape(f, jax.random.key(0))
    if dtype is not None:
        shapes = jax.tree_util.tree_map(
            lambda s: SDS(s.shape, dtype) if s.dtype in
            (jnp.float32, jnp.bfloat16) else s, shapes)
    return shapes, box["axes"]


def token_specs(cfg: ArchConfig, batch: int, seq_len: int,
                with_labels: bool) -> Dict[str, SDS]:
    """Token / stub-frontend input specs for one (micro)batch."""
    dl = dec_len(cfg, seq_len)
    out: Dict[str, SDS] = {"tokens": SDS((batch, dl), jnp.int32)}
    if with_labels:
        out["labels"] = SDS((batch, dl), jnp.int32)
    if cfg.is_encoder_decoder:
        out["frames"] = SDS((batch, seq_len, cfg.d_model), jnp.bfloat16)
    if cfg.vision_prefix_tokens:
        out["patches"] = SDS((batch, cfg.vision_prefix_tokens, cfg.d_model),
                             jnp.bfloat16)
    return out


def train_state_specs(cfg: ArchConfig, compress_pod: bool = False):
    """(abstract TrainState, state logical-axes TrainState)."""
    params, axes = abstract_model(cfg)
    f32 = lambda t: jax.tree_util.tree_map(lambda s: SDS(s.shape,
                                                         jnp.float32), t)
    opt = adamw.OptState(m=f32(params), v=f32(params),
                         count=SDS((), jnp.int32))
    err = f32(params) if compress_pod else None
    state = TrainState(params=params, opt=opt, step=SDS((), jnp.int32),
                       err=err)
    oaxes = adamw.state_axes(axes)
    state_axes = TrainState(params=axes, opt=oaxes, step=(),
                            err=(axes if compress_pod else None))
    return state, state_axes


def serve_specs(cfg: ArchConfig, batch: int, seq_len: int, kind: str):
    """(abstract params, axes, batch specs, cache spec tree).

    kind == 'prefill': tokens are the full prompt, cache sized to hold it.
    kind == 'decode' : tokens [B, 1] + scalar position, cache holds seq_len.
    """
    params, axes = abstract_model(cfg, dtype=jnp.bfloat16)
    dl = dec_len(cfg, seq_len)
    enc_len = seq_len if cfg.is_encoder_decoder else 0
    spec = C.cache_spec(cfg, batch, dl, enc_len=enc_len)
    if kind == "prefill":
        batch_specs = token_specs(cfg, batch, seq_len, with_labels=False)
        extra: Dict[str, Any] = {}
    else:
        batch_specs = {"tokens": SDS((batch, 1), jnp.int32)}
        if cfg.is_encoder_decoder:
            pass  # cross-cache already holds projected encoder states
        extra = {"position": SDS((), jnp.int32)}
    return params, axes, batch_specs, extra, spec
