import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))
"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production mesh and extract memory / cost / collective analyses.

This is the proof that the distribution config is coherent: a sharding
mismatch, compile-time OOM or unsupported collective fails the cell.  The
512 placeholder host devices exist ONLY here (flag above, set before any
other import so jax locks the device count correctly).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch olmoe-1b-7b \
      --shape train_4k [--multi-pod] [--accum 8] [--out-dir experiments/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse
import json
import sys
import time
from pathlib import Path
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from .. import configs
from ..models import costs as costs_mod
from ..optim import adamw
from ..parallel import sharding as sh
from ..serve import cache as C
from ..serve import engine
from ..train.step import make_train_step
from . import mesh as mesh_mod
from . import specs as S
from .roofline import analyze_hlo, roofline_terms


def _named(tree, axes_tree, mesh):
    return sh.shard_params(tree, axes_tree, mesh)


def _batch_shardings(batch_specs: Dict, mesh) -> Dict:
    out = {}
    for k, v in batch_specs.items():
        logical = ("batch",) + (None,) * (len(v.shape) - 1)
        out[k] = jax.sharding.NamedSharding(
            mesh, sh.logical_spec(logical, v.shape, mesh))
    return out


def build_train(cfg, shape: configs.Shape, mesh, accum: int):
    state_sds, state_axes = S.train_state_specs(cfg)
    batch_sds = S.token_specs(cfg, shape.global_batch, shape.seq_len,
                              with_labels=True)
    state_sh = _named(state_sds, state_axes, mesh)
    batch_sh = _batch_shardings(batch_sds, mesh)
    opt_cfg = adamw.AdamWConfig()
    # compute_dtype: bf16 is the TPU-target setting, but the XLA *CPU*
    # pipeline trips an internal check ("Invalid binary instruction opcode
    # copy" in float normalization) on the bf16+shard_map+scan combination
    # for the largest MoE, and CPU promotes bf16 compute to f32 before SPMD
    # anyway (EXPERIMENTS.md §Perf, measurement-artifacts note) -- so the
    # dry-run lowers the f32 variant; REPRO_BF16=1 opts in where it works.
    import jax.numpy as _jnp
    param_axes = None if os.environ.get("REPRO_NO_GC") else state_axes.params
    cdtype = _jnp.bfloat16 if os.environ.get("REPRO_BF16") else None
    step = make_train_step(cfg, opt_cfg, accum=accum,
                           param_axes=param_axes, compute_dtype=cdtype)
    fn = jax.jit(step, in_shardings=(state_sh, batch_sh),
                 out_shardings=(state_sh, None), donate_argnums=(0,))
    return fn, (state_sds, batch_sds)


def build_serve(cfg, shape: configs.Shape, mesh, kind: str):
    params_sds, axes, batch_sds, extra, cache_tree = S.serve_specs(
        cfg, shape.global_batch, shape.seq_len, kind)
    params_sh = _named(params_sds, axes, mesh)
    batch_sh = _batch_shardings(batch_sds, mesh)
    cache_sds = C.sds(cache_tree)
    cache_sh = C.shardings(cache_tree, mesh)
    if kind == "prefill":
        def fn(params, batch, cache):
            return engine.prefill(params, cfg, batch, cache)
        jitted = jax.jit(fn, in_shardings=(params_sh, batch_sh, cache_sh),
                         out_shardings=(None, cache_sh),
                         donate_argnums=(2,))
        return jitted, (params_sds, batch_sds, cache_sds)
    def fn(params, tokens, position, cache):
        return engine.decode_step(params, cfg, tokens, position, cache)
    jitted = jax.jit(
        fn,
        in_shardings=(params_sh, batch_sh["tokens"], None, cache_sh),
        out_shardings=(None, cache_sh), donate_argnums=(3,))
    return jitted, (params_sds, batch_sds["tokens"],
                    extra["position"], cache_sds)


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             accum: Optional[int] = None, mesh=None,
             verbose: bool = True) -> Dict:
    cfg = configs.get(arch)
    shape = configs.SHAPES[shape_name]
    if shape not in configs.applicable_shapes(cfg):
        return dict(arch=arch, shape=shape_name, skipped=True,
                    reason="long_500k needs a sub-quadratic arch")
    if mesh is None:
        mesh = mesh_mod.make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    dp = mesh.shape.get("pod", 1) * mesh.shape.get("data", 1)
    if accum is None and shape.kind == "train":
        accum = max(1, min(8, shape.global_batch // dp))

    t0 = time.time()
    with sh.mesh_context(mesh):
        if shape.kind == "train":
            fn, args = build_train(cfg, shape, mesh, accum)
        else:
            fn, args = build_serve(cfg, shape, mesh, shape.kind)
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = analyze_hlo(compiled.as_text(), n_dev)
    model_fl = costs_mod.model_flops(cfg, shape)
    terms = roofline_terms(
        hlo.dot_flops, hlo.bytes_written, hlo.collective_wire_bytes,
        peak_flops=mesh_mod.PEAK_FLOPS_BF16, hbm_bw=mesh_mod.HBM_BW,
        ici_bw=mesh_mod.ICI_BW)
    per_dev_bytes = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                     - mem.alias_size_in_bytes + mem.temp_size_in_bytes)
    rec = dict(
        arch=arch, shape=shape_name,
        mesh=dict(shape=list(mesh.devices.shape),
                  axes=list(mesh.axis_names), n_devices=int(n_dev)),
        accum=accum,
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        memory=dict(
            argument_bytes=mem.argument_size_in_bytes,
            output_bytes=mem.output_size_in_bytes,
            alias_bytes=mem.alias_size_in_bytes,
            temp_bytes=mem.temp_size_in_bytes,
            peak_per_device_bytes=per_dev_bytes,
            fits_16gb=bool(per_dev_bytes < 16e9),
        ),
        cost_analysis=dict(
            flops_uncorrected=cost.get("flops", 0.0),
            bytes_accessed_uncorrected=cost.get("bytes accessed", 0.0)),
        hlo=hlo.merged(),
        model_flops=model_fl,
        useful_flops_ratio=(model_fl["total_flops"] / n_dev / hlo.dot_flops
                            if hlo.dot_flops else 0.0),
        roofline=terms,
    )
    if verbose:
        print(json.dumps(rec, indent=1))
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(configs.ARCH_IDS))
    ap.add_argument("--shape", choices=list(configs.SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--accum", type=int, default=None)
    ap.add_argument("--out-dir", default="experiments/dryrun")
    args = ap.parse_args(argv)

    out_dir = Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    mesh = mesh_mod.make_production_mesh(multi_pod=args.multi_pod)
    tag = "multipod" if args.multi_pod else "singlepod"
    cells = (configs.all_cells() if args.all
             else [(args.arch, args.shape)])
    failures = 0
    for arch, shape in cells:
        out_path = out_dir / f"{arch}_{shape}_{tag}.json"
        try:
            rec = run_cell(arch, shape, multi_pod=args.multi_pod,
                           accum=args.accum, mesh=mesh, verbose=False)
            out_path.write_text(json.dumps(rec, indent=1))
            mem = rec.get("memory", {})
            print(f"OK   {arch:24s} {shape:12s} {tag}: "
                  f"compile={rec.get('compile_s', 0):7.1f}s "
                  f"perdev={mem.get('peak_per_device_bytes', 0)/1e9:6.2f}GB "
                  f"dominant={rec.get('roofline', {}).get('dominant', '?')}",
                  flush=True)
        except Exception as e:  # noqa: BLE001 -- report and continue
            failures += 1
            print(f"FAIL {arch:24s} {shape:12s} {tag}: "
                  f"{type(e).__name__}: {e}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
