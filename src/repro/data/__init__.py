from .pipeline import DataConfig, DataIterator, make_batch, synth_tokens
