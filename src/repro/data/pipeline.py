"""Deterministic sharded synthetic-token pipeline.

Every batch is a pure function of (seed, step, shape): resume-after-failure
replays the exact token stream with no host state to checkpoint beyond the
step counter -- the property the fault-tolerance layer (fault/runner.py)
relies on for bitwise-identical restarts.  Per-host sharding: a host with
(host_id, n_hosts) materializes only its slice of the global batch; under
pjit the per-host slices are assembled into the global array
(jax.make_array_from_process_local_data in a real multi-host launch; on one
host the full batch is returned).

The "dataset" is a mixture of structured streams (repeating n-grams +
skip-patterns + noise) rather than iid-uniform tokens, so cross-entropy has
learnable structure and short training runs show a falling loss curve.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

from ..models.config import ArchConfig


@dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    batch: int = 8
    seq_len: int = 128
    host_id: int = 0
    n_hosts: int = 1
    # structure of the synthetic language
    ngram: int = 4
    n_patterns: int = 64
    noise: float = 0.05


def _rng_for(cfg: DataConfig, step: int) -> np.random.Generator:
    # counter-based: independent stream per (seed, step, host)
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, cfg.host_id]))


def _pattern_bank(cfg: DataConfig, vocab: int) -> np.ndarray:
    rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, 0xBEEF]))
    return rng.integers(0, vocab, size=(cfg.n_patterns, cfg.ngram),
                        dtype=np.int32)


def synth_tokens(cfg: DataConfig, vocab: int, step: int,
                 batch: Optional[int] = None,
                 seq_len: Optional[int] = None) -> np.ndarray:
    """[local_batch, seq_len+1] int32 (shifted into tokens/labels later)."""
    b = (batch if batch is not None else cfg.batch) // cfg.n_hosts
    s = (seq_len if seq_len is not None else cfg.seq_len) + 1
    rng = _rng_for(cfg, step)
    bank = _pattern_bank(cfg, vocab)
    n_chunks = -(-s // cfg.ngram)
    pat = rng.integers(0, cfg.n_patterns, size=(b, n_chunks))
    toks = bank[pat].reshape(b, n_chunks * cfg.ngram)[:, :s]
    noise_mask = rng.random((b, s)) < cfg.noise
    noise = rng.integers(0, vocab, size=(b, s), dtype=np.int32)
    return np.where(noise_mask, noise, toks).astype(np.int32)


def make_batch(arch: ArchConfig, dcfg: DataConfig, step: int,
               batch: Optional[int] = None,
               seq_len: Optional[int] = None) -> Dict[str, np.ndarray]:
    """Training batch for any assigned architecture (incl. stub frontends)."""
    b = batch if batch is not None else dcfg.batch
    s = seq_len if seq_len is not None else dcfg.seq_len
    rng = _rng_for(dcfg, step)
    if arch.is_encoder_decoder:
        dec = max(8, int(s * arch.decoder_frac))
        t = synth_tokens(dcfg, arch.vocab, step, batch=b, seq_len=dec)
        frames = rng.standard_normal(
            (b // dcfg.n_hosts, s, arch.d_model)).astype(np.float32) * 0.1
        return dict(tokens=t[:, :-1], labels=t[:, 1:], frames=frames)
    if arch.vision_prefix_tokens:
        text = s - arch.vision_prefix_tokens
        t = synth_tokens(dcfg, arch.vocab, step, batch=b, seq_len=text)
        patches = rng.standard_normal(
            (b // dcfg.n_hosts, arch.vision_prefix_tokens,
             arch.d_model)).astype(np.float32) * 0.1
        return dict(tokens=t[:, :-1], labels=t[:, 1:], patches=patches)
    t = synth_tokens(dcfg, arch.vocab, step, batch=b, seq_len=s)
    return dict(tokens=t[:, :-1], labels=t[:, 1:])


class DataIterator:
    """Stateful view over the stateless stream (checkpoint = step int)."""

    def __init__(self, arch: ArchConfig, dcfg: DataConfig, start_step: int = 0):
        self.arch = arch
        self.dcfg = dcfg
        self.step = start_step

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        batch = make_batch(self.arch, self.dcfg, self.step)
        self.step += 1
        return batch

    def state(self) -> int:
        return self.step

    @classmethod
    def restore(cls, arch: ArchConfig, dcfg: DataConfig,
                state: int) -> "DataIterator":
        return cls(arch, dcfg, start_step=state)
