from .store import CheckpointStore
