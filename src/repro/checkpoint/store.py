"""Async pytree checkpointing with elastic re-sharding on restore.

Checkpoints store LOGICAL arrays (fully-gathered numpy) plus the logical
sharding axes, so a restore may target a *different* mesh shape than the
save -- the elastic-rescale path: shardings are re-derived from the axes
tree under the new mesh and the arrays re-placed with device_put.

Layout:  <dir>/step_<n>/manifest.json  (+ one .npy per leaf)
         <dir>/LATEST                  (atomic pointer file)

Writes happen on a background thread (the train loop only pays for the
device_get); ``wait()`` joins outstanding writes, and save() of step N+1
joins the previous write first so at most one checkpoint is in flight.

At 1000+ node scale each host would write only its address-able shards
(tensorstore/OCDBT); the single-host layout keeps the same manifest schema
so that swap is local to this module (DESIGN.md §5).
"""
from __future__ import annotations

import json
import shutil
import threading
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out


class CheckpointStore:
    def __init__(self, directory: str):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree, extra: Optional[Dict] = None) -> None:
        """Gather to host then write asynchronously."""
        self.wait()
        host_leaves = [(k, np.asarray(jax.device_get(v)))
                       for k, v in _flatten(tree)]
        treedef = jax.tree_util.tree_structure(tree)

        def _write():
            try:
                tmp = self.dir / f".tmp_step_{step}"
                if tmp.exists():
                    shutil.rmtree(tmp)
                tmp.mkdir(parents=True)
                manifest = dict(step=step, extra=extra or {},
                                treedef=str(treedef),
                                leaves=[k for k, _ in host_leaves])
                for i, (k, v) in enumerate(host_leaves):
                    np.save(tmp / f"leaf_{i}.npy", v)
                (tmp / "manifest.json").write_text(json.dumps(manifest))
                final = self.dir / f"step_{step}"
                if final.exists():
                    shutil.rmtree(final)
                tmp.rename(final)
                (self.dir / "LATEST.tmp").write_text(str(step))
                (self.dir / "LATEST.tmp").rename(self.dir / "LATEST")
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # -- restore -------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        p = self.dir / "LATEST"
        if not p.exists():
            return None
        return int(p.read_text().strip())

    def restore(self, step: Optional[int], like_tree,
                shardings=None) -> Tuple[Any, Dict]:
        """Restore into the structure of ``like_tree``.

        ``shardings``: optional matching pytree of NamedSharding for elastic
        re-placement under the CURRENT mesh (which may differ from the mesh
        at save time); None keeps plain numpy/host arrays.
        """
        self.wait()
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = self.dir / f"step_{step}"
        manifest = json.loads((d / "manifest.json").read_text())
        leaves = [np.load(d / f"leaf_{i}.npy")
                  for i in range(len(manifest["leaves"]))]
        treedef = jax.tree_util.tree_structure(like_tree)
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.tree_util.tree_map(
                lambda v, s: jax.device_put(v, s) if s is not None else v,
                tree, shardings)
        return tree, manifest["extra"]
