from .sharding import (MeshContext, current_mesh, logical_spec, mesh_context,
                       shard, shard_params)
