"""Logical-axis sharding: mesh-agnostic models, mesh-specific placement.

Models annotate tensors with *logical* axes ("batch", "heads", "embed", ...);
this module resolves them to mesh axes under the active mesh and applies
``with_sharding_constraint``.  Resolution silently drops a mesh axis whenever
the dimension is not divisible by it (e.g. hymba's 25 heads on a 16-way
'model' axis, internvl2's 92553 vocab), so every architecture shards as far
as its shapes allow and replicates the rest -- no per-arch special cases.

Default rules (overridable per-context, the perf hillclimb uses this):
  batch   -> ('pod', 'data')     activations' batch dim (pure DP across pods)
  fsdp    -> 'data'              parameter / optimizer-state sharding (ZeRO-3)
  tp      -> 'model'             tensor-parallel dim (heads / ffn / vocab)
  kv_seq  -> 'model'             decode KV-cache sequence when heads < TP
  expert  -> 'model'             expert parallelism for MoE weight stacks
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[str, None]


def shard_map_compat(f, mesh, in_specs, out_specs, axis_names, check=False):
    """``jax.shard_map`` across jax versions.

    Newer jax exposes top-level ``jax.shard_map(..., axis_names=...,
    check_vma=...)``; 0.4.x has ``jax.experimental.shard_map.shard_map``
    where the manual axes are instead the complement of ``auto`` and the
    flag is ``check_rep``."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  axis_names=frozenset(axis_names), check_vma=check)
    from jax.experimental.shard_map import shard_map as sm
    auto = frozenset(mesh.axis_names) - frozenset(axis_names)
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              auto=auto, check_rep=check)

DEFAULT_RULES: Dict[str, Tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "fsdp": ("data",),
    "tp": ("model",),
    "heads": ("model",),
    "q_seq": ("model",),     # sequence parallelism when heads % tp != 0
    "kv_seq": ("model",),
    "expert": ("model",),
    "vocab": ("model",),
    "seq": (),
    "embed": (),
    "none": (),
}


class MeshContext(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: Dict[str, Tuple[str, ...]] = dict(DEFAULT_RULES)


_CTX = MeshContext()


@contextmanager
def mesh_context(mesh: Mesh, rules: Optional[Dict[str, Tuple[str, ...]]] = None):
    prev_mesh, prev_rules = _CTX.mesh, _CTX.rules
    _CTX.mesh = mesh
    _CTX.rules = {**DEFAULT_RULES, **(rules or {})}
    try:
        with mesh:
            yield mesh
    finally:
        _CTX.mesh, _CTX.rules = prev_mesh, prev_rules


def current_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def axis_size(name: str) -> int:
    """Size of a mesh axis under the active mesh (1 when absent)."""
    mesh = _CTX.mesh
    if mesh is None or name not in mesh.shape:
        return 1
    return int(mesh.shape[name])


def _resolve(logical: Sequence[Axis], shape: Sequence[int],
             mesh: Mesh) -> P:
    """Map logical axis names to mesh axes, dropping non-divisible ones."""
    out = []
    used: set = set()
    for dim, name in zip(shape, logical):
        if name is None:
            out.append(None)
            continue
        mesh_axes = _CTX.rules.get(name, (name,) if name in mesh.shape else ())
        picked = []
        size = 1
        for ax in mesh_axes:
            if ax in used or ax not in mesh.shape:
                continue
            nsize = size * mesh.shape[ax]
            if dim % nsize == 0:
                picked.append(ax)
                used.add(ax)
                size = nsize
        out.append(tuple(picked) if len(picked) > 1 else
                   (picked[0] if picked else None))
    return P(*out)


def logical_spec(logical: Sequence[Axis], shape: Sequence[int],
                 mesh: Optional[Mesh] = None) -> P:
    mesh = mesh or _CTX.mesh
    if mesh is None:
        return P()
    return _resolve(logical, shape, mesh)


def shard(x: jax.Array, *logical: Axis) -> jax.Array:
    """Apply a sharding constraint resolved from logical axis names.

    No-op outside a mesh context so tests / single-device runs are untouched.
    """
    mesh = _CTX.mesh
    if mesh is None:
        return x
    if len(logical) != x.ndim:
        raise ValueError(f"{len(logical)} axes for rank-{x.ndim} tensor")
    spec = _resolve(logical, x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def shard_params(params, axes_tree, mesh: Optional[Mesh] = None):
    """Build a NamedSharding pytree for a param tree + logical-axes tree."""
    mesh = mesh or _CTX.mesh

    def one(x, axes):
        if mesh is None:
            return None
        spec = _resolve(axes, np.shape(x), mesh)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map(one, params, axes_tree,
                                  is_leaf=lambda a: isinstance(a, tuple)
                                  and all(isinstance(e, (str, type(None)))
                                          for e in a))
