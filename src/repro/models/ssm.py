"""Recurrent / state-space blocks: mLSTM + sLSTM (xLSTM) and Mamba (hymba).

Training/prefill paths are chunkwise-parallel (mLSTM) or associative-scan
(Mamba) so sequence compute is matmul-shaped for the MXU; decode paths are
O(1)-state steps.  ``mlstm_sequential`` is the exact stabilized recurrence
used as the oracle in tests (and by kernels/mlstm_chunk/ref.py).

Dimensional note (DESIGN.md): xlstm-1.3b uses ssm_expand=1 with qk_dim =
head_dim/2, calibrated to the published 1.3B parameter count; the official
repo's block has proj_factor=2 with a narrower backbone.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..parallel.sharding import shard
from .config import ArchConfig
from .layers import Init, Params, rms_norm

# ---------------------------------------------------------------------------
# mLSTM cell
# ---------------------------------------------------------------------------


def mlstm_sequential(q, k, v, i_raw, lf, state=None):
    """Exact stabilized mLSTM recurrence (oracle + decode step).

    q,k [B,T,H,dk]; v [B,T,H,dv]; i_raw,lf [B,T,H] (lf = logsigmoid(f_raw)).
    state: (C [B,H,dk,dv], n [B,H,dk], m [B,H]).  Returns (h, state).
    """
    B, T, H, dk = q.shape
    dv = v.shape[-1]
    if state is None:
        C = jnp.zeros((B, H, dk, dv), jnp.float32)
        n = jnp.zeros((B, H, dk), jnp.float32)
        m = jnp.full((B, H), -jnp.inf, jnp.float32)
        state = (C, n, m)
    qf = q.astype(jnp.float32) / math.sqrt(dk)
    kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)
    i_raw = i_raw.astype(jnp.float32)
    lf = lf.astype(jnp.float32)

    def step(state, inp):
        C, n, m = state
        qt, kt, vt, it, ft = inp          # [B,H,dk] ... [B,H]
        m_new = jnp.maximum(ft + m, it)
        m_prev = jnp.where(jnp.isneginf(m), m_new, m)  # first step guard
        fp = jnp.exp(ft + m_prev - m_new) * (~jnp.isneginf(m))
        ip = jnp.exp(it - m_new)
        C = fp[..., None, None] * C + ip[..., None, None] * \
            (kt[..., :, None] * vt[..., None, :])
        n = fp[..., None] * n + ip[..., None] * kt
        num = jnp.einsum("bhd,bhdv->bhv", qt, C)
        den = jnp.abs(jnp.einsum("bhd,bhd->bh", qt, n))
        den = jnp.maximum(den, jnp.exp(-m_new))
        h = num / den[..., None]
        return (C, n, m_new), h

    xs = (qf.transpose(1, 0, 2, 3), kf.transpose(1, 0, 2, 3),
          vf.transpose(1, 0, 2, 3), i_raw.transpose(1, 0, 2),
          lf.transpose(1, 0, 2))
    state, hs = jax.lax.scan(step, state, xs)
    return hs.transpose(1, 0, 2, 3), state


def mlstm_chunkwise(q, k, v, i_raw, lf, state=None, chunk: int = 128):
    """Chunkwise-parallel stabilized mLSTM (training/prefill fast path)."""
    B, T, H, dk = q.shape
    dv = v.shape[-1]
    nc = max(1, T // chunk)
    assert nc * chunk == T, "sequence length must be a multiple of chunk"
    if state is None:
        C0 = jnp.zeros((B, H, dk, dv), jnp.float32)
        n0 = jnp.zeros((B, H, dk), jnp.float32)
        m0 = jnp.full((B, H), -jnp.inf, jnp.float32)
    else:
        C0, n0, m0 = state

    resh = lambda x, d: x.astype(jnp.float32).reshape(B, nc, chunk, H, d) \
        .transpose(1, 0, 3, 2, 4)  # [nc,B,H,Lc,d]
    qc = resh(q, dk) / math.sqrt(dk)
    kc = resh(k, dk)
    vc = resh(v, dv)
    ic = i_raw.astype(jnp.float32).reshape(B, nc, chunk, H).transpose(1, 0, 3, 2)
    fc = lf.astype(jnp.float32).reshape(B, nc, chunk, H).transpose(1, 0, 3, 2)

    def chunk_step(carry, inp):
        C, n, m = carry                       # running inter-chunk state
        qj, kj, vj, ij, fj = inp              # [B,H,Lc,(d)]
        b = jnp.cumsum(fj, axis=-1)           # [B,H,Lc] cumulative log-decay
        Btot = b[..., -1]
        m_fin = jnp.where(jnp.isneginf(m), 0.0, m)
        # intra-chunk log weights: w[t,s] = b_t - b_s + i_s  (s <= t)
        wl = b[..., :, None] - b[..., None, :] + ij[..., None, :]
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        wl = jnp.where(tri, wl, -jnp.inf)
        m_intra = wl.max(axis=-1)                         # [B,H,Lc]
        m_inter = b + m_fin[..., None]                    # [B,H,Lc]
        have_state = ~jnp.isneginf(m)
        m_row = jnp.maximum(m_intra, jnp.where(have_state[..., None],
                                               m_inter, -jnp.inf))
        m_row = jnp.where(jnp.isneginf(m_row), 0.0, m_row)
        P = jnp.exp(wl - m_row[..., None])                # [B,H,Lc,Lc]
        P = jnp.where(tri, P, 0.0)
        scores = jnp.einsum("bhtd,bhsd->bhts", qj, kj)
        num_intra = jnp.einsum("bhts,bhts,bhsv->bhtv", scores, P, vj)
        den_intra = jnp.einsum("bhts,bhts->bht", scores, P)
        inter_w = jnp.exp(m_inter - m_row) * have_state[..., None]
        num_inter = jnp.einsum("bht,bhtd,bhdv->bhtv", inter_w, qj, C)
        den_inter = inter_w * jnp.einsum("bhtd,bhd->bht", qj, n)
        den = jnp.maximum(jnp.abs(den_intra + den_inter), jnp.exp(-m_row))
        h = (num_intra + num_inter) / den[..., None]
        # ---- state update to end of chunk
        g = Btot[..., None] - b + ij                       # [B,H,Lc]
        m_state = jnp.maximum(g.max(axis=-1),
                              jnp.where(have_state, Btot + m_fin, -jnp.inf))
        sw = jnp.exp(g - m_state[..., None])
        carry_w = jnp.exp(Btot + m_fin - m_state) * have_state
        C_new = carry_w[..., None, None] * C + \
            jnp.einsum("bht,bhtd,bhtv->bhdv", sw, kj, vj)
        n_new = carry_w[..., None] * n + jnp.einsum("bht,bhtd->bhd", sw, kj)
        return (C_new, n_new, m_state), h

    (C, n, m), hs = jax.lax.scan(chunk_step, (C0, n0, m0),
                                 (qc, kc, vc, ic, fc))
    h = hs.transpose(1, 0, 3, 2, 4).reshape(B, T, H, dv)
    return h, (C, n, m)


def init_mlstm_block(ini: Init, cfg: ArchConfig) -> None:
    D = cfg.d_model
    Din = cfg.ssm_expand * D
    H = cfg.n_heads
    dqk = Din // H // 2
    ini.mk("norm", (D,), (None,), mode="zeros")
    ini.mk("up_l", (D, Din), ("fsdp", "tp"))
    ini.mk("up_r", (D, Din), ("fsdp", "tp"))
    ini.mk("conv_w", (cfg.conv_kernel, Din), (None, "tp"), scale=0.3)
    ini.mk("wq", (Din, H * dqk), ("fsdp", "tp"))
    ini.mk("wk", (Din, H * dqk), ("fsdp", "tp"))
    ini.mk("wv", (Din, Din), ("fsdp", "tp"))
    ini.mk("w_gates", (Din, 2 * H), ("fsdp", None), scale=0.02)
    ini.mk("b_gates", (2 * H,), (None,), mode="zeros")
    ini.mk("out_norm", (Din,), (None,), mode="zeros")
    ini.mk("down", (Din, D), ("tp", "fsdp"),
           scale=1.0 / math.sqrt(Din * 2 * cfg.n_layers))


def causal_conv1d(x: jax.Array, w: jax.Array,
                  state: Optional[jax.Array] = None):
    """Depthwise causal conv; x [B,T,C], w [K,C].  Returns (y, new_state)."""
    K = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype)
            for i in range(K))
    return y, xp[:, -(K - 1):] if K > 1 else jnp.zeros_like(x[:, :0])


def mlstm_block(params: Params, x: jax.Array, cfg: ArchConfig,
                state: Optional[Dict] = None,
                chunk: int = 128) -> Tuple[jax.Array, Optional[Dict]]:
    B, T, D = x.shape
    Din = cfg.ssm_expand * D
    H = cfg.n_heads
    dqk = Din // H // 2
    h = rms_norm(x, params["norm"], cfg.norm_eps)
    xl = h @ params["up_l"].astype(x.dtype)
    xr = h @ params["up_r"].astype(x.dtype)
    xl = shard(xl, "batch", None, "tp")
    conv_state = None if state is None else state["conv"]
    c, conv_new = causal_conv1d(xl, params["conv_w"], conv_state)
    c = jax.nn.silu(c)
    q = (c @ params["wq"].astype(x.dtype)).reshape(B, T, H, dqk)
    k = (c @ params["wk"].astype(x.dtype)).reshape(B, T, H, dqk)
    v = (xl @ params["wv"].astype(x.dtype)).reshape(B, T, H, -1)
    gates = c @ params["w_gates"].astype(x.dtype) + \
        params["b_gates"].astype(x.dtype)
    i_raw = gates[..., :H].astype(jnp.float32)
    lf = jax.nn.log_sigmoid(gates[..., H:].astype(jnp.float32))
    cell_state = None if state is None else state["cell"]
    if T == 1 or (T % chunk) != 0:
        hout, cell_new = mlstm_sequential(q, k, v, i_raw, lf, cell_state)
    else:
        hout, cell_new = mlstm_chunkwise(q, k, v, i_raw, lf, cell_state,
                                         chunk=chunk)
    hout = hout.reshape(B, T, Din).astype(x.dtype)
    hout = rms_norm(hout, params["out_norm"], cfg.norm_eps)
    y = (hout * jax.nn.silu(xr)) @ params["down"].astype(x.dtype)
    new_state = None
    if state is not None:
        new_state = dict(conv=conv_new, cell=cell_new)
    return shard(y, "batch", None, None), new_state


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def init_slstm_block(ini: Init, cfg: ArchConfig) -> None:
    D, H = cfg.d_model, cfg.n_heads
    dh = D // H
    ini.mk("norm", (D,), (None,), mode="zeros")
    for g in ("z", "i", "f", "o"):
        ini.mk(f"w{g}", (D, D), ("fsdp", "tp"))
        ini.mk(f"r{g}", (H, dh, dh), (None, None, None), scale=1.0 / math.sqrt(dh))
        ini.mk(f"b{g}", (D,), (None,), mode="zeros")
    ini.mk("out_norm", (D,), (None,), mode="zeros")
    ini.mk("down", (D, D), ("tp", "fsdp"),
           scale=1.0 / math.sqrt(D * 2 * cfg.n_layers))
    # small FFN (factor 4/3, GeGLU) as in the xLSTM paper's sLSTM block
    dff = int(4 * D / 3 / 64) * 64 or 64
    ini.mk("ffn_gate", (D, dff), ("fsdp", "tp"))
    ini.mk("ffn_up", (D, dff), ("fsdp", "tp"))
    ini.mk("ffn_down", (dff, D), ("tp", "fsdp"),
           scale=1.0 / math.sqrt(dff * 2 * cfg.n_layers))
    ini.mk("ffn_norm", (D,), (None,), mode="zeros")


def slstm_block(params: Params, x: jax.Array, cfg: ArchConfig,
                state: Optional[Dict] = None) -> Tuple[jax.Array, Optional[Dict]]:
    B, T, D = x.shape
    H = cfg.n_heads
    dh = D // H
    xin = rms_norm(x, params["norm"], cfg.norm_eps)
    pre = {g: (xin @ params[f"w{g}"].astype(x.dtype) +
               params[f"b{g}"].astype(x.dtype)).astype(jnp.float32)
           .reshape(B, T, H, dh) for g in ("z", "i", "f", "o")}
    if state is None:
        h0 = jnp.zeros((B, H, dh), jnp.float32)
        c0 = jnp.zeros((B, H, dh), jnp.float32)
        n0 = jnp.zeros((B, H, dh), jnp.float32)
        m0 = jnp.full((B, H, dh), -jnp.inf, jnp.float32)
    else:
        h0, c0, n0, m0 = state["h"], state["c"], state["n"], state["m"]
    R = {g: params[f"r{g}"].astype(jnp.float32) for g in ("z", "i", "f", "o")}

    def step(carry, inp):
        h, c, n, m = carry
        pz, pi, pf, po = inp
        rec = lambda g: jnp.einsum("bhd,hde->bhe", h, R[g])
        z = jnp.tanh(pz + rec("z"))
        it = pi + rec("i")
        ft = jax.nn.log_sigmoid(pf + rec("f"))
        o = jax.nn.sigmoid(po + rec("o"))
        m_new = jnp.maximum(ft + m, it)
        m_prev = jnp.where(jnp.isneginf(m), m_new, m)
        fp = jnp.exp(ft + m_prev - m_new) * (~jnp.isneginf(m))
        ip = jnp.exp(it - m_new)
        c = fp * c + ip * z
        n = fp * n + ip
        h = o * c / jnp.maximum(n, 1e-6)
        return (h, c, n, m_new), h

    xs = tuple(pre[g].transpose(1, 0, 2, 3) for g in ("z", "i", "f", "o"))
    (h, c, n, m), hs = jax.lax.scan(step, (h0, c0, n0, m0), xs)
    hout = hs.transpose(1, 0, 2, 3).reshape(B, T, D).astype(x.dtype)
    hout = rms_norm(hout, params["out_norm"], cfg.norm_eps)
    y = x + hout @ params["down"].astype(x.dtype)
    # FFN sub-block
    f = rms_norm(y, params["ffn_norm"], cfg.norm_eps)
    f = (jax.nn.gelu(f @ params["ffn_gate"].astype(x.dtype))
         * (f @ params["ffn_up"].astype(x.dtype)))
    y = y + f @ params["ffn_down"].astype(x.dtype)
    new_state = None
    if state is not None:
        new_state = dict(h=h, c=c, n=n, m=m)
    return shard(y - x, "batch", None, None), new_state  # residual added by caller


# ---------------------------------------------------------------------------
# Mamba (selective diagonal SSM), hymba's parallel branch
# ---------------------------------------------------------------------------


def init_mamba(ini: Init, cfg: ArchConfig, prefix: str = "") -> None:
    D = cfg.d_model
    Din = cfg.ssm_expand * D
    St = cfg.ssm_state
    dt_rank = max(1, math.ceil(D / 16))
    ini.mk(prefix + "in_proj", (D, 2 * Din), ("fsdp", "tp"))
    ini.mk(prefix + "conv_w", (cfg.conv_kernel, Din), (None, "tp"), scale=0.3)
    ini.mk(prefix + "x_proj", (Din, dt_rank + 2 * St), ("tp", None), scale=0.02)
    ini.mk(prefix + "dt_proj", (dt_rank, Din), (None, "tp"), scale=0.1)
    ini.mk(prefix + "dt_bias", (Din,), (None,), mode="zeros")
    ini.mk(prefix + "A_log", (Din, St), ("tp", None), mode="ones")
    ini.mk(prefix + "D_skip", (Din,), (None,), mode="ones")
    ini.mk(prefix + "out_proj", (Din, D), ("tp", "fsdp"),
           scale=1.0 / math.sqrt(Din * 2 * cfg.n_layers))


def mamba(params: Params, x: jax.Array, cfg: ArchConfig,
          state: Optional[Dict] = None,
          prefix: str = "") -> Tuple[jax.Array, Optional[Dict]]:
    B, T, D = x.shape
    Din = cfg.ssm_expand * D
    St = cfg.ssm_state
    dt_rank = max(1, math.ceil(D / 16))
    xz = x @ params[prefix + "in_proj"].astype(x.dtype)
    xs, z = jnp.split(xz, 2, axis=-1)
    xs = shard(xs, "batch", None, "tp")
    conv_state = None if state is None else state["conv"]
    xs, conv_new = causal_conv1d(xs, params[prefix + "conv_w"], conv_state)
    xs = jax.nn.silu(xs)
    proj = xs @ params[prefix + "x_proj"].astype(x.dtype)
    dt = jax.nn.softplus(
        proj[..., :dt_rank] @ params[prefix + "dt_proj"].astype(x.dtype)
        + params[prefix + "dt_bias"].astype(x.dtype)).astype(jnp.float32)
    Bc = proj[..., dt_rank:dt_rank + St].astype(jnp.float32)     # [B,T,St]
    Cc = proj[..., dt_rank + St:].astype(jnp.float32)            # [B,T,St]
    A = -jnp.exp(params[prefix + "A_log"].astype(jnp.float32))   # [Din,St]

    if T == 1:
        a = jnp.exp(dt[..., None] * A[None, None])
        bx = (dt * xs.astype(jnp.float32))[..., None] * Bc[:, :, None, :]
        h_prev = (jnp.zeros((B, Din, St), jnp.float32) if state is None
                  else state["h"])
        h = a[:, 0] * h_prev + bx[:, 0]
        y = jnp.einsum("bds,bs->bd", h, Cc[:, 0])[:, None]
        h_new = h
    else:
        # chunked parallel scan: the discretized [B, chunk, Din, St]
        # tensors are built INSIDE the chunk body (never for the full T --
        # at T=4k they are ~1.7 GB each per layer), associative_scan runs
        # log-depth within the chunk, a sequential carry links chunks, and
        # the body is checkpointed so backward recomputes one chunk at a
        # time instead of stacking every chunk's scan levels.
        def combine(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, a2 * b1 + b2

        chunk = min(128, T)
        while T % chunk:
            chunk -= 1
        nc = T // chunk
        resh = lambda t: t.reshape((B, nc, chunk) + t.shape[2:]) \
            .transpose((1, 0, 2) + tuple(range(3, t.ndim + 1)))
        dt_c, xs_c, B_c, C_c = (resh(dt), resh(xs.astype(jnp.float32)),
                                resh(Bc), resh(Cc))
        h0 = (jnp.zeros((B, Din, St), jnp.float32) if state is None
              else state["h"])

        @jax.checkpoint
        def chunk_body(h_prev, inp):
            dtj, xsj, bj_in, cj = inp
            aj = jnp.exp(dtj[..., None] * A[None, None])
            bj = (dtj * xsj)[..., None] * bj_in[:, :, None, :]
            bj = bj.at[:, 0].add(aj[:, 0] * h_prev)
            _, h_all = jax.lax.associative_scan(combine, (aj, bj), axis=1)
            yj = jnp.einsum("btds,bts->btd", h_all, cj)
            return h_all[:, -1], yj

        h_new, yc = jax.lax.scan(chunk_body, h0, (dt_c, xs_c, B_c, C_c))
        y = yc.transpose(1, 0, 2, 3).reshape(B, T, Din)
    y = y + params[prefix + "D_skip"].astype(jnp.float32) * xs.astype(jnp.float32)
    y = (y.astype(x.dtype) * jax.nn.silu(z))
    out = y @ params[prefix + "out_proj"].astype(x.dtype)
    new_state = None
    if state is not None:
        new_state = dict(conv=conv_new, h=h_new)
    return shard(out, "batch", None, None), new_state
