"""Shared transformer building blocks (pure JAX, mesh-agnostic).

Attention is flash-style (KV-chunked online softmax) in plain jnp so it
compiles on any backend and doubles as the oracle for the Pallas kernel in
kernels/flash_attention.  Supports GQA, sliding windows, logit softcaps,
qk-norm and MLA.  MoE uses capacity-based dispatch blocked over token groups
(GShard-style) so the HLO FLOPs reflect *active* expert compute.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..parallel.sharding import shard, shard_map_compat
from .config import ArchConfig

Params = Dict[str, Any]

# ---------------------------------------------------------------------------
# init helper
# ---------------------------------------------------------------------------


class Init:
    """Collects parameter arrays + their logical sharding axes."""

    def __init__(self, key: jax.Array):
        self._key = key
        self.params: Params = {}
        self.axes: Dict[str, Tuple] = {}

    def _next(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    def mk(self, name: str, shape, axes, scale: Optional[float] = None,
           mode: str = "normal") -> None:
        assert len(axes) == len(shape), (name, shape, axes)
        if mode == "zeros":
            val = jnp.zeros(shape, jnp.float32)
        elif mode == "ones":
            val = jnp.ones(shape, jnp.float32)
        else:
            if scale is None:
                fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
                scale = 1.0 / math.sqrt(max(1, fan_in))
            val = scale * jax.random.normal(self._next(), shape, jnp.float32)
        self.params[name] = val
        self.axes[name] = tuple(axes)

    def sub(self, name: str, init_fn) -> None:
        """Nest another init under ``name``."""
        child = Init(self._next())
        init_fn(child)
        self.params[name] = child.params
        self.axes[name] = child.axes


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def rope(x: jax.Array, positions: jax.Array, theta: float,
         rot_dims: Optional[int] = None) -> jax.Array:
    """Rotary embedding on the last dim; x [..., S, H, D], positions [..., S]."""
    d = rot_dims or x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(0, half, dtype=jnp.float32)
                    / half)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, half]
    cos = jnp.cos(ang)[..., :, None, :]   # [..., S, 1, half]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:d]
    rx1 = x1 * cos - x2 * sin
    rx2 = x2 * cos + x1 * sin
    out = jnp.concatenate([rx1, rx2, x[..., d:]], axis=-1)
    return out.astype(x.dtype)


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# flash-style attention (jnp oracle; Pallas kernel mirrors this)
# ---------------------------------------------------------------------------


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    q_positions: jax.Array, kv_positions: jax.Array,
                    causal: bool = True, window: Optional[int] = None,
                    logit_cap: Optional[float] = None,
                    kv_chunk: int = 1024) -> jax.Array:
    """Online-softmax attention, scanned over KV chunks.

    q [B, Sq, H, D]; k/v [B, Skv, KH, D(v)]; GQA via H = KH * G.
    ``kv_positions`` < 0 marks padded/unwritten cache slots (masked out).
    Never materializes the [Sq, Skv] score matrix beyond one chunk.
    """
    B, Sq, H, D = q.shape
    Skv, KH = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = H // KH
    scale = 1.0 / math.sqrt(D)
    qg = (q * scale).reshape(B, Sq, KH, G, D).astype(jnp.float32)

    n_chunks = max(1, math.ceil(Skv / kv_chunk))
    pad = n_chunks * kv_chunk - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, (0, pad), constant_values=-1)
    kc = k.reshape(B, n_chunks, kv_chunk, KH, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, kv_chunk, KH, Dv).transpose(1, 0, 2, 3, 4)
    pc = kv_positions.reshape(n_chunks, kv_chunk)

    def chunk_step(carry, inp):
        m, l, acc = carry
        kj, vj, pj = inp          # [B, C, KH, D], [B, C, KH, Dv], [C]
        s = jnp.einsum("bqhgd,bchd->bqhgc", qg, kj.astype(jnp.float32))
        s = softcap(s, logit_cap)
        mask = (pj >= 0)[None, None, None, None, :]
        if causal:
            rel = q_positions[None, :, None, None, None] - \
                pj[None, None, None, None, :]
            mask = mask & (rel >= 0)
            if window is not None:
                mask = mask & (rel < window)
        s = jnp.where(mask, s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(axis=-1))
        # guard fully-masked rows
        m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(jnp.where(jnp.isneginf(m), 0.0, m) - m_safe)
        corr = jnp.where(jnp.isneginf(m), 0.0, corr)
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bqhgc,bchv->bqhgv", p, vj.astype(jnp.float32))
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Sq, KH, G), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, Sq, KH, G), jnp.float32)
    a0 = jnp.zeros((B, Sq, KH, G, Dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(chunk_step, (m0, l0, a0), (kc, vc, pc))
    out = acc / jnp.maximum(l, 1e-20)[..., None]
    return out.reshape(B, Sq, H, Dv)


def direct_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                     q_positions: jax.Array, kv_positions: jax.Array,
                     causal: bool = True, window: Optional[int] = None,
                     logit_cap: Optional[float] = None) -> jax.Array:
    """Unchunked attention for short q (decode): one einsum over the cache.

    Because there is no sequential chunk scan, the XLA SPMD partitioner can
    shard k/v along the *sequence* axis and lower the softmax max/sum into
    all-reduces -- distributed flash-decode.  Memory is O(B*H*Sq*Skv) scores,
    fine for Sq <= a few tokens.
    """
    B, Sq, H, D = q.shape
    Skv, KH = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = H // KH
    qg = (q.astype(jnp.float32) / math.sqrt(D)).reshape(B, Sq, KH, G, D)
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qg, k.astype(jnp.float32))
    s = softcap(s, logit_cap)
    mask = (kv_positions >= 0)[None, None, None, None, :]
    if causal:
        rel = q_positions[None, :, None, None, None] - \
            kv_positions[None, None, None, None, :]
        mask = mask & (rel >= 0)
        if window is not None:
            mask = mask & (rel < window)
    s = jnp.where(mask, s, -jnp.inf)
    m = jnp.max(s, axis=-1, keepdims=True)
    m = jnp.where(jnp.isneginf(m), 0.0, m)
    p = jnp.exp(s - m)
    p = jnp.where(mask, p, 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bqhgk,bkhv->bqhgv", p, v.astype(jnp.float32))
    out = out / jnp.maximum(l, 1e-20)
    return out.reshape(B, Sq, H, Dv)


# Q sequence lengths up to this use the direct (seq-shardable) path.
DECODE_DIRECT_MAX_Q = 8


# ---------------------------------------------------------------------------
# flash attention with a TRUE flash backward (custom VJP)
#
# Differentiating the chunked forward scan makes JAX stack every chunk's
# probability tensor for the VJP: O(n_chunks * B * Sq * H * chunk) fp32 --
# measured 190+ GB/device on hymba train_4k.  The custom backward below
# recomputes scores one kv chunk at a time (the standard FlashAttention-2
# backward), carrying only dq and emitting dk/dv per chunk.
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8))
def _flash(q, k, v, q_positions, kv_positions, causal, window, logit_cap,
           kv_chunk):
    return flash_attention(q, k, v, q_positions=q_positions,
                           kv_positions=kv_positions, causal=causal,
                           window=window, logit_cap=logit_cap,
                           kv_chunk=kv_chunk)


def _flash_fwd(q, k, v, q_positions, kv_positions, causal, window,
               logit_cap, kv_chunk):
    out = _flash(q, k, v, q_positions, kv_positions, causal, window,
                 logit_cap, kv_chunk)
    return out, (q, k, v, q_positions, kv_positions, out)


def _flash_bwd(causal, window, logit_cap, kv_chunk, res, do):
    q, k, v, q_positions, kv_positions, out = res
    B, Sq, H, D = q.shape
    Skv, KH = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = H // KH
    scale = 1.0 / math.sqrt(D)
    qg = (q.astype(jnp.float32) * scale).reshape(B, Sq, KH, G, D)
    og = out.astype(jnp.float32).reshape(B, Sq, KH, G, Dv)
    dog = do.astype(jnp.float32).reshape(B, Sq, KH, G, Dv)
    delta = jnp.sum(og * dog, axis=-1)                     # [B,Sq,KH,G]

    n_chunks = max(1, math.ceil(Skv / kv_chunk))
    pad = n_chunks * kv_chunk - Skv
    kp, vp, kvp = k, v, kv_positions
    if pad:
        kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kvp = jnp.pad(kv_positions, (0, pad), constant_values=-1)
    kc = kp.reshape(B, n_chunks, kv_chunk, KH, D).transpose(1, 0, 2, 3, 4)
    vc = vp.reshape(B, n_chunks, kv_chunk, KH, Dv).transpose(1, 0, 2, 3, 4)
    pc = kvp.reshape(n_chunks, kv_chunk)

    # softmax statistics are recomputed from a first light pass: exact
    # log-sum-exp via the forward oracle is equivalent to caching (m, l);
    # we recompute row max/sum per chunk pair-free using the forward's out
    # identity  p = exp(s - lse)  with  lse = log l + m  derived below.
    # One extra pass computes lse exactly:
    def lse_pass(carry, inp):
        m_run, l_run = carry
        kj, pj = inp
        s = jnp.einsum("bqhgd,bchd->bqhgc", qg, kj.astype(jnp.float32))
        s = softcap(s, logit_cap)
        mask = (pj >= 0)[None, None, None, None, :]
        if causal:
            rel = q_positions[None, :, None, None, None] - \
                pj[None, None, None, None, :]
            mask = mask & (rel >= 0)
            if window is not None:
                mask = mask & (rel < window)
        s = jnp.where(mask, s, -jnp.inf)
        m_new = jnp.maximum(m_run, s.max(axis=-1))
        m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
        corr = jnp.exp(jnp.where(jnp.isneginf(m_run), 0.0, m_run) - m_safe)
        corr = jnp.where(jnp.isneginf(m_run), 0.0, corr)
        p = jnp.where(mask, jnp.exp(s - m_safe[..., None]), 0.0)
        return (m_new, l_run * corr + p.sum(axis=-1)), None

    m0 = jnp.full((B, Sq, KH, G), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, Sq, KH, G), jnp.float32)
    (m_fin, l_fin), _ = jax.lax.scan(lse_pass, (m0, l0), (kc, pc))
    m_safe = jnp.where(jnp.isneginf(m_fin), 0.0, m_fin)
    lse = m_safe + jnp.log(jnp.maximum(l_fin, 1e-20))      # [B,Sq,KH,G]

    def bwd_chunk(dq_acc, inp):
        kj, vj, pj = inp
        kf = kj.astype(jnp.float32)
        s = jnp.einsum("bqhgd,bchd->bqhgc", qg, kf)
        t = s if logit_cap is None else s / logit_cap
        sc = softcap(s, logit_cap)
        mask = (pj >= 0)[None, None, None, None, :]
        if causal:
            rel = q_positions[None, :, None, None, None] - \
                pj[None, None, None, None, :]
            mask = mask & (rel >= 0)
            if window is not None:
                mask = mask & (rel < window)
        p = jnp.where(mask, jnp.exp(sc - lse[..., None]), 0.0)
        dv_j = jnp.einsum("bqhgc,bqhgv->bchv", p, dog)
        dp = jnp.einsum("bqhgv,bchv->bqhgc", dog, vj.astype(jnp.float32))
        ds = p * (dp - delta[..., None])
        if logit_cap is not None:                 # d softcap = 1 - tanh^2
            ds = ds * (1.0 - jnp.tanh(t) ** 2)
        dq_acc = dq_acc + jnp.einsum("bqhgc,bchd->bqhgd", ds, kf)
        dk_j = jnp.einsum("bqhgc,bqhgd->bchd", ds, qg)
        return dq_acc, (dk_j, dv_j)

    dq0 = jnp.zeros((B, Sq, KH, G, D), jnp.float32)
    dq, (dk_c, dv_c) = jax.lax.scan(bwd_chunk, dq0, (kc, vc, pc))
    dq = (dq * scale).reshape(B, Sq, H, D).astype(q.dtype)
    dk = dk_c.transpose(1, 0, 2, 3, 4).reshape(B, n_chunks * kv_chunk,
                                               KH, D)[:, :Skv].astype(k.dtype)
    dv = dv_c.transpose(1, 0, 2, 3, 4).reshape(B, n_chunks * kv_chunk,
                                               KH, Dv)[:, :Skv].astype(v.dtype)
    return dq, dk, dv, None, None


_flash.defvjp(_flash_fwd, _flash_bwd)


def attend(q, k, v, *, q_positions, kv_positions, causal=True, window=None,
           logit_cap=None, kv_chunk: int = 512):
    """Dispatch: direct path for decode-sized q, flash (custom VJP) else."""
    if q.shape[1] <= DECODE_DIRECT_MAX_Q:
        return direct_attention(q, k, v, q_positions=q_positions,
                                kv_positions=kv_positions, causal=causal,
                                window=window, logit_cap=logit_cap)
    return _flash(q, k, v, q_positions, kv_positions, causal, window,
                  logit_cap, kv_chunk)


# ---------------------------------------------------------------------------
# attention block (GQA / SWA / softcap / qk-norm) with KV cache
# ---------------------------------------------------------------------------


def init_attention(ini: Init, cfg: ArchConfig, prefix: str = "") -> None:
    D, H, KH, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ini.mk(prefix + "wq", (D, H * Dh), ("fsdp", "tp"))
    ini.mk(prefix + "wk", (D, KH * Dh), ("fsdp", "tp"))
    ini.mk(prefix + "wv", (D, KH * Dh), ("fsdp", "tp"))
    ini.mk(prefix + "wo", (H * Dh, D), ("tp", "fsdp"),
           scale=1.0 / math.sqrt(H * Dh * 2 * cfg.n_layers))
    if cfg.qk_norm:
        ini.mk(prefix + "q_norm", (Dh,), (None,), mode="zeros")
        ini.mk(prefix + "k_norm", (Dh,), (None,), mode="zeros")


def attention(params: Params, x: jax.Array, cfg: ArchConfig, *,
              positions: jax.Array, cache: Optional[Dict] = None,
              causal: bool = True, window: Optional[int] = None,
              prefix: str = "") -> Tuple[jax.Array, Optional[Dict]]:
    """x [B, S, D] -> [B, S, D].  cache: {"k","v" [B,Smax,KH,Dh], "pos" []}.

    SWA cache is a ring buffer of size Smax (== window for windowed layers):
    slot = position % Smax; slot positions are tracked in cache["pos_ids"].
    """
    B, S, D = x.shape
    H, KH, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ params[prefix + "wq"].astype(x.dtype)).reshape(B, S, H, Dh)
    k = (x @ params[prefix + "wk"].astype(x.dtype)).reshape(B, S, KH, Dh)
    v = (x @ params[prefix + "wv"].astype(x.dtype)).reshape(B, S, KH, Dh)
    if cfg.qk_norm:
        q = rms_norm(q, params[prefix + "q_norm"], cfg.norm_eps)
        k = rms_norm(k, params[prefix + "k_norm"], cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    from ..parallel.sharding import axis_size
    if H % max(1, axis_size("model")) == 0 or S <= DECODE_DIRECT_MAX_Q:
        q = shard(q, "batch", None, "heads", None)
    else:
        # heads don't divide the TP axis (hymba: 25 heads on 16-way model):
        # fall back to sequence parallelism for the q rows so attention
        # compute doesn't silently replicate across the model axis.
        q = shard(q, "batch", "q_seq", None, None)
    k = shard(k, "batch", None, "heads", None)

    if cache is None:
        kv_pos = positions[0] if positions.ndim == 2 else positions
        out = attend(q, k, v, q_positions=kv_pos,
                     kv_positions=kv_pos, causal=causal,
                     window=window,
                     logit_cap=cfg.attn_logit_softcap)
        new_cache = None
    else:
        Smax = cache["k"].shape[1]
        slots = positions % Smax                       # ring-buffer slots
        ck = _scatter_kv(cache["k"], k, slots)
        cv = _scatter_kv(cache["v"], v, slots)
        pos_ids = cache["pos_ids"].at[slots].set(positions)
        ck = shard(ck, "batch", "kv_seq", None, None)
        cv = shard(cv, "batch", "kv_seq", None, None)
        out = attend(q, ck, cv, q_positions=positions,
                     kv_positions=pos_ids, causal=causal,
                     window=window,
                     logit_cap=cfg.attn_logit_softcap)
        new_cache = dict(k=ck, v=cv, pos_ids=pos_ids)
    out = out.astype(x.dtype).reshape(B, S, H * Dh)
    y = out @ params[prefix + "wo"].astype(x.dtype)
    return shard(y, "batch", None, None), new_cache


def _scatter_kv(buf: jax.Array, new: jax.Array, slots: jax.Array) -> jax.Array:
    """buf [B,Smax,KH,Dh] <- new [B,S,KH,Dh] at ``slots`` [S]."""
    return buf.astype(new.dtype).at[:, slots].set(new)


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2): low-rank compressed KV with decoupled RoPE
# ---------------------------------------------------------------------------


def init_mla(ini: Init, cfg: ArchConfig) -> None:
    D, H = cfg.d_model, cfg.n_heads
    dn, dr, dv = cfg.head_dim, cfg.rope_head_dim, cfg.v_dim
    ini.mk("wq_a", (D, cfg.q_lora_rank), ("fsdp", None))
    ini.mk("q_a_norm", (cfg.q_lora_rank,), (None,), mode="zeros")
    ini.mk("wq_b", (cfg.q_lora_rank, H * (dn + dr)), (None, "tp"))
    ini.mk("wkv_a", (D, cfg.kv_lora_rank + dr), ("fsdp", None))
    ini.mk("kv_a_norm", (cfg.kv_lora_rank,), (None,), mode="zeros")
    ini.mk("wk_b", (cfg.kv_lora_rank, H * dn), (None, "tp"))
    ini.mk("wv_b", (cfg.kv_lora_rank, H * dv), (None, "tp"))
    ini.mk("wo", (H * dv, D), ("tp", "fsdp"),
           scale=1.0 / math.sqrt(H * dv * 2 * cfg.n_layers))


def mla_attention(params: Params, x: jax.Array, cfg: ArchConfig, *,
                  positions: jax.Array, cache: Optional[Dict] = None
                  ) -> Tuple[jax.Array, Optional[Dict]]:
    """Cache holds the compressed c_kv [B, Smax, kv_lora] + k_rope."""
    B, S, D = x.shape
    H = cfg.n_heads
    dn, dr, dv = cfg.head_dim, cfg.rope_head_dim, cfg.v_dim
    rank = cfg.kv_lora_rank

    qa = rms_norm(x @ params["wq_a"].astype(x.dtype), params["q_a_norm"],
                  cfg.norm_eps)
    q = (qa @ params["wq_b"].astype(x.dtype)).reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)

    kv_a = x @ params["wkv_a"].astype(x.dtype)          # [B,S,rank+dr]
    c_kv = rms_norm(kv_a[..., :rank], params["kv_a_norm"], cfg.norm_eps)
    k_rope = rope(kv_a[..., None, rank:], positions, cfg.rope_theta)  # [B,S,1,dr]

    if cache is not None:
        Smax = cache["c_kv"].shape[1]
        slots = positions % Smax
        c_kv = cache["c_kv"].astype(x.dtype).at[:, slots].set(c_kv)
        k_rope = cache["k_rope"].astype(x.dtype).at[:, slots].set(
            k_rope.squeeze(2))[..., None, :]
        pos_ids = cache["pos_ids"].at[slots].set(positions)
        c_kv = shard(c_kv, "batch", "kv_seq", None)
        new_cache = dict(c_kv=c_kv, k_rope=k_rope.squeeze(2), pos_ids=pos_ids)
    else:
        pos_ids = positions
        new_cache = None

    if S <= DECODE_DIRECT_MAX_Q and cache is not None:
        # Absorbed decode path: attention runs IN the compressed space, the
        # cache is never expanded to per-head K/V (the point of MLA).
        #   q_c[b,s,h,r]   = q_nope . wk_b(head h)          (W^UK absorbed)
        #   score          = q_c . c_kv + q_rope . k_rope
        #   out            = (softmax . c_kv) @ wv_b        (W^UV absorbed)
        wk_b = params["wk_b"].astype(x.dtype).reshape(rank, H, dn)
        wv_b = params["wv_b"].astype(x.dtype).reshape(rank, H, dv)
        q_c = jnp.einsum("bshd,rhd->bshr", q_nope, wk_b).astype(jnp.float32)
        scale = 1.0 / math.sqrt(dn + dr)
        s_c = jnp.einsum("bshr,bkr->bshk", q_c,
                         c_kv.astype(jnp.float32)) * scale
        s_r = jnp.einsum("bshd,bkd->bshk", q_rope.astype(jnp.float32),
                         k_rope.squeeze(2).astype(jnp.float32)) * scale
        s = s_c + s_r
        mask = (pos_ids >= 0)[None, None, None, :]
        rel = positions[None, :, None, None] - pos_ids[None, None, None, :]
        mask = mask & (rel >= 0)
        s = jnp.where(mask, s, -jnp.inf)
        m = jnp.max(s, axis=-1, keepdims=True)
        p = jnp.exp(s - jnp.where(jnp.isneginf(m), 0.0, m))
        p = jnp.where(mask, p, 0.0)
        p = p / jnp.maximum(p.sum(-1, keepdims=True), 1e-20)
        out_c = jnp.einsum("bshk,bkr->bshr", p, c_kv.astype(jnp.float32))
        out = jnp.einsum("bshr,rhv->bshv", out_c.astype(x.dtype), wv_b)
    else:
        # expand compressed KV to per-head keys/values (train / prefill)
        Skv = c_kv.shape[1]
        k_nope = (c_kv @ params["wk_b"].astype(x.dtype)).reshape(B, Skv, H, dn)
        val = (c_kv @ params["wv_b"].astype(x.dtype)).reshape(B, Skv, H, dv)
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, (B, Skv, H, dr))], axis=-1)
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        q_full = shard(q_full, "batch", None, "heads", None)
        k_full = shard(k_full, "batch", None, "heads", None)
        out = attend(q_full, k_full, val, q_positions=positions,
                     kv_positions=pos_ids, causal=True)
    out = out.astype(x.dtype).reshape(B, S, H * dv)
    y = out @ params["wo"].astype(x.dtype)
    return shard(y, "batch", None, None), new_cache


# ---------------------------------------------------------------------------
# dense MLP + MoE
# ---------------------------------------------------------------------------


def init_mlp(ini: Init, d_model: int, d_ff: int, n_layers: int,
             prefix: str = "") -> None:
    ini.mk(prefix + "w_gate", (d_model, d_ff), ("fsdp", "tp"))
    ini.mk(prefix + "w_up", (d_model, d_ff), ("fsdp", "tp"))
    ini.mk(prefix + "w_down", (d_ff, d_model), ("tp", "fsdp"),
           scale=1.0 / math.sqrt(d_ff * 2 * n_layers))


def mlp(params: Params, x: jax.Array, prefix: str = "") -> jax.Array:
    g = x @ params[prefix + "w_gate"].astype(x.dtype)
    u = x @ params[prefix + "w_up"].astype(x.dtype)
    h = jax.nn.silu(g) * u
    h = shard(h, "batch", None, "tp")
    y = h @ params[prefix + "w_down"].astype(x.dtype)
    return shard(y, "batch", None, None)


def init_moe(ini: Init, cfg: ArchConfig) -> None:
    D, E, F = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ini.mk("router", (D, E), ("fsdp", None), scale=0.02)
    ini.mk("we_gate", (E, D, F), ("expert", "fsdp", None))
    ini.mk("we_up", (E, D, F), ("expert", "fsdp", None))
    ini.mk("we_down", (E, F, D), ("expert", None, "fsdp"),
           scale=1.0 / math.sqrt(F * 2 * cfg.n_layers))
    if cfg.n_shared_experts:
        init_mlp(ini, D, cfg.moe_d_ff * cfg.n_shared_experts, cfg.n_layers,
                 prefix="shared_")


def moe_onehot_group(params: Params, xg: jax.Array, cfg: ArchConfig,
                     cap: int) -> jax.Array:
    """GShard-style matmul dispatch for one token group (default impl).

    The classic [Tg, K, E, C] position one-hot is avoided by gathering each
    (token, k)'s queue position at its SELECTED expert, so the dispatch mask
    is built from two 3-D one-hots: disp = einsum("tke,tkc->tec").  The
    dispatch/combine matmuls are what GSPMD partitions into all-to-alls.
    """
    Tg, D = xg.shape
    E, K = cfg.n_experts, cfg.top_k
    xg = shard(xg, "batch", None)
    logits = (xg @ params["router"].astype(xg.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                 # [Tg, E]
    gate_vals, expert_idx = jax.lax.top_k(probs, K)         # [Tg, K]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)
    onehot = jax.nn.one_hot(expert_idx, E, dtype=jnp.float32)  # [Tg,K,E]
    # the queue-position cumsum is inherently sequential over tokens, so it
    # de-shards its (tiny) [Tg*K, E] operand; everything downstream is
    # re-constrained to token sharding so the heavy dispatch/combine einsums
    # stay distributed (without this they silently replicate 256-way).
    pos = jnp.cumsum(onehot.reshape(Tg * K, E), axis=0) - 1.0
    pos = pos.reshape(Tg, K, E)
    # queue position at the selected expert only: [Tg, K]
    pos_sel = jnp.take_along_axis(
        pos, expert_idx[..., None], axis=-1)[..., 0]
    in_cap = (pos_sel < cap).astype(jnp.float32)            # [Tg, K]
    poh_c = jax.nn.one_hot(
        jnp.clip(pos_sel, 0, cap - 1).astype(jnp.int32), cap,
        dtype=jnp.float32)                                  # [Tg, K, C]
    poh_c = shard(poh_c, "batch", None, None)
    onehot = shard(onehot, "batch", None, None)
    disp = jnp.einsum("tke,tkc,tk->tec", onehot, poh_c, in_cap)
    disp = shard(disp, "batch", None, None)
    comb = jnp.einsum("tec,tke,tk->tec", disp, onehot, gate_vals)
    comb = shard(comb, "batch", None, None)
    disp = disp.astype(xg.dtype)
    xe = jnp.einsum("tec,td->ecd", disp, xg)
    xe = shard(xe, "expert", "fsdp", None)
    ye = _expert_ffn(params, xe, cfg)
    y = jnp.einsum("tec,ecd->td", comb.astype(xg.dtype), ye,
                   preferred_element_type=jnp.float32)
    y = shard(y, "batch", None)
    return y.astype(xg.dtype)


def _expert_ffn(params: Params, xe: jax.Array, cfg: ArchConfig) -> jax.Array:
    """xe [E, C, D] -> [E, C, D] through each expert's gated MLP.

    Sharded over experts ('model') AND capacity slots ('data'): without the
    capacity factor every data shard would redundantly run the same expert
    GEMMs (a silent 16x compute replication caught by the §Perf loop).
    """
    g = jnp.einsum("ecd,edf->ecf", xe, params["we_gate"].astype(xe.dtype))
    u = jnp.einsum("ecd,edf->ecf", xe, params["we_up"].astype(xe.dtype))
    h = jax.nn.silu(g) * u
    h = shard(h, "expert", "fsdp", None)
    ye = jnp.einsum("ecf,efd->ecd", h, params["we_down"].astype(xe.dtype))
    return shard(ye, "expert", "fsdp", None)


def _expert_ffn_dsharded(params: Params, xe: jax.Array,
                         cfg: ArchConfig) -> jax.Array:
    """Expert MLP with the D dim sharded to match the weights (decode)."""
    g = jnp.einsum("ecd,edf->ecf", xe, params["we_gate"].astype(xe.dtype))
    u = jnp.einsum("ecd,edf->ecf", xe, params["we_up"].astype(xe.dtype))
    h = jax.nn.silu(g) * u
    h = shard(h, "expert", None, None)
    ye = jnp.einsum("ecf,efd->ecd", h, params["we_down"].astype(xe.dtype))
    return shard(ye, "expert", None, "fsdp")


def moe_sort_group(params: Params, xg: jax.Array, cfg: ArchConfig,
                   cap: int) -> jax.Array:
    """Sort-based (ragged) dispatch for one token group.

    argsort tokens by expert, scatter into the [E, C, D] expert buffer,
    gather back with gate weighting: O(Tg K D) memory, no dispatch matmuls.
    """
    Tg, D = xg.shape
    E, K = cfg.n_experts, cfg.top_k
    logits = (xg @ params["router"].astype(xg.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)          # [Tg, K]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    flat_e = expert_idx.reshape(-1)                           # [Tg*K]
    perm = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[perm]
    seg_sizes = jnp.sum(jax.nn.one_hot(flat_e, E, dtype=jnp.int32), axis=0)
    seg_start = jnp.cumsum(seg_sizes) - seg_sizes             # exclusive
    ranks_sorted = jnp.arange(Tg * K, dtype=jnp.int32) - seg_start[sorted_e]
    token_sorted = (perm // K).astype(jnp.int32)

    xs = jnp.take(xg, token_sorted, axis=0)                   # [Tg*K, D]
    xe = jnp.zeros((E, cap, D), xg.dtype)
    xe = xe.at[sorted_e, ranks_sorted].set(xs, mode="drop")   # over-cap drop
    # D-dim sharded over 'data' to MATCH the weight layout: the expert
    # GEMMs contract the sharded dim (partial sums + tiny activation
    # psums) instead of all-gathering the expert weights -- the decode-path
    # fix from §Perf hillclimb #3 (52 GB/step of weight gathers before).
    xe = shard(xe, "expert", None, "fsdp")
    ye = _expert_ffn_dsharded(params, xe, cfg)

    # combine: gather each (t, k)'s expert output, gate-weight, sum over k
    ranks = jnp.zeros((Tg * K,), jnp.int32).at[perm].set(ranks_sorted)
    ranks = ranks.reshape(Tg, K)
    in_cap = (ranks < cap).astype(jnp.float32)
    flat_idx = expert_idx * cap + jnp.minimum(ranks, cap - 1)  # [Tg, K]
    ye_flat = ye.reshape(E * cap, D)
    ytk = jnp.take(ye_flat, flat_idx.reshape(-1), axis=0).reshape(Tg, K, D)
    w = (gate_vals * in_cap).astype(ytk.dtype)
    y = jnp.einsum("tkd,tk->td", ytk, w,
                   preferred_element_type=jnp.float32)
    return y.astype(xg.dtype)


def _moe_local_sort(router, wg, wu, wd, shared, xg, cfg: ArchConfig,
                    cap: int) -> jax.Array:
    """Per-data-shard sort dispatch (runs inside shard_map, constraint-free).

    xg [T_local, D] is this data shard's tokens; expert weights arrive
    data-gathered (P() on the manual axes) but still 'model'-sharded on the
    auto axis, so the expert GEMMs partition over experts automatically.
    """
    T, D = xg.shape
    E, K = cfg.n_experts, cfg.top_k
    logits = (xg @ router.astype(xg.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    flat_e = expert_idx.reshape(-1)
    perm = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[perm]
    seg_sizes = jnp.sum(jax.nn.one_hot(flat_e, E, dtype=jnp.int32), axis=0)
    seg_start = jnp.cumsum(seg_sizes) - seg_sizes
    ranks_sorted = jnp.arange(T * K, dtype=jnp.int32) - seg_start[sorted_e]
    token_sorted = (perm // K).astype(jnp.int32)

    xs = jnp.take(xg, token_sorted, axis=0)
    xe = jnp.zeros((E, cap, D), xg.dtype)
    xe = xe.at[sorted_e, ranks_sorted].set(xs, mode="drop")

    g = jnp.einsum("ecd,edf->ecf", xe, wg.astype(xe.dtype))
    u = jnp.einsum("ecd,edf->ecf", xe, wu.astype(xe.dtype))
    h = jax.nn.silu(g) * u
    ye = jnp.einsum("ecf,efd->ecd", h, wd.astype(xe.dtype))

    ranks = jnp.zeros((T * K,), jnp.int32).at[perm].set(ranks_sorted)
    ranks = ranks.reshape(T, K)
    in_cap = (ranks < cap).astype(jnp.float32)
    flat_idx = expert_idx * cap + jnp.minimum(ranks, cap - 1)
    ytk = jnp.take(ye.reshape(E * cap, D), flat_idx.reshape(-1), axis=0) \
        .reshape(T, K, D)
    w = (gate_vals * in_cap).astype(ytk.dtype)
    y = jnp.einsum("tkd,tk->td", ytk, w,
                   preferred_element_type=jnp.float32).astype(xg.dtype)
    if shared:
        sg, su, sd = shared
        hh = jax.nn.silu(xg @ sg.astype(xg.dtype)) * (xg @ su.astype(xg.dtype))
        y = y + hh @ sd.astype(xg.dtype)
    return y


def moe_ep(params: Params, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    """Expert-parallel MoE: shard_map manual over the (pod, data) axes.

    Each data shard routes/sorts its own tokens locally (no GSPMD scatter
    pathology, no dispatch matmuls), expert GEMMs stay auto-partitioned over
    the 'model' axis, the FSDP weight gather and the weight-grad reduction
    happen ONCE per layer instead of once per token group.  Capacity is
    enforced per data shard (standard EP practice).
    """
    from jax.sharding import PartitionSpec as P
    from ..parallel.sharding import current_mesh
    mesh = current_mesh()
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    manual = tuple(a for a in ("pod", "data") if a in (mesh.shape if mesh
                                                       else {}))
    dp = 1
    for a in manual:
        dp *= mesh.shape[a]
    t_local = B * S // max(dp, 1)
    cap = max(16, -(-int(cfg.capacity_factor * t_local * K / E) // 16) * 16)
    shared_keys = ("shared_w_gate", "shared_w_up", "shared_w_down")
    shared = tuple(params[k] for k in shared_keys
                   if k in params)  # () when no shared experts

    def body(router, wg, wu, wd, shared, xs):
        y = _moe_local_sort(router, wg, wu, wd, shared,
                            xs.reshape(-1, D), cfg, cap)
        return y.reshape(xs.shape)

    if mesh is None or not manual or B % dp != 0:
        y = body(params["router"], params["we_gate"], params["we_up"],
                 params["we_down"], shared, x)
        return shard(y, "batch", None, None)

    # Weights enter replicated-on-manual-axes (P()): the data-axis gather
    # this implies sits OUTSIDE the shard_map body, where XLA hoists it out
    # of the layer/accum scan loops (loop-invariant).  The alternative --
    # weights sharded-in + explicit lax.all_gather inside the body so the
    # cotangent is a reduce-scatter -- was tried and REFUTED: the in-body
    # gather cannot be hoisted and re-runs per layer x microbatch
    # (deepseek train wire 7.1 -> 33.3 TB/dev; EXPERIMENTS.md §Perf #2).
    wrapped = shard_map_compat(
        body, mesh,
        in_specs=(P(), P(), P(), P(), tuple(P() for _ in shared), P(manual)),
        out_specs=P(manual),
        axis_names=frozenset(manual))
    y = wrapped(params["router"], params["we_gate"], params["we_up"],
                params["we_down"], shared, x)
    return shard(y, "batch", None, None)


def moe(params: Params, x: jax.Array, cfg: ArchConfig,
        impl: str = "ep_sort") -> jax.Array:
    """Top-k MoE with capacity-based dispatch.

    impl='onehot' (default): GShard-style matmul dispatch, scanned over
    groups cut along the SEQUENCE dim so the batch-dim sharding survives the
    regrouping; each group is rematerialized in backward (bounded memory).
    GSPMD partitions the dispatch matmuls into all-to-alls.

    impl='sort': ragged argsort/gather dispatch over all tokens.  Zero
    dispatch FLOPs but GSPMD's scatter/gather partitioning materializes
    index tensors of the gathered shape -- memory-hostile under pjit
    (kept as a §Perf data point; viable under shard_map).
    """
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    if impl == "ep_sort":
        if T >= 4096:
            return moe_ep(params, x, cfg)
        # decode-sized batches: the shard_map EP path would all-gather the
        # expert weights over 'data' to process a handful of tokens; the
        # pjit sort path below keeps weights in place and moves activations
        impl = "sort"
    # capacity rounded up to a multiple of 16 so the slot dim shards over
    # the 16-way data axis (non-divisible dims silently replicate).
    rcap = lambda c: max(16, -(-c // 16) * 16)
    if impl == "sort":
        # weights keep their (expert, fsdp) layout: the GEMMs contract the
        # data-sharded D dim in place (no gather; see moe_sort_group)
        cap = rcap(int(cfg.capacity_factor * T * K / E))
        y = moe_sort_group(params, x.reshape(T, D), cfg, cap) \
            .reshape(B, S, D)
    else:
        # Hoist the FSDP weight gather out of the group loop: constrain
        # expert weights to expert-sharding only (no 'fsdp' factor) BEFORE
        # the scan so the data-axis all-gather happens once per layer, not
        # once per group.
        gathered = dict(params)
        for k in ("we_gate", "we_up", "we_down"):
            if k in params:
                gathered[k] = shard(params[k].astype(x.dtype),
                                    "expert", None, None)
        chunk = max(1, min(S, cfg.moe_group_tokens // B))
        while S % chunk:
            chunk -= 1
        n_groups = S // chunk
        Tg = B * chunk
        cap = rcap(int(cfg.capacity_factor * Tg * K / E))
        # [B, S, D] -> [n_groups, B*chunk, D] keeping batch-dim sharding
        xt = x.reshape(B, n_groups, chunk, D).transpose(1, 0, 2, 3) \
            .reshape(n_groups, Tg, D)
        group_fn = lambda xg: moe_onehot_group(gathered, xg, cfg, cap)
        y = jax.lax.map(group_fn, xt)
        y = y.reshape(n_groups, B, chunk, D).transpose(1, 0, 2, 3) \
            .reshape(B, S, D)
    if cfg.n_shared_experts:
        y = y + mlp(params, x, prefix="shared_")
    return shard(y, "batch", None, None)
