"""Unified architecture configuration for the 10 assigned architectures.

One frozen dataclass covers dense GQA transformers, MLA, MoE, SWA /
local-global attention, logit softcaps, xLSTM (mLSTM+sLSTM), hybrid
attn-parallel-Mamba, encoder-decoder (whisper) and VLM-stub (internvl2).
Per-arch instances live in ``repro.configs.<id>``; each also exposes a
``smoke()`` reduction used by CPU tests.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0                   # 0 -> d_model // n_heads

    # attention flavour ----------------------------------------------------
    qk_norm: bool = False             # qwen3
    sliding_window: Optional[int] = None      # danube / hymba attention
    local_global_period: int = 0      # gemma2: every k-th layer is global
    attn_logit_softcap: Optional[float] = None   # gemma2 (50.0)
    final_logit_softcap: Optional[float] = None  # gemma2 (30.0)
    rope_theta: float = 10_000.0

    # MLA (deepseek-v2) ------------------------------------------------------
    use_mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    rope_head_dim: int = 64
    v_head_dim: int = 0               # 0 -> d_head

    # MoE --------------------------------------------------------------------
    moe: bool = False
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0       # deepseek-v2: layer 0 is dense
    moe_group_tokens: int = 4096      # dispatch block size (memory knob)
    capacity_factor: float = 1.25

    # SSM / recurrent ----------------------------------------------------------
    block_pattern: Tuple[str, ...] = ()   # e.g. 7x'mlstm'+1x'slstm' per group
    ssm_state: int = 0                # mamba state dim (hymba)
    ssm_expand: int = 2               # mamba d_inner = expand * d_model
    conv_kernel: int = 4

    # encoder-decoder / multimodal ----------------------------------------------
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    decoder_frac: float = 0.125       # dec_len = seq_len * frac (whisper train)
    vision_prefix_tokens: int = 0     # internvl2 stub patch embeddings

    # numerics -------------------------------------------------------------------
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    remat_policy: str = "full"        # full | dots | none
    scan_layers: bool = True

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def v_dim(self) -> int:
        return self.v_head_dim or self.head_dim

    @property
    def group_size(self) -> int:
        return self.n_heads // max(1, self.n_kv_heads)

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm" and not any(
            b == "attn" for b in self.block_pattern)

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run 500k-token contexts (task: long_500k gate)?"""
        if self.family in ("ssm", "hybrid"):
            return True
        # pure SWA (every layer windowed) is sub-quadratic too (danube)
        return (self.sliding_window is not None
                and self.local_global_period == 0)

    def layer_kinds(self) -> Tuple[str, ...]:
        """Static per-layer block kind, length n_layers."""
        if self.block_pattern:
            reps = math.ceil(self.n_layers / len(self.block_pattern))
            return tuple((self.block_pattern * reps)[: self.n_layers])
        return ("block",) * self.n_layers


def reduced(cfg: ArchConfig, **overrides) -> ArchConfig:
    """Smoke-test reduction: same family/topology flags, tiny sizes."""
    base = dict(
        n_layers=max(2, min(4, cfg.n_layers)),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(4, max(1, cfg.n_kv_heads * 4 // max(1, cfg.n_heads))),
        d_ff=256 if cfg.d_ff else 0,
        vocab=512,
        d_head=32,
    )
    if cfg.use_mla:
        base.update(kv_lora_rank=32, q_lora_rank=48, rope_head_dim=16,
                    d_head=32, v_head_dim=32)
    if cfg.moe:
        base.update(n_experts=min(8, cfg.n_experts), top_k=min(2, cfg.top_k),
                    moe_d_ff=64, moe_group_tokens=64,
                    n_shared_experts=min(1, cfg.n_shared_experts),
                    first_dense_layers=min(1, cfg.first_dense_layers))
    if cfg.block_pattern:
        # keep the kind mix but shrink the group
        kinds = tuple(dict.fromkeys(cfg.block_pattern))
        pattern = kinds * (base["n_layers"] // len(kinds) or 1)
        base.update(block_pattern=pattern[: base["n_layers"]])
    if cfg.ssm_state:
        base.update(ssm_state=8)
    if cfg.is_encoder_decoder:
        base.update(encoder_layers=2)
    if cfg.vision_prefix_tokens:
        base.update(vision_prefix_tokens=8)
    if cfg.sliding_window:
        base.update(sliding_window=64)
    base.update(overrides)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **base)
