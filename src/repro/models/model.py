"""Model assembly: configs -> parameter trees -> train / prefill / decode fns.

Every assigned architecture is expressed as a *layer plan*: a list of groups,
each group a repeating unit of block kinds, e.g.

  qwen3-4b        [("attn",) x 36]
  gemma2-27b      [("attn_local", "attn_global") x 23]
  xlstm-1.3b      [("mlstm",)*7 + ("slstm",) x 6]
  deepseek-v2     [("mla_dense",) x 1, ("mla_moe",) x 59]
  hymba-1.5b      [("hymba_global",) + ("hymba_local",)*15 x 2]
  whisper-base    encoder [("enc_attn",) x 6] + decoder [("dec_attn",) x 6]

Group parameters are stacked along a leading `repeats` axis and applied with
``jax.lax.scan`` so HLO size / compile time is O(#groups), not O(#layers) --
the property that makes the 40-cell multi-pod dry-run tractable.  Remat is
applied per scanned unit (policy in cfg.remat_policy).

Decode uses a direct (non-chunked) attention path so the XLA SPMD partitioner
can shard the KV cache along the sequence axis and turn softmax reductions
into all-reduces (distributed flash-decode); train/prefill use the chunked
online-softmax path from layers.py.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..parallel.sharding import shard
from . import ssm
from .config import ArchConfig
from .layers import (Init, Params, attention, flash_attention, init_attention,
                     init_mla, init_mlp, init_moe, mla_attention, mlp, moe,
                     rms_norm, rope, softcap)

# ---------------------------------------------------------------------------
# layer plan
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LayerGroup:
    kinds: Tuple[str, ...]
    repeats: int


def _periodic_groups(kinds: Tuple[str, ...], max_period: int = 16
                     ) -> List[LayerGroup]:
    """Split a kind sequence into repeating units (smallest period <= cap)."""
    n = len(kinds)
    for p in range(1, min(max_period, n) + 1):
        if n % p == 0 and all(kinds[i] == kinds[i % p] for i in range(n)):
            return [LayerGroup(kinds=kinds[:p], repeats=n // p)]
    # fall back: split off a prefix until the remainder is periodic
    for cut in range(1, n):
        rest = _periodic_groups(kinds[cut:], max_period)
        if len(rest) == 1:
            return [LayerGroup(kinds=kinds[:cut], repeats=1)] + rest
    return [LayerGroup(kinds=kinds, repeats=1)]


def layer_plan(cfg: ArchConfig) -> List[LayerGroup]:
    """Decoder-side (or decoder-only) layer plan."""
    L = cfg.n_layers
    if cfg.family == "ssm" and cfg.block_pattern:
        return _periodic_groups(cfg.layer_kinds())
    if cfg.family == "hybrid":
        period = cfg.local_global_period or L
        kinds = tuple("hymba_global" if i % period == 0 else "hymba_local"
                      for i in range(L))
        return _periodic_groups(kinds)
    if cfg.use_mla:
        nd = cfg.first_dense_layers
        groups = []
        if nd:
            groups.append(LayerGroup(kinds=("mla_dense",) * nd, repeats=1))
        groups.append(LayerGroup(kinds=("mla_moe",), repeats=L - nd))
        return groups
    if cfg.moe:
        return [LayerGroup(kinds=("attn_moe",), repeats=L)]
    if cfg.is_encoder_decoder:
        return [LayerGroup(kinds=("dec_attn",), repeats=L)]
    if cfg.local_global_period:
        p = cfg.local_global_period
        kinds = tuple("attn_global" if i % p == (p - 1) else "attn_local"
                      for i in range(L))
        return _periodic_groups(kinds)
    return [LayerGroup(kinds=("attn",), repeats=L)]


def encoder_plan(cfg: ArchConfig) -> List[LayerGroup]:
    if not cfg.is_encoder_decoder:
        return []
    return [LayerGroup(kinds=("enc_attn",), repeats=cfg.encoder_layers)]


def block_window(cfg: ArchConfig, kind: str) -> Optional[int]:
    """Static sliding window for a block kind (None = full attention)."""
    if kind in ("attn_local", "hymba_local"):
        return cfg.sliding_window or 4096
    if kind in ("attn_global", "hymba_global", "enc_attn", "dec_attn"):
        return None
    return cfg.sliding_window


# ---------------------------------------------------------------------------
# per-kind init / apply
# ---------------------------------------------------------------------------


def _dense_ff(cfg: ArchConfig) -> int:
    # deepseek-v2's first (dense) layer uses a wider FFN than the per-expert
    # width; public config: 12288.  Everything else uses cfg.d_ff.
    if cfg.use_mla and cfg.moe:
        return 12288 if cfg.d_ff <= 2048 else cfg.d_ff
    return cfg.d_ff


def init_block(ini: Init, cfg: ArchConfig, kind: str) -> None:
    D = cfg.d_model
    if kind in ("attn", "attn_local", "attn_global", "enc_attn"):
        ini.mk("ln1", (D,), (None,), mode="zeros")
        init_attention(ini, cfg)
        ini.mk("ln2", (D,), (None,), mode="zeros")
        init_mlp(ini, D, cfg.d_ff, cfg.n_layers)
    elif kind == "dec_attn":
        ini.mk("ln1", (D,), (None,), mode="zeros")
        init_attention(ini, cfg)
        ini.mk("ln_x", (D,), (None,), mode="zeros")
        init_attention(ini, cfg, prefix="x_")   # cross-attention
        ini.mk("ln2", (D,), (None,), mode="zeros")
        init_mlp(ini, D, cfg.d_ff, cfg.n_layers)
    elif kind == "attn_moe":
        ini.mk("ln1", (D,), (None,), mode="zeros")
        init_attention(ini, cfg)
        ini.mk("ln2", (D,), (None,), mode="zeros")
        init_moe(ini, cfg)
    elif kind == "mla_dense":
        ini.mk("ln1", (D,), (None,), mode="zeros")
        init_mla(ini, cfg)
        ini.mk("ln2", (D,), (None,), mode="zeros")
        init_mlp(ini, D, _dense_ff(cfg), cfg.n_layers)
    elif kind == "mla_moe":
        ini.mk("ln1", (D,), (None,), mode="zeros")
        init_mla(ini, cfg)
        ini.mk("ln2", (D,), (None,), mode="zeros")
        init_moe(ini, cfg)
    elif kind == "mlstm":
        ssm.init_mlstm_block(ini, cfg)
    elif kind == "slstm":
        ssm.init_slstm_block(ini, cfg)
    elif kind in ("hymba_local", "hymba_global"):
        ini.mk("ln1", (D,), (None,), mode="zeros")
        init_attention(ini, cfg, prefix="attn_")
        ssm.init_mamba(ini, cfg, prefix="mamba_")
        ini.mk("ln2", (D,), (None,), mode="zeros")
        init_mlp(ini, D, cfg.d_ff, cfg.n_layers)
    else:
        raise ValueError(f"unknown block kind {kind!r}")


def apply_block(params: Params, x: jax.Array, cfg: ArchConfig, kind: str, *,
                positions: jax.Array, cache: Optional[Dict] = None,
                enc_out: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, Optional[Dict]]:
    window = block_window(cfg, kind)
    if kind in ("attn", "attn_local", "attn_global", "enc_attn"):
        h = rms_norm(x, params["ln1"], cfg.norm_eps)
        a, new_cache = attention(params, h, cfg, positions=positions,
                                 cache=cache, window=window,
                                 causal=(kind != "enc_attn"))
        x = x + a
        h = rms_norm(x, params["ln2"], cfg.norm_eps)
        x = x + mlp(params, h)
        return x, new_cache
    if kind == "dec_attn":
        c_self = None if cache is None else cache["self"]
        h = rms_norm(x, params["ln1"], cfg.norm_eps)
        a, nc_self = attention(params, h, cfg, positions=positions,
                               cache=c_self, window=None, causal=True)
        x = x + a
        h = rms_norm(x, params["ln_x"], cfg.norm_eps)
        a, nc_cross = cross_attention(params, h, cfg, enc_out=enc_out,
                                      cache=None if cache is None
                                      else cache["cross"])
        x = x + a
        h = rms_norm(x, params["ln2"], cfg.norm_eps)
        x = x + mlp(params, h)
        nc = None if cache is None else dict(self=nc_self, cross=nc_cross)
        return x, nc
    if kind in ("attn_moe", "mla_moe", "mla_dense"):
        h = rms_norm(x, params["ln1"], cfg.norm_eps)
        if kind.startswith("mla"):
            a, new_cache = mla_attention(params, h, cfg, positions=positions,
                                         cache=cache)
        else:
            a, new_cache = attention(params, h, cfg, positions=positions,
                                     cache=cache, window=window)
        x = x + a
        h = rms_norm(x, params["ln2"], cfg.norm_eps)
        x = x + (mlp(params, h) if kind == "mla_dense" else moe(params, h, cfg))
        return x, new_cache
    if kind == "mlstm":
        d, new_cache = ssm.mlstm_block(params, x, cfg, state=cache)
        return x + d, new_cache
    if kind == "slstm":
        d, new_cache = ssm.slstm_block(params, x, cfg, state=cache)
        return x + d, new_cache
    if kind in ("hymba_local", "hymba_global"):
        c_attn = None if cache is None else cache["attn"]
        c_mamba = None if cache is None else cache["mamba"]
        h = rms_norm(x, params["ln1"], cfg.norm_eps)
        a, nc_attn = attention(params, h, cfg, positions=positions,
                               cache=c_attn, window=window, prefix="attn_")
        m, nc_mamba = ssm.mamba(params, h, cfg, state=c_mamba,
                                prefix="mamba_")
        x = x + 0.5 * (a + m)
        h = rms_norm(x, params["ln2"], cfg.norm_eps)
        x = x + mlp(params, h)
        nc = None if cache is None else dict(attn=nc_attn, mamba=nc_mamba)
        return x, nc
    raise ValueError(f"unknown block kind {kind!r}")


def cross_attention(params: Params, x: jax.Array, cfg: ArchConfig, *,
                    enc_out: Optional[jax.Array], cache: Optional[Dict],
                    prefix: str = "x_") -> Tuple[jax.Array, Optional[Dict]]:
    """Encoder-decoder cross attention (no rope, non-causal over enc states).

    At prefill/decode the projected encoder K/V is computed once and cached.
    """
    B, S, D = x.shape
    H, KH, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ params[prefix + "wq"].astype(x.dtype)).reshape(B, S, H, Dh)
    if enc_out is None:
        assert cache is not None, "cross attention needs enc_out or cache"
        k, v = cache["k"].astype(x.dtype), cache["v"].astype(x.dtype)
    else:
        # prefill: project encoder states once; cached for decode
        k = (enc_out @ params[prefix + "wk"].astype(x.dtype)) \
            .reshape(B, -1, KH, Dh)
        v = (enc_out @ params[prefix + "wv"].astype(x.dtype)) \
            .reshape(B, -1, KH, Dh)
    q = shard(q, "batch", None, "heads", None)
    S_enc = k.shape[1]
    kv_pos = jnp.arange(S_enc)
    q_pos = jnp.zeros((S,), jnp.int32)  # non-causal: mask never fires
    out = flash_attention(q, k, v, q_positions=q_pos, kv_positions=kv_pos,
                          causal=False)
    out = out.astype(x.dtype).reshape(B, S, H * Dh)
    y = out @ params[prefix + "wo"].astype(x.dtype)
    new_cache = dict(k=k, v=v) if cache is not None else None
    return shard(y, "batch", None, None), new_cache


# ---------------------------------------------------------------------------
# parameter tree
# ---------------------------------------------------------------------------


def _stack_trees(trees: List[Params]) -> Params:
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def _stack_axes(axes: Dict, repeats: int) -> Dict:
    """Prepend a 'layers' (unsharded) axis to every leaf's logical axes."""
    is_axes = lambda a: isinstance(a, tuple) and all(
        isinstance(e, (str, type(None))) for e in a)
    return jax.tree_util.tree_map(lambda a: (None,) + a, axes, is_leaf=is_axes)


def init_model(cfg: ArchConfig, key: jax.Array) -> Tuple[Params, Dict]:
    """Returns (params, logical_axes) with group-stacked layer params."""
    ini = Init(key)
    ini.mk("embed", (cfg.vocab, cfg.d_model), ("tp", "fsdp"), scale=0.02)
    ini.mk("final_norm", (cfg.d_model,), (None,), mode="zeros")
    if not cfg.tie_embeddings:
        ini.mk("lm_head", (cfg.d_model, cfg.vocab), ("fsdp", "tp"),
               scale=1.0 / math.sqrt(cfg.d_model))

    def build_groups(plan: List[LayerGroup], tag: str) -> None:
        for gi, grp in enumerate(plan):
            reps = []
            for _ in range(grp.repeats):
                unit = Init(ini._next())
                for j, kind in enumerate(grp.kinds):
                    sub = Init(unit._next())
                    init_block(sub, cfg, kind)
                    unit.params[f"b{j}"] = sub.params
                    unit.axes[f"b{j}"] = sub.axes
                reps.append(unit.params)
                unit_axes = unit.axes
            ini.params[f"{tag}{gi}"] = _stack_trees(reps)
            ini.axes[f"{tag}{gi}"] = _stack_axes(unit_axes, grp.repeats)

    build_groups(layer_plan(cfg), "g")
    if cfg.is_encoder_decoder:
        build_groups(encoder_plan(cfg), "enc_g")
        ini.mk("enc_final_norm", (cfg.d_model,), (None,), mode="zeros")
    return ini.params, ini.axes


def param_count(params: Params) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(params))


# ---------------------------------------------------------------------------
# stack application (scan over stacked layer groups)
# ---------------------------------------------------------------------------


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)


def apply_stack(params: Params, x: jax.Array, cfg: ArchConfig,
                plan: List[LayerGroup], tag: str, *,
                positions: jax.Array, caches: Optional[List] = None,
                enc_out: Optional[jax.Array] = None,
                remat_policy: Optional[str] = None
                ) -> Tuple[jax.Array, Optional[List]]:
    """Run x through all layer groups; caches is a per-group list or None."""
    policy = cfg.remat_policy if remat_policy is None else remat_policy
    new_caches: Optional[List] = None if caches is None else []
    for gi, grp in enumerate(plan):
        gp = params[f"{tag}{gi}"]
        gcache = None if caches is None else caches[gi]
        # Nested remat: multi-layer units (gemma2's local/global pair,
        # hymba's 16-layer period, xlstm's 7+1 pattern) checkpoint each
        # BLOCK as well as the unit, so the unit's backward recomputes one
        # block at a time instead of materializing every block's residuals
        # at once (hymba: 16 blocks x ~13 GB -> ~1 block live).
        nested = len(grp.kinds) > 1 and policy != "none"

        def unit(x, unit_params, unit_cache, _kinds=grp.kinds,
                 _nested=nested):
            ncs = {}
            for j, kind in enumerate(_kinds):
                c = None if unit_cache is None else unit_cache[f"b{j}"]
                blk = lambda x, p, c, _k=kind: apply_block(
                    p, x, cfg, _k, positions=positions, cache=c,
                    enc_out=enc_out)
                if _nested:
                    blk = jax.checkpoint(blk)
                x, nc = blk(x, unit_params[f"b{j}"], c)
                if unit_cache is not None:
                    ncs[f"b{j}"] = nc
            return x, (ncs if unit_cache is not None else None)

        if grp.repeats == 1:
            squeeze = lambda t: jax.tree_util.tree_map(lambda a: a[0], t)
            up = squeeze(gp)
            uc = None if gcache is None else squeeze(gcache)
            x, nc = _remat(unit, policy)(x, up, uc)
            if caches is not None:
                new_caches.append(jax.tree_util.tree_map(
                    lambda a: a[None], nc))
        elif cfg.scan_layers:
            if gcache is None:
                def body(x, up):
                    x, _ = _remat(unit, policy)(x, up, None)
                    return x, None
                x, _ = jax.lax.scan(body, x, gp)
                nc = None
            else:
                def body(x, inp):
                    up, uc = inp
                    x, nc = _remat(unit, policy)(x, up, uc)
                    return x, nc
                x, nc = jax.lax.scan(body, x, (gp, gcache))
            if caches is not None:
                new_caches.append(nc)
        else:  # unrolled (hillclimb knob)
            ncs = []
            for r in range(grp.repeats):
                take = lambda t: jax.tree_util.tree_map(lambda a: a[r], t)
                uc = None if gcache is None else take(gcache)
                x, nc = _remat(unit, policy)(x, take(gp), uc)
                ncs.append(nc)
            if caches is not None:
                new_caches.append(_stack_trees(ncs))
    return x, new_caches


# ---------------------------------------------------------------------------
# embeddings, logits, loss
# ---------------------------------------------------------------------------


def embed_tokens(params: Params, cfg: ArchConfig, tokens: jax.Array,
                 dtype=None) -> jax.Array:
    dtype = jnp.dtype(cfg.dtype) if dtype is None else dtype
    emb = params["embed"].astype(dtype)
    x = emb[tokens]
    return shard(x, "batch", None, None)


def logits_fn(params: Params, cfg: ArchConfig, h: jax.Array) -> jax.Array:
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    w = (params["embed"].T if cfg.tie_embeddings
         else params["lm_head"]).astype(h.dtype)
    logits = h @ w
    logits = softcap(logits.astype(jnp.float32), cfg.final_logit_softcap)
    return shard(logits, "batch", None, "vocab")


def xent_loss(params: Params, cfg: ArchConfig, h: jax.Array,
              labels: jax.Array, n_chunks: int = 8) -> jax.Array:
    """Chunked softmax cross-entropy: never materializes [B, S, V] at once."""
    B, S, D = h.shape
    n_chunks = min(n_chunks, S)
    while S % n_chunks:
        n_chunks -= 1
    hc = h.reshape(B, n_chunks, S // n_chunks, D).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, n_chunks, S // n_chunks).transpose(1, 0, 2)

    def chunk_loss(args):
        hh, ll = args
        logits = logits_fn(params, cfg, hh)          # [B, s, V] fp32
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(logits, ll[..., None], axis=-1)[..., 0]
        return jnp.sum(lse - picked)

    total = jnp.sum(jax.lax.map(chunk_loss, (hc, lc)))
    return total / (B * S)


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------


def _decoder_inputs(params: Params, cfg: ArchConfig, batch: Dict
                    ) -> Tuple[jax.Array, jax.Array, Optional[jax.Array]]:
    """Returns (x, positions, enc_out) handling enc-dec and VLM stubs."""
    enc_out = None
    if cfg.is_encoder_decoder:
        # stub frontend: precomputed frame embeddings [B, S_enc, D]
        enc_in = shard(batch["frames"].astype(jnp.dtype(cfg.dtype)),
                       "batch", None, None)
        enc_pos = jnp.arange(enc_in.shape[1])
        enc_out, _ = apply_stack(params, enc_in, cfg, encoder_plan(cfg),
                                 "enc_g", positions=enc_pos)
        enc_out = rms_norm(enc_out, params["enc_final_norm"], cfg.norm_eps)
        x = embed_tokens(params, cfg, batch["tokens"])
        positions = jnp.arange(batch["tokens"].shape[1])
        return x, positions, enc_out
    x = embed_tokens(params, cfg, batch["tokens"])
    if cfg.vision_prefix_tokens:
        # stub frontend: precomputed patch embeddings [B, P, D]
        vis = shard(batch["patches"].astype(x.dtype), "batch", None, None)
        x = jnp.concatenate([vis, x], axis=1)
    positions = jnp.arange(x.shape[1])
    return x, positions, None


def forward_train(params: Params, cfg: ArchConfig, batch: Dict) -> jax.Array:
    """Mean next-token loss for one (micro)batch."""
    x, positions, enc_out = _decoder_inputs(params, cfg, batch)
    x, _ = apply_stack(params, x, cfg, layer_plan(cfg), "g",
                       positions=positions, enc_out=enc_out)
    labels = batch["labels"]
    if cfg.vision_prefix_tokens:     # loss only on the text tail
        x = x[:, cfg.vision_prefix_tokens:]
    return xent_loss(params, cfg, x, labels)


def forward_hidden(params: Params, cfg: ArchConfig, batch: Dict) -> jax.Array:
    x, positions, enc_out = _decoder_inputs(params, cfg, batch)
    x, _ = apply_stack(params, x, cfg, layer_plan(cfg), "g",
                       positions=positions, enc_out=enc_out)
    return x
