"""Analytic parameter / FLOP / bandwidth accounting.

Two consumers:
  * the roofline report (MODEL_FLOPS = 6*N*D train / 2*N*D-per-token decode,
    N = active non-embedding params, + attention context terms), compared
    against trip-corrected HLO dot-FLOPs to expose remat / dispatch waste;
  * the CFN bridge (core.vsr.from_architecture): per-layer GFLOP/token and
    inter-layer activation bitrates turn any assigned architecture into the
    paper's VSR abstraction.

Everything is derived from ``jax.eval_shape`` over the real ``init_model``,
so the numbers track the actual parameter tree, not a hand-maintained
formula.
"""
from __future__ import annotations

import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ArchConfig
from . import model as M


def _tree_sizes(tree, path=()) -> List[Tuple[Tuple, int]]:
    out = []
    if isinstance(tree, dict):
        for k, v in tree.items():
            out += _tree_sizes(v, path + (k,))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out += _tree_sizes(v, path + (str(i),))
    else:
        out.append((path, int(np.prod(tree.shape))))
    return out


def param_breakdown(cfg: ArchConfig) -> Dict[str, int]:
    """total / embedding / expert / active parameter counts."""
    shapes = jax.eval_shape(
        lambda k: M.init_model(cfg, k)[0], jax.random.key(0))
    sizes = _tree_sizes(shapes)
    total = sum(s for _, s in sizes)
    embed = sum(s for p, s in sizes
                if p[-1] in ("embed", "lm_head"))
    expert = sum(s for p, s in sizes
                 if any(str(k).startswith("we_") for k in p))
    active_expert = (expert * cfg.top_k / cfg.n_experts
                     if cfg.moe and cfg.n_experts else 0)
    active = total - expert + active_expert
    return dict(total=total, embed=embed, expert=expert,
                active=int(active), active_nonembed=int(active - embed),
                nonembed=total - embed)


def _attention_layers(cfg: ArchConfig) -> List[Tuple[str, int]]:
    """(kind, effective kv dim) for every layer that attends."""
    out = []
    for grp in M.layer_plan(cfg):
        for _ in range(grp.repeats):
            for kind in grp.kinds:
                if kind in ("mlstm", "slstm"):
                    continue
                out.append((kind, cfg.head_dim))
    return out


def attention_flops(cfg: ArchConfig, s_q: int, s_kv: int,
                    causal_avg: bool) -> float:
    """Scores + PV flops for the whole stack at the given context."""
    total = 0.0
    H, Dh = cfg.n_heads, cfg.head_dim
    for kind, _ in _attention_layers(cfg):
        w = M.block_window(cfg, kind)
        kv = min(w, s_kv) if w else s_kv
        if causal_avg and kv == s_kv:
            kv = max(1, kv // 2)
        total += 4.0 * s_q * kv * H * Dh
    return total


def model_flops(cfg: ArchConfig, shape) -> Dict[str, float]:
    """Useful FLOPs for one step of the given shape (whole mesh)."""
    pb = param_breakdown(cfg)
    N = pb["active_nonembed"]
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        from ..launch.specs import dec_len
        toks = B * dec_len(cfg, S)
        flops = 6.0 * N * toks + 3.0 * attention_flops(
            cfg, dec_len(cfg, S), dec_len(cfg, S), causal_avg=True) * B
    elif shape.kind == "prefill":
        from ..launch.specs import dec_len
        toks = B * dec_len(cfg, S)
        flops = 2.0 * N * toks + attention_flops(
            cfg, dec_len(cfg, S), dec_len(cfg, S), causal_avg=True) * B
    else:  # decode: one token against an S-token cache
        flops = 2.0 * N * B + attention_flops(cfg, 1, S,
                                              causal_avg=False) * B
    return dict(total_flops=flops, params=pb)


def layer_costs(cfg: ArchConfig, context: int = 2048,
                ) -> Tuple[List[float], List[float]]:
    """(gflop_per_token per layer, boundary activation bytes per token).

    Used by core.vsr.from_architecture: one transformer layer == one VM in
    the paper's abstraction.  Inference cost: 2 FLOPs per active param plus
    the attention context term at the given context length.
    """
    shapes = jax.eval_shape(
        lambda k: M.init_model(cfg, k)[0], jax.random.key(0))
    plan = M.layer_plan(cfg)
    gflops: List[float] = []
    act_bytes: List[float] = []
    H, Dh = cfg.n_heads, cfg.head_dim
    for gi, grp in enumerate(plan):
        sizes = _tree_sizes(shapes[f"g{gi}"])
        per_layer: Dict[str, int] = {}
        for path, size in sizes:
            bj = path[0]
            per_layer[bj] = per_layer.get(bj, 0) + size // grp.repeats
        for _ in range(grp.repeats):
            for j, kind in enumerate(grp.kinds):
                n = per_layer.get(f"b{j}", 0)
                if cfg.moe and kind in ("attn_moe", "mla_moe"):
                    sizes_j = [(p, s) for p, s in sizes if p[0] == f"b{j}"]
                    expert = sum(s for p, s in sizes_j
                                 if any(str(k).startswith("we_")
                                        for k in p)) // grp.repeats
                    n = n - expert + expert * cfg.top_k / cfg.n_experts
                fl = 2.0 * n
                if kind not in ("mlstm", "slstm"):
                    w = M.block_window(cfg, kind)
                    kv = min(w, context) if w else context
                    fl += 4.0 * kv * H * Dh
                gflops.append(fl / 1e9)
                act_bytes.append(2.0 * cfg.d_model)
    return gflops, act_bytes
