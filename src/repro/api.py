"""Public placement API: ``from repro.api import PlacementSpec, CFNSession``.

Re-export of ``repro.core.api`` -- the declarative constraint object
(``PlacementSpec``), the session facade (``CFNSession``), and the
multi-region federation facade (``FederatedSession`` /
``RegionPartition``) every placement path (batch, online, serving,
federated) consumes.  See those modules for the full story;
``examples/quickstart.py``, ``examples/online_day.py`` and
``examples/federated_regions.py`` are the walkthroughs.
"""
from .core.api import (CFNSession, FederatedSession, PlacementSpec,
                       RegionPartition, SolveResult, SubstrateHealth,
                       solve_portfolio)
from .core.api import __all__ as _core_all

__all__ = list(_core_all)
