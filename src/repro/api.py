"""Public placement API: ``from repro.api import PlacementSpec, CFNSession``.

Re-export of ``repro.core.api`` -- the declarative constraint object
(``PlacementSpec``) and the session facade (``CFNSession``) every placement
path (batch, online, serving) consumes.  See that module for the full
story; ``examples/quickstart.py`` and ``examples/online_day.py`` are the
walkthroughs.
"""
from .core.api import (CFNSession, PlacementSpec, SolveResult,
                       solve_portfolio)
from .core.api import __all__ as _core_all

__all__ = list(_core_all)
