"""hymba-1.5b [hybrid] — parallel attention + mamba heads [arXiv:2411.13676].

32L d_model=1600 25H (GQA kv=5) d_ff=5504 ssm_state=16 vocab=32001.
Every layer fuses an attention branch and a mamba branch on the same input
(mean-combined).  Window 1024 on most layers; one global layer per 16
(the published model uses 3 global layers at first/middle/last -- we use the
periodic approximation 0 and 16, recorded in DESIGN.md).  Hybrid with O(1)
SSM state + windowed attention => long_500k runs.
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab=32001,
    d_head=64,
    sliding_window=1024,
    local_global_period=16,
    ssm_state=16,
    ssm_expand=2,
    conv_kernel=4,
)
