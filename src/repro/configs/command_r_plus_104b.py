"""command-r-plus-104b [dense] — GQA, no-bias [hf:CohereForAI/c4ai-command-r].

64L d_model=12288 96H (GQA kv=8) d_ff=33792 vocab=256000, head_dim=128.
Pure full attention => long_500k skip.
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="command-r-plus-104b",
    family="dense",
    n_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=33792,
    vocab=256_000,
    d_head=128,
)
