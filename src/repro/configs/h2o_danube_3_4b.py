"""h2o-danube-3-4b [dense] — llama+mistral mix with SWA [arXiv:2401.16818].

24L d_model=3840 32H (GQA kv=8) d_ff=10240 vocab=32000; sliding window 4096
on every layer => sub-quadratic decode, long_500k runs with a ring-buffer KV
cache of 4096 slots.
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-3-4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    d_ff=10240,
    vocab=32000,
    d_head=120,
    sliding_window=4096,
)
