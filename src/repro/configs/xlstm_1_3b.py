"""xlstm-1.3b [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517].

48L d_model=2048 4H d_ff=0 vocab=50304.  Block pattern 7:1 mLSTM:sLSTM (the
paper's 1.3B ratio); ssm_expand=1 calibrates to the published ~1.3B total
(DESIGN.md dimensional note).  Attention-free => long_500k runs (O(1) state).
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    block_pattern=("mlstm",) * 7 + ("slstm",),
    ssm_expand=1,
    conv_kernel=4,
    tie_embeddings=False,
)
