"""internvl2-2b [vlm] — InternViT (stub) + InternLM2-1.8B backbone
[arXiv:2404.16821].

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553.  The vision tower is
a STUB: input_specs() supplies 256 precomputed patch embeddings per image,
prepended to the text tokens (the paper's "input VM pinned at the source" in
CFN terms).  Loss is computed on the text tail only.
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab=92553,
    d_head=128,
    vision_prefix_tokens=256,
)
