"""gemma2-27b [dense] — local+global alternating attention, logit softcaps
[arXiv:2408.00118].

46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000, head_dim=128.
Layer pattern (local, global) x 23; local window 4096; attn softcap 50,
final-logit softcap 30.  Global layers are full attention => long_500k skip.
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    d_ff=36864,
    vocab=256_000,
    d_head=128,
    local_global_period=2,
    sliding_window=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
)
