"""whisper-base [audio] — enc-dec, conv frontend (stub) [arXiv:2212.04356].

6 encoder + 6 decoder layers, d_model=512 8H d_ff=2048 vocab=51865.  The
conv/mel frontend is a STUB: input_specs() supplies precomputed frame
embeddings [B, S_enc, d_model]; decoder length = seq_len * decoder_frac.
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base",
    family="audio",
    n_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab=51865,
    is_encoder_decoder=True,
    encoder_layers=6,
    decoder_frac=0.125,
    tie_embeddings=True,
)
