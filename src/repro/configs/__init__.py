"""Architecture registry + assigned input shapes.

``get(name)`` returns the full published config; ``get_smoke(name)`` the
reduced CPU-testable config.  ``SHAPES`` is the assigned shape set; cells are
(arch x shape) pairs filtered by ``applicable_shapes`` (long_500k only for
sub-quadratic archs, per the task spec; skips recorded in DESIGN.md §4).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Tuple

from ..models.config import ArchConfig, reduced
from . import (command_r_plus_104b, deepseek_v2_236b, gemma2_27b,
               h2o_danube_3_4b, hymba_1_5b, internvl2_2b, olmoe_1b_7b,
               qwen3_4b, whisper_base, xlstm_1_3b)

REGISTRY: Dict[str, ArchConfig] = {
    m.CONFIG.name: m.CONFIG for m in (
        xlstm_1_3b, qwen3_4b, h2o_danube_3_4b, gemma2_27b,
        command_r_plus_104b, deepseek_v2_236b, olmoe_1b_7b, whisper_base,
        hymba_1_5b, internvl2_2b)
}

ARCH_IDS: Tuple[str, ...] = tuple(REGISTRY)


def get(name: str) -> ArchConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(REGISTRY)}")
    return REGISTRY[name]


def get_smoke(name: str, **overrides) -> ArchConfig:
    return reduced(get(name), **overrides)


@dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES: Dict[str, Shape] = {
    "train_4k": Shape("train_4k", 4_096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32_768, 128, "decode"),
    "long_500k": Shape("long_500k", 524_288, 1, "decode"),
}


def applicable_shapes(cfg: ArchConfig) -> List[Shape]:
    """Task skip rules: long_500k needs a sub-quadratic arch."""
    out = [SHAPES["train_4k"], SHAPES["prefill_32k"], SHAPES["decode_32k"]]
    if cfg.sub_quadratic:
        out.append(SHAPES["long_500k"])
    return out


def all_cells() -> List[Tuple[str, str]]:
    cells = []
    for arch in ARCH_IDS:
        for shape in applicable_shapes(get(arch)):
            cells.append((arch, shape.name))
    return cells
