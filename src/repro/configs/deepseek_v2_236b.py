"""deepseek-v2-236b [moe] — MLA kv_lora=512, 2 shared + 160 routed top-6
[arXiv:2405.04434].

60L d_model=5120 128H vocab=102400; per-expert d_ff=1536; layer 0 dense
(public config: dense FFN 12288, see models.model._dense_ff).  MLA: q_lora
1536, kv_lora 512, qk_nope 128, qk_rope 64, v_head 128.
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    d_ff=1536,
    vocab=102_400,
    d_head=128,
    use_mla=True,
    kv_lora_rank=512,
    q_lora_rank=1536,
    rope_head_dim=64,
    v_head_dim=128,
    moe=True,
    n_experts=160,
    n_shared_experts=2,
    top_k=6,
    moe_d_ff=1536,
    first_dense_layers=1,
    moe_group_tokens=512,
)
