"""Failure detection: heartbeats and straggler tracking.

On a real cluster the heartbeat source is the per-host agent (and the
coordinator is the jax.distributed service); here workers are simulated so
the detection/reaction logic -- the part that belongs to this framework --
is real and testable: a missed heartbeat triggers restart-from-checkpoint,
a straggling step raises a mitigation signal (at scale: evict + elastic
rescale to the surviving host set).
"""
from __future__ import annotations

import statistics
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional


@dataclass
class HeartbeatMonitor:
    timeout_s: float = 10.0
    clock: Callable[[], float] = time.monotonic
    last_beat: Dict[str, float] = field(default_factory=dict)

    def register(self, worker: str) -> None:
        self.last_beat[worker] = self.clock()

    def beat(self, worker: str) -> None:
        self.last_beat[worker] = self.clock()

    def dead_workers(self) -> List[str]:
        now = self.clock()
        return [w for w, t in self.last_beat.items()
                if now - t > self.timeout_s]

    def healthy(self) -> bool:
        return not self.dead_workers()


@dataclass
class StragglerTracker:
    """Flags steps slower than ``threshold`` x the rolling median."""

    threshold: float = 3.0
    window: int = 32
    times: List[float] = field(default_factory=list)
    flagged_steps: List[int] = field(default_factory=list)

    def record(self, step: int, duration_s: float) -> bool:
        history = self.times[-self.window:]
        self.times.append(duration_s)
        if len(history) < 5:
            return False
        med = statistics.median(history)
        if duration_s > self.threshold * med:
            self.flagged_steps.append(step)
            return True
        return False
