"""Failure detection: heartbeats, straggler tracking, and placement-plane
counters.

On a real cluster the heartbeat source is the per-host agent (and the
coordinator is the jax.distributed service); here workers are simulated so
the detection/reaction logic -- the part that belongs to this framework --
is real and testable: a missed heartbeat triggers restart-from-checkpoint,
a straggling step raises a mitigation signal (at scale: evict + elastic
rescale to the surviving host set).

``PlacementMonitor`` is the placement-plane half: the online engine
(``core.dynamic.OnlineEmbedder``) and the federation coordinator
(``core.federation.FederatedSession``) report admission rejections,
power-budget violations, regional budget breaches, and cross-region
migrations here instead of dropping them -- the counters an operator
alerts on.
"""
from __future__ import annotations

import statistics
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple


@dataclass
class HeartbeatMonitor:
    timeout_s: float = 10.0
    clock: Callable[[], float] = time.monotonic
    last_beat: Dict[str, float] = field(default_factory=dict)

    def register(self, worker: str) -> None:
        self.last_beat[worker] = self.clock()

    def deregister(self, worker: str) -> None:
        """Forget a worker that was evicted or restarted under a new name:
        it stops re-alarming ``dead_workers`` forever."""
        self.last_beat.pop(worker, None)

    def reset(self) -> None:
        """Forget every worker (fleet restart)."""
        self.last_beat.clear()

    def beat(self, worker: str) -> None:
        self.last_beat[worker] = self.clock()

    def dead_workers(self) -> List[str]:
        now = self.clock()
        return [w for w, t in self.last_beat.items()
                if now - t > self.timeout_s]

    def healthy(self) -> bool:
        return not self.dead_workers()


@dataclass
class PlacementMonitor:
    """Operational counters for the placement control plane.

    Canonical kinds (emitters in parentheses):
      * ``admission_rejected``    -- an arrival refused by SLA admission
                                     control (OnlineEmbedder.add).
      * ``power_budget_exceeded`` -- the refusal was the incremental power
                                     budget (spec.power_budget_w).
      * ``violation_budget_exceeded`` -- the refusal was the capacity
                                     violation tolerance (spec.violation_tol).
      * ``region_budget_breach``  -- a region's TOTAL watts crossed its
                                     spec.region_power_budget_w
                                     (FederatedSession coordinator).
      * ``cross_region_migration`` -- a service re-homed to another region
                                     after a breach (FederatedSession).

    Fault-plane kinds (the closed loop; see core.dynamic FaultEvent):
      * ``node_failed`` / ``node_recovered`` / ``link_failed`` /
        ``link_recovered``        -- substrate state transitions
                                     (OnlineEmbedder fail/recover handlers).
      * ``region_failed`` / ``region_recovered`` -- federated region faults.
      * ``service_stranded``      -- a service lost its placement (source
                                     node died, or no admissible node
                                     remains) and was parked for retry;
                                     counted by ``strand``.
      * ``re_embedded``           -- a displaced service was re-placed: mass
                                     re-embeds after a fault, and stranded
                                     services re-admitted on recovery
                                     (``unstrand``).
      * ``evacuation``            -- a service moved out of a failed or
                                     browned-out region (FederatedSession).
      * ``brownout`` / ``brownout_end`` -- a power budget tightened /
                                     restored mid-run.

    Availability: ``strand(sid, t)`` opens a window at time ``t`` and
    ``unstrand(sid, t)`` closes it, accumulating into
    ``stranded_service_s`` -- the stranded-service-seconds integral (units
    follow the caller's clock; churn timelines tick in hours).
    ``availability(horizon, n)`` normalizes it to a [0, 1] fraction.

    ``count`` is also open to new kinds; ``events`` keeps the last
    ``max_events`` (kind, detail) pairs for debugging.

    Telemetry delegation: with a ``repro.telemetry.Telemetry`` attached
    (``attach_telemetry``), every ``count`` additionally increments the
    registry counter ``<prefix>.<kind>`` and emits a JSONL ``event`` --
    standalone behavior (``counters`` / ``events`` ring / ``snapshot`` /
    ``merge`` semantics, the ``max_events`` bound) is unchanged, and the
    registry mirror is purely additive.  ``reset()`` does NOT rewind the
    registry (its counters are cumulative across the run by design).
    """

    counters: Dict[str, int] = field(default_factory=dict)
    events: List[Tuple[str, Optional[str]]] = field(default_factory=list)
    max_events: int = 256
    stranded_service_s: float = 0.0
    stranded_since: Dict[int, float] = field(default_factory=dict)
    telemetry: Optional[object] = None
    telemetry_prefix: str = "monitor"

    def attach_telemetry(self, telemetry, prefix: str = "monitor") -> None:
        """Mirror this monitor's counters/events into a ``Telemetry``
        registry from now on (``None`` detaches)."""
        self.telemetry = telemetry
        self.telemetry_prefix = prefix

    def count(self, kind: str, detail: Optional[str] = None,
              n: int = 1) -> None:
        self.counters[kind] = self.counters.get(kind, 0) + n
        self.events.append((kind, detail))
        if len(self.events) > self.max_events:
            del self.events[:len(self.events) - self.max_events]
        tel = self.telemetry
        if tel is not None:
            tel.inc(f"{self.telemetry_prefix}.{kind}", n)
            tel.emit("event", kind=kind, detail=detail, n=n)

    def get(self, kind: str) -> int:
        return self.counters.get(kind, 0)

    def __getitem__(self, kind: str) -> int:
        return self.get(kind)

    def snapshot(self) -> Dict[str, int]:
        return dict(self.counters)

    # -- availability integral --------------------------------------------
    def strand(self, sid: int, t: float = 0.0,
               detail: Optional[str] = None) -> None:
        """Open a stranded window for ``sid`` at time ``t`` (idempotent
        while the window is open)."""
        if sid in self.stranded_since:
            return
        self.stranded_since[sid] = float(t)
        self.count("service_stranded", detail or f"sid={sid}")
        if self.telemetry is not None:
            self.telemetry.gauge(f"{self.telemetry_prefix}.stranded_open",
                                 len(self.stranded_since))

    def unstrand(self, sid: int, t: float = 0.0,
                 re_embedded: bool = True) -> bool:
        """Close ``sid``'s stranded window at ``t``, accumulating the
        elapsed span into ``stranded_service_s``.  ``re_embedded=False``
        marks a window closed by departure rather than re-placement.
        No-op (returns False) when no window is open."""
        t0 = self.stranded_since.pop(sid, None)
        if t0 is None:
            return False
        self.stranded_service_s += max(0.0, float(t) - t0)
        if re_embedded:
            self.count("re_embedded", f"sid={sid}")
        if self.telemetry is not None:
            self.telemetry.gauge(f"{self.telemetry_prefix}.stranded_open",
                                 len(self.stranded_since))
            self.telemetry.gauge(
                f"{self.telemetry_prefix}.stranded_service_s",
                self.stranded_service_s)
        return True

    def close_strands(self, t: float) -> int:
        """End-of-horizon flush: close every open window at ``t`` (without
        counting re-embeds) so the integral covers the full run."""
        open_sids = list(self.stranded_since)
        for sid in open_sids:
            self.unstrand(sid, t, re_embedded=False)
        return len(open_sids)

    def availability(self, horizon: float, n_services: int) -> float:
        """1 - stranded time / (horizon * services): the fraction of
        service-time NOT spent stranded.  Flush open windows with
        ``close_strands`` first for an end-of-run reading."""
        denom = float(horizon) * max(int(n_services), 1)
        if denom <= 0.0:
            return 1.0
        return 1.0 - min(self.stranded_service_s / denom, 1.0)

    # -- fleet roll-up -----------------------------------------------------
    def reset(self) -> None:
        """Zero all counters, events, and availability state."""
        self.counters.clear()
        self.events.clear()
        self.stranded_service_s = 0.0
        self.stranded_since.clear()

    def merge(self, other: "PlacementMonitor") -> "PlacementMonitor":
        """Fold ``other`` into this monitor (per-region monitors roll up
        into one fleet snapshot): counters add, event logs concatenate in
        order and keep this monitor's ``max_events`` ring bound, stranded
        integrals add, and open windows keep the earliest start."""
        for kind, n in other.counters.items():
            self.counters[kind] = self.counters.get(kind, 0) + n
            # mirror the fold into the registry -- unless other already
            # reports to the SAME registry (its counts are there already)
            if (self.telemetry is not None
                    and other.telemetry is not self.telemetry):
                self.telemetry.inc(f"{self.telemetry_prefix}.{kind}", n)
        self.events.extend(other.events)
        if len(self.events) > self.max_events:
            del self.events[:len(self.events) - self.max_events]
        self.stranded_service_s += other.stranded_service_s
        for sid, t0 in other.stranded_since.items():
            self.stranded_since[sid] = min(
                t0, self.stranded_since.get(sid, t0))
        return self


@dataclass
class StragglerTracker:
    """Flags steps slower than ``threshold`` x the rolling median."""

    threshold: float = 3.0
    window: int = 32
    times: List[float] = field(default_factory=list)
    flagged_steps: List[int] = field(default_factory=list)

    def reset(self) -> None:
        """Drop the step-time history (restart): pre-failure durations must
        not poison the rolling median of the new incarnation.  Flagged
        steps are a report, not detector state, and are kept."""
        self.times.clear()

    def record(self, step: int, duration_s: float) -> bool:
        history = self.times[-self.window:]
        self.times.append(duration_s)
        if len(history) < 5:
            return False
        med = statistics.median(history)
        if duration_s > self.threshold * med:
            self.flagged_steps.append(step)
            return True
        return False
