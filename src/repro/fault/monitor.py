"""Failure detection: heartbeats, straggler tracking, and placement-plane
counters.

On a real cluster the heartbeat source is the per-host agent (and the
coordinator is the jax.distributed service); here workers are simulated so
the detection/reaction logic -- the part that belongs to this framework --
is real and testable: a missed heartbeat triggers restart-from-checkpoint,
a straggling step raises a mitigation signal (at scale: evict + elastic
rescale to the surviving host set).

``PlacementMonitor`` is the placement-plane half: the online engine
(``core.dynamic.OnlineEmbedder``) and the federation coordinator
(``core.federation.FederatedSession``) report admission rejections,
power-budget violations, regional budget breaches, and cross-region
migrations here instead of dropping them -- the counters an operator
alerts on.
"""
from __future__ import annotations

import statistics
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple


@dataclass
class HeartbeatMonitor:
    timeout_s: float = 10.0
    clock: Callable[[], float] = time.monotonic
    last_beat: Dict[str, float] = field(default_factory=dict)

    def register(self, worker: str) -> None:
        self.last_beat[worker] = self.clock()

    def beat(self, worker: str) -> None:
        self.last_beat[worker] = self.clock()

    def dead_workers(self) -> List[str]:
        now = self.clock()
        return [w for w, t in self.last_beat.items()
                if now - t > self.timeout_s]

    def healthy(self) -> bool:
        return not self.dead_workers()


@dataclass
class PlacementMonitor:
    """Operational counters for the placement control plane.

    Canonical kinds (emitters in parentheses):
      * ``admission_rejected``    -- an arrival refused by SLA admission
                                     control (OnlineEmbedder.add).
      * ``power_budget_exceeded`` -- the refusal was the incremental power
                                     budget (spec.power_budget_w).
      * ``violation_budget_exceeded`` -- the refusal was the capacity
                                     violation tolerance (spec.violation_tol).
      * ``region_budget_breach``  -- a region's TOTAL watts crossed its
                                     spec.region_power_budget_w
                                     (FederatedSession coordinator).
      * ``cross_region_migration`` -- a service re-homed to another region
                                     after a breach (FederatedSession).

    ``count`` is also open to new kinds; ``events`` keeps the last
    ``max_events`` (kind, detail) pairs for debugging.
    """

    counters: Dict[str, int] = field(default_factory=dict)
    events: List[Tuple[str, Optional[str]]] = field(default_factory=list)
    max_events: int = 256

    def count(self, kind: str, detail: Optional[str] = None,
              n: int = 1) -> None:
        self.counters[kind] = self.counters.get(kind, 0) + n
        self.events.append((kind, detail))
        if len(self.events) > self.max_events:
            del self.events[:len(self.events) - self.max_events]

    def get(self, kind: str) -> int:
        return self.counters.get(kind, 0)

    def __getitem__(self, kind: str) -> int:
        return self.get(kind)

    def snapshot(self) -> Dict[str, int]:
        return dict(self.counters)


@dataclass
class StragglerTracker:
    """Flags steps slower than ``threshold`` x the rolling median."""

    threshold: float = 3.0
    window: int = 32
    times: List[float] = field(default_factory=list)
    flagged_steps: List[int] = field(default_factory=list)

    def record(self, step: int, duration_s: float) -> bool:
        history = self.times[-self.window:]
        self.times.append(duration_s)
        if len(history) < 5:
            return False
        med = statistics.median(history)
        if duration_s > self.threshold * med:
            self.flagged_steps.append(step)
            return True
        return False
