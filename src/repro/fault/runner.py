"""Resilient training runner: checkpoint / restart / elastic rescale.

``ResilientTrainer.run`` drives the train step with
  * periodic async checkpoints (params + optimizer + data-iterator step),
  * failure injection hooks (tests raise SimulatedFailure at chosen steps),
  * restart-from-latest-checkpoint with bitwise-identical data replay
    (the pipeline is a pure function of the step counter),
  * elastic rescale: ``rescale(new_mesh)`` re-derives shardings from the
    logical axes under the new mesh and re-places the state -- restores
    written on a 16-device mesh load fine on 8 or 32 devices.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional

import jax

from ..checkpoint import CheckpointStore
from ..data.pipeline import DataConfig, make_batch
from ..models.config import ArchConfig
from ..parallel import sharding as sh
from .monitor import HeartbeatMonitor, StragglerTracker


class SimulatedFailure(RuntimeError):
    pass


@dataclass
class RunReport:
    losses: List[float]
    restarts: int
    straggler_steps: List[int]
    final_step: int


class ResilientTrainer:
    def __init__(self, arch: ArchConfig, dcfg: DataConfig, step_fn,
                 init_state_fn: Callable[[], Any], ckpt_dir: str,
                 ckpt_every: int = 10, state_axes=None, mesh=None):
        self.arch = arch
        self.dcfg = dcfg
        self.step_fn = step_fn
        self.init_state_fn = init_state_fn
        self.store = CheckpointStore(ckpt_dir)
        self.ckpt_every = ckpt_every
        self.state_axes = state_axes
        self.mesh = mesh
        self.monitor = HeartbeatMonitor(timeout_s=60.0)
        self.straggler = StragglerTracker()

    def _shardings(self, like):
        if self.mesh is None or self.state_axes is None:
            return None
        return sh.shard_params(like, self.state_axes, self.mesh)

    def _restore_or_init(self):
        step = self.store.latest_step()
        if step is None:
            return self.init_state_fn(), 0
        like = jax.eval_shape(self.init_state_fn)
        state, extra = self.store.restore(step, like,
                                          self._shardings(like))
        return state, int(extra["data_step"])

    def rescale(self, new_mesh) -> None:
        """Elastic rescale: re-place the latest checkpoint on a new mesh."""
        self.mesh = new_mesh

    def run(self, n_steps: int,
            fail_at: Optional[Dict[int, Exception]] = None,
            max_restarts: int = 8) -> RunReport:
        fail_at = dict(fail_at or {})
        losses: List[float] = []
        restarts = 0
        while True:
            try:
                state, data_step = self._restore_or_init()
                while data_step < n_steps:
                    if data_step in fail_at:
                        raise fail_at.pop(data_step)
                    t0 = time.monotonic()
                    batch = make_batch(self.arch, self.dcfg, data_step)
                    state, metrics = self.step_fn(state, batch)
                    loss = float(metrics["loss"])
                    losses.append(loss)
                    self.straggler.record(data_step,
                                          time.monotonic() - t0)
                    self.monitor.beat("worker0")
                    data_step += 1
                    if data_step % self.ckpt_every == 0:
                        self.store.save(data_step, state,
                                        extra=dict(data_step=data_step))
                self.store.save(n_steps, state,
                                extra=dict(data_step=n_steps))
                self.store.wait()
                return RunReport(losses=losses, restarts=restarts,
                                 straggler_steps=self.straggler.flagged_steps,
                                 final_step=n_steps)
            except SimulatedFailure:
                restarts += 1
                if restarts > max_restarts:
                    raise
                # the new incarnation must not inherit detector state: old
                # step times would poison the straggler median, and the dead
                # worker would re-alarm dead_workers() forever
                self.straggler.reset()
                self.monitor.deregister("worker0")
                self.store.wait()
