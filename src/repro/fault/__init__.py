from .monitor import HeartbeatMonitor, PlacementMonitor, StragglerTracker
from .runner import ResilientTrainer, RunReport, SimulatedFailure
