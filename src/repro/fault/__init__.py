from .monitor import HeartbeatMonitor, StragglerTracker
from .runner import ResilientTrainer, RunReport, SimulatedFailure
