"""Serving entry points: prefill and decode steps over the layer-group stack.

``prefill`` embeds a prompt batch, writes every layer's KV/state cache and
returns last-position logits; ``decode_step`` consumes one token per sequence
against the cache (the function lowered for the decode_32k / long_500k
dry-run cells).  Both are pure functions of (params, batch, cache) so they
pjit cleanly; cache buffers should be donated by the caller.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models import model as M
from ..models.config import ArchConfig
from ..parallel.sharding import shard


def _encode(params, cfg: ArchConfig, frames: jax.Array) -> jax.Array:
    enc_pos = jnp.arange(frames.shape[1])
    enc_in = shard(frames.astype(jnp.dtype(cfg.dtype)), "batch", None, None)
    enc_out, _ = M.apply_stack(params, enc_in, cfg, M.encoder_plan(cfg),
                               "enc_g", positions=enc_pos,
                               remat_policy="none")
    return M.rms_norm(enc_out, params["enc_final_norm"], cfg.norm_eps)


def prefill(params, cfg: ArchConfig, batch: Dict, cache: List
            ) -> Tuple[jax.Array, List]:
    """Run the prompt through the stack, filling caches.

    batch: tokens [B, S] (+ frames / patches for stub frontends).
    Returns (last-position logits [B, V], new cache).
    """
    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = _encode(params, cfg, batch["frames"])
    x = M.embed_tokens(params, cfg, batch["tokens"])
    if cfg.vision_prefix_tokens:
        vis = shard(batch["patches"].astype(x.dtype), "batch", None, None)
        x = jnp.concatenate([vis, x], axis=1)
    positions = jnp.arange(x.shape[1])
    x, new_cache = M.apply_stack(params, x, cfg, M.layer_plan(cfg), "g",
                                 positions=positions, caches=cache,
                                 enc_out=enc_out, remat_policy="none")
    logits = M.logits_fn(params, cfg, x[:, -1:])[:, 0]
    return logits, new_cache


def decode_step(params, cfg: ArchConfig, tokens: jax.Array,
                position: jax.Array, cache: List
                ) -> Tuple[jax.Array, List]:
    """One decode step: tokens [B, 1], position [] int32 (shared offset).

    The cache already holds `position` tokens of history; returns logits for
    the next token and the updated cache.
    """
    x = M.embed_tokens(params, cfg, tokens)
    positions = position[None] if position.ndim == 0 else position
    x, new_cache = M.apply_stack(params, x, cfg, M.layer_plan(cfg), "g",
                                 positions=positions, caches=cache,
                                 remat_policy="none")
    logits = M.logits_fn(params, cfg, x)[:, 0]
    return logits, new_cache


def greedy_generate(params, cfg: ArchConfig, batch: Dict, cache: List,
                    n_steps: int) -> Tuple[jax.Array, List]:
    """Prefill + greedy decode loop (example / integration-test path)."""
    logits, cache = prefill(params, cfg, batch, cache)
    B = batch["tokens"].shape[0]
    prompt_len = batch["tokens"].shape[1] + (cfg.vision_prefix_tokens or 0)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out = [tok]

    def body(carry, i):
        tok, cache = carry
        logits, cache = decode_step(params, cfg, tok[:, None],
                                    prompt_len + i, cache)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return (tok, cache), tok

    (tok, cache), toks = jax.lax.scan(body, (tok, cache),
                                      jnp.arange(n_steps - 1))
    seq = jnp.concatenate([out[0][:, None], toks.T], axis=1)
    return seq, cache
