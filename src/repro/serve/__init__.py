from . import cache, engine
from .cache import cache_spec, sds, shardings, zeros
from .engine import decode_step, greedy_generate, prefill
