"""Per-architecture decode-state (KV cache / SSM state) specifications.

Caches mirror the layer plan: a list with one entry per layer group, each a
pytree stacked along a leading `repeats` axis.  Leaves are ``TSpec``s carrying
shape, dtype and *logical* sharding axes, so the same spec tree yields
  * zeros            (real serving),
  * ShapeDtypeStruct (dry-run lowering),
  * NamedSharding    (pjit in/out shardings).

Sizing rules:
  * full-attention layers:   Smax = max_len           (k/v ring degenerate)
  * sliding-window layers:   Smax = min(window, max_len)   (ring buffer)
  * MLA layers:              compressed c_kv [B, Smax, rank] + k_rope
  * mLSTM / sLSTM / mamba:   O(1) state -- the "KV cache of seq_len" for a
                             recurrent arch is a constant-size state (the
                             whole point of running long_500k on SSMs).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding

from ..models.config import ArchConfig
from ..models.model import LayerGroup, block_window, encoder_plan, layer_plan
from ..parallel.sharding import logical_spec


@dataclass(frozen=True)
class TSpec:
    shape: Tuple[int, ...]
    dtype: Any
    axes: Tuple


def _is_tspec(x) -> bool:
    return isinstance(x, TSpec)


def tmap(fn, tree):
    return jax.tree_util.tree_map(fn, tree, is_leaf=_is_tspec)


def zeros(tree):
    def one(s: TSpec):
        if s.dtype == jnp.int32:   # position ids start unwritten
            return jnp.full(s.shape, -1, jnp.int32)
        return jnp.zeros(s.shape, s.dtype)
    return tmap(one, tree)


def sds(tree):
    return tmap(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), tree)


def shardings(tree, mesh: Mesh):
    return tmap(lambda s: NamedSharding(
        mesh, logical_spec(s.axes, s.shape, mesh)), tree)


# ---------------------------------------------------------------------------
# per-kind cache specs
# ---------------------------------------------------------------------------


def _attn_spec(cfg: ArchConfig, B: int, smax: int, dtype) -> Dict:
    KH, Dh = cfg.n_kv_heads, cfg.head_dim
    return dict(
        k=TSpec((B, smax, KH, Dh), dtype, ("batch", "kv_seq", None, None)),
        v=TSpec((B, smax, KH, Dh), dtype, ("batch", "kv_seq", None, None)),
        pos_ids=TSpec((smax,), jnp.int32, (None,)),
    )


def _mla_spec(cfg: ArchConfig, B: int, smax: int, dtype) -> Dict:
    return dict(
        c_kv=TSpec((B, smax, cfg.kv_lora_rank), dtype,
                   ("batch", "kv_seq", None)),
        k_rope=TSpec((B, smax, cfg.rope_head_dim), dtype,
                     ("batch", "kv_seq", None)),
        pos_ids=TSpec((smax,), jnp.int32, (None,)),
    )


def _mlstm_spec(cfg: ArchConfig, B: int) -> Dict:
    Din = cfg.ssm_expand * cfg.d_model
    H = cfg.n_heads
    dqk = Din // H // 2
    dv = Din // H
    K = cfg.conv_kernel
    return dict(
        conv=TSpec((B, K - 1, Din), jnp.float32, ("batch", None, "tp")),
        cell=(TSpec((B, H, dqk, dv), jnp.float32, ("batch", "heads", None, None)),
              TSpec((B, H, dqk), jnp.float32, ("batch", "heads", None)),
              TSpec((B, H), jnp.float32, ("batch", "heads"))),
    )


def _slstm_spec(cfg: ArchConfig, B: int) -> Dict:
    H = cfg.n_heads
    dh = cfg.d_model // H
    t = lambda: TSpec((B, H, dh), jnp.float32, ("batch", "heads", None))
    return dict(h=t(), c=t(), n=t(), m=t())


def _mamba_spec(cfg: ArchConfig, B: int) -> Dict:
    Din = cfg.ssm_expand * cfg.d_model
    return dict(
        conv=TSpec((B, cfg.conv_kernel - 1, Din), jnp.float32,
                   ("batch", None, "tp")),
        h=TSpec((B, Din, cfg.ssm_state), jnp.float32, ("batch", "tp", None)),
    )


def block_cache_spec(cfg: ArchConfig, kind: str, B: int, max_len: int,
                     enc_len: int = 0, dtype=jnp.bfloat16):
    window = block_window(cfg, kind)
    smax = min(window, max_len) if window else max_len
    if kind in ("attn", "attn_local", "attn_global", "attn_moe"):
        return _attn_spec(cfg, B, smax, dtype)
    if kind == "dec_attn":
        KH, Dh = cfg.n_kv_heads, cfg.head_dim
        return dict(
            self=_attn_spec(cfg, B, smax, dtype),
            cross=dict(
                k=TSpec((B, enc_len, KH, Dh), dtype,
                        ("batch", "kv_seq", None, None)),
                v=TSpec((B, enc_len, KH, Dh), dtype,
                        ("batch", "kv_seq", None, None))),
        )
    if kind in ("mla_dense", "mla_moe"):
        return _mla_spec(cfg, B, smax, dtype)
    if kind == "mlstm":
        return _mlstm_spec(cfg, B)
    if kind == "slstm":
        return _slstm_spec(cfg, B)
    if kind in ("hymba_local", "hymba_global"):
        return dict(attn=_attn_spec(cfg, B, smax, dtype),
                    mamba=_mamba_spec(cfg, B))
    raise ValueError(f"no cache spec for kind {kind!r}")


def cache_spec(cfg: ArchConfig, batch_size: int, max_len: int,
               enc_len: int = 0, dtype=jnp.bfloat16) -> List:
    """Spec tree for the full decode state, one entry per layer group."""
    out = []
    for grp in layer_plan(cfg):
        unit = {f"b{j}": block_cache_spec(cfg, kind, batch_size, max_len,
                                          enc_len, dtype)
                for j, kind in enumerate(grp.kinds)}
        # stack along the repeats axis
        stacked = tmap(lambda s: TSpec((grp.repeats,) + s.shape, s.dtype,
                                       (None,) + s.axes), unit)
        out.append(stacked)
    return out


def cache_bytes(spec: List) -> int:
    total = 0
    for leaf in jax.tree_util.tree_leaves(spec, is_leaf=_is_tspec):
        total += math.prod(leaf.shape) * np.dtype(leaf.dtype).itemsize
    return total
