"""Placement-aware serving scheduler: the paper's technique in the serving
path.

Each inference service (an architecture + token rate) becomes a VSR; the
scheduler embeds all active services into the CFN substrate with the MILP
stand-in and accounts energy per request with the same Eq.(1)/(2) power
model.  ``route()`` then tells the serving tier (edge | fog | cloud) where
each service's stages live.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from ..core import embed as cfn_embed
from ..core import power as cfn_power
from ..core import vsr as cfn_vsr
from ..core.topology import CFNTopology
from ..models.config import ArchConfig


@dataclass
class Service:
    name: str
    arch: ArchConfig
    tokens_per_s: float
    n_stages: int = 4
    source_node: int = 0


@dataclass
class Placement:
    service: str
    stage_nodes: List[str]
    layers: List[str]
    power_w: float


class EnergyAwareScheduler:
    def __init__(self, topo: CFNTopology, method: str = "cfn-milp"):
        self.topo = topo
        self.method = method
        self.services: List[Service] = []
        self._result = None

    def add_service(self, svc: Service) -> None:
        self.services.append(svc)
        self._result = None

    def _vsrs(self) -> cfn_vsr.VSRBatch:
        batches = [cfn_vsr.from_architecture(
            s.arch, tokens_per_s=s.tokens_per_s, n_stages=s.n_stages,
            source_node=s.source_node) for s in self.services]
        out = batches[0]
        for b in batches[1:]:
            out = out.concat(b)
        return out

    def solve(self) -> List[Placement]:
        vsrs = self._vsrs()
        res = cfn_embed.embed(self.topo, vsrs, method=self.method)
        problem = cfn_power.build_problem(self.topo, vsrs)
        placements = []
        for r, svc in enumerate(self.services):
            nodes = [self.topo.proc_names[p] for p in res.X[r]]
            layers = [self.topo.proc_layer[p] for p in res.X[r]]
            placements.append(Placement(
                service=svc.name, stage_nodes=nodes, layers=layers,
                power_w=float(res.breakdown.total) / len(self.services)))
        self._result = res
        return placements

    def total_power_w(self) -> float:
        if self._result is None:
            self.solve()
        return float(self._result.breakdown.total)

    def savings_vs_cloud(self) -> Dict[str, float]:
        vsrs = self._vsrs()
        return {k: v for k, v in cfn_embed.savings_vs_baseline(
            self.topo, vsrs, baseline="cdc", method=self.method).items()
            if isinstance(v, float)}
