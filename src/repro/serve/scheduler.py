"""Placement-aware serving scheduler: the paper's technique in the serving
path, now *online*.

Each inference service (an architecture + token rate) becomes a VSR; the
scheduler embeds the active fleet into the CFN substrate and accounts
energy per tenant with the same Eq.(1)/(2) power model.  ``add_service`` /
``remove_service`` are churn events handled by the core online engine
(core.dynamic.OnlineEmbedder): the previous embedding is carried through
``power.warm_state`` and only the churned service's VMs are re-placed by
``solvers.resolve_incremental`` -- a periodic full-portfolio defrag bounds
the drift of local re-optimization.  Per-service ``Placement.power_w`` is
attributed from the per-node breakdown via each service's placed nodes and
traversed routes (``power.attribute_power``), so tenant numbers sum to the
fleet total.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np

from ..core import dynamic as cfn_dynamic
from ..core import embed as cfn_embed
from ..core import power as cfn_power
from ..core import vsr as cfn_vsr
from ..core.topology import CFNTopology
from ..models.config import ArchConfig


@dataclass
class Service:
    name: str
    arch: ArchConfig
    tokens_per_s: float
    n_stages: int = 4
    source_node: int = 0


@dataclass
class Placement:
    service: str
    stage_nodes: List[str]
    layers: List[str]
    power_w: float


class EnergyAwareScheduler:
    def __init__(self, topo: CFNTopology, method: str = "cfn-milp",
                 defrag_every: int = 16, max_hops: Optional[int] = None,
                 admit_power_budget_w: Optional[float] = None):
        self.topo = topo
        self.method = method
        self.services: List[Service] = []
        self.rejected: List[str] = []   # names refused by admission control
        self._engine = cfn_dynamic.OnlineEmbedder(
            topo, defrag_every=defrag_every, method=method,
            max_hops=max_hops, admit_power_budget_w=admit_power_budget_w)
        self._by_sid: Dict[int, Service] = {}

    # -- churn events ------------------------------------------------------
    def add_service(self, svc: Service) -> List[Placement]:
        """Admit a service: one incremental re-embedding event.  Names key
        the removal API, so they must be unique among live services.  With
        SLA admission control configured (max_hops / power budget), a
        refused service is recorded in ``self.rejected`` and the fleet
        placement is returned unchanged."""
        if any(s.name == svc.name for s in self.services):
            raise ValueError(f"service named {svc.name!r} is already live")
        vs = cfn_vsr.from_architecture(
            svc.arch, tokens_per_s=svc.tokens_per_s, n_stages=svc.n_stages,
            source_node=svc.source_node)
        if self._engine.add(vs) is None:
            self.rejected.append(svc.name)
            return self.placements()
        self.services.append(svc)
        self._by_sid[self._engine.sids[-1]] = svc
        return self.placements()

    def remove_service(self, name: str) -> List[Placement]:
        """Retire a service by name: detach + survivor re-pack."""
        sid = next((s for s, svc in self._by_sid.items()
                    if svc.name == name), None)
        if sid is None:
            raise KeyError(f"no service named {name!r}")
        self._engine.remove(sid)
        svc = self._by_sid.pop(sid)
        self.services.remove(svc)    # by identity: exactly this admission
        return self.placements()

    def defrag(self) -> List[Placement]:
        """Force a full-portfolio re-pack of the current fleet."""
        self._engine.defrag()
        return self.placements()

    # -- reporting ---------------------------------------------------------
    def placements(self) -> List[Placement]:
        res = self._engine.result
        if res is None:
            return []
        per_w = self._engine.per_service_power_w()
        placements = []
        for row, sid in enumerate(self._engine.sids):
            svc = self._by_sid[sid]
            V = self._engine.service_vms(row)   # rest is concat padding
            nodes = [self.topo.proc_names[p] for p in res.X[row][:V]]
            layers = [self.topo.proc_layer[p] for p in res.X[row][:V]]
            placements.append(Placement(
                service=svc.name, stage_nodes=nodes, layers=layers,
                power_w=per_w[sid]))
        return placements

    def solve(self) -> List[Placement]:
        """Kept for the one-shot API: returns the current placements (the
        engine re-solves eagerly on every churn event)."""
        return self.placements()

    def total_power_w(self) -> float:
        return self._engine.power_w()

    def savings_vs_cloud(self) -> Dict[str, float]:
        vsrs = self._vsrs()
        return {k: v for k, v in cfn_embed.savings_vs_baseline(
            self.topo, vsrs, baseline="cdc", method=self.method).items()
            if isinstance(v, float)}

    def _vsrs(self) -> cfn_vsr.VSRBatch:
        batches = [cfn_vsr.from_architecture(
            s.arch, tokens_per_s=s.tokens_per_s, n_stages=s.n_stages,
            source_node=s.source_node) for s in self.services]
        out = batches[0]
        for b in batches[1:]:
            out = out.concat(b)
        return out
