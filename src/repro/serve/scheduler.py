"""Placement-aware serving scheduler: the paper's technique in the serving
path, now a thin consumer of the unified placement API.

Each inference service (an architecture + token rate) becomes a VSR; the
scheduler drives a ``repro.api.CFNSession`` -- or, passed via
``session=``, a multi-region ``repro.api.FederatedSession``, so serving
schedules onto a federated fog unchanged -- whose declarative
``PlacementSpec`` carries the constraint set (SLA hop bounds, admission
power budget, regional budgets) and the portfolio configuration.  ``add_service`` /
``remove_service`` are churn events on the session: the previous embedding
is carried through ``power.warm_state`` and only the churned service's VMs
are re-placed by ``solvers.resolve_incremental`` -- a periodic
full-portfolio defrag (masked by the same spec) bounds the drift of local
re-optimization.  Per-service ``Placement.power_w`` is attributed from the
per-node breakdown via each service's placed nodes and traversed routes
(``CFNSession.attribute``), so tenant numbers sum to the fleet total.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

from ..core import api as cfn_api
from ..core import embed as cfn_embed
from ..core import vsr as cfn_vsr
from ..core.topology import CFNTopology
from ..models.config import ArchConfig


@dataclass
class Service:
    name: str
    arch: ArchConfig
    tokens_per_s: float
    n_stages: int = 4
    source_node: int = 0
    priority: int = 0   # admission class, 0 = highest (spec.priority_classes)


@dataclass
class Placement:
    service: str
    stage_nodes: List[str]
    layers: List[str]
    power_w: float


class EnergyAwareScheduler:
    def __init__(self, topo: CFNTopology, method: str = "cfn-milp",
                 defrag_every: int = 16, max_hops: Optional[int] = None,
                 admit_power_budget_w: Optional[float] = None,
                 spec: Optional[cfn_api.PlacementSpec] = None,
                 session=None, monitor=None, telemetry=None):
        """``session`` (optional) supplies a pre-built placement session --
        a ``CFNSession`` or a multi-region ``FederatedSession`` -- so the
        serving path schedules onto a federation unchanged; otherwise a
        flat session is built from ``spec`` (or the legacy kwargs).
        ``monitor`` (a ``fault.monitor.PlacementMonitor``) receives
        admission rejections and budget violations; ``telemetry`` (a
        ``repro.telemetry.Telemetry``) receives spans, the energy ledger,
        and compile attribution from the underlying session."""
        if spec is None:
            spec = cfn_api.PlacementSpec(
                method=method, defrag_every=defrag_every, max_hops=max_hops,
                power_budget_w=admit_power_budget_w)
        self.topo = topo
        if session is not None:
            if monitor is not None:
                session.attach_monitor(monitor)
            if telemetry is not None:
                session.attach_telemetry(telemetry)
            self.session = session
        else:
            self.session = cfn_api.CFNSession(topo, spec, monitor=monitor,
                                              telemetry=telemetry)
        self.services: List[Service] = []
        self.rejected: List[str] = []   # names refused by admission control
        self.queued: List[str] = []     # names parked in the priority queue
        self._by_sid: Dict[int, Service] = {}
        self._queued_by_sid: Dict[int, Service] = {}

    @property
    def spec(self) -> cfn_api.PlacementSpec:
        return self.session.spec

    @property
    def method(self) -> str:
        return self.session.spec.method

    # -- churn events ------------------------------------------------------
    def add_service(self, svc: Service) -> List[Placement]:
        """Admit a service: one incremental re-embedding event.  Names key
        the removal API, so they must be unique among live services.  With
        SLA admission control configured (spec.max_hops / power budget), a
        refused service is recorded in ``self.rejected`` and the fleet
        placement is returned unchanged."""
        if any(s.name == svc.name for s in self.services):
            raise ValueError(f"service named {svc.name!r} is already live")
        vs = self._to_vsr(svc)
        before = self._session_queued_sids()
        if self.session.add(vs, priority=svc.priority) is None:
            fresh = [s for s in self._session_queued_sids() - before
                     if s not in self._by_sid]
            if fresh:   # parked, not refused: keeps its sid in the queue
                sid = max(fresh)
                self.queued.append(svc.name)
                self._queued_by_sid[sid] = svc
            else:
                self.rejected.append(svc.name)
            self._adopt_drained()
            return self.placements()
        self.services.append(svc)
        self._by_sid[self.session.sids[-1]] = svc
        self._adopt_drained()
        return self.placements()

    def add_services(self, svcs: List[Service]) -> List[Placement]:
        """Admit a BATCH of services as one churn wave
        (``session.apply_wave``): one fused re-solve + single polish pass
        instead of one per service, with admission decided per service in
        priority order.  Refused names land in ``self.rejected``, parked
        ones (``spec.queue_rejected``) in ``self.queued``."""
        for svc in svcs:
            if any(s.name == svc.name for s in self.services):
                raise ValueError(
                    f"service named {svc.name!r} is already live")
        names = [s.name for s in svcs]
        if len(names) != len(set(names)):
            raise ValueError("duplicate service name in batch")
        wres = self.session.apply_wave(
            [(self._to_vsr(s), None, s.priority) for s in svcs])
        by_sid = dict(zip(wres.sids, svcs))
        for sid in wres.admitted:
            self.services.append(by_sid[sid])
            self._by_sid[sid] = by_sid[sid]
        self.rejected.extend(by_sid[sid].name for sid in wres.rejected)
        # a queued service keeps its sid while parked and re-enters the
        # fleet under it when capacity frees (see _adopt_drained)
        for sid in wres.queued:
            self.queued.append(by_sid[sid].name)
            self._queued_by_sid[sid] = by_sid[sid]
        self._adopt_drained()
        return self.placements()

    def remove_service(self, name: str) -> List[Placement]:
        """Retire a service by name: detach + survivor re-pack."""
        sid = next((s for s, svc in self._by_sid.items()
                    if svc.name == name), None)
        if sid is None:
            raise KeyError(f"no service named {name!r}")
        self.session.remove(sid)
        svc = self._by_sid.pop(sid)
        self.services.remove(svc)    # by identity: exactly this admission
        self._adopt_drained()
        return self.placements()

    def remove_services(self, names: List[str]) -> List[Placement]:
        """Retire a BATCH of services as one departure wave: one fused
        ``detach_vsrs`` + one survivor re-settle, then the freed capacity
        drains the priority queue."""
        sids = []
        for name in names:
            sid = next((s for s, svc in self._by_sid.items()
                        if svc.name == name), None)
            if sid is None:
                raise KeyError(f"no service named {name!r}")
            sids.append(sid)
        self.session.apply_wave(departures=sids)
        for sid in sids:
            svc = self._by_sid.pop(sid)
            self.services.remove(svc)
        self._adopt_drained()
        return self.placements()

    def _to_vsr(self, svc: Service) -> cfn_vsr.VSRBatch:
        return cfn_vsr.from_architecture(
            svc.arch, tokens_per_s=svc.tokens_per_s, n_stages=svc.n_stages,
            source_node=svc.source_node)

    def _adopt_drained(self) -> None:
        """Reconcile queue churn with the session.  A parked service keeps
        its sid in the session's priority queue, so when freed capacity
        re-admits it the same sid shows up live -- move it queued -> live.
        Symmetrically, a live service preempted by a higher class
        (``spec.preempt``) moves live -> queued."""
        for sid in self.session.sids:
            svc = self._queued_by_sid.pop(sid, None)
            if svc is not None:
                self.services.append(svc)
                self._by_sid[sid] = svc
                self.queued.remove(svc.name)
        live = set(self.session.sids)
        gone = [s for s in self._by_sid if s not in live]
        if gone:
            parked = self._session_queued_sids()
            for sid in gone:
                if sid in parked:
                    svc = self._by_sid.pop(sid)
                    self.services.remove(svc)
                    self._queued_by_sid[sid] = svc
                    self.queued.append(svc.name)

    def _session_queued_sids(self) -> set:
        eng = getattr(self.session, "engine", None)
        if eng is not None:   # flat CFNSession
            return set(eng.queued_sids)
        out = set()
        for eng in getattr(self.session, "_engines", {}).values():
            out.update(eng.queued_sids)
        out.update(e[1] for e in getattr(self.session, "_fqueue", ()))
        return out

    def defrag(self) -> List[Placement]:
        """Force a full-portfolio re-pack of the current fleet (the spec's
        constraint masks apply -- hop-bounded services stay in radius)."""
        self.session.defrag()
        return self.placements()

    # -- reporting ---------------------------------------------------------
    def placements(self) -> List[Placement]:
        X = self.session.X   # merged node ids for flat AND federated paths
        if X is None:
            return []
        per_w = self.session.attribute()
        placements = []
        for row, sid in enumerate(self.session.sids):
            svc = self._by_sid.get(sid)
            if svc is None:   # admitted outside this facade (raw session)
                continue
            V = self.session.service_vms(row)   # rest is bucket/concat pad
            nodes = [self.topo.proc_names[p] for p in X[row][:V]]
            layers = [self.topo.proc_layer[p] for p in X[row][:V]]
            placements.append(Placement(
                service=svc.name, stage_nodes=nodes, layers=layers,
                power_w=per_w[sid]))
        return placements

    def solve(self) -> List[Placement]:
        """Kept for the one-shot API: returns the current placements (the
        session re-solves eagerly on every churn event)."""
        return self.placements()

    def total_power_w(self) -> float:
        return self.session.power_w()

    def savings_vs_cloud(self) -> Dict[str, float]:
        vsrs = self._vsrs()
        return {k: v for k, v in cfn_embed.savings_vs_baseline(
            self.topo, vsrs, baseline="cdc", method=self.method).items()
            if isinstance(v, float)}

    def _vsrs(self) -> cfn_vsr.VSRBatch:
        batches = [cfn_vsr.from_architecture(
            s.arch, tokens_per_s=s.tokens_per_s, n_stages=s.n_stages,
            source_node=s.source_node) for s in self.services]
        out = batches[0]
        for b in batches[1:]:
            out = out.concat(b)
        return out
