from . import compress, step
from .step import TrainState, init_state, make_grads_fn, make_train_step
