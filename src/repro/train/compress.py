"""Error-feedback int8 gradient compression for the cross-pod reduction.

The pod axis is the slow (DCN) axis: the per-step cross-pod gradient
all-reduce is the dominant inter-pod collective.  We compress it by
  1. adding the carried error-feedback residual to the local gradient,
  2. quantizing to int8 with a per-tensor fp32 scale,
  3. all-gathering the int8 payload over 'pod' (1 byte/element on the wire
     instead of 2-4) and summing the dequantized shards locally,
  4. keeping the quantization error as the next step's residual.

Implemented with ``jax.shard_map`` manual over *only* the 'pod' axis
(`axis_names={'pod'}`): data/model axes stay automatic, so the body is still
ordinary pjit-style code.
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..parallel.sharding import shard_map_compat


def quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_pod_sum(g: jax.Array, err: jax.Array, n_pods: int
                       ) -> Tuple[jax.Array, jax.Array]:
    """Inside a shard_map manual over 'pod': returns (mean-grad, new error)."""
    x = g.astype(jnp.float32) + err
    q, scale = quantize(x)
    new_err = x - dequantize(q, scale)
    qs = jax.lax.all_gather(q, "pod")          # [n_pods, ...] int8 on the wire
    ss = jax.lax.all_gather(scale, "pod")      # [n_pods] fp32
    total = jnp.tensordot(ss, qs.astype(jnp.float32), axes=1)
    return (total / n_pods).astype(g.dtype), new_err


def make_compressed_sync(mesh, param_specs):
    """Build sync(grads, err) -> (grads, err) with int8 pod all-gather.

    ``param_specs``: pytree of PartitionSpecs for the gradient tree (its
    data/model factors); the pod axis never appears in parameter specs, so
    grads are pod-local partial means going in and pod-averaged coming out.
    """
    n_pods = mesh.shape.get("pod", 1)

    def body(grads, err):
        out = jax.tree_util.tree_map(
            lambda g, e: compressed_pod_sum(g, e, n_pods), grads, err)
        new_g = jax.tree_util.tree_map(lambda _, o: o[0], grads, out)
        new_e = jax.tree_util.tree_map(lambda _, o: o[1], grads, out)
        return new_g, new_e

    if n_pods == 1:
        return lambda grads, err: (grads, err)

    specs = (param_specs, param_specs)
    return shard_map_compat(body, mesh, in_specs=specs, out_specs=specs,
                            axis_names={"pod"})
