"""Training step factory: grad accumulation, clipping, AdamW, compression.

``make_train_step`` returns a pure ``step(state, batch) -> (state, metrics)``
suitable for ``jax.jit`` with donated state.  Gradient accumulation splits
the (already pod+data-sharded) global batch along the leading axis and
accumulates fp32 gradients with ``lax.scan`` so peak activation memory is
one microbatch regardless of global batch size.

With ``compress_pod=True`` the gradient computation is wrapped in a
``shard_map`` manual over the 'pod' axis only: each pod computes grads on its
local half of the batch and the cross-pod reduction is the error-feedback
int8 all-gather from compress.py instead of a bf16 all-reduce.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..models import model as M
from ..models.config import ArchConfig
from ..optim import adamw
from ..parallel.sharding import shard_map_compat


class TrainState(NamedTuple):
    params: Any
    opt: adamw.OptState
    step: jnp.ndarray
    err: Any = None          # error-feedback residuals (compression only)


def init_state(cfg: ArchConfig, key: jax.Array,
               compress_pod: bool = False) -> Tuple[TrainState, Dict]:
    params, axes = M.init_model(cfg, key)
    opt = adamw.init(params)
    err = (jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
        if compress_pod else None)
    return TrainState(params=params, opt=opt,
                      step=jnp.zeros((), jnp.int32), err=err), axes


def _split_microbatches(batch: Dict, accum: int) -> Dict:
    def resh(x):
        b = x.shape[0]
        assert b % accum == 0, (b, accum)
        return x.reshape(accum, b // accum, *x.shape[1:])
    return {k: resh(v) for k, v in batch.items()}


def _grad_constrainer(param_axes):
    """Constrain gradients to the parameter shardings (ZeRO semantics):
    the per-microbatch gradient reduction lowers to reduce-scatter instead
    of a full all-reduce, and the fp32 accumulator is stored sharded."""
    from ..parallel import sharding as sh_mod

    def constrain(grads):
        mesh = sh_mod.current_mesh()
        if param_axes is None or mesh is None:
            return grads
        shardings = sh_mod.shard_params(grads, param_axes, mesh)
        return jax.tree_util.tree_map(
            lambda g, s: (jax.lax.with_sharding_constraint(g, s)
                          if s is not None else g), grads, shardings)

    return constrain


def make_grads_fn(cfg: ArchConfig, accum: int = 1,
                  compute_dtype=jnp.bfloat16, param_axes=None):
    """Gradient function with mixed precision + sharded accumulation.

    Parameters stay fp32 masters; a bf16 copy is differentiated so every
    FSDP gather and gradient reduction moves 2-byte payloads (collective
    term halved vs fp32 -- §Perf).  compute_dtype=None disables the cast.
    """
    constrain = _grad_constrainer(param_axes)

    def cast(params):
        if compute_dtype is None:
            return params
        return jax.tree_util.tree_map(
            lambda p: p.astype(compute_dtype)
            if p.dtype == jnp.float32 else p, params)

    def loss_fn(p16, mb):
        return M.forward_train(p16, cfg, mb)

    if accum == 1:
        def grads_fn(params, batch):
            loss, g = jax.value_and_grad(loss_fn)(cast(params), batch)
            g = constrain(g)
            return loss, jax.tree_util.tree_map(
                lambda x: x.astype(jnp.float32), g)
        return grads_fn

    def grads_fn(params, batch):
        mbs = _split_microbatches(batch, accum)
        p16 = cast(params)

        def body(carry, mb):
            loss_acc, g_acc = carry
            loss, g = jax.value_and_grad(loss_fn)(p16, mb)
            g = constrain(g)
            g_acc = jax.tree_util.tree_map(
                lambda a, b: a + b.astype(jnp.float32), g_acc, g)
            return (loss_acc + loss, g_acc), None

        zeros = constrain(jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params))
        (loss, g), _ = jax.lax.scan(body, (jnp.zeros(()), zeros), mbs)
        inv = 1.0 / accum
        g = jax.tree_util.tree_map(lambda x: x * inv, g)
        return loss * inv, g

    return grads_fn


def make_train_step(cfg: ArchConfig, opt_cfg: adamw.AdamWConfig,
                    accum: int = 1, compress_pod: bool = False,
                    mesh=None, compute_dtype=jnp.bfloat16,
                    param_axes=None):
    """Returns step(state, batch) -> (state, metrics)."""
    grads_fn = make_grads_fn(cfg, accum, compute_dtype=compute_dtype,
                             param_axes=param_axes)

    if not compress_pod:
        def step(state: TrainState, batch: Dict):
            loss, grads = grads_fn(state.params, batch)
            params, opt, metrics = adamw.apply_updates(
                state.params, grads, state.opt, opt_cfg)
            metrics["loss"] = loss
            return TrainState(params, opt, state.step + 1, state.err), metrics
        return step

    assert mesh is not None, "compress_pod needs the mesh"
    from .compress import compressed_pod_sum
    n_pods = mesh.shape.get("pod", 1)

    def pod_body(params, batch, err):
        loss, g = grads_fn(params, batch)
        if n_pods > 1:
            synced = jax.tree_util.tree_map(
                lambda gi, ei: compressed_pod_sum(gi, ei, n_pods), g, err)
            g = jax.tree_util.tree_map(lambda _, o: o[0], g, synced)
            err = jax.tree_util.tree_map(lambda _, o: o[1], g, synced)
            loss = jax.lax.pmean(loss, "pod")
        return loss, g, err

    wrapped = shard_map_compat(
        pod_body, mesh,
        in_specs=(P(), P("pod"), P()),
        out_specs=(P(), P(), P()),
        axis_names={"pod"})

    def step(state: TrainState, batch: Dict):
        loss, grads, err = wrapped(state.params, batch, state.err)
        params, opt, metrics = adamw.apply_updates(
            state.params, grads, state.opt, opt_cfg)
        metrics["loss"] = loss
        return TrainState(params, opt, state.step + 1, err), metrics

    return step
