"""Pallas TPU flash attention (GQA + sliding window + logit softcap).

Targets the MXU: the score/PV products are [bq, d] x [d, bkv] and
[bq, bkv] x [bkv, d] dots per tile, with the online-softmax running max/sum
held in VMEM scratch across the kv grid dimension (TPU grids execute
sequentially, so scratch persists along the last axis).

Grid: (B * H, Sq // bq, Skv // bkv); block shapes are explicit BlockSpecs:
  q   (1, 1, bq, D)    indexed by (bh, iq)
  k/v (1, 1, bkv, D)   indexed by (bh // G, jkv)   -- GQA head folding
  out (1, 1, bq, D)    indexed by (bh, iq), written at the last kv step

The pure-jnp oracle is kernels/ref.py::flash_attention_ref (the same
online-softmax math, used by the model stack); tests sweep shapes, dtypes,
windows and softcaps in interpret mode.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: Optional[int],
            logit_cap: Optional[float], q_offset: int, kv_len: int,
            bq: int, bkv: int, n_kv: int):
    jkv = pl.program_id(2)

    @pl.when(jkv == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # [bq, D]
    k = k_ref[0, 0].astype(jnp.float32)                  # [bkv, D]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))   # [bq, bkv]
    if logit_cap is not None:
        s = logit_cap * jnp.tanh(s / logit_cap)

    iq = pl.program_id(1)
    q_pos = q_offset + iq * bq + jax.lax.broadcasted_iota(
        jnp.int32, (bq, bkv), 0)
    kv_pos = jkv * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    mask = kv_pos < kv_len                                # kv padding
    if causal:
        rel = q_pos - kv_pos
        mask = mask & (rel >= 0)
        if window is not None:
            mask = mask & (rel < window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                   # [bq]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=-1)
    v = v_ref[0, 0].astype(jnp.float32)                   # [bkv, D]
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))  # [bq, D]
    acc_ref[...] = acc_ref[...] * corr[:, None] + pv
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(jkv == n_kv - 1)
    def _finish():
        l = l_ref[...]
        safe = jnp.maximum(l, 1e-20)
        o_ref[0, 0, :, :] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


def flash_attention_tpu(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: Optional[int] = None,
                        logit_cap: Optional[float] = None, q_offset: int = 0,
                        bq: int = 128, bkv: int = 128,
                        interpret: bool = False) -> jax.Array:
    """q [B, H, Sq, D]; k/v [B, KH, Skv, D] -> [B, H, Sq, D].

    Sq/Skv are padded to block multiples here; padding keys are masked via
    ``kv_len`` and padded query rows are sliced off the result.
    """
    B, H, Sq, D = q.shape
    KH, Skv = k.shape[1], k.shape[2]
    G = H // KH
    scale = 1.0 / math.sqrt(D)

    bq = min(bq, max(Sq, 8))
    bkv = min(bkv, max(Skv, 8))
    pq = (-Sq) % bq
    pkv = (-Skv) % bkv
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pkv:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pkv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pkv), (0, 0)))
    Sqp, Skvp = Sq + pq, Skv + pkv
    n_q, n_kv = Sqp // bq, Skvp // bkv

    grid = (B * H, n_q, n_kv)
    kern = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        logit_cap=logit_cap, q_offset=q_offset, kv_len=Skv,
        bq=bq, bkv=bkv, n_kv=n_kv)

    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D),
                         lambda bh, iq, jkv: (bh // H, bh % H, iq, 0)),
            pl.BlockSpec((1, 1, bkv, D),
                         lambda bh, iq, jkv: (bh // H, (bh % H) // G, jkv, 0)),
            pl.BlockSpec((1, 1, bkv, D),
                         lambda bh, iq, jkv: (bh // H, (bh % H) // G, jkv, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D),
                               lambda bh, iq, jkv: (bh // H, bh % H, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sqp, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),      # running max
            pltpu.VMEM((bq,), jnp.float32),      # running sum
            pltpu.VMEM((bq, D), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :Sq, :]
