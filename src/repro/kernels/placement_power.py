"""Pallas TPU kernels for the CFN placement objective (paper Eq. 1+2).

Two kernels share the Eq.(1)/(2) math:

  * ``placement_power_tpu`` -- batched FULL evaluation: each grid step
    evaluates a [bc]-candidate block entirely in VMEM (one-hot contractions
    on the MXU, elementwise power terms on the VPU).  Used when a whole
    placement changes (genetic crossover, exhaustive enumeration) and as
    the oracle-checked reference kernel.

  * ``fused_anneal_tpu`` -- the solver hot loop.  Simulated annealing
    mutates ONE VM per Metropolis step, so instead of launching a full
    [bc]-candidate evaluation per step, this kernel keeps the per-chain
    placement AND its live load tensors (omega[P], theta[P], lam[N], obj)
    resident in VMEM and fuses proposal -> delta-evaluation -> accept across
    the entire chain: one launch for the whole schedule.  The delta math
    mirrors core.power's incremental engine (the processing terms move only
    at the source/destination node; the network terms only along the two
    touched routes), expressed as one-hot contractions so it vectorizes over
    the [bc] chains in a block.  Proposals (free-VM index, destination,
    uniform draw) are precomputed outside and streamed from VMEM.

Routes enter both kernels as the padded-CSR table ``route_flat [P*P, K]``
(float32 node ids, sentinel N marks padding) instead of the dense
``[P*P, N]`` incidence tensor: a route lookup is a one-hot row-select matmul
returning <= K ids, expanded against an N-iota only where traffic actually
flows.  At city scale (P ~ 256, N ~ 90, K ~ 14) the table shrinks from
P^2*N*4B ~ 22 MB -- past VMEM -- to P^2*K*4B ~ 3.5 MB, which is what lets
chain state PLUS routes stay VMEM-resident in the fused kernel.

Blocked over candidates/chains: problem tensors (route table, device
parameters, per-VM incident-link tables) are broadcast to every block via
constant index maps.  Oracles: kernels/ref.py::placement_objective_ref for
the full kernel, ref.placement_delta_ref (float64) for the fused deltas;
core.power re-evaluation pins the fused kernel's reported best objective.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Mirrors core.power (kernels stay import-clean of core).
ACTIVE_EPS = 1.0e-6
PENALTY = 1.0e4
SNAP_GFLOPS = 1.0e-3
SNAP_MBPS = 1.0e-2


def mask_proposals(j_prop, p_prop, eligible, V: int):
    """Project precomputed proposal destinations onto per-row eligible sets.

    The fused anneal kernel streams its Metropolis proposals from VMEM
    rather than sampling in-kernel, so SLA eligibility
    (``repro.api.PlacementSpec.masks``) enters the kernel as proposal
    masking HERE: any destination outside its service row's eligible set is
    replaced by that row's first eligible node before the stream reaches
    the kernel, so the chain can never be asked to accept an ineligible
    move.  Upstream samplers (``core.solvers._anneal_proposals``) already
    draw from the eligible set, making this the kernel-side guarantee
    rather than the primary sampler.

    j_prop/p_prop [C, T] (flat free-VM index, destination node);
    eligible [R, P] bool; V = VMs per service (flat index stride).
    """
    el = jnp.asarray(eligible)
    rows = j_prop // V
    ok = el[rows, p_prop]
    fallback = jnp.argmax(el, axis=1).astype(p_prop.dtype)
    return jnp.where(ok, p_prop, fallback[rows])


def _power_terms(omega, theta, lam, pp, nn):
    """Eq.(1)/(2) from loads; broadcasts over leading dims.

    omega/theta [..., P], lam [..., N]; pp [9, P]; nn [5, N].
    Returns (objective, net, proc, violation) each [...].
    """
    E, C_pr, NS, pi_pr, pue_pr, EL, C_lan, pi_lan, lan_share = \
        (pp[i] for i in range(9))
    eps, C_net, pi_net, pue_net, idle_share = (nn[i] for i in range(5))
    n_srv = jnp.ceil(omega / C_pr)
    beta = (lam > ACTIVE_EPS).astype(jnp.float32)
    phi = ((omega > ACTIVE_EPS) | (theta > ACTIVE_EPS)).astype(jnp.float32)
    per_net = pue_net * (eps * lam / 1e3 + beta * idle_share * pi_net)
    per_proc = pue_pr * (E * omega + n_srv * pi_pr
                         + EL * theta / 1e3 + phi * lan_share * pi_lan)
    relu = lambda x: jnp.maximum(x, 0.0)
    violation = (jnp.sum(relu(omega - NS * C_pr), axis=-1)
                 + jnp.sum(relu(lam / 1e3 - C_net), axis=-1)
                 + jnp.sum(relu(theta / 1e3 - C_lan), axis=-1))
    net = jnp.sum(per_net, axis=-1)
    proc = jnp.sum(per_proc, axis=-1)
    return net + proc + PENALTY * violation, net, proc, violation


def _block_loads(X, U, W, F, H, route, *, P: int, N: int, K: int, bc: int):
    """One-hot load contractions for a [bc]-placement block.

    X [bc, J]; U/W [bc, L] link-endpoint placements; route [P*P, K] CSR
    node-id table (float32 ids, sentinel N); returns (omega [bc, P],
    theta [bc, P], lam [bc, N]).

    lambda is per-link over the route table: a two-stage one-hot matmul
    gathers each link's <= K route node ids, and a final N-iota compare
    accumulates the bitrates -- no [P*P, N] operand in the kernel.
    """
    iota_p = jax.lax.broadcasted_iota(jnp.int32, (1, 1, P), 2)
    oh_x = (X[:, :, None] == iota_p).astype(jnp.float32)        # [bc, J, P]
    oh_u = (U[:, :, None] == iota_p).astype(jnp.float32)        # [bc, L, P]
    oh_w = (W[:, :, None] == iota_p).astype(jnp.float32)        # [bc, L, P]
    L = U.shape[1]

    omega = jax.lax.dot_general(
        oh_x, F, (((1,), (0,)), ((), ())))                       # [bc, P]
    # lam: row-select the source side, then contract the destination side
    rowsel = jax.lax.dot_general(
        oh_u.reshape(bc * L, P), route.reshape(P, P * K),
        (((1,), (0,)), ((), ()))).reshape(bc, L, P, K)
    ids = jnp.einsum("clq,clqk->clk", oh_w, rowsel)              # [bc, L, K]
    iota_n = jax.lax.broadcasted_iota(jnp.int32, (bc, L, K, N), 3)
    oh_n = (iota_n == ids.astype(jnp.int32)[..., None]).astype(jnp.float32)
    lam = jnp.einsum("l,clkn->cn", H, oh_n)                      # [bc, N]
    # theta: traffic touching node p (in + out minus double-counted
    # intra-node traffic)
    uh = oh_u * H[None, :, None]
    ones = jnp.ones((bc, L), jnp.float32)
    t_out = jax.lax.dot_general(uh, ones, (((1,), (1,)), ((0,), (0,))))
    wh = oh_w * H[None, :, None]
    t_in = jax.lax.dot_general(wh, ones, (((1,), (1,)), ((0,), (0,))))
    intra = jnp.sum(uh * oh_w, axis=1)                           # [bc, P]
    theta = t_out + t_in - intra
    return omega, theta, lam


def _kernel(x_ref, u_ref, w_ref,
            f_ref, h_ref, route_ref, pp_ref, nn_ref,
            out_ref, *, P: int, N: int, K: int, bc: int):
    X = x_ref[...]                                   # [bc, J]  int32
    U = u_ref[...]                                   # [bc, L]  int32
    W = w_ref[...]                                   # [bc, L]  int32
    F = f_ref[...]                                   # [J]
    H = h_ref[...]                                   # [L]
    route = route_ref[...]                           # [P*P, K] float ids
    pp = pp_ref[...]                                 # [9, P] processing params
    nn = nn_ref[...]                                 # [5, N] network params

    omega, theta, lam = _block_loads(X, U, W, F, H, route,
                                     P=P, N=N, K=K, bc=bc)
    obj, net, proc, violation = _power_terms(omega, theta, lam, pp, nn)
    out_ref[:, 0] = obj
    out_ref[:, 1] = net
    out_ref[:, 2] = proc
    out_ref[:, 3] = violation


def placement_power_tpu(X: jax.Array, link_src: jax.Array,
                        link_dst: jax.Array, F: jax.Array, H: jax.Array,
                        route_flat: jax.Array, proc_params: jax.Array,
                        net_params: jax.Array, *, bc: int = 256,
                        interpret: bool = False) -> jax.Array:
    """Evaluate B candidate placements.

    X [B, J=R*V] int32 (pins already applied); link_src/dst [L] indices into
    the flattened VM space; F [J] GFLOPS; H [L] Mbps; route_flat [P*P, K]
    float32 CSR node ids (sentinel N); proc_params [9, P]; net_params [5, N].
    Returns [B, 4]: (objective, net W, proc W, violation).
    """
    B, J = X.shape
    L = link_src.shape[0]
    P = proc_params.shape[1]
    N = net_params.shape[1]
    K = route_flat.shape[1]
    bc = min(bc, max(B, 8))
    pad = (-B) % bc
    if pad:
        X = jnp.pad(X, ((0, pad), (0, 0)))
    Bp = B + pad
    U = jnp.take(X, link_src, axis=1)                 # [Bp, L]
    W = jnp.take(X, link_dst, axis=1)

    grid = (Bp // bc,)
    const = lambda i: (0, 0)
    out = pl.pallas_call(
        functools.partial(_kernel, P=P, N=N, K=K, bc=bc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bc, J), lambda i: (i, 0)),
            pl.BlockSpec((bc, L), lambda i: (i, 0)),
            pl.BlockSpec((bc, L), lambda i: (i, 0)),
            pl.BlockSpec((J,), lambda i: (0,)),
            pl.BlockSpec((L,), lambda i: (0,)),
            pl.BlockSpec((P * P, K), const),
            pl.BlockSpec((9, P), const),
            pl.BlockSpec((5, N), const),
        ],
        out_specs=pl.BlockSpec((bc, 4), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Bp, 4), jnp.float32),
        interpret=interpret,
    )(X, U, W, F, H, route_flat, proc_params, net_params)
    return out[:B]


def pack_problem(problem) -> Tuple[jax.Array, ...]:
    """Flatten a core.power.PlacementProblem into kernel operands.

    The route table ships as float32 node ids so in-kernel route lookups are
    one-hot matmuls; ids (< N + 1) are exactly representable."""
    p = problem
    route_flat = p.route_idx.reshape(p.P * p.P, p.K).astype(jnp.float32)
    proc_params = jnp.stack([p.E, p.C_pr, p.NS, p.pi_pr, p.pue_pr,
                             p.EL, p.C_lan, p.pi_lan, p.lan_share])
    net_params = jnp.stack([p.eps, p.C_net, p.pi_net, p.pue_net,
                            p.idle_share])
    F = p.F.reshape(-1)
    return (p.link_src, p.link_dst, F, p.link_h, route_flat,
            proc_params, net_params)


# ---------------------------------------------------------------------------
# Fused annealing kernel
# ---------------------------------------------------------------------------

def _fused_kernel(x_ref, j_ref, pn_ref, un_ref, temps_ref,
                  f_ref, io_ref, ih_ref, is_ref, route_ref, pp_ref,
                  nn_ref, om_ref, th_ref, lm_ref, ob_ref, bx_ref, stat_ref, *,
                  P: int, N: int, K: int, J: int, D: int, T: int, bc: int):
    """Whole Metropolis chain for a [bc]-chain block, state in VMEM.

    All per-step gathers are expressed as iota-compare one-hots +
    contractions so they vectorize on TPU (no dynamic scatter/gather).
    Routes come from the compact CSR table (``route_ref [P*P, K]`` float
    ids): route lookups are one-hot row-select matmuls followed by an
    N-iota expansion of <= K ids -- the table is K/N the size of the dense
    incidence tensor, which is what keeps chain state + routes VMEM-resident
    at P >> 100.  Initial loads (omega/theta/lam/obj) are computed outside
    the kernel (one batched evaluation) and streamed in."""
    X0 = x_ref[...]                                  # [bc, J] int32
    F = f_ref[...]                                   # [J]
    route = route_ref[...]                           # [P*P, K] float ids
    pp = pp_ref[...]                                 # [9, P]
    nn = nn_ref[...]                                 # [5, N]
    inc_o = io_ref[...]                              # [J, D] int32 other VM
    inc_h = ih_ref[...]                              # [J, D] bitrate
    inc_s = is_ref[...]                              # [J, D] 1.0 if j is src
    jv = j_ref[...]                                  # [bc, T] proposal VM
    pnv = pn_ref[...]                                # [bc, T] proposal node
    uv = un_ref[...]                                 # [bc, T] uniform draw
    temps = temps_ref[...]                           # [T]

    E, C_pr, NS, pi_pr, pue_pr, EL, C_lan, pi_lan, lan_share = \
        (pp[i] for i in range(9))
    eps_n, C_net, pi_net, pue_net, idle_share = (nn[i] for i in range(5))
    cap_pr = NS * C_pr
    share_pi = lan_share * pi_lan

    omega = om_ref[...]                              # [bc, P]
    theta = th_ref[...]                              # [bc, P]
    lam = lm_ref[...]                                # [bc, N]
    obj = ob_ref[...]                                # [bc]

    iota_J = jax.lax.broadcasted_iota(jnp.int32, (bc, J), 1)
    iota_P = jax.lax.broadcasted_iota(jnp.int32, (bc, P), 1)
    iota_DJ = jax.lax.broadcasted_iota(jnp.int32, (bc, D, J), 2)
    iota_DPP = jax.lax.broadcasted_iota(jnp.int32, (bc, 2 * D, P * P), 2)
    iota_DKN = jax.lax.broadcasted_iota(jnp.int32, (bc, 2 * D, K, N), 3)
    relu = lambda x: jnp.maximum(x, 0.0)
    snap = lambda x, e: jnp.where(jnp.abs(x) < e, 0.0, x)

    def entry_proc(om, th, Ep, Cp, pip, puep, ELp, spp):
        """per_proc at one gathered node; all operands [bc]."""
        phi = ((om > ACTIVE_EPS) | (th > ACTIVE_EPS)).astype(jnp.float32)
        return puep * (Ep * om + jnp.ceil(om / Cp) * pip + ELp * th / 1e3
                       + phi * spp)

    def step(t, carry):
        X, omega, theta, lam, obj, bX, bobj = carry
        j = jax.lax.dynamic_slice_in_dim(jv, t, 1, axis=1)[:, 0]     # [bc]
        p_new = jax.lax.dynamic_slice_in_dim(pnv, t, 1, axis=1)[:, 0]
        u = jax.lax.dynamic_slice_in_dim(uv, t, 1, axis=1)[:, 0]
        Tt = jax.lax.dynamic_slice_in_dim(temps, t, 1, axis=0)[0]

        ohj = iota_J == j[:, None]                                   # [bc, J]
        ohj_f = ohj.astype(jnp.float32)
        p_old = jnp.sum(jnp.where(ohj, X, 0), axis=1)                # [bc]
        F_j = jax.lax.dot_general(ohj_f, F, (((1,), (0,)), ((), ())))
        # incident-link rows of VM j, gathered by one-hot matmuls
        hk = jnp.dot(ohj_f, inc_h, preferred_element_type=jnp.float32)
        sk = jnp.dot(ohj_f, inc_s, preferred_element_type=jnp.float32)
        ok = jnp.dot(ohj_f, inc_o.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
        ok = ok.astype(jnp.int32)                                    # [bc, D]
        is_self = ok == j[:, None]
        oh_other = iota_DJ == ok[:, :, None]                         # [bc,D,J]
        q = jnp.sum(jnp.where(oh_other, X[:, None, :], 0), axis=2)   # [bc, D]
        q_rm = jnp.where(is_self, p_old[:, None], q)
        q_in = jnp.where(is_self, p_new[:, None], q)

        oh_po = (iota_P == p_old[:, None]).astype(jnp.float32)       # [bc, P]
        oh_pn = (iota_P == p_new[:, None]).astype(jnp.float32)
        # signed bitrates: -h on the removal leg, +h on the insertion leg
        hh = jnp.concatenate([-hk, hk], axis=1)                      # [bc,2D]
        q2 = jnp.concatenate([q_rm, q_in], axis=1)                   # [bc,2D]
        iota_DP = jax.lax.broadcasted_iota(jnp.int32, (bc, 2 * D, P), 2)
        oh_q2 = (iota_DP == q2[:, :, None]).astype(jnp.float32)
        H_tot = hk.sum(-1)
        same_r = ((q_rm == p_old[:, None]).astype(jnp.float32) * hk).sum(-1)
        same_i = ((q_in == p_new[:, None]).astype(jnp.float32) * hk).sum(-1)
        d_theta = ((H_tot - same_i)[:, None] * oh_pn
                   - (H_tot - same_r)[:, None] * oh_po
                   + jnp.einsum("cd,cdp->cp", hh, oh_q2))
        # routes: ordered endpoint pair -> row of the path-incidence table
        sk2 = jnp.concatenate([sk, sk], axis=1) > 0.5
        a2 = jnp.concatenate(
            [jnp.broadcast_to(p_old[:, None], (bc, D)),
             jnp.broadcast_to(p_new[:, None], (bc, D))], axis=1)
        idx2 = jnp.where(sk2, a2 * P + q2, q2 * P + a2)              # [bc,2D]
        oh_rt = (iota_DPP == idx2[:, :, None]).astype(jnp.float32)
        rt_ids = jax.lax.dot_general(
            oh_rt.reshape(bc * 2 * D, P * P), route,
            (((1,), (0,)), ((), ()))).reshape(bc, 2 * D, K)
        # expand <= K ids against the N-iota (sentinel N never matches)
        oh_n = (iota_DKN == rt_ids.astype(jnp.int32)[..., None]
                ).astype(jnp.float32)                        # [bc, 2D, K, N]
        d_lam = jnp.einsum("cd,cdkn->cn", hh, oh_n)

        omega2 = snap(omega + F_j[:, None] * (oh_pn - oh_po), SNAP_GFLOPS)
        theta2 = snap(theta + d_theta, SNAP_MBPS)
        lam2 = snap(lam + d_lam, SNAP_MBPS)

        # delta objective: processing terms at the two touched nodes only
        def at_node(oh, vec):
            return jnp.sum(oh * vec, axis=1)
        d_proc = jnp.float32(0.0)
        d_viol = jnp.float32(0.0)
        for oh in (oh_po, oh_pn):
            Ep, Cp = at_node(oh, E), at_node(oh, C_pr)
            pip, puep = at_node(oh, pi_pr), at_node(oh, pue_pr)
            ELp, spp = at_node(oh, EL), at_node(oh, share_pi)
            capp, Clp = at_node(oh, cap_pr), at_node(oh, C_lan)
            om_o, om_n = at_node(oh, omega), at_node(oh, omega2)
            th_o, th_n = at_node(oh, theta), at_node(oh, theta2)
            d_proc += (entry_proc(om_n, th_n, Ep, Cp, pip, puep, ELp, spp)
                       - entry_proc(om_o, th_o, Ep, Cp, pip, puep, ELp, spp))
            d_viol += (relu(om_n - capp) - relu(om_o - capp)
                       + relu(th_n / 1e3 - Clp) - relu(th_o / 1e3 - Clp))
        beta_d = ((lam2 > ACTIVE_EPS).astype(jnp.float32)
                  - (lam > ACTIVE_EPS).astype(jnp.float32))
        d_net = (pue_net * (eps_n * (lam2 - lam) / 1e3
                            + beta_d * idle_share * pi_net)).sum(-1)
        d_viol += (relu(lam2 / 1e3 - C_net) - relu(lam / 1e3 - C_net)).sum(-1)
        delta = d_proc + d_net + PENALTY * d_viol

        acc = (delta < 0) | (u < jnp.exp(-jnp.maximum(delta, 0.0)
                                         / jnp.maximum(Tt, 1e-9)))
        a1 = acc[:, None]
        X = jnp.where(a1 & ohj, p_new[:, None], X)
        omega = jnp.where(a1, omega2, omega)
        theta = jnp.where(a1, theta2, theta)
        lam = jnp.where(a1, lam2, lam)
        obj = jnp.where(acc, obj + delta, obj)
        better = obj < bobj
        bX = jnp.where(better[:, None], X, bX)
        bobj = jnp.where(better, obj, bobj)
        return X, omega, theta, lam, obj, bX, bobj

    init = (X0, omega, theta, lam, obj, X0, obj)
    X, omega, theta, lam, obj, bX, bobj = jax.lax.fori_loop(0, T, step, init)
    bx_ref[...] = bX
    stat_ref[:, 0] = bobj
    stat_ref[:, 1] = obj


def fused_anneal_tpu(X: jax.Array, j_prop: jax.Array, p_prop: jax.Array,
                     u_prop: jax.Array, temps: jax.Array,
                     inc_other: jax.Array, inc_h: jax.Array,
                     inc_src: jax.Array,
                     omega0: jax.Array, theta0: jax.Array, lam0: jax.Array,
                     obj0: jax.Array,
                     F: jax.Array, route_flat: jax.Array,
                     proc_params: jax.Array, net_params: jax.Array, *,
                     bc: int = 8, interpret: bool = False):
    """Run full Metropolis chains in one kernel launch.

    X [C, J] int32 starting placements (pins applied); j_prop/p_prop/u_prop
    [C, T] per-step proposals; temps [T]; inc_* [J, D] per-VM incident-link
    tables (core.power.build_aux); omega0/theta0 [C, P], lam0 [C, N],
    obj0 [C] the starting loads/objective (kernels.ops computes them with
    one batched evaluation); route_flat [P*P, K] float32 CSR node ids.
    Returns (best_X [C, J] int32, stats [C, 2] = (best objective, final
    objective)).
    """
    C, J = X.shape
    T = temps.shape[0]
    D = inc_h.shape[1]
    P = proc_params.shape[1]
    N = net_params.shape[1]
    K = route_flat.shape[1]
    bc = min(bc, max(C, 1))
    pad = (-C) % bc
    if pad:
        X = jnp.pad(X, ((0, pad), (0, 0)))
        j_prop = jnp.pad(j_prop, ((0, pad), (0, 0)))
        p_prop = jnp.pad(p_prop, ((0, pad), (0, 0)))
        u_prop = jnp.pad(u_prop, ((0, pad), (0, 0)), constant_values=1.0)
        omega0 = jnp.pad(omega0, ((0, pad), (0, 0)))
        theta0 = jnp.pad(theta0, ((0, pad), (0, 0)))
        lam0 = jnp.pad(lam0, ((0, pad), (0, 0)))
        obj0 = jnp.pad(obj0, ((0, pad),))
    Cp = C + pad

    grid = (Cp // bc,)
    row = lambda i: (i, 0)
    const = lambda i: (0, 0)
    bX, stats = pl.pallas_call(
        functools.partial(_fused_kernel, P=P, N=N, K=K, J=J, D=D, T=T,
                          bc=bc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bc, J), row),
            pl.BlockSpec((bc, T), row),
            pl.BlockSpec((bc, T), row),
            pl.BlockSpec((bc, T), row),
            pl.BlockSpec((T,), lambda i: (0,)),
            pl.BlockSpec((J,), lambda i: (0,)),
            pl.BlockSpec((J, D), const),
            pl.BlockSpec((J, D), const),
            pl.BlockSpec((J, D), const),
            pl.BlockSpec((P * P, K), const),
            pl.BlockSpec((9, P), const),
            pl.BlockSpec((5, N), const),
            pl.BlockSpec((bc, P), row),
            pl.BlockSpec((bc, P), row),
            pl.BlockSpec((bc, N), row),
            pl.BlockSpec((bc,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((bc, J), row),
            pl.BlockSpec((bc, 2), row),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Cp, J), jnp.int32),
            jax.ShapeDtypeStruct((Cp, 2), jnp.float32),
        ],
        interpret=interpret,
    )(X, j_prop, p_prop, u_prop, temps, F,
      inc_other, inc_h, inc_src, route_flat, proc_params, net_params,
      omega0, theta0, lam0, obj0)
    return bX[:C], stats[:C]


def pack_aux(aux) -> Tuple[jax.Array, ...]:
    """Flatten a core.power.PlacementAux into fused-kernel operands."""
    return (aux.inc_other.astype(jnp.int32),
            aux.inc_h.astype(jnp.float32),
            aux.inc_src.astype(jnp.float32))
