"""Pallas TPU kernel for the CFN placement power objective (paper Eq. 1+2).

This is the solver hot loop: simulated annealing / genetic / coordinate
descent evaluate thousands of candidate placements per step, and each
evaluation is a chain of small contractions:

  onehot[b, j, p]  = (X[b, j] == p)                 (iota compare, VPU)
  omega[b, p]      = sum_j F[j] * onehot[b, j, p]   (dot, MXU)
  tm[b, p, q]      = sum_l H[l] u[b,l,p] w[b,l,q]   (batched dot, MXU)
  lam[b, n]        = tm[b, :] . path[:, n]          (dot, MXU)
  power terms      = elementwise over [b, P] / [b, N] + penalties

Blocked over candidates: each grid step evaluates a [bc]-candidate block
entirely in VMEM.  Problem tensors (path incidence, device parameters) are
broadcast to every block via constant index maps.  The oracle is
kernels/ref.py::placement_objective_ref == core.power.objective_batch.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ACTIVE_EPS = 1.0e-6
PENALTY = 1.0e4


def _kernel(x_ref, u_ref, w_ref,
            f_ref, h_ref, path_ref, pp_ref, nn_ref,
            out_ref, *, P: int, N: int, bc: int):
    X = x_ref[...]                                   # [bc, J]  int32
    U = u_ref[...]                                   # [bc, L]  int32
    W = w_ref[...]                                   # [bc, L]  int32
    F = f_ref[...]                                   # [J]
    H = h_ref[...]                                   # [L]
    path = path_ref[...]                             # [P*P, N]
    pp = pp_ref[...]                                 # [9, P] processing params
    nn = nn_ref[...]                                 # [5, N] network params

    J = X.shape[1]
    L = U.shape[1]
    iota_p = jax.lax.broadcasted_iota(jnp.int32, (1, 1, P), 2)
    oh_x = (X[:, :, None] == iota_p).astype(jnp.float32)        # [bc, J, P]
    oh_u = (U[:, :, None] == iota_p).astype(jnp.float32)        # [bc, L, P]
    oh_w = (W[:, :, None] == iota_p).astype(jnp.float32)        # [bc, L, P]

    # omega[b,p] = F . onehot
    omega = jax.lax.dot_general(
        oh_x, F, (((1,), (0,)), ((), ())))                       # [bc, P]
    # tm[b,p,q] = sum_l H_l u w ; uh = u * H
    uh = oh_u * H[None, :, None]
    tm = jax.lax.dot_general(
        uh, oh_w, (((1,), (1,)), ((0,), (0,))))                  # [bc, P, P]
    lam = jax.lax.dot_general(
        tm.reshape(bc, P * P), path, (((1,), (0,)), ((), ())))   # [bc, N]
    # theta: traffic touching node p (sum of in+out minus double-counted
    # intra-node traffic)
    t_out = jax.lax.dot_general(uh, jnp.ones((bc, L), jnp.float32),
                                (((1,), (1,)), ((0,), (0,))))    # [bc, P]
    wh = oh_w * H[None, :, None]
    t_in = jax.lax.dot_general(wh, jnp.ones((bc, L), jnp.float32),
                               (((1,), (1,)), ((0,), (0,))))
    intra = jnp.sum(uh * oh_w, axis=1)                           # [bc, P]
    theta = t_out + t_in - intra

    E, C_pr, NS, pi_pr, pue_pr, EL, C_lan, pi_lan, lan_share = \
        (pp[i] for i in range(9))
    eps, C_net, pi_net, pue_net, idle_share = (nn[i] for i in range(5))

    n_srv = jnp.ceil(omega / C_pr)
    beta = (lam > ACTIVE_EPS).astype(jnp.float32)
    phi = ((omega > ACTIVE_EPS) | (theta > ACTIVE_EPS)).astype(jnp.float32)
    per_net = pue_net * (eps * lam / 1e3 + beta * idle_share * pi_net)
    per_proc = pue_pr * (E * omega + n_srv * pi_pr
                         + EL * theta / 1e3 + phi * lan_share * pi_lan)
    relu = lambda x: jnp.maximum(x, 0.0)
    violation = (jnp.sum(relu(omega - NS * C_pr), axis=-1)
                 + jnp.sum(relu(lam / 1e3 - C_net), axis=-1)
                 + jnp.sum(relu(theta / 1e3 - C_lan), axis=-1))
    net = jnp.sum(per_net, axis=-1)
    proc = jnp.sum(per_proc, axis=-1)
    out_ref[:, 0] = net + proc + PENALTY * violation
    out_ref[:, 1] = net
    out_ref[:, 2] = proc
    out_ref[:, 3] = violation


def placement_power_tpu(X: jax.Array, link_src: jax.Array,
                        link_dst: jax.Array, F: jax.Array, H: jax.Array,
                        path_flat: jax.Array, proc_params: jax.Array,
                        net_params: jax.Array, *, bc: int = 256,
                        interpret: bool = False) -> jax.Array:
    """Evaluate B candidate placements.

    X [B, J=R*V] int32 (pins already applied); link_src/dst [L] indices into
    the flattened VM space; F [J] GFLOPS; H [L] Mbps; path_flat [P*P, N];
    proc_params [9, P]; net_params [5, N].
    Returns [B, 4]: (objective, net W, proc W, violation).
    """
    B, J = X.shape
    L = link_src.shape[0]
    P = proc_params.shape[1]
    N = net_params.shape[1]
    bc = min(bc, max(B, 8))
    pad = (-B) % bc
    if pad:
        X = jnp.pad(X, ((0, pad), (0, 0)))
    Bp = B + pad
    U = jnp.take(X, link_src, axis=1)                 # [Bp, L]
    W = jnp.take(X, link_dst, axis=1)

    grid = (Bp // bc,)
    const = lambda i: (0, 0)
    out = pl.pallas_call(
        functools.partial(_kernel, P=P, N=N, bc=bc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bc, J), lambda i: (i, 0)),
            pl.BlockSpec((bc, L), lambda i: (i, 0)),
            pl.BlockSpec((bc, L), lambda i: (i, 0)),
            pl.BlockSpec((J,), lambda i: (0,)),
            pl.BlockSpec((L,), lambda i: (0,)),
            pl.BlockSpec((P * P, N), const),
            pl.BlockSpec((9, P), const),
            pl.BlockSpec((5, N), const),
        ],
        out_specs=pl.BlockSpec((bc, 4), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Bp, 4), jnp.float32),
        interpret=interpret,
    )(X, U, W, F, H, path_flat, proc_params, net_params)
    return out[:B]


def pack_problem(problem) -> Tuple[jax.Array, ...]:
    """Flatten a core.power.PlacementProblem into kernel operands."""
    p = problem
    path_flat = p.path_nodes.reshape(p.P * p.P, p.N)
    proc_params = jnp.stack([p.E, p.C_pr, p.NS, p.pi_pr, p.pue_pr,
                             p.EL, p.C_lan, p.pi_lan, p.lan_share])
    net_params = jnp.stack([p.eps, p.C_net, p.pi_net, p.pue_net,
                            p.idle_share])
    F = p.F.reshape(-1)
    return (p.link_src, p.link_dst, F, p.link_h, path_flat,
            proc_params, net_params)
