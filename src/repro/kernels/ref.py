"""Pure-jnp / numpy oracles for the Pallas kernels (tests assert vs these).

  * flash_attention_ref: chunked online-softmax attention -- the same code
    path the model stack uses (models.layers.flash_attention), re-exposed in
    the [B, H, S, D] kernel layout.
  * placement_objective_ref: the paper's Eq.(1)+(2) objective from
    core.power, evaluated with vmap -- the "CPLEX objective" ground truth.
  * placement_objective_f64 / placement_delta_ref: float64 numpy
    re-implementation of Eq.(1)+(2) on the SPARSE (padded-CSR) route form:
    lambda accumulates each traffic-matrix entry along its route's <= K node
    ids.  The delta oracle computes objective(X') - objective(X) at float64,
    where the subtraction is exact to ~1e-10 -- the yardstick for the
    incremental delta engine (core.power.delta_move) and the fused annealing
    kernel, whose float32 deltas must agree to fp32 tolerance.  The dense
    [P, P, N] incidence einsum survives only in the tests
    (tests/test_sparse_routes.py builds it from topology.dense_path_nodes and
    cross-checks this oracle against it).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.power import (ACTIVE_EPS, PENALTY, PlacementProblem, apply_pins,
                          evaluate)
from ..models.layers import flash_attention


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: Optional[int] = None,
                        logit_cap: Optional[float] = None,
                        q_offset: int = 0) -> jax.Array:
    """q [B, H, Sq, D]; k/v [B, KH, Skv, D] -> [B, H, Sq, D]."""
    B, H, Sq, D = q.shape
    Skv = k.shape[2]
    qpos = q_offset + jnp.arange(Sq)
    kpos = jnp.arange(Skv)
    out = flash_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), q_positions=qpos, kv_positions=kpos,
        causal=causal, window=window, logit_cap=logit_cap, kv_chunk=128)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def placement_objective_ref(problem: PlacementProblem,
                            Xb: jax.Array) -> jax.Array:
    """[B, R, V] placements -> [B, 4] (objective, net, proc, violation)."""
    def one(X):
        bd = evaluate(problem, X)
        return jnp.stack([bd.objective, bd.net, bd.proc, bd.violation])
    return jax.vmap(one)(Xb)


def lam_f64_sparse(problem: PlacementProblem, tm: np.ndarray) -> np.ndarray:
    """lambda [N] from a traffic matrix [P, P] at float64, accumulated over
    the CSR route table (the sparse counterpart of the dense
    ``einsum("pq,pqn->n", tm, path_nodes)``)."""
    p = problem
    rt = np.asarray(p.route_idx)                              # [P, P, K]
    K = rt.shape[2]
    buf = np.zeros(p.N + 1, np.float64)
    np.add.at(buf, rt.reshape(-1),
              np.repeat(np.asarray(tm, np.float64).reshape(-1), K))
    return buf[:p.N]


def eq_terms_f64(pp: dict, nn: dict, omega: np.ndarray, theta: np.ndarray,
                 lam: np.ndarray):
    """Per-node Eq.(1)/(2) terms at float64 -- THE single f64 copy of the
    paper's power formulas, shared by ``placement_objective_f64`` and the
    federation's decomposed accounting (``core.federation``).

    ``pp``/``nn`` map the ``topology.proc_param_arrays`` /
    ``net_param_arrays`` keys to arrays; returns
    ``(per_net [N], per_proc [P], violation [])``.
    """
    g = lambda a: np.asarray(a, np.float64)
    n_srv = np.ceil(omega / g(pp["C_pr"]))
    beta = (lam > ACTIVE_EPS).astype(np.float64)
    phi = ((omega > ACTIVE_EPS) | (theta > ACTIVE_EPS)).astype(np.float64)
    per_net = g(nn["pue_net"]) * (g(nn["eps"]) * lam / 1e3
                                  + beta * g(nn["idle_share"])
                                  * g(nn["pi_net"]))
    per_proc = g(pp["pue_pr"]) * (g(pp["E"]) * omega + n_srv * g(pp["pi_pr"])
                                  + g(pp["EL"]) * theta / 1e3
                                  + phi * g(pp["lan_share"])
                                  * g(pp["pi_lan"]))
    relu = lambda x: np.maximum(x, 0.0)
    violation = (relu(omega - g(pp["NS"]) * g(pp["C_pr"])).sum()
                 + relu(lam / 1e3 - g(nn["C_net"])).sum()
                 + relu(theta / 1e3 - g(pp["C_lan"])).sum())
    return per_net, per_proc, float(violation)


_PP_KEYS = ("E", "C_pr", "NS", "pi_pr", "pue_pr", "EL", "C_lan", "pi_lan",
            "lan_share")
_NN_KEYS = ("eps", "C_net", "pi_net", "pue_net", "idle_share")


def placement_objective_f64(problem: PlacementProblem, X,
                            path_dense: Optional[np.ndarray] = None
                            ) -> float:
    """Eq.(1)+(2) objective of one placement at float64 (numpy).

    By default lambda comes from the sparse CSR route table; pass
    ``path_dense`` (a [P, P, N] incidence tensor from
    ``topology.dense_path_nodes()``) to evaluate the SAME term assembly on
    the dense form -- the sparse-vs-dense objective cross-check
    benchmarks/kernel_bench.py::sparse_routes reports."""
    p = problem
    P = p.P
    X = np.where(np.asarray(p.fixed_mask), np.asarray(p.fixed_node),
                 np.asarray(X))
    onehot = np.eye(P, dtype=np.float64)[X]                   # [R, V, P]
    F = np.asarray(p.F, np.float64)
    h = np.asarray(p.link_h, np.float64)
    flat = onehot.reshape(-1, P)
    u = flat[np.asarray(p.link_src)]                          # [L, P]
    w = flat[np.asarray(p.link_dst)]
    omega = np.einsum("rvp,rv->p", onehot, F)
    tm = np.einsum("l,lp,lq->pq", h, u, w)
    intra = np.einsum("l,lp,lp->p", h, u, w)
    if path_dense is None:
        lam = lam_f64_sparse(p, tm)
    else:
        lam = np.einsum("pq,pqn->n", tm, np.asarray(path_dense, np.float64))
    theta = (u.T @ h) + (w.T @ h) - intra

    per_net, per_proc, violation = eq_terms_f64(
        {k: getattr(p, k) for k in _PP_KEYS},
        {k: getattr(p, k) for k in _NN_KEYS}, omega, theta, lam)
    return float(per_net.sum() + per_proc.sum() + PENALTY * violation)


def placement_delta_ref(problem: PlacementProblem, X, r: int, v: int,
                        p_new: int) -> float:
    """Float64 oracle for a single-VM move: objective(X') - objective(X)."""
    X = np.asarray(X)
    X2 = X.copy()
    X2[r, v] = p_new
    return (placement_objective_f64(problem, X2)
            - placement_objective_f64(problem, X))
