"""Pure-jnp oracles for the Pallas kernels (tests assert_allclose vs these).

  * flash_attention_ref: chunked online-softmax attention -- the same code
    path the model stack uses (models.layers.flash_attention), re-exposed in
    the [B, H, S, D] kernel layout.
  * placement_objective_ref: the paper's Eq.(1)+(2) objective from
    core.power, evaluated with vmap -- the "CPLEX objective" ground truth.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..core.power import PlacementProblem, apply_pins, evaluate
from ..models.layers import flash_attention


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: Optional[int] = None,
                        logit_cap: Optional[float] = None,
                        q_offset: int = 0) -> jax.Array:
    """q [B, H, Sq, D]; k/v [B, KH, Skv, D] -> [B, H, Sq, D]."""
    B, H, Sq, D = q.shape
    Skv = k.shape[2]
    qpos = q_offset + jnp.arange(Sq)
    kpos = jnp.arange(Skv)
    out = flash_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), q_positions=qpos, kv_positions=kpos,
        causal=causal, window=window, logit_cap=logit_cap, kv_chunk=128)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def placement_objective_ref(problem: PlacementProblem,
                            Xb: jax.Array) -> jax.Array:
    """[B, R, V] placements -> [B, 4] (objective, net, proc, violation)."""
    def one(X):
        bd = evaluate(problem, X)
        return jnp.stack([bd.objective, bd.net, bd.proc, bd.violation])
    return jax.vmap(one)(Xb)
