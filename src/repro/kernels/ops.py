"""Jit'd public wrappers for the Pallas kernels.

On this CPU container the kernels execute in interpret mode (the kernel
body runs as Python/jnp on CPU); on a real TPU set ``interpret=False`` (the
default flips automatically based on the backend).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..core.power import (PlacementProblem, apply_pins, batched_hard_loads)
from . import flash_attention as fa
from . import placement_power as pp


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "logit_cap",
                                             "q_offset", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    logit_cap: Optional[float] = None, q_offset: int = 0,
                    interpret: Optional[bool] = None) -> jax.Array:
    """Pallas flash attention; q [B, H, Sq, D], k/v [B, KH, Skv, D]."""
    interpret = _default_interpret() if interpret is None else interpret
    return fa.flash_attention_tpu(q, k, v, causal=causal, window=window,
                                  logit_cap=logit_cap, q_offset=q_offset,
                                  interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def placement_objective(problem: PlacementProblem, Xb: jax.Array, *,
                        interpret: Optional[bool] = None) -> jax.Array:
    """Batched placement objective: Xb [B, R, V] -> [B, 4].

    Columns: (objective = power + penalty*violation, net W, proc W,
    violation).  Matches kernels.ref.placement_objective_ref bit-for-bit up
    to float accumulation order.
    """
    interpret = _default_interpret() if interpret is None else interpret
    B = Xb.shape[0]
    Xp = jax.vmap(lambda X: apply_pins(problem, X))(Xb)
    Xflat = Xp.reshape(B, -1).astype(jnp.int32)
    operands = pp.pack_problem(problem)
    return pp.placement_power_tpu(Xflat, *operands, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_anneal(problem: PlacementProblem, aux, Xc: jax.Array,
                 j_prop: jax.Array, p_prop: jax.Array, u_prop: jax.Array,
                 temps: jax.Array, eligible: Optional[jax.Array] = None, *,
                 interpret: Optional[bool] = None):
    """Fused Metropolis annealing: whole chains in ONE kernel launch.

    Xc [C, R, V] int32 starting placements (pins applied by the caller);
    j_prop/p_prop/u_prop [C, T] proposals (flat free-VM index, destination
    node, uniform draw); temps [T]; aux = core.power.build_aux(problem).
    ``eligible`` [R, P] bool (optional) masks the proposal stream onto each
    service row's eligible node set (pp.mask_proposals) before it reaches
    the kernel -- the SLA constraint surface of repro.api.PlacementSpec
    enforced identically to the pure-JAX backends.
    Returns (best_X [C, R, V], stats [C, 2] = (best obj, final obj)).
    Chain state (placement + live load tensors) stays resident in VMEM
    across all T steps -- no per-step objective launch.  Initial loads are
    one batched evaluation out here; the kernel only ever touches the
    compact [P*P, K] route table.
    """
    interpret = _default_interpret() if interpret is None else interpret
    C, R, V = Xc.shape
    if eligible is not None:
        p_prop = pp.mask_proposals(j_prop, p_prop, eligible, V)
    Xflat = Xc.reshape(C, -1).astype(jnp.int32)
    omega0, theta0, lam0, obj0 = batched_hard_loads(problem, Xc)
    (_, _, F, _, route_flat, proc_params, net_params) = \
        pp.pack_problem(problem)
    bX, stats = pp.fused_anneal_tpu(
        Xflat, j_prop.astype(jnp.int32), p_prop.astype(jnp.int32), u_prop,
        temps, *pp.pack_aux(aux), omega0, theta0, lam0, obj0,
        F, route_flat, proc_params, net_params, interpret=interpret)
    return bX.reshape(C, R, V), stats
