"""Jit'd public wrappers for the Pallas kernels.

On this CPU container the kernels execute in interpret mode (the kernel
body runs as Python/jnp on CPU); on a real TPU set ``interpret=False`` (the
default flips automatically based on the backend).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..core.power import PlacementProblem, apply_pins
from . import flash_attention as fa
from . import placement_power as pp


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "logit_cap",
                                             "q_offset", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    logit_cap: Optional[float] = None, q_offset: int = 0,
                    interpret: Optional[bool] = None) -> jax.Array:
    """Pallas flash attention; q [B, H, Sq, D], k/v [B, KH, Skv, D]."""
    interpret = _default_interpret() if interpret is None else interpret
    return fa.flash_attention_tpu(q, k, v, causal=causal, window=window,
                                  logit_cap=logit_cap, q_offset=q_offset,
                                  interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def placement_objective(problem: PlacementProblem, Xb: jax.Array, *,
                        interpret: Optional[bool] = None) -> jax.Array:
    """Batched placement objective: Xb [B, R, V] -> [B, 4].

    Columns: (objective = power + penalty*violation, net W, proc W,
    violation).  Matches kernels.ref.placement_objective_ref bit-for-bit up
    to float accumulation order.
    """
    interpret = _default_interpret() if interpret is None else interpret
    B = Xb.shape[0]
    Xp = jax.vmap(lambda X: apply_pins(problem, X))(Xb)
    Xflat = Xp.reshape(B, -1).astype(jnp.int32)
    operands = pp.pack_problem(problem)
    return pp.placement_power_tpu(Xflat, *operands, interpret=interpret)
