"""The energy ledger: per-tick watts decomposed into the paper's Eq.(1)
networking vs Eq.(2) processing terms, integrated to joules over a
replay horizon.

Sampling model: the serving workload's power is PIECEWISE CONSTANT --
it changes only when a placement commits (churn, defrag, fault
re-embed), never between commits -- so sampling at commit time with
left-hold (step) integration is exact, and costs nothing: the committed
``SolveResult`` already carries the full ``PowerBreakdown``.

Dimensions:
  * total / net (Eq.1) / proc (Eq.2) watts -- every tick;
  * per-tier proc watts (iot/af/mf/cdc, from ``breakdown.per_proc``
    grouped by ``topo.proc_layer``) -- every tick once ``set_tiers``
    ran;
  * per-tenant watts (exact ``power.attribute_power`` split) and
    per-region watts (``federated_breakdown``) -- on the caller's
    cadence; held between samples.

Time units follow the caller's clock (churn timelines tick in hours,
so ``integrate()`` reports joules = W * 3600 * h when ``hours=True``).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Sequence


class EnergyLedger:
    def __init__(self, emit: Optional[Callable[..., Any]] = None) -> None:
        self.samples: List[dict] = []
        self.tiers: Optional[Dict[str, List[int]]] = None
        self._emit = emit

    def set_tiers(self, tiers: Dict[str, Sequence[int]]) -> None:
        """Processing-node tier map, e.g. ``{layer: node indices}`` built
        from ``topo.proc_layer`` (see ``tiers_of``)."""
        self.tiers = {k: list(v) for k, v in tiers.items()}

    def tick(self, t: float, total_w: float, net_w: float, proc_w: float,
             per_proc: Any = None,
             per_tenant: Optional[Dict[int, float]] = None,
             per_region: Optional[Dict[str, float]] = None,
             event: Optional[str] = None) -> dict:
        s: Dict[str, Any] = {"t": float(t), "total_w": float(total_w),
                             "net_w": float(net_w), "proc_w": float(proc_w)}
        if event is not None:
            s["event"] = event
        if per_proc is not None and self.tiers:
            s["tier_w"] = {layer: float(sum(float(per_proc[i]) for i in idx))
                           for layer, idx in self.tiers.items()}
        if per_tenant is not None:
            s["tenant_w"] = {str(k): float(v) for k, v in per_tenant.items()}
        if per_region is not None:
            s["region_w"] = {str(k): float(v) for k, v in per_region.items()}
        self.samples.append(s)
        if self._emit is not None:
            self._emit("energy", **s)
        return s

    def integrate(self, t_end: Optional[float] = None,
                  hours: bool = True) -> Dict[str, Any]:
        """Left-hold step integration of every recorded dimension.  The
        last sample extends to ``t_end`` (default: the last sample's
        time, i.e. it contributes nothing).  ``hours=True`` converts
        W*h to joules (x3600)."""
        if not self.samples:
            return {"joules_total": 0.0, "joules_net": 0.0,
                    "joules_proc": 0.0, "t_start": None, "t_end": None,
                    "samples": 0}
        ss = self.samples
        t1 = float(ss[-1]["t"]) if t_end is None else float(t_end)
        scale = 3600.0 if hours else 1.0
        tot = net = proc = 0.0
        by_tier: Dict[str, float] = {}
        by_tenant: Dict[str, float] = {}
        by_region: Dict[str, float] = {}
        held_tenant: Optional[Dict[str, float]] = None
        held_region: Optional[Dict[str, float]] = None
        for i, s in enumerate(ss):
            dt = (t1 if i + 1 == len(ss) else float(ss[i + 1]["t"])) \
                - float(s["t"])
            if dt < 0.0:
                dt = 0.0
            tot += s["total_w"] * dt
            net += s["net_w"] * dt
            proc += s["proc_w"] * dt
            for k, w in s.get("tier_w", {}).items():
                by_tier[k] = by_tier.get(k, 0.0) + w * dt
            held_tenant = s.get("tenant_w", held_tenant)
            if held_tenant:
                for k, w in held_tenant.items():
                    by_tenant[k] = by_tenant.get(k, 0.0) + w * dt
            held_region = s.get("region_w", held_region)
            if held_region:
                for k, w in held_region.items():
                    by_region[k] = by_region.get(k, 0.0) + w * dt
        out: Dict[str, Any] = {
            "joules_total": tot * scale, "joules_net": net * scale,
            "joules_proc": proc * scale,
            "t_start": float(ss[0]["t"]), "t_end": t1, "samples": len(ss)}
        if by_tier:
            out["joules_by_tier"] = {k: v * scale
                                     for k, v in by_tier.items()}
        if by_tenant:
            out["joules_by_tenant"] = {k: v * scale
                                       for k, v in by_tenant.items()}
        if by_region:
            out["joules_by_region"] = {k: v * scale
                                       for k, v in by_region.items()}
        return out


def tiers_of(topo: Any) -> Dict[str, List[int]]:
    """``{layer: processing-node indices}`` from ``topo.proc_layer``."""
    out: Dict[str, List[int]] = {}
    for i, layer in enumerate(topo.proc_layer):
        out.setdefault(layer, []).append(i)
    return out
