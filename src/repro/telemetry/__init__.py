"""repro.telemetry -- the unified observability plane.

A low-overhead, host-side telemetry subsystem for the serving stack:

* ``Telemetry`` -- the registry: counters, gauges, log2-bucketed
  histograms, a span/trace API (``with tel.span("resolve_wave", ...)``)
  and a JSON-lines event sink;
* ``EnergyLedger`` (``tel.ledger``) -- per-commit watts decomposed into
  Eq.(1) networking vs Eq.(2) processing, per tier / tenant / region,
  integrated to joules over a replay horizon;
* compile attribution -- ``tel.attach_traces()`` hooks
  ``solvers.count_traces`` so every fresh jit trace is recorded with its
  entry name and abstract shape fingerprint, and ``tel.report()``
  cross-checks the log against live ``TRACE_COUNTS`` (and the CFN108
  static bounds when given);
* exporters -- streaming JSONL, Prometheus text exposition
  (``tel.prometheus()``), and the ``python -m repro.telemetry report``
  CLI.

Threading: pass ``telemetry=`` to ``OnlineEmbedder`` / ``CFNSession`` /
``FederatedSession`` / ``EnergyAwareScheduler`` (default ``None`` keeps
every instrumented path a strict no-op -- bit-identical placements,
zero extra compiles).  See docs/OBSERVABILITY.md.
"""
from .ledger import EnergyLedger, tiers_of
from .registry import Histogram, Span, Telemetry
from .report import (EVENT_SCHEMA, load_events, render, summarize_events,
                     validate_events)

__all__ = [
    "Telemetry", "Span", "Histogram", "EnergyLedger", "tiers_of",
    "EVENT_SCHEMA", "load_events", "validate_events", "summarize_events",
    "render",
]
