"""Offline summarizer for telemetry JSONL run files.

``python -m repro.telemetry report run.jsonl`` loads the event stream a
``Telemetry(jsonl_path=...)`` run wrote and prints the run summary:
event counts, span latency stats, the integrated energy ledger (joules
by tier / tenant / region), availability when the run carried monitor
events, and the compile attribution.  The same loader backs the CI
``obs-smoke`` schema validation.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional

from .ledger import EnergyLedger

# per-type required fields (the JSONL event schema the CI job validates)
EVENT_SCHEMA: Dict[str, tuple] = {
    "meta": ("ts", "version"),
    "span": ("ts", "name", "id", "dur_ms", "ok"),
    "solve": ("ts", "event", "method", "objective", "power_w", "n_live",
              "t"),
    "energy": ("ts", "t", "total_w", "net_w", "proc_w"),
    "event": ("ts", "kind"),
    "trace": ("ts", "entry", "fingerprint"),
    "summary": ("ts", "report"),
}


def load_events(path: str) -> List[dict]:
    out = []
    with open(path) as fh:
        for ln, line in enumerate(fh, 1):
            line = line.strip()
            if not line:
                continue
            try:
                ev = json.loads(line)
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{ln}: bad JSON line: {e}") from e
            out.append(ev)
    return out


def validate_events(events: List[dict]) -> List[str]:
    """Schema check: every event needs a known ``type`` and that type's
    required fields.  Returns human-readable problems (empty = valid)."""
    problems = []
    for i, ev in enumerate(events):
        t = ev.get("type")
        if t not in EVENT_SCHEMA:
            problems.append(f"event {i}: unknown type {t!r}")
            continue
        missing = [f for f in EVENT_SCHEMA[t] if f not in ev]
        if missing:
            problems.append(f"event {i} ({t}): missing fields {missing}")
    return problems


def summarize_events(events: List[dict]) -> Dict[str, Any]:
    """Re-derive the run summary from the event stream alone (no live
    registry needed): span stats, re-integrated ledger, compile log."""
    by_type: Dict[str, int] = {}
    spans: Dict[str, Dict[str, float]] = {}
    ledger = EnergyLedger()
    traces: Dict[str, int] = {}
    monitor_counts: Dict[str, int] = {}
    final_report: Optional[dict] = None
    for ev in events:
        t = ev.get("type", "?")
        by_type[t] = by_type.get(t, 0) + 1
        if t == "span":
            s = spans.setdefault(ev["name"],
                                 {"count": 0, "total_ms": 0.0,
                                  "max_ms": 0.0, "errors": 0})
            s["count"] += 1
            s["total_ms"] += ev["dur_ms"]
            s["max_ms"] = max(s["max_ms"], ev["dur_ms"])
            if not ev.get("ok", True):
                s["errors"] += 1
        elif t == "energy":
            ledger.tick(ev["t"], ev["total_w"], ev["net_w"], ev["proc_w"],
                        event=ev.get("event"))
            last = ledger.samples[-1]
            for k in ("tier_w", "tenant_w", "region_w"):
                if k in ev:
                    last[k] = ev[k]
        elif t == "trace":
            traces[ev["entry"]] = traces.get(ev["entry"], 0) + 1
        elif t == "event":
            k = ev.get("kind", "?")
            monitor_counts[k] = monitor_counts.get(k, 0) + ev.get("n", 1)
        elif t == "summary":
            final_report = ev.get("report")
    return {"events_by_type": by_type, "spans": spans,
            "energy": ledger.integrate(), "compiles": traces,
            "monitor": monitor_counts, "final_report": final_report}


def render(summary: Dict[str, Any]) -> str:
    lines = ["== telemetry run summary =="]
    lines.append("events: " + ", ".join(
        f"{k}={v}" for k, v in sorted(summary["events_by_type"].items())))
    if summary["spans"]:
        lines.append("spans:")
        for name, s in sorted(summary["spans"].items()):
            mean = s["total_ms"] / max(s["count"], 1)
            lines.append(
                f"  {name:<24} n={s['count']:<6} mean={mean:8.2f}ms "
                f"max={s['max_ms']:8.2f}ms errors={s['errors']}")
    e = summary["energy"]
    if e.get("samples"):
        lines.append(
            f"energy: {e['joules_total']:.1f} J total "
            f"(net Eq.1 {e['joules_net']:.1f} J, "
            f"proc Eq.2 {e['joules_proc']:.1f} J) over "
            f"t=[{e['t_start']:.2f}, {e['t_end']:.2f}]")
        for dim in ("joules_by_tier", "joules_by_region"):
            if dim in e:
                parts = ", ".join(f"{k}={v:.1f}J"
                                  for k, v in sorted(e[dim].items()))
                lines.append(f"  {dim[10:]}: {parts}")
        if "joules_by_tenant" in e:
            top = sorted(e["joules_by_tenant"].items(),
                         key=lambda kv: -kv[1])[:5]
            lines.append("  top tenants: " + ", ".join(
                f"sid {k}={v:.1f}J" for k, v in top))
    if summary["compiles"]:
        lines.append("compiles: " + ", ".join(
            f"{k}={v}" for k, v in sorted(summary["compiles"].items())))
    if summary["monitor"]:
        lines.append("monitor events: " + ", ".join(
            f"{k}={v}" for k, v in sorted(summary["monitor"].items())))
    rep = summary.get("final_report")
    if rep and rep.get("compiles", {}).get("agree") is not None:
        lines.append("compile attribution agrees with TRACE_COUNTS: "
                     f"{rep['compiles']['agree']}")
    return "\n".join(lines)
