"""CLI: ``python -m repro.telemetry report run.jsonl [--json]``.

Subcommands:
  report   -- summarize a telemetry JSONL run file (spans, joules by
              tier/tenant/region, compile attribution).
  validate -- schema-check the event stream; exit 1 on problems
              (the CI obs-smoke gate).
"""
from __future__ import annotations

import argparse
import json
import sys

from .report import load_events, render, summarize_events, validate_events


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.telemetry")
    sub = ap.add_subparsers(dest="cmd", required=True)
    rp = sub.add_parser("report", help="summarize a run file")
    rp.add_argument("path")
    rp.add_argument("--json", action="store_true",
                    help="machine-readable summary")
    vp = sub.add_parser("validate", help="schema-check a run file")
    vp.add_argument("path")
    args = ap.parse_args(argv)

    events = load_events(args.path)
    if args.cmd == "validate":
        problems = validate_events(events)
        for p in problems:
            print(p, file=sys.stderr)
        print(f"{args.path}: {len(events)} events, "
              f"{len(problems)} schema problems")
        return 1 if problems else 0
    summary = summarize_events(events)
    if args.json:
        print(json.dumps(summary, indent=2, default=str))
    else:
        print(render(summary))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
