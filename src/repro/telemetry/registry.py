"""The telemetry registry: counters, gauges, log-bucketed histograms, and
a span/trace API emitting JSON-lines events.

Everything here is HOST-side and synchronous-by-construction: nothing in
this module is reachable from a jitted body (tracelint CFN101 has no jit
roots to anchor on), nothing allocates device arrays, and nothing forces
a device sync unless a span explicitly asks for one via ``sync=`` /
``Span.sync(...)`` -- the one sanctioned ``jax.block_until_ready`` call,
taken at the span BOUNDARY so the measured duration covers the device
work without planting a sync inside traced code.

Overhead discipline: the registry is designed so the *disabled* path is a
no-op (callers guard on ``telemetry is None``) and the *enabled* path is
a few dict operations plus one buffered file write per event -- the
``telemetry_overhead`` benchmark (BENCH_obs.json) holds it under 2% on
the city_p468 churn-wave workload.

Single-threaded by design (the serving loop is host-single-threaded);
the span stack is a plain list, not thread-local.
"""
from __future__ import annotations

import json
import math
import time
from typing import Any, Dict, IO, List, Optional

from .ledger import EnergyLedger

_EVENT_SCHEMA_VERSION = 1


def _key(name: str, labels: Dict[str, Any]) -> str:
    """Flat metric key: ``name`` or ``name{a=1,b=x}`` (labels sorted)."""
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


def _bucket_edge(value: float) -> float:
    """Upper edge of ``value``'s log2 bucket: the smallest power of two
    >= value (exact powers of two land on their own edge)."""
    if value <= 0.0:
        return 0.0
    m, e = math.frexp(value)          # value = m * 2**e, 0.5 <= m < 1
    return float(2.0 ** (e - 1 if m == 0.5 else e))


class Histogram:
    """Log2-bucketed histogram: O(1) observe, ~60 buckets over the full
    float range actually hit, plus exact sum/count/min/max."""

    __slots__ = ("buckets", "sum", "count", "min", "max")

    def __init__(self) -> None:
        self.buckets: Dict[float, int] = {}
        self.sum = 0.0
        self.count = 0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, value: float) -> None:
        v = float(value)
        edge = _bucket_edge(v)
        self.buckets[edge] = self.buckets.get(edge, 0) + 1
        self.sum += v
        self.count += 1
        self.min = min(self.min, v)
        self.max = max(self.max, v)

    def snapshot(self) -> Dict[str, Any]:
        return {"sum": self.sum, "count": self.count,
                "min": (None if self.count == 0 else self.min),
                "max": (None if self.count == 0 else self.max),
                "buckets": {str(e): n
                            for e, n in sorted(self.buckets.items())}}


class Span:
    """One timed section.  Context manager; exception-safe (the event is
    emitted with ``ok=False`` and the error type, and the exception
    propagates).  ``sync(value)`` registers a jax value (array / pytree)
    to ``block_until_ready`` at exit, so device work launched inside the
    span is charged to it."""

    __slots__ = ("tel", "name", "attrs", "id", "parent", "t0", "_sync")

    def __init__(self, tel: "Telemetry", name: str,
                 sync: Any = None, **attrs: Any) -> None:
        self.tel = tel
        self.name = name
        self.attrs = attrs
        self._sync = sync
        self.id = -1
        self.parent: Optional[int] = None
        self.t0 = 0.0

    def sync(self, value: Any) -> Any:
        """Block on ``value`` at span exit (returns it for chaining)."""
        self._sync = value
        return value

    def __enter__(self) -> "Span":
        tel = self.tel
        self.id = tel._next_id
        tel._next_id += 1
        self.parent = tel._span_stack[-1] if tel._span_stack else None
        tel._span_stack.append(self.id)
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        if self._sync is not None:
            import jax
            jax.block_until_ready(self._sync)
        dur_ms = (time.perf_counter() - self.t0) * 1e3
        tel = self.tel
        if tel._span_stack and tel._span_stack[-1] == self.id:
            tel._span_stack.pop()
        tel.observe(f"span.{self.name}.ms", dur_ms)
        tel.inc(f"span.{self.name}")
        tel.emit("span", name=self.name, id=self.id, parent=self.parent,
                 dur_ms=dur_ms, ok=exc_type is None,
                 err=None if exc_type is None else exc_type.__name__,
                 attrs=self.attrs or None)
        return False                                  # never swallow


class Telemetry:
    """The registry.  One per serving process (or per experiment arm).

    Parameters
    ----------
    jsonl_path:
        When set, every event is appended to this file as one JSON line
        (opened lazily on the first event, closed by ``close()``).
    max_events:
        In-memory event ring bound (the file, when set, gets everything).
    convergence:
        Record solver convergence traces (``SolveResult.conv``) on
        commits.  The traces are fixed-length per effort bucket -- the
        jitted anneal scans always compute them, this flag only controls
        host-side materialization -- so toggling it can never retrace.
    attribution_every:
        Every N-th engine commit additionally runs the exact per-tenant
        ``power.attribute_power`` split (an O(R) host loop) and records
        it into the energy ledger.  ``None`` (default) disables per-tenant
        attribution; keep it cadenced, not per-commit, at R >~ 1000.
    """

    def __init__(self, jsonl_path: Optional[str] = None,
                 max_events: int = 65536,
                 convergence: bool = True,
                 attribution_every: Optional[int] = None) -> None:
        self.counters: Dict[str, float] = {}
        self.gauges: Dict[str, float] = {}
        self.hists: Dict[str, Histogram] = {}
        self.events: List[dict] = []
        self.max_events = int(max_events)
        self.convergence = bool(convergence)
        self.attribution_every = attribution_every
        self.jsonl_path = jsonl_path
        self._fh: Optional[IO[str]] = None
        self._span_stack: List[int] = []
        self._next_id = 0
        self.ledger = EnergyLedger(emit=self.emit)
        # compile attribution: records appended by the count_traces hook
        self._trace_log: List[dict] = []
        self._trace_base: Optional[Dict[str, int]] = None
        self._trace_hook = None

    # -- metrics -----------------------------------------------------------
    def inc(self, name: str, n: float = 1, **labels: Any) -> None:
        k = _key(name, labels)
        self.counters[k] = self.counters.get(k, 0) + n

    def gauge(self, name: str, value: float, **labels: Any) -> None:
        self.gauges[_key(name, labels)] = float(value)

    def observe(self, name: str, value: float, **labels: Any) -> None:
        k = _key(name, labels)
        h = self.hists.get(k)
        if h is None:
            h = self.hists[k] = Histogram()
        h.observe(value)

    # -- events ------------------------------------------------------------
    def emit(self, type_: str, **fields: Any) -> dict:
        ev = {"type": type_, "ts": time.time()}
        ev.update(fields)
        self.events.append(ev)
        if len(self.events) > self.max_events:
            del self.events[:len(self.events) - self.max_events]
        if self.jsonl_path is not None:
            if self._fh is None:
                self._fh = open(self.jsonl_path, "a")
                self._fh.write(json.dumps(
                    {"type": "meta", "ts": time.time(),
                     "version": _EVENT_SCHEMA_VERSION}) + "\n")
            self._fh.write(json.dumps(ev) + "\n")
        return ev

    def span(self, name: str, sync: Any = None, **attrs: Any) -> Span:
        return Span(self, name, sync=sync, **attrs)

    # -- compile attribution (count_traces hook) ---------------------------
    def attach_traces(self) -> None:
        """Hook ``solvers.count_traces``: every fresh trace of any counted
        entry is recorded with a timestamp, the entry name, and the
        abstract shape fingerprint jax traced it at.  ``TRACE_COUNTS`` is
        snapshotted so ``report()`` can compare recorded vs live deltas."""
        if self._trace_hook is not None:
            return
        from ..core import solvers

        def hook(entry: str, fingerprint: str) -> None:
            rec = {"ts": time.time(), "entry": entry,
                   "fingerprint": fingerprint}
            self._trace_log.append(rec)
            self.inc(f"compile.{entry}")
            self.emit("trace", entry=entry, fingerprint=fingerprint)

        self._trace_base = dict(solvers.TRACE_COUNTS)
        self._trace_hook = hook
        solvers.TRACE_HOOKS.append(hook)

    def detach_traces(self) -> None:
        if self._trace_hook is None:
            return
        from ..core import solvers
        try:
            solvers.TRACE_HOOKS.remove(self._trace_hook)
        except ValueError:
            pass
        self._trace_hook = None

    def compile_attribution(self) -> List[dict]:
        return list(self._trace_log)

    # -- engine-facing recorders ------------------------------------------
    def record_commit(self, event: str, res: Any, t: float,
                      n_live: int,
                      per_tenant: Optional[Dict[int, float]] = None,
                      per_region: Optional[Dict[str, float]] = None,
                      engine: str = "online") -> None:
        """One engine commit: a ``solve`` event (with the convergence
        trace when recorded) plus an energy-ledger tick from the commit's
        already-computed breakdown (sampling at commits is EXACT for this
        workload model -- power only changes when a placement commits)."""
        bd = res.breakdown
        rec: Dict[str, Any] = {
            "engine": engine, "event": event, "method": res.method,
            "objective": float(res.objective), "power_w": float(res.power),
            "n_live": int(n_live), "t": float(t)}
        conv = getattr(res, "conv", None)
        if conv is not None and self.convergence:
            ds = {}
            for k, v in conv.items():
                step = -(-len(v) // 64) or 1    # <= 64 points per trace
                ds[k] = [float(x) for x in v[::step]]
            rec["conv"] = ds
            if "accept_rate" in conv and len(conv["accept_rate"]):
                self.observe("solve.accept_rate_final",
                             float(conv["accept_rate"][-1]))
        self.emit("solve", **rec)
        self.inc(f"commit.{event}")
        self.ledger.tick(t, total_w=float(bd.total), net_w=float(bd.net),
                         proc_w=float(bd.proc), per_proc=bd.per_proc,
                         per_tenant=per_tenant, per_region=per_region,
                         event=event)

    # -- exporters ---------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        return {"counters": dict(self.counters),
                "gauges": dict(self.gauges),
                "hists": {k: h.snapshot() for k, h in self.hists.items()}}

    def prometheus(self) -> str:
        """Prometheus-style text exposition of counters, gauges, and
        histograms (cumulative ``le`` buckets)."""
        def sanitize(name: str) -> str:
            base, _, labels = name.partition("{")
            out = "".join(c if c.isalnum() else "_" for c in base)
            return (f"repro_{out}{{{labels}" if labels
                    else f"repro_{out}")

        lines: List[str] = []
        for k in sorted(self.counters):
            lines.append(f"# TYPE {sanitize(k).partition('{')[0]} counter")
            lines.append(f"{sanitize(k)} {self.counters[k]}")
        for k in sorted(self.gauges):
            lines.append(f"# TYPE {sanitize(k).partition('{')[0]} gauge")
            lines.append(f"{sanitize(k)} {self.gauges[k]}")
        for k in sorted(self.hists):
            h = self.hists[k]
            base = sanitize(k).partition("{")[0]
            lines.append(f"# TYPE {base} histogram")
            acc = 0
            for edge in sorted(h.buckets):
                acc += h.buckets[edge]
                lines.append(f'{base}_bucket{{le="{edge}"}} {acc}')
            lines.append(f'{base}_bucket{{le="+Inf"}} {h.count}')
            lines.append(f"{base}_sum {h.sum}")
            lines.append(f"{base}_count {h.count}")
        return "\n".join(lines) + "\n"

    def report(self, bounds: Optional[Dict[str, Any]] = None
               ) -> Dict[str, Any]:
        """Run summary: metrics, integrated energy, and the compile
        attribution cross-checked against live ``TRACE_COUNTS`` (and,
        when ``bounds`` -- the ``repro.analysis.compute_cache_bounds``
        dict -- is given, against the CFN108 static bounds)."""
        from ..core import solvers
        out = self.snapshot()
        out["energy"] = self.ledger.integrate()
        recorded: Dict[str, int] = {}
        for rec in self._trace_log:
            recorded[rec["entry"]] = recorded.get(rec["entry"], 0) + 1
        compiles: Dict[str, Any] = {"recorded": recorded}
        if self._trace_base is not None:
            live = {k: solvers.TRACE_COUNTS.get(k, 0)
                    - self._trace_base.get(k, 0)
                    for k in set(solvers.TRACE_COUNTS)
                    | set(self._trace_base)}
            live = {k: v for k, v in live.items() if v}
            compiles["live"] = live
            compiles["agree"] = (recorded == live)
        if bounds is not None:
            checks = {}
            for entry, n in recorded.items():
                eb = bounds.get(entry)
                b = None if eb is None else eb.static_bound()
                checks[entry] = {"static_bound": b,
                                 "within": (b is None or n <= b)}
            compiles["bounds"] = checks
        out["compiles"] = compiles
        return out

    # -- lifecycle ---------------------------------------------------------
    def flush(self) -> None:
        if self._fh is not None:
            self._fh.flush()

    def close(self) -> None:
        """Emit the final ``summary`` event and close the JSONL sink."""
        self.detach_traces()
        self.emit("summary", report=self.report())
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def __enter__(self) -> "Telemetry":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()
