"""tracelint: static analysis for the repo's JAX/Pallas discipline.

The codebase's performance story rests on hand-maintained invariants --
one compile per shape bucket, value-only fault degradation, the f64
oracle confined to ``kernels/ref.py``, Pallas working sets sized to
VMEM.  Runtime spot-checks (``solvers.TRACE_COUNTS`` assertions) catch
some regressions after the fact; this package catches them at PR time
by walking the AST of ``src/`` against a rule catalog:

  CFN101  retrace hazards -- host-sync / concretization calls
          (``.item()``, ``float()``, ``int()``, ``bool()``,
          ``np.asarray()``) inside functions reachable from a
          ``jax.jit`` / ``lax.scan`` / ``vmap`` body.
  CFN102  dtype discipline -- float64 literals or casts outside the
          oracle whitelist, and implicit-promotion hazards.
  CFN103  pytree hygiene -- frozen-dataclass pytrees must account for
          every field in ``tree_flatten``; value-only paths
          (``degrade``-style) must not change shapes.
  CFN104  trace-counter coverage -- every jitted solver entry must be
          wrapped by ``solvers.count_traces`` so compile-stability
          tests can assert on it.
  CFN105  Pallas VMEM budget -- per-kernel VMEM estimate from
          BlockSpec shapes at the documented max scale, plus Python
          loops over non-constant bounds inside kernel bodies.

Rules CFN106-CFN109 ride on the flow-sensitive, interprocedural
dataflow engine (``repro.analysis.dataflow``: per-function def-use
chains, a project call graph, a small provenance lattice):

  CFN106  PRNG-key discipline -- a key consumed by two draws, a key
          fanned into a loop body without a per-iteration split, and
          split outputs that are silently dropped.
  CFN107  donation & aliasing -- arguments donated via
          ``donate_argnums`` read (or stored into) after the jitted
          call consumed their buffers.
  CFN108  compile-cache cardinality -- a static bound on the jit-cache
          key-space of every ``@count_traces`` entry; flags unbounded
          or over-cap entries (``rules_flow.CACHE_CAPS``).
  CFN109  dead device compute -- device arrays computed but never
          consumed (allocation + compute with no observable effect).

CLI: ``python -m repro.analysis [--baseline FILE] [--format text|json]
[--changed [REF]] [paths...]`` (exit 1 on any non-suppressed finding;
``--changed`` reports only files touched vs the git ref while the full
path set still feeds cross-module context).  Suppression is per-line
via ``# tracelint: allow[CFN10x]`` pragmas or per-finding via a
committed baseline file (``analysis/baseline.json`` for ``src``,
``analysis/baseline-tools.json`` for benchmarks/examples).  The rule
catalog is documented in ``docs/ANALYSIS.md``.
"""
from .engine import (Finding, Module, Project, ProjectRule, Rule,
                     analyze_paths, analyze_project, analyze_source,
                     apply_baseline, baseline_payload, iter_python_files,
                     load_baseline, load_project)
from .rules import (MAX_SCALE, VMEM_BUDGET_BYTES, DtypeDiscipline,
                    PallasVmemBudget, PytreeHygiene, RetraceHazards,
                    TraceCounterCoverage, all_rules)
from .rules_flow import (CACHE_CAPS, CacheCardinality, DeadDeviceCompute,
                         DonationDiscipline, EntryBound, PrngKeyDiscipline,
                         compute_cache_bounds, flow_rules)

__all__ = [
    "Finding", "Module", "Project", "ProjectRule", "Rule", "analyze_paths",
    "analyze_project", "analyze_source", "apply_baseline",
    "baseline_payload", "iter_python_files", "load_baseline", "load_project",
    "all_rules", "RetraceHazards", "DtypeDiscipline", "PytreeHygiene",
    "TraceCounterCoverage", "PallasVmemBudget", "MAX_SCALE",
    "VMEM_BUDGET_BYTES", "PrngKeyDiscipline", "DonationDiscipline",
    "CacheCardinality", "DeadDeviceCompute", "EntryBound", "CACHE_CAPS",
    "compute_cache_bounds", "flow_rules",
]
