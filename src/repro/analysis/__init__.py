"""tracelint: static analysis for the repo's JAX/Pallas discipline.

The codebase's performance story rests on hand-maintained invariants --
one compile per shape bucket, value-only fault degradation, the f64
oracle confined to ``kernels/ref.py``, Pallas working sets sized to
VMEM.  Runtime spot-checks (``solvers.TRACE_COUNTS`` assertions) catch
some regressions after the fact; this package catches them at PR time
by walking the AST of ``src/`` against a rule catalog:

  CFN101  retrace hazards -- host-sync / concretization calls
          (``.item()``, ``float()``, ``int()``, ``bool()``,
          ``np.asarray()``) inside functions reachable from a
          ``jax.jit`` / ``lax.scan`` / ``vmap`` body.
  CFN102  dtype discipline -- float64 literals or casts outside the
          oracle whitelist, and implicit-promotion hazards.
  CFN103  pytree hygiene -- frozen-dataclass pytrees must account for
          every field in ``tree_flatten``; value-only paths
          (``degrade``-style) must not change shapes.
  CFN104  trace-counter coverage -- every jitted solver entry must be
          wrapped by ``solvers.count_traces`` so compile-stability
          tests can assert on it.
  CFN105  Pallas VMEM budget -- per-kernel VMEM estimate from
          BlockSpec shapes at the documented max scale, plus Python
          loops over non-constant bounds inside kernel bodies.

CLI: ``python -m repro.analysis [--baseline FILE] [--format text|json]
[paths...]`` (exit 1 on any non-suppressed finding).  Suppression is
per-line via ``# tracelint: allow[CFN10x]`` pragmas or per-finding via
a committed baseline file (``analysis/baseline.json``).  The rule
catalog is documented in ``docs/ANALYSIS.md``.
"""
from .engine import (Finding, Module, Rule, analyze_paths, analyze_source,
                     apply_baseline, baseline_payload, iter_python_files,
                     load_baseline)
from .rules import (MAX_SCALE, VMEM_BUDGET_BYTES, DtypeDiscipline,
                    PallasVmemBudget, PytreeHygiene, RetraceHazards,
                    TraceCounterCoverage, all_rules)

__all__ = [
    "Finding", "Module", "Rule", "analyze_paths", "analyze_source",
    "apply_baseline", "baseline_payload", "iter_python_files",
    "load_baseline", "all_rules", "RetraceHazards", "DtypeDiscipline",
    "PytreeHygiene", "TraceCounterCoverage", "PallasVmemBudget",
    "MAX_SCALE", "VMEM_BUDGET_BYTES",
]
