"""The tracelint rule catalog (CFN101-CFN105).

Every rule is a pure AST pass over one ``engine.Module``; cross-file
state is deliberately avoided so the pass stays O(file) and fixture
tests can feed single source strings.  Call-graph reachability (CFN101)
and jit-entry discovery (CFN104) therefore resolve simple-name calls
within the module -- calls into other modules are checked where those
functions are defined, which is exactly where the fix belongs.

See docs/ANALYSIS.md for the catalog with examples and suppression
guidance.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from .engine import Finding, Module, Rule

# The documented maximum deployment scale (ROADMAP city_p468 + federated
# buckets): CFN105 prices every Pallas BlockSpec at these substrate/problem
# dims.  Kernel-local tile sizes (bc, block_*) come from each wrapper's
# keyword defaults, which override these.
MAX_SCALE: Dict[str, int] = {
    "P": 468, "N": 160, "K": 14,     # substrate: nodes / net elems / route pad
    "R": 32, "V": 16, "J": 512,      # services x VMs (J = R * V)
    "L": 1024,                       # virtual links after _pad_links
    "T": 4000, "D": 16,              # anneal steps, incident-link degree
    "S": 4000, "G": 8,               # scan length, federated regions
}

VMEM_BUDGET_BYTES = 16 * 1024 * 1024   # one TPU core's VMEM
_BYTES_PER_ELEM = 4                    # f32 / i32 lanes (the kernel dtypes)

_JIT_NAMES = {"jax.jit", "jit", "pjit", "jax.pjit"}
_PARTIAL_NAMES = {"functools.partial", "partial"}
_TRACE_BODY_CALLS = {"jax.lax.scan", "lax.scan", "jax.lax.fori_loop",
                     "lax.fori_loop", "jax.lax.while_loop", "lax.while_loop",
                     "jax.vmap", "vmap", "jax.pmap", "pmap"}
_UNWRAP_CALLS = _TRACE_BODY_CALLS | _PARTIAL_NAMES | {
    "jax.value_and_grad", "jax.grad", "jax.checkpoint", "jax.remat",
    "count_traces"}


def _dotted(node: ast.AST) -> Optional[str]:
    """'a.b.c' for a Name/Attribute chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _call_target(node: ast.AST) -> Optional[str]:
    return _dotted(node.func) if isinstance(node, ast.Call) else None


def _is_jit_decorator(dec: ast.AST) -> bool:
    d = _dotted(dec)
    if d in _JIT_NAMES:
        return True
    if isinstance(dec, ast.Call):
        f = _dotted(dec.func)
        if f in _JIT_NAMES:
            return True
        # functools.partial(jax.jit, static_argnames=...)
        if f in _PARTIAL_NAMES and dec.args \
                and _dotted(dec.args[0]) in _JIT_NAMES:
            return True
    return False


def _is_count_traces_decorator(dec: ast.AST) -> bool:
    if isinstance(dec, ast.Call):
        d = _dotted(dec.func)
        return d is not None and d.split(".")[-1] == "count_traces"
    return False


def _unwrap_to_names(node: ast.AST) -> List[str]:
    """Function names inside transform wrappers: jax.jit(jax.vmap(f)) -> f,
    jax.jit(count_traces("x")(f)) -> f, partial(k, P=...) -> k."""
    out: List[str] = []
    stack = [node]
    while stack:
        n = stack.pop()
        if isinstance(n, ast.Name):
            out.append(n.id)
        elif isinstance(n, ast.Call):
            t = _dotted(n.func)
            if t is not None and (t in _UNWRAP_CALLS
                                  or t.split(".")[-1] == "count_traces"):
                stack.extend(n.args[:1])
            elif isinstance(n.func, ast.Call):
                # decorator-factory application: count_traces("x")(f)
                stack.extend(n.args[:1])
    return out


def _module_functions(tree: ast.Module) -> Dict[str, ast.FunctionDef]:
    """Function defs reachable by BARE name (module-level and nested),
    keyed by name.  Class methods are excluded -- a simple-name call can
    never hit one, and including them would shadow same-named module
    functions (e.g. a ``objective`` property vs the jitted ``objective``)."""
    methods = {m for n in ast.walk(tree) if isinstance(n, ast.ClassDef)
               for m in n.body
               if isinstance(m, (ast.FunctionDef, ast.AsyncFunctionDef))}
    return {n.name: n for n in ast.walk(tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
            and n not in methods}


def _toplevel_functions(tree: ast.Module) -> Dict[str, ast.FunctionDef]:
    return {n.name: n for n in tree.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}


# ---------------------------------------------------------------------------
# CFN101: retrace hazards
# ---------------------------------------------------------------------------

class RetraceHazards(Rule):
    """Host-sync / concretization calls inside traced code.

    Roots: functions decorated with ``jax.jit`` (incl. the
    ``functools.partial(jax.jit, ...)`` form), functions passed to
    ``jax.jit`` / ``vmap`` / ``pmap`` / ``lax.scan`` / ``lax.fori_loop``
    / ``lax.while_loop`` call sites, and everything those reach through
    simple-name calls in this module.  Inside that set, ``.item()``,
    ``float()`` / ``int()`` / ``bool()`` on non-static values, and
    ``np.asarray`` / ``np.array`` all force a host round trip per trace
    -- or fail outright on abstract tracers.
    """

    id = "CFN101"
    title = "retrace hazard"
    CASTS = {"float", "int", "bool"}
    NP_CALLS = {"np.asarray", "np.array", "numpy.asarray", "numpy.array",
                "onp.asarray", "onp.array"}

    def _roots(self, mod: Module,
               funcs: Dict[str, ast.FunctionDef]) -> Set[str]:
        roots: Set[str] = set()
        for name, fn in funcs.items():
            if any(_is_jit_decorator(d) for d in fn.decorator_list):
                roots.add(name)
        for node in ast.walk(mod.tree):
            t = _call_target(node)
            if t in _JIT_NAMES or t in _TRACE_BODY_CALLS:
                for nm in _unwrap_to_names(node.args[0]) if node.args else []:
                    if nm in funcs:
                        roots.add(nm)
        return roots

    @staticmethod
    def _static_cast_arg(arg: ast.AST) -> bool:
        """Casts of shapes/dims/constants are trace-safe."""
        if isinstance(arg, ast.Constant):
            return True
        if isinstance(arg, ast.Call) and _dotted(arg.func) == "len":
            return True
        for n in ast.walk(arg):
            if isinstance(n, ast.Attribute) and n.attr in ("shape", "ndim",
                                                           "size", "dtype"):
                return True
        return False

    def check(self, mod: Module) -> Iterable[Finding]:
        funcs = _module_functions(mod.tree)
        roots = self._roots(mod, funcs)
        if not roots:
            return
        # reachability over simple-name calls within the module
        reach: Set[str] = set()
        work = list(roots)
        while work:
            name = work.pop()
            if name in reach:
                continue
            reach.add(name)
            for node in ast.walk(funcs[name]):
                t = _call_target(node)
                if t in funcs and t not in reach:
                    work.append(t)
        seen: Set[Tuple[int, int, str]] = set()
        for name in sorted(reach):
            for node in ast.walk(funcs[name]):
                if not isinstance(node, ast.Call):
                    continue
                t = _dotted(node.func)
                msg = None
                if isinstance(node.func, ast.Attribute) \
                        and node.func.attr == "item" and not node.args:
                    msg = (f"`.item()` in `{name}` (traced from a jit/scan/"
                           "vmap entry) forces a device sync per trace")
                elif t in self.CASTS and node.args \
                        and not self._static_cast_arg(node.args[0]):
                    msg = (f"`{t}(...)` on a traced value in `{name}` "
                           "concretizes under jit (TracerError / silent "
                           "host sync)")
                elif t in self.NP_CALLS:
                    msg = (f"`{t}(...)` in `{name}` materializes traced "
                           "values on host (breaks tracing / forces a "
                           "round trip)")
                if msg is not None:
                    key = (node.lineno, node.col_offset, msg)
                    if key not in seen:
                        seen.add(key)
                        yield self.finding(mod, node, msg)


# ---------------------------------------------------------------------------
# CFN102: dtype discipline
# ---------------------------------------------------------------------------

class DtypeDiscipline(Rule):
    """float64 belongs to the oracle (``kernels/ref.py``) and the byte-size
    table (``launch/roofline.py``); everywhere else it either silently
    doubles memory traffic (under ``jax_enable_x64``) or silently truncates
    (without), so every other use must carry an explicit
    ``# tracelint: allow[CFN102]`` pragma stating why."""

    id = "CFN102"
    title = "dtype discipline"
    WHITELIST_SUFFIXES = ("kernels/ref.py", "launch/roofline.py")
    DTYPE_STRS = {"float64", "f64"}
    DTYPE_CALLS = {"astype", "asarray", "array", "zeros", "ones", "full",
                   "empty", "arange"}

    def check(self, mod: Module) -> Iterable[Finding]:
        if mod.path.endswith(self.WHITELIST_SUFFIXES):
            return
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Attribute) and node.attr == "float64":
                yield self.finding(
                    mod, node,
                    f"float64 reference `{_dotted(node)}` outside the f64 "
                    "oracle whitelist")
            elif isinstance(node, ast.Name) and node.id == "float64":
                yield self.finding(
                    mod, node,
                    "float64 reference outside the f64 oracle whitelist")
            elif isinstance(node, ast.Call):
                fn = _dotted(node.func)
                leaf = fn.split(".")[-1] if fn else ""
                for kw in node.keywords:
                    if kw.arg == "dtype":
                        if isinstance(kw.value, ast.Constant) \
                                and kw.value.value in self.DTYPE_STRS:
                            yield self.finding(
                                mod, node,
                                f'dtype="{kw.value.value}" outside the f64 '
                                "oracle whitelist")
                        elif isinstance(kw.value, ast.Name) \
                                and kw.value.id == "float":
                            yield self.finding(
                                mod, node,
                                "dtype=float promotes to float64 under "
                                "jax_enable_x64 (implicit-promotion hazard)",
                                severity="warning")
                if leaf in self.DTYPE_CALLS:
                    for arg in node.args:
                        if isinstance(arg, ast.Constant) \
                                and arg.value in self.DTYPE_STRS:
                            yield self.finding(
                                mod, node,
                                f'`{leaf}(..., "{arg.value}")` outside the '
                                "f64 oracle whitelist")
                        elif leaf == "astype" and isinstance(arg, ast.Name) \
                                and arg.id == "float":
                            yield self.finding(
                                mod, node,
                                "astype(float) promotes to float64 under "
                                "jax_enable_x64 (implicit-promotion hazard)",
                                severity="warning")


# ---------------------------------------------------------------------------
# CFN103: pytree hygiene
# ---------------------------------------------------------------------------

class PytreeHygiene(Rule):
    """Frozen-dataclass pytrees must account for EVERY field in
    ``tree_flatten`` (a field that is neither leaf nor aux silently
    disappears through tree_map/jit, resurrected from stale defaults by
    unflatten), and ``degrade``-style value-only paths must never change
    array shapes (a shape change retraces every solver kernel that
    consumes the pytree)."""

    id = "CFN103"
    title = "pytree hygiene"
    SHAPE_OPS = {"concatenate", "pad", "stack", "hstack", "vstack", "tile",
                 "repeat", "append", "delete"}
    VALUE_ONLY_NAMES = {"degrade"}

    @staticmethod
    def _is_dataclass_decorated(cls: ast.ClassDef) -> bool:
        for dec in cls.decorator_list:
            d = _dotted(dec) or (_dotted(dec.func)
                                 if isinstance(dec, ast.Call) else None)
            if d and d.split(".")[-1] == "dataclass":
                return True
        return False

    def _check_flatten_coverage(self, mod: Module,
                                cls: ast.ClassDef) -> Iterable[Finding]:
        flatten = next((n for n in cls.body
                        if isinstance(n, ast.FunctionDef)
                        and n.name == "tree_flatten"), None)
        if flatten is None:
            return
        fields: List[str] = []
        str_tuples: Dict[str, Set[str]] = {}
        for stmt in cls.body:
            if isinstance(stmt, ast.AnnAssign) \
                    and isinstance(stmt.target, ast.Name):
                ann = ast.dump(stmt.annotation)
                if "ClassVar" not in ann:
                    fields.append(stmt.target.id)
            elif isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                    and isinstance(stmt.targets[0], ast.Name) \
                    and isinstance(stmt.value, (ast.Tuple, ast.List)):
                elts = stmt.value.elts
                if elts and all(isinstance(e, ast.Constant)
                                and isinstance(e.value, str) for e in elts):
                    str_tuples[stmt.targets[0].id] = {e.value for e in elts}
        covered: Set[str] = set()
        all_covered = False
        for node in ast.walk(flatten):
            if isinstance(node, ast.Attribute):
                if node.attr == "__dataclass_fields__":
                    all_covered = True
                elif isinstance(node.value, ast.Name) \
                        and node.value.id == "self":
                    covered.add(node.attr)
            elif isinstance(node, ast.Name) and node.id in str_tuples:
                covered |= str_tuples[node.id]
        if all_covered:
            return
        missing = [f for f in fields if f not in covered]
        if missing:
            yield self.finding(
                mod, flatten,
                f"pytree `{cls.name}`: field(s) {', '.join(missing)} are "
                "neither leaf nor aux in tree_flatten (dropped through "
                "tree_map/jit, resurrected stale by unflatten)")

    def _check_value_only(self, mod: Module,
                          fn: ast.FunctionDef) -> Iterable[Finding]:
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            t = _dotted(node.func)
            leaf = t.split(".")[-1] if t else ""
            if leaf in self.SHAPE_OPS:
                yield self.finding(
                    mod, node,
                    f"shape-changing `{leaf}` inside value-only path "
                    f"`{fn.name}` (fail/recover must keep solver kernels "
                    "on their compile buckets)")
            elif leaf == "reshape" and any(
                    not isinstance(a, (ast.Constant, ast.UnaryOp))
                    for a in node.args):
                yield self.finding(
                    mod, node,
                    f"`reshape` with non-static args inside value-only "
                    f"path `{fn.name}`")

    def check(self, mod: Module) -> Iterable[Finding]:
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.ClassDef) \
                    and self._is_dataclass_decorated(node):
                yield from self._check_flatten_coverage(mod, node)
            elif isinstance(node, ast.FunctionDef) \
                    and node.name in self.VALUE_ONLY_NAMES:
                yield from self._check_value_only(mod, node)


# ---------------------------------------------------------------------------
# CFN104: trace-counter coverage
# ---------------------------------------------------------------------------

class TraceCounterCoverage(Rule):
    """Every module-level jitted solver entry in the solver modules must
    route through ``count_traces`` (under the jit, so the counter ticks
    per TRACE, not per call) -- that is what lets compile-stability tests
    assert "zero fresh compiles across this storm".

    Scope: ``core/solvers.py`` and ``core/federation.py`` (the modules
    whose entries the TRACE_COUNTS tests assert on).  Jit wrappers around
    functions imported from other modules are exempt: their counter
    contract belongs to the defining module."""

    id = "CFN104"
    title = "trace-counter coverage"
    ENFORCE_SUFFIXES = ("core/solvers.py", "core/federation.py")

    def check(self, mod: Module) -> Iterable[Finding]:
        if not mod.path.endswith(self.ENFORCE_SUFFIXES):
            return
        top = _toplevel_functions(mod.tree)
        for name, fn in top.items():
            jit_idx = [i for i, d in enumerate(fn.decorator_list)
                       if _is_jit_decorator(d)]
            if not jit_idx:
                continue
            ct_idx = [i for i, d in enumerate(fn.decorator_list)
                      if _is_count_traces_decorator(d)]
            if not ct_idx:
                yield self.finding(
                    mod, fn,
                    f"jitted solver entry `{name}` does not increment "
                    "TRACE_COUNTS (add @count_traces under @jax.jit)")
            elif ct_idx[0] < jit_idx[0]:
                yield self.finding(
                    mod, fn,
                    f"`{name}`: @count_traces must sit UNDER @jax.jit "
                    "(above it, the counter ticks per call, not per trace)")
        for node in mod.tree.body:
            if not (isinstance(node, ast.Assign)
                    and isinstance(node.value, ast.Call)
                    and _dotted(node.value.func) in _JIT_NAMES
                    and node.value.args):
                continue
            arg = node.value.args[0]
            if isinstance(arg, ast.Call):
                f = _dotted(arg.func)
                if isinstance(arg.func, ast.Call) or (
                        f and f.split(".")[-1] == "count_traces"):
                    continue     # jax.jit(count_traces("x")(f))
            wrapped = _unwrap_to_names(arg)
            target = top.get(wrapped[0]) if wrapped else None
            if target is None:
                continue         # wraps an imported function: exempt
            if not any(_is_count_traces_decorator(d)
                       for d in target.decorator_list):
                yield self.finding(
                    mod, node,
                    f"jitted solver entry `{wrapped[0]}` (via "
                    "`jax.jit(...)` assignment) does not increment "
                    "TRACE_COUNTS (decorate it with @count_traces)")


# ---------------------------------------------------------------------------
# CFN105: Pallas VMEM budget
# ---------------------------------------------------------------------------

def _eval_dim(node: ast.AST, env: Dict[str, int]) -> Optional[int]:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return node.value
    if isinstance(node, ast.Name):
        return env.get(node.id)
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        v = _eval_dim(node.operand, env)
        return None if v is None else -v
    if isinstance(node, ast.BinOp):
        lo, hi = _eval_dim(node.left, env), _eval_dim(node.right, env)
        if lo is None or hi is None:
            return None
        if isinstance(node.op, ast.Mult):
            return lo * hi
        if isinstance(node.op, ast.Add):
            return lo + hi
        if isinstance(node.op, ast.Sub):
            return lo - hi
        if isinstance(node.op, (ast.FloorDiv, ast.Div)):
            return lo // hi if hi else None
        if isinstance(node.op, ast.Pow):
            return lo ** hi
    return None


class PallasVmemBudget(Rule):
    """Per ``pallas_call``, the blocks named by in/out BlockSpecs are
    resident in VMEM together; this rule prices them at the documented
    max scale (``MAX_SCALE``, bc/tile names overridden by the wrapper's
    own keyword defaults) and fails anything over ``VMEM_BUDGET_BYTES``.
    Also flags Python ``for ... in range(non-constant)`` loops inside
    kernel bodies -- they unroll at trace time into dim-many statements.
    """

    id = "CFN105"
    title = "Pallas VMEM budget"

    def _env_for(self, mod: Module, fn: Optional[ast.FunctionDef]
                 ) -> Dict[str, int]:
        env = dict(MAX_SCALE)
        for node in mod.tree.body:
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name) \
                    and isinstance(node.value, ast.Constant) \
                    and isinstance(node.value.value, int):
                env[node.targets[0].id] = node.value.value
        if fn is not None:
            args = fn.args
            defaults = list(args.defaults)
            names = [a.arg for a in args.args][len(args.args)
                                               - len(defaults):]
            for nm, d in zip(names, defaults):
                if isinstance(d, ast.Constant) and isinstance(d.value, int):
                    env[nm] = d.value
            for a, d in zip(args.kwonlyargs, args.kw_defaults):
                if isinstance(d, ast.Constant) and isinstance(d.value, int):
                    env[a.arg] = d.value
        return env

    @staticmethod
    def _kernel_names(call: ast.Call) -> List[str]:
        if not call.args:
            return []
        return _unwrap_to_names(call.args[0])

    def check(self, mod: Module) -> Iterable[Finding]:
        funcs = _module_functions(mod.tree)
        calls: List[Tuple[Optional[ast.FunctionDef], ast.Call]] = []
        for fn in funcs.values():
            for node in ast.walk(fn):
                t = _call_target(node)
                if t and t.split(".")[-1] == "pallas_call":
                    calls.append((fn, node))
        kernel_fns: Set[str] = set()
        for fn, call in calls:
            kernel_fns |= {n for n in self._kernel_names(call) if n in funcs}
            env = self._env_for(mod, fn)
            total = 0
            unknown = 0
            for node in ast.walk(call):
                t = _call_target(node)
                if not (t and t.split(".")[-1] == "BlockSpec"):
                    continue
                shape = None
                if node.args and isinstance(node.args[0],
                                            (ast.Tuple, ast.List)):
                    shape = node.args[0].elts
                else:
                    for kw in node.keywords:
                        if kw.arg == "block_shape" and isinstance(
                                kw.value, (ast.Tuple, ast.List)):
                            shape = kw.value.elts
                if shape is None:
                    continue
                n = _BYTES_PER_ELEM
                for dim in shape:
                    v = _eval_dim(dim, env)
                    if v is None:
                        unknown += 1
                        n = 0
                        break
                    n *= v
                total += n
            if unknown:
                yield self.finding(
                    mod, call,
                    f"pallas_call in `{fn.name if fn else '<module>'}`: "
                    f"{unknown} BlockSpec shape(s) not statically "
                    "evaluable at MAX_SCALE -- VMEM estimate is a "
                    "lower bound", severity="warning")
            if total > VMEM_BUDGET_BYTES:
                yield self.finding(
                    mod, call,
                    f"pallas_call in `{fn.name if fn else '<module>'}`: "
                    f"estimated VMEM {total / 2**20:.2f} MiB at max scale "
                    f"(P={MAX_SCALE['P']}, K={MAX_SCALE['K']}) exceeds the "
                    f"{VMEM_BUDGET_BYTES / 2**20:.0f} MiB budget")
        for name in sorted(kernel_fns):
            for node in ast.walk(funcs[name]):
                if isinstance(node, ast.For) \
                        and isinstance(node.iter, ast.Call) \
                        and _dotted(node.iter.func) == "range" \
                        and any(not isinstance(a, ast.Constant)
                                for a in node.iter.args):
                    yield self.finding(
                        mod, node,
                        f"Python loop over a non-constant bound in Pallas "
                        f"kernel `{name}` unrolls at trace time (use "
                        "lax.fori_loop or a constant tile)")


def all_rules() -> List[Rule]:
    from . import rules_flow
    return [RetraceHazards(), DtypeDiscipline(), PytreeHygiene(),
            TraceCounterCoverage(), PallasVmemBudget()] \
        + list(rules_flow.flow_rules())
