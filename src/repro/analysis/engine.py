"""tracelint engine: findings, pragma/baseline suppression, runners.

Rules are stateless objects with an ``id``/``severity`` and a
``check(module) -> findings`` method; the engine owns everything rules
share -- parsing, the per-line ``# tracelint: allow[...]`` pragma map,
line-independent baseline fingerprints, and path walking.  Keeping
suppression out of the rules means a rule only ever reports what it
sees; policy (accept / pragma / baseline) lives with the code owner.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import re
from pathlib import Path
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Set

# matches "# tracelint: allow[CFN101]" and "# tracelint: allow[CFN101,CFN102]"
_PRAGMA_RE = re.compile(r"#\s*tracelint:\s*allow\[([A-Za-z0-9,\s]+)\]")

BASELINE_VERSION = 2


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str         # "CFN101"
    severity: str     # "error" | "warning"
    path: str         # normalized with forward slashes
    line: int         # 1-based
    message: str
    context: str = ""  # enclosing function qualname ("" at module level)

    @property
    def key(self) -> str:
        """Line- and file-independent fingerprint: a baseline entry keeps
        matching after unrelated edits shift the finding up or down the
        file, and after the enclosing function is MOVED across files (the
        fingerprint anchors on the function's qualname, not the path;
        module-level findings fall back to the path)."""
        return f"{self.rule}::{self.context or self.path}::{self.message}"

    def to_dict(self) -> dict:
        return {"rule": self.rule, "severity": self.severity,
                "path": self.path, "line": self.line,
                "message": self.message, "context": self.context,
                "key": self.key}

    def render(self) -> str:
        return (f"{self.path}:{self.line}: {self.rule} "
                f"[{self.severity}] {self.message}")


def _pragma_lines(lines: Sequence[str]) -> Dict[int, Set[str]]:
    out: Dict[int, Set[str]] = {}
    for i, text in enumerate(lines, start=1):
        m = _PRAGMA_RE.search(text)
        if m:
            out[i] = {t.strip() for t in m.group(1).split(",") if t.strip()}
    return out


class Module:
    """One parsed source file, handed to every rule."""

    def __init__(self, source: str, path: str = "<string>"):
        self.source = source
        self.path = str(path).replace("\\", "/")
        self.tree = ast.parse(source)
        self.lines = source.splitlines()
        self.pragmas = _pragma_lines(self.lines)
        # (start, end, qualname) spans of every def, innermost last
        self._spans: List[tuple] = []
        self._index_spans(self.tree, ())

    def _index_spans(self, node: ast.AST, stack: tuple) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
                sub = stack + (child.name,)
                if not isinstance(child, ast.ClassDef):
                    self._spans.append((child.lineno,
                                        child.end_lineno or child.lineno,
                                        ".".join(sub)))
                self._index_spans(child, sub)
            else:
                self._index_spans(child, stack)

    def context_at(self, line: int) -> str:
        """Qualname of the innermost function def enclosing ``line``
        ("" for module-level code) -- the move-stable fingerprint anchor."""
        best = ""
        best_span = None
        for start, end, qual in self._spans:
            if start <= line <= end:
                if best_span is None or (end - start) <= best_span:
                    best, best_span = qual, end - start
        return best

    def allowed(self, rule_id: str, line: int) -> bool:
        """A pragma suppresses findings on its own line and, when it sits
        on a standalone comment line, on the line below it."""
        for ln in (line, line - 1):
            if rule_id in self.pragmas.get(ln, ()):
                return True
        return False


class Project:
    """Every parsed module of one analysis run: the cross-file context
    ``ProjectRule``s (the flow-sensitive CFN106-CFN109 families) resolve
    imports and build their call graph over.  Single-source runs
    (``analyze_source``) are one-module projects."""

    def __init__(self, modules: Sequence["Module"]):
        self.modules = list(modules)
        self.by_path: Dict[str, Module] = {m.path: m for m in self.modules}
        self.by_name: Dict[str, Module] = {}
        for m in self.modules:
            name = module_name(m.path)
            if name:
                self.by_name[name] = m
        self._caches: Dict[str, object] = {}   # dataflow index memo

    def cache(self, key: str, build):
        if key not in self._caches:
            self._caches[key] = build()
        return self._caches[key]


def module_name(path: str) -> Optional[str]:
    """Dotted import name for a source path: ``src/repro/core/solvers.py``
    -> ``repro.core.solvers`` (anchored at the ``src`` dir or the
    top-most package dir seen in the path); None when not derivable."""
    p = str(path).replace("\\", "/")
    if not p.endswith(".py"):
        return None
    parts = p[:-3].split("/")
    if "src" in parts:
        parts = parts[parts.index("src") + 1:]
    elif "repro" in parts:
        parts = parts[parts.index("repro"):]
    else:
        parts = parts[-1:]
    if parts and parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(x for x in parts if x) or None


class Rule:
    """Base rule: subclasses set ``id``/``severity``/``title`` and yield
    findings from ``check``."""

    id = "CFN000"
    severity = "error"
    title = ""

    def check(self, mod: Module) -> Iterable[Finding]:
        raise NotImplementedError

    def finding(self, mod: Module, node, message: str,
                severity: Optional[str] = None) -> Finding:
        line = node if isinstance(node, int) else getattr(node, "lineno", 0)
        return Finding(rule=self.id, severity=severity or self.severity,
                       path=mod.path, line=line, message=message,
                       context=mod.context_at(line))


class ProjectRule(Rule):
    """A rule that sees the WHOLE parsed project at once (imports, call
    graph, cross-module dataflow).  ``check_project`` replaces ``check``;
    findings land on whichever module/line they belong to and the engine
    applies that module's pragmas."""

    def check(self, mod: Module) -> Iterable[Finding]:   # pragma: no cover
        return self.check_project(Project([mod]))

    def check_project(self, project: Project) -> Iterable[Finding]:
        raise NotImplementedError


def _default_rules() -> List[Rule]:
    from . import rules
    return rules.all_rules()


def analyze_project(project: Project,
                    rules: Optional[Sequence[Rule]] = None) -> List[Finding]:
    """Run the rule catalog over a parsed project.  Pragma-suppressed
    findings are dropped here; baseline suppression is the caller's
    (``apply_baseline``)."""
    out: List[Finding] = []
    for rule in (rules if rules is not None else _default_rules()):
        if isinstance(rule, ProjectRule):
            found = list(rule.check_project(project))
        else:
            found = [f for m in project.modules for f in rule.check(m)]
        for f in found:
            mod = project.by_path.get(f.path)
            if mod is None or not mod.allowed(f.rule, f.line):
                out.append(f)
    return sorted(out, key=lambda f: (f.path, f.line, f.rule))


def analyze_source(source: str, path: str = "<string>",
                   rules: Optional[Sequence[Rule]] = None) -> List[Finding]:
    """Run the rule catalog over one source string (a one-module project)."""
    return analyze_project(Project([Module(source, path=path)]), rules=rules)


def iter_python_files(paths: Sequence[str]) -> Iterator[Path]:
    for p in paths:
        path = Path(p)
        if path.is_dir():
            yield from sorted(path.rglob("*.py"))
        elif path.suffix == ".py":
            yield path


def load_project(paths: Sequence[str]) -> tuple:
    """Parse every file under ``paths`` into a Project; returns
    ``(project, syntax_error_findings)``."""
    mods: List[Module] = []
    errors: List[Finding] = []
    for f in iter_python_files(paths):
        try:
            mods.append(Module(f.read_text(), path=str(f)))
        except SyntaxError as e:
            errors.append(Finding(
                rule="E999", severity="error",
                path=str(f).replace("\\", "/"), line=e.lineno or 0,
                message=f"syntax error: {e.msg}"))
    return Project(mods), errors


def analyze_paths(paths: Sequence[str],
                  rules: Optional[Sequence[Rule]] = None,
                  only: Optional[Sequence[str]] = None) -> List[Finding]:
    """Analyze every python file under ``paths``.  ``only`` (optional)
    restricts the REPORTED findings to the given files while the whole
    path set still feeds the cross-module context (the ``--changed``
    mode: lint a handful of touched files against the full call graph).
    """
    project, findings = load_project(paths)
    findings = list(findings)
    findings.extend(analyze_project(project, rules=rules))
    if only is not None:
        keep = {str(p).replace("\\", "/") for p in only}
        findings = [f for f in findings if f.path in keep]
    return sorted(findings, key=lambda f: (f.path, f.line, f.rule))


# -- baseline ---------------------------------------------------------------

def baseline_payload(findings: Sequence[Finding]) -> dict:
    return {"version": BASELINE_VERSION,
            "suppressions": sorted({f.key for f in findings})}


def load_baseline(path: str) -> Set[str]:
    data = json.loads(Path(path).read_text())
    if data.get("version") != BASELINE_VERSION:
        raise ValueError(f"baseline {path}: unsupported version "
                         f"{data.get('version')!r}")
    return set(data.get("suppressions", ()))


def apply_baseline(findings: Sequence[Finding], baseline: Set[str]
                   ) -> List[Finding]:
    """Findings NOT covered by the baseline (the ones that fail CI)."""
    return [f for f in findings if f.key not in baseline]
