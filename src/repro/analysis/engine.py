"""tracelint engine: findings, pragma/baseline suppression, runners.

Rules are stateless objects with an ``id``/``severity`` and a
``check(module) -> findings`` method; the engine owns everything rules
share -- parsing, the per-line ``# tracelint: allow[...]`` pragma map,
line-independent baseline fingerprints, and path walking.  Keeping
suppression out of the rules means a rule only ever reports what it
sees; policy (accept / pragma / baseline) lives with the code owner.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import re
from pathlib import Path
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Set

# matches "# tracelint: allow[CFN101]" and "# tracelint: allow[CFN101,CFN102]"
_PRAGMA_RE = re.compile(r"#\s*tracelint:\s*allow\[([A-Za-z0-9,\s]+)\]")

BASELINE_VERSION = 1


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str         # "CFN101"
    severity: str     # "error" | "warning"
    path: str         # normalized with forward slashes
    line: int         # 1-based
    message: str

    @property
    def key(self) -> str:
        """Line-independent fingerprint: a baseline entry keeps matching
        after unrelated edits shift the finding up or down the file."""
        return f"{self.rule}::{self.path}::{self.message}"

    def to_dict(self) -> dict:
        return {"rule": self.rule, "severity": self.severity,
                "path": self.path, "line": self.line,
                "message": self.message, "key": self.key}

    def render(self) -> str:
        return (f"{self.path}:{self.line}: {self.rule} "
                f"[{self.severity}] {self.message}")


def _pragma_lines(lines: Sequence[str]) -> Dict[int, Set[str]]:
    out: Dict[int, Set[str]] = {}
    for i, text in enumerate(lines, start=1):
        m = _PRAGMA_RE.search(text)
        if m:
            out[i] = {t.strip() for t in m.group(1).split(",") if t.strip()}
    return out


class Module:
    """One parsed source file, handed to every rule."""

    def __init__(self, source: str, path: str = "<string>"):
        self.source = source
        self.path = str(path).replace("\\", "/")
        self.tree = ast.parse(source)
        self.lines = source.splitlines()
        self.pragmas = _pragma_lines(self.lines)

    def allowed(self, rule_id: str, line: int) -> bool:
        """A pragma suppresses findings on its own line and, when it sits
        on a standalone comment line, on the line below it."""
        for ln in (line, line - 1):
            if rule_id in self.pragmas.get(ln, ()):
                return True
        return False


class Rule:
    """Base rule: subclasses set ``id``/``severity``/``title`` and yield
    findings from ``check``."""

    id = "CFN000"
    severity = "error"
    title = ""

    def check(self, mod: Module) -> Iterable[Finding]:
        raise NotImplementedError

    def finding(self, mod: Module, node, message: str,
                severity: Optional[str] = None) -> Finding:
        line = node if isinstance(node, int) else getattr(node, "lineno", 0)
        return Finding(rule=self.id, severity=severity or self.severity,
                       path=mod.path, line=line, message=message)


def _default_rules() -> List[Rule]:
    from . import rules
    return rules.all_rules()


def analyze_source(source: str, path: str = "<string>",
                   rules: Optional[Sequence[Rule]] = None) -> List[Finding]:
    """Run the rule catalog over one source string.  Pragma-suppressed
    findings are dropped here; baseline suppression is the caller's
    (``apply_baseline``)."""
    mod = Module(source, path=path)
    out: List[Finding] = []
    for rule in (rules if rules is not None else _default_rules()):
        for f in rule.check(mod):
            if not mod.allowed(f.rule, f.line):
                out.append(f)
    return sorted(out, key=lambda f: (f.path, f.line, f.rule))


def iter_python_files(paths: Sequence[str]) -> Iterator[Path]:
    for p in paths:
        path = Path(p)
        if path.is_dir():
            yield from sorted(path.rglob("*.py"))
        elif path.suffix == ".py":
            yield path


def analyze_paths(paths: Sequence[str],
                  rules: Optional[Sequence[Rule]] = None) -> List[Finding]:
    findings: List[Finding] = []
    for f in iter_python_files(paths):
        try:
            src = f.read_text()
            findings.extend(analyze_source(src, path=str(f), rules=rules))
        except SyntaxError as e:
            findings.append(Finding(
                rule="E999", severity="error",
                path=str(f).replace("\\", "/"), line=e.lineno or 0,
                message=f"syntax error: {e.msg}"))
    return sorted(findings, key=lambda f: (f.path, f.line, f.rule))


# -- baseline ---------------------------------------------------------------

def baseline_payload(findings: Sequence[Finding]) -> dict:
    return {"version": BASELINE_VERSION,
            "suppressions": sorted({f.key for f in findings})}


def load_baseline(path: str) -> Set[str]:
    data = json.loads(Path(path).read_text())
    if data.get("version") != BASELINE_VERSION:
        raise ValueError(f"baseline {path}: unsupported version "
                         f"{data.get('version')!r}")
    return set(data.get("suppressions", ()))


def apply_baseline(findings: Sequence[Finding], baseline: Set[str]
                   ) -> List[Finding]:
    """Findings NOT covered by the baseline (the ones that fail CI)."""
    return [f for f in findings if f.key not in baseline]
