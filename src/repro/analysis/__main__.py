"""CLI: ``python -m repro.analysis [--baseline FILE] [--format text|json]
[--changed [REF]] [paths...]``.  Exit 0 when every finding is suppressed
(pragma or baseline), 1 otherwise."""
from __future__ import annotations

import argparse
import json
import subprocess
import sys
from pathlib import Path

from . import engine


def _changed_files(ref: str) -> set:
    """Paths touched vs ``ref`` (diff + untracked), repo-relative."""
    out = subprocess.run(
        ["git", "diff", "--name-only", ref],
        capture_output=True, text=True, check=True).stdout
    untracked = subprocess.run(
        ["git", "ls-files", "--others", "--exclude-standard"],
        capture_output=True, text=True, check=True).stdout
    return {ln.strip() for ln in (out + untracked).splitlines() if ln.strip()}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="tracelint: JAX/Pallas compile-stability, numerics, and "
                    "dataflow static analysis (rules CFN101-CFN109; see "
                    "docs/ANALYSIS.md)")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to analyze (default: src)")
    ap.add_argument("--baseline", metavar="FILE",
                    help="JSON baseline of accepted findings "
                         "(analysis/baseline.json)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--write-baseline", metavar="FILE",
                    help="write the current findings as a new baseline "
                         "and exit 0")
    ap.add_argument("--changed", metavar="REF", nargs="?", const="HEAD",
                    default=None,
                    help="report only findings in files changed vs REF "
                         "(default HEAD); unchanged files still feed "
                         "cross-module context")
    args = ap.parse_args(argv)

    only = None
    if args.changed is not None:
        try:
            changed = _changed_files(args.changed)
        except (OSError, subprocess.CalledProcessError) as e:
            print(f"error: --changed {args.changed}: {e}", file=sys.stderr)
            return 2
        # restrict REPORTING to changed .py files under the given paths;
        # the full path set still loads so interprocedural facts survive
        roots = [Path(p) for p in args.paths]
        only = set()
        for c in changed:
            p = Path(c)
            if p.suffix != ".py":
                continue
            if any(p == r or r in p.parents for r in roots):
                only.add(str(p))

    findings = engine.analyze_paths(args.paths, only=only)

    if args.write_baseline:
        payload = engine.baseline_payload(findings)
        Path(args.write_baseline).write_text(
            json.dumps(payload, indent=2, sort_keys=True) + "\n")
        print(f"wrote {len(payload['suppressions'])} suppression(s) to "
              f"{args.write_baseline}")
        return 0

    baseline = (engine.load_baseline(args.baseline)
                if args.baseline else set())
    fresh = engine.apply_baseline(findings, baseline)
    n_suppressed = len(findings) - len(fresh)

    if args.format == "json":
        print(json.dumps({
            "findings": [f.to_dict() for f in fresh],
            "suppressed": n_suppressed,
            "total": len(findings),
        }, indent=2))
    else:
        for f in fresh:
            print(f.render())
        summary = (f"{len(fresh)} finding(s)"
                   + (f", {n_suppressed} baselined" if n_suppressed else ""))
        print(("FAIL: " if fresh else "OK: ") + summary)
    return 1 if fresh else 0


if __name__ == "__main__":
    sys.exit(main())
