"""CLI: ``python -m repro.analysis [--baseline FILE] [--format text|json]
[paths...]``.  Exit 0 when every finding is suppressed (pragma or
baseline), 1 otherwise."""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from . import engine


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="tracelint: JAX/Pallas compile-stability and numerics "
                    "static analysis (rules CFN101-CFN105; see "
                    "docs/ANALYSIS.md)")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to analyze (default: src)")
    ap.add_argument("--baseline", metavar="FILE",
                    help="JSON baseline of accepted findings "
                         "(analysis/baseline.json)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--write-baseline", metavar="FILE",
                    help="write the current findings as a new baseline "
                         "and exit 0")
    args = ap.parse_args(argv)

    findings = engine.analyze_paths(args.paths)

    if args.write_baseline:
        payload = engine.baseline_payload(findings)
        Path(args.write_baseline).write_text(
            json.dumps(payload, indent=2, sort_keys=True) + "\n")
        print(f"wrote {len(payload['suppressions'])} suppression(s) to "
              f"{args.write_baseline}")
        return 0

    baseline = (engine.load_baseline(args.baseline)
                if args.baseline else set())
    fresh = engine.apply_baseline(findings, baseline)
    n_suppressed = len(findings) - len(fresh)

    if args.format == "json":
        print(json.dumps({
            "findings": [f.to_dict() for f in fresh],
            "suppressed": n_suppressed,
            "total": len(findings),
        }, indent=2))
    else:
        for f in fresh:
            print(f.render())
        summary = (f"{len(fresh)} finding(s)"
                   + (f", {n_suppressed} baselined" if n_suppressed else ""))
        print(("FAIL: " if fresh else "OK: ") + summary)
    return 1 if fresh else 0


if __name__ == "__main__":
    sys.exit(main())
