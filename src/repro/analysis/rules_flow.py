"""Flow-sensitive tracelint rules (CFN106-CFN109) over ``dataflow``.

These are ``ProjectRule``s: one shared dataflow run per analysis
(``dataflow.analyze_dataflow``, memoized on the Project) feeds all four
families, and findings land on whichever module/line they belong to.

  CFN106  PRNG-key discipline -- a key consumed by two draws, a key
          defined outside a loop consumed inside it without a
          per-iteration split, a split output silently dropped.
  CFN107  donation & aliasing -- args at ``donate_argnums`` slots read
          (or written, incl. ``.at[].set`` / subscript stores) after the
          jitted call, and a donated buffer aliased by another argument
          slot of the same call.
  CFN108  compile-cache cardinality -- the statically bounded jit-cache
          key-space of every ``@count_traces`` entry; unbounded
          provenance reaching an entry, or a bound above the declared
          cap, is a finding.  ``compute_cache_bounds`` is the public API
          the runtime contract test cross-checks against TRACE_COUNTS.
  CFN109  dead device compute -- device arrays computed and never
          consumed (the ``np.asarray(st.X)`` bug class of PR 7).

Findings deliberately carry NO line numbers in their messages: the
baseline fingerprint is ``rule::context::message`` and must survive both
line shifts and a function moving across files.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from .engine import Finding, Project, ProjectRule
from .dataflow import CacheAxis, EntryCall, analyze_dataflow

# ---------------------------------------------------------------------------
# CFN106: PRNG-key discipline
# ---------------------------------------------------------------------------

class PrngKeyDiscipline(ProjectRule):
    """Every ``jax.random`` draw must own its key.

    Three defects: (1) one key definition consumed by two or more draws
    (correlated streams -- the paper's Metropolis acceptance must be
    independent of its proposal stream); (2) a key defined outside a
    loop consumed inside it with no per-iteration ``split`` and no
    reassignment of the key in the loop body (every iteration replays
    the same stream); (3) a ``split`` output that is never read (a
    silently dropped stream -- usually a refactoring leftover).
    ``fold_in`` derives an independent stream without consuming its
    argument, so ``uniform(fold_in(k, 1))`` after ``randint(k, ...)``
    is the sanctioned two-stream idiom.  Consumption is counted
    path-insensitively: two branch-exclusive draws from one key are
    still flagged, because nothing ties the branches' streams apart.
    """

    id = "CFN106"
    title = "PRNG-key discipline"

    def check_project(self, project: Project) -> Iterable[Finding]:
        an = analyze_dataflow(project)
        for key in sorted(an.functions):
            facts = an.functions[key]
            mod = project.by_path.get(facts.path)
            if mod is None:
                continue
            # (1) multi-consumption of one definition (a merged binding --
            # `k = PRNGKey(0) if k is None else k` -- holds several def
            # sites, so dedupe by rendered message and line)
            emitted: Set[Tuple] = set()
            for site in sorted(facts.consumes,
                               key=lambda s: (s[1], s[2])):
                uses = facts.consumes[site]
                distinct = sorted({(u.line, u.col) for u in uses})
                if len(distinct) < 2:
                    continue
                var = uses[0].var
                hows = ", ".join(sorted({u.how for u in uses}))
                msg = (f"PRNG key `{var}` is consumed by {len(distinct)} "
                       f"draws ({hows}); split it (or fold_in) so every "
                       "draw owns an independent stream")
                if (distinct[1][0], msg) in emitted:
                    continue
                emitted.add((distinct[1][0], msg))
                yield self.finding(mod, distinct[1][0], msg)
            # (2) loop fan-out without a per-iteration split
            seen: Set[Tuple] = set()
            for site in sorted(facts.consumes,
                               key=lambda s: (s[1], s[2])):
                def_loops = facts.site_loops.get(site, frozenset())
                for u in facts.consumes[site]:
                    for loop_id in sorted(set(u.loops) - set(def_loops)):
                        stores = facts.loop_stores.get(loop_id, set())
                        if u.var in stores:
                            continue   # carry idiom: key, k = split(key)
                        k = (site, loop_id)
                        if k in seen:
                            continue
                        seen.add(k)
                        yield self.finding(
                            mod, u.line,
                            f"PRNG key `{u.var}` defined outside the loop "
                            "is consumed inside it without a per-iteration "
                            "split (every iteration replays the same "
                            "stream)")
            # (3) dropped split outputs
            for line, names, _loops in facts.split_assigns:
                for nm in names:
                    if nm.startswith("_") or nm == "<unpack>":
                        continue
                    if nm not in facts.loads:
                        yield self.finding(
                            mod, line,
                            f"split output `{nm}` is never used (a "
                            f"dropped stream; rename it to `_{nm}` if "
                            "that is intentional)")


# ---------------------------------------------------------------------------
# CFN107: donation & aliasing
# ---------------------------------------------------------------------------

class DonationDiscipline(ProjectRule):
    """``donate_argnums`` invalidates the caller's buffer: any later read
    (or subscript / ``.at[].set`` write) of a name still bound to the
    donated value is a use-after-free on the device, and passing the
    same buffer to a donated slot AND another slot of one call aliases
    input and output storage.  Rebinding (``x = step(x)``) is the clean
    idiom and is not flagged -- the new binding is a new definition."""

    id = "CFN107"
    title = "donation & aliasing"

    def check_project(self, project: Project) -> Iterable[Finding]:
        an = analyze_dataflow(project)
        for key in sorted(an.functions):
            facts = an.functions[key]
            mod = project.by_path.get(facts.path)
            if mod is None:
                continue
            seen: Set[Tuple] = set()
            for ev in facts.donation_events:
                k = (ev.kind, ev.var, ev.entry, ev.line)
                if k in seen:
                    continue
                seen.add(k)
                if ev.kind == "alias":
                    yield self.finding(
                        mod, ev.line,
                        f"`{ev.var}` is passed both to a donated slot of "
                        f"`{ev.entry}` and to another argument slot of the "
                        "same call (the donated buffer aliases a live "
                        "input)")
                else:
                    yield self.finding(
                        mod, ev.line,
                        f"`{ev.var}` is used after being donated to "
                        f"`{ev.entry}` (donate_argnums invalidates the "
                        "buffer; rebind the result instead)")


# ---------------------------------------------------------------------------
# CFN108: compile-cache cardinality
# ---------------------------------------------------------------------------

# Declared per-entry jit-cache caps: how many distinct cache keys the
# shape-bucket discipline is allowed to produce for each @count_traces
# entry at the documented deployment scale.  The runtime contract test
# (tests/test_cache_contract.py) cross-checks the static bound against
# measured TRACE_COUNTS.
CACHE_CAPS: Dict[str, int] = {
    "sweep": 64,
    "anneal_delta": 64,
    "anneal_full": 32,
    "solve_regions": 32,
}
DEFAULT_CACHE_CAP = 64

# default axis cardinalities for the STATIC bound: a pow-2 bucket axis
# can realize at most ~log2(R*V) distinct buckets at the documented max
# scale; a param axis is one compile per caller-supplied shape family.
STATIC_BUCKET_CARD = 8
STATIC_PARAM_CARD = 1


@dataclasses.dataclass
class EntryBound:
    """Static jit-cache key-space of one ``@count_traces`` entry.

    ``sites`` are its project-wide call sites; each carries the cache
    axes (provenance roots) of the values reaching the entry there.
    The bound is the sum over call sites of the product of axis
    cardinalities -- ``evaluate`` lets a runtime scenario substitute
    realized cardinalities (and drop unexercised sites) to compare
    against measured TRACE_COUNTS."""

    entry: str
    sites: List[EntryCall] = dataclasses.field(default_factory=list)

    def axes(self) -> Dict[str, CacheAxis]:
        out: Dict[str, CacheAxis] = {}
        for s in self.sites:
            for ax in s.axes:
                out.setdefault(ax.name, ax)
        return out

    @staticmethod
    def _card(ax: CacheAxis, axis_cards: Optional[Dict[str, int]],
              default_bucket: int, default_param: int) -> Optional[int]:
        if axis_cards and ax.name in axis_cards:
            return axis_cards[ax.name]
        if ax.kind == "finite":
            return ax.card
        if ax.kind == "param":
            return default_param
        if ax.kind == "bucket":
            return default_bucket
        if ax.kind == "unbounded":
            return None
        return 1

    def evaluate(self, sites: Optional[Sequence[str]] = None,
                 axis_cards: Optional[Dict[str, int]] = None,
                 default_bucket: int = 1,
                 default_param: int = 1) -> Optional[int]:
        """Bound under a scenario: ``sites`` restricts to call sites in
        the named enclosing functions (None = all); ``axis_cards`` maps
        axis names to realized cardinalities.  Returns None when an
        included axis is statically unbounded and not overridden."""
        total = 0
        for s in self.sites:
            if sites is not None and s.context not in sites:
                continue
            prod = 1
            for ax in s.axes:
                c = self._card(ax, axis_cards, default_bucket,
                               default_param)
                if c is None:
                    return None
                prod *= max(int(c), 1)
            total += prod
        return total

    def static_bound(self) -> Optional[int]:
        return self.evaluate(default_bucket=STATIC_BUCKET_CARD,
                             default_param=STATIC_PARAM_CARD)


def compute_cache_bounds(project: Project) -> Dict[str, EntryBound]:
    """Per-entry static jit-cache bounds over the whole project (the
    CFN108 substrate and the contract-test API)."""
    an = analyze_dataflow(project)
    out: Dict[str, EntryBound] = {
        name: EntryBound(name) for name in an.index.entry_defs}
    for c in an.entry_calls:
        out.setdefault(c.entry, EntryBound(c.entry)).sites.append(c)
    for eb in out.values():
        eb.sites.sort(key=lambda s: (s.path, s.line))
    return out


class CacheCardinality(ProjectRule):
    """Every ``@count_traces`` entry must have a statically BOUNDED
    jit-cache key-space under the declared caps: a value of unbounded
    provenance (I/O, wall clock, unresolved call with no rooted inputs)
    reaching an entry's static or shape-determining slots means every
    new value is a fresh compile -- exactly the regression the
    TRACE_COUNTS assertions exist to catch, caught at PR time."""

    id = "CFN108"
    title = "compile-cache cardinality"

    def check_project(self, project: Project) -> Iterable[Finding]:
        bounds = compute_cache_bounds(project)
        an = analyze_dataflow(project)
        for entry in sorted(bounds):
            eb = bounds[entry]
            unbounded = False
            for site in eb.sites:
                mod = project.by_path.get(site.path)
                if mod is None:
                    continue
                for ax in site.axes:
                    if ax.kind != "unbounded":
                        continue
                    unbounded = True
                    root = ax.name.split("@")[0]
                    slot = "a static arg slot" if ax.static \
                        else "a shape-determining slot"
                    yield self.finding(
                        mod, site.line,
                        f"entry `{entry}`: value of statically unbounded "
                        f"provenance ({root}) reaches {slot} of the "
                        "jitted call -- its jit-cache key-space is "
                        "unbounded (every new value is a fresh compile)")
            if unbounded:
                continue
            b = eb.static_bound()
            cap = CACHE_CAPS.get(entry, DEFAULT_CACHE_CAP)
            if b is not None and b > cap:
                ed = an.index.entry_defs.get(entry)
                if ed is None:
                    continue
                yield self.finding(
                    ed.mod, ed.fn.lineno,
                    f"entry `{entry}`: static jit-cache bound {b} exceeds "
                    f"the declared cap {cap} (tighten the shape bucketing "
                    "or raise CACHE_CAPS with justification)")


# ---------------------------------------------------------------------------
# CFN109: dead device compute
# ---------------------------------------------------------------------------

class DeadDeviceCompute(ProjectRule):
    """A device-producing call assigned to a name that is never read is
    wasted device compute -- and for ``np.asarray``/``np.array`` on
    device values, a dead device->host transfer that blocks the
    dispatch stream (the exact bug PR 7 had to find by hand).  Names
    prefixed ``_`` are exempt (the documented discard idiom)."""

    id = "CFN109"
    title = "dead device compute"

    def check_project(self, project: Project) -> Iterable[Finding]:
        an = analyze_dataflow(project)
        for key in sorted(an.functions):
            facts = an.functions[key]
            mod = project.by_path.get(facts.path)
            if mod is None:
                continue
            for line, name, call in sorted(facts.dead_assigns):
                yield self.finding(
                    mod, line,
                    f"device array `{name}` ({call}) is computed but "
                    "never consumed (dead compute / dead transfer; "
                    f"delete it or rename to `_{name}`)")


def flow_rules() -> List[ProjectRule]:
    return [PrngKeyDiscipline(), DonationDiscipline(), CacheCardinality(),
            DeadDeviceCompute()]
