"""Flow-sensitive, interprocedural dataflow core for tracelint v2.

The syntactic rules (CFN101-CFN105, ``rules.py``) see one module and one
statement at a time; the CFN106-CFN109 families (``rules_flow.py``) need
*values flowing between statements and functions*: which PRNG key a draw
consumes, whether a donated buffer is read after the donating call,
which static/shape-determining values reach a jitted entry.  This module
supplies that machinery:

  * ``ProjectIndex`` -- function tables per module (methods and nested
    defs included), import resolution (absolute and relative), and call
    resolution for bare names, ``module.fn`` attributes and
    ``self.method`` calls.
  * ``FlowWalker`` -- an abstract interpreter over one function body.
    The environment maps variable names (including ``self.attr``
    pseudo-variables) to abstract values: a set of *definition sites*
    (for def-use chains: reassignment kills, aliases share) and a set of
    *provenance atoms* (a small lattice: const < finite(k) < param <
    bucket < opaque) used to bound jit-cache key-spaces.  ``if``/``else``
    forks the environment and merges by union; loop bodies are walked
    once with the loop span recorded on every def and use, which is
    enough to detect "key defined outside the loop, consumed inside it"
    while admitting the carry idiom (``key, k = split(key)`` -- the
    consumed name is re-stored in the body).
  * function summaries, computed to fixpoint over the project call
    graph: which parameters a function consumes as PRNG keys (so a call
    ``f(kp)`` counts as one consumption of ``kp`` at the call site).
  * per-entry jit-cache records: every call site of a ``@count_traces``
    entry, with the provenance-derived cache axes of its arguments
    (``compute_cache_bounds`` in ``rules_flow`` builds on these).

Scope and limits (documented in docs/ANALYSIS.md): calls through
variables bound to transformed functions (``g = jax.jit(f); g(k)``) are
resolved for *donation wrappers* and *entries* (assignment forms are
indexed) but not for arbitrary key-consuming closures; containers of
keys (``keys[i]``) are tracked only through direct iteration of a
``split`` result; exceptional control flow is assumed to fall through.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, FrozenSet, Iterable, List, Optional, Sequence, Set, Tuple

from .engine import Module, Project, module_name
from .rules import (_dotted, _is_count_traces_decorator, _is_jit_decorator,
                    _unwrap_to_names)

# ---------------------------------------------------------------------------
# vocabulary
# ---------------------------------------------------------------------------

# jax.random draws: each CONSUMES the key passed first (arg 0)
_DRAW_FNS = {
    "split", "uniform", "normal", "randint", "bernoulli", "choice",
    "permutation", "shuffle", "categorical", "gumbel", "exponential",
    "truncated_normal", "beta", "gamma", "dirichlet", "poisson", "laplace",
    "cauchy", "logistic", "multivariate_normal", "rademacher", "bits",
    "orthogonal", "t", "loggamma", "binomial", "geometric", "rayleigh",
    "weibull_min", "chisquare", "f", "wald", "triangular", "ball",
}
# derive a NEW independent key without consuming the argument
_KEY_DERIVERS = {"fold_in", "PRNGKey", "key", "clone", "wrap_key_data"}

# shape-bucketing helpers: results take finitely many values (the pow-2
# bucket policy), so a bucketed value feeding a jit entry is a bounded
# cache axis, not an unbounded one
_BUCKET_FNS = {"_pow2", "_pad_positions", "_pad_links", "_bucket_rows",
               "pow2", "next_pow2", "bucket"}

# calls whose results inherit their arguments' provenance even when we
# cannot resolve the callee (pure array/math/builtin surface); an
# UNRESOLVED call with no rooted argument and not on this surface is
# opaque -- the "unbounded" end of the lattice
_PURE_PREFIXES = ("jnp.", "jax.", "np.", "numpy.", "onp.", "lax.", "math.",
                  "functools.")
_PURE_BARE = {
    "len", "int", "float", "bool", "str", "abs", "min", "max", "sum",
    "round", "sorted", "list", "tuple", "set", "dict", "frozenset",
    "range", "enumerate", "zip", "map", "filter", "reversed", "getattr",
    "hasattr", "isinstance", "print", "repr", "divmod", "pow", "any",
    "all", "slice", "iter", "next", "vars", "id", "type", "format",
}

# assignments of these calls to a never-read name are dead device compute
# (CFN109): the PR 7 `np.asarray(st.X)` bug class
_DEVICE_PREFIXES = ("jnp.", "jax.numpy.", "jax.random.", "jax.lax.", "lax.",
                    "jax.nn.", "jax.scipy.")
_DEVICE_EXACT = {"np.asarray", "np.array", "numpy.asarray", "numpy.array",
                 "onp.asarray", "onp.array", "jax.device_put",
                 "jax.device_get"}


def _is_draw(dotted: Optional[str]) -> bool:
    if not dotted:
        return False
    parts = dotted.split(".")
    return parts[-1] in _DRAW_FNS and (
        len(parts) >= 2 and parts[-2] in ("random", "jr")
        or parts[0] == "random")


def _is_key_deriver(dotted: Optional[str]) -> bool:
    if not dotted:
        return False
    parts = dotted.split(".")
    return parts[-1] in _KEY_DERIVERS and (
        len(parts) == 1 or parts[-2] in ("random", "jr", "jax")
        or parts[0] == "random")


def _is_split(dotted: Optional[str]) -> bool:
    return _is_draw(dotted) and dotted.split(".")[-1] == "split"


# ---------------------------------------------------------------------------
# abstract values
# ---------------------------------------------------------------------------

# a definition site: (module_path, line, distinguishing_token)
DefSite = Tuple[str, int, str]

# provenance atoms (the CFN108 lattice):
#   ("const",)                 literal / module constant           card 1
#   ("finite", name, k)        one of k literal options            card k
#   ("param", name)            rooted at a caller-supplied value   card "per scenario"
#   ("bucket", name)           through the pow-2 bucket policy     card #buckets
#   ("opaque", name)           unknown origin                      unbounded
Atom = Tuple


@dataclasses.dataclass(frozen=True)
class Val:
    defs: FrozenSet[DefSite] = frozenset()
    prov: FrozenSet[Atom] = frozenset()

    @staticmethod
    def merge(vals: Iterable["Val"]) -> "Val":
        defs: Set[DefSite] = set()
        prov: Set[Atom] = set()
        for v in vals:
            defs |= v.defs
            prov |= v.prov
        return Val(frozenset(defs), frozenset(prov))


CONST = Val(prov=frozenset({("const",)}))


# ---------------------------------------------------------------------------
# per-function facts
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class KeyDef:
    site: DefSite
    var: str
    line: int
    loops: FrozenSet[int]      # lines of enclosing loops at the def
    kind: str                  # "prngkey" | "split" | "derive" | "param"


@dataclasses.dataclass
class Consume:
    site: DefSite              # the def being consumed
    var: str                   # name it was reached through
    line: int
    col: int
    loops: FrozenSet[int]      # lines of enclosing loops at the use
    how: str                   # "jax.random.uniform" | "call:anneal" | ...


@dataclasses.dataclass(frozen=True)
class CacheAxis:
    name: str                  # "resolve_incremental.pad_changed_to"
    kind: str                  # "const" | "finite" | "param" | "bucket" | "unbounded"
    card: Optional[int]        # finite k; None otherwise
    static: bool = False       # reaches a static_argnums/static_argnames slot


@dataclasses.dataclass
class EntryCall:
    entry: str                 # TRACE_COUNTS name
    path: str
    context: str               # caller qualname
    line: int
    axes: Tuple[CacheAxis, ...]


@dataclasses.dataclass
class DonationEvent:
    kind: str                  # "read-after-donate" | "alias"
    var: str
    entry: str                 # wrapper name
    donate_line: int
    line: int                  # the offending read / the aliasing call


@dataclasses.dataclass
class FuncFacts:
    qual: str
    path: str
    line: int
    params: List[str] = dataclasses.field(default_factory=list)
    key_defs: Dict[DefSite, KeyDef] = dataclasses.field(default_factory=dict)
    consumes: Dict[DefSite, List[Consume]] = dataclasses.field(
        default_factory=dict)
    consumed_params: Set[str] = dataclasses.field(default_factory=set)
    # (line, [target names], loops) of every tuple-unpacked split
    split_assigns: List[Tuple[int, List[str], FrozenSet[int]]] = \
        dataclasses.field(default_factory=list)
    loop_stores: Dict[int, Set[str]] = dataclasses.field(default_factory=dict)
    # loop context of EVERY definition site (anonymous call results too):
    # the loop-fan-out check needs to know a def was born inside the loop
    site_loops: Dict[DefSite, FrozenSet[int]] = dataclasses.field(
        default_factory=dict)
    loads: Set[str] = dataclasses.field(default_factory=set)
    dead_assigns: List[Tuple[int, str, str]] = dataclasses.field(
        default_factory=list)
    donation_events: List[DonationEvent] = dataclasses.field(
        default_factory=list)
    entry_calls: List[EntryCall] = dataclasses.field(default_factory=list)


# ---------------------------------------------------------------------------
# project index: functions, imports, entries, donation wrappers
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FuncInfo:
    mod: Module
    node: ast.AST              # FunctionDef / AsyncFunctionDef
    qual: str
    class_name: Optional[str]

    @property
    def params(self) -> List[str]:
        a = self.node.args
        return [x.arg for x in
                list(getattr(a, "posonlyargs", [])) + list(a.args)]

    @property
    def kwonly(self) -> List[str]:
        return [x.arg for x in self.node.args.kwonlyargs]


@dataclasses.dataclass
class EntryDef:
    name: str                  # the count_traces literal
    mod: Module
    fn: ast.AST                # the wrapped (impl) FunctionDef
    callables: Set[str]        # names that invoke it in the defining module
    static_names: Set[str]


@dataclasses.dataclass
class DonationWrapper:
    name: str                  # callable name in the defining module
    mod: Module
    donate: Tuple[int, ...]    # donated positional indices
    fn: Optional[ast.AST]      # wrapped FunctionDef when local


def _static_names_from_jit(call: ast.Call,
                           fn: Optional[ast.AST]) -> Set[str]:
    """Param names keyed statically by a ``jax.jit(...)`` call node."""
    names: Set[str] = set()
    nums: List[int] = []
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            for n in ast.walk(kw.value):
                if isinstance(n, ast.Constant) and isinstance(n.value, str):
                    names.add(n.value)
        elif kw.arg == "static_argnums":
            for n in ast.walk(kw.value):
                if isinstance(n, ast.Constant) and isinstance(n.value, int):
                    nums.append(n.value)
    if nums and fn is not None:
        params = FuncInfo(None, fn, fn.name, None).params
        for i in nums:
            if 0 <= i < len(params):
                names.add(params[i])
    return names


def _donate_nums(call: ast.Call) -> Tuple[int, ...]:
    for kw in call.keywords:
        if kw.arg in ("donate_argnums", "donate_argnames"):
            return tuple(n.value for n in ast.walk(kw.value)
                         if isinstance(n, ast.Constant)
                         and isinstance(n.value, int))
    return ()


class ProjectIndex:
    """Name resolution over the whole project (the call graph substrate)."""

    def __init__(self, project: Project):
        self.project = project
        self.funcs: Dict[Tuple[str, str], FuncInfo] = {}   # (path, qual)
        self.bare: Dict[str, Dict[str, FuncInfo]] = {}     # path -> name -> fi
        self.methods: Dict[str, Dict[str, Dict[str, FuncInfo]]] = {}
        self.imports: Dict[str, Dict[str, Tuple]] = {}     # path -> alias -> ..
        self.const_dicts: Dict[str, Dict[str, int]] = {}   # path -> name -> len
        self.entries: Dict[str, Dict[str, EntryDef]] = {}  # path -> callable ->
        self.entry_defs: Dict[str, EntryDef] = {}          # entry name -> def
        self.donations: Dict[str, Dict[str, DonationWrapper]] = {}
        for m in project.modules:
            self._index_module(m)

    # -- per-module tables --------------------------------------------------

    def _index_module(self, mod: Module) -> None:
        p = mod.path
        self.bare[p] = {}
        self.methods[p] = {}
        self.imports[p] = self._imports(mod)
        self.const_dicts[p] = {}
        self.entries[p] = {}
        self.donations[p] = {}
        self._index_defs(mod, mod.tree, (), None)
        for node in mod.tree.body:
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name) \
                    and isinstance(node.value, ast.Dict):
                self.const_dicts[p][node.targets[0].id] = \
                    len(node.value.keys)
        self._index_entries(mod)
        self._index_donations(mod)

    def _index_defs(self, mod: Module, node: ast.AST, stack: tuple,
                    class_name: Optional[str]) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qual = ".".join(stack + (child.name,))
                fi = FuncInfo(mod, child, qual, class_name)
                self.funcs[(mod.path, qual)] = fi
                if class_name is None:
                    # bare-name reachable (module-level and nested defs);
                    # first (outermost) definition wins
                    self.bare[mod.path].setdefault(child.name, fi)
                else:
                    self.methods[mod.path].setdefault(class_name, {})
                    self.methods[mod.path][class_name][child.name] = fi
                self._index_defs(mod, child, stack + (child.name,),
                                 class_name)
            elif isinstance(child, ast.ClassDef):
                self._index_defs(mod, child, stack + (child.name,),
                                 child.name)
            else:
                self._index_defs(mod, child, stack, class_name)

    def _imports(self, mod: Module) -> Dict[str, Tuple]:
        out: Dict[str, Tuple] = {}
        base = (module_name(mod.path) or "").split(".")
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    if a.asname:
                        out[a.asname] = ("mod", a.name)
                    else:
                        out[a.name.split(".")[0]] = \
                            ("mod", a.name.split(".")[0])
            elif isinstance(node, ast.ImportFrom):
                if node.level:
                    parent = base[:-node.level] if node.level <= len(base) \
                        else []
                    target = ".".join(parent + ([node.module]
                                                if node.module else []))
                else:
                    target = node.module or ""
                for a in node.names:
                    out[a.asname or a.name] = ("attr", target, a.name)
        return out

    def _index_entries(self, mod: Module) -> None:
        top = {n.name: n for n in mod.tree.body
               if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
        for fn in top.values():
            ct = next((d for d in fn.decorator_list
                       if _is_count_traces_decorator(d)), None)
            if ct is None or not any(_is_jit_decorator(d)
                                     for d in fn.decorator_list):
                continue
            name = (ct.args[0].value if ct.args
                    and isinstance(ct.args[0], ast.Constant) else fn.name)
            static: Set[str] = set()
            for d in fn.decorator_list:
                if isinstance(d, ast.Call) and _is_jit_decorator(d):
                    static |= _static_names_from_jit(d, fn)
            e = EntryDef(name, mod, fn, {fn.name}, static)
            self.entries[mod.path][fn.name] = e
            self.entry_defs.setdefault(name, e)
        for node in mod.tree.body:
            if not (isinstance(node, ast.Assign) and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)
                    and isinstance(node.value, ast.Call)
                    and _is_jit_decorator(node.value)
                    and node.value.args):
                continue
            wrapped = _unwrap_to_names(node.value.args[0])
            fn = top.get(wrapped[0]) if wrapped else None
            if fn is None:
                continue
            ct = next((d for d in fn.decorator_list
                       if _is_count_traces_decorator(d)), None)
            if ct is None:
                continue
            name = (ct.args[0].value if ct.args
                    and isinstance(ct.args[0], ast.Constant) else fn.name)
            wname = node.targets[0].id
            e = self.entry_defs.get(name)
            if e is None or e.fn is not fn:
                e = EntryDef(name, mod, fn, set(), set())
                self.entry_defs.setdefault(name, e)
            e = self.entry_defs[name]
            e.callables.add(wname)
            e.static_names |= _static_names_from_jit(node.value, fn)
            self.entries[mod.path][wname] = e

    def _index_donations(self, mod: Module) -> None:
        top = {n.name: n for n in mod.tree.body
               if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
        for node in mod.tree.body:
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name) \
                    and isinstance(node.value, ast.Call) \
                    and _is_jit_decorator(node.value):
                donate = _donate_nums(node.value)
                if donate:
                    wrapped = _unwrap_to_names(node.value.args[0]) \
                        if node.value.args else []
                    self.donations[mod.path][node.targets[0].id] = \
                        DonationWrapper(node.targets[0].id, mod, donate,
                                        top.get(wrapped[0])
                                        if wrapped else None)
        for fn in top.values():
            for d in fn.decorator_list:
                if isinstance(d, ast.Call) and _is_jit_decorator(d):
                    donate = _donate_nums(d)
                    if donate:
                        self.donations[mod.path][fn.name] = \
                            DonationWrapper(fn.name, mod, donate, fn)

    # -- resolution ---------------------------------------------------------

    def _module_for(self, mod: Module, head: str) -> Optional[Module]:
        imp = self.imports[mod.path].get(head)
        if imp is None:
            return None
        if imp[0] == "mod":
            return self.project.by_name.get(imp[1])
        target, attr = imp[1], imp[2]
        return self.project.by_name.get(f"{target}.{attr}")

    def resolve_func(self, mod: Module, dotted: Optional[str],
                     class_name: Optional[str] = None) -> Optional[FuncInfo]:
        if not dotted:
            return None
        parts = dotted.split(".")
        if len(parts) == 2 and parts[0] == "self" and class_name:
            return self.methods[mod.path].get(class_name, {}).get(parts[1])
        if len(parts) == 1:
            fi = self.bare[mod.path].get(parts[0])
            if fi is not None:
                return fi
            imp = self.imports[mod.path].get(parts[0])
            if imp and imp[0] == "attr":
                m = self.project.by_name.get(imp[1])
                if m is not None:
                    return self.bare[m.path].get(imp[2])
            return None
        if len(parts) == 2:
            m = self._module_for(mod, parts[0])
            if m is not None:
                return self.bare[m.path].get(parts[1])
        # fully-dotted module path: repro.core.solvers.anneal
        for i in range(len(parts) - 1, 0, -1):
            m = self.project.by_name.get(".".join(parts[:i]))
            if m is not None and i == len(parts) - 1:
                return self.bare[m.path].get(parts[-1])
        return None

    def resolve_entry(self, mod: Module,
                      dotted: Optional[str]) -> Optional[EntryDef]:
        if not dotted:
            return None
        parts = dotted.split(".")
        if len(parts) == 1:
            return self.entries[mod.path].get(parts[0])
        if len(parts) == 2:
            m = self._module_for(mod, parts[0])
            if m is not None:
                return self.entries[m.path].get(parts[1])
        return None

    def resolve_donation(self, mod: Module,
                         dotted: Optional[str]) -> Optional[DonationWrapper]:
        if not dotted:
            return None
        parts = dotted.split(".")
        if len(parts) == 1:
            return self.donations[mod.path].get(parts[0])
        if len(parts) == 2:
            m = self._module_for(mod, parts[0])
            if m is not None:
                return self.donations[m.path].get(parts[1])
        return None

    def resolve_const_dict(self, mod: Module,
                           dotted: Optional[str]) -> Optional[int]:
        if not dotted:
            return None
        parts = dotted.split(".")
        if len(parts) == 1:
            return self.const_dicts[mod.path].get(parts[0])
        if len(parts) == 2:
            m = self._module_for(mod, parts[0])
            if m is not None:
                return self.const_dicts[m.path].get(parts[1])
        return None


# ---------------------------------------------------------------------------
# the flow walker
# ---------------------------------------------------------------------------

def _target_name(node: ast.AST) -> Optional[str]:
    """Plain assignable name: ``x`` or the ``self.attr`` pseudo-variable."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute) and isinstance(node.value, ast.Name) \
            and node.value.id == "self":
        return f"self.{node.attr}"
    return None


def _stored_names(node: ast.AST) -> Set[str]:
    out: Set[str] = set()
    for n in ast.walk(node):
        if isinstance(n, (ast.Name, ast.Attribute)) \
                and isinstance(getattr(n, "ctx", None), ast.Store):
            t = _target_name(n)
            if t:
                out.add(t)
    return out


def _loaded_names(fn: ast.AST) -> Set[str]:
    """Every name (and ``self.attr``) read anywhere in ``fn``, nested
    scopes included -- the scope-wide liveness set for the split-unused
    and dead-compute checks."""
    out: Set[str] = set()
    for n in ast.walk(fn):
        if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load):
            out.add(n.id)
        elif isinstance(n, ast.Attribute) \
                and isinstance(n.ctx, ast.Load):
            t = _target_name(n)
            if t:
                out.add(t)
    return out


class FlowWalker:
    """Abstract interpretation of one function body (see module docstring)."""

    def __init__(self, analyzer: "Analyzer", fi: FuncInfo):
        self.an = analyzer
        self.fi = fi
        self.mod = fi.mod
        self.facts = FuncFacts(qual=fi.qual, path=fi.mod.path,
                               line=fi.node.lineno, params=fi.params)
        self.env: Dict[str, Val] = {}
        self.loops: Tuple[int, ...] = ()
        self.donated: Dict[DefSite, Tuple[str, int]] = {}  # -> (entry, line)
        self._fresh = 0
        self._param_sites: Dict[DefSite, str] = {}
        # defs killed on EVERY path through their consuming statement
        # (`key, k = split(key)`): later sightings are path-exclusive
        # merge artifacts, not double draws
        self._retired: Set[DefSite] = set()
        # single-target split results are ARRAYS of keys: indexing one
        # derives a per-index key (memoized per constant index, so
        # drawing from ks[0] twice is still a double consumption)
        self._split_arrays: Set[DefSite] = set()
        self._derived_idx: Dict[Tuple[DefSite, str], Val] = {}
        for p in fi.params + fi.kwonly:
            site = (fi.mod.path, fi.node.lineno, f"param:{p}")
            self._param_sites[site] = p
            self.env[p] = Val(frozenset({site}),
                              frozenset({("param", f"{fi.qual}.{p}")}))

    # -- plumbing -----------------------------------------------------------

    def _site(self, line: int, token: str) -> DefSite:
        self._fresh += 1
        site = (self.mod.path, line, f"{token}#{self._fresh}")
        self.facts.site_loops[site] = frozenset(self.loops)
        return site

    def _bind(self, name: str, val: Val) -> None:
        self.env[name] = val

    def _consume(self, val: Val, node: ast.AST, var: str, how: str) -> None:
        for site in val.defs:
            if site in self._retired:
                continue
            self.facts.consumes.setdefault(site, []).append(Consume(
                site=site, var=var, line=node.lineno, col=node.col_offset,
                loops=frozenset(self.loops), how=how))
            if site in self._param_sites:
                self.facts.consumed_params.add(self._param_sites[site])

    def _key_def(self, name: str, line: int, kind: str) -> Val:
        site = self._site(line, name)
        self.facts.key_defs[site] = KeyDef(
            site=site, var=name, line=line, loops=frozenset(self.loops),
            kind=kind)
        return Val(frozenset({site}), frozenset({("param",
                                                  f"{self.fi.qual}.{name}")}))

    # -- expressions --------------------------------------------------------

    def eval(self, node: Optional[ast.AST]) -> Val:
        if node is None or isinstance(node, ast.Constant):
            return CONST
        if isinstance(node, ast.Name):
            return self._eval_name(node)
        if isinstance(node, ast.Attribute):
            return self._eval_attr(node)
        if isinstance(node, ast.Call):
            return self._eval_call(node)
        if isinstance(node, ast.Subscript):
            return self._eval_subscript(node)
        if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
            return Val.merge([self.eval(e) for e in node.elts])
        if isinstance(node, ast.Dict):
            return Val.merge([self.eval(e) for e in
                              list(node.keys) + list(node.values)
                              if e is not None])
        if isinstance(node, ast.IfExp):
            self.eval(node.test)
            return Val.merge([self.eval(node.body), self.eval(node.orelse)])
        if isinstance(node, ast.BoolOp):
            return Val.merge([self.eval(v) for v in node.values])
        if isinstance(node, ast.BinOp):
            return Val.merge([self.eval(node.left), self.eval(node.right)])
        if isinstance(node, ast.UnaryOp):
            return self.eval(node.operand)
        if isinstance(node, ast.Compare):
            return Val.merge([self.eval(node.left)]
                             + [self.eval(c) for c in node.comparators])
        if isinstance(node, ast.Starred):
            return self.eval(node.value)
        if isinstance(node, (ast.ListComp, ast.SetComp, ast.GeneratorExp,
                             ast.DictComp)):
            # comprehensions: evaluate iterables for provenance; the
            # element expression runs in its own scope (not walked)
            return Val.merge([self.eval(g.iter) for g in node.generators])
        if isinstance(node, ast.JoinedStr):
            return CONST
        if isinstance(node, (ast.Lambda, ast.NamedExpr)):
            if isinstance(node, ast.NamedExpr):
                val = self.eval(node.value)
                t = _target_name(node.target)
                if t:
                    self._bind(t, val)
                return val
            return CONST
        return CONST

    def _check_donated_read(self, val: Val, node: ast.AST,
                            name: str) -> None:
        for site in val.defs:
            if site in self.donated:
                entry, dline = self.donated[site]
                self.facts.donation_events.append(DonationEvent(
                    kind="read-after-donate", var=name, entry=entry,
                    donate_line=dline, line=node.lineno))
                return

    def _eval_name(self, node: ast.Name) -> Val:
        val = self.env.get(node.id)
        if val is None:
            return CONST      # module global / builtin / closure constant
        self._check_donated_read(val, node, node.id)
        return val

    def _eval_attr(self, node: ast.Attribute) -> Val:
        t = _target_name(node)
        if t is not None and t in self.env:
            val = self.env[t]
            self._check_donated_read(val, node, t)
            return val
        # attribute chain rooted at a local value (problem.R, aux.free_pos)
        root = node
        while isinstance(root, ast.Attribute):
            root = root.value
        if isinstance(root, ast.Name) and root.id in self.env:
            val = self.env[root.id]
            self._check_donated_read(val, node, root.id)
            return val
        return CONST          # module attribute (np.int32, solvers.X, ...)

    def _eval_subscript(self, node: ast.Subscript) -> Val:
        base = _dotted(node.value)
        k = self.an.index.resolve_const_dict(self.mod, base)
        idx = node.slice
        if k is not None:
            nm = idx.id if isinstance(idx, ast.Name) else (base or "idx")
            self.eval(idx)
            return Val(prov=frozenset({("finite",
                                        f"{self.fi.qual}.{nm}", k)}))
        bval = self.eval(node.value)
        # `ks = split(key, n)` is an ARRAY of keys: `ks[i]` derives a
        # per-index key, not a read of the array's own def.  Constant
        # indices are memoized so `normal(ks[0])` twice is still a
        # double draw; dynamic indices get fresh defs each sighting.
        if bval.defs and bval.defs <= self._split_arrays:
            self.eval(idx)
            site = next(iter(sorted(bval.defs)))
            if isinstance(idx, ast.Constant):
                memo_key = (site, repr(idx.value))
                if memo_key not in self._derived_idx:
                    self._derived_idx[memo_key] = self._key_def(
                        f"{base or 'ks'}[{idx.value!r}]", node.lineno,
                        "split-index")
                return self._derived_idx[memo_key]
            return self._key_def(f"{base or 'ks'}[…]", node.lineno,
                                 "split-index")
        return Val.merge([bval, self.eval(idx)])

    # -- calls --------------------------------------------------------------

    def _arg_vals(self, node: ast.Call) -> Tuple[List[Val], Dict[str, Val]]:
        pos = [self.eval(a) for a in node.args]
        kw = {k.arg: self.eval(k.value) for k in node.keywords
              if k.arg is not None}
        for k in node.keywords:
            if k.arg is None:
                self.eval(k.value)
        return pos, kw

    def _eval_call(self, node: ast.Call) -> Val:
        t = _dotted(node.func)
        pos, kw = self._arg_vals(node)
        inherit = Val.merge(pos + list(kw.values()))

        # jax.random draws consume their key (split/fold_in handled too)
        if _is_draw(t):
            if node.args:
                self._consume(pos[0], node, _dotted(node.args[0]) or "<expr>",
                              t)
            return Val(frozenset({self._site(node.lineno, t.split(".")[-1])}),
                       inherit.prov or frozenset({("const",)}))
        if _is_key_deriver(t):
            # derives an independent stream WITHOUT consuming the argument
            return self._key_def(f"<{t.split('.')[-1]}>", node.lineno,
                                 "derive")

        # shape-bucket helpers: finitely many results (pow-2 policy)
        leaf = t.split(".")[-1] if t else ""
        if leaf in _BUCKET_FNS:
            size = node.args[-1] if node.args else None
            nm = _dotted(size) if size is not None else None
            axis = f"{self.fi.qual}.{nm or leaf + '@' + str(node.lineno)}"
            return Val(frozenset({self._site(node.lineno, leaf)}),
                       frozenset({("bucket", axis)}))

        # donation wrappers: poison donated args, catch same-call aliasing
        dw = self.an.index.resolve_donation(self.mod, t)
        if dw is not None:
            self._apply_donation(node, dw, pos)

        # jitted @count_traces entries: record the cache axes reaching them
        entry = self.an.index.resolve_entry(self.mod, t)
        if entry is not None:
            self._record_entry_call(node, entry, pos, kw)

        # interprocedural key consumption via summaries
        fi = self.an.index.resolve_func(
            self.mod, t, class_name=self.fi.class_name)
        if fi is not None:
            key_params = self.an.summaries.get((fi.mod.path, fi.qual), set())
            if key_params:
                params = fi.params
                off = 1 if (fi.class_name is not None and t
                            and t.startswith("self.")) else 0
                for i, v in enumerate(pos):
                    j = i + off
                    if j < len(params) and params[j] in key_params:
                        self._consume(v, node,
                                      _dotted(node.args[i]) or "<expr>",
                                      f"call:{fi.qual}")
                for name, v in kw.items():
                    if name in key_params:
                        self._consume(
                            v, node,
                            _dotted(dict((k.arg, k.value)
                                         for k in node.keywords)[name])
                            or "<expr>", f"call:{fi.qual}")
            return Val(frozenset({self._site(node.lineno, leaf or "call")}),
                       inherit.prov or frozenset({("const",)}))

        # unresolved call: method calls on rooted objects and the pure
        # array/builtin surface inherit argument provenance; anything
        # else with NO rooted inputs is opaque (statically unbounded)
        obj_val = CONST
        if isinstance(node.func, ast.Attribute):
            obj_val = self.eval(node.func.value)
        merged = Val.merge([inherit, obj_val])
        rooted = any(a[0] != "const" for a in merged.prov)
        pure = (t is not None and (t.startswith(_PURE_PREFIXES)
                                   or t in _PURE_BARE))
        if rooted or pure:
            return Val(frozenset({self._site(node.lineno, leaf or "call")}),
                       merged.prov or frozenset({("const",)}))
        return Val(frozenset({self._site(node.lineno, leaf or "call")}),
                   frozenset({("opaque",
                               f"{self.fi.qual}.{leaf or 'call'}"
                               f"@{node.lineno}")}))

    def _apply_donation(self, node: ast.Call, dw: DonationWrapper,
                        pos: List[Val]) -> None:
        donated_names: Set[str] = set()
        for i in dw.donate:
            if i < len(node.args):
                nm = _target_name(node.args[i])
                if nm:
                    donated_names.add(nm)
        # same-call aliasing: a donated name also passed in another slot
        for i, a in enumerate(node.args):
            nm = _target_name(a)
            if nm in donated_names and i not in dw.donate:
                self.facts.donation_events.append(DonationEvent(
                    kind="alias", var=nm, entry=dw.name,
                    donate_line=node.lineno, line=node.lineno))
        for i in dw.donate:
            if i < len(node.args):
                nm = _target_name(node.args[i])
                if nm and nm in self.env:
                    for site in self.env[nm].defs:
                        self.donated[site] = (dw.name, node.lineno)

    def _axes_from_val(self, val: Val, static: bool) -> List[CacheAxis]:
        out = []
        for a in val.prov:
            if a[0] == "const":
                continue
            if a[0] == "finite":
                out.append(CacheAxis(a[1], "finite", a[2], static))
            elif a[0] == "param":
                out.append(CacheAxis(a[1], "param", None, static))
            elif a[0] == "bucket":
                out.append(CacheAxis(a[1], "bucket", None, static))
            elif a[0] == "opaque":
                out.append(CacheAxis(a[1], "unbounded", None, static))
        return out

    def _record_entry_call(self, node: ast.Call, entry: EntryDef,
                           pos: List[Val], kw: Dict[str, Val]) -> None:
        params = FuncInfo(entry.mod, entry.fn, entry.fn.name, None).params
        axes: Dict[str, CacheAxis] = {}
        for i, v in enumerate(pos):
            pname = params[i] if i < len(params) else f"arg{i}"
            static = pname in entry.static_names
            for ax in self._axes_from_val(v, static):
                prev = axes.get(ax.name)
                if prev is None or (ax.static and not prev.static):
                    axes[ax.name] = ax
        for name, v in kw.items():
            static = name in entry.static_names
            for ax in self._axes_from_val(v, static):
                prev = axes.get(ax.name)
                if prev is None or (ax.static and not prev.static):
                    axes[ax.name] = ax
        self.facts.entry_calls.append(EntryCall(
            entry=entry.name, path=self.mod.path, context=self.fi.qual,
            line=node.lineno, axes=tuple(sorted(axes.values(),
                                                key=lambda a: a.name))))

    # -- statements ---------------------------------------------------------

    def walk(self) -> FuncFacts:
        self._walk_body(self.fi.node.body)
        self.facts.loads = _loaded_names(self.fi.node)
        self._collect_dead_assigns()
        return self.facts

    def _walk_body(self, body: Sequence[ast.stmt]) -> None:
        for stmt in body:
            self._walk_stmt(stmt)

    def _walk_stmt(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, (ast.Assign, ast.AnnAssign)):
            targets = stmt.targets if isinstance(stmt, ast.Assign) \
                else [stmt.target]
            self._assign(targets, stmt.value)
        elif isinstance(stmt, ast.AugAssign):
            val = self.eval(stmt.value)
            t = _target_name(stmt.target)
            if t and t in self.env:
                self._bind(t, Val.merge([self.env[t], val]))
        elif isinstance(stmt, ast.Expr):
            self.eval(stmt.value)
        elif isinstance(stmt, ast.Return):
            self.eval(stmt.value)
        elif isinstance(stmt, ast.If):
            self.eval(stmt.test)
            before = dict(self.env)
            self._walk_body(stmt.body)
            after_if = self.env
            self.env = dict(before)
            self._walk_body(stmt.orelse)
            self._merge_env(after_if)
        elif isinstance(stmt, (ast.For, ast.AsyncFor)):
            self._walk_loop(stmt)
        elif isinstance(stmt, ast.While):
            self.eval(stmt.test)
            self._walk_loop(stmt, target=None, it=None)
        elif isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                val = self.eval(item.context_expr)
                if item.optional_vars is not None:
                    self._bind_target(item.optional_vars, val)
            self._walk_body(stmt.body)
        elif isinstance(stmt, ast.Try):
            before = dict(self.env)
            self._walk_body(stmt.body)
            for h in stmt.handlers:
                saved = self.env
                self.env = dict(before)
                self._walk_body(h.body)
                self._merge_env(saved)
            self._walk_body(stmt.orelse)
            self._walk_body(stmt.finalbody)
        elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef)):
            pass   # nested defs are analyzed as their own functions
        elif isinstance(stmt, (ast.Raise, ast.Assert)):
            if isinstance(stmt, ast.Assert):
                self.eval(stmt.test)
            elif stmt.exc is not None:
                self.eval(stmt.exc)
        elif isinstance(stmt, ast.Delete):
            for tgt in stmt.targets:
                t = _target_name(tgt)
                if t:
                    self.env.pop(t, None)

    def _merge_env(self, other: Dict[str, Val]) -> None:
        for name, val in other.items():
            if name in self.env:
                self.env[name] = Val.merge([self.env[name], val])
            else:
                self.env[name] = val

    def _walk_loop(self, stmt, target="sentinel", it="sentinel") -> None:
        if target == "sentinel":
            target, it = stmt.target, stmt.iter
        loop_id = stmt.lineno
        self.facts.loop_stores[loop_id] = \
            self.facts.loop_stores.get(loop_id, set()) | _stored_names(stmt)
        if it is not None:
            # iterating a split result yields a FRESH key per iteration
            if isinstance(it, ast.Call) and _is_split(_dotted(it.func)):
                if it.args:
                    self._consume(self.eval(it.args[0]), it,
                                  _dotted(it.args[0]) or "<expr>",
                                  "jax.random.split")
                    for a in it.args[1:]:
                        self.eval(a)
                val = None
            else:
                val = self.eval(it)
        before = dict(self.env)
        self.loops = self.loops + (loop_id,)
        if target is not None:
            if val is None:
                self._bind_target(target, self._key_def(
                    _target_name(target) or "<key>", stmt.lineno, "split"))
            else:
                self._bind_target(target, Val(
                    frozenset({self._site(stmt.lineno,
                                          _target_name(target) or "it")}),
                    val.prov))
        self._walk_body(stmt.body)
        self.loops = self.loops[:-1]
        self._merge_env(before)
        self._walk_body(getattr(stmt, "orelse", []) or [])

    def _bind_target(self, target: ast.AST, val: Val) -> None:
        if isinstance(target, (ast.Tuple, ast.List)):
            for e in target.elts:
                self._bind_target(
                    e, Val(frozenset({self._site(
                        getattr(e, "lineno", 0),
                        _target_name(e) or "unpack")}), val.prov))
            return
        t = _target_name(target)
        if t is not None:
            self._bind(t, val)
        elif isinstance(target, ast.Subscript):
            base = target.value
            nm = _target_name(base)
            if nm and nm in self.env:
                self._check_donated_read(self.env[nm], target, nm)
        elif isinstance(target, ast.Starred):
            self._bind_target(target.value, val)

    def _assign(self, targets: List[ast.AST], value: Optional[ast.AST]
                ) -> None:
        if value is None:
            return
        # split / PRNGKey / fold_in on the right-hand side: key defs
        if isinstance(value, ast.Call):
            t = _dotted(value.func)
            if _is_split(t):
                consumed = CONST
                if value.args:
                    consumed = self.eval(value.args[0])
                    self._consume(consumed, value,
                                  _dotted(value.args[0]) or "<expr>",
                                  "jax.random.split")
                    for a in value.args[1:]:
                        self.eval(a)
                for kwd in value.keywords:
                    self.eval(kwd.value)
                # carry idiom `key, k = split(key)`: the consumed def dies
                # on EVERY path through this statement -- retire it so the
                # path-insensitive count never sees a merge-resurrected copy
                carry = _dotted(value.args[0]) if value.args else None
                stored = set()
                for tgt in targets:
                    stored |= _stored_names(tgt)
                if carry is not None and carry in stored:
                    self._retired |= consumed.defs
                for tgt in targets:
                    if isinstance(tgt, (ast.Tuple, ast.List)):
                        names = []
                        for e in tgt.elts:
                            nm = _target_name(e) or "<unpack>"
                            names.append(nm)
                            self._bind(nm, self._key_def(nm, value.lineno,
                                                         "split"))
                        self.facts.split_assigns.append(
                            (value.lineno, names, frozenset(self.loops)))
                    else:
                        nm = _target_name(tgt)
                        if nm:
                            val = self._key_def(nm, value.lineno, "split")
                            self._split_arrays |= val.defs
                            self._bind(nm, val)
                        else:
                            self._bind_target(tgt, CONST)
                return
            if _is_key_deriver(t):
                for a in value.args:
                    self.eval(a)
                for tgt in targets:
                    nm = _target_name(tgt)
                    if nm:
                        self._bind(nm, self._key_def(
                            nm, value.lineno,
                            "prngkey" if t.split(".")[-1] in ("PRNGKey",
                                                              "key")
                            else "derive"))
                    else:
                        self._bind_target(tgt, CONST)
                return
        val = self.eval(value)
        for tgt in targets:
            nm = _target_name(tgt)
            if nm is not None and isinstance(value, (ast.Name,
                                                     ast.Attribute)):
                # plain alias: SHARE def sites (x2 = x), so a draw from
                # either name counts against the same definition
                self._bind(nm, val)
            else:
                self._bind_target(tgt, val)

    # -- dead device compute (CFN109 substrate) -----------------------------

    def _collect_dead_assigns(self) -> None:
        loads = self.facts.loads
        for n in ast.walk(self.fi.node):
            if not (isinstance(n, ast.Assign) and len(n.targets) == 1
                    and isinstance(n.targets[0], ast.Name)
                    and isinstance(n.value, ast.Call)):
                continue
            name = n.targets[0].id
            if name.startswith("_") or name in loads:
                continue
            t = _dotted(n.value.func)
            if t and (t.startswith(_DEVICE_PREFIXES) or t in _DEVICE_EXACT):
                self.facts.dead_assigns.append((n.lineno, name, t))


# ---------------------------------------------------------------------------
# the analyzer: summaries to fixpoint, facts for every function
# ---------------------------------------------------------------------------

class Analysis:
    """What one project-wide dataflow run produces (shared by all four
    CFN106-CFN109 rules through ``Project.cache``)."""

    def __init__(self, index: ProjectIndex,
                 functions: Dict[Tuple[str, str], FuncFacts]):
        self.index = index
        self.functions = functions

    @property
    def entry_calls(self) -> List[EntryCall]:
        return [c for f in self.functions.values() for c in f.entry_calls]


class Analyzer:
    MAX_PASSES = 5

    def __init__(self, project: Project):
        self.project = project
        self.index = ProjectIndex(project)
        self.summaries: Dict[Tuple[str, str], Set[str]] = {}

    def run(self) -> Analysis:
        functions: Dict[Tuple[str, str], FuncFacts] = {}
        for _ in range(self.MAX_PASSES):
            functions = {}
            changed = False
            for key, fi in self.index.funcs.items():
                facts = FlowWalker(self, fi).walk()
                functions[key] = facts
                if facts.consumed_params != self.summaries.get(key, set()):
                    self.summaries[key] = set(facts.consumed_params)
                    changed = True
            if not changed:
                break
        return Analysis(self.index, functions)


def analyze_dataflow(project: Project) -> Analysis:
    """Project-cached dataflow run (one per ``analyze_project`` call)."""
    return project.cache("dataflow", lambda: Analyzer(project).run())
