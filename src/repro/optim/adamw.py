"""Sharded AdamW (pure JAX) with global-norm clipping.

Optimizer state mirrors the parameter tree (m, v in fp32) so ZeRO-style
sharding falls out of the parameter shardings: state inherits the same
logical axes and the pjit partitioner shards m/v exactly like the params.
Params are kept in fp32 (master weights); model code casts to bf16 at use.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    m: Any
    v: Any
    count: jnp.ndarray


def init(params) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(m=jax.tree_util.tree_map(zeros, params),
                    v=jax.tree_util.tree_map(zeros, params),
                    count=jnp.zeros((), jnp.int32))


def state_axes(param_axes) -> OptState:
    """Logical axes for the optimizer state (mirrors params)."""
    is_axes = lambda a: isinstance(a, tuple) and all(
        isinstance(e, (str, type(None))) for e in a)
    ident = jax.tree_util.tree_map(lambda a: a, param_axes, is_leaf=is_axes)
    return OptState(m=ident, v=ident, count=())


def schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup + cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(1.0, cfg.warmup_steps)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        1.0, cfg.total_steps - cfg.warmup_steps)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * \
        (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(params, grads, state: OptState, cfg: AdamWConfig
                  ) -> Tuple[Any, OptState, Dict[str, jnp.ndarray]]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    count = state.count + 1
    lr = schedule(cfg, count)
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        u = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        # decay only matrix-shaped params (norms/biases exempt)
        wd = cfg.weight_decay if p.ndim >= 2 else 0.0
        newp = p.astype(jnp.float32) - lr * (u + wd * p.astype(jnp.float32))
        return newp.astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = dict(grad_norm=gnorm, lr=lr)
    return new_p, OptState(m=new_m, v=new_v, count=count), metrics
