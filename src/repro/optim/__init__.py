from . import adamw
from .adamw import AdamWConfig, OptState, apply_updates, global_norm, schedule
