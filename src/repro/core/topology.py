"""CFN physical topology: nodes, links, and the path-incidence tensor.

The paper's Fig. 1 architecture is a tree:

    IoT devices --(Wi-Fi)--> ONU APs --> OLT --> metro router --> metro switch
                                   \\-> AF                    \\-> MF
    metro switch --> core (IP/WDM ingress) --> core (IP/WDM egress) --> CDC

Because the substrate is a tree, the route between any two processing nodes is
unique, so flow conservation (paper Eq. 5) holds by construction once we record
for every ordered processing-node pair (b, e) which *network* nodes its route
traverses.  Real routes are SPARSE -- a metro/core route crosses <= ~15 network
nodes however large the substrate -- so the canonical representation is a
padded-CSR route table:

    route_idx[b, e, k]  -- the k-th network node on the (b, e) route
                           (int32; entries beyond the route's length hold the
                           sentinel value N, which every consumer masks out)
    route_len[b, e]     -- number of network nodes on the route (== path_hops)

Traffic aggregated by network node n is then a gather/segment-sum over the
route table (see power.py), O(P^2 * K) instead of the O(P^2 * N) dense
incidence contraction -- the representation that keeps city-scale substrates
(P in the hundreds, see ``city_scale``) on the accelerator hot path.  The
dense ``path_nodes`` tensor survives only as a test-side reference
constructor (``dense_path_nodes``).  A generic BFS router is used so meshed
cores (e.g. NSFNET, the paper's future work) drop in unchanged.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Sequence, Tuple

import numpy as np

from . import hardware as hw

PROCESSING = "processing"
NETWORK = "network"

# Canonical layer tags used by solvers / benchmarks.
LAYER_IOT = "iot"
LAYER_AF = "af"
LAYER_MF = "mf"
LAYER_CDC = "cdc"


@dataclass
class CFNTopology:
    """A CFN substrate graph with hardware annotations.

    Processing nodes and network nodes have separate index spaces:
      * ``proc_names[p]`` / ``proc_hw[p]`` for p in [0, P)
      * ``net_names[n]`` / ``net_hw[n]`` for n in [0, N)
    ``adj`` is over the merged space (processing first, then network) and only
    used to derive ``path_nodes``.
    """

    proc_names: List[str] = field(default_factory=list)
    proc_hw: List[hw.ProcessingHW] = field(default_factory=list)
    proc_layer: List[str] = field(default_factory=list)   # iot/af/mf/cdc tag
    net_names: List[str] = field(default_factory=list)
    net_hw: List[hw.NetworkHW] = field(default_factory=list)
    edges: List[Tuple[str, str]] = field(default_factory=list)
    # derived (padded-CSR route table; see module docstring)
    route_idx: np.ndarray | None = None    # [P, P, K] int32, pad = N
    route_len: np.ndarray | None = None    # [P, P] int32 (#network nodes)
    path_hops: np.ndarray | None = None    # alias of route_len (legacy name)
    _dense_cache: np.ndarray | None = None

    # -- construction ------------------------------------------------------
    def add_proc(self, name: str, h: hw.ProcessingHW, layer: str) -> str:
        self.proc_names.append(name)
        self.proc_hw.append(h)
        self.proc_layer.append(layer)
        return name

    def add_net(self, name: str, h: hw.NetworkHW) -> str:
        self.net_names.append(name)
        self.net_hw.append(h)
        return name

    def connect(self, a: str, b: str) -> None:
        self.edges.append((a, b))

    # -- index helpers -----------------------------------------------------
    @property
    def P(self) -> int:
        return len(self.proc_names)

    @property
    def N(self) -> int:
        return len(self.net_names)

    def proc_index(self, name: str) -> int:
        return self.proc_names.index(name)

    def layer_indices(self, layer: str) -> List[int]:
        return [i for i, l in enumerate(self.proc_layer) if l == layer]

    @property
    def K(self) -> int:
        """Route padding width (max network nodes on any route)."""
        return 0 if self.route_idx is None else self.route_idx.shape[2]

    # -- routing -----------------------------------------------------------
    def finalize(self) -> "CFNTopology":
        """Compute the padded-CSR route table by BFS over the merged graph."""
        names = list(self.proc_names) + list(self.net_names)
        index: Dict[str, int] = {n: i for i, n in enumerate(names)}
        n_all = len(names)
        nbrs: List[List[int]] = [[] for _ in range(n_all)]
        for a, b in self.edges:
            ia, ib = index[a], index[b]
            nbrs[ia].append(ib)
            nbrs[ib].append(ia)

        P, N = self.P, self.N
        routes: List[List[List[int]]] = [[[] for _ in range(P)]
                                         for _ in range(P)]
        route_len = np.zeros((P, P), dtype=np.int32)
        for b in range(P):
            # BFS from processing node b.
            prev = np.full(n_all, -1, dtype=np.int64)
            seen = np.zeros(n_all, dtype=bool)
            seen[b] = True
            frontier = [b]
            while frontier:
                nxt = []
                for u in frontier:
                    for v in nbrs[u]:
                        if not seen[v]:
                            seen[v] = True
                            prev[v] = u
                            nxt.append(v)
                frontier = nxt
            for e in range(P):
                if e == b or not seen[e]:
                    continue
                # walk back, collecting intermediate *network* nodes.
                u = int(prev[e])
                nodes: List[int] = []
                while u != b and u != -1:
                    if u >= P:  # network node
                        nodes.append(u - P)
                    u = int(prev[u])
                routes[b][e] = nodes
                route_len[b, e] = len(nodes)
        K = max(1, int(route_len.max()))
        route_idx = np.full((P, P, K), N, dtype=np.int32)
        for b in range(P):
            for e in range(P):
                nodes = routes[b][e]
                if nodes:
                    route_idx[b, e, :len(nodes)] = nodes
        self.route_idx = route_idx
        self.route_len = route_len
        self.path_hops = route_len
        self._dense_cache = None
        return self

    # -- dense reference (tests / oracles only) -----------------------------
    def dense_path_nodes(self) -> np.ndarray:
        """Materialize the dense ``[P, P, N]`` path-incidence tensor from the
        CSR route table.  O(P^2 * N) memory -- NOT used by any production
        code path; tests and benchmarks use it as the dense reference the
        sparse engine is checked against."""
        if self.route_idx is None:
            raise RuntimeError("finalize() the topology first")
        P, N, K = self.P, self.N, self.K
        dense = np.zeros((P, P, N + 1), dtype=np.float32)
        b, e, _ = np.indices(self.route_idx.shape)
        dense[b.reshape(-1), e.reshape(-1), self.route_idx.reshape(-1)] = 1.0
        return dense[:, :, :N]

    @property
    def path_nodes(self) -> np.ndarray:
        """Dense incidence tensor (cached); reference/test use only."""
        if self._dense_cache is None:
            self._dense_cache = self.dense_path_nodes()
        return self._dense_cache

    # -- parameter vectors (consumed by power.py) ---------------------------
    def proc_param_arrays(self) -> Dict[str, np.ndarray]:
        f = np.float32
        g = lambda attr: np.array([getattr(h, attr) for h in self.proc_hw], f)
        return dict(
            E=np.array([h.eps_w_per_gflops for h in self.proc_hw], f),
            C_pr=g("cap_gflops"),
            NS=g("n_servers"),
            pi_pr=g("idle_w"),
            pue_pr=g("pue"),
            EL=g("lan_eps_w_per_gbps"),
            C_lan=g("lan_cap_gbps"),
            pi_lan=g("lan_idle_w"),
            lan_share=g("lan_idle_share"),
        )

    def net_param_arrays(self) -> Dict[str, np.ndarray]:
        f = np.float32
        g = lambda attr: np.array([getattr(h, attr) for h in self.net_hw], f)
        return dict(
            eps=np.array([h.eps_w_per_gbps for h in self.net_hw], f),
            C_net=g("cap_gbps"),
            pi_net=g("idle_w"),
            pue_net=g("pue"),
            idle_share=g("idle_share"),
        )


def paper_topology(n_iot: int = 20, n_zones: int = 4,
                   af_servers: int | None = None,
                   mf_servers: int | None = None,
                   cdc_servers: int | None = None) -> CFNTopology:
    """The paper's evaluation substrate (§3): 20 IoT devices in 4 zones."""
    t = CFNTopology()
    af_hw = hw.AF_I5 if af_servers is None else hw.scaled(hw.AF_I5, n_servers=af_servers)
    mf_hw = hw.MF_I5 if mf_servers is None else hw.scaled(hw.MF_I5, n_servers=mf_servers)
    cdc_hw = hw.CDC_XEON if cdc_servers is None else hw.scaled(hw.CDC_XEON, n_servers=cdc_servers)

    for i in range(n_iot):
        t.add_proc(f"iot{i}", hw.IOT_RPI4, LAYER_IOT)
    t.add_proc("af0", af_hw, LAYER_AF)
    t.add_proc("mf0", mf_hw, LAYER_MF)
    t.add_proc("cdc0", cdc_hw, LAYER_CDC)

    for z in range(n_zones):
        t.add_net(f"onu{z}", hw.ONU_AP)
    t.add_net("olt0", hw.OLT)
    t.add_net("mrouter0", hw.METRO_ROUTER)
    t.add_net("mswitch0", hw.METRO_SWITCH)
    t.add_net("core0", hw.IPWDM_NODE)   # ingress (aggregation) core node
    t.add_net("core1", hw.IPWDM_NODE)   # egress core node, 1 hop / ~200 km
    # dedicated low-end attachment gear for the fog nodes (paper §2.1)
    t.add_net("af_router0", hw.LOW_END_ROUTER)
    t.add_net("af_switch0", hw.LOW_END_SWITCH)
    t.add_net("mf_router0", hw.LOW_END_ROUTER)
    t.add_net("mf_switch0", hw.LOW_END_SWITCH)

    for i in range(n_iot):
        t.connect(f"iot{i}", f"onu{i % n_zones}")
    for z in range(n_zones):
        t.connect(f"onu{z}", "olt0")
    t.connect("olt0", "af_router0")
    t.connect("af_router0", "af_switch0")
    t.connect("af_switch0", "af0")
    t.connect("olt0", "mrouter0")
    t.connect("mrouter0", "mswitch0")
    t.connect("mswitch0", "mf_router0")
    t.connect("mf_router0", "mf_switch0")
    t.connect("mf_switch0", "mf0")
    t.connect("mswitch0", "core0")
    t.connect("core0", "core1")
    t.connect("cdc0", "core1")
    return t.finalize()


# NSFNET 14-node core (paper §4 future work: "a realistic core network
# topology such as ... NSFNET").  Edges are the standard NSFNET T1 links.
NSFNET_EDGES = [
    (0, 1), (0, 2), (0, 7), (1, 2), (1, 3), (2, 5), (3, 4), (3, 10),
    (4, 5), (4, 6), (5, 9), (5, 13), (6, 7), (7, 8), (8, 9), (8, 11),
    (8, 12), (10, 11), (10, 12), (11, 13), (12, 13),
]


def nsfnet_topology(n_iot: int = 20, n_zones: int = 4,
                    access_core: int = 0, cdc_core: int = 8) -> CFNTopology:
    """The paper's CFN with the tree core replaced by the 14-node NSFNET.

    The access/metro side attaches at core node ``access_core``; the CDC
    hangs off ``cdc_core``.  Because the core is MESHED, routes are no
    longer unique -- the BFS router picks shortest paths, and the
    path-incidence contraction (and hence Eq. 1) still holds: this is the
    drop-in-core property claimed in the module docstring, exercised by
    tests/test_core_paper.py::test_nsfnet_flow_conservation.
    """
    t = CFNTopology()
    for i in range(n_iot):
        t.add_proc(f"iot{i}", hw.IOT_RPI4, LAYER_IOT)
    t.add_proc("af0", hw.AF_I5, LAYER_AF)
    t.add_proc("mf0", hw.MF_I5, LAYER_MF)
    t.add_proc("cdc0", hw.CDC_XEON, LAYER_CDC)

    for z in range(n_zones):
        t.add_net(f"onu{z}", hw.ONU_AP)
    t.add_net("olt0", hw.OLT)
    t.add_net("mrouter0", hw.METRO_ROUTER)
    t.add_net("mswitch0", hw.METRO_SWITCH)
    for c in range(14):
        t.add_net(f"core{c}", hw.IPWDM_NODE)
    t.add_net("af_router0", hw.LOW_END_ROUTER)
    t.add_net("af_switch0", hw.LOW_END_SWITCH)
    t.add_net("mf_router0", hw.LOW_END_ROUTER)
    t.add_net("mf_switch0", hw.LOW_END_SWITCH)

    for i in range(n_iot):
        t.connect(f"iot{i}", f"onu{i % n_zones}")
    for z in range(n_zones):
        t.connect(f"onu{z}", "olt0")
    t.connect("olt0", "af_router0")
    t.connect("af_router0", "af_switch0")
    t.connect("af_switch0", "af0")
    t.connect("olt0", "mrouter0")
    t.connect("mrouter0", "mswitch0")
    t.connect("mswitch0", "mf_router0")
    t.connect("mf_router0", "mf_switch0")
    t.connect("mf_switch0", "mf0")
    t.connect("mswitch0", f"core{access_core}")
    for a, b in NSFNET_EDGES:
        t.connect(f"core{a}", f"core{b}")
    t.connect("cdc0", f"core{cdc_core}")
    return t.finalize()


def city_scale(n_olt: int = 8, onus_per_olt: int = 6, iot_per_onu: int = 5,
               n_metro: int = 2, n_core: int = 6, n_cdc: int = 2,
               mf_servers: int = 8, cdc_servers: int = 64) -> CFNTopology:
    """City-wide PON fabric: the production-scale substrate preset.

    The paper's Fig. 1 tree replicated across a whole city, after the
    city-wide PON fabrics of arXiv:2005.00877 and the multi-tier fog
    hierarchies of arXiv:1808.06120:

      * ``n_olt`` access zones, each an OLT serving ``onus_per_olt`` ONU APs
        with ``iot_per_onu`` IoT devices each, plus one access-fog (AF) node
        behind dedicated low-end gear;
      * ``n_metro`` metro router/switch pairs, each aggregating an equal
        share of the OLT zones and hosting one metro-fog (MF) node;
      * an ``n_core``-node IP/WDM ring interconnecting the metro sites, with
        ``n_cdc`` cloud datacenters hanging off opposite sides of the ring.

    Defaults give P = 8*6*5 + 8 + 2 + 2 = 252 processing nodes and N ~ 88
    network nodes with routes of <= ~15 hops -- the regime where the CSR
    route table (P^2*K) is ~N/K smaller than the dense incidence tensor
    (P^2*N).  All knobs scale the fabric up or down (tests use a small
    instance; benchmarks sweep P).
    """
    t = CFNTopology()
    # processing nodes: IoT first (sources), then fog, then cloud
    for z in range(n_olt):
        for o in range(onus_per_olt):
            for i in range(iot_per_onu):
                t.add_proc(f"iot{z}_{o}_{i}", hw.IOT_RPI4, LAYER_IOT)
    for z in range(n_olt):
        t.add_proc(f"af{z}", hw.AF_I5, LAYER_AF)
    for m in range(n_metro):
        t.add_proc(f"mf{m}", hw.scaled(hw.MF_I5, n_servers=mf_servers),
                   LAYER_MF)
    for c in range(n_cdc):
        t.add_proc(f"cdc{c}", hw.scaled(hw.CDC_XEON, n_servers=cdc_servers),
                   LAYER_CDC)

    # network: access
    for z in range(n_olt):
        for o in range(onus_per_olt):
            t.add_net(f"onu{z}_{o}", hw.ONU_AP)
        t.add_net(f"olt{z}", hw.OLT)
        t.add_net(f"af_router{z}", hw.LOW_END_ROUTER)
        t.add_net(f"af_switch{z}", hw.LOW_END_SWITCH)
    # metro + core
    for m in range(n_metro):
        t.add_net(f"mrouter{m}", hw.METRO_ROUTER)
        t.add_net(f"mswitch{m}", hw.METRO_SWITCH)
        t.add_net(f"mf_router{m}", hw.LOW_END_ROUTER)
        t.add_net(f"mf_switch{m}", hw.LOW_END_SWITCH)
    for c in range(n_core):
        t.add_net(f"core{c}", hw.IPWDM_NODE)

    # wiring: access trees
    for z in range(n_olt):
        for o in range(onus_per_olt):
            for i in range(iot_per_onu):
                t.connect(f"iot{z}_{o}_{i}", f"onu{z}_{o}")
            t.connect(f"onu{z}_{o}", f"olt{z}")
        t.connect(f"olt{z}", f"af_router{z}")
        t.connect(f"af_router{z}", f"af_switch{z}")
        t.connect(f"af_switch{z}", f"af{z}")
        t.connect(f"olt{z}", f"mrouter{z % n_metro}")
    for m in range(n_metro):
        t.connect(f"mrouter{m}", f"mswitch{m}")
        t.connect(f"mswitch{m}", f"mf_router{m}")
        t.connect(f"mf_router{m}", f"mf_switch{m}")
        t.connect(f"mf_switch{m}", f"mf{m}")
        t.connect(f"mswitch{m}", f"core{(m * n_core) // max(1, n_metro)}")
    for c in range(n_core):
        t.connect(f"core{c}", f"core{(c + 1) % n_core}")
    for c in range(n_cdc):
        at = ((c * n_core) // max(1, n_cdc) + n_core // 4) % n_core
        t.connect(f"cdc{c}", f"core{at}")
    return t.finalize()


def federated_scale(n_regions: int = 4, n_olt: int = 2, onus_per_olt: int = 2,
                    iot_per_onu: int = 3, mf_servers: int = 4,
                    cdc_servers: int = 16, n_core: int = 14) -> CFNTopology:
    """Federated fog regions: ``n_regions`` city-style CFN regions stitched
    over a shared NSFNET-like IP/WDM core (the paper's §4 future work made
    a preset, after the cloud-fog federations of arXiv:2008.04004).

    Every region ``g`` is a self-contained Fig.-1-style fabric whose node
    names carry the ``r{g}_`` prefix (the convention
    ``core.federation.RegionPartition`` parses):

      * access: ``n_olt`` OLT zones of ``onus_per_olt`` ONU APs x
        ``iot_per_onu`` IoT devices, one AF node per zone behind dedicated
        low-end gear;
      * metro: one metro router/switch pair hosting the region's MF node;
      * region cloud: a CDC behind the region's own IP/WDM ingress/egress
        pair (``core_in0``/``core_out0``) -- so every intra-region route,
        including routes to the regional CDC, stays on region-prefixed
        network nodes.

    The shared core is ``n_core`` unprefixed ``nsf{c}`` IP/WDM nodes --
    the 14-node NSFNET mesh when ``n_core == 14``, a ring otherwise --
    and region ``g`` attaches its ``core_in0`` at core node
    ``(g * n_core) // n_regions``.  Only inter-region traffic ever touches
    the shared core, which is what lets ``core.federation`` decompose the
    substrate into per-region placement problems plus an inter-region
    core-link table.

    Defaults give 4 regions x 16 processing nodes (P = 64) over the NSFNET
    core; the knobs scale each region like ``city_scale``.
    """
    if n_regions < 1:
        raise ValueError(f"n_regions must be >= 1, got {n_regions}")
    t = CFNTopology()
    # processing nodes, region-major (merged proc index order groups regions)
    for g in range(n_regions):
        p = f"r{g}_"
        for z in range(n_olt):
            for o in range(onus_per_olt):
                for i in range(iot_per_onu):
                    t.add_proc(f"{p}iot{z}_{o}_{i}", hw.IOT_RPI4, LAYER_IOT)
        for z in range(n_olt):
            t.add_proc(f"{p}af{z}", hw.AF_I5, LAYER_AF)
        t.add_proc(f"{p}mf0", hw.scaled(hw.MF_I5, n_servers=mf_servers),
                   LAYER_MF)
        t.add_proc(f"{p}cdc0", hw.scaled(hw.CDC_XEON, n_servers=cdc_servers),
                   LAYER_CDC)
    # network nodes: regions first (region-major), shared core last
    for g in range(n_regions):
        p = f"r{g}_"
        for z in range(n_olt):
            for o in range(onus_per_olt):
                t.add_net(f"{p}onu{z}_{o}", hw.ONU_AP)
            t.add_net(f"{p}olt{z}", hw.OLT)
            t.add_net(f"{p}af_router{z}", hw.LOW_END_ROUTER)
            t.add_net(f"{p}af_switch{z}", hw.LOW_END_SWITCH)
        t.add_net(f"{p}mrouter0", hw.METRO_ROUTER)
        t.add_net(f"{p}mswitch0", hw.METRO_SWITCH)
        t.add_net(f"{p}mf_router0", hw.LOW_END_ROUTER)
        t.add_net(f"{p}mf_switch0", hw.LOW_END_SWITCH)
        t.add_net(f"{p}core_in0", hw.IPWDM_NODE)
        t.add_net(f"{p}core_out0", hw.IPWDM_NODE)
    for c in range(n_core):
        t.add_net(f"nsf{c}", hw.IPWDM_NODE)

    # wiring: each region is a tree hanging off one shared-core attachment
    for g in range(n_regions):
        p = f"r{g}_"
        for z in range(n_olt):
            for o in range(onus_per_olt):
                for i in range(iot_per_onu):
                    t.connect(f"{p}iot{z}_{o}_{i}", f"{p}onu{z}_{o}")
                t.connect(f"{p}onu{z}_{o}", f"{p}olt{z}")
            t.connect(f"{p}olt{z}", f"{p}af_router{z}")
            t.connect(f"{p}af_router{z}", f"{p}af_switch{z}")
            t.connect(f"{p}af_switch{z}", f"{p}af{z}")
            t.connect(f"{p}olt{z}", f"{p}mrouter0")
        t.connect(f"{p}mrouter0", f"{p}mswitch0")
        t.connect(f"{p}mswitch0", f"{p}mf_router0")
        t.connect(f"{p}mf_router0", f"{p}mf_switch0")
        t.connect(f"{p}mf_switch0", f"{p}mf0")
        t.connect(f"{p}mswitch0", f"{p}core_in0")
        t.connect(f"{p}core_in0", f"{p}core_out0")
        t.connect(f"{p}core_out0", f"{p}cdc0")
        t.connect(f"{p}core_in0", f"nsf{(g * n_core) // n_regions}")
    if n_core == 14:
        for a, b in NSFNET_EDGES:
            t.connect(f"nsf{a}", f"nsf{b}")
    else:
        for c in range(n_core):
            t.connect(f"nsf{c}", f"nsf{(c + 1) % n_core}")
    return t.finalize()


def datacenter_topology(n_edge: int = 8, n_fog: int = 2) -> CFNTopology:
    """Beyond-paper preset: TPU-pod-class nodes in the same CFN shape.

    Edge pods sit behind access DCN switches, fog pods behind a metro DCN
    switch, and the cloud pod behind a WAN router pair -- the datacenter
    analogue of Fig. 1 used to place the assigned LM architectures.
    """
    t = CFNTopology()
    for i in range(n_edge):
        t.add_proc(f"edge{i}", hw.EDGE_POD, LAYER_IOT)
    for i in range(n_fog):
        t.add_proc(f"fog{i}", hw.FOG_POD, LAYER_AF if i == 0 else LAYER_MF)
    t.add_proc("cloud0", hw.CLOUD_POD, LAYER_CDC)

    n_acc = max(1, n_edge // 4)
    for z in range(n_acc):
        t.add_net(f"acc{z}", hw.DCN_SWITCH)
    t.add_net("agg0", hw.DCN_SWITCH)
    t.add_net("wan0", hw.WAN_ROUTER)
    t.add_net("wan1", hw.WAN_ROUTER)

    for i in range(n_edge):
        t.connect(f"edge{i}", f"acc{i % n_acc}")
    for z in range(n_acc):
        t.connect(f"acc{z}", "agg0")
    for i in range(n_fog):
        t.connect(f"fog{i}", "agg0")
    t.connect("agg0", "wan0")
    t.connect("wan0", "wan1")
    t.connect("cloud0", "wan1")
    return t.finalize()
