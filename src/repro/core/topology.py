"""CFN physical topology: nodes, links, and the path-incidence tensor.

The paper's Fig. 1 architecture is a tree:

    IoT devices --(Wi-Fi)--> ONU APs --> OLT --> metro router --> metro switch
                                   \\-> AF                    \\-> MF
    metro switch --> core (IP/WDM ingress) --> core (IP/WDM egress) --> CDC

Because the substrate is a tree, the route between any two processing nodes is
unique, so flow conservation (paper Eq. 5) holds by construction once we record
for every ordered processing-node pair (b, e) which *network* nodes its route
traverses: ``path_nodes[b, e, n] in {0, 1}``.  Traffic aggregated by network
node n is then a tensor contraction (see power.py), which is what makes the
placement objective batchable on accelerator.  A generic BFS router is used so
meshed cores (e.g. NSFNET, the paper's future work) drop in unchanged.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Sequence, Tuple

import numpy as np

from . import hardware as hw

PROCESSING = "processing"
NETWORK = "network"

# Canonical layer tags used by solvers / benchmarks.
LAYER_IOT = "iot"
LAYER_AF = "af"
LAYER_MF = "mf"
LAYER_CDC = "cdc"


@dataclass
class CFNTopology:
    """A CFN substrate graph with hardware annotations.

    Processing nodes and network nodes have separate index spaces:
      * ``proc_names[p]`` / ``proc_hw[p]`` for p in [0, P)
      * ``net_names[n]`` / ``net_hw[n]`` for n in [0, N)
    ``adj`` is over the merged space (processing first, then network) and only
    used to derive ``path_nodes``.
    """

    proc_names: List[str] = field(default_factory=list)
    proc_hw: List[hw.ProcessingHW] = field(default_factory=list)
    proc_layer: List[str] = field(default_factory=list)   # iot/af/mf/cdc tag
    net_names: List[str] = field(default_factory=list)
    net_hw: List[hw.NetworkHW] = field(default_factory=list)
    edges: List[Tuple[str, str]] = field(default_factory=list)
    # derived
    path_nodes: np.ndarray | None = None   # [P, P, N] float32
    path_hops: np.ndarray | None = None    # [P, P] int32 (#network nodes)

    # -- construction ------------------------------------------------------
    def add_proc(self, name: str, h: hw.ProcessingHW, layer: str) -> str:
        self.proc_names.append(name)
        self.proc_hw.append(h)
        self.proc_layer.append(layer)
        return name

    def add_net(self, name: str, h: hw.NetworkHW) -> str:
        self.net_names.append(name)
        self.net_hw.append(h)
        return name

    def connect(self, a: str, b: str) -> None:
        self.edges.append((a, b))

    # -- index helpers -----------------------------------------------------
    @property
    def P(self) -> int:
        return len(self.proc_names)

    @property
    def N(self) -> int:
        return len(self.net_names)

    def proc_index(self, name: str) -> int:
        return self.proc_names.index(name)

    def layer_indices(self, layer: str) -> List[int]:
        return [i for i, l in enumerate(self.proc_layer) if l == layer]

    # -- routing -----------------------------------------------------------
    def finalize(self) -> "CFNTopology":
        """Compute ``path_nodes`` by BFS over the merged graph."""
        names = list(self.proc_names) + list(self.net_names)
        index: Dict[str, int] = {n: i for i, n in enumerate(names)}
        n_all = len(names)
        nbrs: List[List[int]] = [[] for _ in range(n_all)]
        for a, b in self.edges:
            ia, ib = index[a], index[b]
            nbrs[ia].append(ib)
            nbrs[ib].append(ia)

        P, N = self.P, self.N
        path_nodes = np.zeros((P, P, N), dtype=np.float32)
        path_hops = np.zeros((P, P), dtype=np.int32)
        for b in range(P):
            # BFS from processing node b.
            prev = np.full(n_all, -1, dtype=np.int64)
            seen = np.zeros(n_all, dtype=bool)
            seen[b] = True
            frontier = [b]
            while frontier:
                nxt = []
                for u in frontier:
                    for v in nbrs[u]:
                        if not seen[v]:
                            seen[v] = True
                            prev[v] = u
                            nxt.append(v)
                frontier = nxt
            for e in range(P):
                if e == b or not seen[e]:
                    continue
                # walk back, collecting intermediate *network* nodes.
                u = int(prev[e])
                hops = 0
                while u != b and u != -1:
                    if u >= P:  # network node
                        path_nodes[b, e, u - P] = 1.0
                        hops += 1
                    u = int(prev[u])
                path_hops[b, e] = hops
        self.path_nodes = path_nodes
        self.path_hops = path_hops
        return self

    # -- parameter vectors (consumed by power.py) ---------------------------
    def proc_param_arrays(self) -> Dict[str, np.ndarray]:
        f = np.float32
        g = lambda attr: np.array([getattr(h, attr) for h in self.proc_hw], f)
        return dict(
            E=np.array([h.eps_w_per_gflops for h in self.proc_hw], f),
            C_pr=g("cap_gflops"),
            NS=g("n_servers"),
            pi_pr=g("idle_w"),
            pue_pr=g("pue"),
            EL=g("lan_eps_w_per_gbps"),
            C_lan=g("lan_cap_gbps"),
            pi_lan=g("lan_idle_w"),
            lan_share=g("lan_idle_share"),
        )

    def net_param_arrays(self) -> Dict[str, np.ndarray]:
        f = np.float32
        g = lambda attr: np.array([getattr(h, attr) for h in self.net_hw], f)
        return dict(
            eps=np.array([h.eps_w_per_gbps for h in self.net_hw], f),
            C_net=g("cap_gbps"),
            pi_net=g("idle_w"),
            pue_net=g("pue"),
            idle_share=g("idle_share"),
        )


def paper_topology(n_iot: int = 20, n_zones: int = 4,
                   af_servers: int | None = None,
                   mf_servers: int | None = None,
                   cdc_servers: int | None = None) -> CFNTopology:
    """The paper's evaluation substrate (§3): 20 IoT devices in 4 zones."""
    t = CFNTopology()
    af_hw = hw.AF_I5 if af_servers is None else hw.scaled(hw.AF_I5, n_servers=af_servers)
    mf_hw = hw.MF_I5 if mf_servers is None else hw.scaled(hw.MF_I5, n_servers=mf_servers)
    cdc_hw = hw.CDC_XEON if cdc_servers is None else hw.scaled(hw.CDC_XEON, n_servers=cdc_servers)

    for i in range(n_iot):
        t.add_proc(f"iot{i}", hw.IOT_RPI4, LAYER_IOT)
    t.add_proc("af0", af_hw, LAYER_AF)
    t.add_proc("mf0", mf_hw, LAYER_MF)
    t.add_proc("cdc0", cdc_hw, LAYER_CDC)

    for z in range(n_zones):
        t.add_net(f"onu{z}", hw.ONU_AP)
    t.add_net("olt0", hw.OLT)
    t.add_net("mrouter0", hw.METRO_ROUTER)
    t.add_net("mswitch0", hw.METRO_SWITCH)
    t.add_net("core0", hw.IPWDM_NODE)   # ingress (aggregation) core node
    t.add_net("core1", hw.IPWDM_NODE)   # egress core node, 1 hop / ~200 km
    # dedicated low-end attachment gear for the fog nodes (paper §2.1)
    t.add_net("af_router0", hw.LOW_END_ROUTER)
    t.add_net("af_switch0", hw.LOW_END_SWITCH)
    t.add_net("mf_router0", hw.LOW_END_ROUTER)
    t.add_net("mf_switch0", hw.LOW_END_SWITCH)

    for i in range(n_iot):
        t.connect(f"iot{i}", f"onu{i % n_zones}")
    for z in range(n_zones):
        t.connect(f"onu{z}", "olt0")
    t.connect("olt0", "af_router0")
    t.connect("af_router0", "af_switch0")
    t.connect("af_switch0", "af0")
    t.connect("olt0", "mrouter0")
    t.connect("mrouter0", "mswitch0")
    t.connect("mswitch0", "mf_router0")
    t.connect("mf_router0", "mf_switch0")
    t.connect("mf_switch0", "mf0")
    t.connect("mswitch0", "core0")
    t.connect("core0", "core1")
    t.connect("cdc0", "core1")
    return t.finalize()


# NSFNET 14-node core (paper §4 future work: "a realistic core network
# topology such as ... NSFNET").  Edges are the standard NSFNET T1 links.
NSFNET_EDGES = [
    (0, 1), (0, 2), (0, 7), (1, 2), (1, 3), (2, 5), (3, 4), (3, 10),
    (4, 5), (4, 6), (5, 9), (5, 13), (6, 7), (7, 8), (8, 9), (8, 11),
    (8, 12), (10, 11), (10, 12), (11, 13), (12, 13),
]


def nsfnet_topology(n_iot: int = 20, n_zones: int = 4,
                    access_core: int = 0, cdc_core: int = 8) -> CFNTopology:
    """The paper's CFN with the tree core replaced by the 14-node NSFNET.

    The access/metro side attaches at core node ``access_core``; the CDC
    hangs off ``cdc_core``.  Because the core is MESHED, routes are no
    longer unique -- the BFS router picks shortest paths, and the
    path-incidence contraction (and hence Eq. 1) still holds: this is the
    drop-in-core property claimed in the module docstring, exercised by
    tests/test_core_paper.py::test_nsfnet_flow_conservation.
    """
    t = CFNTopology()
    for i in range(n_iot):
        t.add_proc(f"iot{i}", hw.IOT_RPI4, LAYER_IOT)
    t.add_proc("af0", hw.AF_I5, LAYER_AF)
    t.add_proc("mf0", hw.MF_I5, LAYER_MF)
    t.add_proc("cdc0", hw.CDC_XEON, LAYER_CDC)

    for z in range(n_zones):
        t.add_net(f"onu{z}", hw.ONU_AP)
    t.add_net("olt0", hw.OLT)
    t.add_net("mrouter0", hw.METRO_ROUTER)
    t.add_net("mswitch0", hw.METRO_SWITCH)
    for c in range(14):
        t.add_net(f"core{c}", hw.IPWDM_NODE)
    t.add_net("af_router0", hw.LOW_END_ROUTER)
    t.add_net("af_switch0", hw.LOW_END_SWITCH)
    t.add_net("mf_router0", hw.LOW_END_ROUTER)
    t.add_net("mf_switch0", hw.LOW_END_SWITCH)

    for i in range(n_iot):
        t.connect(f"iot{i}", f"onu{i % n_zones}")
    for z in range(n_zones):
        t.connect(f"onu{z}", "olt0")
    t.connect("olt0", "af_router0")
    t.connect("af_router0", "af_switch0")
    t.connect("af_switch0", "af0")
    t.connect("olt0", "mrouter0")
    t.connect("mrouter0", "mswitch0")
    t.connect("mswitch0", "mf_router0")
    t.connect("mf_router0", "mf_switch0")
    t.connect("mf_switch0", "mf0")
    t.connect("mswitch0", f"core{access_core}")
    for a, b in NSFNET_EDGES:
        t.connect(f"core{a}", f"core{b}")
    t.connect("cdc0", f"core{cdc_core}")
    return t.finalize()


def datacenter_topology(n_edge: int = 8, n_fog: int = 2) -> CFNTopology:
    """Beyond-paper preset: TPU-pod-class nodes in the same CFN shape.

    Edge pods sit behind access DCN switches, fog pods behind a metro DCN
    switch, and the cloud pod behind a WAN router pair -- the datacenter
    analogue of Fig. 1 used to place the assigned LM architectures.
    """
    t = CFNTopology()
    for i in range(n_edge):
        t.add_proc(f"edge{i}", hw.EDGE_POD, LAYER_IOT)
    for i in range(n_fog):
        t.add_proc(f"fog{i}", hw.FOG_POD, LAYER_AF if i == 0 else LAYER_MF)
    t.add_proc("cloud0", hw.CLOUD_POD, LAYER_CDC)

    n_acc = max(1, n_edge // 4)
    for z in range(n_acc):
        t.add_net(f"acc{z}", hw.DCN_SWITCH)
    t.add_net("agg0", hw.DCN_SWITCH)
    t.add_net("wan0", hw.WAN_ROUTER)
    t.add_net("wan1", hw.WAN_ROUTER)

    for i in range(n_edge):
        t.connect(f"edge{i}", f"acc{i % n_acc}")
    for z in range(n_acc):
        t.connect(f"acc{z}", "agg0")
    for i in range(n_fog):
        t.connect(f"fog{i}", "agg0")
    t.connect("agg0", "wan0")
    t.connect("wan0", "wan1")
    t.connect("cloud0", "wan1")
    return t.finalize()
