"""Virtual Service Requests (VSRs): the paper's workload abstraction.

A VSR is a small directed graph of VMs; each VM carries a processing demand
F^{r,s} (GFLOPS) and each virtual link a bitrate H^{r,s,d} (Mbps).  VM 0 is the
*input* VM, pinned to the source IoT node (paper Eq. 4).

Two generators:
  * ``random_vsrs``      -- the paper's §3 workload: F ~ U(3, 10) GFLOPS,
                            input VM ~ U(0.1, 1) GFLOPS, chain virtual topology
                            (a DNN is a layer chain), bitrates ~ U(5, 50) Mbps
                            (paper does not print bitrates; DESIGN.md §2).
  * ``from_layer_costs`` -- build a VSR from real per-layer FLOP counts and
                            activation sizes of one of the assigned
                            architectures (see models/costs.py), cut into
                            pipeline stages.  This makes the paper's "each VM
                            represents a layer of a DNN model" concrete.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import numpy as np


@dataclass
class VSRBatch:
    """R VSRs, each with V VMs (rectangular; pad with zero-demand VMs)."""

    F: np.ndarray           # [R, V] GFLOPS demand per VM
    H: np.ndarray           # [R, V, V] Mbps on virtual link (s -> d)
    src: np.ndarray         # [R] source IoT processing-node index
    input_vm: np.ndarray    # [R] index of the input VM (always 0 here)

    @property
    def R(self) -> int:
        return self.F.shape[0]

    @property
    def V(self) -> int:
        return self.F.shape[1]

    def links(self):
        """Flattened virtual links: (link_src, link_dst, link_h).

        Indices are into the flattened [R*V] VM space.
        """
        r, s, d = np.nonzero(self.H)
        link_src = (r * self.V + s).astype(np.int32)
        link_dst = (r * self.V + d).astype(np.int32)
        link_h = self.H[r, s, d].astype(np.float32)
        return link_src, link_dst, link_h

    def concat(self, other: "VSRBatch") -> "VSRBatch":
        """Concatenate batches, padding to the wider VM count with
        zero-demand VMs (zero-F, zero-H VMs never affect the objective)."""
        V = max(self.V, other.V)
        def pad(b: "VSRBatch") -> "VSRBatch":
            d = V - b.V
            if d == 0:
                return b
            return VSRBatch(
                F=np.pad(b.F, ((0, 0), (0, d))),
                H=np.pad(b.H, ((0, 0), (0, d), (0, d))),
                src=b.src, input_vm=b.input_vm)
        a, b = pad(self), pad(other)
        return VSRBatch(
            F=np.concatenate([a.F, b.F]),
            H=np.concatenate([a.H, b.H]),
            src=np.concatenate([a.src, b.src]),
            input_vm=np.concatenate([a.input_vm, b.input_vm]),
        )


def random_vsrs(n_vsrs: int,
                rng: np.random.Generator | int = 0,
                n_vms: int = 3,
                source_nodes: Sequence[int] = (0,),
                vm_gflops=(3.0, 10.0),
                input_gflops=(0.1, 1.0),
                link_mbps=(5.0, 50.0),
                topology: str = "chain") -> VSRBatch:
    """Paper §3 workload generator.

    The paper uses a *single* IoT device as the source of all VSRs; pass more
    ``source_nodes`` to distribute sources (sensitivity studies).
    """
    rng = np.random.default_rng(rng) if isinstance(rng, int) else rng
    R, V = n_vsrs, n_vms
    F = rng.uniform(*vm_gflops, size=(R, V)).astype(np.float32)
    F[:, 0] = rng.uniform(*input_gflops, size=R)
    H = np.zeros((R, V, V), dtype=np.float32)
    if topology == "chain":
        for v in range(V - 1):
            H[:, v, v + 1] = rng.uniform(*link_mbps, size=R)
    elif topology == "star":
        for v in range(1, V):
            H[:, 0, v] = rng.uniform(*link_mbps, size=R)
    elif topology == "dag":
        for s in range(V):
            for d in range(s + 1, V):
                mask = rng.random(R) < 0.5
                H[mask, s, d] = rng.uniform(*link_mbps, size=mask.sum())
        # guarantee connectivity through the chain
        for v in range(V - 1):
            zero = H[:, v, v + 1] == 0
            H[zero, v, v + 1] = rng.uniform(*link_mbps, size=zero.sum())
    else:
        raise ValueError(f"unknown virtual topology {topology!r}")
    src = np.asarray(rng.choice(source_nodes, size=R), dtype=np.int32)
    input_vm = np.zeros(R, dtype=np.int32)
    return VSRBatch(F=F, H=H, src=src, input_vm=input_vm)


def from_layer_costs(layer_gflop_per_token: Sequence[float],
                     layer_act_bytes: Sequence[float],
                     tokens_per_s: float,
                     n_stages: int,
                     source_node: int = 0,
                     input_gflop_per_token: float = 1e-4,
                     input_act_bytes: float | None = None) -> VSRBatch:
    """Convert a real DNN (per-layer costs) into a single VSR.

    Stage VM demand  = sum of member-layer GFLOP/token * tokens/s.
    Inter-stage link = boundary activation bytes * tokens/s * 8 bits -> Mbps,
    where the boundary crossing stage s-1 -> s carries the OUTPUT of the last
    layer of stage s-1.  The input-VM -> stage-1 link carries the embedding
    output, ``input_act_bytes`` (when None, approximated by
    ``layer_act_bytes[0]`` -- exact for transformers, whose embedding output
    has a block's hidden size).  VM 0 is the input/embedding VM pinned at
    the source (a camera / sensor gateway in the paper's story).

    ``n_stages`` > L is clamped to L (one layer per stage is the finest
    cut -- avoids silently-zero-demand stages); ``n_stages`` < 1 raises.
    """
    L = len(layer_gflop_per_token)
    if L < 1 or len(layer_act_bytes) != L:
        raise ValueError(f"need matching non-empty layer costs, got L={L} "
                         f"and {len(layer_act_bytes)} activation sizes")
    if n_stages < 1:
        raise ValueError(f"n_stages must be >= 1, got {n_stages}")
    n_stages = min(n_stages, L)
    if input_act_bytes is None:
        input_act_bytes = float(layer_act_bytes[0])
    # spacing L/n_stages >= 1, so rounded bounds are strictly increasing:
    # every stage owns at least one layer
    bounds = np.linspace(0, L, n_stages + 1).round().astype(int)
    V = n_stages + 1  # + input VM
    F = np.zeros((1, V), dtype=np.float32)
    H = np.zeros((1, V, V), dtype=np.float32)
    F[0, 0] = input_gflop_per_token * tokens_per_s
    for s in range(n_stages):
        lo, hi = bounds[s], bounds[s + 1]
        F[0, s + 1] = float(np.sum(layer_gflop_per_token[lo:hi])) * tokens_per_s
        boundary_bytes = layer_act_bytes[lo - 1] if s > 0 else input_act_bytes
        H[0, s, s + 1] = boundary_bytes * tokens_per_s * 8.0 / 1e6  # Mbps
    return VSRBatch(F=F, H=H,
                    src=np.array([source_node], dtype=np.int32),
                    input_vm=np.zeros(1, dtype=np.int32))


def from_architecture(arch_cfg, *, tokens_per_s: float = 50.0,
                      n_stages: int = 4, context: int = 2048,
                      source_node: int = 0) -> VSRBatch:
    """Turn one of the assigned architectures into a VSR (paper §2.2 made
    concrete: "each VM represents a layer of a DNN model").

    Per-layer inference GFLOP/token and boundary activation bytes come from
    models.costs.layer_costs (derived from the real parameter tree); layers
    are grouped into ``n_stages`` pipeline-stage VMs, the input/embedding VM
    is pinned at the source (the camera / sensor gateway -- the VLM patch
    stub is the cleanest instance).
    """
    from ..models.costs import layer_costs
    gflops, act_bytes = layer_costs(arch_cfg, context=context)
    emb_gflop = 2.0 * arch_cfg.d_model / 1e9  # embedding lookup-ish
    emb_bytes = 2.0 * arch_cfg.d_model        # bf16 hidden state per token
    return from_layer_costs(gflops, act_bytes, tokens_per_s, n_stages,
                            source_node=source_node,
                            input_gflop_per_token=emb_gflop,
                            input_act_bytes=emb_bytes)
