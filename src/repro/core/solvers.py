"""Placement solvers for the CFN embedding problem.

The paper solves the MILP with CPLEX (24 cores, 126 GB).  CPLEX is not
available offline, and the contribution we reproduce is the *formulation* and
its energy trade-offs, so we provide a solver suite whose strongest member
(`solve_cfn`, coordinate-descent restarts x batched simulated annealing,
cross-validated by exhaustive enumeration on small instances) acts as the
CPLEX stand-in.  The hot solvers (coordinate, anneal) run on power.py's
incremental delta-evaluation engine -- a proposal changes one VM, so only
the touched load-tensor entries are re-scored; whole-placement evaluation
(exhaustive, genetic) stays on the batched tensor objective (optionally the
Pallas kernel in kernels/placement_power, which also provides a fused
annealing kernel keeping chain state resident in VMEM).

Solvers:
  fixed_layer   -- the paper's CDC / AF / MF baselines (+ IoT first-fit).
  coordinate    -- exact best-single-move sweeps via delta_sweep (monotone).
  exhaustive    -- provably optimal joint enumeration (small instances).
  anneal        -- Metropolis chains on incremental state (delta / fused
                   Pallas / legacy full-objective backends).
  genetic       -- population crossover/mutation search.
  relax           -- differentiable soft-placement + rounding (beyond-paper).
  solve_portfolio -- spec-driven portfolio = best of the above; the
                     "CFN MILP" curve (solve_cfn is its deprecated shim).

Every solver takes an optional ``eligible`` [R, P] mask -- the one
constraint surface ``repro.api.PlacementSpec.masks`` produces -- so SLA
hop bounds are enforced identically in coordinate sweeps, every anneal
backend's Metropolis proposals (one proposal stream feeds the delta scan,
the fused Pallas kernel, and the legacy full-objective path), genetic
search, exhaustive enumeration, and the relaxation.
"""
from __future__ import annotations

import functools
import warnings
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .power import (PENALTY, PlacementAux, PlacementProblem, PlacementState,
                    PowerBreakdown, apply_move, apply_pins,
                    batched_hard_loads, build_aux, delta_sweep, evaluate,
                    init_state, objective, objective_batch, _commit_entries,
                    _delta_objective, _move_core)
from .topology import CFNTopology


@dataclass
class SolveResult:
    X: np.ndarray                 # [R, V] placement (pins applied)
    breakdown: PowerBreakdown
    method: str
    history: List[float] = field(default_factory=list)
    # convergence trace (record_conv=True on the anneal paths): fixed-length
    # per effort bucket -- {"best_obj": [n_steps], "accept_rate": [n_steps]}
    conv: Optional[Dict[str, np.ndarray]] = None

    @property
    def objective(self) -> float:
        return float(self.breakdown.objective)

    @property
    def power(self) -> float:
        return float(self.breakdown.total)

    @property
    def feasible(self) -> bool:
        return float(self.breakdown.violation) <= 1e-6


# Fresh-compile counters for the jitted solver entries.  Tests and
# benchmarks assert on these to pin the compile-stability story (one trace
# per shape bucket; fail/recover events never retrace) -- see
# tests/test_faults.py, tests/test_federation.py, benchmarks/kernel_bench.py.
TRACE_COUNTS: Dict[str, int] = {}

# Compile-attribution hooks (repro.telemetry): called once per fresh trace
# with (entry name, abstract shape fingerprint).  Hooks run at TRACE time
# on the host -- they must not touch traced values beyond static
# attributes (.shape/.dtype), which _trace_fingerprint respects.
TRACE_HOOKS: List = []

_FINGERPRINT_MAX_LEAVES = 16


def _trace_fingerprint(args, kwargs) -> str:
    """Abstract shape fingerprint of a jitted call's arguments: per pytree
    leaf ``dtype[shape]`` (static attribute reads only -- safe on tracers),
    scalars by repr, other statics by type name.  Capped at
    ``_FINGERPRINT_MAX_LEAVES`` leaves per argument."""
    parts = []
    for a in list(args) + [kwargs[k] for k in sorted(kwargs)]:
        leaves = jax.tree_util.tree_leaves(a)
        if not leaves:
            parts.append("()" if a is None else type(a).__name__)
            continue
        sub = []
        for leaf in leaves[:_FINGERPRINT_MAX_LEAVES]:
            shp = getattr(leaf, "shape", None)
            if shp is not None:
                dt = getattr(leaf, "dtype", "?")
                sub.append(f"{dt}[{','.join(str(d) for d in shp)}]")
            else:
                sub.append(repr(leaf) if isinstance(
                    leaf, (bool, int, float, str)) else type(leaf).__name__)
        if len(leaves) > _FINGERPRINT_MAX_LEAVES:
            sub.append(f"+{len(leaves) - _FINGERPRINT_MAX_LEAVES}")
        tag = type(a).__name__
        parts.append("x".join(sub) if tag in ("ArrayImpl", "DynamicJaxprTracer",
                                              "ndarray") and len(sub) == 1
                     else f"{tag}({','.join(sub)})")
    return ";".join(parts)


def count_traces(name: str):
    """Mark a jitted solver entry: ``TRACE_COUNTS[name]`` ticks once per
    fresh TRACE (i.e. per compile), not per call.

    Apply UNDER ``jax.jit`` -- the wrapper body then runs only while jax
    traces the function, so cache hits leave the counter untouched::

        @jax.jit
        @count_traces("sweep")
        def _sweep(...): ...

    ``functools.wraps`` carries the signature through (``__wrapped__``),
    so ``jax.jit(..., static_argnames=...)`` over a counted function still
    resolves argument names.  Rule CFN104 (``repro.analysis``) enforces
    this pattern on every jitted entry here and in ``core.federation``.

    ``TRACE_HOOKS`` (registered by ``repro.telemetry``) observe each fresh
    trace with the entry name and the abstract shape fingerprint jax is
    tracing at -- the compile-attribution record.
    """
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            TRACE_COUNTS[name] = TRACE_COUNTS.get(name, 0) + 1
            if TRACE_HOOKS:
                fp = _trace_fingerprint(args, kwargs)
                for hook in list(TRACE_HOOKS):
                    hook(name, fp)
            return fn(*args, **kwargs)
        return wrapper
    return deco


_evaluate_jit = jax.jit(evaluate)  # shared wrapper: one trace per shape


def _result(problem: PlacementProblem, X, method: str,
            history: Optional[List[float]] = None) -> SolveResult:
    X = np.asarray(apply_pins(problem, jnp.asarray(X, jnp.int32)))
    bd = _evaluate_jit(problem, jnp.asarray(X))
    return SolveResult(X=X, breakdown=jax.device_get(bd), method=method,
                       history=history or [])


# ---------------------------------------------------------------------------
# Fixed-layer baselines (paper Fig. 3 scenarios)
# ---------------------------------------------------------------------------

def fixed_layer(problem: PlacementProblem, topo: CFNTopology,
                layer: str, spill_layer: str = "cdc") -> SolveResult:
    """All non-input VMs at `layer`; first-fit-decreasing across that layer's
    nodes honoring GFLOPS capacity; overflow spills to ``spill_layer``
    (the paper's observed behaviour at 20 VSRs)."""
    nodes = topo.layer_indices(layer)
    spill = topo.layer_indices(spill_layer)
    # host-side FFD accounting, never traced
    cap = np.array([topo.proc_hw[p].cap_gflops * topo.proc_hw[p].n_servers
                    for p in range(topo.P)],
                   dtype=np.float64)  # tracelint: allow[CFN102]
    load = np.zeros(topo.P)
    F = np.asarray(problem.F)
    fixed_mask = np.asarray(problem.fixed_mask)
    fixed_node = np.asarray(problem.fixed_node)
    R, V = F.shape
    # account pinned input VMs first
    for r in range(R):
        for v in range(V):
            if fixed_mask[r, v]:
                load[fixed_node[r, v]] += F[r, v]
    X = np.zeros((R, V), dtype=np.int32)
    order = sorted(((r, v) for r in range(R) for v in range(V)
                    if not fixed_mask[r, v]),
                   key=lambda rv: -F[rv])
    for (r, v) in order:
        placed = False
        for p in sorted(nodes, key=lambda p: load[p]):
            if load[p] + F[r, v] <= cap[p] + 1e-9:
                X[r, v] = p
                load[p] += F[r, v]
                placed = True
                break
        if not placed:
            for p in sorted(spill, key=lambda p: load[p]):
                if load[p] + F[r, v] <= cap[p] + 1e-9:
                    X[r, v] = p
                    load[p] += F[r, v]
                    placed = True
                    break
        if not placed:  # genuinely infeasible; dump on first node
            X[r, v] = nodes[0]
            load[nodes[0]] += F[r, v]
    return _result(problem, X, f"fixed:{layer}")


# ---------------------------------------------------------------------------
# Coordinate descent (exact single-VM moves, scored by the delta engine)
# ---------------------------------------------------------------------------

# objective placeholder for masked-out (SLA-ineligible) destinations: large
# enough to lose every argmin, small enough to stay finite in float32 sums
_INELIGIBLE = 1.0e30


def _eligible_np(eligible: Optional[np.ndarray]):
    """Normalize an [R, P] eligibility mask for the solver paths.

    Returns ``(el, cnt, cand)``: the bool mask with no-eligible-node rows
    fallen back to all-True (a row that cannot satisfy its SLA is placed
    best-effort rather than nowhere), per-row eligible counts [R], and the
    per-row candidate table [R, P] (eligible node ids left-packed) that
    Metropolis destination sampling draws from.  ``(None, None, None)``
    when unmasked.
    """
    if eligible is None:
        return None, None, None
    el = np.asarray(eligible, bool).copy()
    dead = ~el.any(axis=1)
    el[dead] = True
    cnt = el.sum(axis=1).astype(np.int32)
    cand = np.zeros(el.shape, np.int32)
    for r in range(el.shape[0]):
        ids = np.nonzero(el[r])[0]
        cand[r, :len(ids)] = ids
    return el, cnt, cand


def _sample_eligible(u: jnp.ndarray, rows: jnp.ndarray,
                     cnt: jnp.ndarray, cand: jnp.ndarray) -> jnp.ndarray:
    """Map uniform draws ``u`` to eligible destination nodes for service
    rows ``rows`` (broadcast against ``u``) -- the ONE sampling map behind
    every masked random draw: Metropolis proposal streams (pure-JAX delta
    scan, fused Pallas kernel, legacy full-objective backend), restart
    chains, and genetic init/mutation."""
    c = cnt[rows]
    idx = jnp.minimum((u * c).astype(jnp.int32), c - 1)
    return cand[rows, idx]


def _project_eligible(problem: PlacementProblem, X,
                      el_np: np.ndarray):
    """Move every free VM sitting on an ineligible node to its row's first
    eligible node (warm starts handed to masked solvers must start inside
    the constraint set; the solver then optimizes within it).

    Returns ``(X_proj, moved)``.  ``moved`` is a host-side bool (any VM
    actually relocated) computed from the numpy ``bad`` mask, so warm
    callers can decide whether to rebuild state WITHOUT a device round
    trip -- comparing ``X_proj`` against the incumbent on-device
    (``bool((X0 == state.X).all())``) is exactly the per-event blocking
    sync rule CFN101 exists to flag.  A bad entry always relocates (its
    current node is ineligible, the target is eligible), and pins are
    never bad, so ``moved`` is exact."""
    Xn = np.asarray(X).copy()
    fixed = np.asarray(problem.fixed_mask)
    first = el_np.argmax(axis=1).astype(Xn.dtype)
    rows = np.arange(Xn.shape[0])[:, None]
    bad = ~el_np[rows, Xn] & ~fixed
    proj = jnp.asarray(np.where(bad, first[:, None], Xn), jnp.int32)
    return proj, bool(bad.any())


@jax.jit
@count_traces("sweep")
def _sweep(problem: PlacementProblem, aux: PlacementAux,
           state: PlacementState, positions: jnp.ndarray,
           eligible: Optional[jnp.ndarray] = None):
    """One pass over all free VM positions; each VM moved to its best node.

    Destinations are scored by ``delta_sweep`` (one removal + vectorized
    insertion) instead of broadcasting P full candidate placements.
    ``eligible`` [R, P] (optional) masks destinations per service row --
    the SLA hop/eligibility constraint of embed_latency_bounded threaded
    into the sweep.  ``positions`` may contain repeated rows (shape-bucket
    padding): re-sweeping a VM is idempotent up to its own argmin."""
    def body(state, pos):
        r, v = pos[0], pos[1]
        obj_all = delta_sweep(problem, aux, state, r, v)
        if eligible is not None:
            obj_all = jnp.where(eligible[r], obj_all, _INELIGIBLE)
        best = jnp.argmin(obj_all)
        state = apply_move(problem, aux, state, r, v,
                           best.astype(state.X.dtype))
        return state, obj_all[best]

    state, objs = jax.lax.scan(body, state, positions)
    return state, objs[-1]


def coordinate(problem: PlacementProblem, X0: np.ndarray,
               max_sweeps: int = 12, tol: float = 1e-6,
               eligible: Optional[np.ndarray] = None) -> SolveResult:
    """Exact best-single-move sweeps.  ``eligible`` [R, P] (optional) masks
    each service row's destination nodes in every sweep argmin; X0 need not
    satisfy the mask (the first sweep moves every free VM onto it, and the
    incumbent is only ever taken from post-sweep states)."""
    aux = build_aux(problem)
    el_np, _, _ = _eligible_np(eligible)
    el_j = None if el_np is None else jnp.asarray(el_np)
    positions = jnp.asarray(np.asarray(aux.free_pos))
    if positions.shape[0] == 0:  # every VM pinned: nothing to move
        return _result(problem, jnp.asarray(X0, jnp.int32), "coordinate")
    state = init_state(problem, jnp.asarray(X0, jnp.int32))
    # a masked solve may not trust an (ineligible) warm start as incumbent
    best_obj = float("inf") if el_np is not None else float(state.obj)
    best_X = state.X
    history: List[float] = []
    for _ in range(max_sweeps):
        state, _ = _sweep(problem, aux, state, positions, el_j)
        # exact refresh once per sweep: kills float32 drift and yields an
        # exact (incumbent-best, hence monotone) history
        state = init_state(problem, state.X)
        obj = float(state.obj)
        if obj < best_obj:
            best_obj, best_X = obj, state.X
        history.append(best_obj)
        if len(history) > 1 and history[-2] - obj < tol:
            break
    return _result(problem, best_X, "coordinate", history)


# ---------------------------------------------------------------------------
# Exhaustive enumeration (ground truth on small instances)
# ---------------------------------------------------------------------------

def exhaustive(problem: PlacementProblem, max_combos: int = 2_000_000,
               chunk: int = 8192,
               eligible: Optional[np.ndarray] = None) -> SolveResult:
    fixed_mask = np.asarray(problem.fixed_mask)
    free = np.argwhere(~fixed_mask)
    P = problem.P
    n_free = len(free)
    n_combos = P ** n_free
    if n_combos > max_combos:
        raise ValueError(f"{n_combos} combos exceed cap {max_combos}")
    el_np, _, _ = _eligible_np(eligible)
    R, V = fixed_mask.shape
    base = np.zeros((R, V), dtype=np.int32)
    best_obj, best_X = float("inf"), base
    for start in range(0, n_combos, chunk):
        idx = np.arange(start, min(start + chunk, n_combos))
        digits = np.empty((len(idx), n_free), dtype=np.int32)
        rem = idx.copy()
        for j in range(n_free - 1, -1, -1):
            digits[:, j] = rem % P
            rem //= P
        Xb = np.broadcast_to(base, (len(idx), R, V)).copy()
        Xb[:, free[:, 0], free[:, 1]] = digits
        obj = np.asarray(objective_batch(problem, jnp.asarray(Xb)))
        if el_np is not None:
            valid = el_np[free[None, :, 0], digits].all(axis=1)
            obj = np.where(valid, obj, np.inf)
        k = int(np.argmin(obj))
        if obj[k] < best_obj:
            best_obj, best_X = float(obj[k]), Xb[k]
    if not np.isfinite(best_obj):
        raise ValueError("no placement satisfies the eligibility mask")
    return _result(problem, best_X, "exhaustive", [best_obj])


# ---------------------------------------------------------------------------
# Batched simulated annealing
# ---------------------------------------------------------------------------

def _chain_step(problem: PlacementProblem, aux: PlacementAux,
                Xf, omega, theta, lam, obj, j, p_new):
    """One Metropolis proposal on ONE chain's incremental state.

    Returns the candidate state + exact objective delta; the caller decides
    acceptance.  vmapped over chains inside the anneal scan.  All updates
    are entry-wise (iota-compare selects, no [P]-wide temporaries and no
    vmapped scalar scatters, which serialize on XLA CPU)."""
    _, idx, om2, th2, lm2, _ = _move_core(problem, aux, Xf, omega, theta,
                                          lam, j, p_new)
    delta = _delta_objective(problem, omega, theta, lam, idx, om2, th2, lm2)
    Xf2 = jnp.where(jnp.arange(Xf.shape[0]) == j, p_new, Xf)
    omega2 = _commit_entries(omega, idx, om2)
    theta2 = _commit_entries(theta, idx, th2)
    return Xf2, omega2, theta2, lm2, obj + delta, delta


def _anneal_proposals(key: jax.Array, aux: PlacementAux, n_steps: int,
                      n_chains: int, P: int, V: Optional[int] = None,
                      cnt: Optional[np.ndarray] = None,
                      cand: Optional[np.ndarray] = None):
    """Free-position Metropolis proposals: flat VM index, destination, u.

    Pinned input VMs are never proposed (their placement is fixed by
    Eq. 4), so every step is a real move.  With ``cnt``/``cand`` (an
    eligibility table from ``_eligible_np``), destinations are sampled
    from the proposed VM's row-eligible set only -- the single proposal
    stream every anneal backend (delta scan, fused Pallas kernel, legacy
    full-objective) consumes, so SLA masking is enforced identically in
    all of them."""
    kf, kp, ka = jax.random.split(key, 3)
    M = aux.free_pos.shape[0]
    fi = jax.random.randint(kf, (n_steps, n_chains), 0, M)
    if cnt is None:
        p_prop = jax.random.randint(kp, (n_steps, n_chains), 0, P, jnp.int32)
    else:
        # masked branch draws from a fold_in-derived stream: independent of
        # the unmasked randint above, and the unmasked path stays
        # byte-identical (CFN106: one key, one draw)
        rows = aux.free_flat[fi] // V
        u_dst = jax.random.uniform(jax.random.fold_in(kp, 1),
                                   (n_steps, n_chains))
        p_prop = _sample_eligible(u_dst, rows, jnp.asarray(cnt),
                                  jnp.asarray(cand))
    u = jax.random.uniform(ka, (n_steps, n_chains))
    return fi, p_prop, u


def anneal(problem: PlacementProblem, key: jax.Array, X0: np.ndarray,
           n_chains: int = 32, n_steps: int = 4000,
           t0: float = 50.0, t1: float = 0.05,
           backend: str = "auto",
           eligible: Optional[np.ndarray] = None,
           record_conv: bool = False) -> SolveResult:
    """Batched Metropolis chains on incremental (delta-evaluated) state.

    backend:
      * ``"delta"`` -- pure-JAX scan; per-chain loads updated in
        O(deg*N + P) per step (no full objective per proposal).
      * ``"fused"`` -- the Pallas kernel in kernels/placement_power: chain
        state stays resident in VMEM and proposal -> delta-eval -> accept is
        fused across all steps in ONE kernel launch.
      * ``"full"``  -- legacy full `objective_batch` per step (kept as the
        benchmark baseline).
      * ``"auto"``  -- fused on TPU, delta elsewhere.

    ``eligible`` [R, P] (optional) restricts each service row's destination
    nodes: the warm start is projected onto the mask, restart chains are
    sampled from it, and every backend's proposal destinations are drawn
    from it (one proposal stream feeds all three), so no chain ever leaves
    the constraint set.

    ``record_conv=True`` attaches the per-step convergence trace to the
    result (``SolveResult.conv``: best-objective + acceptance-rate arrays,
    length ``n_steps`` -- fixed per effort bucket).  The jitted scans
    always COMPUTE the trace; the flag only materializes it host-side, so
    recording can never retrace (CFN108).  Not available on the fused
    Pallas backend (chain state stays in VMEM).
    """
    R, V, P = problem.R, problem.V, problem.P
    if backend == "auto":
        backend = "fused" if jax.default_backend() == "tpu" else "delta"
    if backend not in ("delta", "fused", "full"):
        raise ValueError(f"unknown anneal backend {backend!r}")
    aux = build_aux(problem)
    if aux.free_pos.shape[0] == 0:
        # every VM is pinned (e.g. single-VM VSRs): nothing to anneal
        return _result(problem, jnp.asarray(X0, jnp.int32), "anneal")
    el_np, cnt_np, cand_np = _eligible_np(eligible)
    k_init, k_prop = jax.random.split(key)
    X = apply_pins(problem, jnp.asarray(X0, jnp.int32))
    if el_np is not None:
        Xp, _ = _project_eligible(problem, X, el_np)
        X = apply_pins(problem, Xp)
    Xc = jnp.broadcast_to(X, (n_chains, R, V)).copy()
    # randomize all but chain 0 (keep one chain at the warm start)
    if el_np is None:
        rand = jax.random.randint(k_init, (n_chains, R, V), 0, P, jnp.int32)
    else:
        # restarted chains must also start on eligible nodes (fold_in:
        # independent of the unmasked randint, which stays byte-identical)
        u_r = jax.random.uniform(jax.random.fold_in(k_init, 1),
                                 (n_chains, R, V))
        rand = _sample_eligible(u_r, jnp.arange(R)[None, :, None],
                                jnp.asarray(cnt_np), jnp.asarray(cand_np))
    keep = (jnp.arange(n_chains) == 0)[:, None, None]
    Xc = jax.vmap(lambda x: apply_pins(problem, x))(jnp.where(keep, Xc, rand))

    temps = t0 * (t1 / t0) ** (jnp.arange(n_steps) / max(1, n_steps - 1))
    fi, p_prop, u_prop = _anneal_proposals(k_prop, aux, n_steps, n_chains, P,
                                           V=V, cnt=cnt_np, cand=cand_np)
    j_prop = aux.free_flat[fi]                            # [n_steps, n_chains]

    if backend == "fused":
        from ..kernels import ops as kops
        el_j = None if el_np is None else jnp.asarray(el_np)
        bXc, stats = kops.fused_anneal(problem, aux, Xc, j_prop.T, p_prop.T,
                                       u_prop.T, temps, eligible=el_j)
        k = int(jnp.argmin(stats[:, 0]))
        return _result(problem, np.asarray(bXc[k]), "anneal(fused)",
                       [float(stats[k, 0])])
    if backend == "full":
        bX, bobj, hist = _anneal_scan_full(problem, Xc, j_prop, p_prop,
                                           u_prop, temps)
    else:
        bX, bobj, hist = _anneal_scan_delta(problem, aux, Xc, j_prop, p_prop,
                                            u_prop, temps)
    tag = "anneal" if backend == "delta" else f"anneal({backend})"
    res = _result(problem, np.asarray(bX), tag,
                  [float(h) for h in
                   np.asarray(hist[0][:: max(1, n_steps // 50)])])
    if record_conv:
        res.conv = {"best_obj": np.asarray(hist[0]),
                    "accept_rate": np.asarray(hist[1])}
    return res


@jax.jit
@count_traces("anneal_delta")
def _anneal_scan_delta(problem: PlacementProblem, aux: PlacementAux,
                       Xc, j_prop, p_prop, u_prop, temps):
    """Metropolis chains on incremental per-chain load state (module-level
    jit: compiles once per problem/chain/step shape, not per solve)."""
    n_chains, R, V = Xc.shape
    Xf = Xc.reshape(n_chains, -1)
    omega, theta, lam, obj = batched_hard_loads(problem, Xc)

    step_fn = jax.vmap(
        lambda Xf, om, th, lm, ob, j, pn: _chain_step(
            problem, aux, Xf, om, th, lm, ob, j, pn))

    def step(carry, inp):
        Xf, omega, theta, lam, obj, bX, bobj = carry
        j, pn, u, T = inp
        Xf2, om2, th2, lm2, obj2, delta = step_fn(
            Xf, omega, theta, lam, obj, j, pn)
        acc = (delta < 0) | (u < jnp.exp(-jnp.maximum(delta, 0.0) / T))
        a1 = acc[:, None]
        Xf = jnp.where(a1, Xf2, Xf)
        omega = jnp.where(a1, om2, omega)
        theta = jnp.where(a1, th2, theta)
        lam = jnp.where(a1, lm2, lam)
        obj = jnp.where(acc, obj2, obj)
        better = obj < bobj
        bX = jnp.where(better[:, None], Xf, bX)
        bobj = jnp.where(better, obj, bobj)
        # per-step convergence trace: incumbent best + acceptance fraction
        # (unconditional outputs -- emitting both keeps the jit cache
        # key-space identical whether or not a caller records them)
        return (Xf, omega, theta, lam, obj, bX, bobj), \
            (bobj.min(), acc.mean())

    init = (Xf, omega, theta, lam, obj, Xf, obj)
    (_, _, _, _, _, bX, bobj), hist = jax.lax.scan(
        step, init, (j_prop, p_prop, u_prop, temps))
    k = jnp.argmin(bobj)
    return bX[k].reshape(R, V), bobj[k], hist


@jax.jit
@count_traces("anneal_full")
def _anneal_scan_full(problem: PlacementProblem, Xc, j_prop, p_prop,
                      u_prop, temps):
    """Legacy annealing: one full batched objective per Metropolis step.

    Kept as the benchmark baseline the delta/fused paths are measured
    against (benchmarks/kernel_bench.py)."""
    n_chains, R, V = Xc.shape
    obj0 = objective_batch(problem, Xc)

    def step(carry, inp):
        Xc, obj, bX, bobj = carry
        j, p, u, T = inp
        ci = jnp.arange(n_chains)
        Xp = Xc.reshape(n_chains, -1).at[ci, j].set(p).reshape(Xc.shape)
        objp = objective_batch(problem, Xp)
        acc = (objp < obj) | (u < jnp.exp(-(objp - obj) / T))
        Xc = jnp.where(acc[:, None, None], Xp, Xc)
        obj = jnp.where(acc, objp, obj)
        better = obj < bobj
        bX = jnp.where(better[:, None, None], Xc, bX)
        bobj = jnp.where(better, obj, bobj)
        return (Xc, obj, bX, bobj), (bobj.min(), acc.mean())

    init = (Xc, obj0, Xc, obj0)
    (_, _, bX, bobj), hist = jax.lax.scan(
        step, init, (j_prop, p_prop, u_prop, temps))
    k = jnp.argmin(bobj)
    return bX[k], bobj[k], hist


# ---------------------------------------------------------------------------
# Genetic search
# ---------------------------------------------------------------------------

def genetic(problem: PlacementProblem, key: jax.Array, X0: np.ndarray,
            pop: int = 64, gens: int = 300, p_mut: float = 0.08,
            eligible: Optional[np.ndarray] = None) -> SolveResult:
    """Population search.  ``eligible`` [R, P] (optional): the elite is
    projected onto the mask, the initial population and every mutation are
    sampled from it, and crossover swaps whole service rows between two
    eligible parents -- so every individual ever evaluated is eligible."""
    R, V, P = problem.R, problem.V, problem.P
    el_np, cnt_np, cand_np = _eligible_np(eligible)
    k_init, k_scan = jax.random.split(key)
    elite = jnp.asarray(X0, jnp.int32)
    if el_np is None:
        Xp = jax.random.randint(k_init, (pop, R, V), 0, P, jnp.int32)
        cnt_j = cand_j = None
    else:
        elite, _ = _project_eligible(problem, elite, el_np)
        cnt_j, cand_j = jnp.asarray(cnt_np), jnp.asarray(cand_np)
        u0 = jax.random.uniform(jax.random.fold_in(k_init, 1), (pop, R, V))
        Xp = _sample_eligible(u0, jnp.arange(R)[None, :, None],
                              cnt_j, cand_j)
    Xp = Xp.at[0].set(elite)

    @jax.jit
    def run(Xp, keys):
        def gen(Xp, k):
            k1, k2, k3, k4 = jax.random.split(k, 4)
            fit = objective_batch(problem, Xp)
            # tournament selection
            a = jax.random.randint(k1, (pop,), 0, pop)
            b = jax.random.randint(k2, (pop,), 0, pop)
            parents = jnp.where((fit[a] < fit[b])[:, None, None], Xp[a], Xp[b])
            # per-VSR uniform crossover with a shifted copy
            mask = jax.random.bernoulli(k3, 0.5, (pop, R))[:, :, None]
            mates = jnp.roll(parents, 1, axis=0)
            children = jnp.where(mask, parents, mates)
            # mutation (masked: drawn from each row's eligible set)
            km1, km2 = jax.random.split(k4)
            mut = jax.random.bernoulli(km1, p_mut, (pop, R, V))
            if cnt_j is None:
                rnd = jax.random.randint(km2, (pop, R, V), 0, P, jnp.int32)
            else:
                u_m = jax.random.uniform(jax.random.fold_in(km2, 1),
                                         (pop, R, V))
                rnd = _sample_eligible(u_m, jnp.arange(R)[None, :, None],
                                       cnt_j, cand_j)
            children = jnp.where(mut, rnd, children)
            # elitism: keep the best individual
            best = jnp.argmin(fit)
            children = children.at[0].set(Xp[best])
            return children, fit[best]

        Xp, hist = jax.lax.scan(gen, Xp, keys)
        fit = objective_batch(problem, Xp)
        k = jnp.argmin(fit)
        return Xp[k], fit[k], hist

    bX, bobj, hist = run(Xp, jax.random.split(k_scan, gens))
    return _result(problem, np.asarray(bX), "genetic",
                   [float(h) for h in np.asarray(hist[:: max(1, gens // 50)])])


# ---------------------------------------------------------------------------
# Differentiable relaxation (beyond-paper)
# ---------------------------------------------------------------------------

def relax(problem: PlacementProblem, key: jax.Array,
          steps: int = 800, lr: float = 0.3,
          temp0: float = 5.0, temp1: float = 0.05,
          eligible: Optional[np.ndarray] = None) -> SolveResult:
    """Soft placement: logits -> softmax assignment, smooth power surrogate,
    Adam descent with annealed temperature, then argmax + coordinate repair.
    ``eligible`` [R, P] (optional) pins ineligible nodes' logits to -inf in
    the softmax (zero probability mass) and masks the final repair."""
    R, V, P = problem.R, problem.V, problem.P
    logits = 0.01 * jax.random.normal(key, (R, V, P))
    el_np, _, _ = _eligible_np(eligible)
    bias = (0.0 if el_np is None
            else jnp.where(jnp.asarray(el_np)[:, None, :], 0.0, -1e9))

    def loss_fn(logits, temp):
        logits = logits + bias
        soft = jax.nn.softmax(logits / jnp.maximum(temp, 1e-3), axis=-1)
        bd = evaluate(problem, soft, hard=False, temp=temp)
        # entropy push towards one-hot as temp decays
        ent = -jnp.sum(soft * jnp.log(soft + 1e-9), axis=-1).mean()
        return bd.total + 10.0 * PENALTY_W * bd.violation + 0.1 * ent

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    m = jnp.zeros_like(logits)
    v = jnp.zeros_like(logits)
    b1, b2, eps = 0.9, 0.999, 1e-8
    history = []
    for i in range(steps):
        temp = temp0 * (temp1 / temp0) ** (i / max(1, steps - 1))
        loss, g = grad_fn(logits, temp)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / (1 - b1 ** (i + 1))
        vh = v / (1 - b2 ** (i + 1))
        logits = logits - lr * mh / (jnp.sqrt(vh) + eps)
        if i % max(1, steps // 40) == 0:
            history.append(float(loss))
    X = np.asarray(jnp.argmax(logits + bias, axis=-1), np.int32)
    res = coordinate(problem, X, max_sweeps=4, eligible=eligible)
    return SolveResult(X=res.X, breakdown=res.breakdown, method="relax",
                       history=history + res.history)


PENALTY_W = 100.0  # relative weight of violation in the relaxed loss


# ---------------------------------------------------------------------------
# Online incremental re-embedding (service churn)
# ---------------------------------------------------------------------------

def _pad_positions(pos: np.ndarray, m: Optional[int]) -> np.ndarray:
    """Pad a free-position list to a fixed length by repeating the first row
    (shape bucketing: a repeated sweep position is a harmless re-sweep, and
    a fixed length keeps the jitted ``_sweep`` scan on one compiled shape
    per bucket)."""
    if m is None or pos.shape[0] == 0 or pos.shape[0] >= m:
        return pos
    return np.concatenate(
        [pos, np.tile(pos[:1], (m - pos.shape[0], 1))])


def resolve_incremental(problem: PlacementProblem,
                        prev_X: Optional[np.ndarray] = None,
                        key: Optional[jax.Array] = None,
                        changed_rows: Optional[Sequence[int]] = None,
                        state: Optional[PlacementState] = None,
                        sweeps: Optional[int] = None,
                        anneal_steps: Optional[int] = None,
                        anneal_chains: Optional[int] = None,
                        anneal_t0: Optional[float] = None,
                        anneal_t1: Optional[float] = None,
                        polish_sweeps: Optional[int] = None,
                        eligible: Optional[np.ndarray] = None,
                        pad_positions_to: Optional[int] = None,
                        pad_changed_to: Optional[int] = None,
                        spec=None,
                        record_conv: bool = False) -> SolveResult:
    """Warm-start re-solve after service churn: surviving services stay at
    their previous nodes, only the VMs of ``changed_rows`` (new arrivals /
    rows the caller distrusts) are actively re-placed.

    Three phases, all on the delta engine:
      1. targeted coordinate sweeps over the changed rows' free VMs
         (survivors act as implicit pins -- their positions are never swept);
      2. a short Metropolis refinement: with changed rows, proposals touch
         ONLY those VMs (chains randomized there escape the greedy local
         minimum); without them (a departure), proposals range over ALL
         free VMs with random-restart chains, re-packing survivors;
      3. ``polish_sweeps`` full sweeps over ALL free VMs (monotone).

    ``spec`` (a ``repro.api.PlacementSpec``, optional) supplies the solver
    knobs and -- unless ``eligible`` is passed explicitly -- the constraint
    masks via ``spec.masks(problem)``; explicit keyword arguments override
    the spec.  ``eligible`` [R, P] bool restricts each row's destination
    nodes through every phase (sweep argmins are masked; Metropolis
    destinations are sampled from each row's eligible set).
    ``pad_positions_to`` pads the all-free-VM sweep lists to a fixed length
    so the jitted sweep compiles once per shape bucket
    (core.dynamic.OnlineEmbedder); ``pad_changed_to`` does the same for the
    changed-rows position list (the wave axis -- see ``resolve_wave``).

    This is LOCAL re-optimization -- a periodic full-portfolio defrag
    (`solve_portfolio`) bounds its drift; see core.dynamic.OnlineEmbedder.

    Warm callers that already carry a ``state`` (``power.warm_state``)
    should pass ``prev_X=None``: the previous placement is only read when
    ``state`` is absent, and materializing ``np.asarray(state.X)`` just to
    fill the argument is a dead device->host transfer per churn event.
    """
    pick = lambda v, sv, d: (v if v is not None
                             else (sv if sv is not None else d))
    sweeps = pick(sweeps, getattr(spec, "sweeps", None), 2)
    anneal_steps = pick(anneal_steps, getattr(spec, "anneal_steps", None), 600)
    anneal_chains = pick(anneal_chains,
                         getattr(spec, "anneal_chains", None), 8)
    anneal_t0 = pick(anneal_t0, getattr(spec, "anneal_t0", None), 5.0)
    anneal_t1 = pick(anneal_t1, getattr(spec, "anneal_t1", None), 0.05)
    polish_sweeps = pick(polish_sweeps,
                         getattr(spec, "polish_sweeps", None), 2)
    if eligible is None and spec is not None:
        eligible = spec.masks(problem)
    key = jax.random.PRNGKey(0) if key is None else key
    aux = build_aux(problem)
    if state is None:
        if prev_X is None:
            raise ValueError("resolve_incremental needs prev_X or state")
        state = init_state(problem, jnp.asarray(prev_X, jnp.int32))
    # else: the caller-carried state (power.warm_state) is trusted as-is --
    # that's the O(V*(N+P)) event path; candidates are re-scored exactly
    # below, so carried float32 load drift cannot corrupt the result
    changed_rows = [] if changed_rows is None else list(changed_rows)
    free = np.asarray(aux.free_pos)
    if free.shape[0] == 0:  # everything pinned: nothing to re-place
        return _result(problem, state.X, "incremental")
    el_np, cnt_np, cand_np = _eligible_np(eligible)
    el_j = None if el_np is None else jnp.asarray(el_np)
    if el_np is not None:
        # the warm incumbent may predate the mask (a substrate fault can
        # arrive after placement): project it first, so a mask-violating
        # placement can never win the exact-objective argmin below
        X0, moved = _project_eligible(problem, state.X, el_np)
        if moved:
            state = init_state(problem, apply_pins(problem, X0))
    cands = [state.X]
    pos_changed = free[np.isin(free[:, 0], changed_rows)]
    # wave axis bucketing: pad the changed-position list so the targeted
    # sweep (and the Metropolis target set below -- duplicate targets are a
    # harmless proposal bias) compiles once per wave-shape bucket
    pos_changed = _pad_positions(pos_changed, pad_changed_to)

    # phase 1: greedy placement of the changed VMs
    if pos_changed.shape[0]:
        pc = jnp.asarray(pos_changed)
        for _ in range(max(1, sweeps)):
            state, _ = _sweep(problem, aux, state, pc, el_j)
        cands.append(state.X)

    # phase 2: short Metropolis refinement
    conv: Optional[Dict[str, np.ndarray]] = None
    if anneal_steps > 0 and anneal_chains > 0:
        P, V = problem.P, problem.V
        target = pos_changed if pos_changed.shape[0] else free
        flat = jnp.asarray((target[:, 0] * V + target[:, 1])
                           .astype(np.int32))
        kf, kp, ka, kx = jax.random.split(key, 4)
        fi = jax.random.randint(kf, (anneal_steps, anneal_chains), 0,
                                flat.shape[0])
        j_prop = flat[fi]
        if el_np is None:
            p_prop = jax.random.randint(kp, (anneal_steps, anneal_chains),
                                        0, P, jnp.int32)
        else:
            # destinations sampled from each proposal row's eligible set
            # (fold_in: unmasked randint stream stays byte-identical)
            rows = j_prop // V
            u_dst = jax.random.uniform(jax.random.fold_in(kp, 1),
                                       (anneal_steps, anneal_chains))
            p_prop = _sample_eligible(u_dst, rows, jnp.asarray(cnt_np),
                                      jnp.asarray(cand_np))
        u_prop = jax.random.uniform(ka, (anneal_steps, anneal_chains))
        temps = anneal_t0 * (anneal_t1 / anneal_t0) ** (
            jnp.arange(anneal_steps) / max(1, anneal_steps - 1))
        Xc = jnp.broadcast_to(state.X, (anneal_chains,) + state.X.shape)
        if el_np is None:
            rand = jax.random.randint(kx, Xc.shape, 0, P, jnp.int32)
        else:
            # restarted chains must also start on eligible nodes
            u_r = jax.random.uniform(jax.random.fold_in(kx, 1), Xc.shape)
            rand = _sample_eligible(
                u_r, jnp.arange(problem.R)[None, :, None],
                jnp.asarray(cnt_np), jnp.asarray(cand_np))
        # chain 0 stays warm; the rest restart at the target positions only
        tgt_mask = np.zeros((problem.R, V), dtype=bool)
        tgt_mask[target[:, 0], target[:, 1]] = True
        keep = ((jnp.arange(anneal_chains) == 0)[:, None, None]
                | ~jnp.asarray(tgt_mask)[None])
        Xc = jnp.where(keep, Xc, rand)
        bX, _, hist = _anneal_scan_delta(problem, aux, Xc, j_prop, p_prop,
                                         u_prop, temps)
        cands.append(bX)
        if record_conv:
            # fixed length anneal_steps (static per effort bucket): the
            # telemetry plane's quality-vs-steps trace for this solve
            conv = {"best_obj": np.asarray(hist[0]),
                    "accept_rate": np.asarray(hist[1])}

    # pick the exact-objective best (one batched call), then polish
    objs = [float(o) for o in
            objective_batch(problem, jnp.stack(cands))]
    k = int(np.argmin(objs))
    best_obj, best_X = objs[k], cands[k]
    history: List[float] = objs + [best_obj]
    if polish_sweeps > 0:
        state = init_state(problem, best_X)
        pa = jnp.asarray(_pad_positions(free, pad_positions_to))
        for _ in range(polish_sweeps):
            state, _ = _sweep(problem, aux, state, pa, el_j)
        obj = float(objective(problem, state.X))
        if obj < best_obj:
            best_obj, best_X = obj, state.X
        history.append(best_obj)
    res = _result(problem, best_X, "incremental", history)
    res.conv = conv
    return res


def resolve_wave(problem: PlacementProblem,
                 state: PlacementState,
                 changed_rows: Sequence[int],
                 key: Optional[jax.Array] = None,
                 pad_changed_to: Optional[int] = None,
                 spec=None, **kw) -> SolveResult:
    """Wave-batched incremental re-solve: ONE warm-start pass over a whole
    churn wave instead of one per event.

    The caller gathers a tick's arrivals/departures, applies
    ``power.detach_vsrs`` / the batch concat as one fused state update and
    builds ONE ``power.warm_state`` (``changed_rows`` = the arrival rows;
    departures need no changed rows -- survivors re-pack exactly as in the
    per-event remove path).  This then runs the three
    ``resolve_incremental`` phases once for the whole wave: targeted sweeps
    over every changed row's free VMs, ONE restricted Metropolis refinement
    whose proposals range over the union of changed positions, and a single
    full-polish pass -- the polish that dominates per-event latency is paid
    once per wave.

    Compile-shape hygiene (the region-axis trick of
    ``federation.solve_portfolio_batched``): the changed-position list is
    padded to a power-of-two bucket (``pad_changed_to``; default
    ``_pow2`` of the wave's free-position count), so the jitted ``_sweep``
    / ``_anneal_scan_delta`` kernels -- both ``@count_traces``-covered --
    compile once per wave-shape bucket, not once per wave size.
    """
    changed_rows = list(changed_rows)
    if pad_changed_to is None and changed_rows:
        fixed = np.asarray(problem.fixed_mask)[changed_rows]
        n_pos = int((~fixed).sum())
        if n_pos:
            pad_changed_to = _pow2(n_pos)
    res = resolve_incremental(problem, key=key, changed_rows=changed_rows,
                              state=state, spec=spec,
                              pad_changed_to=pad_changed_to, **kw)
    return SolveResult(X=res.X, breakdown=res.breakdown, method="wave",
                       history=res.history, conv=res.conv)


# ---------------------------------------------------------------------------
# Portfolio solver: the "CFN (MILP)" stand-in
# ---------------------------------------------------------------------------

def solve_portfolio(problem: PlacementProblem, topo: CFNTopology,
                    spec=None, key: Optional[jax.Array] = None,
                    eligible: Optional[np.ndarray] = None) -> SolveResult:
    """Best-of portfolio driven by a ``repro.api.PlacementSpec``: effort
    tier, anneal backend, and constraint masks all come from the spec
    (``eligible`` overrides ``spec.masks(problem)`` when given explicitly),
    so a full-portfolio solve -- including the online engine's defrag --
    enforces exactly the constraint set every other path enforces.

    On instances small enough for `exhaustive` the unconstrained portfolio
    is provably optimal; tests pin it to the exhaustive optimum.
    """
    key = jax.random.PRNGKey(0) if key is None else key
    effort = getattr(spec, "effort", "standard")
    backend = getattr(spec, "backend", "auto")
    if eligible is None and spec is not None:
        eligible = spec.masks(problem)
    cdc = topo.layer_indices("cdc")[0]
    candidates: List[SolveResult] = []
    # warm starts: CDC-everything and IoT-first-fit (the masked coordinate
    # sweeps project both onto the eligible set in their first pass)
    base_cdc = np.full((problem.R, problem.V), cdc, dtype=np.int32)
    candidates.append(coordinate(problem, base_cdc, eligible=eligible))
    iot_ff = fixed_layer(problem, topo, "iot")
    candidates.append(coordinate(problem, iot_ff.X, eligible=eligible))
    if effort in ("standard", "high"):
        k1, k2 = jax.random.split(key)
        warm = min(candidates, key=lambda r: r.objective).X
        n_steps = 4000 if effort == "standard" else 12000
        candidates.append(anneal(problem, k1, warm, n_steps=n_steps,
                                 backend=backend, eligible=eligible))
        if effort == "high":
            candidates.append(genetic(problem, k2, warm, eligible=eligible))
    best = min(candidates, key=lambda r: r.objective)
    return SolveResult(X=best.X, breakdown=best.breakdown,
                       method=f"cfn-milp({best.method})", history=best.history)


def _pow2(n: int, lo: int = 2) -> int:
    """Next power-of-two bucket >= max(n, 1) (compile-shape hygiene): the
    ONE bucketing policy, shared by the online engine's row/column padding
    (``core.dynamic._bucket_rows``) and the federated batch path
    (``core.federation.solve_portfolio_batched``)."""
    n = max(n, 1)
    b = lo
    while b < n:
        b *= 2
    return b


# The batched (federated) portfolio -- stack_problems / stack_auxes /
# solve_portfolio_batched, the vmapped-over-regions lift of the jitted
# primitives above -- lives in core.federation, its only consumer.  Lazy
# aliases keep the old ``solvers.solve_portfolio_batched`` imports working.
_FEDERATION_MOVED = ("solve_portfolio_batched", "stack_problems",
                     "stack_auxes", "_pad_links", "_solve_regions_impl",
                     "_solve_regions_jit", "_BATCH_EFFORT")


def __getattr__(name: str):
    if name in _FEDERATION_MOVED:
        from . import federation
        return getattr(federation, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def solve_cfn(problem: PlacementProblem, topo: CFNTopology,
              key: Optional[jax.Array] = None,
              effort: str = "standard") -> SolveResult:
    """Deprecated shim: constructs a ``PlacementSpec`` and routes through
    ``solve_portfolio`` (use ``repro.api.CFNSession`` / ``solve_portfolio``
    directly).  Results are identical to the pre-spec portfolio."""
    from . import api
    warnings.warn(
        "solve_cfn() is deprecated; build a repro.api.PlacementSpec and "
        "call solve_portfolio() (or use repro.api.CFNSession)",
        DeprecationWarning, stacklevel=2)
    return solve_portfolio(problem, topo, api.PlacementSpec(effort=effort),
                           key)


def repair_to_eligible(problem: PlacementProblem, res: SolveResult,
                       eligible: np.ndarray) -> SolveResult:
    """Force a solved placement onto an [R, P] eligibility mask.

    Free VMs already inside their row's eligible set are untouched; each
    violator is moved to its masked ``delta_sweep`` argmin (live state kept
    consistent so later repairs see earlier ones).  The safety net that
    makes every ``spec.masks`` consumer -- including solvers with no native
    masking, like the fixed-layer baselines -- end on an eligible
    placement.  A no-op (the input result is returned as-is, history and
    all) when nothing violates.
    """
    el_np, _, _ = _eligible_np(eligible)
    X = np.asarray(res.X).copy()
    fixed = np.asarray(problem.fixed_mask)
    rows = np.arange(X.shape[0])[:, None]
    if not np.any(~el_np[rows, X] & ~fixed):
        return res
    aux = build_aux(problem)
    state = init_state(problem, jnp.asarray(X))
    for r in range(X.shape[0]):
        mask_r = jnp.asarray(el_np[r])
        for v in range(X.shape[1]):
            if fixed[r, v] or el_np[r, X[r, v]]:
                continue
            obj_all = delta_sweep(problem, aux, state, r, v)
            best = int(jnp.argmin(jnp.where(mask_r, obj_all, jnp.inf)))
            state = apply_move(problem, aux, state, r, v, best)
            X[r, v] = best
    return _result(problem, X, res.method, res.history)
