"""Placement solvers for the CFN embedding problem.

The paper solves the MILP with CPLEX (24 cores, 126 GB).  CPLEX is not
available offline, and the contribution we reproduce is the *formulation* and
its energy trade-offs, so we provide a solver suite whose strongest member
(`solve_cfn`, coordinate-descent restarts x batched simulated annealing,
cross-validated by exhaustive enumeration on small instances) acts as the
CPLEX stand-in.  All heavy evaluation is the batched tensor objective in
power.py (optionally the Pallas kernel in kernels/placement_power).

Solvers:
  fixed_layer   -- the paper's CDC / AF / MF baselines (+ IoT first-fit).
  coordinate    -- exact best-single-move sweeps (monotone descent).
  exhaustive    -- provably optimal joint enumeration (small instances).
  anneal        -- batched Metropolis chains (jax.lax.scan over steps).
  genetic       -- population crossover/mutation search.
  relax         -- differentiable soft-placement + rounding (beyond-paper).
  solve_cfn     -- portfolio = best of the above; the "CFN MILP" curve.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .power import (PlacementProblem, PowerBreakdown, apply_pins, evaluate,
                    objective, objective_batch)
from .topology import CFNTopology


@dataclass
class SolveResult:
    X: np.ndarray                 # [R, V] placement (pins applied)
    breakdown: PowerBreakdown
    method: str
    history: List[float] = field(default_factory=list)

    @property
    def objective(self) -> float:
        return float(self.breakdown.objective)

    @property
    def power(self) -> float:
        return float(self.breakdown.total)

    @property
    def feasible(self) -> bool:
        return float(self.breakdown.violation) <= 1e-6


def _result(problem: PlacementProblem, X, method: str,
            history: Optional[List[float]] = None) -> SolveResult:
    X = np.asarray(apply_pins(problem, jnp.asarray(X, jnp.int32)))
    bd = jax.jit(evaluate)(problem, jnp.asarray(X))
    return SolveResult(X=X, breakdown=jax.device_get(bd), method=method,
                       history=history or [])


# ---------------------------------------------------------------------------
# Fixed-layer baselines (paper Fig. 3 scenarios)
# ---------------------------------------------------------------------------

def fixed_layer(problem: PlacementProblem, topo: CFNTopology,
                layer: str, spill_layer: str = "cdc") -> SolveResult:
    """All non-input VMs at `layer`; first-fit-decreasing across that layer's
    nodes honoring GFLOPS capacity; overflow spills to ``spill_layer``
    (the paper's observed behaviour at 20 VSRs)."""
    nodes = topo.layer_indices(layer)
    spill = topo.layer_indices(spill_layer)
    cap = np.array([topo.proc_hw[p].cap_gflops * topo.proc_hw[p].n_servers
                    for p in range(topo.P)], dtype=np.float64)
    load = np.zeros(topo.P)
    F = np.asarray(problem.F)
    fixed_mask = np.asarray(problem.fixed_mask)
    fixed_node = np.asarray(problem.fixed_node)
    R, V = F.shape
    # account pinned input VMs first
    for r in range(R):
        for v in range(V):
            if fixed_mask[r, v]:
                load[fixed_node[r, v]] += F[r, v]
    X = np.zeros((R, V), dtype=np.int32)
    order = sorted(((r, v) for r in range(R) for v in range(V)
                    if not fixed_mask[r, v]),
                   key=lambda rv: -F[rv])
    for (r, v) in order:
        placed = False
        for p in sorted(nodes, key=lambda p: load[p]):
            if load[p] + F[r, v] <= cap[p] + 1e-9:
                X[r, v] = p
                load[p] += F[r, v]
                placed = True
                break
        if not placed:
            for p in sorted(spill, key=lambda p: load[p]):
                if load[p] + F[r, v] <= cap[p] + 1e-9:
                    X[r, v] = p
                    load[p] += F[r, v]
                    placed = True
                    break
        if not placed:  # genuinely infeasible; dump on first node
            X[r, v] = nodes[0]
            load[nodes[0]] += F[r, v]
    return _result(problem, X, f"fixed:{layer}")


# ---------------------------------------------------------------------------
# Coordinate descent (exact single-VM moves)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=())
def _sweep(problem: PlacementProblem, X: jnp.ndarray, positions: jnp.ndarray):
    """One pass over all VM positions; each VM moved to its best node."""
    P = problem.P

    def body(X, pos):
        r, v = pos[0], pos[1]
        cand = jnp.broadcast_to(X, (P,) + X.shape)
        cand = cand.at[:, r, v].set(jnp.arange(P, dtype=X.dtype))
        obj = objective_batch(problem, cand)
        best = jnp.argmin(obj)
        return X.at[r, v].set(best.astype(X.dtype)), obj[best]

    X, objs = jax.lax.scan(body, X, positions)
    return X, objs[-1]


def coordinate(problem: PlacementProblem, X0: np.ndarray,
               max_sweeps: int = 12, tol: float = 1e-6) -> SolveResult:
    fixed_mask = np.asarray(problem.fixed_mask)
    positions = np.argwhere(~fixed_mask).astype(np.int32)
    X = jnp.asarray(X0, jnp.int32)
    prev = float("inf")
    history: List[float] = []
    for _ in range(max_sweeps):
        X, obj = _sweep(problem, X, jnp.asarray(positions))
        obj = float(obj)
        history.append(obj)
        if prev - obj < tol:
            break
        prev = obj
    return _result(problem, X, "coordinate", history)


# ---------------------------------------------------------------------------
# Exhaustive enumeration (ground truth on small instances)
# ---------------------------------------------------------------------------

def exhaustive(problem: PlacementProblem, max_combos: int = 2_000_000,
               chunk: int = 8192) -> SolveResult:
    fixed_mask = np.asarray(problem.fixed_mask)
    free = np.argwhere(~fixed_mask)
    P = problem.P
    n_free = len(free)
    n_combos = P ** n_free
    if n_combos > max_combos:
        raise ValueError(f"{n_combos} combos exceed cap {max_combos}")
    R, V = fixed_mask.shape
    base = np.zeros((R, V), dtype=np.int32)
    best_obj, best_X = float("inf"), base
    for start in range(0, n_combos, chunk):
        idx = np.arange(start, min(start + chunk, n_combos))
        digits = np.empty((len(idx), n_free), dtype=np.int32)
        rem = idx.copy()
        for j in range(n_free - 1, -1, -1):
            digits[:, j] = rem % P
            rem //= P
        Xb = np.broadcast_to(base, (len(idx), R, V)).copy()
        Xb[:, free[:, 0], free[:, 1]] = digits
        obj = np.asarray(objective_batch(problem, jnp.asarray(Xb)))
        k = int(np.argmin(obj))
        if obj[k] < best_obj:
            best_obj, best_X = float(obj[k]), Xb[k]
    return _result(problem, best_X, "exhaustive", [best_obj])


# ---------------------------------------------------------------------------
# Batched simulated annealing
# ---------------------------------------------------------------------------

def anneal(problem: PlacementProblem, key: jax.Array, X0: np.ndarray,
           n_chains: int = 32, n_steps: int = 4000,
           t0: float = 50.0, t1: float = 0.05) -> SolveResult:
    R, V, P = problem.R, problem.V, problem.P
    k_init, k_scan = jax.random.split(key)
    X = jnp.asarray(X0, jnp.int32)
    Xc = jnp.broadcast_to(X, (n_chains, R, V)).copy()
    # randomize all but chain 0 (keep one chain at the warm start)
    rand = jax.random.randint(k_init, (n_chains, R, V), 0, P, jnp.int32)
    keep = (jnp.arange(n_chains) == 0)[:, None, None]
    Xc = jnp.where(keep, Xc, rand)
    obj0 = objective_batch(problem, Xc)

    temps = t0 * (t1 / t0) ** (jnp.arange(n_steps) / max(1, n_steps - 1))
    keys = jax.random.split(k_scan, n_steps)

    @jax.jit
    def run(Xc, obj0, keys, temps):
        def step(carry, inp):
            Xc, obj, bX, bobj = carry
            k, T = inp
            kr, kv, kp, ka = jax.random.split(k, 4)
            r = jax.random.randint(kr, (n_chains,), 0, R)
            v = jax.random.randint(kv, (n_chains,), 0, V)
            p = jax.random.randint(kp, (n_chains,), 0, P)
            ci = jnp.arange(n_chains)
            Xp = Xc.at[ci, r, v].set(p)
            objp = objective_batch(problem, Xp)
            u = jax.random.uniform(ka, (n_chains,))
            acc = (objp < obj) | (u < jnp.exp(-(objp - obj) / T))
            Xc = jnp.where(acc[:, None, None], Xp, Xc)
            obj = jnp.where(acc, objp, obj)
            better = obj < bobj
            bX = jnp.where(better[:, None, None], Xc, bX)
            bobj = jnp.where(better, obj, bobj)
            return (Xc, obj, bX, bobj), bobj.min()

        init = (Xc, obj0, Xc, obj0)
        (_, _, bX, bobj), hist = jax.lax.scan(step, init, (keys, temps))
        k = jnp.argmin(bobj)
        return bX[k], bobj[k], hist

    bX, bobj, hist = run(Xc, obj0, keys, temps)
    return _result(problem, np.asarray(bX), "anneal",
                   [float(h) for h in np.asarray(hist[:: max(1, n_steps // 50)])])


# ---------------------------------------------------------------------------
# Genetic search
# ---------------------------------------------------------------------------

def genetic(problem: PlacementProblem, key: jax.Array, X0: np.ndarray,
            pop: int = 64, gens: int = 300, p_mut: float = 0.08) -> SolveResult:
    R, V, P = problem.R, problem.V, problem.P
    k_init, k_scan = jax.random.split(key)
    elite = jnp.asarray(X0, jnp.int32)
    Xp = jax.random.randint(k_init, (pop, R, V), 0, P, jnp.int32)
    Xp = Xp.at[0].set(elite)

    @jax.jit
    def run(Xp, keys):
        def gen(Xp, k):
            k1, k2, k3, k4 = jax.random.split(k, 4)
            fit = objective_batch(problem, Xp)
            # tournament selection
            a = jax.random.randint(k1, (pop,), 0, pop)
            b = jax.random.randint(k2, (pop,), 0, pop)
            parents = jnp.where((fit[a] < fit[b])[:, None, None], Xp[a], Xp[b])
            # per-VSR uniform crossover with a shifted copy
            mask = jax.random.bernoulli(k3, 0.5, (pop, R))[:, :, None]
            mates = jnp.roll(parents, 1, axis=0)
            children = jnp.where(mask, parents, mates)
            # mutation
            km1, km2 = jax.random.split(k4)
            mut = jax.random.bernoulli(km1, p_mut, (pop, R, V))
            rnd = jax.random.randint(km2, (pop, R, V), 0, P, jnp.int32)
            children = jnp.where(mut, rnd, children)
            # elitism: keep the best individual
            best = jnp.argmin(fit)
            children = children.at[0].set(Xp[best])
            return children, fit[best]

        Xp, hist = jax.lax.scan(gen, Xp, keys)
        fit = objective_batch(problem, Xp)
        k = jnp.argmin(fit)
        return Xp[k], fit[k], hist

    bX, bobj, hist = run(Xp, jax.random.split(k_scan, gens))
    return _result(problem, np.asarray(bX), "genetic",
                   [float(h) for h in np.asarray(hist[:: max(1, gens // 50)])])


# ---------------------------------------------------------------------------
# Differentiable relaxation (beyond-paper)
# ---------------------------------------------------------------------------

def relax(problem: PlacementProblem, key: jax.Array,
          steps: int = 800, lr: float = 0.3,
          temp0: float = 5.0, temp1: float = 0.05) -> SolveResult:
    """Soft placement: logits -> softmax assignment, smooth power surrogate,
    Adam descent with annealed temperature, then argmax + coordinate repair."""
    R, V, P = problem.R, problem.V, problem.P
    logits = 0.01 * jax.random.normal(key, (R, V, P))

    def loss_fn(logits, temp):
        soft = jax.nn.softmax(logits / jnp.maximum(temp, 1e-3), axis=-1)
        bd = evaluate(problem, soft, hard=False, temp=temp)
        # entropy push towards one-hot as temp decays
        ent = -jnp.sum(soft * jnp.log(soft + 1e-9), axis=-1).mean()
        return bd.total + 10.0 * PENALTY_W * bd.violation + 0.1 * ent

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    m = jnp.zeros_like(logits)
    v = jnp.zeros_like(logits)
    b1, b2, eps = 0.9, 0.999, 1e-8
    history = []
    for i in range(steps):
        temp = temp0 * (temp1 / temp0) ** (i / max(1, steps - 1))
        loss, g = grad_fn(logits, temp)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / (1 - b1 ** (i + 1))
        vh = v / (1 - b2 ** (i + 1))
        logits = logits - lr * mh / (jnp.sqrt(vh) + eps)
        if i % max(1, steps // 40) == 0:
            history.append(float(loss))
    X = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
    res = coordinate(problem, X, max_sweeps=4)
    return SolveResult(X=res.X, breakdown=res.breakdown, method="relax",
                       history=history + res.history)


PENALTY_W = 100.0  # relative weight of violation in the relaxed loss


# ---------------------------------------------------------------------------
# Portfolio solver: the "CFN (MILP)" stand-in
# ---------------------------------------------------------------------------

def solve_cfn(problem: PlacementProblem, topo: CFNTopology,
              key: Optional[jax.Array] = None,
              effort: str = "standard") -> SolveResult:
    """Best-of portfolio.  On instances small enough for `exhaustive` this is
    provably optimal; tests pin the portfolio to the exhaustive optimum."""
    key = jax.random.PRNGKey(0) if key is None else key
    cdc = topo.layer_indices("cdc")[0]
    candidates: List[SolveResult] = []
    # warm starts: CDC-everything and IoT-first-fit
    base_cdc = np.full((problem.R, problem.V), cdc, dtype=np.int32)
    candidates.append(coordinate(problem, base_cdc))
    iot_ff = fixed_layer(problem, topo, "iot")
    candidates.append(coordinate(problem, iot_ff.X))
    if effort in ("standard", "high"):
        k1, k2 = jax.random.split(key)
        warm = min(candidates, key=lambda r: r.objective).X
        n_steps = 4000 if effort == "standard" else 12000
        candidates.append(anneal(problem, k1, warm, n_steps=n_steps))
        if effort == "high":
            candidates.append(genetic(problem, k2, warm))
    best = min(candidates, key=lambda r: r.objective)
    return SolveResult(X=best.X, breakdown=best.breakdown,
                       method=f"cfn-milp({best.method})", history=best.history)
