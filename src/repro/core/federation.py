"""Federated fog regions: the hierarchical multi-region CFN.

The paper's CFN is one PON/metro tree hanging off one CDC.  Its stated
future work -- and the meshed-core extension ``topology.nsfnet_topology``
already anticipates -- is a FEDERATION: several fog regions, each a full
Fig.-1 fabric, interconnected over a shared IP/WDM core (the cloud-fog
architectures of arXiv:2008.04004, the geo-distributed service placement
of arXiv:1808.06120).  This module adds that second level of the embedding
hierarchy -- service -> region -> node -- while reusing every existing
solver unchanged underneath:

  * **RegionPartition** maps a merged substrate (``topology.federated_scale``
    or any topology whose node names carry ``r{g}_`` prefixes) into
    per-region sub-substrates: each region gets its own padded-CSR route
    table (region fabrics are trees behind a single core attachment, so
    intra-region routes never leave the region -- validated at
    construction), and the regions share an inter-region core-hop table
    over the unprefixed ``nsf*`` IP/WDM mesh.  For the batched solve the
    partition pads every region onto ONE (P, N, K) shape bucket
    (nonexistent pad nodes carry deterrent parameters and are masked out
    of every solver move), so a single compile covers the fleet.

  * **FederatedSession** is the facade: ``solve(vsrs)`` assigns each
    service to a region (home region of its source node, overridden by
    ``PlacementSpec.region_affinity`` / ``region_anti_affinity``),
    decomposes the workload into per-region placement problems, and runs
    the per-region portfolios through
    ``solve_portfolio_batched`` (below) -- the existing delta-engine
    sweep/anneal primitives vmapped across the region axis under one
    trace.  A top-level coordinator pass then prices inter-region traffic
    into Eq.(1) (exact float64 per-node accounting, see
    ``federated_breakdown``) and, when a region's attributed watts exceed
    its ``region_power_budget_w``, migrates services to cooler regions.
    ``add``/``remove`` are region-aware churn events on per-region
    ``dynamic.OnlineEmbedder`` engines seeded from the batch solve.

  * **Cross-region services.**  A service hosted away from its home region
    keeps its pinned input VM at the physical source: the home region
    carries a *stub* (the input VM's compute), the host region carries the
    *body* (the free VMs, input pin re-anchored at the host region's CDC),
    and the *cut links* between them are priced along the merged route --
    home egress, shared core, host ingress -- which is exactly where
    inter-region core traffic enters Eq.(1) network power.

  * **Exactness.**  ``federated_breakdown`` assembles merged-substrate
    float64 loads from the per-region states plus the cut links and
    evaluates Eq.(1)/(2) per node, grouped into per-region and
    inter-region (shared-core) watts.  Regional + inter-region watts sum
    to the total BY CONSTRUCTION, and the total equals a from-scratch
    float64 oracle evaluation of the equivalent flat placement
    (tests/test_federation.py).  A single-region federation routes through
    the flat ``CFNSession`` unchanged, so 1-region == flat holds exactly.

Admission rejections, regional budget breaches, and migrations are
reported to a ``fault.monitor.PlacementMonitor`` when one is attached.
"""
from __future__ import annotations

import functools
import re
from dataclasses import dataclass, field
from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import dynamic, power, solvers
from . import vsr as vsr_mod
from .topology import CFNTopology

__all__ = ["Region", "RegionPartition", "ServicePlan", "FederatedBreakdown",
           "FederatedResult", "FederatedSession", "federated_breakdown",
           "solve_portfolio_batched", "stack_problems", "stack_auxes"]

_REGION_RE = re.compile(r"^r(\d+)_")


def _region_tag(name: str) -> int:
    m = _REGION_RE.match(name)
    return int(m.group(1)) if m else -1


# ---------------------------------------------------------------------------
# The partition: merged substrate -> per-region substrates + core table
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Region:
    """One fog region of the federation (a dense local index space)."""

    index: int                 # dense federation index in [0, G)
    name: str
    topo: CFNTopology          # the region's own finalized sub-topology
    proc_ids: np.ndarray       # [P_r] merged proc index of local proc p
    net_ids: np.ndarray        # [N_r] merged net index of local net n

    @property
    def P(self) -> int:
        return len(self.proc_ids)

    @property
    def N(self) -> int:
        return len(self.net_ids)

    @property
    def pin_node(self) -> int:
        """Local node a migrated service's input VM is re-anchored at: the
        region's CDC (closest processing node to the core ingress), falling
        back to local node 0.  The pin carries zero demand and no links, so
        only the hop-mask semantics depend on it: a scalar ``max_hops``
        constrains a migrated service's VMs to a radius around the region's
        cloud ingress."""
        cdc = self.topo.layer_indices("cdc")
        return cdc[0] if cdc else 0


# pad-node parameters for the uniform shape bucket: a VM can never be placed
# on a pad node (masked out of every solver move), and a pad node with zero
# load contributes exactly zero power; the deterrent E / zero NS make a
# stray placement catastrophic rather than silently cheap.
_PAD_PROC = dict(E=1.0e6, C_pr=1.0, NS=0.0, pi_pr=0.0, pue_pr=1.0,
                 EL=0.0, C_lan=1.0e9, pi_lan=0.0, lan_share=0.0)
_PAD_NET = dict(eps=0.0, C_net=1.0e9, pi_net=0.0, pue_net=1.0,
                idle_share=0.0)


class RegionPartition:
    """Maps a merged CFN substrate into federated per-region substrates.

    Region membership is parsed from the ``r{g}_`` node-name prefixes that
    ``topology.federated_scale`` emits; unprefixed network nodes form the
    shared inter-region core.  A topology with no prefixes at all is a
    single-region federation (``RegionPartition.single``): the one region
    IS the merged substrate, index spaces untouched.
    """

    def __init__(self, topo: CFNTopology, regions: List[Region],
                 proc_region: np.ndarray, net_region: np.ndarray):
        self.topo = topo
        self.regions = regions
        self.proc_region = np.asarray(proc_region)
        self.net_region = np.asarray(net_region)
        self.core_net_ids = np.nonzero(self.net_region < 0)[0]
        # merged proc id -> region-local proc id
        self._proc_local = np.full(topo.P, -1, np.int64)
        for reg in regions:
            self._proc_local[reg.proc_ids] = np.arange(reg.P)
        self.core_hops = self._core_hop_table()
        self._padded_cache: Optional[tuple] = None

    # -- construction -----------------------------------------------------
    @classmethod
    def from_topology(cls, topo: CFNTopology) -> "RegionPartition":
        pr = np.array([_region_tag(n) for n in topo.proc_names])
        nr = np.array([_region_tag(n) for n in topo.net_names])
        if (pr < 0).all():
            return cls.single(topo)
        if (pr < 0).any():
            bad = [n for n, g in zip(topo.proc_names, pr) if g < 0]
            raise ValueError(f"processing nodes without an r<g>_ region "
                             f"prefix: {bad[:5]}")
        tags = sorted(set(pr.tolist()))
        regions: List[Region] = []
        proc_region = np.zeros(topo.P, np.int64)
        net_region = np.full(topo.N, -1, np.int64)
        for i, g in enumerate(tags):
            proc_ids = np.nonzero(pr == g)[0]
            net_ids = np.nonzero(nr == g)[0]
            proc_region[proc_ids] = i
            net_region[net_ids] = i
            sub = CFNTopology()
            names = set()
            for p in proc_ids:
                sub.add_proc(topo.proc_names[p], topo.proc_hw[p],
                             topo.proc_layer[p])
                names.add(topo.proc_names[p])
            for n in net_ids:
                sub.add_net(topo.net_names[n], topo.net_hw[n])
                names.add(topo.net_names[n])
            for a, b in topo.edges:
                if a in names and b in names:
                    sub.connect(a, b)
            sub.finalize()
            # closure guard: every merged intra-region route must stay on
            # region network nodes with the same hop count the region's own
            # router finds (the tree-behind-one-attachment property the
            # decomposition relies on)
            rt = np.asarray(topo.route_idx)[np.ix_(proc_ids, proc_ids)]
            real = rt[rt < topo.N]
            if real.size and not np.all(net_region[real] == i):
                raise ValueError(
                    f"region r{g} is not closed: an intra-region route "
                    "traverses out-of-region network nodes")
            if not np.array_equal(
                    np.asarray(sub.route_len),
                    np.asarray(topo.route_len)[np.ix_(proc_ids, proc_ids)]):
                raise ValueError(f"region r{g} sub-routes disagree with the "
                                 "merged route table")
            regions.append(Region(i, f"r{g}", sub, proc_ids, net_ids))
        return cls(topo, regions, proc_region, net_region)

    @classmethod
    def single(cls, topo: CFNTopology) -> "RegionPartition":
        """The identity partition: one region whose sub-topology IS the
        merged topology (index spaces untouched, no padding) -- the
        1-region-federation == flat-session contract."""
        reg = Region(0, "all", topo, np.arange(topo.P), np.arange(topo.N))
        return cls(topo, [reg], np.zeros(topo.P, np.int64),
                   np.zeros(topo.N, np.int64))

    # -- introspection ----------------------------------------------------
    @property
    def G(self) -> int:
        return len(self.regions)

    def local_proc(self, merged_id: int) -> int:
        return int(self._proc_local[merged_id])

    def home_region(self, merged_proc_id: int) -> int:
        return int(self.proc_region[merged_proc_id])

    def _core_hop_table(self) -> np.ndarray:
        """[G, G] shared-core hops between region pairs (the inter-region
        core-link table: how many unassigned -- core -- network nodes the
        merged route between the two regions traverses)."""
        G = self.G
        out = np.zeros((G, G), np.int64)
        rt = np.asarray(self.topo.route_idx)
        for a in range(G):
            for b in range(G):
                if a == b:
                    continue
                ids = rt[self.regions[a].proc_ids[0],
                         self.regions[b].proc_ids[0]]
                ids = ids[ids < self.topo.N]
                out[a, b] = int((self.net_region[ids] < 0).sum())
        return out

    # -- the uniform shape bucket (batched solving) ------------------------
    def padded_substrates(self):
        """Per-region ``power.build_problem`` substrate dicts on ONE
        (P_pad, N_pad, K_pad) bucket, plus the per-region real-node masks.

        Returns ``(substrates, real_masks, (P_pad, N_pad, K_pad))``;
        cached (the partition is immutable)."""
        if self._padded_cache is not None:
            return self._padded_cache
        import jax.numpy as jnp
        P_pad = max(r.P for r in self.regions)
        N_pad = max(r.N for r in self.regions)
        K_pad = max(r.topo.K for r in self.regions)
        subs, masks = [], []
        for reg in self.regions:
            d: Dict[str, np.ndarray] = {}
            for k, v in reg.topo.proc_param_arrays().items():
                d[k] = np.concatenate(
                    [v, np.full(P_pad - reg.P, _PAD_PROC[k], np.float32)])
            for k, v in reg.topo.net_param_arrays().items():
                d[k] = np.concatenate(
                    [v, np.full(N_pad - reg.N, _PAD_NET[k], np.float32)])
            rt = np.full((P_pad, P_pad, K_pad), N_pad, np.int32)
            r0 = np.asarray(reg.topo.route_idx)
            rt[:reg.P, :reg.P, :r0.shape[2]] = np.where(r0 == reg.N, N_pad,
                                                        r0)
            out = {k: jnp.asarray(v) for k, v in d.items()}
            out["route_idx"] = jnp.asarray(rt)
            if P_pad <= power.DENSE_ROUTE_MAX_P:
                dense = np.zeros((P_pad * P_pad, N_pad + 1), np.float32)
                bb, ee, _ = np.indices(rt.shape)
                dense[(bb * P_pad + ee).reshape(-1), rt.reshape(-1)] = 1.0
                out["route_dense"] = jnp.asarray(dense[:, :N_pad])
            else:
                out["route_dense"] = None
            subs.append(out)
            m = np.zeros(P_pad, bool)
            m[:reg.P] = True
            masks.append(m)
        self._padded_cache = (subs, masks, (P_pad, N_pad, K_pad))
        return self._padded_cache


# ---------------------------------------------------------------------------
# Service plans: the service -> region level of the hierarchy
# ---------------------------------------------------------------------------

@dataclass
class ServicePlan:
    """Where one service lives in the federation.

    ``body`` is the region-local VSR hosted in ``assigned`` (source index
    localized); for a cross-region service ``stub`` carries the pinned
    input VM's compute in ``home`` and ``cuts`` lists the severed virtual
    links ``(h_mbps, vm_col, input_is_src)`` to be priced along the merged
    home<->host route."""

    sid: int
    home: int
    assigned: int
    vsr: vsr_mod.VSRBatch
    body: vsr_mod.VSRBatch
    stub: Optional[vsr_mod.VSRBatch] = None
    cuts: List[Tuple[float, int, bool]] = field(default_factory=list)
    body_row: int = -1
    stub_row: int = -1

    @property
    def migrated(self) -> bool:
        return self.stub is not None


def make_plan(partition: RegionPartition, service: vsr_mod.VSRBatch,
              sid: int, assigned: int) -> ServicePlan:
    """Split one R=1 service (merged source index) into its regional parts."""
    if service.R != 1:
        raise ValueError(f"services are R=1, got R={service.R}")
    src_m = int(service.src[0])
    home = partition.home_region(src_m)
    src_local = partition.local_proc(src_m)
    iv = int(service.input_vm[0])
    if assigned == home:
        body = vsr_mod.VSRBatch(
            F=service.F.copy(), H=service.H.copy(),
            src=np.array([src_local], np.int32),
            input_vm=service.input_vm.copy())
        return ServicePlan(sid=sid, home=home, assigned=assigned,
                           vsr=service, body=body)
    F = service.F.copy()
    H = service.H.copy()
    V = service.V
    cuts: List[Tuple[float, int, bool]] = []
    self_h = float(H[0, iv, iv])
    H[0, iv, iv] = 0.0
    for d in range(V):
        if d == iv:
            continue
        if H[0, iv, d] > 0:
            cuts.append((float(H[0, iv, d]), d, True))
            H[0, iv, d] = 0.0
        if H[0, d, iv] > 0:
            cuts.append((float(H[0, d, iv]), d, False))
            H[0, d, iv] = 0.0
    F_in = float(F[0, iv])
    F[0, iv] = 0.0
    host = partition.regions[assigned]
    body = vsr_mod.VSRBatch(
        F=F, H=H, src=np.array([host.pin_node], np.int32),
        input_vm=service.input_vm.copy())
    stub_H = np.zeros((1, 2, 2), np.float32)
    stub_H[0, 0, 0] = self_h
    stub = vsr_mod.VSRBatch(
        F=np.array([[F_in, 0.0]], np.float32), H=stub_H,
        src=np.array([src_local], np.int32),
        input_vm=np.zeros(1, np.int32))
    return ServicePlan(sid=sid, home=home, assigned=assigned, vsr=service,
                       body=body, stub=stub, cuts=cuts)


def _placeholder_service() -> vsr_mod.VSRBatch:
    """A zero service for regions with no assigned workload: pinned input
    at local node 0, one free zero-demand VM (so the padded problem keeps
    at least one free position), zero links -- contributes exactly
    nothing."""
    return vsr_mod.VSRBatch(F=np.zeros((1, 2), np.float32),
                            H=np.zeros((1, 2, 2), np.float32),
                            src=np.zeros(1, np.int32),
                            input_vm=np.zeros(1, np.int32))


# ---------------------------------------------------------------------------
# Exact federated power accounting (float64, per merged node)
# ---------------------------------------------------------------------------

class FederatedBreakdown(NamedTuple):
    total_w: float             # fleet watts (regional + inter-region)
    regional_w: np.ndarray     # [G] watts on each region's proc+net nodes
    inter_region_w: float      # Eq.(1) watts on the shared core
    violation: float           # merged capacity-violation magnitude
    per_proc_w: np.ndarray     # [P_merged]
    per_net_w: np.ndarray      # [N_merged]

    @property
    def objective(self) -> float:
        return self.total_w + power.PENALTY * self.violation


def _loads_f64(problem: power.PlacementProblem, X: np.ndarray):
    """(omega[P], theta[P], lam[N]) of a whole placement at float64 --
    the same accumulation ``power._loads`` performs, on numpy."""
    p = problem
    X = np.where(np.asarray(p.fixed_mask), np.asarray(p.fixed_node),
                 np.asarray(X))
    Xf = X.reshape(-1)
    omega = np.zeros(p.P, np.float64)  # tracelint: allow[CFN102]
    theta = np.zeros(p.P, np.float64)  # tracelint: allow[CFN102]
    lam = np.zeros(p.N, np.float64)  # tracelint: allow[CFN102]
    np.add.at(omega, Xf, np.asarray(p.F, np.float64).reshape(-1))  # tracelint: allow[CFN102]
    rt = np.asarray(p.route_idx)
    for s, d, h in zip(np.asarray(p.link_src), np.asarray(p.link_dst),
                       np.asarray(p.link_h, np.float64)):  # tracelint: allow[CFN102]
        b, e = int(Xf[s]), int(Xf[d])
        theta[b] += h
        if e != b:
            theta[e] += h
            ids = rt[b, e]
            lam[ids[ids < p.N]] += h
    return omega, theta, lam


def federated_breakdown(partition: RegionPartition,
                        region_states: Sequence[Tuple[int,
                                                      power.PlacementProblem,
                                                      np.ndarray]],
                        cuts: Sequence[Tuple[float, int, int, bool]] = (),
                        ) -> FederatedBreakdown:
    """Exact fleet power: merged-substrate float64 loads assembled from the
    per-region states plus the inter-region cut links, evaluated per node.

    ``region_states``: ``(region_index, regional_problem, X_local)`` per
    live region (padded problems allowed -- pad nodes must carry zero
    load).  ``cuts``: ``(h_mbps, src_merged, dst_merged, src_is_input)``
    per severed cross-region virtual link; its traffic is accumulated
    along the merged route (home egress + shared core + host ingress),
    which is where inter-region traffic is priced into Eq.(1).

    Regional + inter-region watts sum to ``total_w`` by construction; the
    total equals a from-scratch float64 oracle evaluation of the merged
    placement (tests/test_federation.py pins this).
    """
    topo = partition.topo
    P, N = topo.P, topo.N
    omega = np.zeros(P, np.float64)  # tracelint: allow[CFN102]
    theta = np.zeros(P, np.float64)  # tracelint: allow[CFN102]
    lam = np.zeros(N, np.float64)  # tracelint: allow[CFN102]
    for g, prob, X in region_states:
        reg = partition.regions[g]
        om, th, lm = _loads_f64(prob, X)
        if (np.abs(om[reg.P:]).max(initial=0.0) > 0
                or np.abs(th[reg.P:]).max(initial=0.0) > 0
                or np.abs(lm[reg.N:]).max(initial=0.0) > 0):
            raise ValueError(f"region {reg.name}: load on a pad node "
                             "(placement escaped the real-node mask)")
        omega[reg.proc_ids] += om[:reg.P]
        theta[reg.proc_ids] += th[:reg.P]
        lam[reg.net_ids] += lm[:reg.N]
    rt = np.asarray(topo.route_idx)
    for h, src_m, dst_m, src_is_input in cuts:
        b, e = (src_m, dst_m) if src_is_input else (dst_m, src_m)
        theta[b] += h
        if e != b:
            theta[e] += h
            ids = rt[b, e]
            lam[ids[ids < N]] += h

    # the ONE f64 copy of the Eq.(1)/(2) formulas, shared with the oracle
    from ..kernels.ref import eq_terms_f64
    per_net, per_proc, violation = eq_terms_f64(
        topo.proc_param_arrays(), topo.net_param_arrays(), omega, theta,
        lam)
    regional = np.zeros(partition.G, np.float64)  # tracelint: allow[CFN102]
    for reg in partition.regions:
        regional[reg.index] = (per_proc[reg.proc_ids].sum()
                               + per_net[reg.net_ids].sum())
    inter = float(per_net[partition.core_net_ids].sum())
    return FederatedBreakdown(
        total_w=float(per_proc.sum() + per_net.sum()),
        regional_w=regional, inter_region_w=inter,
        violation=float(violation), per_proc_w=per_proc, per_net_w=per_net)


# ---------------------------------------------------------------------------
# Batched per-region portfolio: stacked problems, ONE vmapped compile
# ---------------------------------------------------------------------------
#
# The partition above decomposes a multi-region substrate into G per-region
# PlacementProblems padded to ONE shape bucket (P_pad/N_pad/K_pad/R_pad/
# V_pad identical across regions), so the whole fleet of regional
# portfolios runs as a single vmapped program: warm-start init, coordinate
# sweeps, and the Metropolis delta scan are all the EXISTING jitted solver
# primitives (``solvers._sweep``, ``solvers._anneal_scan_delta``) lifted
# over a leading region axis.  One trace covers every region -- the compile
# count lands in ``solvers.TRACE_COUNTS["solve_regions"]`` via
# ``solvers.count_traces`` (rule CFN104) and is asserted by tests.


def _pad_links(problem: power.PlacementProblem, L: int) -> power.PlacementProblem:
    """Widen the virtual-link arrays to length ``L`` with zero-bitrate
    self-loops: a 0-Mbps link contributes exactly nothing to any load
    tensor or delta, so padded problems evaluate identically (regions
    carry different link counts; stacking needs one L).  Pad loops are
    spread round-robin over the flat VM space so no single VM's incident
    degree D inflates with the pad count."""
    import dataclasses
    d = L - int(problem.link_src.shape[0])
    if d <= 0:
        return problem
    J = problem.R * problem.V
    ids = jnp.asarray(np.arange(d) % J, problem.link_src.dtype)
    return dataclasses.replace(
        problem,
        link_src=jnp.concatenate([problem.link_src, ids]),
        link_dst=jnp.concatenate([problem.link_dst, ids]),
        link_h=jnp.concatenate([problem.link_h,
                                jnp.zeros(d, problem.link_h.dtype)]))


def stack_problems(problems: Sequence[power.PlacementProblem]
                   ) -> power.PlacementProblem:
    """Stack same-shaped problems along a new leading (region) axis.

    Every leaf must already share its shape across regions (the federation
    pads regions to one bucket and ``_pad_links`` evens the link counts);
    ``route_dense`` must be all-present or all-absent (same P_pad implies
    that)."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), problems[0],
                                  *problems[1:])


def stack_auxes(auxes: Sequence[power.PlacementAux],
                d_pad: Optional[int] = None,
                m_pad: Optional[int] = None) -> power.PlacementAux:
    """Stack per-problem auxes, padding the incident-link width D and the
    free-position count M to the fleet maxima (or the explicit ``d_pad``/
    ``m_pad`` buckets, so re-solves after workload redistribution keep the
    compiled shape).

    D padding appends no-op links (``other = self``, zero bitrate); M
    padding repeats each region's first free position -- a repeated sweep /
    proposal position is a harmless re-sweep (`solvers._pad_positions`
    semantics).  Every region must have >= 1 free position (the federation
    guarantees this by construction)."""
    D = max(max(int(a.inc_h.shape[1]) for a in auxes), d_pad or 0)
    M = max(max(int(a.free_pos.shape[0]) for a in auxes), m_pad or 0)
    io, ih, isrc, fp, ff = [], [], [], [], []
    for a in auxes:
        J, d = a.inc_other.shape
        m = a.free_pos.shape[0]
        if m == 0:
            raise ValueError("stack_auxes: a stacked problem has no free "
                             "position (everything pinned)")
        self_col = np.broadcast_to(np.arange(J, dtype=np.int32)[:, None],
                                   (J, D - d))
        io.append(np.concatenate([np.asarray(a.inc_other), self_col], 1))
        ih.append(np.concatenate(
            [np.asarray(a.inc_h), np.zeros((J, D - d), np.float32)], 1))
        isrc.append(np.concatenate(
            [np.asarray(a.inc_src), np.zeros((J, D - d), bool)], 1))
        pos = np.asarray(a.free_pos)
        fp.append(np.concatenate([pos, np.tile(pos[:1], (M - m, 1))]))
        flat = np.asarray(a.free_flat)
        ff.append(np.concatenate([flat, np.tile(flat[:1], M - m)]))
    j = jnp.asarray
    return power.PlacementAux(
        inc_other=j(np.stack(io)), inc_h=j(np.stack(ih)),
        inc_src=j(np.stack(isrc)), free_pos=j(np.stack(fp)),
        free_flat=j(np.stack(ff)))


@solvers.count_traces("solve_regions")
def _solve_regions_impl(problems, auxes, X0, eligible, positions, rand_chains,
                        j_prop, p_prop, u_prop, temps, n_sweeps: int):
    """One vmapped program over the stacked region axis: init -> n_sweeps
    coordinate sweeps -> (optional) Metropolis delta scan -> best-of.

    All inputs carry a leading [G] axis except ``temps`` [S]; the anneal
    phase is compiled in only when the proposal stream is non-empty
    (static shape)."""
    S = j_prop.shape[1]

    def one_region(prob, aux, X0r, el, pos, rand, jp, pp_, up):
        st = power.init_state(prob, X0r)
        for _ in range(n_sweeps):
            st, _ = solvers._sweep(prob, aux, st, pos, el)
        # exact refresh (kills float32 drift before the best-of compare)
        st = power.init_state(prob, st.X)
        X_best, obj_best = st.X, st.obj
        if S > 0:
            n_chains = rand.shape[0]
            keep = (jnp.arange(n_chains) == 0)[:, None, None]
            Xc = jnp.where(keep, X_best[None], rand)
            Xc = jax.vmap(lambda x: power.apply_pins(prob, x))(Xc)
            bX, bobj, _ = solvers._anneal_scan_delta(prob, aux, Xc, jp, pp_,
                                                     up, temps)
            bobj = power.objective(prob, bX)  # exact re-score (drift hygiene)
            better = bobj < obj_best
            X_best = jnp.where(better, bX, X_best)
            obj_best = jnp.where(better, bobj, obj_best)
        return X_best, obj_best

    return jax.vmap(one_region)(problems, auxes, X0, eligible, positions,
                                rand_chains, j_prop, p_prop, u_prop)


_solve_regions_jit = jax.jit(_solve_regions_impl,
                             static_argnames=("n_sweeps",))

# effort tier -> (coordinate sweeps, Metropolis steps, chains) per region
_BATCH_EFFORT = {"quick": (2, 0, 0), "standard": (2, 2000, 8),
                 "high": (3, 6000, 16)}


def solve_portfolio_batched(problems: Sequence[power.PlacementProblem],
                            X0: Sequence[np.ndarray],
                            eligible: Sequence[np.ndarray],
                            spec=None,
                            key: Optional[jax.Array] = None,
                            ) -> tuple[np.ndarray, np.ndarray]:
    """Solve G same-bucket placement problems under ONE vmapped compile.

    The batched counterpart of ``solvers.solve_portfolio`` for federated
    fleets: per-region warm starts ``X0`` [G, R, V] are swept and annealed
    by the same delta-engine primitives the flat portfolio uses, vectorized
    over the region axis (one trace for any G at a given shape bucket --
    re-solves after coordinator migrations hit the jit cache).

    ``eligible`` [G][R, P] bool is mandatory here (the federation always
    carries at least the real-node mask excluding shape-padding nodes).
    Returns ``(X [G, R, V], objective [G])`` as numpy.
    """
    if not problems:
        raise ValueError("solve_portfolio_batched needs >= 1 problem")
    key = jax.random.PRNGKey(0) if key is None else key
    effort = getattr(spec, "effort", "standard")
    n_sweeps, n_steps, n_chains = _BATCH_EFFORT[effort]
    G = len(problems)
    R, V, P = problems[0].R, problems[0].V, problems[0].P
    # bucket every workload-dependent shape (L links, D degree, M free
    # positions) so ONE compile covers any service-to-region distribution
    # at a given substrate bucket -- coordinator migration re-solves and
    # same-bucket churn all hit the jit cache
    L = solvers._pow2(max(int(p.link_src.shape[0]) for p in problems))
    problems = [_pad_links(p, L) for p in problems]
    auxes = [power.build_aux(p) for p in problems]
    d_pad = solvers._pow2(max(int(a.inc_h.shape[1]) for a in auxes))
    m_pad = R * max(1, V - 1)
    stacked = stack_problems(problems)
    aux_stacked = stack_auxes(auxes, d_pad=d_pad, m_pad=m_pad)
    el_j = jnp.asarray(np.stack([np.asarray(e, bool) for e in eligible]))
    X0_j = jnp.asarray(np.stack([np.asarray(x, np.int32) for x in X0]))
    # per-region proposal streams + eligible chain restarts (host-side RNG;
    # the jit consumes them as data, so one trace covers the fleet)
    n_ch = max(1, n_chains)
    jp = np.zeros((G, max(0, n_steps), n_ch), np.int32)
    pp_ = np.zeros_like(jp)
    up = np.zeros(jp.shape, np.float32)
    rand = np.zeros((G, n_ch, R, V), np.int32)
    for g, (prob, aux) in enumerate(zip(problems, auxes)):
        key, kp, kr = jax.random.split(key, 3)
        if n_steps > 0:   # rand/proposals are dead when anneal compiles out
            el_np, cnt, cand = solvers._eligible_np(eligible[g])
            fi, p_prop, u_prop = solvers._anneal_proposals(
                kp, aux, n_steps, n_ch, P, V=V, cnt=cnt, cand=cand)
            jp[g] = np.asarray(aux.free_flat[fi])
            pp_[g] = np.asarray(p_prop)
            up[g] = np.asarray(u_prop)
            u_r = jax.random.uniform(kr, (n_ch, prob.R, V))
            rand[g] = np.asarray(solvers._sample_eligible(
                u_r, jnp.arange(prob.R)[None, :, None],
                jnp.asarray(cnt), jnp.asarray(cand)))
    temps = jnp.asarray(
        50.0 * (0.05 / 50.0) ** (np.arange(max(1, n_steps))
                                 / max(1, n_steps - 1)), jnp.float32)
    bX, bobj = _solve_regions_jit(
        stacked, aux_stacked, X0_j, el_j, aux_stacked.free_pos,
        jnp.asarray(rand), jnp.asarray(jp), jnp.asarray(pp_),
        jnp.asarray(up), temps, n_sweeps=n_sweeps)
    return np.asarray(bX), np.asarray(bobj)


# ---------------------------------------------------------------------------
# The federation facade
# ---------------------------------------------------------------------------

class FederatedResult(NamedTuple):
    X: np.ndarray              # [R, V] merged placement, original row order
    breakdown: FederatedBreakdown
    assignments: np.ndarray    # [R] region index per service
    region_obj: np.ndarray     # [G] per-region solver objectives
    migrations: int            # coordinator migrations performed

    @property
    def objective(self) -> float:
        return self.breakdown.objective

    @property
    def power(self) -> float:
        return self.breakdown.total_w


def _traced(name: str, ledger: bool = False):
    """Span a ``FederatedSession`` coordinator method when telemetry is
    attached (multi-region only -- the flat path delegates to a flat
    session whose engine records its own spans); ``ledger=True``
    additionally takes one fleet-exact energy sample after the call.
    The no-telemetry path stays a plain call."""
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(self, *args, **kwargs):
            tel = self.telemetry
            if tel is None or self._flat is not None:
                return fn(self, *args, **kwargs)
            with tel.span(name):
                out = fn(self, *args, **kwargs)
            if ledger:
                self._record_fleet_energy(name)
            return out
        return wrapper
    return deco


class FederatedSession:
    """Hierarchical multi-region placement: one facade over G regions.

    ``solve(vsrs)`` is the batch path: assign services to regions, solve
    every region's portfolio under ONE vmapped compile
    (``solve_portfolio_batched``), then run the coordinator --
    exact federated accounting, inter-region pricing, cross-region
    migration on regional ``region_power_budget_w`` breaches -- and seed
    the per-region online engines from the result.  ``add``/``remove``
    are region-aware churn events on those engines; an arrival that
    pushes its region over budget is migrated to the coolest admissible
    region (``region_anti_affinity`` and ``inter_region_hops`` respected),
    with every breach/migration/rejection counted on the attached
    ``fault.monitor.PlacementMonitor``.

    A single-region federation (a topology with no ``r{g}_`` prefixes, or
    an explicit ``RegionPartition.single``) delegates wholesale to the
    flat ``CFNSession`` -- placements and float64 power are IDENTICAL to
    the non-federated path by construction.
    """

    MAX_COORD_PASSES = 4

    def __init__(self, topo, spec=None, key: Optional[jax.Array] = None,
                 monitor=None, partition: Optional[RegionPartition] = None,
                 telemetry=None):
        from . import api as api_mod
        if partition is None:
            partition = (topo if isinstance(topo, RegionPartition)
                         else RegionPartition.from_topology(topo))
        self.partition = partition
        self.topo = partition.topo
        self.spec = spec if spec is not None else api_mod.PlacementSpec()
        self.monitor = monitor
        self._key = jax.random.PRNGKey(1) if key is None else key
        self._plans: Dict[int, ServicePlan] = {}
        self._order: List[int] = []
        self._engines: Dict[int, dynamic.OnlineEmbedder] = {}
        self._next_sid = 0
        self._last_result: Optional[FederatedResult] = None
        # fault plane: down regions, brownout budget overrides, stranded
        # services parked for retry-on-recovery, and the session clock
        self._down: set = set()
        self._budget_override: Dict[int, float] = {}
        self._fqueue: List[Tuple[vsr_mod.VSRBatch, int, int]] = []
        self._prio: Dict[int, int] = {}
        self._now = 0.0
        self._region_monitors: Dict[int, object] = {}
        self._flat = None
        if partition.G == 1:
            self._flat = api_mod.CFNSession(self.topo, self.spec,
                                            key=self._key)
            self._flat.engine.monitor = monitor
        else:
            self._check_spec_supported()
        self.telemetry = None
        if telemetry is not None:
            self.attach_telemetry(telemetry)

    # -- config helpers ---------------------------------------------------
    def attach_monitor(self, monitor) -> None:
        """Attach (or replace) the ``fault.monitor.PlacementMonitor``
        receiving this federation's breach/migration/admission events --
        propagated to every live regional engine."""
        self.monitor = monitor
        if self._flat is not None:
            self._flat.attach_monitor(monitor)
        for eng in self._engines.values():
            eng.monitor = monitor
        if (monitor is not None and self.telemetry is not None
                and hasattr(monitor, "attach_telemetry")):
            monitor.attach_telemetry(self.telemetry)

    def attach_telemetry(self, telemetry) -> None:
        """Attach a ``repro.telemetry.Telemetry`` to the federation.

        Single-region: delegates wholesale to the flat ``CFNSession`` --
        spans, convergence traces, and the energy ledger come from its
        engine, identical to the non-federated path.  Multi-region: the
        COORDINATOR is the instrumented layer -- spans around
        ``solve``/``add``/``remove``/``apply_wave``/``apply_fault``, one
        fleet-exact ledger sample (per-region watt splits from
        ``breakdown()``) after each, and global compile attribution via
        the trace hooks.  Per-region engines deliberately do NOT tick the
        shared ledger: their commit samples would carry regional (not
        fleet) totals and corrupt the fleet watt series."""
        self.telemetry = telemetry
        if telemetry is None:
            if self._flat is not None:
                self._flat.attach_telemetry(None)
            return
        if self._flat is not None:
            self._flat.attach_telemetry(telemetry)
            return
        if telemetry.ledger.tiers is None:
            from ..telemetry import tiers_of
            telemetry.ledger.set_tiers(tiers_of(self.topo))
        telemetry.attach_traces()
        if (self.monitor is not None
                and hasattr(self.monitor, "attach_telemetry")):
            self.monitor.attach_telemetry(telemetry)

    def _record_fleet_energy(self, event: str) -> None:
        """One fleet-exact ledger sample (multi-region path only): total,
        Eq.(1) networking, and Eq.(2) processing watts with per-region
        splits, all from the exact ``federated_breakdown`` accounting."""
        tel = self.telemetry
        if tel is None or self._flat is not None:
            return
        try:
            bd = self.breakdown()
        except ValueError:   # empty session (everything departed/refused)
            return
        per_region = {int(g): float(w)
                      for g, w in enumerate(np.asarray(bd.regional_w))}
        # shared-core watts are in no region: keep the splits summing to
        # the exact fleet total
        per_region["inter_region"] = float(bd.inter_region_w)
        tel.ledger.tick(
            self._now, total_w=float(bd.total_w),
            net_w=float(np.asarray(bd.per_net_w).sum()),
            proc_w=float(np.asarray(bd.per_proc_w).sum()),
            per_region=per_region, event=event)
        tel.inc(f"commit.{event}")

    def _check_spec_supported(self) -> None:
        if self.spec.eligible is not None or (
                self.spec.max_hops is not None
                and np.ndim(self.spec.max_hops) > 0):
            raise ValueError(
                "multi-region federation supports scalar max_hops only "
                "(row-positional constraints cannot follow a service "
                "across regions); use region_affinity for placement "
                "steering")
        if self.spec.preempt:
            raise ValueError(
                "multi-region federation does not support preempt=True: "
                "a region engine preempting into its private queue would "
                "desync the federation's plan registry.  Preemption is a "
                "flat-session / per-region-engine feature")

    def _split_key(self) -> jax.Array:
        self._key, k = jax.random.split(self._key)
        return k

    def _local_spec(self):
        return self.spec.replace(region_affinity=None,
                                 region_anti_affinity=None,
                                 region_power_budget_w=None,
                                 inter_region_hops=None)

    def _engine(self, g: int) -> dynamic.OnlineEmbedder:
        if g not in self._engines:
            self._engines[g] = dynamic.OnlineEmbedder(
                self.partition.regions[g].topo, spec=self._local_spec(),
                key=self._split_key(),
                monitor=self._region_monitors.get(g, self.monitor))
            self._engines[g].tick(self._now)
        return self._engines[g]

    def attach_region_monitors(self, make=None) -> Dict[int, object]:
        """Give every region engine its OWN ``PlacementMonitor`` (the
        session-level monitor keeps receiving coordinator events);
        ``fleet_monitor()`` rolls them all up.  ``make`` overrides the
        monitor factory."""
        from ..fault.monitor import PlacementMonitor
        make = make or PlacementMonitor
        for g in range(self.G):
            self._region_monitors[g] = make()
        for g, eng in self._engines.items():
            eng.monitor = self._region_monitors[g]
        if self._flat is not None:
            self._flat.engine.monitor = self._region_monitors[0]
        return dict(self._region_monitors)

    def fleet_monitor(self):
        """One merged fleet snapshot: the session monitor plus every
        per-region monitor (``PlacementMonitor.merge`` semantics)."""
        from ..fault.monitor import PlacementMonitor
        fleet = PlacementMonitor()
        if self.monitor is not None:
            fleet.merge(self.monitor)
        for g in sorted(self._region_monitors):
            fleet.merge(self._region_monitors[g])
        return fleet

    def _budget(self, g: int) -> Optional[float]:
        if g in self._budget_override:
            return self._budget_override[g]
        b = self.spec.region_power_budget_w
        if b is None:
            return None
        b = np.asarray(b, np.float64)  # tracelint: allow[CFN102]
        return float(b) if b.ndim == 0 else float(b[g])

    def _row_constraint(self, kind: str, row: int) -> int:
        v = getattr(self.spec, kind)
        if v is None:
            return -1
        v = np.asarray(v)
        if v.ndim == 0:
            return int(v)
        return int(v[row]) if row < v.shape[0] else -1

    def _allowed_regions(self, home: int, anti: int) -> List[int]:
        """Host-region candidates for a service homed at ``home``: the home
        region first, then others by core distance, minus the forbidden
        region and anything past the ``inter_region_hops`` cap."""
        cap = self.spec.inter_region_hops
        out = []
        order = sorted(range(self.partition.G),
                       key=lambda g: (g != home,
                                      int(self.partition.core_hops[home, g])))
        for g in order:
            if g == anti or g in self._down:
                continue
            if (g != home and cap is not None
                    and int(self.partition.core_hops[home, g]) > cap):
                continue
            out.append(g)
        return out

    # -- introspection ----------------------------------------------------
    @property
    def G(self) -> int:
        return self.partition.G

    @property
    def n_live(self) -> int:
        return self._flat.n_live if self._flat else len(self._order)

    @property
    def sids(self) -> List[int]:
        return self._flat.sids if self._flat else list(self._order)

    @property
    def result(self):
        return self._flat.result if self._flat else self._last_result

    def service_vms(self, row: int) -> int:
        if self._flat:
            return self._flat.service_vms(row)
        return self._plans[self._order[row]].vsr.V

    def assignment(self, sid: int) -> int:
        """The region currently hosting service ``sid``'s free VMs."""
        if self._flat:
            return 0
        return self._plans[sid].assigned

    @property
    def X(self) -> Optional[np.ndarray]:
        """The merged-substrate placement [n_live, V_max] (merged proc
        indices, original service order; a migrated service's input VM
        shows its true source node)."""
        if self._flat:
            return self._flat.X
        if not self._order:
            return None
        V = max(self._plans[s].vsr.V for s in self._order)
        X = np.zeros((len(self._order), V), np.int32)
        for r, sid in enumerate(self._order):
            X[r, :self._plans[sid].vsr.V] = self._service_nodes(sid)
        return X

    def _service_nodes(self, sid: int) -> np.ndarray:
        """Merged node per VM of one service (from its host engine)."""
        plan = self._plans[sid]
        eng = self._engines[plan.assigned]
        row = eng.sids.index(sid)
        reg = self.partition.regions[plan.assigned]
        V = plan.vsr.V
        nodes = reg.proc_ids[np.asarray(eng.X[row, :V])]
        if plan.migrated:
            nodes = nodes.copy()
            nodes[int(plan.vsr.input_vm[0])] = int(plan.vsr.src[0])
        return nodes

    def _cuts_merged(self) -> List[Tuple[float, int, int, bool]]:
        out = []
        for sid in self._order:
            plan = self._plans[sid]
            if not plan.migrated:
                continue
            nodes = self._service_nodes(sid)
            src_m = int(plan.vsr.src[0])
            for h, vm_col, src_is_input in plan.cuts:
                out.append((h, src_m, int(nodes[vm_col]), src_is_input))
        return out

    def breakdown(self) -> FederatedBreakdown:
        """Exact (float64) fleet accounting: per-region + inter-region
        watts; see ``federated_breakdown``."""
        if self._flat:
            eng = self._flat.engine
            if eng.problem is None:
                raise ValueError("empty session")
            states = [(0, eng.problem, np.asarray(eng.X))]
            return federated_breakdown(self.partition, states)
        states = [(g, e.problem, np.asarray(e.X))
                  for g, e in self._engines.items() if e.problem is not None]
        if not states:
            raise ValueError("empty session")
        return federated_breakdown(self.partition, states,
                                   cuts=self._cuts_merged())

    def power_w(self) -> float:
        return self.breakdown().total_w

    def region_watts(self) -> np.ndarray:
        return self.breakdown().regional_w

    def attribute(self) -> Dict[int, float]:
        """Per-tenant watts summing to the exact fleet total: each
        service's body (+stub) attribution from its regional engines, plus
        the RESIDUAL -- everything the engines cannot see (cut-link watts
        on home-egress/shared-core/host-ingress nodes, f32-vs-f64
        rounding) -- split over the cross-region services by cut-traffic
        share (over everyone when there are none)."""
        if self._flat:
            return self._flat.attribute()
        out: Dict[int, float] = {s: 0.0 for s in self._order}
        for g, eng in self._engines.items():
            for sid, w in eng.per_service_power_w().items():
                out[sid] += w
        residual = self.breakdown().total_w - sum(out.values())
        cut_h = {sid: sum(h for h, _, _ in self._plans[sid].cuts)
                 for sid in self._order if self._plans[sid].migrated}
        tot_h = sum(cut_h.values())
        if tot_h > 0:
            for sid, h in cut_h.items():
                out[sid] += residual * h / tot_h
        elif self._order:
            for sid in self._order:
                out[sid] += residual / len(self._order)
        return out

    # -- batch path -------------------------------------------------------
    @_traced("federated_solve", ledger=True)
    def solve(self, vsrs: Optional[vsr_mod.VSRBatch] = None):
        """Embed a whole VSR batch across the federation (empty session),
        or re-pack the live regions (no batch: per-region defrag).

        Multi-region: one vmapped batched portfolio over all regions, a
        coordinator budget pass (cross-region migration on regional
        budget breaches), engines seeded from the result.  Returns a
        ``FederatedResult``.  Single-region: delegates to the flat
        ``CFNSession`` (identical placements)."""
        if self._flat:
            return self._flat.solve(vsrs)
        if vsrs is None:
            return self.defrag()
        if self._order:
            raise ValueError("session already has live services; use "
                             "add()/remove() for churn or solve() with no "
                             "batch to re-pack")
        services = [vsr_mod.VSRBatch(F=vsrs.F[i:i + 1], H=vsrs.H[i:i + 1],
                                     src=vsrs.src[i:i + 1],
                                     input_vm=vsrs.input_vm[i:i + 1])
                    for i in range(vsrs.R)]
        sids = list(range(vsrs.R))
        self._next_sid = vsrs.R
        assigned = self._assign(services)
        migrations = 0
        while True:   # every applied migration is followed by a re-solve
            plans, problems, eligibles, X0s, region_rows = self._decompose(
                services, sids, assigned)
            X, obj = solve_portfolio_batched(
                problems, X0s, eligibles, spec=self.spec,
                key=self._split_key())
            bd = self._batch_breakdown(plans, problems, X)
            if migrations >= self.MAX_COORD_PASSES:
                break
            move = self._pick_migration(plans, bd, assigned)
            if move is None:
                break
            row, target = move
            if self.monitor is not None:
                self.monitor.count("region_budget_breach",
                                   detail=f"region={assigned[row]}")
                self.monitor.count(
                    "cross_region_migration",
                    detail=f"sid={sids[row]} -> region {target}")
            assigned[row] = target
            migrations += 1
        # commit: seed the per-region engines with the solved placements
        for g, rows in region_rows.items():
            if not rows:
                continue
            eng = self._engine(g)
            svc, ss, x0 = [], [], []
            for plan, kind in rows:
                r = plan.body_row if kind == "body" else plan.stub_row
                svc.append(plan.body if kind == "body" else plan.stub)
                ss.append(plan.sid)
                x0.append(X[g][r])
            eng.bootstrap(svc, sids=ss, X0=np.stack(x0))
        self._plans = {p.sid: p for p in plans}
        self._order = list(sids)
        res = FederatedResult(
            X=self._merged_X_from(plans, X),
            breakdown=self.breakdown(),
            assignments=np.asarray(assigned), region_obj=np.asarray(obj),
            migrations=migrations)
        self._last_result = res
        return res

    def _assign(self, services) -> List[int]:
        out = []
        for i, s in enumerate(services):
            home = self.partition.home_region(int(s.src[0]))
            aff = self._row_constraint("region_affinity", i)
            anti = self._row_constraint("region_anti_affinity", i)
            g = aff if aff >= 0 else home
            if g == anti:
                allowed = [a for a in self._allowed_regions(home, anti)
                           if a != g]
                if not allowed:
                    raise ValueError(f"service {i}: no admissible region "
                                     "(anti-affinity + hop cap exclude all)")
                g = allowed[0]
            if g != home:
                cap = self.spec.inter_region_hops
                if (cap is not None
                        and int(self.partition.core_hops[home, g]) > cap):
                    raise ValueError(
                        f"service {i}: affinity region {g} is "
                        f"{int(self.partition.core_hops[home, g])} core "
                        f"hops from home {home}, past inter_region_hops="
                        f"{cap}")
            out.append(g)
        return out

    def _decompose(self, services, sids, assigned):
        """Per-region plans, padded problems, masks, and warm starts."""
        part = self.partition
        subs, real_masks, _ = part.padded_substrates()
        plans = [make_plan(part, s, sid, g)
                 for s, sid, g in zip(services, sids, assigned)]
        region_rows: Dict[int, list] = {g: [] for g in range(part.G)}
        for plan in plans:
            plan.body_row = len(region_rows[plan.assigned])
            region_rows[plan.assigned].append((plan, "body"))
        for plan in plans:
            if plan.migrated:
                plan.stub_row = len(region_rows[plan.home])
                region_rows[plan.home].append((plan, "stub"))
        batches = []
        for g in range(part.G):
            rows = region_rows[g]
            if rows:
                svcs = [p.body if kind == "body" else p.stub
                        for p, kind in rows]
                b = svcs[0]
                for s in svcs[1:]:
                    b = b.concat(s)
            else:
                b = _placeholder_service()
            if b.V < 2:
                # all-V=1 region: every VM is pinned, leaving the batched
                # solver no free position; widening via the placeholder
                # adds free zero-demand columns (exactly a concat pad)
                b = b.concat(_placeholder_service())
            batches.append(b)
        R_max = max(b.R for b in batches)
        R_pad = (dynamic._bucket_rows(R_max, lo=self.spec.row_bucket_lo)
                 if self.spec.bucket_rows else R_max)
        V_max = max(b.V for b in batches)
        V_pad = (dynamic._bucket_rows(V_max, lo=self.spec.col_bucket_lo)
                 if self.spec.bucket_cols else V_max)
        problems, eligibles, X0s = [], [], []
        for g, b in enumerate(batches):
            reg = part.regions[g]
            prob = power.build_problem(reg.topo, b, substrate=subs[g],
                                       pad_to_rows=R_pad, pad_to_cols=V_pad)
            # spec.masks anchors a migrated body's hop radius at its host
            # pin (the region CDC, see Region.pin_node) -- the SAME
            # semantics the seeded per-region engines enforce on churn and
            # defrag, so no path ever yanks a body the batch solve placed
            el = self.spec.masks(prob)
            el = (np.ones((prob.R, prob.P), bool) if el is None
                  else np.asarray(el, bool))
            el &= real_masks[g][None, :]
            problems.append(prob)
            eligibles.append(el)
            cdc = reg.topo.layer_indices("cdc")
            start = cdc[0] if cdc else 0
            X0 = np.full((prob.R, prob.V), start, np.int32)
            X0s.append(X0)
        return plans, problems, eligibles, X0s, region_rows

    def _batch_breakdown(self, plans, problems, X) -> FederatedBreakdown:
        states = [(g, problems[g], X[g]) for g in range(self.partition.G)]
        cuts = []
        for plan in plans:
            if not plan.migrated:
                continue
            reg = self.partition.regions[plan.assigned]
            src_m = int(plan.vsr.src[0])
            for h, vm_col, src_is_input in plan.cuts:
                dst_local = int(X[plan.assigned][plan.body_row, vm_col])
                cuts.append((h, src_m, int(reg.proc_ids[dst_local]),
                             src_is_input))
        return federated_breakdown(self.partition, states, cuts=cuts)

    def _pick_migration(self, plans, bd: FederatedBreakdown,
                        assigned) -> Optional[Tuple[int, int]]:
        """Coordinator: the (service row, target region) move for the worst
        budget breach, or None when every region is within budget (or no
        admissible move exists)."""
        over = [(bd.regional_w[g] - b, g) for g in range(self.partition.G)
                if (b := self._budget(g)) is not None
                and bd.regional_w[g] > b]
        if not over:
            return None
        _, g = max(over)
        movable = [i for i, p in enumerate(plans)
                   if assigned[i] == g
                   and self._row_constraint("region_affinity", i) < 0]
        if not movable:
            return None
        # move the heaviest service to the coolest admissible region
        row = max(movable,
                  key=lambda i: float(np.sum(plans[i].vsr.F)))
        anti = self._row_constraint("region_anti_affinity", row)
        home = plans[row].home
        cands = [c for c in self._allowed_regions(home, anti)
                 if c != g and (self._budget(c) is None
                                or bd.regional_w[c] < self._budget(c))]
        if not cands:
            return None
        target = min(cands, key=lambda c: bd.regional_w[c])
        return row, target

    def _merged_X_from(self, plans, X) -> np.ndarray:
        V = max(p.vsr.V for p in plans)
        out = np.zeros((len(plans), V), np.int32)
        for r, plan in enumerate(plans):
            reg = self.partition.regions[plan.assigned]
            nodes = reg.proc_ids[X[plan.assigned][plan.body_row,
                                                  :plan.vsr.V]]
            if plan.migrated:
                nodes = nodes.copy()
                nodes[int(plan.vsr.input_vm[0])] = int(plan.vsr.src[0])
            out[r, :plan.vsr.V] = nodes
        return out

    # -- region-aware churn ------------------------------------------------
    @_traced("federated_add", ledger=True)
    def add(self, service: vsr_mod.VSRBatch, sid: Optional[int] = None,
            region: Optional[int] = None, priority: Optional[int] = None):
        """Admit one service: an incremental churn event on its region's
        engine.  On a regional budget breach the arrival is migrated to
        the coolest admissible region (stub left at home, cut links priced
        over the core); ``None`` = rejected everywhere.  ``priority`` is
        the service's admission class (threaded to the region engine's
        priority queue; smaller = more important)."""
        if self._flat:
            return self._flat.add(service, sid=sid, priority=priority)
        if service.R != 1:
            raise ValueError(f"add() takes one service, got R={service.R}")
        for kind in ("region_affinity", "region_anti_affinity"):
            v = getattr(self.spec, kind)
            if v is not None and np.ndim(v) > 0:
                raise ValueError(
                    f"add() with a sequence {kind} is unsupported: it binds "
                    "to batch rows, and churn would silently re-assign "
                    "constraints across services.  Use a scalar, or pass "
                    "region= explicitly.")
        if sid is None:
            sid = self._next_sid
        if sid in self._plans:
            raise ValueError(f"sid {sid} is already live")
        self._next_sid = max(self._next_sid, sid + 1)
        home = self.partition.home_region(int(service.src[0]))
        prio = 0 if priority is None else int(priority)
        if home in self._down:
            # the source region is dark: its pinned input VM cannot run, so
            # the arrival is parked (never dropped) and retried on recovery
            self._fqueue.append((service, sid, prio))
            if self.monitor is not None:
                self.monitor.strand(sid, self._now,
                                    detail=f"sid={sid} home {home} down")
            return None
        aff = self._row_constraint("region_affinity", 0)
        anti = self._row_constraint("region_anti_affinity", 0)
        if region is not None:
            targets = [region]
        elif aff >= 0:
            targets = [aff]
        else:
            targets = self._allowed_regions(home, anti)
        targets = [g for g in targets if g not in self._down]
        if not targets:
            return None
        cap = self.spec.inter_region_hops
        for g in targets:
            # pinned targets (region= / affinity) get the same hop-cap
            # validation the batch path's _assign enforces
            if (g != home and cap is not None
                    and int(self.partition.core_hops[home, g]) > cap):
                raise ValueError(
                    f"region {g} is {int(self.partition.core_hops[home, g])}"
                    f" core hops from home {home}, past inter_region_hops="
                    f"{cap}")
        migrated_off: Optional[int] = None
        for k, g in enumerate(targets):
            res = self._try_add(service, sid, g, prio)
            if res is None:
                continue
            budget = self._budget(g)
            home_budget = self._budget(home)
            if budget is not None or (g != home and home_budget is not None):
                bd = self.breakdown()
                if budget is not None and bd.regional_w[g] > budget:
                    if self.monitor is not None:
                        self.monitor.count("region_budget_breach",
                                           detail=f"region={g} sid={sid}")
                    if k + 1 < len(targets):
                        self._drop(sid)
                        if migrated_off is None:
                            migrated_off = g
                        continue
                    # no cooler region admits it: keep best-effort (breach
                    # already counted for the operator)
                if (g != home and home_budget is not None
                        and bd.regional_w[home] > home_budget
                        and self.monitor is not None):
                    # the stub (pinned input compute + cut egress) can push
                    # the HOME region over budget; it is physically pinned
                    # there, so this is surfaced rather than migrated
                    self.monitor.count(
                        "region_budget_breach",
                        detail=f"region={home} sid={sid} (stub)")
            if migrated_off is not None and self.monitor is not None:
                # ONE migration per arrival that finally landed, counted at
                # the region where it stays (not at intermediate drops)
                self.monitor.count(
                    "cross_region_migration",
                    detail=f"sid={sid} region {migrated_off} -> {g}")
            if self.monitor is not None:
                # closes the availability window of a service stranded by a
                # region fault (no-op otherwise)
                self.monitor.unstrand(sid, self._now)
            return res
        return None

    def _try_add(self, service, sid, g, prio: int = 0):
        plan = make_plan(self.partition, service, sid, g)
        eng = self._engine(g)
        res = eng.add(plan.body, sid=sid, priority=prio)
        if res is None:
            return None
        if plan.migrated:
            stub_res = self._engine(plan.home).add(plan.stub, sid=sid,
                                                   priority=prio)
            if stub_res is None:   # stub refused (pathological budgets)
                eng.remove(sid)
                return None
        self._plans[sid] = plan
        self._order.append(sid)
        self._prio[sid] = prio
        return res

    def _drop(self, sid: int) -> None:
        plan = self._plans.pop(sid)
        self._engines[plan.assigned].remove(sid)
        if plan.migrated:
            self._engines[plan.home].remove(sid)
        self._order.remove(sid)
        self._prio.pop(sid, None)

    @_traced("federated_remove", ledger=True)
    def remove(self, sid: int):
        """Retire a service from its region engine(s) (body + stub)."""
        if self._flat:
            return self._flat.remove(sid)
        if sid not in self._plans:
            raise KeyError(f"no live service {sid}")
        plan = self._plans[sid]
        res = self._engines[plan.assigned].remove(sid)
        if plan.migrated:
            self._engines[plan.home].remove(sid)
        self._plans.pop(sid)
        self._order.remove(sid)
        self._prio.pop(sid, None)
        return res

    @_traced("federated_wave", ledger=True)
    def apply_wave(self, arrivals: Sequence = (),
                   departures: Sequence[int] = ()):
        """Apply one churn wave across the federation.

        Arrivals homed in an up region with no budget pressure batch into
        ONE ``OnlineEmbedder.apply_wave`` per target region (the fused
        detach/attach + single-polish path); anything that needs the
        coordinator -- budget-breach migration, affinity steering
        off-home, a down home region -- falls back to the per-event
        ``add``, as does any arrival its home-region wave refused (the
        per-event path re-probes home, then cooler regions).  Non-migrated
        departures batch per host region; migrated ones (body + stub in
        two regions) retire per-event.  Returns an aggregated
        ``dynamic.WaveResult``; its ``result`` is None -- there is no
        single fleet ``SolveResult`` across regions, use ``breakdown()``.
        """
        if self._flat:
            return self._flat.apply_wave(arrivals, departures)
        for kind in ("region_affinity", "region_anti_affinity"):
            v = getattr(self.spec, kind)
            if v is not None and np.ndim(v) > 0:
                raise ValueError(
                    f"apply_wave() with a sequence {kind} is unsupported "
                    "(see add())")
        arr: List[tuple] = []
        seen: set = set()
        for a in arrivals:
            if isinstance(a, (tuple, list)):
                svc = a[0]
                sid = a[1] if len(a) > 1 else None
                prio = int(a[2]) if len(a) > 2 and a[2] is not None else 0
            else:
                svc, sid, prio = a, None, 0
            if svc.R != 1:
                raise ValueError(
                    f"wave arrivals must be R=1, got R={svc.R}")
            if sid is None:
                sid = self._next_sid
            if sid in self._plans or sid in seen:
                raise ValueError(f"sid {sid} is already live")
            seen.add(sid)
            self._next_sid = max(self._next_sid, sid + 1)
            arr.append((svc, int(sid), prio))
        deps = [int(s) for s in departures]
        if len(deps) != len(set(deps)):
            raise ValueError("duplicate departure sid in wave")
        for s in deps:
            if s not in self._plans:
                raise KeyError(f"no live service {s}")
        wr = dynamic.WaveResult(result=None,
                                sids=[sid for _, sid, _ in arr],
                                departed=deps)
        if not arr and not deps:
            return wr
        aff = self._row_constraint("region_affinity", 0)
        anti = self._row_constraint("region_anti_affinity", 0)
        budgets = (self.spec.region_power_budget_w is not None
                   or bool(self._budget_override))
        dep_by_g: Dict[int, List[int]] = {}
        for s in deps:
            plan = self._plans[s]
            if plan.migrated:
                self.remove(s)
            else:
                dep_by_g.setdefault(plan.assigned, []).append(s)
        arr_by_g: Dict[int, List[tuple]] = {}
        slow_arr: List[tuple] = []
        for svc, sid, prio in arr:
            home = self.partition.home_region(int(svc.src[0]))
            g = aff if aff >= 0 else home
            if budgets or g != home or home in self._down or anti == g:
                slow_arr.append((svc, sid, prio))
            else:
                arr_by_g.setdefault(g, []).append((svc, sid, prio))
        svc_of = {sid: (svc, prio) for svc, sid, prio in arr}
        for g in sorted(set(dep_by_g) | set(arr_by_g)):
            a_g = arr_by_g.get(g, [])
            plans = {sid: make_plan(self.partition, svc, sid, g)
                     for svc, sid, _ in a_g}
            prios = {sid: prio for _, sid, prio in a_g}
            wres = self._engine(g).apply_wave(
                [(plans[sid].body, sid, prios[sid]) for _, sid, _ in a_g],
                dep_by_g.get(g, ()))
            for s in wres.departed:
                self._plans.pop(s)
                self._order.remove(s)
                self._prio.pop(s, None)
            for sid in wres.admitted:
                self._plans[sid] = plans[sid]
                self._order.append(sid)
                self._prio[sid] = prios[sid]
            wr.admitted.extend(wres.admitted)
            wr.queued.extend(wres.queued)
            wr.n_preempted += wres.n_preempted
            for sid in wres.rejected:
                svc, prio = svc_of[sid]
                slow_arr.append((svc, sid, prio))
        # coordinator fallbacks admit in priority order (class first,
        # wave input order within a class)
        pos = {sid: i for i, sid in enumerate(wr.sids)}
        slow_arr.sort(key=lambda e: (e[2], pos[e[1]]))
        for svc, sid, prio in slow_arr:
            res = self.add(svc, sid=sid, priority=prio)
            if res is not None:
                wr.admitted.append(sid)
            elif (any(e[1] == sid for e in self._fqueue)
                  or any(sid in eng.queued_sids
                         for eng in self._engines.values())):
                wr.queued.append(sid)
            else:
                wr.rejected.append(sid)
        return wr

    def defrag(self):
        """Per-region full-portfolio re-pack (each under the spec masks)."""
        if self._flat:
            return self._flat.defrag()
        out = {}
        for g, eng in self._engines.items():
            if eng.problem is not None:
                out[g] = eng.defrag()
        return out

    def defrag_tick(self, rows: Optional[int] = None):
        """One amortized background-defrag slice on every live region
        engine (``OnlineEmbedder.defrag_tick`` semantics: K rows per call,
        round-robin cursor, never-regressing).  Returns ``{region:
        SolveResult}`` for regions whose slice improved the objective."""
        if self._flat:
            return self._flat.defrag_tick(rows)
        out = {}
        for g, eng in self._engines.items():
            if eng.problem is not None:
                res = eng.defrag_tick(rows)
                if res is not None:
                    out[g] = res
        return out

    # -- fault plane -------------------------------------------------------
    def tick(self, t: float) -> None:
        """Advance the federation clock (hours), propagated to every
        region engine -- availability windows are timestamped from it."""
        self._now = float(t)
        if self._flat is not None:
            self._flat.tick(t)
        for eng in self._engines.values():
            eng.tick(t)

    @property
    def down_regions(self) -> List[int]:
        return sorted(self._down)

    def fail_region(self, g: int) -> int:
        """Fail a whole region: services HOMED there are stranded (their
        pinned sources died with the region; parked for recovery), services
        merely HOSTED there are evacuated to the coolest admissible region
        through the ordinary admission path.  Returns the evacuation
        count."""
        if self._flat is not None:
            raise ValueError("fail_region needs a multi-region federation; "
                             "use engine-level fail_node on a flat session")
        if g in self._down:
            return 0
        self._down.add(g)
        if self.monitor is not None:
            self.monitor.count("region_failed", detail=f"region={g}")
        # strand first: sources in g are gone no matter where the body sits
        for sid in [s for s in list(self._order)
                    if self._plans[s].home == g]:
            svc = self._plans[sid].vsr
            prio = self._prio.get(sid, 0)
            self.remove(sid)
            self._fqueue.append((svc, sid, prio))
            if self.monitor is not None:
                self.monitor.strand(sid, self._now,
                                    detail=f"sid={sid} region {g} failed")
        # evacuate: bodies hosted in g whose homes survive re-admit through
        # add() -- the same budget-breach migration path as any arrival,
        # with g excluded via _allowed_regions
        n_evac = 0
        for sid in [s for s in list(self._order)
                    if self._plans[s].assigned == g]:
            svc = self._plans[sid].vsr
            prio = self._prio.get(sid, 0)
            self.remove(sid)
            res = self.add(svc, sid=sid, priority=prio)
            if res is None:
                self._park(svc, sid, f"sid={sid} evacuation refused",
                           prio=prio)
            else:
                n_evac += 1
                if self.monitor is not None:
                    self.monitor.count(
                        "evacuation",
                        detail=f"sid={sid} region {g} -> "
                               f"{self.assignment(sid)}")
        return n_evac

    def recover_region(self, g: int) -> int:
        """Recover a region and retry every parked service (stranded by
        failures, brownout sheds, or arrivals during the outage).  Returns
        the number re-admitted."""
        if self._flat is not None:
            raise ValueError("recover_region needs a multi-region "
                             "federation")
        if g not in self._down:
            return 0
        self._down.discard(g)
        if self.monitor is not None:
            self.monitor.count("region_recovered", detail=f"region={g}")
        return self._drain_fqueue()

    def brownout_region(self, g: int, budget_w: float) -> int:
        """Tighten region ``g``'s power budget mid-run and shed load until
        the region is within it: heaviest movable services re-admit through
        the ordinary budget-breach migration path (so each shed counts a
        ``region_budget_breach`` + ``cross_region_migration``).  Returns
        the number of services moved or parked."""
        if self._flat is not None:
            self._flat.brownout(budget_w)
            return 0
        self._budget_override[g] = float(budget_w)
        if self.monitor is not None:
            self.monitor.count("brownout",
                               detail=f"region={g} budget_w={budget_w}")
        moved = 0
        prev_w = None
        for _ in range(len(self._order)):
            try:
                bd = self.breakdown()
            except ValueError:   # empty session
                break
            w = float(bd.regional_w[g])
            if w <= budget_w:
                break
            if prev_w is not None and w >= prev_w - 1e-9:
                # the last shed did not cool the region (stub compute and
                # cut-link idle watts stay pinned home): stop best-effort
                break
            prev_w = w
            movable = [s for s in self._order
                       if self._plans[s].assigned == g
                       and self._row_constraint("region_affinity", 0) < 0]
            if not movable:
                break
            victim = max(movable,
                         key=lambda s: float(np.sum(self._plans[s].vsr.F)))
            svc = self._plans[victim].vsr
            vprio = self._prio.get(victim, 0)
            before = self.assignment(victim)
            self.remove(victim)
            res = self.add(svc, sid=victim, priority=vprio)
            if res is None:
                self._park(svc, victim, f"sid={victim} brownout shed",
                           prio=vprio)
                moved += 1
                continue
            if self.assignment(victim) == before:
                break   # nowhere cooler admits it: best-effort stay
            moved += 1
        return moved

    def brownout_end_region(self, g: int) -> None:
        """Restore region ``g``'s configured budget and retry parked
        services."""
        if self._flat is not None:
            self._flat.brownout_end()
            return
        if self._budget_override.pop(g, None) is None:
            return
        if self.monitor is not None:
            self.monitor.count("brownout_end", detail=f"region={g}")
        self._drain_fqueue()

    def _park(self, service, sid: int, detail: str, prio: int = 0) -> None:
        if all(e[1] != sid for e in self._fqueue):
            self._fqueue.append((service, sid, prio))
        if self.monitor is not None:
            self.monitor.strand(sid, self._now, detail=detail)

    def _drain_fqueue(self) -> int:
        """Retry every parked service in priority order (class first,
        arrival order within a class); still-unplaceable ones re-park
        (never silently dropped)."""
        queued, self._fqueue = self._fqueue, []
        queued = sorted(enumerate(queued), key=lambda e: (e[1][2], e[0]))
        admitted = 0
        for _, (svc, sid, prio) in queued:
            # re-parks itself if home is down
            res = self.add(svc, sid=sid, priority=prio)
            if res is not None:
                admitted += 1
            elif all(e[1] != sid for e in self._fqueue):
                self._fqueue.append((svc, sid, prio))
        return admitted

    def cancel_queued(self, sid: int) -> bool:
        """Drop a parked service (its lifetime ended while stranded)."""
        n0 = len(self._fqueue)
        self._fqueue = [e for e in self._fqueue if e[1] != sid]
        removed = len(self._fqueue) < n0
        if removed and self.monitor is not None:
            self.monitor.unstrand(sid, self._now, re_embedded=False)
        return removed

    @_traced("federated_fault", ledger=True)
    def apply_fault(self, ev: dynamic.FaultEvent):
        """Dispatch one ``FaultEvent`` at region granularity (node/link
        kinds belong to flat engines; the federated substrate faults whole
        regions)."""
        if ev.kind == "fail_region":
            return self.fail_region(int(ev.target))
        if ev.kind == "recover_region":
            return self.recover_region(int(ev.target))
        if ev.kind == "brownout":
            return self.brownout_region(int(ev.target), float(ev.value))
        if ev.kind == "brownout_end":
            return self.brownout_end_region(int(ev.target))
        raise ValueError(
            f"FederatedSession cannot apply fault kind {ev.kind!r}: "
            "substrate faults are region-granular here (fail_region / "
            "recover_region / brownout)")

    def replay(self, events: Sequence[dynamic.ServiceEvent], make_vsr,
               on_event=None, waves: bool = False) -> list:
        """Drive the federation through a churn timeline (region-aware
        ``dynamic.replay`` semantics: unknown departures are skipped).
        ``FaultEvent``s interleave via ``apply_fault``, with the clock
        ticked to each event's time.  ``waves=True`` groups same-tick
        service events into one ``apply_wave`` each (fault events stay
        single-event barriers) and runs a background ``defrag_tick``
        after every wave when ``spec.defrag_rows_per_tick`` is set."""
        if self._flat:
            return self._flat.replay(events, make_vsr, on_event,
                                     waves=waves)
        if waves:
            return self._replay_waves(events, make_vsr, on_event)
        live = set(self._order)
        stats = []
        for ev in events:
            self.tick(ev.t)
            if isinstance(ev, dynamic.FaultEvent):
                res = self.apply_fault(ev)
                live = set(self._order)
                stats.append((ev, res))
                if on_event is not None:
                    on_event(ev, res)
                continue
            if ev.kind == "arrive":
                res = self.add(make_vsr(ev.sid), sid=ev.sid)
                if res is not None:
                    live.add(ev.sid)
            else:
                if ev.sid not in live:
                    self.cancel_queued(ev.sid)
                    continue
                res = self.remove(ev.sid)
                live.discard(ev.sid)
                live.update(self._order)   # recovery/queue re-admissions
            stats.append((ev, res))
            if on_event is not None:
                on_event(ev, res)
        return stats

    def _replay_waves(self, events, make_vsr, on_event) -> list:
        """The federated ``replay(..., waves=True)`` loop: collect ->
        apply_wave (per-region batched) -> background defrag tick."""
        defrag_budget = self.spec.defrag_rows_per_tick
        stats = []
        for group in dynamic.iter_waves(events):
            self.tick(group[-1].t)
            if isinstance(group[0], dynamic.FaultEvent):
                res = self.apply_fault(group[0])
                stats.append((group[0], res))
                if on_event is not None:
                    on_event(group[0], res)
                continue
            live = set(self._order)
            arrivals, departures = [], []
            for ev in group:
                if ev.kind == "arrive":
                    arrivals.append((make_vsr(ev.sid), ev.sid))
                elif ev.sid in live:
                    departures.append(ev.sid)
                else:
                    self.cancel_queued(ev.sid)
            wres = self.apply_wave(arrivals, departures)
            if defrag_budget:
                self.defrag_tick()
            for ev in group:
                stats.append((ev, wres))
                if on_event is not None:
                    on_event(ev, wres)
        return stats
