"""Device hardware parameters for the CFN power model.

Paper sources:
  Table 1 (processing): RPi-4B (IoT), Intel i5-3427U (AF/MF), Xeon E5-2640 (CDC).
  Table 2 (networking): ONU AP (Wi-Fi), OLT, Metro router port, Metro switch,
  IP/WDM node.
  PUE: AF 1.25, MF 1.35, CDC 1.12, core 1.5, others 1.0 (paper §3).
  Idle-attribution share delta = 3% on shared high-capacity gear (paper §3,
  following [9]); access ONU APs are dedicated to the zone => full idle.

Assumptions not printed in the paper (recorded in DESIGN.md §2):
  * server counts per node (NS), LAN switch parameters inside processing nodes,
  * inter-VM bitrates (see vsr.py).
All power in W, network rates in Gbps for capacity / W-per-Gbps for energy,
processing in GFLOPS.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class ProcessingHW:
    """One processing-node class (Table 1 + LAN assumptions)."""

    name: str
    max_w: float           # max power of one server (W)
    idle_w: float          # idle power of one server (W)
    cap_gflops: float      # capacity of one server (GFLOPS)
    n_servers: int         # servers deployed at the node (NS_p)
    pue: float             # PUE_p
    # LAN inside the node (switches/routers interconnecting the servers)
    lan_idle_w: float      # pi^{LAN}
    lan_eps_w_per_gbps: float   # EL_p
    lan_cap_gbps: float    # C^{LAN}
    lan_idle_share: float  # fraction of LAN idle attributed to this service

    @property
    def eps_w_per_gflops(self) -> float:
        """E_p = (max - idle) / capacity (Table 1 'Efficiency')."""
        return (self.max_w - self.idle_w) / self.cap_gflops


@dataclass(frozen=True)
class NetworkHW:
    """One network-node class (Table 2)."""

    name: str
    max_w: float
    idle_w: float
    cap_gbps: float
    pue: float
    idle_share: float      # delta: attributed fraction of idle power

    @property
    def eps_w_per_gbps(self) -> float:
        """epsilon_n = (max - idle) / capacity (Table 2 'Efficiency')."""
        return (self.max_w - self.idle_w) / self.cap_gbps


# ----------------------------------------------------------------------------
# Paper preset (Tables 1 & 2).
# ----------------------------------------------------------------------------

IOT_RPI4 = ProcessingHW(
    name="iot-rpi4", max_w=7.3, idle_w=2.56, cap_gflops=13.5, n_servers=1,
    pue=1.0, lan_idle_w=0.0, lan_eps_w_per_gbps=0.0, lan_cap_gbps=1.0,
    lan_idle_share=0.0)

AF_I5 = ProcessingHW(
    name="af-i5-3427u", max_w=37.2, idle_w=13.8, cap_gflops=34.5, n_servers=10,
    pue=1.25, lan_idle_w=15.0, lan_eps_w_per_gbps=0.05, lan_cap_gbps=128.0,
    lan_idle_share=1.0)

MF_I5 = ProcessingHW(
    name="mf-i5-3427u", max_w=37.2, idle_w=13.8, cap_gflops=34.5, n_servers=10,
    pue=1.35, lan_idle_w=15.0, lan_eps_w_per_gbps=0.05, lan_cap_gbps=128.0,
    lan_idle_share=1.0)

CDC_XEON = ProcessingHW(
    name="cdc-xeon-e5-2640", max_w=298.0, idle_w=58.7, cap_gflops=428.0,
    n_servers=128, pue=1.12, lan_idle_w=423.0, lan_eps_w_per_gbps=0.08,
    lan_cap_gbps=600.0, lan_idle_share=0.03)

ONU_AP = NetworkHW(name="onu-ap-wifi", max_w=15.0, idle_w=9.0, cap_gbps=10.0,
                   pue=1.0, idle_share=0.03)
OLT = NetworkHW(name="olt", max_w=1940.0, idle_w=60.0, cap_gbps=8600.0,
                pue=1.0, idle_share=0.03)
METRO_ROUTER = NetworkHW(name="metro-router-port", max_w=30.0, idle_w=27.0,
                         cap_gbps=40.0, pue=1.0, idle_share=0.03)
METRO_SWITCH = NetworkHW(name="metro-switch", max_w=470.0, idle_w=423.0,
                         cap_gbps=600.0, pue=1.0, idle_share=0.03)
IPWDM_NODE = NetworkHW(name="ip-wdm-node", max_w=878.0, idle_w=790.0,
                       cap_gbps=40.0, pue=1.5, idle_share=0.03)

# The paper (§2.1) attaches the AF node to the OLT "via low-capacity low end
# routers and switches" (and the MF analogously at the metro aggregation
# switch) but prints no power entries for them; we use datasheet-class figures
# for an enterprise edge router / 48-port GbE switch, FULLY attributed because
# they are dedicated to the fog deployment (unlike the shared OLT/metro/core
# gear at delta = 3%).  This is the calibration that reproduces the paper's
# observed behaviour: AF/MF are never selected and overflow at 20 VSRs spills
# to the CDC (DESIGN.md §2, assumption ii).
LOW_END_ROUTER = NetworkHW(name="low-end-router", max_w=75.0, idle_w=60.0,
                           cap_gbps=20.0, pue=1.0, idle_share=1.0)
LOW_END_SWITCH = NetworkHW(name="low-end-switch", max_w=100.0, idle_w=80.0,
                           cap_gbps=100.0, pue=1.0, idle_share=1.0)


# ----------------------------------------------------------------------------
# Datacenter-scale preset (beyond-paper extension): the same CFN abstraction
# with TPU-pod-class processing nodes, so the placement engine can schedule the
# assigned LM architectures (see vsr.from_architecture).  Values are public
# ballpark figures for a v5e-class chip (197 TFLOPS bf16, ~250 W board power)
# and DCN/WAN optics; they parameterize the model, they are not measurements.
# ----------------------------------------------------------------------------

EDGE_POD = ProcessingHW(
    name="edge-pod-8chip", max_w=8 * 250.0, idle_w=8 * 75.0,
    cap_gflops=8 * 197_000.0, n_servers=4, pue=1.1,
    lan_idle_w=150.0, lan_eps_w_per_gbps=0.02, lan_cap_gbps=1600.0,
    lan_idle_share=1.0)

FOG_POD = ProcessingHW(
    name="fog-pod-32chip", max_w=32 * 250.0, idle_w=32 * 75.0,
    cap_gflops=32 * 197_000.0, n_servers=8, pue=1.25,
    lan_idle_w=600.0, lan_eps_w_per_gbps=0.02, lan_cap_gbps=6400.0,
    lan_idle_share=1.0)

CLOUD_POD = ProcessingHW(
    name="cloud-pod-256chip", max_w=256 * 250.0, idle_w=256 * 75.0,
    cap_gflops=256 * 197_000.0, n_servers=16, pue=1.1,
    lan_idle_w=4000.0, lan_eps_w_per_gbps=0.01, lan_cap_gbps=51_200.0,
    lan_idle_share=0.03)

DCN_SWITCH = NetworkHW(name="dcn-switch", max_w=1200.0, idle_w=800.0,
                       cap_gbps=12_800.0, pue=1.1, idle_share=0.03)
WAN_ROUTER = NetworkHW(name="wan-router", max_w=3000.0, idle_w=2400.0,
                       cap_gbps=25_600.0, pue=1.5, idle_share=0.03)


def scaled(hw: ProcessingHW, **kw) -> ProcessingHW:
    """Return a copy of ``hw`` with fields overridden."""
    return dataclasses.replace(hw, **kw)
