"""Unified placement API: one declarative constraint object, one session.

The paper's MILP is a single optimization with one constraint set (Eq. 1/2
power, hop/latency bounds, capacity).  The repo grew five entry points --
``solve_cfn``, ``embed``, ``embed_latency_bounded``, ``resolve_incremental``
and ``OnlineEmbedder``/``EnergyAwareScheduler`` -- each threading SLA masks,
pinning, budgets and portfolio knobs through different ad-hoc kwargs, which
is exactly how the defrag-ignores-``max_hops`` hole crept in.  This module
replaces the kwarg sprawl with two objects:

  * **PlacementSpec** -- a frozen, declarative bundle of everything that
    constrains or configures a solve: per-service ``max_hops`` /
    eligibility masks, admission budgets, R- and V-shape bucketing policy,
    portfolio method/effort, and the anneal backend.  ``spec.masks(problem)``
    builds the [R, P] eligibility mask in ONE place; every solver path
    (coordinate sweeps, all three Metropolis backends' proposal streams,
    the full-portfolio defrag, the incremental re-solve) consumes that same
    mask, so a constraint declared once is enforced everywhere.  The spec
    is registered as a jax pytree (array-valued constraints are leaves,
    config is static aux data) and survives flatten/unflatten.

  * **CFNSession** -- the facade owning topology + spec + warm state:
    ``solve()`` embeds a whole VSR batch (or re-packs the live set),
    ``add``/``remove`` are warm-start churn events, ``defrag()`` re-packs
    under the SAME spec (closing the ROADMAP's defrag/SLA hole
    structurally), ``attribute()`` splits fleet watts per tenant, and
    ``replay()`` drives a churn timeline.

The legacy entry points remain as deprecated shims that construct a
``PlacementSpec`` internally, so old call sites keep working while new code
declares constraints once:

    from repro.api import CFNSession, PlacementSpec
    spec = PlacementSpec(max_hops=2, power_budget_w=500.0)
    session = CFNSession(topo, spec)
    session.solve(vsrs)                      # batch embedding
    session.add(service); session.defrag()   # online churn, masked defrag
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Union

import jax
import numpy as np

from . import dynamic, embed as embed_mod, vsr as vsr_mod
from .embed import METHODS
from .power import PlacementProblem, SubstrateHealth
from .solvers import SolveResult, solve_portfolio
from .topology import CFNTopology

__all__ = ["PlacementSpec", "CFNSession", "SolveResult", "solve_portfolio",
           "FederatedSession", "RegionPartition", "SubstrateHealth"]

_EFFORTS = ("quick", "standard", "high")
_BACKENDS = ("auto", "delta", "fused", "full")


@dataclass(frozen=True, eq=False)
class PlacementSpec:
    """Declarative constraint + configuration bundle for CFN placement.

    Constraint fields (pytree leaves):
      * ``max_hops`` -- SLA hop bound: every VM of a service must sit within
        this many network hops of the service's source node.  A scalar
        applies to all services; a length-n sequence constrains the first n
        rows (rows beyond it -- e.g. shape-bucket padding -- are
        unconstrained).  ``None`` disables.
      * ``eligible`` -- explicit [R, P] bool mask ANDed on top of the hop
        mask (rows beyond its length are unconstrained).
      * ``health`` -- ``power.SubstrateHealth`` up/down state of the
        physical substrate (fault plane).  Dead nodes and nodes behind dead
        network elements are ANDed out of the mask for EVERY row, and the
        online engine additionally zeroes dead capacities on the problem
        (``health.degrade``).  Column-wise and shape-preserving, so it
        composes with churn: unlike row-positional constraints it never
        binds to batch rows.

    Row-positional forms (sequence ``max_hops``, explicit ``eligible``)
    bind to BATCH rows and are rejected by the churn path (``add`` /
    ``remove`` raise: a removal shifts row indices, which would silently
    re-assign SLAs across services); scalar ``max_hops`` is the online
    contract.

    Federation fields (consumed by ``core.federation.FederatedSession``;
    flat sessions ignore them):
      * ``region_affinity`` -- per-service target region index (-1 = the
        service's home region, i.e. the region owning its source node).  A
        scalar applies to all services; a length-n sequence binds to the
        first n batch rows.
      * ``region_anti_affinity`` -- per-service FORBIDDEN region index
        (-1 = none); a service homed in its forbidden region is migrated
        out at admission.
      * ``region_power_budget_w`` -- per-region total-watts budget (scalar
        = every region; sequence = per region).  The federation coordinator
        migrates services out of a region whose exact attributed watts
        exceed its budget.
      * ``inter_region_hops`` -- cap on shared-core hops a cross-region
        (migrated) service may traverse between its home and host regions.

    Admission budgets (online path; ``None`` disables each):
      * ``power_budget_w`` -- reject an arrival whose incremental fleet
        power draw exceeds this many watts.
      * ``violation_tol`` -- reject an arrival that increases capacity
        violation by more than this.
      * ``queue_rejected`` -- park rejected arrivals and retry after each
        capacity-increasing event (departure, recovery, brownout_end)
        instead of dropping them.
      * ``priority_classes`` -- number of admission priority classes (class
        0 is the most important).  Services carry a class at ``add()`` /
        ``apply_wave()`` time; the rejection queue drains class-by-class
        (FIFO within a class).
      * ``preempt`` -- under power-budget pressure, let an arrival park a
        strictly lower-class live service into the queue to free budget
        (lowest class first, newest first), instead of rejecting.
      * ``defrag_rows_per_tick`` -- amortized background defrag: every
        ``defrag_tick()`` delta-sweeps this many live rows (round-robin
        cursor carried across ticks, never-regressing).  > 0 REPLACES the
        periodic full-portfolio defrag (``defrag_every`` stops firing), so
        defrag cost leaves the per-event latency path entirely.

    Shape-bucketing policy (compile-count hygiene; see power.build_problem):
      * ``bucket_rows``/``bucket_cols`` -- pad the service count R and the
        VM width V to power-of-two buckets (zero-demand fully-pinned pads).
      * ``row_bucket_lo``/``col_bucket_lo`` -- smallest bucket.

    Portfolio / solver configuration:
      * ``method`` -- solver for full solves (one of ``embed.METHODS``).
      * ``effort`` -- portfolio tier: "quick" (coordinate warm starts only),
        "standard" (+4000-step anneal), "high" (+12000 steps and genetic).
      * ``backend`` -- anneal backend ("auto"/"delta"/"fused"/"full").
      * ``defrag_every`` -- full-portfolio re-pack cadence in churn events
        (0 disables periodic defrag).
      * ``sweeps``/``anneal_steps``/``anneal_chains``/``anneal_t0``/
        ``anneal_t1``/``remove_anneal_t0``/``polish_sweeps`` -- the
        incremental re-solve knobs (``solvers.resolve_incremental``);
        departures re-pack survivors from the hotter ``remove_anneal_t0``.
    """

    # constraints --------------------------------------------------------
    max_hops: Optional[Union[int, Sequence[int], np.ndarray]] = None
    eligible: Optional[np.ndarray] = None
    # substrate health (fault plane; see power.SubstrateHealth) -----------
    health: Optional[SubstrateHealth] = None
    # federation (core.federation.FederatedSession; ignored by flat paths) -
    region_affinity: Optional[Union[int, Sequence[int], np.ndarray]] = None
    region_anti_affinity: Optional[Union[int, Sequence[int],
                                         np.ndarray]] = None
    region_power_budget_w: Optional[Union[float, Sequence[float],
                                          np.ndarray]] = None
    inter_region_hops: Optional[int] = None
    # admission budgets ---------------------------------------------------
    power_budget_w: Optional[float] = None
    violation_tol: Optional[float] = None
    queue_rejected: bool = False
    priority_classes: int = 1
    preempt: bool = False
    defrag_rows_per_tick: int = 0
    # bucketing policy ----------------------------------------------------
    bucket_rows: bool = True
    bucket_cols: bool = True
    row_bucket_lo: int = 2
    col_bucket_lo: int = 2
    # portfolio / solver config ------------------------------------------
    method: str = "cfn-milp"
    effort: str = "standard"
    backend: str = "auto"
    defrag_every: int = 16
    sweeps: int = 2
    anneal_steps: int = 600
    anneal_chains: int = 8
    anneal_t0: float = 5.0
    anneal_t1: float = 0.05
    remove_anneal_t0: float = 20.0
    polish_sweeps: int = 2

    def __post_init__(self):
        if self.method not in METHODS:
            raise ValueError(f"unknown method {self.method!r}; "
                             f"choose from {METHODS}")
        if self.effort not in _EFFORTS:
            raise ValueError(f"unknown effort {self.effort!r}; "
                             f"choose from {_EFFORTS}")
        if self.backend not in _BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r}; "
                             f"choose from {_BACKENDS}")
        if self.row_bucket_lo < 1 or self.col_bucket_lo < 1:
            raise ValueError("bucket floors must be >= 1")
        if self.priority_classes < 1:
            raise ValueError("priority_classes must be >= 1")
        if self.defrag_rows_per_tick < 0:
            raise ValueError("defrag_rows_per_tick must be >= 0")

    def replace(self, **changes) -> "PlacementSpec":
        """A copy with ``changes`` applied (validation re-runs)."""
        return dataclasses.replace(self, **changes)

    # -- the one place constraint masks are built -------------------------
    def masks(self, problem: PlacementProblem) -> Optional[np.ndarray]:
        """The [R, P] node-eligibility mask this spec imposes on a problem,
        or ``None`` when unconstrained.

        Hop counts come from the problem's own padded-CSR route table
        (``route_len[b, e]`` == number of non-sentinel ids), and each
        service's source from its pinned input VM, so the mask is a pure
        function of (spec, problem) -- every consumer (coordinate sweep
        argmins, Metropolis destination sampling across all three anneal
        backends, the portfolio defrag, incremental re-solves) sees the
        identical constraint set.
        """
        h_active = self.health is not None and not self.health.all_up
        if self.max_hops is None and self.eligible is None and not h_active:
            return None
        R, P = problem.R, problem.P
        el = np.ones((R, P), dtype=bool)
        if h_active:
            el &= self.health.eligibility(problem)
        if self.max_hops is not None:
            hops = (np.asarray(problem.route_idx) < problem.N).sum(axis=-1)
            fixed_mask = np.asarray(problem.fixed_mask)
            fixed_node = np.asarray(problem.fixed_node)
            src_of = fixed_node[np.arange(R), fixed_mask.argmax(axis=1)]
            mh = np.asarray(self.max_hops)
            lim = np.full(R, np.iinfo(np.int64).max)
            if mh.ndim == 0:
                lim[:] = int(mh)
            else:
                n = min(R, mh.shape[0])
                lim[:n] = mh[:n]
            el &= hops[src_of] <= lim[:, None]
        if self.eligible is not None:
            ex = np.asarray(self.eligible, bool)
            n = min(R, ex.shape[0])
            el[:n] &= ex[:n]
        return el

    # -- pytree protocol --------------------------------------------------
    _LEAF_FIELDS = ("max_hops", "eligible", "health", "region_affinity",
                    "region_anti_affinity", "region_power_budget_w")

    def tree_flatten(self):
        aux_fields = tuple(f for f in self.__dataclass_fields__
                           if f not in self._LEAF_FIELDS)
        children = tuple(getattr(self, f) for f in self._LEAF_FIELDS)
        aux = tuple((f, getattr(self, f)) for f in aux_fields)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        kw = dict(aux)
        kw.update(dict(zip(cls._LEAF_FIELDS, children)))
        return cls(**kw)


jax.tree_util.register_pytree_node(
    PlacementSpec,
    lambda s: s.tree_flatten(),
    PlacementSpec.tree_unflatten)


def _split_services(vsrs: vsr_mod.VSRBatch) -> List[vsr_mod.VSRBatch]:
    """A VSRBatch as a list of R=1 services (session/engine row granularity;
    concat pad columns, if any, ride along as zero-demand VMs)."""
    return [vsr_mod.VSRBatch(F=vsrs.F[i:i + 1], H=vsrs.H[i:i + 1],
                             src=vsrs.src[i:i + 1],
                             input_vm=vsrs.input_vm[i:i + 1])
            for i in range(vsrs.R)]


class CFNSession:
    """The CFN placement facade: topology + spec + warm state, one object.

    All five legacy entry points collapse onto this: batch embedding
    (``solve(vsrs)``), online churn (``add``/``remove``), the masked
    full-portfolio re-pack (``defrag``), per-tenant power accounting
    (``attribute``), and timeline replay (``replay``).  The session's
    engine (``core.dynamic.OnlineEmbedder``) carries the placement and the
    incremental load state between events; every solve -- incremental or
    full -- enforces ``spec.masks`` identically.
    """

    def __init__(self, topo: CFNTopology,
                 spec: Optional[PlacementSpec] = None,
                 key: Optional[jax.Array] = None,
                 monitor=None, telemetry=None):
        self.topo = topo
        self._engine = dynamic.OnlineEmbedder(
            topo, spec=spec if spec is not None else PlacementSpec(),
            key=key, monitor=monitor, telemetry=telemetry)
        if monitor is not None and telemetry is not None:
            monitor.attach_telemetry(telemetry)

    # -- configuration / introspection ------------------------------------
    def attach_monitor(self, monitor) -> None:
        """Attach (or replace) the ``fault.monitor.PlacementMonitor``
        receiving this session's admission/budget events."""
        self._engine.monitor = monitor
        if monitor is not None and self.telemetry is not None:
            monitor.attach_telemetry(self.telemetry)

    def attach_telemetry(self, telemetry) -> None:
        """Attach (or replace) the ``repro.telemetry.Telemetry`` receiving
        this session's spans, energy ledger, and compile attribution; an
        attached monitor mirrors its counters there too."""
        self._engine.attach_telemetry(telemetry)
        if self._engine.monitor is not None and telemetry is not None:
            self._engine.monitor.attach_telemetry(telemetry)

    @property
    def telemetry(self):
        return self._engine.telemetry

    @property
    def spec(self) -> PlacementSpec:
        return self._engine.spec

    @property
    def engine(self) -> "dynamic.OnlineEmbedder":
        """The underlying online engine (escape hatch for benchmarks)."""
        return self._engine

    @property
    def n_live(self) -> int:
        return self._engine.n_live

    @property
    def sids(self) -> List[int]:
        return self._engine.sids

    @property
    def problem(self) -> Optional[PlacementProblem]:
        return self._engine.problem

    @property
    def X(self) -> Optional[np.ndarray]:
        return self._engine.X

    @property
    def result(self) -> Optional[SolveResult]:
        return self._engine.result

    @property
    def stats(self) -> list:
        return self._engine.stats

    @property
    def admission(self) -> Dict[str, int]:
        return self._engine.admission

    def service_vms(self, row: int) -> int:
        return self._engine.service_vms(row)

    def power_w(self) -> float:
        return self._engine.power_w()

    def objective(self) -> float:
        return self._engine.objective()

    def masks(self) -> Optional[np.ndarray]:
        """The live problem's eligibility mask under this spec."""
        return (None if self.problem is None
                else self.spec.masks(self.problem))

    # -- solving ----------------------------------------------------------
    def solve(self, vsrs: Optional[vsr_mod.VSRBatch] = None
              ) -> Optional[SolveResult]:
        """Embed a whole VSR batch under the spec, or re-pack the live set.

        With ``vsrs`` (empty session only): the batch becomes the session's
        live services -- one full solve with ``spec.method``/``effort``,
        constraint masks applied.  Without ``vsrs``: a full re-pack of the
        current live set (identical to ``defrag()``).
        """
        if vsrs is None:
            if self._engine.problem is None:
                raise ValueError("empty session: pass a VSRBatch to solve()")
            return self._engine.defrag()
        if self._engine.n_live:
            raise ValueError(
                "session already has live services; use add()/remove() for "
                "churn or solve() with no batch to re-pack")
        return self._engine.bootstrap(_split_services(vsrs))

    def add(self, service: vsr_mod.VSRBatch, sid: Optional[int] = None,
            priority: Optional[int] = None) -> Optional[SolveResult]:
        """Admit one service (R=1): warm-start incremental re-embedding
        under the spec's masks and admission budgets.  ``priority`` is the
        admission class (0 = highest; < ``spec.priority_classes``).
        ``None`` = rejected."""
        return self._engine.add(service, sid=sid, priority=priority)

    def remove(self, sid: int) -> Optional[SolveResult]:
        """Retire a service: detach its loads, re-settle survivors."""
        return self._engine.remove(sid)

    def apply_wave(self, arrivals: Sequence = (),
                   departures: Sequence[int] = ()) -> "dynamic.WaveResult":
        """Apply one churn wave (a tick's arrivals + departures) as a
        single batched re-solve (``OnlineEmbedder.apply_wave``): one fused
        detach, one warm-started ``solvers.resolve_wave``, one polish pass,
        priority-ordered admission, queue drain.  A wave of size 1 is
        bit-identical to the per-event ``add``/``remove`` path."""
        return self._engine.apply_wave(arrivals, departures)

    def defrag_tick(self, rows: Optional[int] = None) -> Optional[SolveResult]:
        """One amortized background-defrag step (``spec.defrag_rows_per_tick``
        rows, round-robin, never-regressing); see
        ``OnlineEmbedder.defrag_tick``."""
        return self._engine.defrag_tick(rows)

    def defrag(self) -> Optional[SolveResult]:
        """Full-portfolio re-pack of the live set under ``spec.masks`` --
        a hop-constrained service can never be defragged out of its
        radius.  Keeps the live placement when the portfolio can't beat
        it."""
        return self._engine.defrag()

    # -- fault plane ------------------------------------------------------
    @property
    def health(self) -> Optional[SubstrateHealth]:
        return self._engine.spec.health

    def tick(self, t: float) -> None:
        """Advance the session clock (availability timestamps)."""
        self._engine.tick(t)

    def fail_node(self, node: int) -> Optional[SolveResult]:
        """Fail a processing node: strand services sourced there, mass
        re-embed displaced VMs on the degraded substrate."""
        return self._engine.fail_node(node)

    def recover_node(self, node: int) -> Optional[SolveResult]:
        return self._engine.recover_node(node)

    def fail_link(self, n: int) -> Optional[SolveResult]:
        """Fail a network element: traffic routed across it is re-embedded
        around the cut."""
        return self._engine.fail_link(n)

    def recover_link(self, n: int) -> Optional[SolveResult]:
        return self._engine.recover_link(n)

    def brownout(self, budget_w: float) -> None:
        """Tighten the admission power budget mid-run (restore with
        ``brownout_end``)."""
        self._engine.brownout(budget_w)

    def brownout_end(self) -> None:
        self._engine.brownout_end()

    def apply_fault(self, ev: "dynamic.FaultEvent"):
        """Dispatch one ``core.dynamic.FaultEvent`` to the handlers above."""
        return self._engine.apply_fault(ev)

    def attribute(self) -> Dict[int, float]:
        """Per-tenant watts {sid: W}, summing exactly to the fleet total."""
        return self._engine.per_service_power_w()

    def replay(self, events: Sequence["dynamic.ServiceEvent"],
               make_vsr: Callable[[int], vsr_mod.VSRBatch],
               on_event: Optional[Callable] = None,
               waves: bool = False) -> list:
        """Drive the session through a churn timeline
        (``core.dynamic.replay`` on this session's engine).  ``waves=True``
        batches same-tick events through ``apply_wave`` and runs the
        amortized background defrag tick after each wave."""
        return dynamic.replay(self._engine, events, make_vsr, on_event,
                              waves=waves)

    # -- reporting --------------------------------------------------------
    def savings_vs_baseline(self, baseline: str = "cdc") -> dict:
        """Paper headline metric for the live set: power saving vs a
        fixed-layer baseline, BOTH solved under this spec's constraints
        (masks, effort, backend) so the reported saving is achievable
        within the declared SLA."""
        vsrs = self._engine.vsr_batch()
        if vsrs is None:
            raise ValueError("empty session")
        from .power import build_problem
        problem = build_problem(self.topo, vsrs)
        base = embed_mod._embed(self.topo, vsrs,
                                self.spec.replace(method=baseline),
                                problem=problem)
        opt = embed_mod._embed(self.topo, vsrs, self.spec, problem=problem)
        saving = 1.0 - opt.power / max(base.power, 1e-9)
        return dict(baseline_w=base.power, optimized_w=opt.power,
                    saving_frac=saving, baseline=base, optimized=opt)


# Federation layer (bottom import: federation builds on PlacementSpec /
# CFNSession defined above; the lazy `from . import api` inside it resolves
# against this module mid-initialization without a cycle).
from .federation import FederatedSession, RegionPartition  # noqa: E402
