"""Paper core: CFN topology, power model (Eq. 1/2), VSRs, placement solvers,
the online churn engine (dynamic), the federation layer (federation), and
the unified declarative API (api.PlacementSpec / api.CFNSession /
api.FederatedSession)."""
from . import (api, dynamic, embed, federation, hardware, power, solvers,
               topology, vsr)
from .api import CFNSession, PlacementSpec
from .federation import (FederatedBreakdown, FederatedSession,
                         RegionPartition, federated_breakdown,
                         solve_portfolio_batched)
from .dynamic import (SCENARIOS, ChurnScenario, OnlineEmbedder, ServiceEvent,
                      churn_trace, diurnal_rate, poisson_timeline, replay)
from .embed import embed as embed_vsrs, savings_vs_baseline
from .power import (PlacementAux, PlacementProblem, PlacementState,
                    apply_move, attach_vsrs, attribute_power, build_aux,
                    build_problem, delta_move, delta_sweep, detach_vsrs,
                    evaluate, init_state, objective, service_loads,
                    warm_state)
from .solvers import SolveResult, solve_portfolio
from .topology import (CFNTopology, datacenter_topology, federated_scale,
                       nsfnet_topology, paper_topology)
from .vsr import VSRBatch, from_layer_costs, random_vsrs

__all__ = [
    "api", "dynamic", "embed", "federation", "hardware", "power", "solvers",
    "topology", "vsr", "PlacementSpec", "CFNSession", "FederatedSession",
    "FederatedBreakdown", "RegionPartition", "federated_breakdown",
    "federated_scale", "SolveResult", "solve_portfolio",
    "solve_portfolio_batched",
    "embed_vsrs", "savings_vs_baseline", "PlacementProblem", "build_problem",
    "evaluate", "objective", "PlacementAux", "PlacementState", "apply_move",
    "build_aux", "delta_move", "delta_sweep", "init_state", "attach_vsrs",
    "detach_vsrs", "warm_state", "service_loads", "attribute_power",
    "OnlineEmbedder", "ServiceEvent", "ChurnScenario", "SCENARIOS",
    "churn_trace", "diurnal_rate", "poisson_timeline", "replay",
    "CFNTopology", "datacenter_topology", "paper_topology",
    "nsfnet_topology", "VSRBatch", "from_layer_costs", "random_vsrs",
]
