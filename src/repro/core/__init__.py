"""Paper core: CFN topology, power model (Eq. 1/2), VSRs, placement solvers."""
from . import embed, hardware, power, solvers, topology, vsr
from .embed import embed as embed_vsrs, savings_vs_baseline
from .power import (PlacementAux, PlacementProblem, PlacementState,
                    apply_move, build_aux, build_problem, delta_move,
                    delta_sweep, evaluate, init_state, objective)
from .topology import (CFNTopology, datacenter_topology, nsfnet_topology,
                       paper_topology)
from .vsr import VSRBatch, from_layer_costs, random_vsrs

__all__ = [
    "embed", "hardware", "power", "solvers", "topology", "vsr",
    "embed_vsrs", "savings_vs_baseline", "PlacementProblem", "build_problem",
    "evaluate", "objective", "PlacementAux", "PlacementState", "apply_move",
    "build_aux", "delta_move", "delta_sweep", "init_state", "CFNTopology",
    "datacenter_topology", "paper_topology", "nsfnet_topology", "VSRBatch",
    "from_layer_costs", "random_vsrs",
]
