"""The CFN power model: paper Eq. (1) + Eq. (2), full and *incremental*.

Given a placement ``X[r, v]`` (processing-node index per VM), total power is

  net_pc = sum_n PUE_n * ( eps_n * lambda_n + beta_n * delta_n * pi_n )      (1)
  pr_pc  = sum_p PUE_p * ( E_p * Omega_p + N_p * pi_p
                           + EL_p * theta_p + Phi_p * share_p * pi_p^LAN )   (2)

with lambda_n obtained by accumulating traffic along the precomputed
padded-CSR route table (topology.py): ``route_idx[b, e, :]`` lists the <= K
network nodes on the (b, e) route (sentinel N marks padding), so every
lambda contraction is a gather/segment-sum over O(K) ids per route instead
of an O(N) dense incidence row -- the representation that keeps city-scale
substrates (P in the hundreds) off O(P^2 * N) tensors entirely.

Two evaluation regimes coexist:

  * **Full evaluation** (`evaluate` / `objective_batch`): dense tensor algebra
    over one-hot placements, O(R*V*P + L*P^2 + P^2*N) per candidate, vmapped
    over candidate batches.  This is the oracle and the right tool when a
    whole placement changes (genetic crossover, exhaustive enumeration).

  * **Delta evaluation** (the state engine): the solver hot loop (annealing,
    coordinate descent) mutates exactly ONE VM per proposal, so the load
    tensors change on a handful of entries.  ``PlacementState`` carries the
    live loads (omega[P], traffic matrix tm[P, P], theta[P], lam[N]) and a
    cached objective; ``PlacementAux`` precomputes, per VM, the incident
    virtual links (other endpoint, bitrate, direction).  ``delta_move``
    returns the exact objective change of a single-VM move in
    O(deg * N + P) -- the processing terms change only at the source and
    destination node, the network terms only along the two routes touched --
    and ``apply_move`` commits it.  ``delta_sweep`` scores all P destinations
    of one VM at once in O(P * (P + N + deg * N)), which is what coordinate
    descent consumes.  Tiny residuals left by float32 +/- updates are snapped
    to zero (SNAP_*) so the beta/phi activation indicators stay exact.

The same delta math runs fused inside kernels/placement_power.py's annealing
kernel (state resident in VMEM across Metropolis steps); kernels/ref.py holds
a float64 oracle asserting delta == objective(X') - objective(X).

Units: W, GFLOPS, Mbps (converted to Gbps where eps/EL are W per Gbps).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, replace
from typing import Dict, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .topology import CFNTopology
from .vsr import VSRBatch

# Penalty weight for capacity violations (W per unit violation); large enough
# that any feasible placement beats any infeasible one at paper scale.
PENALTY = 1.0e4
# lambda_n > ACTIVE_EPS Mbps counts a network node as activated.
ACTIVE_EPS = 1.0e-6
# Incremental-state snapping: after a +/- float32 update, magnitudes below
# these are residue of exact cancellation, not real load (smallest true
# demands are ~0.1 GFLOPS / ~5 Mbps).  Snapping keeps the beta/phi activation
# indicators identical to a from-scratch evaluation.  Mirrored in
# kernels/placement_power.py.
SNAP_GFLOPS = 1.0e-3
SNAP_MBPS = 1.0e-2
# Substrates up to this many processing nodes additionally carry the dense
# [P*P, N] route incidence table (``PlacementProblem.route_dense``): at paper
# scale the table is ~30 KB and turns the delta engine's O(K*N) per-route
# one-hot expansion back into an O(N) row gather (the ROADMAP "paper-scale
# delta-move overhead" item).  Above the gate the O(P^2*N) operand is exactly
# what the CSR representation exists to avoid, so it is never built.
DENSE_ROUTE_MAX_P = 64


class PowerBreakdown(NamedTuple):
    total: jnp.ndarray        # [] W (net + pr, no penalty)
    net: jnp.ndarray          # [] W
    proc: jnp.ndarray         # [] W
    violation: jnp.ndarray    # [] capacity violation magnitude (0 = feasible)
    per_proc: jnp.ndarray     # [P] W
    per_net: jnp.ndarray      # [N] W
    omega: jnp.ndarray        # [P] GFLOPS allocated

    @property
    def objective(self):
        return self.total + PENALTY * self.violation


@dataclass(frozen=True)
class PlacementProblem:
    """Immutable tensor bundle: substrate parameters + workload."""

    # substrate ----------------------------------------------------------
    route_idx: jnp.ndarray    # [P, P, K] int32 network-node ids, pad = N
    E: jnp.ndarray            # [P] W/GFLOPS
    C_pr: jnp.ndarray         # [P] GFLOPS per server
    NS: jnp.ndarray           # [P] servers
    pi_pr: jnp.ndarray        # [P] W idle per server
    pue_pr: jnp.ndarray       # [P]
    EL: jnp.ndarray           # [P] W/Gbps (LAN)
    C_lan: jnp.ndarray        # [P] Gbps
    pi_lan: jnp.ndarray       # [P] W
    lan_share: jnp.ndarray    # [P]
    eps: jnp.ndarray          # [N] W/Gbps
    C_net: jnp.ndarray        # [N] Gbps
    pi_net: jnp.ndarray       # [N] W
    pue_net: jnp.ndarray      # [N]
    idle_share: jnp.ndarray   # [N]
    # workload -----------------------------------------------------------
    F: jnp.ndarray            # [R, V] GFLOPS
    link_src: jnp.ndarray     # [L] int32 (flattened r*V+v)
    link_dst: jnp.ndarray     # [L] int32
    link_h: jnp.ndarray       # [L] Mbps
    fixed_mask: jnp.ndarray   # [R, V] bool: True where VM is pinned
    fixed_node: jnp.ndarray   # [R, V] int32: pinned node (src for input VMs)
    # optional dense route-row cache (small substrates only; see
    # DENSE_ROUTE_MAX_P): [P*P, N] float32 incidence rows, None above the gate
    route_dense: Optional[jnp.ndarray] = None

    @property
    def P(self) -> int:
        return self.E.shape[0]

    @property
    def N(self) -> int:
        return self.eps.shape[0]

    @property
    def K(self) -> int:
        return self.route_idx.shape[2]

    @property
    def R(self) -> int:
        return self.F.shape[0]

    @property
    def V(self) -> int:
        return self.F.shape[1]

    def tree_flatten(self):  # registered below
        children = tuple(getattr(self, f.name) for f in
                         self.__dataclass_fields__.values())  # type: ignore[attr-defined]
        return children, None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


jax.tree_util.register_pytree_node(
    PlacementProblem,
    lambda p: p.tree_flatten(),
    PlacementProblem.tree_unflatten)


def substrate_arrays(topo: CFNTopology) -> Dict[str, jnp.ndarray]:
    """Workload-independent problem tensors (device-resident).  Cache and
    pass to ``build_problem`` when building many problems on one topology
    (the online engine builds one per churn event)."""
    pp = topo.proc_param_arrays()
    nn = topo.net_param_arrays()
    out = {k: jnp.asarray(v) for k, v in {**pp, **nn}.items()}
    out["route_idx"] = jnp.asarray(topo.route_idx)
    out["route_dense"] = (
        jnp.asarray(topo.dense_path_nodes().reshape(topo.P * topo.P, topo.N))
        if topo.P <= DENSE_ROUTE_MAX_P else None)
    return out


def build_problem(topo: CFNTopology, vsrs: VSRBatch,
                  substrate: Optional[Dict[str, jnp.ndarray]] = None,
                  pad_to_rows: Optional[int] = None,
                  pad_to_cols: Optional[int] = None) -> PlacementProblem:
    """Build the tensor bundle for one workload on one substrate.

    ``pad_to_rows`` (shape bucketing, core.dynamic.OnlineEmbedder): pad the
    service dimension to that many rows with zero-demand, link-free dummy
    services whose every VM is PINNED to node 0 -- they contribute exactly
    zero load and zero free positions, so the objective and the solver move
    set are unchanged while jitted solver shapes stay on a fixed bucket.

    ``pad_to_cols`` buckets the VM dimension the same way: the workload is
    widened to that many columns with zero-demand, link-free VMs PINNED to
    each row's source node, so a single wide service changes V only up to
    its power-of-two bucket instead of recompiling every jitted solver
    shape for the whole concat batch.
    """
    if substrate is None:
        substrate = substrate_arrays(topo)
    V_nat = vsrs.V
    if pad_to_cols is not None and pad_to_cols > V_nat:
        d = pad_to_cols - V_nat
        vsrs = VSRBatch(
            F=np.pad(np.asarray(vsrs.F), ((0, 0), (0, d))),
            H=np.pad(np.asarray(vsrs.H), ((0, 0), (0, d), (0, d))),
            src=vsrs.src, input_vm=vsrs.input_vm)
    link_src, link_dst, link_h = vsrs.links()
    R, V = vsrs.R, vsrs.V
    fixed_mask = np.zeros((R, V), dtype=bool)
    fixed_mask[np.arange(R), vsrs.input_vm] = True
    fixed_node = np.zeros((R, V), dtype=np.int32)
    fixed_node[np.arange(R), vsrs.input_vm] = vsrs.src
    if V > V_nat:
        fixed_mask[:, V_nat:] = True
        fixed_node[:, V_nat:] = np.asarray(vsrs.src)[:, None]
    F = np.asarray(vsrs.F)
    if pad_to_rows is not None and pad_to_rows > R:
        pad = pad_to_rows - R
        F = np.concatenate([F, np.zeros((pad, V), F.dtype)])
        fixed_mask = np.concatenate([fixed_mask, np.ones((pad, V), bool)])
        fixed_node = np.concatenate(
            [fixed_node, np.zeros((pad, V), np.int32)])
    as_j = lambda x: jnp.asarray(x)
    return PlacementProblem(
        **substrate,
        F=as_j(F),
        link_src=as_j(link_src), link_dst=as_j(link_dst), link_h=as_j(link_h),
        fixed_mask=as_j(fixed_mask), fixed_node=as_j(fixed_node),
    )


def apply_pins(problem: PlacementProblem, X: jnp.ndarray) -> jnp.ndarray:
    """Force pinned VMs (input VMs) onto their source nodes."""
    return jnp.where(problem.fixed_mask, problem.fixed_node, X)


# ---------------------------------------------------------------------------
# Substrate health: failures degrade capacities in place (no shape changes)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SubstrateHealth:
    """Up/down state of the physical substrate.

    ``node_up`` [P] marks processing nodes, ``link_up`` [N] network
    elements.  Failures never change tensor shapes -- the same bucketing
    discipline as the row/column padding above: ``degrade`` returns a
    same-shape ``PlacementProblem`` whose failed elements have zero
    capacity (NS = 0 servers, C_lan = 0, C_net = 0), so any load left on a
    dead element draws the capacity penalty, while a *drained* dead element
    draws zero watts automatically because all idle terms are activity
    gated.  ``C_pr`` / idle powers / routes are untouched, keeping
    ``n_srv = ceil(omega / C_pr)`` well defined and jitted solver kernels
    on their compile buckets across fail/recover events.

    ``eligibility`` is the planning-side view: a [R, P] mask that removes
    dead nodes -- and every node whose route from the row's source crosses
    a dead network element -- from the solver move set
    (``PlacementSpec.masks`` ANDs it with the hop/affinity masks).

    Instances are immutable; the ``fail_*`` / ``recover_*`` methods return
    updated copies.
    """

    node_up: np.ndarray   # [P] bool
    link_up: np.ndarray   # [N] bool

    @classmethod
    def fresh(cls, topo: CFNTopology) -> "SubstrateHealth":
        return cls(node_up=np.ones(topo.P, dtype=bool),
                   link_up=np.ones(topo.N, dtype=bool))

    @property
    def all_up(self) -> bool:
        return bool(self.node_up.all()) and bool(self.link_up.all())

    def _set(self, field: str, idx: int, up: bool) -> "SubstrateHealth":
        arr = np.array(getattr(self, field), dtype=bool)
        arr[int(idx)] = up
        return replace(self, **{field: arr})

    def fail_node(self, p: int) -> "SubstrateHealth":
        return self._set("node_up", p, False)

    def recover_node(self, p: int) -> "SubstrateHealth":
        return self._set("node_up", p, True)

    def fail_link(self, n: int) -> "SubstrateHealth":
        return self._set("link_up", n, False)

    def recover_link(self, n: int) -> "SubstrateHealth":
        return self._set("link_up", n, True)

    def degrade(self, problem: PlacementProblem) -> PlacementProblem:
        """Same-shape problem with dead elements' capacities zeroed."""
        if self.all_up:
            return problem
        nu = jnp.asarray(self.node_up)
        lu = jnp.asarray(self.link_up)
        return replace(
            problem,
            NS=jnp.where(nu, problem.NS, 0.0),
            C_lan=jnp.where(nu, problem.C_lan, 0.0),
            C_net=jnp.where(lu, problem.C_net, 0.0))

    def route_ok(self) -> np.ndarray:
        """[P+1] link aliveness lookup with the sentinel slot alive, for
        indexing ``route_idx`` (pad entries hold id N)."""
        return np.concatenate([np.asarray(self.link_up, bool), [True]])

    def pair_alive(self, problem: PlacementProblem) -> np.ndarray:
        """[P, P] bool: route (a, b) traverses no dead network element."""
        route = np.asarray(problem.route_idx)
        return self.route_ok()[route].all(axis=-1)

    def eligibility(self, problem: PlacementProblem) -> np.ndarray:
        """[R, P] bool solver mask under the current health.

        A node is eligible for row r iff it is up AND the route from r's
        pinned source traverses only live network elements.  Rows whose
        source node is itself dead keep their route mask (the engine
        strands them before any solve); rows left with an empty mask must
        likewise be stranded by the caller -- the solvers' best-effort
        all-True fallback would otherwise quietly re-enable dead nodes.
        """
        if self.all_up:
            return np.ones((problem.R, problem.P), dtype=bool)
        fixed_mask = np.asarray(problem.fixed_mask)
        fixed_node = np.asarray(problem.fixed_node)
        rows = np.arange(problem.R)
        src_of = fixed_node[rows, fixed_mask.argmax(axis=1)]         # [R]
        el = self.pair_alive(problem)[src_of]                        # [R, P]
        return el & np.asarray(self.node_up, bool)[None, :]

    def tree_flatten(self):
        return (self.node_up, self.link_up), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


jax.tree_util.register_pytree_node(
    SubstrateHealth,
    lambda h: h.tree_flatten(),
    SubstrateHealth.tree_unflatten)


def _lam_from_tm(problem: PlacementProblem, tm: jnp.ndarray) -> jnp.ndarray:
    """lambda [N] from a traffic matrix [P, P]: segment-sum of tm over the
    CSR route table (sentinel ids land in the dropped N-th slot).  Works for
    soft (fractional) traffic matrices and is differentiable; NOT intended
    under vmap (batched scatters serialize on XLA CPU -- batched callers use
    ``_lam_from_links``)."""
    p = problem
    w = jnp.broadcast_to(tm[..., None], p.route_idx.shape)
    lam = jnp.zeros(p.N + 1, tm.dtype).at[p.route_idx.reshape(-1)].add(
        w.reshape(-1))
    return lam[:p.N]


def _lam_from_links(problem: PlacementProblem, X_flat: jnp.ndarray
                    ) -> jnp.ndarray:
    """lambda [N] for a HARD placement: each virtual link's bitrate
    accumulated along its route's <= K node ids, via a one-hot contraction
    (gathers + matmul only, so it vectorizes cleanly under vmap).
    O(L * K * N) flops, no O(P^2 * N) operand anywhere -- except on small
    substrates, where the ``route_dense`` cache replaces the one-hot
    expansion with an O(N) incidence-row gather (same values)."""
    p = problem
    if p.route_dense is not None:
        idx = X_flat[p.link_src] * p.P + X_flat[p.link_dst]         # [L]
        return p.link_h @ p.route_dense[idx]
    ids = p.route_idx[X_flat[p.link_src], X_flat[p.link_dst]]       # [L, K]
    oh = (ids[..., None] == jnp.arange(p.N)).astype(jnp.float32)    # [L,K,N]
    return jnp.einsum("l,lkn->n", p.link_h, oh)


def _loads(problem: PlacementProblem, onehot: jnp.ndarray,
           X_flat: Optional[jnp.ndarray] = None):
    """Shared load computation given one-hot placements [R, V, P].

    Returns ``(omega[P], tm[P, P], lam[N], theta[P])``.  For hard placements
    pass ``X_flat`` [R*V] so lambda takes the vmap-friendly per-link route
    path; soft (fractional) placements fall back to the tm segment-sum.
    """
    p = problem
    omega = jnp.einsum("rvp,rv->p", onehot, p.F)                    # [P]
    flat = onehot.reshape(-1, p.P)
    u = flat[p.link_src]                                            # [L, P]
    w = flat[p.link_dst]                                            # [L, P]
    tm = jnp.einsum("l,lp,lq->pq", p.link_h, u, w)                  # [P, P]
    intra = jnp.einsum("l,lp,lp->p", p.link_h, u, w)                # [P]
    if X_flat is None:
        lam = _lam_from_tm(p, tm)                                   # [N] Mbps
    else:
        lam = _lam_from_links(p, X_flat)
    theta = (u.T @ p.link_h) + (w.T @ p.link_h) - intra             # [P] Mbps
    return omega, tm, lam, theta


def _assemble_terms(p: PlacementProblem, omega, lam, theta, n_srv, beta, phi):
    """Eq.(1)/(2) term assembly shared by the hard and smooth branches."""
    per_net = p.pue_net * (p.eps * lam / 1e3 + beta * p.idle_share * p.pi_net)
    per_proc = p.pue_pr * (p.E * omega + n_srv * p.pi_pr
                           + p.EL * theta / 1e3
                           + phi * p.lan_share * p.pi_lan)
    violation = (jnp.sum(jax.nn.relu(omega - p.NS * p.C_pr), axis=-1)
                 + jnp.sum(jax.nn.relu(lam / 1e3 - p.C_net), axis=-1)
                 + jnp.sum(jax.nn.relu(theta / 1e3 - p.C_lan), axis=-1))
    return per_net, per_proc, violation


def _hard_terms(problem: PlacementProblem, omega, lam, theta):
    """Eq.(1)/(2) terms for hard placements; broadcasts over leading dims.

    omega/theta [..., P], lam [..., N] -> (per_net [..., N], per_proc [..., P],
    violation [...]).
    """
    p = problem
    n_srv = jnp.ceil(omega / p.C_pr)
    beta = (lam > ACTIVE_EPS).astype(jnp.float32)
    phi = ((omega > ACTIVE_EPS) | (theta > ACTIVE_EPS)).astype(jnp.float32)
    return _assemble_terms(p, omega, lam, theta, n_srv, beta, phi)


def evaluate(problem: PlacementProblem, X: jnp.ndarray,
             hard: bool = True, temp: float = 1.0) -> PowerBreakdown:
    """Total power for one placement X [R, V] (int32 node indices).

    ``hard=False`` computes the differentiable surrogate used by the
    relaxation solver: X is then [R, V, P] soft assignment probabilities,
    ceil() -> smooth overcount, indicator -> saturating soft-gate.
    """
    p = problem
    if hard:
        X = apply_pins(p, X)
        onehot = jax.nn.one_hot(X, p.P, dtype=jnp.float32)
        omega, _, lam, theta = _loads(p, onehot, X.reshape(-1))
    else:
        pin_oh = jax.nn.one_hot(p.fixed_node, p.P, dtype=jnp.float32)
        onehot = jnp.where(p.fixed_mask[..., None], pin_oh, X)
        omega, _, lam, theta = _loads(p, onehot)

    if hard:
        per_net, per_proc, violation = _hard_terms(p, omega, lam, theta)
    else:
        # smooth surrogates (upper-bounding ceil by x/C + sigmoid gate)
        n_srv = omega / p.C_pr + jax.nn.sigmoid(omega / temp)
        beta = 1.0 - jnp.exp(-lam / temp)
        phi = 1.0 - jnp.exp(-(omega + theta) / temp)
        per_net, per_proc, violation = _assemble_terms(
            p, omega, lam, theta, n_srv, beta, phi)
    net = per_net.sum()
    proc = per_proc.sum()
    return PowerBreakdown(total=net + proc, net=net, proc=proc,
                          violation=violation, per_proc=per_proc,
                          per_net=per_net, omega=omega)


@functools.partial(jax.jit, static_argnames=())
def objective(problem: PlacementProblem, X: jnp.ndarray) -> jnp.ndarray:
    """Scalar objective (power + capacity penalty) for a hard placement."""
    return evaluate(problem, X).objective


evaluate_batch = jax.jit(jax.vmap(evaluate, in_axes=(None, 0)))
objective_batch = jax.jit(jax.vmap(objective, in_axes=(None, 0)))


# ---------------------------------------------------------------------------
# Incremental delta evaluation
# ---------------------------------------------------------------------------

class PlacementAux(NamedTuple):
    """Static per-problem precomputation for the delta engine.

    Per flattened VM ``j = r*V + v``, the incident virtual links padded to the
    max degree D (padding rows have ``inc_h == 0`` and ``inc_other == j``):
      * ``inc_other[J, D]`` -- flat index of the link's other endpoint VM
      * ``inc_h[J, D]``     -- bitrate (Mbps); 0 marks padding
      * ``inc_src[J, D]``   -- True where VM j is the link's source
    plus ``free_pos[M, 2]`` -- the (r, v) positions NOT pinned by Eq.(4),
    i.e. the only positions a solver move may touch -- and ``free_flat[M]``,
    the same positions as flat indices (``r*V + v``, the convention every
    per-VM table above uses).
    """
    inc_other: jnp.ndarray
    inc_h: jnp.ndarray
    inc_src: jnp.ndarray
    free_pos: jnp.ndarray
    free_flat: jnp.ndarray


class PlacementState(NamedTuple):
    """Live placement + load tensors, kept consistent by ``apply_move``."""
    X: jnp.ndarray        # [R, V] int32, pins applied
    omega: jnp.ndarray    # [P] GFLOPS
    tm: jnp.ndarray       # [P, P] Mbps inter-node traffic matrix
    theta: jnp.ndarray    # [P] Mbps LAN traffic
    lam: jnp.ndarray      # [N] Mbps network-node traffic
    obj: jnp.ndarray      # [] cached objective (power + penalty)


def build_aux(problem: PlacementProblem) -> PlacementAux:
    """Precompute per-VM incident-link lists (numpy; once per problem)."""
    src = np.asarray(problem.link_src)
    dst = np.asarray(problem.link_dst)
    h = np.asarray(problem.link_h)
    J = problem.R * problem.V
    per_vm: list = [[] for _ in range(J)]
    for l in range(len(src)):
        s, d = int(src[l]), int(dst[l])
        if s == d:
            # self-loop: one entry; its `other` endpoint moves with the VM
            per_vm[s].append((s, float(h[l]), True))
        else:
            per_vm[s].append((d, float(h[l]), True))
            per_vm[d].append((s, float(h[l]), False))
    D = max(1, max((len(e) for e in per_vm), default=1))
    inc_other = np.empty((J, D), dtype=np.int32)
    inc_other[:] = np.arange(J, dtype=np.int32)[:, None]
    inc_h = np.zeros((J, D), dtype=np.float32)
    inc_src = np.zeros((J, D), dtype=bool)
    for j, entries in enumerate(per_vm):
        for k, (o, hh, is_src) in enumerate(entries):
            inc_other[j, k] = o
            inc_h[j, k] = hh
            inc_src[j, k] = is_src
    free_pos = np.argwhere(~np.asarray(problem.fixed_mask)).astype(np.int32)
    free_flat = (free_pos[:, 0] * problem.V + free_pos[:, 1]).astype(np.int32)
    return PlacementAux(inc_other=jnp.asarray(inc_other),
                        inc_h=jnp.asarray(inc_h),
                        inc_src=jnp.asarray(inc_src),
                        free_pos=jnp.asarray(free_pos),
                        free_flat=jnp.asarray(free_flat))


def _snap(x: jnp.ndarray, eps: float) -> jnp.ndarray:
    return jnp.where(jnp.abs(x) < eps, 0.0, x)


def _proc_power_hard(om, th, E, C_pr, pi, pue, EL, share_pi):
    """Eq.(2) power of one (or a vector of) processing node(s) under hard
    activation indicators -- the single source the delta paths share
    (entry-wise gathers in ``_delta_objective``, full vectors in
    ``delta_sweep``; ``_assemble_terms`` keeps the general soft form)."""
    phi = ((om > ACTIVE_EPS) | (th > ACTIVE_EPS)).astype(jnp.float32)
    return pue * (E * om + jnp.ceil(om / C_pr) * pi + EL * th / 1e3
                  + phi * share_pi)


def _objective_from_loads(problem, omega, lam, theta) -> jnp.ndarray:
    per_net, per_proc, viol = _hard_terms(problem, omega, lam, theta)
    return per_net.sum(-1) + per_proc.sum(-1) + PENALTY * viol


@jax.jit
def _init_state_jit(problem: PlacementProblem,
                    X: jnp.ndarray) -> PlacementState:
    X = apply_pins(problem, X)
    onehot = jax.nn.one_hot(X, problem.P, dtype=jnp.float32)
    omega, tm, lam, theta = _loads(problem, onehot, X.reshape(-1))
    obj = _objective_from_loads(problem, omega, lam, theta)
    return PlacementState(X=X, omega=omega, tm=tm, theta=theta, lam=lam,
                          obj=obj)


def batched_hard_loads(problem: PlacementProblem, Xc: jnp.ndarray):
    """Loads + objective for a batch of hard placements ``Xc [C, R, V]``:
    ``(omega [C, P], theta [C, P], lam [C, N], obj [C])``.  The single
    source for chain-state initialization, shared by the pure-JAX delta
    anneal scan and the fused Pallas kernel wrapper."""
    Xf = Xc.reshape(Xc.shape[0], -1)
    onehot = jax.nn.one_hot(Xc, problem.P, dtype=jnp.float32)
    omega, _, lam, theta = jax.vmap(
        lambda oh, xf: _loads(problem, oh, xf))(onehot, Xf)
    per_net, per_proc, viol = _hard_terms(problem, omega, lam, theta)
    obj = per_net.sum(-1) + per_proc.sum(-1) + PENALTY * viol
    return omega, theta, lam, obj


def init_state(problem: PlacementProblem, X: jnp.ndarray) -> PlacementState:
    """Full from-scratch state build (also the drift-killing `refresh`).

    Jitted at module level: the online engine refreshes state once per
    churn event, so re-tracing here would dominate the warm event cost."""
    return _init_state_jit(problem, jnp.asarray(X, jnp.int32))


def _move_core(problem: PlacementProblem, aux: PlacementAux, X_flat,
               omega, theta, lam, j, p_new):
    """Entry-wise effect of moving flat VM ``j`` to ``p_new``.

    The theta/omega deltas are supported on {p_old, p_new} ONLY (the q-side
    contributions of removal and insertion cancel algebraically for non-self
    links), so the move reduces to two per-node scalars plus the [N] route
    delta -- no [P]-wide temporaries.  Returns
    ``(p_old, sm, om2, th2, lam2, link_info)`` where ``om2``/``th2`` are the
    NEW (snapped) omega/theta values at [p_old, p_new] and ``sm`` flags the
    degenerate p_old == p_new move.
    """
    p = problem
    P = p.P
    p_old = X_flat[j]
    F_j = p.F.reshape(-1)[j]
    h = aux.inc_h[j]                                   # [D]
    is_src = aux.inc_src[j]                            # [D]
    other = aux.inc_other[j]                           # [D]
    is_self = other == j
    q = X_flat[other]                                  # [D]
    q_rm = jnp.where(is_self, p_old, q)
    q_in = jnp.where(is_self, p_new, q)
    # signed bitrates: -h for the removal leg, +h for the insertion leg
    hh = jnp.concatenate([-h, h])                       # [2D]
    q2 = jnp.concatenate([q_rm, q_in])                  # [2D]
    H_tot = h.sum()
    sr = (h * (q_rm == p_old)).sum()
    si = (h * (q_in == p_new)).sum()
    # theta delta at p_old / p_new (all other entries cancel exactly)
    alpha = -(H_tot - sr) + (hh * (q2 == p_old)).sum()
    beta = (H_tot - si) + (hh * (q2 == p_new)).sum()
    # lam: the two touched routes per link (ordered pair respects direction).
    # Each route contributes <= K node ids from the CSR table; the sentinel
    # id N never matches iota < N, so padding masks itself out.  O(D*K*N)
    # one-hot contraction -- gathers + matmul only (vmap-safe on XLA CPU),
    # no [P*P, N] dense incidence operand.  Small substrates carry the
    # guarded ``route_dense`` cache instead: the same delta as an O(N)
    # incidence-row gather per touched route, which is the anneal-scan
    # hot-path fix for the ROADMAP paper-scale delta-move overhead item.
    idx_rm = jnp.where(is_src, p_old * P + q_rm, q_rm * P + p_old)
    idx_in = jnp.where(is_src, p_new * P + q_in, q_in * P + p_new)
    idx2 = jnp.concatenate([idx_rm, idx_in])            # [2D]
    if p.route_dense is not None:
        d_lam = hh @ p.route_dense[idx2]
    else:
        rt_flat = p.route_idx.reshape(P * P, p.K)
        ids2 = rt_flat[idx2]                            # [2D, K]
        oh2 = (ids2[..., None] == jnp.arange(p.N)).astype(jnp.float32)
        d_lam = jnp.einsum("d,dkn->n", hh, oh2)
    lam2 = _snap(lam + d_lam, SNAP_MBPS)

    idx = jnp.stack([p_old, p_new])
    sm = (p_old == p_new).astype(jnp.float32)
    # degenerate move: fold the (exactly cancelling) deltas together so both
    # entries see "no change"
    d_om = jnp.stack([-F_j + sm * F_j, F_j - sm * F_j])
    d_th = jnp.stack([alpha + sm * beta, beta + sm * alpha])
    om2 = _snap(omega[idx] + d_om, SNAP_GFLOPS)         # [2]
    th2 = _snap(theta[idx] + d_th, SNAP_MBPS)
    return p_old, idx, om2, th2, lam2, (h, is_src, q_rm, q_in)


def _delta_objective(p: PlacementProblem, omega, theta, lam,
                     idx, om2, th2, lam2):
    """Objective change, summing only changed terms (no large-sum
    cancellation): processing terms move at the two entries ``idx``;
    network terms are differenced full-width where untouched entries give
    exact zeros.  The endpoints share stacked gathers to stay cheap under
    vmap (XLA CPU serializes vmapped gathers per row)."""
    om, th = omega[idx], theta[idx]                    # [2]
    pk = jnp.stack([p.E, p.C_pr, p.pi_pr, p.pue_pr, p.EL,
                    p.lan_share * p.pi_lan, p.NS * p.C_pr, p.C_lan])
    E, Cpr, pi, pue, EL, share_pi, cap_pr, C_lan = pk[:, idx]
    relu = jax.nn.relu
    proc = lambda o, t: _proc_power_hard(o, t, E, Cpr, pi, pue, EL, share_pi)
    d_proc = (proc(om2, th2) - proc(om, th)).sum()
    d_viol = (relu(om2 - cap_pr) - relu(om - cap_pr)
              + relu(th2 / 1e3 - C_lan) - relu(th / 1e3 - C_lan)).sum()
    beta = (lam > ACTIVE_EPS).astype(jnp.float32)
    beta2 = (lam2 > ACTIVE_EPS).astype(jnp.float32)
    d_net = (p.pue_net * (p.eps * (lam2 - lam) / 1e3
                          + (beta2 - beta) * p.idle_share * p.pi_net)).sum()
    d_viol += (relu(lam2 / 1e3 - p.C_net) - relu(lam / 1e3 - p.C_net)).sum()
    return d_proc + d_net + PENALTY * d_viol


def _commit_entries(vec, idx, new_vals):
    """vec with vec[idx[0]] = new_vals[0], then vec[idx[1]] = new_vals[1],
    as iota-compare selects (vmapped scalar scatters serialize on CPU)."""
    iota = jnp.arange(vec.shape[0])
    vec = jnp.where(iota == idx[0], new_vals[0], vec)
    return jnp.where(iota == idx[1], new_vals[1], vec)


def delta_move(problem: PlacementProblem, aux: PlacementAux,
               state: PlacementState, r, v, p_new) -> jnp.ndarray:
    """Exact objective change of moving VM (r, v) to node ``p_new``.

    O(deg * N + P) -- no full re-evaluation.  (r, v) must be a free
    (non-pinned) position; see ``PlacementAux.free_pos``.
    """
    j = r * problem.V + v
    X_flat = state.X.reshape(-1)
    _, idx, om2, th2, lm2, _ = _move_core(
        problem, aux, X_flat, state.omega, state.theta, state.lam, j, p_new)
    return _delta_objective(problem, state.omega, state.theta, state.lam,
                            idx, om2, th2, lm2)


def apply_move(problem: PlacementProblem, aux: PlacementAux,
               state: PlacementState, r, v, p_new) -> PlacementState:
    """Commit a single-VM move, updating every load tensor incrementally."""
    p_new = jnp.asarray(p_new, state.X.dtype)
    j = r * problem.V + v
    X_flat = state.X.reshape(-1)
    p_old, idx, om2, th2, lm2, (h, is_src, q_rm, q_in) = _move_core(
        problem, aux, X_flat, state.omega, state.theta, state.lam, j, p_new)
    delta = _delta_objective(problem, state.omega, state.theta, state.lam,
                             idx, om2, th2, lm2)
    rows = jnp.concatenate([jnp.where(is_src, p_old, q_rm),
                            jnp.where(is_src, p_new, q_in)])
    cols = jnp.concatenate([jnp.where(is_src, q_rm, p_old),
                            jnp.where(is_src, q_in, p_new)])
    vals = jnp.concatenate([-h, h])
    tm2 = _snap(state.tm.at[rows, cols].add(vals), SNAP_MBPS)
    X2 = state.X.at[r, v].set(p_new)
    return PlacementState(X=X2,
                          omega=_commit_entries(state.omega, idx, om2),
                          tm=tm2,
                          theta=_commit_entries(state.theta, idx, th2),
                          lam=lm2, obj=state.obj + delta)


def delta_sweep(problem: PlacementProblem, aux: PlacementAux,
                state: PlacementState, r, v) -> jnp.ndarray:
    """Absolute objective of moving VM (r, v) to EVERY node: [P].

    Removal once, then TOUCHED-ENTRIES scoring of all P insertions.  The
    decomposition: relative to the removal state (with the candidate-
    independent theta contribution at the link peers q_k folded in), placing
    VM j at candidate ``a`` changes

      * the PROCESSING terms at node ``a`` only (omega + F_j, theta +
        diag_add[a]) -- an O(1) correction per candidate;
      * the NETWORK terms only at the <= D*K route node ids of the routes
        a <-> q_k, gathered from the CSR route table as ``ids [P, M]``
        (M = D*K, sentinel N marks padding).  Per-node traffic deltas are
        aggregated by an [M, M] id-match (duplicate ids on several routes
        sum; only the first occurrence scores), and the Eq.(1) delta is
        evaluated on those entries alone.

    Total O(P * (M^2 + M) + P + N) with NO [P, P] / [P, N] candidate-load
    tensor and NO O(P^2*N) route operand -- this was a [P, D, N] dense
    incidence gather + full [P, N]/[P, P] re-assembly before (the version
    benchmarks/kernel_bench.py keeps as the dense baseline).  Entry
    ``p_old`` reproduces the current objective, so ``argmin`` never worsens
    the state.
    """
    p = problem
    P, N, K = p.P, p.N, p.K
    j = r * p.V + v
    X_flat = state.X.reshape(-1)
    p_old = X_flat[j]
    F_j = p.F.reshape(-1)[j]
    h = aux.inc_h[j]
    is_src = aux.inc_src[j]
    other = aux.inc_other[j]
    is_self = other == j
    q = X_flat[other]
    q_rm = jnp.where(is_self, p_old, q)
    h_ns = jnp.where(is_self, 0.0, h)      # non-self bitrates
    h_s = jnp.where(is_self, h, 0.0)

    # ---- removal (exact state with VM j taken out) ----------------------
    e_po = jax.nn.one_hot(p_old, P, dtype=jnp.float32)
    oh_qr = jax.nn.one_hot(q_rm, P, dtype=jnp.float32)          # [D, P]
    same_r = (q_rm == p_old).astype(jnp.float32)
    omega_r = state.omega - F_j * e_po
    theta_r = state.theta - (h.sum() - (h * same_r).sum()) * e_po \
        - (h[:, None] * oh_qr).sum(0)
    rt_flat = p.route_idx.reshape(P * P, K)
    idx_rm = jnp.where(is_src, p_old * P + q_rm, q_rm * P + p_old)
    ids_rm = rt_flat[idx_rm]                                    # [D, K]
    oh_rm = (ids_rm[..., None] == jnp.arange(N)).astype(jnp.float32)
    lam_r = state.lam - jnp.einsum("d,dkn->n", h, oh_rm)

    # ---- candidate-independent insertion loads --------------------------
    # theta gains h_ns_k at every peer q_k regardless of the candidate, and
    # (h_ns.sum() - add_q[a] + h_s.sum()) at the candidate itself
    add_q = (h_ns[:, None] * jax.nn.one_hot(q, P, dtype=jnp.float32)).sum(0)
    diag_add = h_ns.sum() - add_q + h_s.sum()                   # [P]
    theta_i = theta_r + add_q                                   # [P]
    omega_b = _snap(omega_r, SNAP_GFLOPS)
    theta_b = _snap(theta_i, SNAP_MBPS)
    lam_b = _snap(lam_r, SNAP_MBPS)

    # ---- base objective (candidate-independent) -------------------------
    per_net_b, per_proc_b, viol_b = _hard_terms(p, omega_b, lam_b, theta_b)
    relu = jax.nn.relu
    base = per_net_b.sum() + per_proc_b.sum() + PENALTY * viol_b

    # ---- processing correction at the candidate node (O(1) each) --------
    om_new = _snap(omega_r + F_j, SNAP_GFLOPS)                  # [P] diag
    th_new = _snap(theta_i + diag_add, SNAP_MBPS)
    cap_pr = p.NS * p.C_pr
    d_proc = _proc_power_hard(om_new, th_new, p.E, p.C_pr, p.pi_pr,
                              p.pue_pr, p.EL,
                              p.lan_share * p.pi_lan) - per_proc_b   # [P]
    d_viol_pr = (relu(om_new - cap_pr) - relu(omega_b - cap_pr)
                 + relu(th_new / 1e3 - p.C_lan)
                 - relu(theta_b / 1e3 - p.C_lan))

    # ---- network correction on the touched route ids --------------------
    # routes a <-> q_k, direction-ordered: [P, D, K] -> ids [P, M]
    ids_src = p.route_idx[:, q, :]                              # [P, D, K]
    ids_dst = jnp.swapaxes(p.route_idx[q, :, :], 0, 1)          # [P, D, K]
    ids3 = jnp.where(is_src[None, :, None], ids_src, ids_dst)   # [P, D, K]
    D = ids3.shape[1]
    valid3 = ids3 < N
    # A node shared by several of the candidate's routes must see ONE
    # aggregated traffic delta before the beta/relu nonlinearities.  Each
    # route's OWN ids are unique by construction, so duplicates can only
    # occur ACROSS routes: D*(D-1)/2 static [P, K, K] cross-route id
    # matches mark later occurrences as duplicates and accumulate the other
    # routes' bitrates onto the first one -- exact aggregation with no
    # [M, M] match and no sort (sentinel-N pads only ever match other
    # pads, whose entries are masked as invalid anyway).
    dup = [jnp.zeros((P, K), bool) for _ in range(D)]
    tot = [jnp.full((P, K), 0.0, jnp.float32) for _ in range(D)]
    for d2 in range(D):
        for d1 in range(d2):
            eq = ids3[:, d1, :, None] == ids3[:, d2, None, :]   # [P, K, K]
            in2 = eq.any(axis=2)        # route-d1 entry also on route d2
            in1 = eq.any(axis=1)        # route-d2 entry also on route d1
            tot[d1] = tot[d1] + h_ns[d2] * in2
            tot[d2] = tot[d2] + h_ns[d1] * in1
            dup[d2] = dup[d2] | in1
    first = valid3 & ~jnp.stack(dup, axis=1)                    # [P, D, K]
    tot_other = jnp.stack(tot, axis=1)                          # [P, D, K]

    # one merged [6, P, D, K] gather for the per-id operands (sentinel id
    # N hits the zero-padded column)
    tbl = jnp.stack([lam_r, lam_b, p.eps, p.pue_net,
                     p.idle_share * p.pi_net, p.C_net])
    tblp = jnp.concatenate([tbl, jnp.zeros((6, 1), tbl.dtype)], axis=1)
    lam_raw, lam_old, eps_g, pue_g, idle_g, cnet_g = tblp[:, ids3]
    lam_new = _snap(lam_raw + h_ns[None, :, None] + tot_other, SNAP_MBPS)
    beta_d = ((lam_new > ACTIVE_EPS).astype(jnp.float32)
              - (lam_old > ACTIVE_EPS).astype(jnp.float32))
    use = first.astype(jnp.float32)
    d_net = (use * pue_g * (eps_g * (lam_new - lam_old) / 1e3
                            + beta_d * idle_g)).sum((-1, -2))   # [P]
    d_viol_net = (use * (relu(lam_new / 1e3 - cnet_g)
                         - relu(lam_old / 1e3 - cnet_g))).sum((-1, -2))

    return (base + d_proc + d_net
            + PENALTY * (d_viol_pr + d_viol_net))


# ---------------------------------------------------------------------------
# Online state operations: service-granular attach / detach / warm start
# ---------------------------------------------------------------------------
#
# The delta engine above mutates one VM at a time (solver proposals).  The
# *online* regime mutates one SERVICE at a time: a VSR arrives or departs and
# the live placement must absorb the change without a from-scratch rebuild.
# Because every virtual link is intra-service (vsr.VSRBatch.links flattens
# r*V+v), one service's load contribution is separable: O(V*(N+P)) host-side
# work per event instead of the O(R*V*P + L*P^2) full `_loads` contraction.


def service_loads(problem: PlacementProblem, X,
                  rows) -> tuple[np.ndarray, np.ndarray, np.ndarray,
                                 np.ndarray]:
    """Load contribution (omega[P], tm[P, P], theta[P], lam[N]) of the
    services in ``rows`` under placement ``X`` -- exactly the slice of
    ``_loads`` supported on those services' VMs and virtual links.
    """
    p = problem
    X = np.asarray(X)
    Xf = X.reshape(-1)
    P, N, V = p.P, p.N, p.V
    rows = np.atleast_1d(np.asarray(rows, np.int64))
    omega = np.zeros(P, np.float64)  # tracelint: allow[CFN102]
    tm = np.zeros((P, P), np.float64)  # tracelint: allow[CFN102]
    theta = np.zeros(P, np.float64)  # tracelint: allow[CFN102]
    lam = np.zeros(N, np.float64)  # tracelint: allow[CFN102]
    F = np.asarray(p.F, np.float64)  # tracelint: allow[CFN102]
    np.add.at(omega, X[rows].reshape(-1), F[rows].reshape(-1))
    ls = np.asarray(p.link_src)
    ld = np.asarray(p.link_dst)
    lh = np.asarray(p.link_h, np.float64)  # tracelint: allow[CFN102]
    sel = np.isin(ls // V, rows)
    rt = np.asarray(p.route_idx)
    for s, d, h in zip(ls[sel], ld[sel], lh[sel]):
        b, e = int(Xf[s]), int(Xf[d])
        tm[b, e] += h
        theta[b] += h
        if e != b:
            theta[e] += h
            ids = rt[b, e]
            lam[ids[ids < N]] += h    # route ids are unique per route
    f32 = lambda a: a.astype(np.float32)
    return f32(omega), f32(tm), f32(theta), f32(lam)


@jax.jit
def _assemble_state_jit(problem: PlacementProblem, X, omega, tm, theta,
                        lam) -> PlacementState:
    omega = _snap(omega, SNAP_GFLOPS)
    tm = _snap(tm, SNAP_MBPS)
    theta = _snap(theta, SNAP_MBPS)
    lam = _snap(lam, SNAP_MBPS)
    obj = _objective_from_loads(problem, omega, lam, theta)
    return PlacementState(X=X, omega=omega, tm=tm, theta=theta, lam=lam,
                          obj=obj)


def _state_from_loads(problem: PlacementProblem, X, omega, tm, theta,
                      lam) -> PlacementState:
    return _assemble_state_jit(problem, jnp.asarray(X, jnp.int32),
                               jnp.asarray(omega, jnp.float32),
                               jnp.asarray(tm, jnp.float32),
                               jnp.asarray(theta, jnp.float32),
                               jnp.asarray(lam, jnp.float32))


def attach_vsrs(problem: PlacementProblem, state: PlacementState,
                rows, X_rows=None) -> PlacementState:
    """Add the load contribution of services ``rows`` to a live state.

    ``state`` must NOT already carry those services' loads (it came from
    ``detach_vsrs`` or from ``warm_state`` over a problem that grew).  If
    ``X_rows`` [len(rows), V] is given, it is written into ``state.X`` first
    (pins applied); otherwise the placements already in ``state.X`` are
    attached.  O(len(rows) * V * (N + P)); the objective cache is rebuilt
    from the updated loads in O(P + N).
    """
    X = np.asarray(state.X).copy()
    if X_rows is not None:
        X[np.atleast_1d(np.asarray(rows, np.int64))] = np.asarray(X_rows)
        X = np.asarray(apply_pins(problem, jnp.asarray(X, jnp.int32)))
    d_om, d_tm, d_th, d_lam = service_loads(problem, X, rows)
    return _state_from_loads(problem, X,
                             state.omega + d_om, state.tm + d_tm,
                             state.theta + d_th, state.lam + d_lam)


def detach_vsrs(problem: PlacementProblem, state: PlacementState,
                rows) -> PlacementState:
    """Remove the load contribution of services ``rows`` from a live state.

    The inverse of ``attach_vsrs``: the returned state's loads and objective
    describe the substrate as if those services were not embedded (their
    ``state.X`` rows become dead entries the caller drops via
    ``warm_state``'s row map).
    """
    d_om, d_tm, d_th, d_lam = service_loads(problem, state.X, rows)
    return _state_from_loads(problem, state.X,
                             state.omega - d_om, state.tm - d_tm,
                             state.theta - d_th, state.lam - d_lam)


def warm_state(problem_new: PlacementProblem, prev_X,
               prev_loads: Optional[tuple] = None,
               row_map: Optional[Sequence[int]] = None,
               init_node: Optional[int] = None) -> PlacementState:
    """Carry a previous placement into a grown / shrunk problem.

    ``prev_X`` [R_old, V_old] is the placement being carried;
    ``row_map[i] = j`` maps new row i to previous row j (``-1`` marks a
    fresh service).  Defaults to identity on the first min(R_old, R_new)
    rows with fresh rows appended -- the scheduler's arrival case.  Column
    growth (a wider VM padding) fills new columns with the row's pinned
    source (zero-demand pad VMs never affect the objective); column
    shrinkage drops pad columns.  Fresh rows start pinned-input +
    ``init_node`` (default: the row's source node).

    With ``prev_loads`` (omega, tm, theta, lam) carried from a previous
    state whose services match the SURVIVING rows (the caller detached
    departures first), the state is assembled in O(fresh * V * (N + P))
    instead of a full rebuild; otherwise falls back to ``init_state``.
    """
    p = problem_new
    prev_X = np.asarray(prev_X)
    R_old = prev_X.shape[0]
    V_old = prev_X.shape[1] if prev_X.ndim == 2 else 0
    R, V = p.R, p.V
    if row_map is None:
        row_map = list(range(min(R_old, R))) + [-1] * (R - min(R_old, R))
    row_map = list(row_map)
    if len(row_map) != R:
        raise ValueError(f"row_map has {len(row_map)} entries for R={R}")
    fixed_node = np.asarray(p.fixed_node)
    src_of = fixed_node[np.arange(R), np.argmax(np.asarray(p.fixed_mask), 1)]
    X = np.empty((R, V), dtype=np.int32)
    fresh: list = []
    for i, j in enumerate(row_map):
        fill = int(src_of[i]) if init_node is None else int(init_node)
        if j < 0:
            fresh.append(i)
            X[i] = fill
        else:
            k = min(V, V_old)
            X[i, :k] = prev_X[j, :k]
            X[i, k:] = fill
    X = np.asarray(apply_pins(p, jnp.asarray(X)))
    if prev_loads is None:
        return init_state(p, jnp.asarray(X))
    state = _state_from_loads(p, X, *prev_loads)
    if fresh:
        state = attach_vsrs(p, state, fresh)
    return state


def attribute_power(problem: PlacementProblem, X,
                    breakdown: Optional[PowerBreakdown] = None,
                    n_rows: Optional[int] = None) -> np.ndarray:
    """Split ``breakdown.total`` across services: returns per-service watts
    [R] that sum to the total exactly (float64).

    Each node's Eq.(2) power (proportional + idle servers + LAN) is shared
    among the services loading it, proportionally to their marginal energy
    there (E*omega_r + EL*theta_r); each network node's Eq.(1) power by the
    services' traffic shares lam_r.  Idle/activation terms thus follow the
    marginal load -- the per-tenant accounting the online engine reports.

    ``n_rows``: attribute over the first n_rows services only (the rows
    beyond are shape-bucketing pad rows with zero load; excluding them keeps
    the unattributable-idle residue split across REAL tenants so the
    returned watts still sum to the total).
    """
    p = problem
    X = np.asarray(apply_pins(p, jnp.asarray(X, jnp.int32)))
    bd = evaluate(p, jnp.asarray(X)) if breakdown is None else breakdown
    R = p.R if n_rows is None else int(n_rows)
    per_proc = np.asarray(bd.per_proc, np.float64)  # tracelint: allow[CFN102]
    per_net = np.asarray(bd.per_net, np.float64)  # tracelint: allow[CFN102]
    E = np.asarray(p.E, np.float64)  # tracelint: allow[CFN102]
    EL = np.asarray(p.EL, np.float64)  # tracelint: allow[CFN102]
    w_proc = np.zeros((R, p.P))
    w_net = np.zeros((R, p.N))
    for r in range(R):
        om, _, th, lm = service_loads(p, X, [r])
        present = (om > 0) | (th > 0)
        w_proc[r] = E * om + EL * th / 1e3 + 1e-12 * present
        w_net[r] = lm
    out = np.zeros(R)
    for W, per in ((w_proc, per_proc), (w_net, per_net)):
        tot = W.sum(axis=0)
        used = tot > 0
        share = np.where(used, W / np.where(used, tot, 1.0), 0.0)
        out += share @ per
        out += per[~used].sum() / max(R, 1)  # unattributable residue
    return out


def summarize(problem: PlacementProblem, topo: CFNTopology,
              X: np.ndarray) -> Dict[str, float]:
    """Human-readable per-layer report (drives Fig. 3 / Fig. 4 benchmarks)."""
    bd = evaluate(problem, jnp.asarray(X))
    per_proc = np.asarray(bd.per_proc)
    omega = np.asarray(bd.omega)
    out = dict(total_w=float(bd.total), net_w=float(bd.net),
               proc_w=float(bd.proc), violation=float(bd.violation))
    for layer in ("iot", "af", "mf", "cdc"):
        idx = topo.layer_indices(layer)
        out[f"proc_w_{layer}"] = float(per_proc[idx].sum())
        out[f"gflops_{layer}"] = float(omega[idx].sum())
    return out
