"""The CFN power model: paper Eq. (1) + Eq. (2), batched in JAX.

Given a placement ``X[r, v]`` (processing-node index per VM), total power is

  net_pc = sum_n PUE_n * ( eps_n * lambda_n + beta_n * delta_n * pi_n )      (1)
  pr_pc  = sum_p PUE_p * ( E_p * Omega_p + N_p * pi_p
                           + EL_p * theta_p + Phi_p * share_p * pi_p^LAN )   (2)

with lambda_n obtained by contracting the per-candidate traffic matrix with the
precomputed path-incidence tensor (topology.py).  Everything is expressed as
dense tensor algebra so the objective vmaps over thousands of candidate
placements -- this is the "solver hot loop" that kernels/placement_power
implements as a Pallas TPU kernel.

Units: W, GFLOPS, Mbps (converted to Gbps where eps/EL are W per Gbps).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .topology import CFNTopology
from .vsr import VSRBatch

# Penalty weight for capacity violations (W per unit violation); large enough
# that any feasible placement beats any infeasible one at paper scale.
PENALTY = 1.0e4
# lambda_n > ACTIVE_EPS Mbps counts a network node as activated.
ACTIVE_EPS = 1.0e-6


class PowerBreakdown(NamedTuple):
    total: jnp.ndarray        # [] W (net + pr, no penalty)
    net: jnp.ndarray          # [] W
    proc: jnp.ndarray         # [] W
    violation: jnp.ndarray    # [] capacity violation magnitude (0 = feasible)
    per_proc: jnp.ndarray     # [P] W
    per_net: jnp.ndarray      # [N] W
    omega: jnp.ndarray        # [P] GFLOPS allocated

    @property
    def objective(self):
        return self.total + PENALTY * self.violation


@dataclass(frozen=True)
class PlacementProblem:
    """Immutable tensor bundle: substrate parameters + workload."""

    # substrate ----------------------------------------------------------
    path_nodes: jnp.ndarray   # [P, P, N]
    E: jnp.ndarray            # [P] W/GFLOPS
    C_pr: jnp.ndarray         # [P] GFLOPS per server
    NS: jnp.ndarray           # [P] servers
    pi_pr: jnp.ndarray        # [P] W idle per server
    pue_pr: jnp.ndarray       # [P]
    EL: jnp.ndarray           # [P] W/Gbps (LAN)
    C_lan: jnp.ndarray        # [P] Gbps
    pi_lan: jnp.ndarray       # [P] W
    lan_share: jnp.ndarray    # [P]
    eps: jnp.ndarray          # [N] W/Gbps
    C_net: jnp.ndarray        # [N] Gbps
    pi_net: jnp.ndarray       # [N] W
    pue_net: jnp.ndarray      # [N]
    idle_share: jnp.ndarray   # [N]
    # workload -----------------------------------------------------------
    F: jnp.ndarray            # [R, V] GFLOPS
    link_src: jnp.ndarray     # [L] int32 (flattened r*V+v)
    link_dst: jnp.ndarray     # [L] int32
    link_h: jnp.ndarray       # [L] Mbps
    fixed_mask: jnp.ndarray   # [R, V] bool: True where VM is pinned
    fixed_node: jnp.ndarray   # [R, V] int32: pinned node (src for input VMs)

    @property
    def P(self) -> int:
        return self.E.shape[0]

    @property
    def N(self) -> int:
        return self.eps.shape[0]

    @property
    def R(self) -> int:
        return self.F.shape[0]

    @property
    def V(self) -> int:
        return self.F.shape[1]

    def tree_flatten(self):  # registered below
        children = tuple(getattr(self, f.name) for f in
                         self.__dataclass_fields__.values())  # type: ignore[attr-defined]
        return children, None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


jax.tree_util.register_pytree_node(
    PlacementProblem,
    lambda p: p.tree_flatten(),
    PlacementProblem.tree_unflatten)


def build_problem(topo: CFNTopology, vsrs: VSRBatch) -> PlacementProblem:
    pp = topo.proc_param_arrays()
    nn = topo.net_param_arrays()
    link_src, link_dst, link_h = vsrs.links()
    R, V = vsrs.R, vsrs.V
    fixed_mask = np.zeros((R, V), dtype=bool)
    fixed_mask[np.arange(R), vsrs.input_vm] = True
    fixed_node = np.zeros((R, V), dtype=np.int32)
    fixed_node[np.arange(R), vsrs.input_vm] = vsrs.src
    as_j = lambda x: jnp.asarray(x)
    return PlacementProblem(
        path_nodes=as_j(topo.path_nodes),
        **{k: as_j(v) for k, v in pp.items()},
        **{k: as_j(v) for k, v in nn.items()},
        F=as_j(vsrs.F),
        link_src=as_j(link_src), link_dst=as_j(link_dst), link_h=as_j(link_h),
        fixed_mask=as_j(fixed_mask), fixed_node=as_j(fixed_node),
    )


def apply_pins(problem: PlacementProblem, X: jnp.ndarray) -> jnp.ndarray:
    """Force pinned VMs (input VMs) onto their source nodes."""
    return jnp.where(problem.fixed_mask, problem.fixed_node, X)


def _loads(problem: PlacementProblem, onehot: jnp.ndarray):
    """Shared load computation given one-hot placements [R, V, P]."""
    p = problem
    omega = jnp.einsum("rvp,rv->p", onehot, p.F)                    # [P]
    flat = onehot.reshape(-1, p.P)
    u = flat[p.link_src]                                            # [L, P]
    w = flat[p.link_dst]                                            # [L, P]
    tm = jnp.einsum("l,lp,lq->pq", p.link_h, u, w)                  # [P, P]
    intra = jnp.einsum("l,lp,lp->p", p.link_h, u, w)                # [P]
    lam = jnp.einsum("pq,pqn->n", tm, p.path_nodes)                 # [N] Mbps
    theta = (u.T @ p.link_h) + (w.T @ p.link_h) - intra             # [P] Mbps
    return omega, lam, theta


def evaluate(problem: PlacementProblem, X: jnp.ndarray,
             hard: bool = True, temp: float = 1.0) -> PowerBreakdown:
    """Total power for one placement X [R, V] (int32 node indices).

    ``hard=False`` computes the differentiable surrogate used by the
    relaxation solver: X is then [R, V, P] soft assignment probabilities,
    ceil() -> smooth overcount, indicator -> saturating soft-gate.
    """
    p = problem
    if hard:
        X = apply_pins(p, X)
        onehot = jax.nn.one_hot(X, p.P, dtype=jnp.float32)
    else:
        pin_oh = jax.nn.one_hot(p.fixed_node, p.P, dtype=jnp.float32)
        onehot = jnp.where(p.fixed_mask[..., None], pin_oh, X)
    omega, lam, theta = _loads(p, onehot)

    if hard:
        n_srv = jnp.ceil(omega / p.C_pr)
        beta = (lam > ACTIVE_EPS).astype(jnp.float32)
        phi = ((omega > ACTIVE_EPS) | (theta > ACTIVE_EPS)).astype(jnp.float32)
    else:
        # smooth surrogates (upper-bounding ceil by x/C + sigmoid gate)
        n_srv = omega / p.C_pr + jax.nn.sigmoid(omega / temp)
        beta = 1.0 - jnp.exp(-lam / temp)
        phi = 1.0 - jnp.exp(-(omega + theta) / temp)

    per_net = p.pue_net * (p.eps * lam / 1e3 + beta * p.idle_share * p.pi_net)
    per_proc = p.pue_pr * (p.E * omega + n_srv * p.pi_pr
                           + p.EL * theta / 1e3
                           + phi * p.lan_share * p.pi_lan)
    violation = (jnp.sum(jax.nn.relu(omega - p.NS * p.C_pr))
                 + jnp.sum(jax.nn.relu(lam / 1e3 - p.C_net))
                 + jnp.sum(jax.nn.relu(theta / 1e3 - p.C_lan)))
    net = per_net.sum()
    proc = per_proc.sum()
    return PowerBreakdown(total=net + proc, net=net, proc=proc,
                          violation=violation, per_proc=per_proc,
                          per_net=per_net, omega=omega)


@functools.partial(jax.jit, static_argnames=())
def objective(problem: PlacementProblem, X: jnp.ndarray) -> jnp.ndarray:
    """Scalar objective (power + capacity penalty) for a hard placement."""
    return evaluate(problem, X).objective


evaluate_batch = jax.jit(jax.vmap(evaluate, in_axes=(None, 0)))
objective_batch = jax.jit(jax.vmap(objective, in_axes=(None, 0)))


def summarize(problem: PlacementProblem, topo: CFNTopology,
              X: np.ndarray) -> Dict[str, float]:
    """Human-readable per-layer report (drives Fig. 3 / Fig. 4 benchmarks)."""
    bd = evaluate(problem, jnp.asarray(X))
    per_proc = np.asarray(bd.per_proc)
    omega = np.asarray(bd.omega)
    out = dict(total_w=float(bd.total), net_w=float(bd.net),
               proc_w=float(bd.proc), violation=float(bd.violation))
    for layer in ("iot", "af", "mf", "cdc"):
        idx = topo.layer_indices(layer)
        out[f"proc_w_{layer}"] = float(per_proc[idx].sum())
        out[f"gflops_{layer}"] = float(omega[idx].sum())
    return out
