"""Dynamic service churn: event timelines + the online embedding engine.

The paper evaluates static VSR sets (1-20 VSRs placed once).  A serving
system sees services *arrive and depart* continuously -- the regime studied
by Yosuf et al. ("Energy Efficient Service Distribution in IoT", diurnal
demand profiles) and named the core open problem for fog AI by Tuli et al.
This module supplies both halves of that regime:

  * **Timelines** -- non-homogeneous Poisson arrivals (thinning) under a
    24 h diurnal rate profile, exponential service lifetimes, and scenario
    presets (`steady`, `diurnal24`, `burst`).
  * **OnlineEmbedder** -- the live placement state machine: `add` / `remove`
    carry the previous embedding through `power.warm_state` /
    `power.detach_vsrs` and re-solve with `solvers.resolve_incremental`
    (only the churned service's VMs are re-placed; survivors polish in
    place).  Every `defrag_every` events a full portfolio solve
    (`solvers.solve_portfolio`) re-packs the substrate and bounds the drift of
    purely local re-optimization.

Times are in hours throughout; rates in services/hour.
"""
from __future__ import annotations

import functools
import heapq
import warnings
from contextlib import nullcontext
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, Iterator, List, Optional, \
    Sequence, Tuple, Union

import jax
import numpy as np

from . import embed as embed_mod
from . import power, solvers, vsr
from .topology import CFNTopology


# ---------------------------------------------------------------------------
# Rate profiles and event timelines
# ---------------------------------------------------------------------------

def diurnal_rate(t_h, base_rate: float, peak_rate: float,
                 peak_hour: float = 20.0):
    """24 h-periodic arrival rate (services/h): a raised cosine between
    ``base_rate`` (quietest, 12 h off-peak) and ``peak_rate`` at
    ``peak_hour`` -- the evening-peak shape of Yosuf et al.'s demand
    profiles.  Accepts scalars or arrays.
    """
    phase = 2.0 * np.pi * (np.asarray(t_h, np.float64) - peak_hour) / 24.0  # tracelint: allow[CFN102]
    return base_rate + (peak_rate - base_rate) * 0.5 * (1.0 + np.cos(phase))


@dataclass(frozen=True)
class ServiceEvent:
    """One churn event: service ``sid`` arrives or departs at hour ``t``."""
    t: float
    kind: str          # "arrive" | "depart"
    sid: int


def poisson_timeline(duration_h: float,
                     rate_fn: Callable[[float], float],
                     mean_lifetime_h: float,
                     rng: np.random.Generator | int = 0,
                     max_services: Optional[int] = None
                     ) -> List[ServiceEvent]:
    """Arrival/departure events over ``[0, duration_h)``.

    Arrivals are a non-homogeneous Poisson process with intensity
    ``rate_fn(t)`` sampled by thinning; each arrival draws an Exp(mean)
    lifetime and emits a matching departure if it falls inside the horizon.
    Events are returned time-sorted (departures before arrivals on exact
    ties, so the live set stays minimal).
    """
    rng = np.random.default_rng(rng) if isinstance(rng, int) else rng
    grid = np.linspace(0.0, duration_h, 512)
    lam_max = float(np.max([rate_fn(t) for t in grid]))
    if lam_max <= 0:
        return []
    events: List[ServiceEvent] = []
    t, sid = 0.0, 0
    while True:
        t += rng.exponential(1.0 / lam_max)
        if t >= duration_h:
            break
        if rng.random() <= rate_fn(t) / lam_max:
            events.append(ServiceEvent(t, "arrive", sid))
            t_dep = t + rng.exponential(mean_lifetime_h)
            if t_dep < duration_h:
                events.append(ServiceEvent(t_dep, "depart", sid))
            sid += 1
            if max_services is not None and sid >= max_services:
                break
    events.sort(key=lambda e: (e.t, e.kind == "arrive"))
    return events


def churn_trace(n_steady: int, n_events: int,
                rng: np.random.Generator | int = 0) -> List[ServiceEvent]:
    """The benchmark trace: a steady state of ``n_steady`` live services
    perturbed by alternating single departure / arrival events (depart a
    uniformly random live service, then admit a fresh one), so every event
    is a one-service change at paper scale."""
    rng = np.random.default_rng(rng) if isinstance(rng, int) else rng
    events = [ServiceEvent(0.0, "arrive", sid) for sid in range(n_steady)]
    live = list(range(n_steady))
    sid = n_steady
    for i in range(n_events):
        t = 1.0 + i
        if i % 2 == 0:
            victim = live.pop(int(rng.integers(0, len(live))))
            events.append(ServiceEvent(t, "depart", victim))
        else:
            events.append(ServiceEvent(t, "arrive", sid))
            live.append(sid)
            sid += 1
    return events


def flash_crowd_trace(n_steady: int, n_waves: int, wave_size: int,
                      rng: np.random.Generator | int = 0,
                      replace: bool = True) -> List[ServiceEvent]:
    """A flash-crowd timeline: churn arrives in correlated same-tick WAVES
    instead of one event at a time (the regime ``apply_wave`` /
    ``replay(..., waves=True)`` batches).

    ``n_steady`` services arrive at t=0 (the bootstrap burst), then
    ``n_waves`` bursts land at t = 1, 2, ...:

      * ``replace=True`` (the steady benchmark shape): each wave departs
        ``wave_size // 2`` uniformly random live services and admits
        ``wave_size - wave_size // 2`` fresh ones IN THE SAME TICK, so the
        live count -- and the solver's compile bucket -- never moves.
      * ``replace=False`` (the classic flash crowd): ``n_waves`` pure
        arrival bursts ramp the crowd up, then equal departure bursts drain
        it in LIFO order.

    Within every tick the departures sort before the arrivals
    (``merge_timelines`` tie order), so a same-tick replace never
    double-counts capacity."""
    rng = np.random.default_rng(rng) if isinstance(rng, int) else rng
    events = [ServiceEvent(0.0, "arrive", sid) for sid in range(n_steady)]
    live = list(range(n_steady))
    sid = n_steady
    t = 0.0
    if replace:
        n_dep = wave_size // 2
        for _ in range(n_waves):
            t += 1.0
            for _ in range(n_dep):
                victim = live.pop(int(rng.integers(0, len(live))))
                events.append(ServiceEvent(t, "depart", victim))
            for _ in range(wave_size - n_dep):
                events.append(ServiceEvent(t, "arrive", sid))
                live.append(sid)
                sid += 1
    else:
        crowd: List[int] = []
        for _ in range(n_waves):
            t += 1.0
            for _ in range(wave_size):
                events.append(ServiceEvent(t, "arrive", sid))
                crowd.append(sid)
                sid += 1
        while crowd:
            t += 1.0
            for _ in range(min(wave_size, len(crowd))):
                events.append(ServiceEvent(t, "depart", crowd.pop()))
    return merge_timelines(events)


@dataclass(frozen=True)
class ChurnScenario:
    """A named workload regime: rate profile + lifetimes + VSR shape."""
    name: str
    duration_h: float
    base_rate: float           # services/h (off-peak)
    peak_rate: float           # services/h (at peak_hour)
    peak_hour: float
    mean_lifetime_h: float
    n_vms: int = 3
    vm_gflops: Tuple[float, float] = (3.0, 10.0)
    link_mbps: Tuple[float, float] = (5.0, 50.0)
    source_nodes: Tuple[int, ...] = (0,)

    def rate_fn(self) -> Callable[[float], float]:
        return lambda t: float(diurnal_rate(t, self.base_rate,
                                            self.peak_rate, self.peak_hour))

    def timeline(self, rng: np.random.Generator | int = 0
                 ) -> List[ServiceEvent]:
        return poisson_timeline(self.duration_h, self.rate_fn(),
                                self.mean_lifetime_h, rng=rng)

    def sample_vsr(self, rng: np.random.Generator | int) -> vsr.VSRBatch:
        """One fresh service (R=1 VSR) drawn from the scenario's shape."""
        return vsr.random_vsrs(1, rng=rng, n_vms=self.n_vms,
                               source_nodes=list(self.source_nodes),
                               vm_gflops=self.vm_gflops,
                               link_mbps=self.link_mbps)


SCENARIOS: Dict[str, ChurnScenario] = {
    # flat arrival rate; ~8 concurrent services in expectation
    "steady": ChurnScenario("steady", duration_h=24.0, base_rate=2.0,
                            peak_rate=2.0, peak_hour=12.0,
                            mean_lifetime_h=4.0),
    # paper-scale diurnal day: ~4 services overnight, ~20 at the peak
    "diurnal24": ChurnScenario("diurnal24", duration_h=24.0, base_rate=1.0,
                               peak_rate=5.0, peak_hour=20.0,
                               mean_lifetime_h=4.0),
    # short sharp evening burst of small services
    "burst": ChurnScenario("burst", duration_h=6.0, base_rate=0.5,
                           peak_rate=12.0, peak_hour=3.0,
                           mean_lifetime_h=1.0, vm_gflops=(1.0, 4.0)),
}


# ---------------------------------------------------------------------------
# Fault timelines: substrate failures composable with service churn
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FaultEvent:
    """One substrate fault at hour ``t``.

    Kinds on flat engines: ``fail_node`` / ``recover_node`` (``target`` =
    processing-node id), ``fail_link`` / ``recover_link`` (``target`` =
    network-element id), ``brownout`` / ``brownout_end`` (``value`` = the
    tightened fleet admission budget in watts).  Federated sessions take
    region granularity instead: ``fail_region`` / ``recover_region``
    (``target`` = region index) and ``brownout`` / ``brownout_end`` with
    ``target`` = the region whose power budget tightens to ``value``.
    """
    t: float
    kind: str
    target: int = -1
    value: Optional[float] = None


# Tie-break order at equal t: departures free capacity first, failures land
# before recoveries (a same-instant fail/recover pair nets to a clean
# recover), and arrivals admit last, onto the settled substrate.
_EVENT_ORDER = {"depart": 0,
                "fail_node": 1, "fail_link": 1, "fail_region": 1,
                "brownout": 1,
                "recover_node": 2, "recover_link": 2, "recover_region": 2,
                "brownout_end": 2,
                "arrive": 3}


def merge_timelines(*streams) -> List:
    """Merge churn (``ServiceEvent``) and fault (``FaultEvent``) streams
    into one time-sorted list, stable within the tie-break order above;
    feed the result to ``replay``."""
    events = [e for s in streams for e in s]
    events.sort(key=lambda e: (e.t, _EVENT_ORDER.get(e.kind, 9)))
    return events


def iter_waves(events: Iterable) -> Iterator[List]:
    """Group a time-sorted event stream (``merge_timelines`` output) into
    same-tick waves: maximal runs of ``ServiceEvent``s sharing one
    timestamp.  Because ``merge_timelines`` sorts departures before
    arrivals on ties, every yielded wave carries its departures first -- a
    same-tick replace inside one wave never double-counts capacity.
    ``FaultEvent``s are barriers: each is yielded as its own single-element
    wave (the churn before it must land on the pre-fault substrate)."""
    wave: List = []
    for ev in events:
        if wave and (isinstance(ev, FaultEvent) or ev.t != wave[0].t):
            yield wave
            wave = []
        if isinstance(ev, FaultEvent):
            yield [ev]
        else:
            wave.append(ev)
    if wave:
        yield wave


def _storm_nodes(topo: CFNTopology, n: int) -> List[int]:
    """The first ``n`` fog-tier nodes to fail in a storm preset: mini-fog
    servers first (the tier the paper calls "limited ... and highly
    distributed"), then access fog, then anything non-source."""
    pool: List[int] = []
    for layer in ("mf", "af", "cdc"):
        pool += [p for p in topo.layer_indices(layer) if p not in pool]
    if len(pool) < n:
        pool += [p for p in range(topo.P) if p not in pool]
    return pool[:n]


def single_node(topo: CFNTopology, node: Optional[int] = None,
                t_fail: float = 20.0, outage_h: float = 2.0
                ) -> List[FaultEvent]:
    """One fog node dies at the diurnal peak and recovers ``outage_h``
    later."""
    if node is None:
        node = _storm_nodes(topo, 1)[0]
    return [FaultEvent(t_fail, "fail_node", node),
            FaultEvent(t_fail + outage_h, "recover_node", node)]


def rack_storm(topo: CFNTopology, nodes: Optional[Sequence[int]] = None,
               n_nodes: int = 4, t_fail: float = 20.0,
               stagger_h: float = 0.05, outage_h: float = 1.0
               ) -> List[FaultEvent]:
    """A cascading rack outage: ``n_nodes`` fog nodes fail in quick
    succession (``stagger_h`` apart) and recover in the same order after
    ``outage_h``."""
    if nodes is None:
        nodes = _storm_nodes(topo, n_nodes)
    ev: List[FaultEvent] = []
    for k, p in enumerate(nodes):
        ev.append(FaultEvent(t_fail + k * stagger_h, "fail_node", int(p)))
        ev.append(FaultEvent(t_fail + outage_h + k * stagger_h,
                             "recover_node", int(p)))
    return merge_timelines(ev)


def brownout_day(topo: CFNTopology, region: int = 0,
                 budget_w: float = 500.0, t0: float = 10.0,
                 t1: float = 16.0) -> List[FaultEvent]:
    """A mid-day brownout: the power budget tightens to ``budget_w`` over
    ``[t0, t1)`` (region-targeted on a FederatedSession; a flat engine
    applies it fleet-wide)."""
    return [FaultEvent(t0, "brownout", region, value=budget_w),
            FaultEvent(t1, "brownout_end", region)]


FAULT_SCENARIOS: Dict[str, Callable] = {
    "single_node": single_node,
    "rack_storm": rack_storm,
    "brownout_day": brownout_day,
}


def fault_preset(name: str, topo: CFNTopology, **kw) -> List[FaultEvent]:
    """Build a named storm preset on a topology (see FAULT_SCENARIOS)."""
    if name not in FAULT_SCENARIOS:
        raise ValueError(f"unknown fault preset {name!r}; choose from "
                         f"{sorted(FAULT_SCENARIOS)}")
    return FAULT_SCENARIOS[name](topo, **kw)


# ---------------------------------------------------------------------------
# The online embedding engine
# ---------------------------------------------------------------------------

@dataclass
class OnlineStats:
    """Bookkeeping for one engine event (exposed to benchmarks/examples)."""
    event: str                 # "add" | "remove" | "defrag" | "reject"
    method: str
    objective: float
    power_w: float
    n_live: int


@dataclass
class WaveResult:
    """Outcome of one ``apply_wave`` call.

    ``sids`` maps the call's arrivals (input order) to their assigned
    service ids; each of those sids lands in exactly one of ``admitted`` /
    ``rejected`` / ``queued``.  ``result`` is the engine's committed fleet
    ``SolveResult`` after the wave (``None`` once the engine is empty);
    ``n_preempted`` counts live services parked to make room."""
    result: Optional[solvers.SolveResult]
    sids: List[int] = field(default_factory=list)
    admitted: List[int] = field(default_factory=list)
    rejected: List[int] = field(default_factory=list)
    queued: List[int] = field(default_factory=list)
    departed: List[int] = field(default_factory=list)
    n_preempted: int = 0


def _bucket_rows(n: int, lo: int = 2) -> int:
    """Shape bucket for a live-service count: the next power of two (>= lo).

    Each distinct problem shape compiles its own `_sweep` /
    `_anneal_scan_delta` variants (~3 s each on a 2-core box), so the online
    engine pads the service dimension to these buckets -- the compile set
    is O(log R) instead of O(distinct R), which kills the p90 latency
    spikes in examples/online_day.py.  The ONE bucketing policy, shared
    with the federated batch path (``solvers._pow2``)."""
    return solvers._pow2(n, lo=lo)


def _traced(name: str):
    """Wrap an engine entry point in a telemetry span (no-op -- not even a
    context manager allocation -- when no ``Telemetry`` is attached, so
    the disabled path stays bit-identical and free)."""
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(self, *args, **kwargs):
            tel = self.telemetry
            if tel is None:
                return fn(self, *args, **kwargs)
            with tel.span(name):
                return fn(self, *args, **kwargs)
        return wrapper
    return deco


class OnlineEmbedder:
    """Live CFN embedding under service churn.

    Keeps the current VSR set, placement, and incremental
    ``PlacementState``; ``add`` / ``remove`` re-solve with
    ``solvers.resolve_incremental`` (one-service warm-start re-embedding)
    and every ``spec.defrag_every`` events -- or on demand via ``defrag()``
    -- runs the full portfolio to re-pack the substrate.  Service identity
    is the caller's ``sid``; internally rows are dense [0, R).

    Configuration lives in one declarative ``repro.api.PlacementSpec``
    (pass ``spec=``; the legacy kwarg signature is a deprecated shim that
    builds a spec internally and keeps working via the property aliases
    below).  The spec governs:

    **Shape bucketing** (``spec.bucket_rows`` / ``spec.bucket_cols``): the
    tensor problem is padded to power-of-two service counts AND VM widths
    with zero-demand fully-pinned dummy rows/columns (power.build_problem),
    and sweep position lists are padded to the bucket, so the jitted solver
    kernels compile once per bucket instead of once per live count -- and a
    single wide service no longer recompiles the whole concat batch.

    **SLA admission control**: with ``spec.max_hops`` set, every service
    may only be placed within that many network hops of its source --
    ``spec.masks(problem)`` is rebuilt per event and threaded through every
    incremental re-solve AND through the full-portfolio defrag
    (``solvers.solve_portfolio``), so no path can move a hop-constrained
    service out of its radius; with ``spec.power_budget_w`` and/or
    ``spec.violation_tol`` set, arrivals whose incremental power draw or
    capacity-violation increase exceeds the budget are rejected -- or, with
    ``spec.queue_rejected``, parked and retried after each departure.
    Counters in ``admission`` (surfaced by ``replay``).
    """

    def __init__(self, topo: CFNTopology, defrag_every: int = 16,
                 key: Optional[jax.Array] = None, sweeps: int = 2,
                 anneal_steps: int = 600, anneal_chains: int = 8,
                 polish_sweeps: int = 2, method: str = "cfn-milp",
                 bucket_rows: bool = True,
                 max_hops: Optional[int] = None,
                 admit_power_budget_w: Optional[float] = None,
                 admit_violation_tol: Optional[float] = None,
                 queue_rejected: bool = False,
                 spec=None, monitor=None, telemetry=None):
        if spec is None:
            from . import api
            warnings.warn(
                "OnlineEmbedder(defrag_every=..., max_hops=..., ...) kwargs "
                "are deprecated; build a repro.api.PlacementSpec and pass "
                "spec= (or use repro.api.CFNSession)",
                DeprecationWarning, stacklevel=2)
            spec = api.PlacementSpec(
                method=method, defrag_every=defrag_every, max_hops=max_hops,
                power_budget_w=admit_power_budget_w,
                violation_tol=admit_violation_tol,
                queue_rejected=queue_rejected,
                bucket_rows=bucket_rows, bucket_cols=bucket_rows,
                sweeps=sweeps, anneal_steps=anneal_steps,
                anneal_chains=anneal_chains, polish_sweeps=polish_sweeps)
        self.topo = topo
        self.spec = spec
        # a fault.monitor.PlacementMonitor (optional): admission rejections
        # and budget violations are counted there instead of being dropped
        self.monitor = monitor
        # a repro.telemetry.Telemetry (optional): spans on the entry
        # points, energy-ledger ticks + convergence traces on commits,
        # compile attribution via the count_traces hook.  None (default)
        # keeps every instrumented path a strict no-op.
        self.telemetry = None
        self._commits_since_attr = 0
        if telemetry is not None:
            self.attach_telemetry(telemetry)
        self._key = jax.random.PRNGKey(1) if key is None else key
        self._add_kw = dict(sweeps=spec.sweeps,
                            anneal_steps=spec.anneal_steps,
                            anneal_chains=spec.anneal_chains,
                            anneal_t0=spec.anneal_t0,
                            anneal_t1=spec.anneal_t1,
                            polish_sweeps=spec.polish_sweeps)
        # departures re-pack the survivors: random-restart chains over all
        # free VMs need a hotter start to escape the vacated layout
        self._remove_kw = dict(self._add_kw, sweeps=0,
                               anneal_t0=spec.remove_anneal_t0)
        self.admission = dict(admitted=0, rejected=0, queued=0, preempted=0)
        # the rejection queue is a priority heap of (class, seq, sid,
        # service): class 0 drains first, FIFO (seq) within a class
        self._queue: List[tuple] = []
        self._qseq = 0
        self._vsrs: List[vsr.VSRBatch] = []    # one R=1 batch per service
        self._sids: List[int] = []
        self._prio: List[int] = []             # admission class per live row
        self._next_sid = 0
        # amortized background defrag: round-robin row cursor carried
        # across defrag_tick() calls
        self._defrag_cursor = 0
        # per-event cost hygiene: the concatenated batch is maintained
        # incrementally (concat/delete-row, never a 20-way re-concat) and
        # the substrate tensors are built once per topology
        self._batch_cache: Optional[vsr.VSRBatch] = None
        self._substrate: Optional[dict] = None
        self._problem: Optional[power.PlacementProblem] = None
        self._X: Optional[np.ndarray] = None
        self._state: Optional[power.PlacementState] = None
        self._result: Optional[solvers.SolveResult] = None
        self._events_since_defrag = 0
        self.stats: List[OnlineStats] = []
        # fault plane: engine clock (hours; availability timestamps) and the
        # pre-brownout admission budget to restore on brownout_end
        self._now = 0.0
        self._brownout_saved: Optional[tuple] = None

    # -- legacy attribute aliases (read/write through the spec) -----------
    def _spec_alias(name):  # noqa: N805 -- descriptor factory, not a method
        def get(self):
            return getattr(self.spec, name)

        def set_(self, v):
            self.spec = self.spec.replace(**{name: v})
        return property(get, set_)

    defrag_every = _spec_alias("defrag_every")
    method = _spec_alias("method")
    bucket_rows = _spec_alias("bucket_rows")
    max_hops = _spec_alias("max_hops")
    admit_power_budget_w = _spec_alias("power_budget_w")
    admit_violation_tol = _spec_alias("violation_tol")
    queue_rejected = _spec_alias("queue_rejected")
    del _spec_alias

    # -- introspection ----------------------------------------------------
    @property
    def n_live(self) -> int:
        return len(self._vsrs)

    @property
    def sids(self) -> List[int]:
        return list(self._sids)

    @property
    def problem(self) -> Optional[power.PlacementProblem]:
        return self._problem

    @property
    def X(self) -> Optional[np.ndarray]:
        return None if self._X is None else self._X.copy()

    @property
    def result(self) -> Optional[solvers.SolveResult]:
        return self._result

    def service_vms(self, row: int) -> int:
        """The row's OWN VM count (columns beyond it are concat padding)."""
        return self._vsrs[row].V

    def attach_telemetry(self, tel) -> None:
        """Attach (or replace) a ``repro.telemetry.Telemetry``: spans,
        energy ledger, convergence traces, and compile attribution start
        flowing from the next event.  Pass ``None`` to detach."""
        self.telemetry = tel
        if tel is not None:
            if tel.ledger.tiers is None:
                from ..telemetry import tiers_of
                tel.ledger.set_tiers(tiers_of(self.topo))
            tel.attach_traces()

    def _span(self, name: str, **attrs):
        tel = self.telemetry
        return nullcontext() if tel is None else tel.span(name, **attrs)

    def clone(self) -> "OnlineEmbedder":
        """A detached copy sharing the (immutable) arrays: events applied to
        the clone leave this engine untouched.  Used by benchmarks to replay
        one event several times for min-of-reps timing."""
        other = OnlineEmbedder(self.topo, spec=self.spec, key=self._key)
        other._add_kw = dict(self._add_kw)
        other._remove_kw = dict(self._remove_kw)
        other.admission = dict(self.admission)
        other._queue = list(self._queue)
        other._qseq = self._qseq
        other._vsrs = list(self._vsrs)
        other._sids = list(self._sids)
        other._prio = list(self._prio)
        other._next_sid = self._next_sid
        other._defrag_cursor = self._defrag_cursor
        other._batch_cache = self._batch_cache
        other._substrate = self._substrate
        other._problem = self._problem
        other._X = self._X
        other._state = self._state
        other._result = self._result
        other._events_since_defrag = self._events_since_defrag
        other.stats = list(self.stats)
        other._now = self._now
        other._brownout_saved = self._brownout_saved
        return other

    def objective(self) -> float:
        return float("nan") if self._result is None \
            else self._result.objective

    def power_w(self) -> float:
        return 0.0 if self._result is None else self._result.power

    def per_service_power_w(self) -> Dict[int, float]:
        """Per-tenant watts (sums to the total; power.attribute_power)."""
        if self._problem is None or not self._sids:
            return {}
        per = power.attribute_power(self._problem, self._X,
                                    self._result.breakdown,
                                    n_rows=self.n_live)
        return {sid: float(w) for sid, w in zip(self._sids, per)}

    def vsr_batch(self) -> Optional[vsr.VSRBatch]:
        """The live service set as one concatenated VSRBatch (may carry
        zero-demand pad columns from departed wider services)."""
        return self._batch_cache

    # -- internals --------------------------------------------------------
    def _split_key(self) -> jax.Array:
        self._key, k = jax.random.split(self._key)
        return k

    def _pad_rows(self) -> Optional[int]:
        return (_bucket_rows(len(self._vsrs), lo=self.spec.row_bucket_lo)
                if self.spec.bucket_rows else None)

    def _pad_cols(self) -> Optional[int]:
        """V-width bucket: a wide arrival only widens the problem up to the
        next power of two, so jitted solver shapes stay on O(log V) buckets
        instead of one per distinct concat width."""
        if not self.spec.bucket_cols or self._batch_cache is None:
            return None
        return _bucket_rows(self._batch_cache.V, lo=self.spec.col_bucket_lo)

    def _rebuild_problem(self) -> None:
        if self._substrate is None:
            self._substrate = power.substrate_arrays(self.topo)
        self._problem = power.build_problem(self.topo, self._batch_cache,
                                            substrate=self._substrate,
                                            pad_to_rows=self._pad_rows(),
                                            pad_to_cols=self._pad_cols())
        h = self.spec.health
        if h is not None and not h.all_up:
            # value-only substitution: dead capacities zero, same shapes,
            # so jitted solver kernels stay on their compile buckets
            self._problem = h.degrade(self._problem)

    def _resolve_kw(self, base: dict) -> dict:
        """Per-event solver kwargs: bucket-stable sweep padding, plus
        convergence-trace recording when telemetry wants it (host-side
        materialization only -- the jitted scans always compute the
        trace, so this flag can never retrace)."""
        kw = dict(base)
        if self.bucket_rows and self._problem is not None:
            kw["pad_positions_to"] = int(
                self._problem.R * (self._problem.V - 1))
        if self.telemetry is not None and self.telemetry.convergence:
            kw["record_conv"] = True
        return kw

    def _drop_row(self, row: int) -> None:
        """Delete one service's row from the cached batch, KEEPING the VM
        width (stable shapes keep the jit caches warm; pad VMs are free)."""
        b = self._batch_cache
        self._batch_cache = vsr.VSRBatch(
            F=np.delete(b.F, row, axis=0), H=np.delete(b.H, row, axis=0),
            src=np.delete(b.src, row), input_vm=np.delete(b.input_vm, row))

    def _commit(self, res: solvers.SolveResult, event: str) -> None:
        self._X = np.asarray(res.X)
        self._state = power.init_state(self._problem, self._X)
        self._result = res
        self.stats.append(OnlineStats(
            event=event, method=res.method, objective=res.objective,
            power_w=res.power, n_live=self.n_live))
        if self.telemetry is not None:
            self._telemetry_commit(res, event)

    def _telemetry_commit(self, res: solvers.SolveResult,
                          event: str) -> None:
        """Record one commit into the attached telemetry: a solve event
        (with the convergence trace when recorded), an energy-ledger tick
        from the commit's already-computed breakdown, and -- every
        ``telemetry.attribution_every``-th commit -- the exact per-tenant
        ``power.attribute_power`` split (an O(R) host loop, so it runs on
        a cadence, never per commit by default)."""
        tel = self.telemetry
        per_tenant = None
        every = tel.attribution_every
        if every:
            self._commits_since_attr += 1
            if self._commits_since_attr >= every:
                self._commits_since_attr = 0
                per = power.attribute_power(self._problem, self._X,
                                            res.breakdown,
                                            n_rows=self.n_live)
                per_tenant = {int(s): float(w)
                              for s, w in zip(self._sids, per)}
        tel.record_commit(event=event, res=res, t=self._now,
                          n_live=self.n_live, per_tenant=per_tenant)

    def _full_solve(self, event: str,
                    incumbent: Optional[solvers.SolveResult] = None
                    ) -> solvers.SolveResult:
        """Spec-driven full solve (``spec.method``, ``spec.masks`` applied
        -- a defrag can no longer move a hop-constrained service out of its
        radius); an ``incumbent`` result for the SAME problem (the
        incremental solution, or the live placement on an explicit defrag)
        is kept when the portfolio fails to beat it, so defrags never
        regress."""
        res = embed_mod._embed(self.topo, self._batch_cache, self.spec,
                               key=self._split_key(), problem=self._problem)
        if incumbent is not None and incumbent.objective < res.objective:
            res = solvers.SolveResult(
                X=incumbent.X, breakdown=incumbent.breakdown,
                method=f"defrag-kept({incumbent.method})",
                history=incumbent.history)
        self._events_since_defrag = 0
        self._commit(res, event)
        return res

    def _carry_loads(self) -> Optional[tuple]:
        if self._state is None:
            return None
        s = self._state
        return (s.omega, s.tm, s.theta, s.lam)

    # -- the priority rejection queue -------------------------------------
    @property
    def queued_sids(self) -> List[int]:
        """Parked service ids in drain order (class, then FIFO)."""
        return [e[2] for e in sorted(self._queue)]

    def _park(self, service: vsr.VSRBatch, sid: int, prio: int = 0,
              seq: Optional[int] = None) -> None:
        """Push one service onto the priority rejection heap.  ``seq``
        re-parks a drained entry at its original within-class position
        (a failed retry keeps its place at the head of its class)."""
        if seq is None:
            seq = self._qseq
            self._qseq += 1
        heapq.heappush(self._queue, (int(prio), seq, sid, service))

    def _priority_of(self, priority: Optional[int]) -> int:
        prio = 0 if priority is None else int(priority)
        if not 0 <= prio < self.spec.priority_classes:
            raise ValueError(
                f"priority {prio} out of range for "
                f"{self.spec.priority_classes} priority class(es)")
        return prio

    # -- the online API ---------------------------------------------------
    @_traced("bootstrap")
    def bootstrap(self, services: Sequence[vsr.VSRBatch],
                  sids: Optional[Sequence[int]] = None,
                  X0: Optional[np.ndarray] = None,
                  priorities: Optional[Sequence[int]] = None
                  ) -> solvers.SolveResult:
        """Cold-start with a whole service set in ONE full-portfolio solve
        (serving restart / benchmark steady state) instead of N incremental
        admissions.

        ``X0`` [len(services), V0] (optional) ADOPTS a placement computed
        elsewhere (a checkpoint, or the federation's vmapped batch solve)
        instead of solving: pins are applied, missing columns fill from each
        row's source, and the engine commits the exact evaluation of that
        placement as its live state -- churn events then warm-start from it.
        """
        if self._vsrs:
            raise RuntimeError("bootstrap() requires an empty engine")
        if not services:
            raise ValueError("bootstrap() needs at least one service")
        if sids is not None and len(sids) != len(services):
            raise ValueError(f"{len(sids)} sids for {len(services)} services")
        if priorities is not None and len(priorities) != len(services):
            raise ValueError(f"{len(priorities)} priorities for "
                             f"{len(services)} services")
        for k, s in enumerate(services):
            if s.R != 1:
                raise ValueError(f"service {k} must be R=1, got R={s.R}")
        self._vsrs = list(services)
        self._sids = (list(range(len(services))) if sids is None
                      else list(sids))
        self._prio = ([0] * len(services) if priorities is None
                      else [self._priority_of(p) for p in priorities])
        self._next_sid = max(self._sids, default=-1) + 1
        out = services[0]
        for b in services[1:]:
            out = out.concat(b)
        self._batch_cache = out
        self._rebuild_problem()
        self.admission["admitted"] += len(services)
        if X0 is not None:
            X0 = np.asarray(X0)
            if X0.shape[0] != len(services):
                raise ValueError(f"X0 has {X0.shape[0]} rows for "
                                 f"{len(services)} services")
            # shape-map only (no state rebuild here: _commit re-derives the
            # incremental state and _result scores the placement exactly):
            # adopted rows fill the leading block, extra columns / bucket
            # pad rows fall back to each row's pinned source
            p = self._problem
            fixed_node = np.asarray(p.fixed_node)
            src_of = fixed_node[np.arange(p.R),
                                np.asarray(p.fixed_mask).argmax(axis=1)]
            X = np.tile(src_of[:, None], (1, p.V)).astype(np.int32)
            k = min(p.V, X0.shape[1])
            X[:X0.shape[0], :k] = X0[:, :k]
            res = solvers._result(p, X, "bootstrap(adopted)")
            self._events_since_defrag = 0
            self._commit(res, "bootstrap")
            return res
        return self._full_solve("bootstrap")

    @property
    def _positional_constraints(self) -> bool:
        """True when the spec carries ROW-positional constraints (sequence
        ``max_hops`` or an explicit ``eligible`` matrix).  Those bind to
        batch rows; churn shifts row indices on removal, which would
        silently re-assign SLAs to the wrong services -- so churn events
        reject them (scalar ``max_hops`` is the online contract)."""
        return (self.spec.eligible is not None
                or (self.spec.max_hops is not None
                    and np.ndim(self.spec.max_hops) > 0))

    def _check_churn_constraints(self, event: str) -> None:
        if self._positional_constraints:
            raise ValueError(
                f"{event}() with row-positional constraints (sequence "
                "max_hops / explicit eligible) is unsupported: removal "
                "shifts row indices, mis-assigning per-service SLAs.  Use "
                "a scalar max_hops for churn, or positional constraints "
                "with the static batch path (CFNSession.solve/defrag).")

    def _admit_reason(self, res: solvers.SolveResult, prev_power: float,
                      prev_violation: float) -> Optional[str]:
        """SLA admission test on the solved arrival placement: ``None`` when
        admissible, else the monitor counter kind naming the violated
        budget."""
        if (self.admit_power_budget_w is not None
                and res.power - prev_power > self.admit_power_budget_w):
            return "power_budget_exceeded"
        if (self.admit_violation_tol is not None
                and float(res.breakdown.violation) - prev_violation
                > self.admit_violation_tol):
            return "violation_budget_exceeded"
        return None

    @property
    def _admission_active(self) -> bool:
        return (self.max_hops is not None
                or self.admit_power_budget_w is not None
                or self.admit_violation_tol is not None)

    @_traced("add")
    def add(self, service: vsr.VSRBatch, sid: Optional[int] = None,
            priority: Optional[int] = None,
            _retry: bool = False,
            _qseq: Optional[int] = None) -> Optional[solvers.SolveResult]:
        """Admit one service (an R=1 VSRBatch): warm-start incremental
        re-embedding; the very first service (and every
        ``defrag_every``-th event) takes the full-portfolio path -- except
        under admission control, where even the first service goes through
        the masked incremental path so the hop/budget contract holds.

        ``priority`` is the service's admission class (0 = most important;
        must be < ``spec.priority_classes``).  With admission control
        configured, returns ``None`` when the arrival is rejected (the
        engine state is rolled back; with ``queue_rejected`` the service is
        parked and retried after the next capacity-increasing event).
        With ``spec.preempt``, a power-budget rejection may instead park a
        strictly lower-class live service (lowest class, newest first) and
        retry.  ``_retry`` marks a queue re-attempt: a re-rejection does
        not re-increment the rejected/queued counters (they count distinct
        arrivals) and re-parks the service at its original queue position
        (``_qseq``), while an eventual success still counts as admitted."""
        if service.R != 1:
            raise ValueError(f"add() takes one service, got R={service.R}")
        self._check_churn_constraints("add")
        prio = self._priority_of(priority)
        if sid is None:
            sid = self._next_sid
        if sid in self._sids:
            raise ValueError(f"sid {sid} is already live")
        self._next_sid = max(self._next_sid, sid + 1)
        h = self.spec.health
        if h is not None and not bool(h.node_up[int(service.src[0])]):
            # the service's pinned source node is down: a fault is not an
            # SLA rejection, so the arrival is always parked (regardless of
            # queue_rejected) and retried on recovery
            self._park(service, sid, prio, seq=_qseq)
            if not _retry:
                self.admission["queued"] += 1
                if self.monitor is not None:
                    self.monitor.strand(sid, self._now,
                                        detail=f"sid={sid} source down")
            self.stats.append(OnlineStats(
                event="strand", method="fault", objective=self.objective(),
                power_w=self.power_w(), n_live=self.n_live))
            return None
        prev = (self._vsrs[:], self._sids[:], self._prio[:],
                self._batch_cache, self._problem, self._X, self._state,
                self._result, self._events_since_defrag)
        prev_X, prev_loads = self._X, self._carry_loads()
        self._vsrs.append(service)
        self._sids.append(sid)
        self._prio.append(prio)
        self._batch_cache = (service if self._batch_cache is None
                             else self._batch_cache.concat(service))
        self._rebuild_problem()
        self._events_since_defrag += 1
        if prev_X is None and not self._admission_active:
            res = self._full_solve("add")
            self.admission["admitted"] += 1
            return res
        row = self.n_live - 1
        if prev_X is None:
            # empty engine under admission control: start from the pinned
            # sources (an all-src placement) so the masked incremental
            # path and the budget check below still apply
            st = power.init_state(self._problem,
                                  np.asarray(self._problem.fixed_node))
            prev_power, prev_viol = 0.0, 0.0
        else:
            row_map = list(range(row)) + [-1] * (self._problem.R - row)
            st = power.warm_state(self._problem, prev_X,
                                  prev_loads=prev_loads, row_map=row_map)
            prev_power = 0.0 if prev[7] is None else prev[7].power
            prev_viol = (0.0 if prev[7] is None
                         else float(prev[7].breakdown.violation))
        res = solvers.resolve_incremental(
            self._problem, key=self._split_key(),
            changed_rows=[row], state=st, spec=self.spec,
            **self._resolve_kw(self._add_kw))
        reason = self._admit_reason(res, prev_power, prev_viol)
        if reason is not None:
            (self._vsrs, self._sids, self._prio, self._batch_cache,
             self._problem, self._X, self._state, self._result,
             self._events_since_defrag) = prev
            if reason == "power_budget_exceeded" and self.spec.preempt:
                victim = self._preempt_victim(prio)
                if victim is not None:
                    return self.add(service, sid=sid, priority=prio,
                                    _retry=_retry, _qseq=_qseq)
            if self.monitor is not None and not _retry:
                # distinct arrivals only (queue re-tries would double-count
                # against the engine's own admission['rejected'])
                self.monitor.count("admission_rejected", detail=f"sid={sid}")
                self.monitor.count(reason, detail=f"sid={sid}")
            if not _retry:
                self.admission["rejected"] += 1
                if self.queue_rejected:
                    self.admission["queued"] += 1
            if self.queue_rejected or _retry:
                self._park(service, sid, prio, seq=_qseq)
            self.stats.append(OnlineStats(
                event="reject", method="admission", objective=res.objective,
                power_w=res.power, n_live=self.n_live))
            return None
        self.admission["admitted"] += 1
        if self.monitor is not None:
            # closes the availability window if this sid was stranded by a
            # fault (no-op otherwise)
            self.monitor.unstrand(sid, self._now)
        if self._defrag_due():
            return self._full_solve("add", incumbent=res)
        self._commit(res, "add")
        return res

    def _preempt_victim(self, prio: int) -> Optional[int]:
        """Park the lowest-class live service strictly below ``prio``
        (newest first on class ties) to free admission budget; returns its
        sid, or ``None`` when no live service may be preempted."""
        victims = [r for r in range(self.n_live) if self._prio[r] > prio]
        if not victims:
            return None
        r = max(victims, key=lambda i: (self._prio[i], i))
        vsid, vsvc, vprio = self._sids[r], self._vsrs[r], self._prio[r]
        # no drain: the arrival that triggered this retries first, and a
        # drain here would just re-admit the victim we parked
        self.remove(vsid, _drain=False)
        self._park(vsvc, vsid, vprio)
        self.admission["preempted"] += 1
        if self.monitor is not None:
            self.monitor.count("preempted", detail=f"sid={vsid}")
        self.stats.append(OnlineStats(
            event="preempt", method="admission", objective=self.objective(),
            power_w=self.power_w(), n_live=self.n_live))
        return vsid

    @_traced("remove")
    def remove(self, sid: int,
               _drain: bool = True) -> Optional[solvers.SolveResult]:
        """Retire a service: detach its loads in O(V*(N+P)), then let the
        survivors re-settle with polish sweeps (no changed rows).  Freed
        capacity re-admits queued arrivals (``queue_rejected``)."""
        self._check_churn_constraints("remove")
        row = self._sids.index(sid)
        detached = power.detach_vsrs(self._problem, self._state, [row])
        prev_X = self._X
        surv = [i for i in range(self.n_live) if i != row]
        del self._vsrs[row]
        del self._sids[row]
        del self._prio[row]
        if not self._vsrs:
            self._problem = self._X = self._state = self._result = None
            self._batch_cache = None
            self.stats.append(OnlineStats("remove", "empty", 0.0, 0.0, 0))
            if _drain:
                self._drain_queue()
            return None
        self._drop_row(row)
        self._rebuild_problem()
        self._events_since_defrag += 1
        row_map = surv + [-1] * (self._problem.R - len(surv))
        st = power.warm_state(
            self._problem, prev_X,
            prev_loads=(detached.omega, detached.tm, detached.theta,
                        detached.lam),
            row_map=row_map)
        res = solvers.resolve_incremental(
            self._problem, key=self._split_key(),
            changed_rows=[], state=st, spec=self.spec,
            **self._resolve_kw(self._remove_kw))
        if self._defrag_due():
            res = self._full_solve("remove", incumbent=res)
        else:
            self._commit(res, "remove")
        if _drain:
            self._drain_queue()
        return res

    # -- wave-batched churn ------------------------------------------------
    @_traced("apply_wave")
    def apply_wave(self, arrivals: Sequence = (),
                   departures: Sequence[int] = ()) -> WaveResult:
        """Apply one churn WAVE -- a tick's worth of arrivals and
        departures -- as a single batched engine event.

        ``arrivals``: R=1 ``VSRBatch``es, or ``(service, sid)`` /
        ``(service, sid, priority)`` tuples (``sid=None`` auto-assigns).
        ``departures``: live sids.  Lifecycle: departures detach first in
        ONE fused ``detach_vsrs`` (a same-tick replace never double-counts
        capacity), arrivals join the batch in one concat + problem rebuild,
        ``solvers.resolve_wave`` re-solves the whole wave with ONE targeted
        sweep / Metropolis / polish pass (the polish that dominates
        per-event latency is paid once per wave), admission verdicts land
        per arrival in priority order, and a departure-carrying wave drains
        the rejection queue.

        A wave of size 1 delegates verbatim to ``add``/``remove`` --
        bit-identical placements, power, and admission counters -- so
        per-event callers can migrate with no behavior change."""
        self._check_churn_constraints("apply_wave")
        arr: List[tuple] = []
        seen: set = set()
        for a in arrivals:
            if isinstance(a, (tuple, list)):
                svc = a[0]
                sid = a[1] if len(a) > 1 else None
                prio = self._priority_of(a[2] if len(a) > 2 else 0)
            else:
                svc, sid, prio = a, None, 0
            if svc.R != 1:
                raise ValueError(
                    f"wave arrivals must be R=1, got R={svc.R}")
            if sid is None:
                sid = self._next_sid
            if sid in self._sids or sid in seen:
                raise ValueError(f"sid {sid} is already live")
            seen.add(sid)
            self._next_sid = max(self._next_sid, sid + 1)
            arr.append((svc, int(sid), prio))
        deps = [int(s) for s in departures]
        if len(deps) != len(set(deps)):
            raise ValueError("duplicate departure sid in wave")
        for s in deps:
            if s not in self._sids:
                raise KeyError(f"no live service {s}")
        wr = WaveResult(result=self._result,
                        sids=[sid for _, sid, _ in arr], departed=deps)
        pre_preempted = self.admission["preempted"]
        if not arr and not deps:
            return wr
        if len(arr) + len(deps) == 1:
            # deprecation parity: a size-1 wave IS the per-event path
            if deps:
                wr.result = self.remove(deps[0])
            else:
                svc, sid, prio = arr[0]
                res = self.add(svc, sid=sid, priority=prio)
                if res is not None:
                    wr.result = res
                    wr.admitted.append(sid)
                else:
                    wr.result = self._result
                    if any(e[2] == sid for e in self._queue):
                        wr.queued.append(sid)
                    else:
                        wr.rejected.append(sid)
        else:
            self._wave(arr, deps, wr)
        wr.n_preempted = self.admission["preempted"] - pre_preempted
        return wr

    def _wave(self, arr: List[tuple], deps: List[int], wr: WaveResult,
              deferred: Optional[List[tuple]] = None) -> WaveResult:
        """One attempt at a batched wave; admission refusals roll the whole
        attempt back and recurse without the refused arrivals."""
        deferred = [] if deferred is None else deferred
        # source-down arrivals park immediately: a fault is not an SLA
        # rejection (recursive attempts see only the already-filtered list)
        h = self.spec.health
        if h is not None and arr:
            up = []
            for svc, sid, prio in arr:
                if bool(h.node_up[int(svc.src[0])]):
                    up.append((svc, sid, prio))
                    continue
                self._park(svc, sid, prio)
                self.admission["queued"] += 1
                if self.monitor is not None:
                    self.monitor.strand(sid, self._now,
                                        detail=f"sid={sid} source down")
                self.stats.append(OnlineStats(
                    event="strand", method="fault",
                    objective=self.objective(), power_w=self.power_w(),
                    n_live=self.n_live))
                wr.queued.append(sid)
            arr = up
        if not arr and not deps:
            wr.result = self._result
            return self._wave_deferred(wr, deferred)
        prev = (self._vsrs[:], self._sids[:], self._prio[:],
                self._batch_cache, self._problem, self._X, self._state,
                self._result, self._events_since_defrag)
        state, prev_X = self._state, self._X
        n0 = self.n_live
        # phase 1: departures detach as ONE fused state update, BEFORE any
        # arrival lands (merge_timelines tie order; capacity is never
        # double-counted inside a wave)
        dep_rows = sorted(self._sids.index(s) for s in deps)
        if dep_rows:
            state = power.detach_vsrs(self._problem, state, dep_rows)
            for r in sorted(dep_rows, reverse=True):
                del self._vsrs[r]
                del self._sids[r]
                del self._prio[r]
                self._drop_row(r)
        surv = [i for i in range(n0) if i not in set(dep_rows)]
        # phase 2: arrivals join the batch in one pass
        for svc, sid, prio in arr:
            self._vsrs.append(svc)
            self._sids.append(sid)
            self._prio.append(prio)
            self._batch_cache = (svc if self._batch_cache is None
                                 else self._batch_cache.concat(svc))
        if not self._vsrs:
            self._problem = self._X = self._state = self._result = None
            self._batch_cache = None
            self.stats.append(OnlineStats("wave", "empty", 0.0, 0.0, 0))
            wr.result = None
            self._drain_queue()
            return self._wave_deferred(wr, deferred)
        self._rebuild_problem()
        self._events_since_defrag += len(arr) + len(dep_rows)
        new_rows = list(range(len(surv), self.n_live))
        row_map = surv + [-1] * (self._problem.R - len(surv))
        if prev_X is None:
            # cold wave: start every arrival at its pinned source (the
            # targeted sweeps re-place them; mirrors add-under-admission)
            st = power.init_state(self._problem,
                                  np.asarray(self._problem.fixed_node))
            prev_power, prev_viol = 0.0, 0.0
        else:
            st = power.warm_state(
                self._problem, prev_X,
                prev_loads=(state.omega, state.tm, state.theta, state.lam),
                row_map=row_map)
            prev_power = 0.0 if prev[7] is None else prev[7].power
            prev_viol = (0.0 if prev[7] is None
                         else float(prev[7].breakdown.violation))
        # phase 3: ONE batched re-solve for the whole wave
        kw = self._add_kw if new_rows else self._remove_kw
        wave_bucket = 0
        if self.telemetry is not None and new_rows:
            n_pos = int((~np.asarray(
                self._problem.fixed_mask)[new_rows]).sum())
            wave_bucket = solvers._pow2(n_pos) if n_pos else 0
        with self._span("resolve_wave", n_arrive=len(new_rows),
                        n_depart=len(deps), wave_bucket=wave_bucket,
                        r_bucket=int(self._problem.R)) as sp:
            res = solvers.resolve_wave(
                self._problem, st, new_rows, key=self._split_key(),
                spec=self.spec, **self._resolve_kw(kw))
            if self.telemetry is not None:
                # _result already materialized res.X/breakdown on host, so
                # the span closes on completed device work without an
                # extra sync point
                sp.attrs["objective"] = float(res.objective)
        # phase 4: admission, per arrival in priority order
        if new_rows and self._admission_active:
            refused = self._wave_refusals(res, arr, new_rows,
                                          prev_power, prev_viol)
            if refused:
                (self._vsrs, self._sids, self._prio, self._batch_cache,
                 self._problem, self._X, self._state, self._result,
                 self._events_since_defrag) = prev
                keep = []
                for i, (svc, sid, prio) in enumerate(arr):
                    if i not in refused:
                        keep.append((svc, sid, prio))
                        continue
                    reason = refused[i]
                    if (reason == "power_budget_exceeded"
                            and self.spec.preempt):
                        # retried per-event after the wave commits, where
                        # preemption may park a lower-class victim
                        deferred.append((svc, sid, prio))
                        continue
                    self.admission["rejected"] += 1
                    if self.monitor is not None:
                        self.monitor.count("admission_rejected",
                                           detail=f"sid={sid}")
                        self.monitor.count(reason, detail=f"sid={sid}")
                    if self.queue_rejected:
                        self.admission["queued"] += 1
                        self._park(svc, sid, prio)
                        wr.queued.append(sid)
                    else:
                        wr.rejected.append(sid)
                    self.stats.append(OnlineStats(
                        event="reject", method="admission",
                        objective=res.objective, power_w=res.power,
                        n_live=self.n_live))
                return self._wave(keep, deps, wr, deferred)
        # phase 5: commit, then drain freed capacity into queued arrivals
        for _, sid, _ in arr:
            wr.admitted.append(sid)
            self.admission["admitted"] += 1
            if self.monitor is not None:
                self.monitor.unstrand(sid, self._now)
        if self._defrag_due():
            res = self._full_solve("wave", incumbent=res)
        else:
            self._commit(res, "wave")
        wr.result = res
        if deps:
            self._drain_queue()
        return self._wave_deferred(wr, deferred)

    def _wave_deferred(self, wr: WaveResult,
                       deferred: List[tuple]) -> WaveResult:
        """Retry power-refused arrivals per-event (``spec.preempt``: each
        may park a lower-class victim to free budget)."""
        for svc, sid, prio in deferred:
            res = self.add(svc, sid=sid, priority=prio)
            if res is not None:
                wr.admitted.append(sid)
                wr.result = res
            elif any(e[2] == sid for e in self._queue):
                wr.queued.append(sid)
            else:
                wr.rejected.append(sid)
        return wr

    def _wave_refusals(self, res: solvers.SolveResult, arr: List[tuple],
                       new_rows: List[int], prev_power: float,
                       prev_viol: float) -> Dict[int, str]:
        """Admission verdicts for one solved wave attempt: {arr index ->
        reason}.  The wave's budgets are the per-event budgets linearly
        extended to the wave (mean marginal power / violation increase per
        arrival); when exceeded, ONE victim is refused per attempt --
        lowest priority class first, and within it the arrival with the
        highest exact attributed watts (``power.attribute_power``) when a
        power budget is set, else the newest -- and the remaining wave is
        re-solved, so higher classes keep their seats."""
        budget, tol = self.admit_power_budget_w, self.admit_violation_tol
        over_power = (budget is not None
                      and res.power - prev_power > budget * len(new_rows))
        over_viol = (tol is not None
                     and float(res.breakdown.violation) - prev_viol
                     > tol * len(new_rows))
        if not over_power and not over_viol:
            return {}
        reason = ("power_budget_exceeded" if over_power
                  else "violation_budget_exceeded")
        lowest = max(prio for _, _, prio in arr)
        cls = [j for j in range(len(arr)) if arr[j][2] == lowest]
        if budget is not None:
            per = power.attribute_power(self._problem, np.asarray(res.X),
                                        res.breakdown, n_rows=self.n_live)
            i = max(cls, key=lambda j: (float(per[new_rows[j]]), j))
        else:
            i = max(cls)
        return {i: reason}

    def _drain_queue(self) -> None:
        """Retry parked arrivals class-by-class (FIFO within a class);
        stop at the first re-rejection.  Runs after EVERY
        capacity-increasing event: departures (per-event or wave),
        node/link recoveries, and brownout_end."""
        while self._queue:
            prio, seq, sid, service = heapq.heappop(self._queue)
            if self.add(service, sid=sid, priority=prio, _retry=True,
                        _qseq=seq) is None:
                # add() re-parked it at its original position (seq)
                break

    def cancel_queued(self, sid: int) -> bool:
        """Drop a parked arrival (its lifetime ended while queued)."""
        n0 = len(self._queue)
        self._queue = [e for e in self._queue if e[2] != sid]
        removed = len(self._queue) < n0
        if removed:
            heapq.heapify(self._queue)
            if self.monitor is not None:
                # a stranded service departing from the queue closes its
                # availability window without counting as re-embedded
                self.monitor.unstrand(sid, self._now, re_embedded=False)
        return removed

    @_traced("defrag")
    def defrag(self) -> Optional[solvers.SolveResult]:
        """Force a full-portfolio re-pack of the current service set (keeps
        the live placement when the portfolio cannot beat it)."""
        if self._problem is None:
            return None
        return self._full_solve("defrag", incumbent=self._result)

    @_traced("defrag_tick")
    def defrag_tick(self, rows: Optional[int] = None
                    ) -> Optional[solvers.SolveResult]:
        """Amortized background defrag: ONE targeted delta-sweep over the
        free VMs of ``rows`` live services (default
        ``spec.defrag_rows_per_tick``), round-robin from a cursor carried
        across ticks -- over ceil(R / K) ticks every service gets
        re-considered, without a full-portfolio solve ever landing on the
        event path.

        Never-regressing: the swept placement is committed only when its
        exact objective improves on the incumbent.  Bucket-stable: the
        position list is padded to a power-of-two, so steady-state ticks
        replay ONE compiled ``_sweep`` per (K, V) bucket.  Returns the
        committed result, or ``None`` when the tick found no improvement
        (or there is nothing to defrag)."""
        k = self.spec.defrag_rows_per_tick if rows is None else int(rows)
        if k <= 0 or self._problem is None or self._result is None:
            return None
        n = self.n_live
        sel = [(self._defrag_cursor + i) % n for i in range(min(k, n))]
        self._defrag_cursor = (self._defrag_cursor + len(sel)) % n
        aux = power.build_aux(self._problem)
        free = np.asarray(aux.free_pos)
        pos = free[np.isin(free[:, 0], sel)]
        if pos.shape[0] == 0:
            return None
        bucket = solvers._pow2(int(pos.shape[0]))
        pos_j = jax.numpy.asarray(solvers._pad_positions(pos, bucket))
        el = self.spec.masks(self._problem)
        el_np, _, _ = solvers._eligible_np(el)
        el_j = None if el_np is None else jax.numpy.asarray(el_np)
        st, _ = solvers._sweep(self._problem, aux, self._state, pos_j, el_j)
        res = solvers._result(self._problem, st.X, "defrag_tick")
        if res.objective >= self._result.objective - 1e-9:
            return None  # never-regressing: keep the incumbent
        self._commit(res, "defrag_tick")
        return res

    def _defrag_due(self) -> bool:
        # amortized mode (defrag_rows_per_tick > 0) REPLACES the periodic
        # full-portfolio defrag: re-packing happens K rows per tick in
        # defrag_tick(), off the event latency path
        return (self.spec.defrag_rows_per_tick == 0
                and self.defrag_every > 0
                and self._events_since_defrag >= self.defrag_every)

    # -- fault plane ------------------------------------------------------
    def tick(self, t: float) -> None:
        """Advance the engine clock (hours).  Strand / unstrand timestamps
        -- the availability integral -- come from this clock."""
        self._now = float(t)

    def _health(self) -> "power.SubstrateHealth":
        h = self.spec.health
        return power.SubstrateHealth.fresh(self.topo) if h is None else h

    def _fault_rows(self) -> Tuple[List[int], List[int]]:
        """(stranded, moved) row indices for the live placement under the
        just-updated ``spec.health``: stranded rows lost their pinned
        source -- or every admissible node -- and are parked; moved rows
        have VMs on dead nodes or traffic routed over dead elements and
        get mass re-embedded."""
        h = self.spec.health
        el = self.spec.masks(self._problem)
        pair_ok = h.pair_alive(self._problem)
        all_links = bool(h.link_up.all())
        X = self._X
        stranded: List[int] = []
        moved: List[int] = []
        for r in range(self.n_live):
            svc = self._vsrs[r]
            if not bool(h.node_up[int(svc.src[0])]):
                stranded.append(r)
                continue
            nodes = X[r, :svc.V]
            hit = bool((~h.node_up[nodes]).any())
            if not hit and not all_links:
                H = np.asarray(svc.H)[0]
                uu, vv = np.nonzero(H > 0)
                if uu.size:
                    hit = bool((~pair_ok[nodes[uu], nodes[vv]]).any())
            if not hit:
                continue
            if el is not None and not bool(el[r].any()):
                # nowhere admissible left: the solvers' best-effort
                # all-True fallback must never see this row
                stranded.append(r)
            else:
                moved.append(r)
        return stranded, moved

    def _apply_fault_impl(self, event: str) -> Optional[solvers.SolveResult]:
        """Shared fail/recover re-embedding: strand rows that lost their
        source (parked in the retry queue -- never silently dropped), mass
        re-embed displaced rows through ``warm_state`` +
        ``resolve_incremental`` on the degraded problem."""
        if self._X is None:
            return None  # nothing placed; _rebuild_problem degrades later
        recovery = event.startswith("recover")
        stranded, moved = ([], []) if recovery else self._fault_rows()
        state = self._state
        prev_X = self._X
        n0 = self.n_live
        if stranded:
            state = power.detach_vsrs(self._problem, state, stranded)
            for r in sorted(stranded, reverse=True):
                svc, sid = self._vsrs[r], self._sids[r]
                self._park(svc, sid, self._prio[r])
                if self.monitor is not None:
                    self.monitor.strand(sid, self._now,
                                        detail=f"sid={sid} {event}")
                del self._vsrs[r]
                del self._sids[r]
                del self._prio[r]
                self._drop_row(r)
        if not self._vsrs:
            self._problem = self._X = self._state = self._result = None
            self._batch_cache = None
            self.stats.append(OnlineStats(event, "empty", 0.0, 0.0, 0))
            return None
        dead = set(stranded)
        surv = [i for i in range(n0) if i not in dead]
        moved_new = [surv.index(r) for r in moved]
        self._rebuild_problem()
        self._events_since_defrag += 1
        row_map = surv + [-1] * (self._problem.R - len(surv))
        st = power.warm_state(
            self._problem, prev_X,
            prev_loads=(state.omega, state.tm, state.theta, state.lam),
            row_map=row_map)
        if not recovery and not moved_new and not stranded:
            # the dead element hosted nothing: re-score the same placement
            # on the degraded problem, no solver work
            res = solvers._result(self._problem, st.X, "untouched")
            self._commit(res, event)
            return res
        kw = self._add_kw if moved_new else self._remove_kw
        res = solvers.resolve_incremental(
            self._problem, key=self._split_key(),
            changed_rows=moved_new, state=st, spec=self.spec,
            **self._resolve_kw(kw))
        if self._defrag_due():
            res = self._full_solve(event, incumbent=res)
        else:
            self._commit(res, event)
        if moved_new and self.monitor is not None:
            self.monitor.count("re_embedded", n=len(moved_new),
                               detail=f"{event}: {len(moved_new)} displaced")
        return res

    def fail_node(self, node: int) -> Optional[solvers.SolveResult]:
        """Fail a processing node: services sourced there are stranded
        (queued for recovery), services with VMs there are mass
        re-embedded on the degraded substrate."""
        self._check_churn_constraints("fail_node")
        h = self._health()
        if not bool(h.node_up[node]):
            return None
        self.spec = self.spec.replace(health=h.fail_node(node))
        if self.monitor is not None:
            self.monitor.count("node_failed", detail=f"node={node}")
        return self._apply_fault_impl("fail_node")

    def recover_node(self, node: int) -> Optional[solvers.SolveResult]:
        """Recover a node: survivors re-settle onto the restored capacity
        and stranded / parked services retry admission."""
        self._check_churn_constraints("recover_node")
        h = self._health()
        if bool(h.node_up[node]):
            return None
        self.spec = self.spec.replace(health=h.recover_node(node))
        if self.monitor is not None:
            self.monitor.count("node_recovered", detail=f"node={node}")
        res = self._apply_fault_impl("recover_node")
        self._drain_queue()
        return res

    def fail_link(self, n: int) -> Optional[solvers.SolveResult]:
        """Fail a network element: traffic routed across it is re-embedded
        around the cut (zero C_net penalizes any load left there)."""
        self._check_churn_constraints("fail_link")
        h = self._health()
        if not bool(h.link_up[n]):
            return None
        self.spec = self.spec.replace(health=h.fail_link(n))
        if self.monitor is not None:
            self.monitor.count("link_failed", detail=f"link={n}")
        return self._apply_fault_impl("fail_link")

    def recover_link(self, n: int) -> Optional[solvers.SolveResult]:
        self._check_churn_constraints("recover_link")
        h = self._health()
        if bool(h.link_up[n]):
            return None
        self.spec = self.spec.replace(health=h.recover_link(n))
        if self.monitor is not None:
            self.monitor.count("link_recovered", detail=f"link={n}")
        res = self._apply_fault_impl("recover_link")
        self._drain_queue()
        return res

    def brownout(self, budget_w: Optional[float]) -> None:
        """Tighten the fleet admission power budget mid-run (arrivals
        beyond it reject/queue through the existing admission path);
        ``brownout_end`` restores the previous budget."""
        if self._brownout_saved is None:
            self._brownout_saved = (self.spec.power_budget_w,)
        self.spec = self.spec.replace(power_budget_w=budget_w)
        if self.monitor is not None:
            self.monitor.count("brownout", detail=f"budget_w={budget_w}")

    def brownout_end(self) -> None:
        if self._brownout_saved is None:
            return
        (prev_budget,) = self._brownout_saved
        self._brownout_saved = None
        self.spec = self.spec.replace(power_budget_w=prev_budget)
        if self.monitor is not None:
            self.monitor.count("brownout_end",
                               detail=f"budget_w={prev_budget}")
        self._drain_queue()

    @_traced("apply_fault")
    def apply_fault(self, ev: FaultEvent):
        """Dispatch one ``FaultEvent`` to the handlers above (region kinds
        belong to ``FederatedSession``; a flat engine rejects them)."""
        if ev.kind == "fail_node":
            return self.fail_node(int(ev.target))
        if ev.kind == "recover_node":
            return self.recover_node(int(ev.target))
        if ev.kind == "fail_link":
            return self.fail_link(int(ev.target))
        if ev.kind == "recover_link":
            return self.recover_link(int(ev.target))
        if ev.kind == "brownout":
            return self.brownout(ev.value)
        if ev.kind == "brownout_end":
            return self.brownout_end()
        raise ValueError(f"flat engine cannot apply fault kind {ev.kind!r} "
                         "(region faults need a FederatedSession)")


def replay(engine: OnlineEmbedder, events: Sequence[ServiceEvent],
           make_vsr: Callable[[int], vsr.VSRBatch],
           on_event: Optional[Callable] = None,
           waves: bool = False) -> List[OnlineStats]:
    """Drive an engine through a timeline.  ``make_vsr(sid)`` materializes
    the service for each arrival; departures of services neither live in
    the engine (e.g. bootstrapped) nor admitted by this replay are skipped.
    ``on_event(event, result)`` observes each step (``result`` is None for
    an SLA-rejected arrival).  Admission counters accumulate in
    ``engine.admission`` (admitted / rejected / queued).

    The timeline may interleave ``FaultEvent``s (``merge_timelines``):
    those dispatch through ``engine.apply_fault``, and the engine clock is
    ticked to each event's time so strand/unstrand availability windows
    are measured on the timeline's clock.

    ``waves=True`` batches each same-tick run of churn events
    (``iter_waves``) through ``engine.apply_wave`` -- one fused re-solve
    per tick instead of one per event -- and, when the engine carries an
    amortized defrag budget (``spec.defrag_rows_per_tick``), runs one
    background ``defrag_tick()`` after each wave, OFF the event path.
    ``on_event`` then observes ``(event, WaveResult)`` for every event of
    the wave."""
    if waves:
        return _replay_waves(engine, events, make_vsr, on_event)
    live = set(engine.sids)
    for ev in events:
        tick = getattr(engine, "tick", None)
        if tick is not None:
            tick(ev.t)
        if isinstance(ev, FaultEvent):
            res = engine.apply_fault(ev)
            # faults strand (sids leave the engine for the retry queue) and
            # recoveries re-admit: re-sync the live set either way
            live = set(engine.sids)
            if on_event is not None:
                on_event(ev, res)
            continue
        if ev.kind == "arrive":
            res = engine.add(make_vsr(ev.sid), sid=ev.sid)
            if res is not None:
                live.add(ev.sid)
        else:
            if ev.sid not in live:
                # not live -- but it may be parked in the retry queue
                # (stranded by a fault): a departure cancels the retry
                engine.cancel_queued(ev.sid)
                if on_event is not None:
                    on_event(ev, None)
                continue
            res = engine.remove(ev.sid)
            live.discard(ev.sid)
            live.update(s for s in engine.sids)  # queue re-admissions
        if on_event is not None:
            on_event(ev, res)
    return engine.stats


def _replay_waves(engine, events, make_vsr, on_event) -> List[OnlineStats]:
    """The ``replay(..., waves=True)`` loop: collect -> apply_wave ->
    background defrag tick, one pass per same-tick wave."""
    defrag_budget = getattr(engine.spec, "defrag_rows_per_tick", 0)
    for group in iter_waves(events):
        tick = getattr(engine, "tick", None)
        if tick is not None:
            tick(group[-1].t)
        if isinstance(group[0], FaultEvent):
            res = engine.apply_fault(group[0])
            if on_event is not None:
                on_event(group[0], res)
            continue
        live = set(engine.sids)
        arrivals, departures = [], []
        for ev in group:
            if ev.kind == "arrive":
                arrivals.append((make_vsr(ev.sid), ev.sid))
            elif ev.sid in live:
                departures.append(ev.sid)
            else:
                engine.cancel_queued(ev.sid)
        wres = engine.apply_wave(arrivals, departures)
        if defrag_budget:
            engine.defrag_tick()
        if on_event is not None:
            for ev in group:
                on_event(ev, wres)
    return engine.stats
