"""High-level embedding API: the paper's technique as one call.

    topo = topology.paper_topology()
    vsrs = vsr.random_vsrs(10, rng=0, source_nodes=[0])
    result = embed.embed(topo, vsrs, method="cfn-milp")
    print(result.power, result.breakdown.net, result.breakdown.proc)

`method` selects the solver; "cfn-milp" is the portfolio stand-in for the
paper's CPLEX run, and "cdc"/"af"/"mf" are the paper's Fig. 3 baselines.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np

from . import power, solvers
from .power import PlacementProblem, build_problem
from .topology import CFNTopology
from .vsr import VSRBatch

METHODS = ("cdc", "af", "mf", "iot", "coordinate", "exhaustive", "anneal",
           "genetic", "relax", "cfn-milp")


def embed(topo: CFNTopology, vsrs: VSRBatch, method: str = "cfn-milp",
          key: Optional[jax.Array] = None, effort: str = "standard",
          problem: Optional[PlacementProblem] = None) -> solvers.SolveResult:
    problem = build_problem(topo, vsrs) if problem is None else problem
    key = jax.random.PRNGKey(0) if key is None else key
    if method in ("cdc", "af", "mf", "iot"):
        return solvers.fixed_layer(problem, topo, method)
    if method == "coordinate":
        cdc = topo.layer_indices("cdc")[0]
        X0 = np.full((problem.R, problem.V), cdc, dtype=np.int32)
        return solvers.coordinate(problem, X0)
    if method == "exhaustive":
        return solvers.exhaustive(problem)
    if method == "anneal":
        X0 = solvers.fixed_layer(problem, topo, "iot").X
        return solvers.anneal(problem, key, X0)
    if method == "genetic":
        X0 = solvers.fixed_layer(problem, topo, "iot").X
        return solvers.genetic(problem, key, X0)
    if method == "relax":
        return solvers.relax(problem, key)
    if method == "cfn-milp":
        return solvers.solve_cfn(problem, topo, key, effort=effort)
    raise ValueError(f"unknown method {method!r}; choose from {METHODS}")


def embed_latency_bounded(topo: CFNTopology, vsrs: VSRBatch,
                          max_hops: int, method: str = "cfn-milp",
                          key: Optional[jax.Array] = None
                          ) -> solvers.SolveResult:
    """Latency-constrained embedding (paper §2: "latency can easily be
    added" to the framework): every placed VM pair connected by a virtual
    link must sit within ``max_hops`` network nodes of each other.

    Implemented as a hard mask on candidate nodes per VM: a node is
    eligible only if it is within max_hops of the VSR's source (a sound
    over-approximation for chain VSRs whose traffic originates at the
    input VM; exact pairwise hop constraints would enter the objective as
    penalties the same way capacity violations do).

    The repair runs on the delta engine: one ``delta_sweep`` scores every
    destination of an offending VM at once (the eligibility mask knocks
    out far nodes), and ``apply_move`` keeps the live state consistent so
    later repairs see earlier ones -- same results as brute-force
    re-evaluation, O(R*V) sweeps instead of O(R*V*P) full objectives.
    """
    import numpy as np
    problem = build_problem(topo, vsrs)
    res = embed(topo, vsrs, method, key=key, problem=problem)
    hops = topo.path_hops
    X = res.X.copy()
    fixed = np.asarray(problem.fixed_mask)
    eligible = hops[np.asarray(vsrs.src)] <= max_hops          # [R, P]
    aux = power.build_aux(problem)
    state = power.init_state(problem, jax.numpy.asarray(X))
    for r in range(X.shape[0]):
        src = int(vsrs.src[r])
        mask_r = jax.numpy.asarray(eligible[r])
        for v in range(X.shape[1]):
            if fixed[r, v] or hops[src, X[r, v]] <= max_hops:
                continue
            obj_all = power.delta_sweep(problem, aux, state, r, v)
            best = int(jax.numpy.argmin(
                jax.numpy.where(mask_r, obj_all, jax.numpy.inf)))
            state = power.apply_move(problem, aux, state, r, v, best)
            X[r, v] = best
    return solvers._result(problem, X, f"latency<={max_hops}({res.method})")


def savings_vs_baseline(topo: CFNTopology, vsrs: VSRBatch,
                        baseline: str = "cdc", method: str = "cfn-milp",
                        key: Optional[jax.Array] = None) -> dict:
    """Paper headline metric: power saving of CFN placement vs the baseline."""
    problem = build_problem(topo, vsrs)
    base = embed(topo, vsrs, baseline, key=key, problem=problem)
    opt = embed(topo, vsrs, method, key=key, problem=problem)
    saving = 1.0 - opt.power / max(base.power, 1e-9)
    return dict(baseline_w=base.power, optimized_w=opt.power,
                saving_frac=saving, baseline=base, optimized=opt)
