"""High-level embedding API: the paper's technique as one call.

    topo = topology.paper_topology()
    vsrs = vsr.random_vsrs(10, rng=0, source_nodes=[0])
    spec = api.PlacementSpec(method="cfn-milp")
    result = api.CFNSession(topo, spec).solve(vsrs)

The canonical path is ``repro.api``: a declarative ``PlacementSpec``
(constraints + solver config) consumed by ``CFNSession`` / ``_embed``.
``embed`` / ``embed_latency_bounded`` remain as deprecated shims that
construct a spec internally; "cfn-milp" is the portfolio stand-in for the
paper's CPLEX run, and "cdc"/"af"/"mf" are the paper's Fig. 3 baselines.
"""
from __future__ import annotations

import warnings
from typing import Optional

import jax
import numpy as np

from . import solvers
from .power import PlacementProblem, build_problem
from .topology import CFNTopology
from .vsr import VSRBatch

METHODS = ("cdc", "af", "mf", "iot", "coordinate", "exhaustive", "anneal",
           "genetic", "relax", "cfn-milp")


def _spec(method: str = "cfn-milp", effort: str = "standard",
          max_hops: Optional[int] = None):
    """Build a PlacementSpec (deferred import: api imports this module)."""
    from . import api
    return api.PlacementSpec(method=method, effort=effort, max_hops=max_hops)


def _embed(topo: CFNTopology, vsrs: VSRBatch, spec,
           key: Optional[jax.Array] = None,
           problem: Optional[PlacementProblem] = None) -> solvers.SolveResult:
    """Spec-driven embedding dispatch -- the single batch-path consumer.

    ``spec.masks(problem)`` is built ONCE here and threaded into whichever
    solver ``spec.method`` selects; solvers without native masking (the
    fixed-layer baselines) are forced onto the mask by
    ``solvers.repair_to_eligible`` afterwards, so every method returns an
    eligible placement.
    """
    problem = build_problem(topo, vsrs) if problem is None else problem
    key = jax.random.PRNGKey(0) if key is None else key
    eligible = spec.masks(problem)
    m = spec.method
    if m in ("cdc", "af", "mf", "iot"):
        res = solvers.fixed_layer(problem, topo, m)
    elif m == "coordinate":
        cdc = topo.layer_indices("cdc")[0]
        X0 = np.full((problem.R, problem.V), cdc, dtype=np.int32)
        res = solvers.coordinate(problem, X0, eligible=eligible)
    elif m == "exhaustive":
        res = solvers.exhaustive(problem, eligible=eligible)
    elif m == "anneal":
        X0 = solvers.fixed_layer(problem, topo, "iot").X
        res = solvers.anneal(problem, key, X0, backend=spec.backend,
                             eligible=eligible)
    elif m == "genetic":
        X0 = solvers.fixed_layer(problem, topo, "iot").X
        # exactly ONE dispatch arm consumes `key` per call; sharing the
        # seed across methods keeps them comparable under a fixed seed
        res = solvers.genetic(problem, key, X0,  # tracelint: allow[CFN106]
                              eligible=eligible)
    elif m == "relax":
        res = solvers.relax(problem, key, eligible=eligible)
    elif m == "cfn-milp":
        res = solvers.solve_portfolio(problem, topo, spec, key,
                                      eligible=eligible)
    else:
        raise ValueError(f"unknown method {m!r}; choose from {METHODS}")
    if eligible is not None:
        res = solvers.repair_to_eligible(problem, res, eligible)
    return res


def embed(topo: CFNTopology, vsrs: VSRBatch, method: str = "cfn-milp",
          key: Optional[jax.Array] = None, effort: str = "standard",
          problem: Optional[PlacementProblem] = None,
          spec=None) -> solvers.SolveResult:
    """Deprecated shim (kept for the original one-call API): constructs a
    ``PlacementSpec`` from the method/effort kwargs and routes through the
    spec path.  Pass ``spec=`` (or use ``repro.api.CFNSession``) instead."""
    if spec is None:
        warnings.warn(
            "embed(method=..., effort=...) is deprecated; build a "
            "repro.api.PlacementSpec and use repro.api.CFNSession (or pass "
            "spec=)", DeprecationWarning, stacklevel=2)
        spec = _spec(method=method, effort=effort)
    return _embed(topo, vsrs, spec, key=key, problem=problem)


def embed_latency_bounded(topo: CFNTopology, vsrs: VSRBatch,
                          max_hops: int, method: str = "cfn-milp",
                          key: Optional[jax.Array] = None
                          ) -> solvers.SolveResult:
    """Latency-constrained embedding (paper §2: "latency can easily be
    added" to the framework): every VM placed within ``max_hops`` network
    nodes of its VSR's source.

    Deprecated shim preserving the historical semantics (unconstrained
    solve, then masked ``delta_sweep`` repair of each violating VM): the
    hop mask now comes from ``PlacementSpec.masks`` -- the same [R, P]
    surface the native path enforces -- and the repair is
    ``solvers.repair_to_eligible``.  New code should set
    ``PlacementSpec(max_hops=...)`` instead, which threads the mask
    natively through every solver proposal rather than repairing after the
    fact.
    """
    warnings.warn(
        "embed_latency_bounded() is deprecated; set "
        "repro.api.PlacementSpec(max_hops=...) and use repro.api.CFNSession",
        DeprecationWarning, stacklevel=2)
    spec = _spec(method=method, max_hops=max_hops)
    problem = build_problem(topo, vsrs)
    base = _embed(topo, vsrs, spec.replace(max_hops=None), key=key,
                  problem=problem)
    res = solvers.repair_to_eligible(problem, base, spec.masks(problem))
    return solvers._result(problem, res.X,
                           f"latency<={max_hops}({base.method})")


def savings_vs_baseline(topo: CFNTopology, vsrs: VSRBatch,
                        baseline: str = "cdc", method: str = "cfn-milp",
                        key: Optional[jax.Array] = None) -> dict:
    """Paper headline metric: power saving of CFN placement vs the baseline."""
    problem = build_problem(topo, vsrs)
    base = _embed(topo, vsrs, _spec(method=baseline), key=key,
                  problem=problem)
    # paired comparison: baseline and optimized DELIBERATELY share a seed
    opt = _embed(topo, vsrs, _spec(method=method), key=key,  # tracelint: allow[CFN106]
                 problem=problem)
    saving = 1.0 - opt.power / max(base.power, 1e-9)
    return dict(baseline_w=base.power, optimized_w=opt.power,
                saving_frac=saving, baseline=base, optimized=opt)
