"""City-scale CFN embedding walkthrough: 50 VSRs on the P=252 substrate.

    PYTHONPATH=src python examples/city_scale.py [--quick]

The paper evaluates 1-20 VSRs on a 23-node metro substrate; the ROADMAP
north-star is a city.  This example embeds 50 services on the
``topology.city_scale()`` preset (8 OLT access zones x 6 ONUs x 5 IoT
devices, 8 access-fog + 2 metro-fog nodes, a 6-node IP/WDM core ring with 2
CDCs -- 252 processing nodes, 86 network nodes) and shows why the
padded-CSR route table is what makes this tractable:

  * the route state is ``route_idx [P, P, K=14]`` -- ~3.5 MB -- where the
    dense incidence tensor would be [P, P, N] ~ 22 MB and every
    ``delta_sweep`` used to gather [P, D, N] rows of it;
  * ``solvers.coordinate`` / ``resolve_incremental`` run entirely on
    touched-entries scoring: per destination candidate only the candidate
    node's Eq.(2) terms and the <= D*K route node ids of its Eq.(1) terms
    are re-evaluated.

Sources are spread across the city's IoT devices, so CFN placement pulls
services onto their zone's access fog instead of hauling everything to the
CDC -- the paper's Fig. 3 story at city scale.
"""
from __future__ import annotations

import sys
import time

import numpy as np

from repro.core import embed, power, topology, vsr


def main(quick: bool = False) -> None:
    t0 = time.time()
    topo = topology.city_scale()
    print(f"substrate: P={topo.P} processing nodes, N={topo.N} network "
          f"nodes, K={topo.K} max hops "
          f"(CSR table {topo.P**2 * topo.K * 4 / 1e6:.1f} MB vs dense "
          f"{topo.P**2 * topo.N * 4 / 1e6:.1f} MB)  "
          f"[built in {time.time() - t0:.1f}s]")

    n_vsrs = 10 if quick else 50
    iot = topo.layer_indices("iot")
    rng = np.random.default_rng(0)
    sources = sorted(int(s) for s in
                     rng.choice(iot, size=min(16, len(iot)), replace=False))
    vs = vsr.random_vsrs(n_vsrs, rng=0, source_nodes=sources)
    problem = power.build_problem(topo, vs)
    print(f"workload: {n_vsrs} VSRs x {vs.V} VMs from {len(sources)} "
          f"source zones")

    t0 = time.time()
    base = embed.embed(topo, vs, "cdc", problem=problem)
    print(f"all-in-CDC baseline: {base.power:,.0f} W "
          f"({time.time() - t0:.1f}s)")

    t0 = time.time()
    res = embed.embed(topo, vs, "coordinate", problem=problem)
    print(f"CFN coordinate descent: {res.power:,.0f} W "
          f"({time.time() - t0:.1f}s, feasible={res.feasible})")
    saving = 1.0 - res.power / max(base.power, 1e-9)
    print(f"power saving vs cloud-only: {saving:.1%} "
          f"(paper band at metro scale: 19-91%)")

    # where did the VMs land?
    layers = np.asarray([topo.proc_layer[p] for p in res.X.reshape(-1)])
    for layer in ("iot", "af", "mf", "cdc"):
        n = int((layers == layer).sum())
        if n:
            print(f"  {layer:>4}: {n:3d} VMs")


if __name__ == "__main__":
    main(quick="--quick" in sys.argv[1:])
