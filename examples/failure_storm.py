"""A failure storm against a live city-scale substrate.

Walkthrough of the fault plane: a ``rack_storm`` preset (staggered fog-node
failures with recoveries an hour later) is merged into a churn timeline and
replayed through a ``CFNSession``.  Each failure flows through the closed
loop -- the substrate degrades in place (failed nodes keep their array
slots with zero capacity, so nothing retraces), displaced services are
mass re-embedded via a warm-started incremental re-solve, services whose
pinned source died are parked in the retry queue, and recoveries drain the
queue back onto the healed substrate.  The ``PlacementMonitor`` integrates
stranded-service-seconds into the availability number an operator would
alert on.

  PYTHONPATH=src python examples/failure_storm.py            # full storm
  PYTHONPATH=src python examples/failure_storm.py --quick    # CI-sized
  PYTHONPATH=src python examples/failure_storm.py --telemetry run.jsonl

Prints a per-event log (watts, live/queued counts) and the storm's
availability / re-embed totals.  With ``--telemetry PATH`` the run
streams spans, monitor events, compile attribution, and the energy
ledger to a JSONL file and closes with the telemetry report summary.
"""
import sys
import time

import numpy as np

from repro.api import CFNSession, PlacementSpec
from repro.core import dynamic, topology, vsr
from repro.fault.monitor import PlacementMonitor
from repro.telemetry import (Telemetry, load_events, render,
                             summarize_events)

QUICK = "--quick" in sys.argv
SEED = 0
TEL_PATH = (sys.argv[sys.argv.index("--telemetry") + 1]
            if "--telemetry" in sys.argv else None)

topo = (topology.city_scale(n_olt=2, onus_per_olt=2, iot_per_onu=2)
        if QUICK else
        topology.city_scale(n_olt=3, onus_per_olt=3, iot_per_onu=3))
n_services = 6 if QUICK else 12
iot = topo.layer_indices("iot")


def make_vsr(sid):
    return vsr.random_vsrs(1, rng=np.random.default_rng(SEED + sid),
                           n_vms=3, source_nodes=iot[:max(4, len(iot) // 3)])


monitor = PlacementMonitor()
telemetry = (Telemetry(jsonl_path=TEL_PATH, attribution_every=4)
             if TEL_PATH else None)
spec = PlacementSpec(effort="quick", defrag_every=0)
session = CFNSession(topo, spec, monitor=monitor, telemetry=telemetry)

# the steady state: services admitted before the storm hits
arrivals = [dynamic.ServiceEvent(float(i) * 0.5, "arrive", i)
            for i in range(n_services)]

# aim the storm where it hurts: a probe placement finds the busiest
# hosting nodes, and the storm takes those plus one pinned source (that
# service can only wait in the retry queue until recovery)
probe = CFNSession(topo, spec)
for ev in arrivals:
    probe.add(make_vsr(ev.sid), sid=ev.sid)
srcs = {int(make_vsr(i).src[0]) for i in range(n_services)}
cnt = {}
Xp = np.asarray(probe.X)
for r in range(probe.n_live):
    for x in Xp[r, :probe.engine._vsrs[r].V]:
        if int(x) not in srcs:
            cnt[int(x)] = cnt.get(int(x), 0) + 1
hot = sorted(cnt, key=lambda n: -cnt[n])
targets = (hot[:1 if QUICK else 3]
           + [int(make_vsr(0).src[0])])[:2 if QUICK else 4]
storm = dynamic.fault_preset("rack_storm", topo, nodes=targets,
                             t_fail=4.0, stagger_h=0.25, outage_h=1.5)
# one departure mid-storm: churn and faults share a single merged clock
churn = arrivals + [dynamic.ServiceEvent(4.6, "depart", 0)]
events = dynamic.merge_timelines(churn, storm)
horizon = max(e.t for e in events) + 1.0

print(f"substrate: P={topo.P} N={topo.N}; {n_services} services, "
      f"storm of {sum(e.kind == 'fail_node' for e in storm)} node failures")


def log_event(ev, res):
    queued = len(session.engine._queue)
    kind = getattr(ev, "kind", "?")
    target = f" node={ev.target}" if isinstance(ev, dynamic.FaultEvent) else ""
    print(f"  t={ev.t:5.2f}h {kind:13s}{target:9s} "
          f"live={session.n_live:2d} queued={queued} "
          f"power={session.power_w():7.1f}W")


t0 = time.time()
session.replay(events, make_vsr, on_event=log_event)
wall = time.time() - t0

monitor.close_strands(horizon)
snap = monitor.snapshot()
print(f"\nstorm of {snap.get('node_failed', 0)} failures / "
      f"{snap.get('node_recovered', 0)} recoveries in {wall:.1f}s wall:")
print(f"  services stranded   : {snap.get('service_stranded', 0)} "
      f"({monitor.stranded_service_s:.2f} service-hours dark)")
print(f"  re-embeds           : {snap.get('re_embedded', 0)} "
      "(mass re-embeds + queue drains)")
print(f"  availability        : "
      f"{monitor.availability(horizon, n_services):.4f}")
print(f"  final live services : {session.n_live} "
      f"(queue={len(session.engine._queue)}, "
      f"substrate healthy={session.health is None or session.health.all_up})")
assert not session.engine._queue, "recovery must drain the retry queue"

if telemetry is not None:
    telemetry.close()
    print(f"\ntelemetry -> {TEL_PATH}")
    print(render(summarize_events(load_events(TEL_PATH))))
