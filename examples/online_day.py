"""A 24-hour day of service churn on the cloud-fog substrate.

Simulates the `diurnal24` scenario (Poisson arrivals under a raised-cosine
diurnal rate profile, exponential lifetimes -- the regime of Yosuf et al.'s
IoT service-distribution study) against the paper topology, serving every
event through a ``CFNSession``: arrivals and departures are warm-start
incremental re-embeddings (`solvers.resolve_incremental`), with a periodic
full-portfolio defrag -- masked by the same ``PlacementSpec`` as every
other path -- re-packing the substrate.

  PYTHONPATH=src python examples/online_day.py
  PYTHONPATH=src python examples/online_day.py --telemetry day.jsonl

Prints an hourly log of live services, fleet power, per-event re-solve
latency, and the day's totals.  (First-time shapes pay jit compiles; the
steady-state per-event latencies are the numbers to look at, and
BENCH_online.json tracks them rigorously.)  With ``--telemetry PATH``
the day streams spans, compile attribution, and the energy ledger to a
JSONL file and closes with the telemetry report: the day's joules split
into the paper's Eq.(1) networking vs Eq.(2) processing terms, by fog
tier and by tenant.
"""
import sys
import time

import numpy as np

from repro.api import CFNSession, PlacementSpec
from repro.core import dynamic, topology
from repro.telemetry import (Telemetry, load_events, render,
                             summarize_events)

SEED = 0
SCENARIO = dynamic.SCENARIOS["diurnal24"]
TEL_PATH = (sys.argv[sys.argv.index("--telemetry") + 1]
            if "--telemetry" in sys.argv else None)

topo = topology.paper_topology()
events = SCENARIO.timeline(rng=SEED)
print(f"scenario {SCENARIO.name}: {len(events)} events over "
      f"{SCENARIO.duration_h:.0f}h "
      f"(rate {SCENARIO.base_rate:.0f}->{SCENARIO.peak_rate:.0f}/h, "
      f"mean lifetime {SCENARIO.mean_lifetime_h:.0f}h)")

# one declarative spec: defrag cadence + (R, V) shape bucketing; add
# max_hops= / power_budget_w= here and every event path enforces them
telemetry = (Telemetry(jsonl_path=TEL_PATH, attribution_every=16)
             if TEL_PATH else None)
session = CFNSession(topo, PlacementSpec(defrag_every=8),
                     telemetry=telemetry)
lat, hour_mark = [], 0.0


def log_event(ev, dt):
    global hour_mark
    lat.append(dt)
    if ev.t >= hour_mark:
        rate = SCENARIO.rate_fn()(ev.t)
        print(f"  t={ev.t:5.1f}h rate={rate:4.1f}/h live={session.n_live:2d} "
              f"power={session.power_w():7.1f}W last={ev.kind:7s} "
              f"({dt * 1e3:6.1f} ms)")
        hour_mark = np.floor(ev.t) + 1.0


t_day = time.time()
live = set()
for ev in events:
    session.tick(ev.t)   # the ledger integrates against this clock
    t0 = time.time()   # per-event solve latency (print I/O excluded)
    if ev.kind == "arrive":
        session.add(SCENARIO.sample_vsr(1000 + ev.sid), sid=ev.sid)
        live.add(ev.sid)
    else:
        if ev.sid not in live:
            continue
        session.remove(ev.sid)
        live.discard(ev.sid)
    log_event(ev, time.time() - t0)

n_events = len(lat)
methods = [s.method for s in session.stats]
n_inc = sum(1 for m in methods if m == "incremental")
print(f"\nday done: {n_events} churn events in {time.time() - t_day:.1f}s "
      f"wall ({n_inc} incremental, {n_events - n_inc} full/defrag)")
print(f"re-solve latency: median={np.median(lat) * 1e3:.1f}ms "
      f"p90={np.percentile(lat, 90) * 1e3:.1f}ms "
      f"(includes first-shape jit compiles)")
if session.n_live:
    per = session.attribute()
    top = sorted(per.items(), key=lambda kv: -kv[1])[:3]
    print(f"end of day: {session.n_live} live services, "
          f"{session.power_w():.1f}W fleet "
          f"(top tenants: "
          + ", ".join(f"svc{sid}={w:.1f}W" for sid, w in top) + ")")

if telemetry is not None:
    telemetry.close()
    print(f"\ntelemetry -> {TEL_PATH}")
    print(render(summarize_events(load_events(TEL_PATH))))
