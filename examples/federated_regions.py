"""Federated fog regions walkthrough: 3 regions x 50 VSRs, cross-region
migration on a regional power-budget breach.

    PYTHONPATH=src python examples/federated_regions.py [--quick]

The paper's CFN is one metro tree; this example runs the multi-region
federation (``topology.federated_scale``): three city-style fog regions --
each its own PON access fabric, metro fog, and regional CDC -- stitched
over a shared IP/WDM core.  The ``FederatedSession``:

  * assigns every service to its HOME region (the region owning its
    source IoT device) and solves all three regional portfolios under ONE
    vmapped compile (``federation.solve_portfolio_batched``) -- the scaling
    move past the single-substrate ceiling: G small problems instead of
    one ever-bigger flat one;

  * accounts power EXACTLY per region (float64 per-node Eq. 1/2): the
    sum of regional + inter-region watts equals a from-scratch oracle
    evaluation of the merged placement;

  * enforces per-region power budgets: when churn pushes a region past
    its ``region_power_budget_w``, the coordinator migrates the arrival
    to the coolest admissible region -- its pinned input VM stays home,
    the cut virtual links are priced along the merged route (home egress
    + shared core + host ingress), which is where inter-region traffic
    enters Eq.(1) network power.  Breaches and migrations are counted on
    a ``fault.monitor.PlacementMonitor``.
"""
from __future__ import annotations

import sys
import time

import numpy as np

from repro.api import FederatedSession, PlacementSpec
from repro.core import federation, topology, vsr
from repro.fault.monitor import PlacementMonitor


def main(quick: bool = False) -> None:
    t0 = time.time()
    topo = topology.federated_scale(n_regions=3, n_olt=2, onus_per_olt=2,
                                    iot_per_onu=3, n_core=6)
    part = federation.RegionPartition.from_topology(topo)
    print(f"federation: G={part.G} regions x P_r="
          f"{part.regions[0].P} processing nodes (P={topo.P} merged), "
          f"{len(part.core_net_ids)}-node shared core "
          f"[built in {time.time() - t0:.1f}s]")
    print(f"inter-region core hops:\n{part.core_hops}")

    # workload: services sourced from IoT devices across all three regions
    n_vsrs = 12 if quick else 50
    rng = np.random.default_rng(0)
    sources = []
    for reg in part.regions:
        iot_local = reg.topo.layer_indices("iot")
        picks = rng.choice(iot_local, size=min(4, len(iot_local)),
                           replace=False)
        sources += [int(reg.proc_ids[i]) for i in picks]
    vs = vsr.random_vsrs(n_vsrs, rng=0, source_nodes=sources)

    monitor = PlacementMonitor()
    spec = PlacementSpec(effort="quick", anneal_steps=150)
    sess = FederatedSession(topo, spec, monitor=monitor)

    t0 = time.time()
    res = sess.solve(vs)
    bd = res.breakdown
    print(f"\nbatch solve: {n_vsrs} services in {time.time() - t0:.1f}s "
          f"(ONE vmapped compile across {part.G} regional portfolios)")
    per_region = {g: int((res.assignments == g).sum())
                  for g in range(part.G)}
    print(f"assignments: {per_region}  "
          f"(coordinator migrations: {res.migrations})")
    print(f"power: total={bd.total_w:,.1f} W = regional "
          f"{np.round(bd.regional_w, 1)} + inter-region "
          f"{bd.inter_region_w:.1f} W (exact f64 conservation)")

    # churn: cap region 0 just above its current draw, then hammer it with
    # arrivals until the budget breaks and the coordinator migrates
    budgets = np.full(part.G, 1e9)
    budgets[0] = float(bd.regional_w[0]) + 25.0
    sess.spec = sess.spec.replace(region_power_budget_w=budgets)
    print(f"\nchurn: adding services sourced in region 0 "
          f"(budget {budgets[0]:.0f} W on region 0) ...")
    src0 = sources[0]
    n_extra = 3 if quick else 8
    for k in range(n_extra):
        svc = vsr.random_vsrs(1, rng=1000 + k, source_nodes=[src0])
        r = sess.add(svc)
        sid = sess.sids[-1]
        host = sess.assignment(sid)
        w = sess.region_watts()
        tag = "HOME" if host == 0 else f"MIGRATED -> region {host}"
        print(f"  arrival {sid}: {tag:22s} regional W="
              f"{np.round(w, 0)}  admitted={r is not None}")
    print(f"\nmonitor: {monitor.snapshot()}")
    bd = sess.breakdown()
    print(f"final: total={bd.total_w:,.1f} W, inter-region core "
          f"{bd.inter_region_w:.1f} W over {len(part.core_net_ids)} "
          f"shared IP/WDM nodes")
    heavy = max(sess.sids, key=lambda s: sess._plans[s].migrated)
    plan = sess._plans[heavy]
    if plan.migrated:
        print(f"service {heavy}: input VM pinned at home "
              f"'{topo.proc_names[int(plan.vsr.src[0])]}', body hosted in "
              f"region {plan.assigned}, {len(plan.cuts)} cut links priced "
              "over the core")


if __name__ == "__main__":
    main(quick="--quick" in sys.argv[1:])
