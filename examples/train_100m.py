"""Train a ~100M-parameter model (whisper-base scale) on the synthetic
pipeline with checkpoint/restart enabled.

CPU-friendly default runs a reduced config for a quick loss-curve check;
pass --full --steps 300 for the real ~110M whisper-base (slow on CPU, the
same command scales on a mesh).

  PYTHONPATH=src python examples/train_100m.py --steps 40
"""
import argparse

import jax

from repro import configs
from repro.data.pipeline import DataConfig
from repro.fault.runner import ResilientTrainer
from repro.models import costs
from repro.optim import adamw
from repro.train.step import init_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--full", action="store_true",
                    help="full whisper-base (~110M params; slow on CPU)")
    ap.add_argument("--ckpt-dir", default="/tmp/train100m_ckpt")
    args = ap.parse_args()

    cfg = (configs.get("whisper-base") if args.full
           else configs.get_smoke("whisper-base"))
    n = costs.param_breakdown(cfg)["total"]
    print(f"training {cfg.name}: {n / 1e6:.1f}M params")

    dcfg = DataConfig(seed=0, batch=4, seq_len=256 if args.full else 64)
    step = jax.jit(make_train_step(cfg, adamw.AdamWConfig(lr=3e-3)),
                   donate_argnums=(0,))
    trainer = ResilientTrainer(
        cfg, dcfg, step,
        lambda: init_state(cfg, jax.random.PRNGKey(0))[0],
        args.ckpt_dir, ckpt_every=20)
    report = trainer.run(args.steps)
    print(f"loss {report.losses[0]:.4f} -> {report.losses[-1]:.4f} "
          f"over {report.final_step} steps "
          f"(restarts={report.restarts}, "
          f"stragglers={len(report.straggler_steps)})")
    assert report.losses[-1] < report.losses[0], "loss did not improve"
    print("checkpoints in", args.ckpt_dir)


if __name__ == "__main__":
    main()
