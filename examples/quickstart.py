"""Quickstart: the paper in ~30 lines, on the unified placement API.

Builds the paper's Cloud-Fog Network, declares the optimization once as a
``PlacementSpec``, embeds DNN-inference VSRs through a ``CFNSession``
(the MILP stand-in), and prints the energy comparison against the
CDC / AF / MF baselines (paper Fig. 3/4).

  PYTHONPATH=src python examples/quickstart.py
"""
from repro.api import CFNSession, PlacementSpec
from repro.core import topology, vsr

# 1. the paper's substrate: 20 RPi-class IoT devices in 4 Wi-Fi zones,
#    one Access-Fog and one Metro-Fog server, a Xeon CDC behind the core
topo = topology.paper_topology()

# 2. ten DNN inference services; each VSR = input VM (pinned at the IoT
#    source) + compute VMs with U(3,10) GFLOPS demands, chained by Mbps links
vsrs = vsr.random_vsrs(10, rng=0, source_nodes=[0])

# 3. declare the optimization ONCE: method, effort, and (optionally) SLA
#    constraints / admission budgets all live on the spec -- every solver
#    path enforces the same set.  Bucketing off: one static batch solve.
spec = PlacementSpec(method="cfn-milp", bucket_rows=False, bucket_cols=False)

# 4. optimize the placement (portfolio solver = the CPLEX stand-in)
result = CFNSession(topo, spec).solve(vsrs)
print(f"CFN-MILP : {result.power:8.1f} W  "
      f"(feasible={result.feasible}, method={result.method})")

# 5. the paper's fixed-layer baselines: same spec, different method
for pol in ("cdc", "af", "mf"):
    base = CFNSession(topo, spec.replace(method=pol)).solve(vsrs)
    saving = 1 - result.power / base.power
    print(f"{pol.upper():9s}: {base.power:8.1f} W  -> CFN saves {saving:.1%}")

# 6. where did the VMs land?  (paper: the IoT layer, AF/MF bypassed)
layers = [topo.proc_layer[p] for p in result.X.reshape(-1)]
print("placement layers:", sorted(set(layers)))
