"""Quickstart: the paper in ~30 lines.

Builds the paper's Cloud-Fog Network, embeds DNN-inference VSRs with the
MILP stand-in, and prints the energy comparison against the CDC / AF / MF
baselines (paper Fig. 3/4).

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core import embed, power, topology, vsr

# 1. the paper's substrate: 20 RPi-class IoT devices in 4 Wi-Fi zones,
#    one Access-Fog and one Metro-Fog server, a Xeon CDC behind the core
topo = topology.paper_topology()

# 2. ten DNN inference services; each VSR = input VM (pinned at the IoT
#    source) + compute VMs with U(3,10) GFLOPS demands, chained by Mbps links
vsrs = vsr.random_vsrs(10, rng=0, source_nodes=[0])

# 3. optimize the placement (portfolio solver = the CPLEX stand-in)
problem = power.build_problem(topo, vsrs)
result = embed.embed(topo, vsrs, "cfn-milp", problem=problem)
print(f"CFN-MILP : {result.power:8.1f} W  "
      f"(feasible={result.feasible}, method={result.method})")

# 4. the paper's fixed-layer baselines
for pol in ("cdc", "af", "mf"):
    base = embed.embed(topo, vsrs, pol, problem=problem)
    saving = 1 - result.power / base.power
    print(f"{pol.upper():9s}: {base.power:8.1f} W  -> CFN saves {saving:.1%}")

# 5. where did the VMs land?  (paper: the IoT layer, AF/MF bypassed)
layers = [topo.proc_layer[p] for p in result.X.reshape(-1)]
print("placement layers:", sorted(set(layers)))
