"""End-to-end driver (the paper is an inference paper): serve real models
with batched requests, then place the serving fleet on the cloud-fog
substrate with the paper's optimizer and report energy per deployment.

  PYTHONPATH=src python examples/placement_aware_serving.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import topology
from repro.models import model as M
from repro.serve import cache as C
from repro.serve import engine
from repro.serve.scheduler import EnergyAwareScheduler, Service

# --- 1. serve a batch of requests through a real (reduced) model ----------
cfg = configs.get_smoke("qwen3-4b")
params, _ = M.init_model(cfg, jax.random.PRNGKey(0))
rng = np.random.default_rng(0)
B, S, GEN = 4, 24, 12
batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                               jnp.int32)}
cache = C.zeros(C.cache_spec(cfg, B, S + GEN + 8))
t0 = time.time()
seq, _ = engine.greedy_generate(params, cfg, batch, cache, GEN)
dt = time.time() - t0
tok_rate = B * GEN / dt
print(f"served {B} requests x {GEN} tokens in {dt:.2f}s "
      f"({tok_rate:.1f} tok/s)")

# --- 2. place the serving fleet on the datacenter-scale CFN ---------------
# Each production service (full-size arch + its measured token rate) becomes
# a VSR; the paper's optimizer decides edge / fog / cloud per stage.
sched = EnergyAwareScheduler(topology.datacenter_topology())
sched.add_service(Service("qwen3-chat", configs.get("qwen3-4b"),
                          tokens_per_s=2000.0))
sched.add_service(Service("olmoe-embed", configs.get("olmoe-1b-7b"),
                          tokens_per_s=8000.0))
sched.add_service(Service("deepseek-api", configs.get("deepseek-v2-236b"),
                          tokens_per_s=500.0, n_stages=8))
for p in sched.solve():
    print(f"{p.service:14s} -> {'/'.join(p.layers)}")
s = sched.savings_vs_cloud()
print(f"fleet power: {sched.total_power_w():.0f} W  "
      f"(vs all-cloud: saves {s['saving_frac']:.1%})")
