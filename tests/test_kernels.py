"""Pallas kernel tests: shape/dtype sweeps vs the pure-jnp oracles
(interpret mode on CPU; the same kernels lower via Mosaic on TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import power, topology, vsr
from repro.kernels import ops, ref

FLASH_CASES = [
    # B, H, KH, Sq, Skv, D, causal, window, cap, dtype
    (2, 4, 2, 64, 64, 32, True, None, None, jnp.float32),
    (1, 8, 8, 128, 256, 64, True, None, 50.0, jnp.float32),
    (2, 4, 1, 96, 160, 32, True, 32, None, jnp.float32),
    (1, 2, 2, 48, 80, 16, False, None, None, jnp.float32),
    (2, 8, 4, 200, 200, 64, True, 64, 30.0, jnp.float32),
    (1, 4, 2, 64, 128, 32, True, None, None, jnp.bfloat16),
    (2, 2, 2, 33, 65, 24, True, None, None, jnp.float32),  # ragged blocks
]


@pytest.mark.parametrize("case", FLASH_CASES,
                         ids=[f"c{i}" for i in range(len(FLASH_CASES))])
def test_flash_attention_vs_ref(case):
    B, H, KH, Sq, Skv, D, causal, window, cap, dtype = case
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, Sq, D), dtype)
    k = jax.random.normal(ks[1], (B, KH, Skv, D), dtype)
    v = jax.random.normal(ks[2], (B, KH, Skv, D), dtype)
    off = Skv - Sq
    got = ops.flash_attention(q, k, v, causal=causal, window=window,
                              logit_cap=cap, q_offset=off)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                   logit_cap=cap, q_offset=off)
    atol = 2e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=atol)


def test_flash_attention_fully_masked_rows_are_zero():
    """q before every kv position (q_offset past end): zero output, no NaN."""
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 2, 16, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 2, 32, 16))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 2, 32, 16))
    got = ops.flash_attention(q, k, v, causal=True, q_offset=-64)
    assert bool(jnp.isfinite(got).all())
    np.testing.assert_allclose(np.asarray(got), 0.0, atol=1e-6)


@settings(deadline=None, max_examples=10)
@given(seed=st.integers(0, 10_000), n_vsrs=st.integers(1, 6),
       n_vms=st.integers(2, 4))
@pytest.mark.slow
def test_placement_kernel_vs_oracle(seed, n_vsrs, n_vms):
    topo = topology.paper_topology()
    vs = vsr.random_vsrs(n_vsrs, rng=seed, n_vms=n_vms, source_nodes=[0])
    prob = power.build_problem(topo, vs)
    key = jax.random.PRNGKey(seed)
    Xb = jax.random.randint(key, (17, prob.R, prob.V), 0, prob.P, jnp.int32)
    got = ops.placement_objective(prob, Xb)
    pinned = jax.vmap(lambda X: power.apply_pins(prob, X))(Xb)
    want = ref.placement_objective_ref(prob, pinned)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=1e-2)


def test_placement_kernel_block_padding():
    """B not a multiple of the candidate block: padded rows are dropped."""
    topo = topology.paper_topology()
    vs = vsr.random_vsrs(3, rng=1, source_nodes=[0])
    prob = power.build_problem(topo, vs)
    Xb = jax.random.randint(jax.random.PRNGKey(0), (5, prob.R, prob.V),
                            0, prob.P, jnp.int32)
    got = ops.placement_objective(prob, Xb)
    assert got.shape == (5, 4)
    pinned = jax.vmap(lambda X: power.apply_pins(prob, X))(Xb)
    want = ref.placement_objective_ref(prob, pinned)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=1e-2)
