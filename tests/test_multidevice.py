"""Multi-device semantics tests (run in a subprocess with 8 host devices so
the main test process keeps its single-device view).

Covers: production-mesh construction, sharded train_step numerics vs the
single-device step, int8-compressed pod gradient sync, and elastic
checkpoint re-shard onto a different mesh shape.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro import configs
    from repro.data.pipeline import DataConfig, make_batch
    from repro.launch import mesh as mesh_mod
    from repro.launch import specs as S
    from repro.optim import adamw
    from repro.parallel import sharding as sh
    from repro.serve import cache as C
    from repro.checkpoint import CheckpointStore
    from repro.train.step import init_state, make_train_step

    cfg = dataclasses.replace(configs.get_smoke("qwen3-4b"), n_layers=2)
    dcfg = DataConfig(seed=0, batch=8, seq_len=32)
    opt = adamw.AdamWConfig(lr=1e-3)
    batch = {k: jnp.asarray(v) for k, v in
             make_batch(cfg, dcfg, 0).items()}

    # 1) single-device reference
    state0, axes = init_state(cfg, jax.random.PRNGKey(0))
    step1 = jax.jit(make_train_step(cfg, opt))
    sref, mref = step1(state0, batch)
    loss_ref = float(mref["loss"])

    # 2) sharded (2, 2, 2) pod/data/model mesh
    mesh = mesh_mod.make_mesh((2, 2, 2), ("pod", "data", "model"))
    with sh.mesh_context(mesh):
        state0b, _ = init_state(cfg, jax.random.PRNGKey(0))
        state_sh = sh.shard_params(
            state0b, __import__("repro.launch.specs", fromlist=["x"])
            .train_state_specs(cfg)[1], mesh)
        step2 = jax.jit(make_train_step(cfg, opt),
                        in_shardings=(state_sh, None),
                        out_shardings=(state_sh, None))
        s2, m2 = step2(state0b, batch)
        loss_sh = float(m2["loss"])
    assert abs(loss_sh - loss_ref) < 5e-2, (loss_sh, loss_ref)
    print("SHARDED_STEP_OK", loss_ref, loss_sh)

    # 3) compressed pod sync step compiles + runs, loss close to reference
    with sh.mesh_context(mesh, rules={"batch": ("data",)}):
        st_c, _ = init_state(cfg, jax.random.PRNGKey(0), compress_pod=True)
        stepc = jax.jit(make_train_step(cfg, opt, compress_pod=True,
                                        mesh=mesh))
        sc, mc = stepc(st_c, batch)
        loss_c = float(mc["loss"])
    assert abs(loss_c - loss_ref) < 5e-2, (loss_c, loss_ref)
    print("COMPRESSED_STEP_OK", loss_c)

    # 4) elastic re-shard: save on (2,2,2), restore on (4, 2) mesh
    store = CheckpointStore("/tmp/elastic_ck")
    store.save(1, s2, extra=dict(data_step=1))
    store.wait()
    mesh2 = mesh_mod.make_mesh((4, 2), ("data", "model"))
    with sh.mesh_context(mesh2):
        like = jax.eval_shape(
            lambda: init_state(cfg, jax.random.PRNGKey(0))[0])
        sh_tree = sh.shard_params(
            like, __import__("repro.launch.specs", fromlist=["x"])
            .train_state_specs(cfg)[1], mesh2)
        restored, _ = store.restore(None, like, sh_tree)
        step3 = jax.jit(make_train_step(cfg, opt))
        s3, m3 = step3(restored, batch)
    # the re-sharded state continues training bit-compatibly
    s2b, m2b = step1(jax.device_get(s2), batch)
    assert abs(float(m3["loss"]) - float(m2b["loss"])) < 5e-3
    print("ELASTIC_OK", float(m3["loss"]), float(m2b["loss"]))

    # 5) decode on the mesh with sharded cache
    with sh.mesh_context(mesh):
        params_sds, axes2, batch_sds, extra, spec = S.serve_specs(
            cfg, 8, 64, "decode")
        csh = C.shardings(spec, mesh)
        print("CACHE_SHARDINGS_OK", len(jax.tree_util.tree_leaves(csh)))
    print("ALL_OK")
""")


@pytest.mark.slow
@pytest.mark.skipif(
    not hasattr(__import__("jax"), "shard_map"),
    reason="512-device dry-run needs jax>=0.5 shard_map semantics (jax 0.4.x"
           " jaxlib fails an IsManualSubgroup check on these shardings)")
def test_multidevice_semantics(tmp_path):
    script = tmp_path / "md.py"
    script.write_text(SCRIPT)
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, str(script)], env=env,
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stdout + "\n" + res.stderr
    assert "ALL_OK" in res.stdout
