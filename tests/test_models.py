"""Per-architecture model tests: smoke forward/train, decode==forward,
recurrence equivalences, MoE dispatch equivalence, loss chunking."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import layers as L
from repro.models import model as M
from repro.models import ssm
from repro.optim import adamw
from repro.serve import cache as C
from repro.serve import engine
from repro.train.step import init_state, make_train_step

# full-architecture smoke/train/decode sweeps dominate tier-1 wall time
pytestmark = pytest.mark.slow


def _batch(cfg, B=2, S=32, seed=7):
    rng = np.random.default_rng(seed)
    out = {}
    text = S - (cfg.vision_prefix_tokens or 0)
    if cfg.is_encoder_decoder:
        out["frames"] = jnp.asarray(
            0.1 * rng.standard_normal((B, S, cfg.d_model)), jnp.float32)
    if cfg.vision_prefix_tokens:
        out["patches"] = jnp.asarray(
            0.1 * rng.standard_normal((B, cfg.vision_prefix_tokens,
                                       cfg.d_model)), jnp.float32)
    toks = rng.integers(0, cfg.vocab, (B, text + 1))
    out["tokens"] = jnp.asarray(toks[:, :-1], jnp.int32)
    out["labels"] = jnp.asarray(toks[:, 1:], jnp.int32)
    return out


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_smoke_forward_and_shapes(arch):
    cfg = configs.get_smoke(arch)
    params, axes = M.init_model(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    loss = M.forward_train(params, cfg, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))
    h = M.forward_hidden(params, cfg, batch)
    S_total = batch["tokens"].shape[1] + (cfg.vision_prefix_tokens or 0)
    assert h.shape == (2, S_total, cfg.d_model)
    assert bool(jnp.isfinite(h).all())


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_smoke_train_step_improves(arch):
    cfg = configs.get_smoke(arch)
    state, _ = init_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, adamw.AdamWConfig(lr=5e-3)),
                   donate_argnums=(0,))
    batch = _batch(cfg, B=4, S=32)
    losses = []
    for _ in range(8):    # same batch: loss must fall if grads flow
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
    assert np.isfinite(losses).all()


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_decode_matches_forward(arch):
    # fp32 so near-tie MoE routing decisions can't flip between the cached
    # and uncached paths (a bf16 rounding effect, not a cache bug)
    cfg = dataclasses.replace(configs.get_smoke(arch), dtype="float32")
    if cfg.moe:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)  # lossless
    params, _ = M.init_model(cfg, jax.random.PRNGKey(1))
    B, S = 2, 17
    batch = _batch(cfg, B=B, S=S)
    h = M.forward_hidden(params, cfg, batch)
    ref = M.logits_fn(params, cfg, h[:, -1:])[:, 0]
    enc_len = S if cfg.is_encoder_decoder else 0
    cache = C.zeros(C.cache_spec(cfg, B, 64, enc_len=enc_len))
    pre = dict(batch)
    pre.pop("labels")
    toks = pre.pop("tokens")
    _, cache = engine.prefill(params, cfg, {"tokens": toks[:, :-1], **pre},
                              cache)
    pos = jnp.asarray(toks.shape[1] - 1 + (cfg.vision_prefix_tokens or 0),
                      jnp.int32)
    got, _ = engine.decode_step(params, cfg, toks[:, -1:], pos, cache)
    rel = float(jnp.max(jnp.abs(got - ref))) / \
        (float(jnp.max(jnp.abs(ref))) + 1e-9)
    assert rel < 3e-2, f"{arch}: cached decode diverges ({rel:.3e})"


def test_mlstm_chunkwise_matches_sequential():
    B, T, H, dk, dv = 2, 256, 4, 16, 32
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    q = jax.random.normal(ks[0], (B, T, H, dk))
    k = jax.random.normal(ks[1], (B, T, H, dk))
    v = jax.random.normal(ks[2], (B, T, H, dv))
    i_raw = jax.random.normal(ks[3], (B, T, H))
    lf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (B, T, H)))
    h1, s1 = ssm.mlstm_sequential(q, k, v, i_raw, lf)
    h2, s2 = ssm.mlstm_chunkwise(q, k, v, i_raw, lf, chunk=64)
    np.testing.assert_allclose(h1, h2, atol=2e-4)
    np.testing.assert_allclose(s1[0], s2[0], atol=2e-4)


def test_mamba_chunked_scan_matches_stepwise():
    cfg = configs.get_smoke("hymba-1.5b")
    ini = L.Init(jax.random.PRNGKey(0))
    ssm.init_mamba(ini, cfg, prefix="m_")
    x = 0.3 * jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
    y_par, _ = ssm.mamba(ini.params, x, cfg, state=None, prefix="m_")
    # stepwise decode over the same sequence
    state = dict(conv=jnp.zeros((2, cfg.conv_kernel - 1,
                                 cfg.ssm_expand * cfg.d_model)),
                 h=jnp.zeros((2, cfg.ssm_expand * cfg.d_model,
                              cfg.ssm_state)))
    outs = []
    for t in range(64):
        y, state = ssm.mamba(ini.params, x[:, t:t + 1], cfg, state=state,
                             prefix="m_")
        outs.append(y)
    y_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                               atol=3e-3)


def test_moe_impls_agree_lossless():
    cfg = dataclasses.replace(configs.get_smoke("olmoe-1b-7b"),
                              capacity_factor=8.0)
    params, _ = M.init_model(cfg, jax.random.PRNGKey(0))
    unit = jax.tree_util.tree_map(lambda a: a[0], params["g0"])["b0"]
    x = 0.3 * jax.random.normal(jax.random.PRNGKey(5), (2, 16, cfg.d_model))
    y_onehot = L.moe(unit, x, cfg, impl="onehot")
    y_sort = L.moe(unit, x, cfg, impl="sort")
    y_ep = L.moe(unit, x, cfg, impl="ep_sort")
    np.testing.assert_allclose(y_onehot, y_sort, atol=1e-5)
    np.testing.assert_allclose(y_onehot, y_ep, atol=1e-5)


def test_chunked_xent_matches_direct():
    cfg = configs.get_smoke("qwen3-4b")
    params, _ = M.init_model(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, B=2, S=32)
    h = M.forward_hidden(params, cfg, batch)
    chunked = M.xent_loss(params, cfg, h, batch["labels"], n_chunks=8)
    direct = M.xent_loss(params, cfg, h, batch["labels"], n_chunks=1)
    np.testing.assert_allclose(float(chunked), float(direct), rtol=1e-5)


def test_layer_plan_covers_all_layers():
    for arch in configs.ARCH_IDS:
        cfg = configs.get(arch)
        plan = M.layer_plan(cfg)
        total = sum(len(g.kinds) * g.repeats for g in plan)
        assert total == cfg.n_layers, (arch, total)


def test_param_counts_match_published():
    # +-15% of the advertised sizes (embeddings / stubs explain the slack)
    expected = {
        "xlstm-1.3b": 1.3e9, "qwen3-4b": 4.0e9, "h2o-danube-3-4b": 4.0e9,
        "gemma2-27b": 27.2e9, "command-r-plus-104b": 104e9,
        "deepseek-v2-236b": 236e9, "olmoe-1b-7b": 6.9e9,
        "hymba-1.5b": 1.5e9, "internvl2-2b": 1.9e9,
    }
    from repro.models import costs
    for arch, n in expected.items():
        got = costs.param_breakdown(configs.get(arch))["total"]
        assert abs(got - n) / n < 0.16, (arch, got, n)
