"""Paper-core tests: topology routing, Eq.(1)/(2) power model, solvers.

Property tests (hypothesis) check the system invariants the MILP relies on:
flow conservation of the path-incidence contraction, placement-pin respect,
monotonicity of power in workload, and solver optimality against exhaustive
enumeration on small instances.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import embed, power, solvers, topology, vsr  # noqa: F401

SETTINGS = dict(deadline=None, max_examples=15)


@pytest.fixture(scope="module")
def topo():
    return topology.paper_topology()


def _problem(topo, n_vsrs=4, seed=0, **kw):
    vs = vsr.random_vsrs(n_vsrs, rng=seed, source_nodes=[0], **kw)
    return power.build_problem(topo, vs), vs


# ---------------------------------------------------------------------------
# routing / flow conservation
# ---------------------------------------------------------------------------

def test_paths_symmetric_and_acyclic(topo):
    pn = topo.path_nodes
    assert pn.shape == (topo.P, topo.P, topo.N)
    np.testing.assert_array_equal(pn, pn.transpose(1, 0, 2))
    assert np.all(pn.diagonal(axis1=0, axis2=1).T == 0)


def test_same_node_traffic_stays_local(topo):
    # traffic between a node and itself crosses no network node
    assert float(topo.path_nodes[3, 3].sum()) == 0.0


@settings(**SETTINGS)
@given(seed=st.integers(0, 10_000), n=st.integers(1, 6))
def test_flow_conservation(seed, n):
    """lambda_n from the tensor contraction == independent route walk."""
    topo = topology.paper_topology()
    prob, vs = _problem(topo, n_vsrs=n, seed=seed)
    rng = np.random.default_rng(seed)
    X = rng.integers(0, prob.P, size=(prob.R, prob.V)).astype(np.int32)
    Xp = np.asarray(power.apply_pins(prob, jnp.asarray(X)))
    # model's lambda
    onehot = jax.nn.one_hot(jnp.asarray(Xp), prob.P, dtype=jnp.float32)
    _, _, lam, _ = power._loads(prob, onehot)
    # independent accumulation: for each virtual link, add its bitrate to
    # every network node on the (unique) route
    lam_ref = np.zeros(topo.N)
    ls, ld, lh = vs.links()
    flatX = Xp.reshape(-1)
    for s, d, h in zip(ls, ld, lh):
        b, e = int(flatX[s]), int(flatX[d])
        if b == e:
            continue
        lam_ref += h * topo.path_nodes[b, e]
    np.testing.assert_allclose(np.asarray(lam), lam_ref, rtol=1e-5,
                               atol=1e-3)


# ---------------------------------------------------------------------------
# power model invariants
# ---------------------------------------------------------------------------

def test_pins_respected(topo):
    prob, vs = _problem(topo, n_vsrs=3, seed=1)
    X = np.full((prob.R, prob.V), 5, dtype=np.int32)
    Xp = np.asarray(power.apply_pins(prob, jnp.asarray(X)))
    np.testing.assert_array_equal(Xp[np.arange(prob.R), vs.input_vm], vs.src)


@settings(**SETTINGS)
@given(seed=st.integers(0, 10_000))
def test_power_monotone_in_workload(seed):
    """Scaling all demands up never decreases total power."""
    topo = topology.paper_topology()
    vs = vsr.random_vsrs(3, rng=seed, source_nodes=[0])
    prob1 = power.build_problem(topo, vs)
    vs2 = vsr.VSRBatch(F=vs.F * 1.7, H=vs.H * 1.7, src=vs.src,
                       input_vm=vs.input_vm)
    prob2 = power.build_problem(topo, vs2)
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.integers(0, prob1.P, size=(prob1.R, prob1.V)),
                    jnp.int32)
    p1 = power.evaluate(prob1, X)
    p2 = power.evaluate(prob2, X)
    assert float(p2.total) >= float(p1.total) - 1e-3


def test_cdc_only_placement_matches_hand_calc(topo):
    """One VM at the CDC: per-server idle + proportional + route power."""
    vs = vsr.VSRBatch(
        F=np.array([[0.5, 8.0]], np.float32),
        H=np.zeros((1, 2, 2), np.float32),
        src=np.array([0], np.int32), input_vm=np.array([0], np.int32))
    vs.H[0, 0, 1] = 20.0  # Mbps input->compute
    prob = power.build_problem(topo, vs)
    cdc = topo.proc_index("cdc0")
    X = jnp.asarray([[0, cdc]], jnp.int32)
    bd = power.evaluate(prob, X)
    # processing: iot server idle+prop for input VM, cdc server idle+prop
    iot, cdch = topo.proc_hw[0], topo.proc_hw[cdc]
    exp_proc = (1.0 * (iot.idle_w + iot.eps_w_per_gflops * 0.5)
                + 1.12 * (cdch.idle_w + cdch.eps_w_per_gflops * 8.0
                          + cdch.lan_idle_share * cdch_lan_idle(topo, cdc)
                          + cdch.lan_eps_w_per_gbps * 20.0 / 1e3))
    assert abs(float(bd.proc) - exp_proc) < 1.0
    assert float(bd.net) > 0.0       # route crosses onu/olt/metro/core
    assert float(bd.violation) == 0.0


def cdch_lan_idle(topo, p):
    return topo.proc_hw[p].lan_idle_w


# ---------------------------------------------------------------------------
# solvers
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=6)
@given(seed=st.integers(0, 1000))
def test_exhaustive_is_lower_bound(seed):
    """No solver beats exhaustive enumeration (tiny instance)."""
    topo = topology.paper_topology(n_iot=4, n_zones=2)
    vs = vsr.random_vsrs(2, rng=seed, n_vms=2, source_nodes=[0])
    prob = power.build_problem(topo, vs)
    best = solvers.exhaustive(prob).objective
    for method in ("cdc", "af", "mf", "iot", "coordinate"):
        res = embed.embed(topo, vs, method, problem=prob)
        assert res.objective >= best - 1e-4


def test_portfolio_matches_exhaustive_small():
    topo = topology.paper_topology(n_iot=4, n_zones=2)
    vs = vsr.random_vsrs(2, rng=7, n_vms=2, source_nodes=[0])
    prob = power.build_problem(topo, vs)
    best = solvers.exhaustive(prob).objective
    res = solvers.solve_cfn(prob, topo, jax.random.PRNGKey(0))
    assert res.objective <= best * 1.001


def test_coordinate_descent_monotone(topo):
    prob, vs = _problem(topo, n_vsrs=5, seed=3)
    cdc = topo.layer_indices("cdc")[0]
    X0 = np.full((prob.R, prob.V), cdc, dtype=np.int32)
    res = solvers.coordinate(prob, X0)
    hist = res.history
    assert all(hist[i + 1] <= hist[i] + 1e-6 for i in range(len(hist) - 1))


def test_anneal_improves_over_random(topo):
    prob, vs = _problem(topo, n_vsrs=5, seed=4)
    rng = np.random.default_rng(0)
    X0 = rng.integers(0, prob.P, size=(prob.R, prob.V)).astype(np.int32)
    start = float(power.objective(prob, jnp.asarray(X0)))
    res = solvers.anneal(prob, jax.random.PRNGKey(1), X0, n_chains=8,
                         n_steps=500)
    assert res.objective <= start


def test_fixed_layer_spills_on_overflow():
    """IoT layer saturates -> first-fit spills to the CDC (paper's 20-VSR
    spike)."""
    topo = topology.paper_topology(n_iot=2)
    vs = vsr.random_vsrs(20, rng=0, source_nodes=[0],
                         vm_gflops=(8.0, 10.0))
    prob = power.build_problem(topo, vs)
    res = solvers.fixed_layer(prob, topo, "iot")
    layers_used = {topo.proc_layer[p] for p in res.X.reshape(-1)}
    assert "cdc" in layers_used
    assert res.feasible


# ---------------------------------------------------------------------------
# paper headline (fast version; benchmarks reproduce the full figure)
# ---------------------------------------------------------------------------

def test_cfn_beats_cdc_baseline(topo):
    vs = vsr.random_vsrs(8, rng=0, source_nodes=[0])
    out = embed.savings_vs_baseline(topo, vs, baseline="cdc",
                                    method="cfn-milp")
    assert out["saving_frac"] > 0.15          # paper worst case is 19%
    assert out["optimized"].feasible


def test_af_mf_never_selected_by_optimizer(topo):
    """Paper finding: AF/MF bypassed (inefficient W/GFLOPS + PUE)."""
    vs = vsr.random_vsrs(6, rng=2, source_nodes=[0])
    res = embed.embed(topo, vs, "cfn-milp")
    layers_used = {topo.proc_layer[p] for p in res.X.reshape(-1)}
    assert "af" not in layers_used and "mf" not in layers_used


# ---------------------------------------------------------------------------
# beyond-paper: meshed NSFNET core + latency-bounded embedding (paper §4)
# ---------------------------------------------------------------------------

def test_nsfnet_flow_conservation():
    """The meshed core breaks route uniqueness but not conservation: the
    path tensor still routes every unit of traffic along one connected
    shortest path (symmetric, CDC reachable, sane hop counts)."""
    t = topology.nsfnet_topology()
    pn, hops = t.path_nodes, t.path_hops
    np.testing.assert_array_equal(pn, pn.transpose(1, 0, 2))
    cdc = t.proc_index("cdc0")
    # iot -> cdc crosses access + metro + several core nodes
    assert 5 <= hops[0, cdc] <= 14
    # per-pair: number of network nodes on the route == recorded hops
    np.testing.assert_array_equal(pn.sum(-1), hops)


@pytest.mark.slow
def test_nsfnet_savings_band():
    t = topology.nsfnet_topology()
    vs = vsr.random_vsrs(6, rng=0, source_nodes=[0])
    out = embed.savings_vs_baseline(t, vs, method="cfn-milp")
    # deeper core => CDC costs more => savings at least as large as tree
    assert out["saving_frac"] > 0.3


@pytest.mark.slow
def test_latency_bounded_embedding(topo):
    vs = vsr.random_vsrs(5, rng=1, source_nodes=[0])
    res = embed.embed_latency_bounded(topo, vs, max_hops=2)
    hops = topo.path_hops
    for r in range(res.X.shape[0]):
        src = int(vs.src[r])
        for v in range(res.X.shape[1]):
            assert hops[src, res.X[r, v]] <= 2
    # with a 2-hop budget the CDC (5+ hops away) is unreachable
    cdc = topo.proc_index("cdc0")
    assert cdc not in set(res.X.reshape(-1))


def test_latency_repair_matches_bruteforce(topo):
    """The delta-sweep repair returns the same placement as the original
    brute-force repair (full objective re-evaluation per candidate) on a
    small instance."""
    vs = vsr.random_vsrs(3, rng=5, source_nodes=[0])
    max_hops = 2
    res = embed.embed_latency_bounded(topo, vs, max_hops=max_hops)

    # brute force, replicating the pre-rewrite semantics
    problem = power.build_problem(topo, vs)
    base = embed.embed(topo, vs, "cfn-milp", problem=problem)
    hops = topo.path_hops
    X = base.X.copy()
    for r in range(X.shape[0]):
        src = int(vs.src[r])
        for v in range(X.shape[1]):
            if hops[src, X[r, v]] > max_hops:
                eligible = [p for p in range(topo.P)
                            if hops[src, p] <= max_hops]
                best, best_obj = X[r, v], float("inf")
                for p in eligible:
                    X2 = X.copy()
                    X2[r, v] = p
                    o = float(solvers.objective(problem, jnp.asarray(X2)))
                    if o < best_obj:
                        best, best_obj = p, o
                X[r, v] = best
    np.testing.assert_array_equal(res.X, X)


# ---------------------------------------------------------------------------
# VSR construction from per-layer costs (regression: boundary bytes)
# ---------------------------------------------------------------------------

def test_from_layer_costs_boundary_bytes():
    """Hand-computed stage boundaries: the stage s-1 -> s link carries the
    OUTPUT of the last layer of stage s-1, and the input-VM link carries
    the embedding output (input_act_bytes), not the first layer's output."""
    gfl = [1.0, 2.0, 3.0, 4.0]
    act = [10.0, 20.0, 30.0, 40.0]        # heterogeneous, catches indexing
    tps = 100.0
    v = vsr.from_layer_costs(gfl, act, tps, n_stages=2,
                             input_gflop_per_token=0.5,
                             input_act_bytes=7.0)
    # stages: layers [0,2) and [2,4)
    np.testing.assert_allclose(
        v.F[0], [0.5 * tps, (1 + 2) * tps, (3 + 4) * tps])
    mbps = lambda b: b * tps * 8.0 / 1e6
    assert abs(v.H[0, 0, 1] - mbps(7.0)) < 1e-9      # embedding output
    assert abs(v.H[0, 1, 2] - mbps(20.0)) < 1e-9     # layer 1's output
    assert np.count_nonzero(v.H) == 2

    # default input_act_bytes falls back to layer 0's size
    v2 = vsr.from_layer_costs(gfl, act, tps, n_stages=2)
    assert abs(v2.H[0, 0, 1] - mbps(10.0)) < 1e-9


def test_from_layer_costs_degenerate_stages():
    """n_stages > L clamps to one layer per stage (no zero-demand stages);
    n_stages < 1 and mismatched inputs raise."""
    gfl, act = [1.0, 2.0], [10.0, 20.0]
    v = vsr.from_layer_costs(gfl, act, 10.0, n_stages=5)
    assert v.V == 3                       # clamped to L=2 stages + input VM
    assert np.all(v.F[0, 1:] > 0)         # every stage owns >= 1 layer
    with pytest.raises(ValueError):
        vsr.from_layer_costs(gfl, act, 10.0, n_stages=0)
    with pytest.raises(ValueError):
        vsr.from_layer_costs([], [], 10.0, n_stages=1)
    with pytest.raises(ValueError):
        vsr.from_layer_costs(gfl, [1.0], 10.0, n_stages=1)


def test_from_layer_costs_no_zero_demand_stages():
    """Rounded bounds stay strictly increasing for any n_stages <= L."""
    gfl = list(np.linspace(0.5, 2.0, 7))
    act = [100.0] * 7
    for n in range(1, 12):
        v = vsr.from_layer_costs(gfl, act, 10.0, n_stages=n)
        assert np.all(v.F[0, 1:] > 0), n
        # chain links present between consecutive stage VMs
        n_eff = v.V - 1
        for s in range(n_eff):
            assert v.H[0, s, s + 1] > 0
