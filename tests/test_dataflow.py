"""Flow-sensitive tracelint rules (CFN106-CFN109) and the dataflow engine.

Pure-AST (no jax import): each rule family gets violation fixtures with
the exact rule id asserted and clean twins that must produce nothing --
including the sanctioned idioms the engine must NOT flag (the
``key, k = split(key)`` loop carry, ``fold_in`` stream derivation,
split-array indexing, rebinding after donation).  Also covers pragma
suppression for the new ids, and the move-stability contract: a baseline
fingerprint survives the offending function moving to another file.
"""
import json
import textwrap
from pathlib import Path

from repro.analysis import (CACHE_CAPS, analyze_paths, analyze_source,
                            apply_baseline, baseline_payload,
                            compute_cache_bounds)
from repro.analysis.engine import load_project

REPO = Path(__file__).resolve().parents[1]


def findings_for(src, path="<string>"):
    return analyze_source(textwrap.dedent(src), path=path)


def hits(findings, rule):
    return [f for f in findings if f.rule == rule]


# ---------------------------------------------------------------------------
# CFN106: PRNG-key discipline
# ---------------------------------------------------------------------------

def test_cfn106_key_consumed_by_two_draws():
    fs = findings_for("""\
        import jax

        def f(key):
            a = jax.random.uniform(key, (4,))
            b = jax.random.normal(key, (4,))
            return a + b
    """)
    got = hits(fs, "CFN106")
    assert got and got[0].line == 5 and "2 draws" in got[0].message


def test_cfn106_branch_exclusive_double_use_still_flagged():
    # path-insensitive by design: nothing ties the branches' streams apart
    fs = findings_for("""\
        import jax

        def f(key, masked):
            if masked:
                u = jax.random.uniform(key, (4,))
            else:
                u = jax.random.normal(key, (4,))
            return u
    """)
    assert hits(fs, "CFN106")


def test_cfn106_split_then_draw_clean():
    fs = findings_for("""\
        import jax

        def f(key):
            k1, k2 = jax.random.split(key)
            a = jax.random.uniform(k1, (4,))
            b = jax.random.normal(k2, (4,))
            return a + b
    """)
    assert not hits(fs, "CFN106")


def test_cfn106_fold_in_two_stream_idiom_clean():
    # fold_in derives an independent stream WITHOUT consuming its argument
    fs = findings_for("""\
        import jax

        def f(key):
            a = jax.random.randint(key, (4,), 0, 10)
            b = jax.random.uniform(jax.random.fold_in(key, 1), (4,))
            return a + b
    """)
    assert not hits(fs, "CFN106")


def test_cfn106_loop_fanout_without_split():
    fs = findings_for("""\
        import jax

        def f(key, xs):
            out = []
            for x in xs:
                out.append(jax.random.uniform(key, (4,)))
            return out
    """)
    got = hits(fs, "CFN106")
    assert got and "loop" in got[0].message


def test_cfn106_loop_carry_split_clean():
    # key, k = split(key): the canonical per-iteration carry
    fs = findings_for("""\
        import jax

        def f(key, xs):
            out = []
            for x in xs:
                key, k = jax.random.split(key)
                out.append(jax.random.uniform(k, (4,)))
            return out
    """)
    assert not hits(fs, "CFN106")


def test_cfn106_dropped_split_output():
    fs = findings_for("""\
        import jax

        def f(key):
            k1, k2 = jax.random.split(key)
            return jax.random.uniform(k1, (4,))
    """)
    got = hits(fs, "CFN106")
    assert got and "`k2`" in got[0].message and "never used" in got[0].message


def test_cfn106_underscore_split_output_clean():
    fs = findings_for("""\
        import jax

        def f(key):
            k1, _k2 = jax.random.split(key)
            return jax.random.uniform(k1, (4,))
    """)
    assert not hits(fs, "CFN106")


def test_cfn106_split_array_index_reuse_flagged_distinct_clean():
    # ks = split(key, 3) is an ARRAY of keys: ks[0] twice is a double
    # draw, ks[0]/ks[1] is clean
    bad = findings_for("""\
        import jax

        def f(key):
            ks = jax.random.split(key, 3)
            a = jax.random.uniform(ks[0], (4,))
            b = jax.random.normal(ks[0], (4,))
            return a + b
    """)
    assert hits(bad, "CFN106")
    clean = findings_for("""\
        import jax

        def f(key):
            ks = jax.random.split(key, 3)
            a = jax.random.uniform(ks[0], (4,))
            b = jax.random.normal(ks[1], (4,))
            return a + b
    """)
    assert not hits(clean, "CFN106")


def test_cfn106_interprocedural_consumption_through_helper():
    # the second consumption happens INSIDE a callee: still two draws
    fs = findings_for("""\
        import jax

        def helper(k):
            return jax.random.normal(k, (4,))

        def f(key):
            a = jax.random.uniform(key, (4,))
            return a + helper(key)
    """)
    assert hits(fs, "CFN106")


# ---------------------------------------------------------------------------
# CFN107: donation & aliasing
# ---------------------------------------------------------------------------

_DONATE = textwrap.dedent("""\
    import jax

    def update(state, x):
        return state + x

    step = jax.jit(update, donate_argnums=(0,))

""")


def test_cfn107_read_after_donation():
    fs = findings_for(_DONATE + textwrap.dedent("""\
        def run(state, x):
            new = step(state, x)
            return state + new
    """))
    got = hits(fs, "CFN107")
    assert got and "donated" in got[0].message


def test_cfn107_rebind_idiom_clean():
    fs = findings_for(_DONATE + textwrap.dedent("""\
        def run(state, x):
            state = step(state, x)
            return state
    """))
    assert not hits(fs, "CFN107")


def test_cfn107_donated_buffer_aliased_in_same_call():
    fs = findings_for(_DONATE + textwrap.dedent("""\
        def run(state):
            return step(state, state)
    """))
    got = hits(fs, "CFN107")
    assert got and "alias" in got[0].message


# ---------------------------------------------------------------------------
# CFN108: compile-cache cardinality
# ---------------------------------------------------------------------------

_ENTRY = textwrap.dedent("""\
    import jax
    import jax.numpy as jnp
    from .solvers import count_traces

    def _pow2(n, lo=2):
        b = lo
        while b < n:
            b *= 2
        return b

    @jax.jit
    @count_traces("kern")
    def kern(x):
        return x * 2

""")


def test_cfn108_unbounded_provenance_reaching_entry():
    fs = findings_for(_ENTRY + textwrap.dedent("""\
        def run():
            import time
            n = time.time()
            return kern(jnp.zeros(int(n)))
    """), path="src/repro/core/mymod.py")
    got = hits(fs, "CFN108")
    assert got and "unbounded" in got[0].message


def test_cfn108_bucketed_shapes_clean():
    fs = findings_for(_ENTRY + textwrap.dedent("""\
        def run(xs):
            return kern(jnp.zeros(_pow2(len(xs))))
    """), path="src/repro/core/mymod.py")
    assert not hits(fs, "CFN108")


def test_cfn108_static_bound_over_cap():
    # three independent pow-2 bucket axes: 8^3 = 512 > the default cap
    fs = findings_for(_ENTRY + textwrap.dedent("""\
        def run(a, b, c):
            x = jnp.zeros((_pow2(a), _pow2(b), _pow2(c)))
            return kern(x)
    """), path="src/repro/core/mymod.py")
    got = hits(fs, "CFN108")
    assert got and "exceeds" in got[0].message


def test_cfn108_shipped_bounds_under_caps():
    """The committed tree's entries all sit under their declared caps."""
    project, errs = load_project([str(REPO / "src")])
    assert not errs
    bounds = compute_cache_bounds(project)
    for entry in ("sweep", "anneal_delta", "anneal_full", "solve_regions"):
        eb = bounds[entry]
        b = eb.static_bound()
        assert b is not None, f"{entry}: unbounded static provenance"
        assert b <= CACHE_CAPS[entry], f"{entry}: {b} > cap"


# ---------------------------------------------------------------------------
# CFN109: dead device compute
# ---------------------------------------------------------------------------

def test_cfn109_dead_device_array():
    fs = findings_for("""\
        import jax.numpy as jnp

        def f(x):
            y = jnp.sum(x * x)
            return x
    """)
    got = hits(fs, "CFN109")
    assert got and "`y`" in got[0].message


def test_cfn109_dead_host_transfer():
    # the PR 7 bug class: np.asarray(device_value) never consumed
    fs = findings_for("""\
        import numpy as np

        def f(state):
            snapshot = np.asarray(state)
            return state
    """)
    assert hits(fs, "CFN109")


def test_cfn109_consumed_and_underscore_clean():
    fs = findings_for("""\
        import jax.numpy as jnp

        def f(x):
            y = jnp.sum(x * x)
            _warm = jnp.ones((4,))
            return y
    """)
    assert not hits(fs, "CFN109")


# ---------------------------------------------------------------------------
# suppression + fingerprint stability
# ---------------------------------------------------------------------------

def test_flow_rule_pragma_right_id_suppresses_wrong_id_does_not():
    src = """\
        import jax

        def f(key):
            a = jax.random.uniform(key, (4,))
            b = jax.random.normal(key, (4,))  # tracelint: allow[CFN106]
            return a + b
    """
    assert not hits(findings_for(src), "CFN106")
    wrong = src.replace("allow[CFN106]", "allow[CFN104]")
    assert hits(findings_for(wrong), "CFN106")


def test_baseline_fingerprint_survives_cross_file_move(tmp_path):
    body = textwrap.dedent("""\
        import jax

        def correlated(key):
            a = jax.random.uniform(key, (4,))
            b = jax.random.normal(key, (4,))
            return a + b
    """)
    (tmp_path / "alpha.py").write_text(body)
    (tmp_path / "beta.py").write_text("import jax\n")
    fs = analyze_paths([str(tmp_path)])
    assert hits(fs, "CFN106")
    baseline = set(json.loads(json.dumps(
        baseline_payload(fs)))["suppressions"])
    # move the function (with extra padding lines) to the OTHER file
    (tmp_path / "alpha.py").write_text("import jax\n")
    (tmp_path / "beta.py").write_text("import jax\n\n\n" + body[len("import jax\n"):])
    moved = analyze_paths([str(tmp_path)])
    assert hits(moved, "CFN106")
    assert apply_baseline(moved, baseline) == []
