"""Online engine tests: service-granular state ops (attach/detach/warm),
incremental re-embedding, per-service power attribution, churn timelines,
and the OnlineEmbedder / scheduler event loop.

The attach/detach yardstick is kernels.ref.placement_objective_f64 -- the
float64 objective whose own error is ~1e-10 -- so tolerances measure the
float32 state math, not reference noise.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import dynamic, power, solvers, topology, vsr
from repro.kernels import ref


@pytest.fixture(scope="module")
def topo():
    return topology.paper_topology()


def _services(n, seed0=100, **kw):
    return [vsr.random_vsrs(1, rng=seed0 + i, source_nodes=[0], **kw)
            for i in range(n)]


def _concat(batches):
    out = batches[0]
    for b in batches[1:]:
        out = out.concat(b)
    return out


# ---------------------------------------------------------------------------
# attach / detach / warm_state vs the float64 oracle
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=8)
@given(seed=st.integers(0, 10_000), r=st.integers(0, 5))
def test_attach_detach_roundtrip_matches_f64_oracle(seed, r):
    """detach(attach) is the identity AND the detached objective equals the
    float64 oracle of the problem without that service."""
    topo = topology.paper_topology()
    vs = vsr.random_vsrs(6, rng=seed, source_nodes=[0])
    prob = power.build_problem(topo, vs)
    rng = np.random.default_rng(seed)
    X = rng.integers(0, prob.P, size=(prob.R, prob.V)).astype(np.int32)
    st0 = power.init_state(prob, jnp.asarray(X))
    Xp = np.asarray(st0.X)

    det = power.detach_vsrs(prob, st0, [r])
    back = power.attach_vsrs(prob, det, [r])
    for name in ("omega", "tm", "theta", "lam"):
        np.testing.assert_allclose(np.asarray(getattr(back, name)),
                                   np.asarray(getattr(st0, name)),
                                   rtol=1e-5, atol=1e-2)
    assert abs(float(back.obj) - float(st0.obj)) <= \
        1e-3 + 1e-6 * abs(float(st0.obj))

    keep = [i for i in range(prob.R) if i != r]
    vs_red = vsr.VSRBatch(F=vs.F[keep], H=vs.H[keep], src=vs.src[keep],
                          input_vm=vs.input_vm[keep])
    prob_red = power.build_problem(topo, vs_red)
    want = ref.placement_objective_f64(prob_red, Xp[keep])
    assert abs(float(det.obj) - want) <= 5e-2 + 1e-5 * abs(want)


def test_attach_with_explicit_rows_equals_init_state(topo):
    """attach_vsrs(X_rows=...) writes the placement and its loads in one
    step: the result matches a from-scratch init_state."""
    vs = vsr.random_vsrs(4, rng=3, source_nodes=[0])
    prob = power.build_problem(topo, vs)
    rng = np.random.default_rng(3)
    X = rng.integers(0, prob.P, size=(prob.R, prob.V)).astype(np.int32)
    st0 = power.init_state(prob, jnp.asarray(X))
    det = power.detach_vsrs(prob, st0, [1])
    new_row = rng.integers(0, prob.P, size=(1, prob.V)).astype(np.int32)
    got = power.attach_vsrs(prob, det, [1], X_rows=new_row)
    X2 = np.asarray(st0.X).copy()
    X2[1] = new_row[0]
    want = power.init_state(prob, jnp.asarray(X2))
    np.testing.assert_array_equal(np.asarray(got.X), np.asarray(want.X))
    assert abs(float(got.obj) - float(want.obj)) <= \
        1e-3 + 1e-6 * abs(float(want.obj))


def test_warm_state_grow_and_shrink(topo):
    """Carrying loads through arrival (grow) and departure (shrink) matches
    a from-scratch state build, including a VM-width change."""
    wide = vsr.random_vsrs(3, rng=0, n_vms=4, source_nodes=[0])
    prob = power.build_problem(topo, wide)
    rng = np.random.default_rng(1)
    X = rng.integers(0, prob.P, size=(prob.R, prob.V)).astype(np.int32)
    st0 = power.init_state(prob, jnp.asarray(X))
    loads = (st0.omega, st0.tm, st0.theta, st0.lam)

    # grow by a NARROWER service (width stays 4, new row padded)
    narrow = vsr.random_vsrs(1, rng=7, n_vms=2, source_nodes=[0])
    grown = wide.concat(narrow)
    prob_g = power.build_problem(topo, grown)
    wg = power.warm_state(prob_g, np.asarray(st0.X), prev_loads=loads)
    fresh = power.init_state(prob_g, wg.X)
    assert abs(float(wg.obj) - float(fresh.obj)) <= \
        1e-3 + 1e-6 * abs(float(fresh.obj))
    # survivors kept their placement
    np.testing.assert_array_equal(np.asarray(wg.X)[:3], np.asarray(st0.X))

    # shrink: drop row 1, carried loads from detach
    det = power.detach_vsrs(prob, st0, [1])
    keep = [0, 2]
    vs_red = vsr.VSRBatch(F=wide.F[keep], H=wide.H[keep], src=wide.src[keep],
                          input_vm=wide.input_vm[keep])
    prob_s = power.build_problem(topo, vs_red)
    ws = power.warm_state(prob_s, np.asarray(st0.X),
                          prev_loads=(det.omega, det.tm, det.theta, det.lam),
                          row_map=keep)
    fresh_s = power.init_state(prob_s, ws.X)
    assert abs(float(ws.obj) - float(fresh_s.obj)) <= \
        1e-3 + 1e-6 * abs(float(fresh_s.obj))
    np.testing.assert_array_equal(np.asarray(ws.X),
                                  np.asarray(st0.X)[keep])


def test_warm_state_rejects_bad_row_map(topo):
    vs = vsr.random_vsrs(2, rng=0, source_nodes=[0])
    prob = power.build_problem(topo, vs)
    with pytest.raises(ValueError):
        power.warm_state(prob, np.zeros((2, 3), np.int32), row_map=[0])


# ---------------------------------------------------------------------------
# per-service power attribution
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=8)
@given(seed=st.integers(0, 10_000))
def test_attribution_sums_to_total(seed):
    topo = topology.paper_topology()
    vs = vsr.random_vsrs(5, rng=seed, source_nodes=[0])
    prob = power.build_problem(topo, vs)
    rng = np.random.default_rng(seed)
    X = rng.integers(0, prob.P, size=(prob.R, prob.V)).astype(np.int32)
    bd = power.evaluate(prob, power.apply_pins(prob, jnp.asarray(X)))
    per = power.attribute_power(prob, X, bd)
    assert per.shape == (prob.R,)
    assert np.all(per >= -1e-9)
    np.testing.assert_allclose(per.sum(), float(bd.total),
                               rtol=1e-5, atol=1e-3)


def test_attribution_isolated_service_pays_its_own_way(topo):
    """Two identical services on disjoint nodes split the total evenly;
    a heavier service is attributed more."""
    vs = vsr.VSRBatch(
        F=np.array([[0.5, 4.0], [0.5, 8.0]], np.float32),
        H=np.zeros((2, 2, 2), np.float32),
        src=np.array([0, 1], np.int32), input_vm=np.zeros(2, np.int32))
    vs.H[:, 0, 1] = 20.0
    prob = power.build_problem(topo, vs)
    cdc = topo.proc_index("cdc0")
    X = np.array([[0, cdc], [1, cdc]], np.int32)
    per = power.attribute_power(prob, X)
    bd = power.evaluate(prob, jnp.asarray(X))
    np.testing.assert_allclose(per.sum(), float(bd.total), rtol=1e-6)
    assert per[1] > per[0]  # heavier stage at the shared CDC pays more


# ---------------------------------------------------------------------------
# incremental re-solve
# ---------------------------------------------------------------------------

def test_resolve_incremental_close_to_portfolio(topo):
    """One arrival on a warm 5-service placement lands within 1% of the
    from-scratch portfolio, keeps pins, and reports a sane history."""
    base = _concat(_services(5))
    prob_b = power.build_problem(topo, base)
    warm = solvers.solve_cfn(prob_b, topo, jax.random.PRNGKey(0))
    grown = base.concat(_services(1, seed0=500)[0])
    prob = power.build_problem(topo, grown)
    st = power.warm_state(prob, warm.X)
    res = solvers.resolve_incremental(prob, np.asarray(st.X),
                                      key=jax.random.PRNGKey(1),
                                      changed_rows=[5], state=st)
    scratch = solvers.solve_cfn(prob, topo, jax.random.PRNGKey(2))
    assert res.objective <= scratch.objective * 1.01
    fixed_mask = np.asarray(prob.fixed_mask)
    np.testing.assert_array_equal(res.X[fixed_mask],
                                  np.asarray(prob.fixed_node)[fixed_mask])
    assert res.method == "incremental"
    assert res.history[-1] <= res.history[0] + 1e-6


def test_resolve_incremental_departure_repacks(topo):
    """changed_rows=[] (a departure): the re-solve never worsens the carried
    placement and stays feasible."""
    vs = _concat(_services(6))
    prob6 = power.build_problem(topo, vs)
    warm = solvers.solve_cfn(prob6, topo, jax.random.PRNGKey(0))
    keep = [0, 1, 3, 4, 5]
    vs_red = vsr.VSRBatch(F=vs.F[keep], H=vs.H[keep], src=vs.src[keep],
                          input_vm=vs.input_vm[keep])
    prob = power.build_problem(topo, vs_red)
    X0 = warm.X[keep]
    start = float(power.objective(prob, jnp.asarray(X0)))
    res = solvers.resolve_incremental(prob, X0, key=jax.random.PRNGKey(1),
                                      changed_rows=[])
    assert res.objective <= start + 1e-6
    assert res.feasible


def test_resolve_incremental_state_only_no_prev_x(topo):
    """Warm callers pass state WITHOUT prev_X: materializing
    ``np.asarray(state.X)`` just to fill an unread argument was a dead
    device->host transfer per churn event (CFN101 hazard class).  The
    state-only call must match the legacy call bit-for-bit."""
    base = _concat(_services(5))
    prob_b = power.build_problem(topo, base)
    warm = solvers.solve_cfn(prob_b, topo, jax.random.PRNGKey(0))
    grown = base.concat(_services(1, seed0=500)[0])
    prob = power.build_problem(topo, grown)
    st = power.warm_state(prob, warm.X)
    kw = dict(changed_rows=[5], key=jax.random.PRNGKey(1))
    res_new = solvers.resolve_incremental(prob, state=st, **kw)
    res_old = solvers.resolve_incremental(prob, np.asarray(st.X), state=st,
                                          **kw)
    np.testing.assert_array_equal(res_new.X, res_old.X)
    assert res_new.objective == res_old.objective
    with pytest.raises(ValueError, match="prev_X or state"):
        solvers.resolve_incremental(prob)


def test_project_eligible_host_side_moved_flag(topo):
    """_project_eligible reports whether projection moved anything as a
    host bool (replacing the old on-device ``(X0 == state.X).all()``
    compare -- a blocking sync per masked churn event).  The flag must be
    exact: False iff the projected array is unchanged."""
    vs = _concat(_services(4))
    prob = power.build_problem(topo, vs)
    st = power.init_state(prob, jnp.zeros((prob.R, prob.V), jnp.int32))
    el = np.ones((prob.R, prob.P), bool)
    proj, moved = solvers._project_eligible(prob, st.X, el)
    assert moved is False
    np.testing.assert_array_equal(np.asarray(proj), np.asarray(st.X))
    # forbid node 0 (where every free VM sits): projection must move them
    el0 = el.copy()
    el0[:, 0] = False
    proj, moved = solvers._project_eligible(prob, st.X, el0)
    assert moved is True
    free = ~np.asarray(prob.fixed_mask)
    rows = np.arange(prob.R)[:, None]
    assert el0[np.broadcast_to(rows, proj.shape)[free],
               np.asarray(proj)[free]].all()
    # the warm masked re-solve path stays inside the mask end-to-end
    res = solvers.resolve_incremental(prob, state=st, eligible=el0,
                                      key=jax.random.PRNGKey(3),
                                      anneal_steps=50, anneal_chains=2)
    assert el0[np.broadcast_to(rows, res.X.shape)[free],
               res.X[free]].all()


# ---------------------------------------------------------------------------
# timelines
# ---------------------------------------------------------------------------

def test_diurnal_rate_profile():
    r = dynamic.diurnal_rate(np.arange(0.0, 48.0, 0.5), 1.0, 5.0,
                             peak_hour=20.0)
    assert r.min() >= 1.0 - 1e-9 and r.max() <= 5.0 + 1e-9
    assert abs(float(dynamic.diurnal_rate(20.0, 1.0, 5.0, 20.0)) - 5.0) < 1e-9
    assert abs(float(dynamic.diurnal_rate(8.0, 1.0, 5.0, 20.0)) - 1.0) < 1e-9
    # 24h periodic
    np.testing.assert_allclose(r[:48], r[48:96], rtol=1e-12)


def test_poisson_timeline_well_formed():
    ev = dynamic.poisson_timeline(24.0, lambda t: 3.0, 2.0, rng=0)
    assert len(ev) > 10
    ts = [e.t for e in ev]
    assert ts == sorted(ts)
    seen = {}
    for e in ev:
        if e.kind == "arrive":
            assert e.sid not in seen
            seen[e.sid] = e.t
        else:
            assert e.sid in seen and e.t >= seen[e.sid]
    # deterministic under the same seed
    ev2 = dynamic.poisson_timeline(24.0, lambda t: 3.0, 2.0, rng=0)
    assert ev == ev2


def test_churn_trace_single_event_granularity():
    ev = dynamic.churn_trace(4, 6, rng=0)
    assert len(ev) == 10
    live = set()
    for e in ev[:4]:
        assert e.kind == "arrive"
        live.add(e.sid)
    for e in ev[4:]:
        if e.kind == "depart":
            assert e.sid in live
            live.discard(e.sid)
        else:
            live.add(e.sid)
    assert len(live) == 4  # alternating events preserve steady state


def test_scenario_presets_sample():
    for name, sc in dynamic.SCENARIOS.items():
        v = sc.sample_vsr(0)
        assert v.R == 1 and v.V == sc.n_vms
        assert callable(sc.rate_fn())


# ---------------------------------------------------------------------------
# the online engine
# ---------------------------------------------------------------------------

def test_online_embedder_event_loop(topo):
    """bootstrap -> add -> remove: state stays consistent with a fresh
    evaluation, per-service watts sum to the fleet total, and the final
    objective is within 2% of a from-scratch portfolio solve."""
    eng = dynamic.OnlineEmbedder(topo, defrag_every=0,
                                 key=jax.random.PRNGKey(0))
    svcs = _services(4)
    eng.bootstrap(svcs)
    assert eng.n_live == 4 and eng.result.method.startswith("cfn-milp")

    eng.add(_services(1, seed0=900)[0])
    assert eng.n_live == 5
    assert eng.result.method == "incremental"
    # engine state agrees with a fresh evaluation of its placement
    fresh = power.init_state(eng.problem, jnp.asarray(eng.X))
    assert abs(eng.objective() - float(fresh.obj)) <= \
        1e-3 + 1e-6 * abs(float(fresh.obj))

    per = eng.per_service_power_w()
    assert set(per) == set(eng.sids)
    np.testing.assert_allclose(sum(per.values()), eng.power_w(),
                               rtol=1e-5, atol=1e-3)

    eng.remove(eng.sids[1])
    assert eng.n_live == 4
    # local re-pack stays in the ballpark; defrag() never regresses (small
    # instances leave the most on the table for a purely local re-solve)
    scratch = solvers.solve_cfn(eng.problem, topo, jax.random.PRNGKey(9))
    assert eng.objective() <= scratch.objective * 1.10
    before = eng.objective()
    eng.defrag()
    assert eng.objective() <= before + 1e-6

    # events were recorded
    kinds = [s.event for s in eng.stats]
    assert kinds == ["bootstrap", "add", "remove", "defrag"]


@pytest.mark.slow
def test_online_embedder_defrag_and_drain(topo):
    eng = dynamic.OnlineEmbedder(topo, defrag_every=2,
                                 key=jax.random.PRNGKey(1))
    s = _services(3, seed0=300)
    eng.add(s[0])                     # first event: full solve
    eng.add(s[1])                     # incremental
    eng.add(s[2])                     # 2 events since defrag -> full again
    assert eng.stats[-1].method.startswith(("cfn-milp", "defrag-kept"))
    eng.remove(eng.sids[0])
    eng.remove(eng.sids[0])
    last = eng.remove(eng.sids[0])    # drains the engine
    assert last is None and eng.n_live == 0 and eng.power_w() == 0.0
    # engine is reusable after draining
    eng.add(s[0])
    assert eng.n_live == 1 and eng.objective() > 0


def test_replay_skips_unmaterialized_departures(topo):
    sc = dynamic.SCENARIOS["steady"]
    events = [dynamic.ServiceEvent(0.0, "arrive", 0),
              dynamic.ServiceEvent(0.5, "depart", 99),   # never arrived
              dynamic.ServiceEvent(1.0, "arrive", 1),
              dynamic.ServiceEvent(2.0, "depart", 0)]
    eng = dynamic.OnlineEmbedder(topo, defrag_every=0)
    stats = dynamic.replay(eng, events, lambda sid: sc.sample_vsr(sid))
    assert eng.n_live == 1
    assert [s.event for s in stats] == ["add", "add", "remove"]


def test_online_embedder_rejects_bad_inputs(topo):
    sc = dynamic.SCENARIOS["steady"]
    eng = dynamic.OnlineEmbedder(topo, defrag_every=0)
    with pytest.raises(ValueError):
        dynamic.OnlineEmbedder(topo, method="nope")
    with pytest.raises(ValueError):
        eng.bootstrap([])
    with pytest.raises(ValueError):
        eng.bootstrap([sc.sample_vsr(0)], sids=[1, 2])
    eng.add(sc.sample_vsr(0), sid=5)
    with pytest.raises(ValueError):      # sid already live
        eng.add(sc.sample_vsr(1), sid=5)
    assert eng.sids == [5]               # rejected before any mutation
    eng.add(sc.sample_vsr(1), sid=6)
    assert eng.sids == [5, 6]


def test_replay_departs_bootstrapped_services(topo):
    """Departures of services admitted via bootstrap() (not by this replay)
    must still be executed."""
    sc = dynamic.SCENARIOS["steady"]
    eng = dynamic.OnlineEmbedder(topo, defrag_every=0)
    eng.bootstrap([sc.sample_vsr(0), sc.sample_vsr(1)], sids=[10, 11])
    events = [dynamic.ServiceEvent(1.0, "depart", 10),
              dynamic.ServiceEvent(2.0, "arrive", 12)]
    dynamic.replay(eng, events, lambda sid: sc.sample_vsr(sid))
    assert eng.n_live == 2 and set(eng.sids) == {11, 12}
