"""PRNG stream-discipline regression tests (the CFN106 fixes).

The masked (eligibility-constrained) branches of ``_anneal_proposals``,
``anneal``, ``genetic`` and ``resolve_incremental`` draw from
``fold_in``-derived streams instead of re-consuming the sibling key.
These tests pin the contract: the unmasked streams are byte-identical
whether or not a mask is in play, proposal and acceptance streams are
statistically independent, and the seeded solvers stay deterministic.
"""
import jax
import numpy as np
import pytest

from repro.core import power, solvers, topology, vsr


@pytest.fixture(scope="module")
def topo():
    return topology.paper_topology()


@pytest.fixture(scope="module")
def setup(topo):
    vs = vsr.random_vsrs(4, rng=0, source_nodes=topo.layer_indices("iot")[:2])
    problem = power.build_problem(topo, vs)
    return problem, power.build_aux(problem)


def _mask(problem):
    el = np.ones((problem.R, problem.P), bool)
    el[:, ::3] = False          # knock out every third node
    return el


def test_masked_branch_leaves_sibling_streams_byte_identical(setup):
    """fold_in derivation: adding a mask must not perturb the flat-index
    or acceptance streams (they come from kf/ka, which the masked
    destination branch no longer touches)."""
    problem, aux = setup
    key = jax.random.PRNGKey(11)
    _, cnt, cand = solvers._eligible_np(_mask(problem))
    fi_u, p_u, u_u = solvers._anneal_proposals(key, aux, 64, 4, problem.P)
    fi_m, p_m, u_m = solvers._anneal_proposals(key, aux, 64, 4, problem.P,
                                               V=problem.V, cnt=cnt,
                                               cand=cand)
    np.testing.assert_array_equal(np.asarray(fi_u), np.asarray(fi_m))
    np.testing.assert_array_equal(np.asarray(u_u), np.asarray(u_m))
    # masked destinations all land on eligible nodes
    assert bool(np.asarray(cnt).min()) >= 0
    rows = np.asarray(aux.free_flat)[np.asarray(fi_m)] // problem.V
    el = _mask(problem)
    assert el[rows.ravel(), np.asarray(p_m).ravel()].all()


def test_proposal_and_acceptance_streams_independent(setup):
    """The acceptance uniforms must be statistically independent of the
    destination stream (the paper's Metropolis correctness condition --
    the original double-consumption correlated them)."""
    problem, aux = setup
    key = jax.random.PRNGKey(3)
    _, cnt, cand = solvers._eligible_np(_mask(problem))
    _, p_prop, u = solvers._anneal_proposals(key, aux, 2000, 8, problem.P,
                                             V=problem.V, cnt=cnt, cand=cand)
    a = np.asarray(p_prop, np.float64).ravel()
    b = np.asarray(u, np.float64).ravel()
    r = np.corrcoef(a, b)[0, 1]
    assert abs(r) < 0.03, f"proposal/acceptance correlation {r:.4f}"
    # and the destination stream is NOT the acceptance stream in disguise
    assert not np.array_equal(a % 1.0, b)


def test_anneal_deterministic_and_mask_respected(setup):
    problem, _ = setup
    X0 = solvers.fixed_layer(problem, topology.paper_topology(), "iot").X
    el = _mask(problem)
    key = jax.random.PRNGKey(5)
    r1 = solvers.anneal(problem, key, X0, n_chains=4, n_steps=50,
                        backend="delta", eligible=el)
    r2 = solvers.anneal(problem, key, X0, n_chains=4, n_steps=50,
                        backend="delta", eligible=el)
    np.testing.assert_array_equal(np.asarray(r1.X), np.asarray(r2.X))
    free = ~np.asarray(problem.fixed_mask)
    rows, vms = np.where(free)
    assert el[rows, np.asarray(r1.X)[rows, vms]].all()


def test_genetic_deterministic_for_fixed_seed(setup):
    problem, _ = setup
    X0 = solvers.fixed_layer(problem, topology.paper_topology(), "iot").X
    key = jax.random.PRNGKey(9)
    r1 = solvers.genetic(problem, key, X0, pop=8, gens=3,
                         eligible=_mask(problem))
    r2 = solvers.genetic(problem, key, X0, pop=8, gens=3,
                         eligible=_mask(problem))
    np.testing.assert_array_equal(np.asarray(r1.X), np.asarray(r2.X))


def test_resolve_incremental_and_wave_deterministic(setup):
    problem, _ = setup
    X0 = solvers.fixed_layer(problem, topology.paper_topology(), "iot").X
    key = jax.random.PRNGKey(2)
    kw = dict(changed_rows=[0, 1], anneal_steps=40, anneal_chains=4,
              eligible=_mask(problem))
    r1 = solvers.resolve_incremental(problem, prev_X=X0, key=key, **kw)
    r2 = solvers.resolve_incremental(problem, prev_X=X0, key=key, **kw)
    np.testing.assert_array_equal(np.asarray(r1.X), np.asarray(r2.X))
    st = power.init_state(problem, np.asarray(X0, np.int32))
    w1 = solvers.resolve_wave(problem, st, [0, 1], key=key, anneal_steps=40,
                              anneal_chains=4)
    w2 = solvers.resolve_wave(problem, st, [0, 1], key=key, anneal_steps=40,
                              anneal_chains=4)
    np.testing.assert_array_equal(np.asarray(w1.X), np.asarray(w2.X))
