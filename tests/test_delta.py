"""Delta-evaluation engine tests: exactness of single-VM move deltas, state
consistency over random move sequences (including beta/phi indicator flips),
the vectorized destination sweep, and the fused Pallas annealing kernel.

The yardstick is kernels.ref.placement_delta_ref -- a float64 objective
difference whose own error is ~1e-10 -- so the asserted tolerance measures
the engine's float32 delta math, not reference cancellation noise.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import power, solvers, topology, vsr
from repro.kernels import ops, ref


@pytest.fixture(scope="module")
def topo():
    return topology.paper_topology()


def _problem(topo, n_vsrs=10, seed=0, **kw):
    vs = vsr.random_vsrs(n_vsrs, rng=seed, source_nodes=[0], **kw)
    return power.build_problem(topo, vs)


def _random_moves(prob, aux, rng, n):
    free = np.asarray(aux.free_pos)
    for _ in range(n):
        r, v = free[rng.integers(0, len(free))]
        yield int(r), int(v), int(rng.integers(0, prob.P))


def test_delta_move_exact_feasible_sequence(topo):
    """Paper scale (R=10), feasible-leaning workload: every delta along a
    random 150-move sequence matches the float64 oracle to <= 1e-3."""
    prob = _problem(topo, vm_gflops=(0.5, 2.0))
    aux = power.build_aux(prob)
    rng = np.random.default_rng(1)
    st = power.init_state(prob, solvers.fixed_layer(prob, topo, "iot").X)
    for r, v, p_new in _random_moves(prob, aux, rng, 150):
        got = float(power.delta_move(prob, aux, st, r, v, p_new))
        want = ref.placement_delta_ref(prob, np.asarray(st.X), r, v, p_new)
        assert abs(got - want) <= 1e-3, (r, v, p_new, got, want)
        st = power.apply_move(prob, aux, st, r, v, p_new)


def test_delta_move_exact_violated_sequence(topo):
    """Heavy workload (capacity violations active, PENALTY-scaled terms):
    deltas stay exact to float32 resolution of the violation magnitudes."""
    prob = _problem(topo)
    aux = power.build_aux(prob)
    rng = np.random.default_rng(0)
    X = rng.integers(0, prob.P, size=(prob.R, prob.V)).astype(np.int32)
    st = power.init_state(prob, jnp.asarray(X))
    for r, v, p_new in _random_moves(prob, aux, rng, 150):
        got = float(power.delta_move(prob, aux, st, r, v, p_new))
        want = ref.placement_delta_ref(prob, np.asarray(st.X), r, v, p_new)
        # floor: PENALTY * ulp(float32 load entries) ~= 1e4 * 6e-8 * 60
        # GFLOPS ~= 4e-2, independent of the objective's size -- fp32
        # resolution of the relu'd capacity terms, not engine error
        assert abs(got - want) <= 5e-2, (r, v, p_new, got, want)
        st = power.apply_move(prob, aux, st, r, v, p_new)


def test_state_consistent_with_full_evaluate(topo):
    """After a random move sequence every live tensor (omega, tm, theta,
    lam) and the cached objective agree with a from-scratch evaluation."""
    prob = _problem(topo)
    aux = power.build_aux(prob)
    rng = np.random.default_rng(2)
    X = rng.integers(0, prob.P, size=(prob.R, prob.V)).astype(np.int32)
    st = power.init_state(prob, jnp.asarray(X))
    for r, v, p_new in _random_moves(prob, aux, rng, 300):
        st = power.apply_move(prob, aux, st, r, v, p_new)
    fresh = power.init_state(prob, st.X)
    np.testing.assert_allclose(np.asarray(st.omega), np.asarray(fresh.omega),
                               rtol=1e-5, atol=1e-3)
    np.testing.assert_allclose(np.asarray(st.tm), np.asarray(fresh.tm),
                               rtol=1e-5, atol=1e-2)
    np.testing.assert_allclose(np.asarray(st.theta), np.asarray(fresh.theta),
                               rtol=1e-5, atol=1e-2)
    np.testing.assert_allclose(np.asarray(st.lam), np.asarray(fresh.lam),
                               rtol=1e-5, atol=1e-2)
    assert abs(float(st.obj) - float(fresh.obj)) <= \
        1e-3 + 1e-6 * abs(float(fresh.obj))
    bd = power.evaluate(prob, st.X)
    np.testing.assert_allclose(float(fresh.obj), float(bd.objective),
                               rtol=1e-6)


def test_delta_indicator_flips(topo):
    """Moves onto an empty node (phi 0->1) and off it again (1->0), plus a
    beta flip when the last traffic leaves a route, are exact."""
    vs = vsr.VSRBatch(
        F=np.array([[0.5, 8.0]], np.float32),
        H=np.zeros((1, 2, 2), np.float32),
        src=np.array([0], np.int32), input_vm=np.array([0], np.int32))
    vs.H[0, 0, 1] = 20.0
    prob = power.build_problem(topo, vs)
    aux = power.build_aux(prob)
    cdc = topo.proc_index("cdc0")
    st = power.init_state(prob, jnp.asarray([[0, cdc]], jnp.int32))
    # cdc -> empty iot node 5: phi flips ON at 5, OFF at cdc; the metro/core
    # route empties so several beta_n flip OFF
    for p_new in (5, cdc, 0, 7, cdc):
        want = ref.placement_delta_ref(prob, np.asarray(st.X), 0, 1, p_new)
        got = float(power.delta_move(prob, aux, st, 0, 1, p_new))
        assert abs(got - want) <= 1e-3, (p_new, got, want)
        st = power.apply_move(prob, aux, st, 0, 1, p_new)
        fresh = power.init_state(prob, st.X)
        assert abs(float(st.obj) - float(fresh.obj)) <= 1e-3
        # moving the only traffic-bearing VM around must keep lam exact
        np.testing.assert_allclose(np.asarray(st.lam),
                                   np.asarray(fresh.lam), atol=1e-3)


def test_delta_sweep_matches_objective_batch(topo):
    """delta_sweep == objective_batch over the P broadcast candidates."""
    prob = _problem(topo)
    aux = power.build_aux(prob)
    rng = np.random.default_rng(3)
    X = rng.integers(0, prob.P, size=(prob.R, prob.V)).astype(np.int32)
    st = power.init_state(prob, jnp.asarray(X))
    free = np.asarray(aux.free_pos)
    for (r, v) in free[rng.permutation(len(free))[:6]]:
        got = power.delta_sweep(prob, aux, st, int(r), int(v))
        cand = np.broadcast_to(np.asarray(st.X),
                               (prob.P,) + st.X.shape).copy()
        cand[:, r, v] = np.arange(prob.P)
        want = power.objective_batch(prob, jnp.asarray(cand))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=1e-2)


def test_anneal_delta_matches_full_backend(topo):
    """Identical proposal stream -> the incremental and the legacy
    full-objective backends accept the same moves and land on the same
    placement."""
    prob = _problem(topo, n_vsrs=5)
    rng = np.random.default_rng(0)
    X0 = rng.integers(0, prob.P, size=(prob.R, prob.V)).astype(np.int32)
    key = jax.random.PRNGKey(3)
    r_delta = solvers.anneal(prob, key, X0, n_chains=16, n_steps=400)
    r_full = solvers.anneal(prob, key, X0, n_chains=16, n_steps=400,
                            backend="full")
    np.testing.assert_array_equal(r_delta.X, r_full.X)


def test_fused_anneal_kernel(topo):
    """The fused Pallas kernel (interpret mode on CPU) matches the pure-JAX
    incremental backend on the same proposals, and its reported best
    objective is consistent with a full re-evaluation of its best X."""
    prob = _problem(topo, n_vsrs=5)
    aux = power.build_aux(prob)
    rng = np.random.default_rng(1)
    C, S = 6, 250
    X0 = jnp.asarray(rng.integers(0, prob.P, size=(C, prob.R, prob.V)),
                     jnp.int32)
    Xc = jax.vmap(lambda x: power.apply_pins(prob, x))(X0)
    key = jax.random.PRNGKey(7)
    fi, p_prop, u_prop = solvers._anneal_proposals(key, aux, S, C, prob.P)
    j_prop = aux.free_flat[fi]
    temps = jnp.asarray(50.0 * (0.05 / 50.0) ** (np.arange(S) / (S - 1)),
                        jnp.float32)
    bX, stats = ops.fused_anneal(prob, aux, Xc, j_prop.T, p_prop.T,
                                 u_prop.T, temps)
    # self-consistency: reported best == exact objective of best X
    exact = np.array([float(power.objective(prob, bX[c])) for c in range(C)])
    np.testing.assert_allclose(np.asarray(stats[:, 0]), exact,
                               rtol=1e-5, atol=5e-2)
    # agreement with the pure-JAX incremental scan
    bX2, bobj2, _ = solvers._anneal_scan_delta(prob, aux, Xc, j_prop,
                                               p_prop, u_prop, temps)
    assert abs(float(stats[:, 0].min()) - float(bobj2)) <= 5e-2


def test_fused_anneal_chain_padding(topo):
    """Chain counts that don't divide the block size are padded and the
    padding is dropped."""
    prob = _problem(topo, n_vsrs=3)
    aux = power.build_aux(prob)
    rng = np.random.default_rng(2)
    C, S = 5, 60
    Xc = jax.vmap(lambda x: power.apply_pins(prob, x))(
        jnp.asarray(rng.integers(0, prob.P, size=(C, prob.R, prob.V)),
                    jnp.int32))
    key = jax.random.PRNGKey(11)
    fi, p_prop, u_prop = solvers._anneal_proposals(key, aux, S, C, prob.P)
    temps = jnp.full((S,), 1.0, jnp.float32)
    bX, stats = ops.fused_anneal(prob, aux, Xc, aux.free_flat[fi].T,
                                 p_prop.T, u_prop.T, temps)
    assert bX.shape == (C, prob.R, prob.V)
    assert stats.shape == (C, 2)
    exact = np.array([float(power.objective(prob, bX[c])) for c in range(C)])
    np.testing.assert_allclose(np.asarray(stats[:, 0]), exact,
                               rtol=1e-5, atol=5e-2)


def test_anneal_only_moves_free_positions(topo):
    """Pinned input VMs are never proposed: every chain keeps them at the
    source node throughout (checked via the returned placement)."""
    prob = _problem(topo, n_vsrs=4)
    rng = np.random.default_rng(0)
    X0 = rng.integers(0, prob.P, size=(prob.R, prob.V)).astype(np.int32)
    res = solvers.anneal(prob, jax.random.PRNGKey(0), X0, n_chains=8,
                         n_steps=200)
    fixed_mask = np.asarray(prob.fixed_mask)
    fixed_node = np.asarray(prob.fixed_node)
    np.testing.assert_array_equal(res.X[fixed_mask], fixed_node[fixed_mask])


def test_dense_route_cache_gate(topo):
    """The guarded [P*P, N] route-row cache exists exactly on small
    substrates (P <= power.DENSE_ROUTE_MAX_P) and never above the gate."""
    prob = _problem(topo)
    assert prob.P <= power.DENSE_ROUTE_MAX_P
    assert prob.route_dense is not None
    assert prob.route_dense.shape == (prob.P * prob.P, prob.N)
    big = topology.city_scale(n_olt=4, onus_per_olt=4, iot_per_onu=4)
    assert big.P > power.DENSE_ROUTE_MAX_P
    vs = vsr.random_vsrs(2, rng=0, source_nodes=[0])
    assert power.build_problem(big, vs).route_dense is None


def test_dense_route_cache_delta_parity(topo):
    """With the dense cache on (paper scale), delta_move / apply_move match
    BOTH the cache-off CSR path and the float64 oracle along a random move
    sequence -- the cache is a pure gather-level substitution."""
    import dataclasses
    prob = _problem(topo, vm_gflops=(0.5, 2.0))
    prob_nc = dataclasses.replace(prob, route_dense=None)
    aux = power.build_aux(prob)
    rng = np.random.default_rng(7)
    X0 = solvers.fixed_layer(prob, topo, "iot").X
    st = power.init_state(prob, X0)
    st_nc = power.init_state(prob_nc, X0)
    for r, v, p_new in _random_moves(prob, aux, rng, 60):
        got = float(power.delta_move(prob, aux, st, r, v, p_new))
        got_nc = float(power.delta_move(prob_nc, aux, st_nc, r, v, p_new))
        want = ref.placement_delta_ref(prob, np.asarray(st.X), r, v, p_new)
        assert abs(got - want) <= 1e-3, (r, v, p_new, got, want)
        assert abs(got - got_nc) <= 1e-3, (r, v, p_new, got, got_nc)
        st = power.apply_move(prob, aux, st, r, v, p_new)
        st_nc = power.apply_move(prob_nc, aux, st_nc, r, v, p_new)
        np.testing.assert_allclose(np.asarray(st.lam),
                                   np.asarray(st_nc.lam),
                                   rtol=1e-5, atol=1e-2)
    # full evaluation through _lam_from_links agrees across the gate too
    obj = float(power.objective(prob, st.X))
    obj_nc = float(power.objective(prob_nc, st.X))
    want = kref_obj = ref.placement_objective_f64(prob, np.asarray(st.X))
    assert abs(obj - obj_nc) <= 1e-3 + 1e-6 * abs(obj_nc)
    assert abs(obj - kref_obj) <= 1e-2 + 1e-5 * abs(want)


def test_coordinate_on_delta_engine_still_descends(topo):
    prob = _problem(topo, n_vsrs=6, seed=5)
    cdc = topo.layer_indices("cdc")[0]
    X0 = np.full((prob.R, prob.V), cdc, dtype=np.int32)
    res = solvers.coordinate(prob, X0)
    hist = res.history
    assert all(hist[i + 1] <= hist[i] + 1e-6 for i in range(len(hist) - 1))
    # the returned incumbent matches its reported objective
    assert abs(res.objective - hist[-1]) <= 1e-3 + 1e-6 * abs(hist[-1])
