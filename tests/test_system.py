"""End-to-end behaviour tests: train CLI learns, serve path generates,
scheduler reproduces the paper's qualitative findings, VSR bridge sanity."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import embed, topology, vsr
from repro.launch import train as train_cli
from repro.models import costs
from repro.models import model as M
from repro.serve import cache as C
from repro.serve import engine
from repro.serve.scheduler import EnergyAwareScheduler, Service


def test_train_cli_improves_loss(capsys):
    rc = train_cli.main(["--arch", "qwen3-4b", "--steps", "12",
                         "--batch", "4", "--seq", "32", "--lr", "5e-3"])
    assert rc == 0
    out = capsys.readouterr().out
    summary = json.loads(out.strip().splitlines()[-1])
    assert summary["improved"] is True


def test_generate_roundtrip():
    cfg = configs.get_smoke("h2o-danube-3-4b")
    params, _ = M.init_model(cfg, jax.random.PRNGKey(0))
    B, S, G = 2, 12, 6
    batch = {"tokens": jnp.arange(B * S, dtype=jnp.int32).reshape(B, S)
             % cfg.vocab}
    cache = C.zeros(C.cache_spec(cfg, B, S + G + 4))
    seq, _ = engine.greedy_generate(params, cfg, batch, cache, G)
    assert seq.shape == (B, G)
    assert bool((seq >= 0).all()) and bool((seq < cfg.vocab).all())


def test_scheduler_places_and_saves_energy():
    topo = topology.datacenter_topology()
    sched = EnergyAwareScheduler(topo)
    sched.add_service(Service("qwen", configs.get("qwen3-4b"), 500.0))
    sched.add_service(Service("olmoe", configs.get("olmoe-1b-7b"), 500.0))
    placements = sched.solve()
    assert len(placements) == 2
    for p in placements:
        assert len(p.stage_nodes) == 5     # input VM + 4 stages
    s = sched.savings_vs_cloud()
    assert s["saving_frac"] > 0.0


def test_vsr_bridge_matches_cost_model():
    cfg = configs.get("olmoe-1b-7b")
    vs = vsr.from_architecture(cfg, tokens_per_s=100.0, n_stages=4)
    gflops, _ = costs.layer_costs(cfg)
    total_gflops = float(np.sum(vs.F))
    expected = (sum(gflops) + 2.0 * cfg.d_model / 1e9) * 100.0
    assert abs(total_gflops - expected) / expected < 1e-3
    # one input VM pinned at the source
    assert vs.input_vm[0] == 0 and vs.src[0] == 0


def test_paper_band_savings_sweep():
    """Savings across small VSR sweeps stay inside the paper's band
    (avg 68%, min 19%, max 91% -- we assert a tolerant envelope; the full
    reproduction with stats lives in benchmarks/)."""
    topo = topology.paper_topology()
    fracs = []
    for n in (1, 4, 8):
        vs = vsr.random_vsrs(n, rng=n, source_nodes=[0])
        out = embed.savings_vs_baseline(topo, vs, method="cfn-milp")
        fracs.append(out["saving_frac"])
    assert min(fracs) > 0.10
    assert max(fracs) < 0.97
