"""End-to-end behaviour tests: train CLI learns, serve path generates,
scheduler reproduces the paper's qualitative findings, VSR bridge sanity."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import embed, topology, vsr
from repro.launch import train as train_cli
from repro.models import costs
from repro.models import model as M
from repro.serve import cache as C
from repro.serve import engine
from repro.serve.scheduler import EnergyAwareScheduler, Service


@pytest.mark.slow
def test_train_cli_improves_loss(capsys):
    rc = train_cli.main(["--arch", "qwen3-4b", "--steps", "12",
                         "--batch", "4", "--seq", "32", "--lr", "5e-3"])
    assert rc == 0
    out = capsys.readouterr().out
    summary = json.loads(out.strip().splitlines()[-1])
    assert summary["improved"] is True


def test_generate_roundtrip():
    cfg = configs.get_smoke("h2o-danube-3-4b")
    params, _ = M.init_model(cfg, jax.random.PRNGKey(0))
    B, S, G = 2, 12, 6
    batch = {"tokens": jnp.arange(B * S, dtype=jnp.int32).reshape(B, S)
             % cfg.vocab}
    cache = C.zeros(C.cache_spec(cfg, B, S + G + 4))
    seq, _ = engine.greedy_generate(params, cfg, batch, cache, G)
    assert seq.shape == (B, G)
    assert bool((seq >= 0).all()) and bool((seq < cfg.vocab).all())


@pytest.mark.slow
def test_scheduler_places_and_saves_energy():
    topo = topology.datacenter_topology()
    sched = EnergyAwareScheduler(topo)
    sched.add_service(Service("qwen", configs.get("qwen3-4b"), 500.0))
    sched.add_service(Service("olmoe", configs.get("olmoe-1b-7b"), 500.0))
    placements = sched.solve()
    assert len(placements) == 2
    for p in placements:
        assert len(p.stage_nodes) == 5     # input VM + 4 stages
    # per-service attribution sums to the fleet total (not total / R)
    total = sched.total_power_w()
    assert abs(sum(p.power_w for p in placements) - total) <= \
        1e-5 * max(total, 1.0) + 1e-3
    s = sched.savings_vs_cloud()
    assert s["saving_frac"] > 0.0


@pytest.mark.slow
def test_scheduler_online_churn():
    """remove_service is a first-class churn event: placements shrink,
    attribution re-sums, and re-adding keeps the engine consistent."""
    topo = topology.datacenter_topology()
    sched = EnergyAwareScheduler(topo, defrag_every=0)
    sched.add_service(Service("qwen", configs.get("qwen3-4b"), 500.0))
    sched.add_service(Service("olmoe", configs.get("olmoe-1b-7b"), 500.0))
    p_two = sched.total_power_w()
    placements = sched.remove_service("qwen")
    assert [p.service for p in placements] == ["olmoe"]
    assert sched.total_power_w() < p_two
    with pytest.raises(ValueError):      # names key the removal API
        sched.add_service(Service("olmoe", configs.get("olmoe-1b-7b"), 1.0))
    with pytest.raises(KeyError):
        sched.remove_service("nonexistent")
    placements = sched.add_service(
        Service("hymba", configs.get("hymba-1.5b"), 250.0, n_stages=3))
    assert {p.service for p in placements} == {"olmoe", "hymba"}
    by_name = {p.service: p for p in placements}
    assert len(by_name["hymba"].stage_nodes) == 4   # input VM + 3 stages
    total = sched.total_power_w()
    assert abs(sum(p.power_w for p in placements) - total) <= \
        1e-5 * max(total, 1.0) + 1e-3


def test_vsr_bridge_matches_cost_model():
    cfg = configs.get("olmoe-1b-7b")
    vs = vsr.from_architecture(cfg, tokens_per_s=100.0, n_stages=4)
    gflops, _ = costs.layer_costs(cfg)
    total_gflops = float(np.sum(vs.F))
    expected = (sum(gflops) + 2.0 * cfg.d_model / 1e9) * 100.0
    assert abs(total_gflops - expected) / expected < 1e-3
    # one input VM pinned at the source
    assert vs.input_vm[0] == 0 and vs.src[0] == 0


@pytest.mark.slow
def test_paper_band_savings_sweep():
    """Savings across small VSR sweeps stay inside the paper's band
    (avg 68%, min 19%, max 91% -- we assert a tolerant envelope; the full
    reproduction with stats lives in benchmarks/)."""
    topo = topology.paper_topology()
    fracs = []
    for n in (1, 4, 8):
        vs = vsr.random_vsrs(n, rng=n, source_nodes=[0])
        out = embed.savings_vs_baseline(topo, vs, method="cfn-milp")
        fracs.append(out["saving_frac"])
    assert min(fracs) > 0.10
    assert max(fracs) < 0.97
