"""Fault-plane tests (the failure-storms PR).

Covers: SubstrateHealth degrade/eligibility (shape-preserving, value-only),
PlacementSpec.health threading through masks and the pytree protocol, the
closed fail -> mass re-embed -> recover loop on the online engine (objective
matching the float64 oracle on BOTH the degraded and the recovered
substrate), the never-silently-dropped guarantee for stranded services,
compile-count stability across same-bucket fail/recover events, link
failures rerouting traffic off the cut, brownouts through the admission
path, fault timelines/presets merged with churn, the availability integral
and monitor reset/merge roll-up, heartbeat deregistration, straggler-history
reset, and federated region evacuation with exact conservation on the
surviving substrate.
"""
import numpy as np
import pytest

from _hyp import given, settings, st
from repro.api import (CFNSession, FederatedSession, PlacementSpec,
                       SubstrateHealth)
from repro.core import dynamic, power, solvers, topology, vsr
from repro.fault.monitor import (HeartbeatMonitor, PlacementMonitor,
                                 StragglerTracker)
from repro.kernels import ref as kref


def _topo():
    return topology.city_scale(n_olt=2, onus_per_olt=2, iot_per_onu=2)


@pytest.fixture(scope="module")
def topo():
    return _topo()


def _quick_spec(**kw):
    return PlacementSpec(effort="quick", anneal_steps=0, defrag_every=0,
                         **kw)


def _services(topo, n, seed0=0, n_vms=3):
    iot = topo.layer_indices("iot")
    return [vsr.random_vsrs(1, rng=np.random.default_rng(seed0 + i),
                            n_vms=n_vms, source_nodes=iot[:4])
            for i in range(n)]


def _session(topo, n=5, seed0=0, **spec_kw):
    mon = PlacementMonitor()
    s = CFNSession(topo, _quick_spec(**spec_kw), monitor=mon)
    svcs = _services(topo, n, seed0=seed0)
    for i, sv in enumerate(svcs):
        assert s.add(sv, sid=i) is not None
    return s, svcs, mon


def _hosting_non_source(s, svcs):
    """A node hosting at least one live VM that is no service's source."""
    srcs = {int(sv.src[0]) for sv in svcs}
    X = s.X
    for r in range(s.n_live):
        for x in X[r, :s.engine._vsrs[r].V]:
            if int(x) not in srcs:
                return int(x)
    return None


def _oracle_gap(problem, X, objective):
    oracle = kref.placement_objective_f64(problem, X)
    return abs(oracle - objective), oracle


# ---------------------------------------------------------------------------
# SubstrateHealth: degrade + eligibility
# ---------------------------------------------------------------------------

def test_health_degrade_shapes_and_values(topo):
    h = SubstrateHealth.fresh(topo)
    assert h.all_up
    svcs = _services(topo, 3)
    b = svcs[0]
    for sv in svcs[1:]:
        b = b.concat(sv)
    prob = power.build_problem(topo, b)
    assert h.degrade(prob) is prob          # all-up: identity, no copies
    h2 = h.fail_node(3).fail_link(5)
    assert not h2.all_up and h.all_up       # immutable updates
    d = h2.degrade(prob)
    # value-only substitution: same shapes everywhere
    assert d.NS.shape == prob.NS.shape
    assert d.C_net.shape == prob.C_net.shape
    assert float(d.NS[3]) == 0.0 and float(d.C_lan[3]) == 0.0
    assert float(d.C_net[5]) == 0.0
    # untouched fields: C_pr stays nonzero (ceil division), routes intact
    assert float(d.C_pr[3]) == float(prob.C_pr[3])
    assert d.route_idx is prob.route_idx
    h3 = h2.recover_node(3).recover_link(5)
    assert h3.all_up


def test_health_eligibility_masks_dead_elements(topo):
    svcs = _services(topo, 3)
    b = svcs[0]
    for sv in svcs[1:]:
        b = b.concat(sv)
    prob = power.build_problem(topo, b)
    h = SubstrateHealth.fresh(topo).fail_node(2)
    el = h.eligibility(prob)
    assert el.shape == (prob.R, prob.P)
    assert not el[:, 2].any()               # dead node ineligible everywhere
    # a dead network element removes every node routed through it
    lam_links = np.asarray(prob.route_idx)
    n = int(lam_links[lam_links < prob.N].flat[0])
    h2 = SubstrateHealth.fresh(topo).fail_link(n)
    el2 = h2.eligibility(prob)
    pair = h2.pair_alive(prob)
    src0 = int(b.src[0])
    assert (el2[0] == pair[src0]).all()


def test_spec_health_masks_and_pytree(topo):
    import jax
    svcs = _services(topo, 2)
    prob = power.build_problem(topo, svcs[0].concat(svcs[1]))
    spec = _quick_spec(health=SubstrateHealth.fresh(topo))
    assert spec.masks(prob) is None         # all-up: unconstrained fast path
    spec = spec.replace(health=spec.health.fail_node(1))
    el = spec.masks(prob)
    assert el is not None and not el[:, 1].any()
    # health survives the pytree protocol (vmap/jit closure hygiene)
    leaves, treedef = jax.tree_util.tree_flatten(spec)
    spec2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert not spec2.health.node_up[1]
    el2 = spec2.masks(prob)
    assert (el == el2).all()


# ---------------------------------------------------------------------------
# the closed loop: fail -> re-embed -> recover on the online engine
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=4)
@given(seed=st.integers(0, 10_000))
def test_fail_recover_roundtrip_matches_oracle(seed):
    topo = _topo()
    s, svcs, mon = _session(topo, n=5, seed0=seed % 100)
    node = _hosting_non_source(s, svcs)
    if node is None:
        return
    s.tick(1.0)
    assert s.fail_node(node) is not None
    # displaced VMs moved off the dead node
    X = s.X
    for r in range(s.n_live):
        assert node not in X[r, :s.engine._vsrs[r].V]
    # conservation on the DEGRADED substrate: engine objective == f64
    # oracle of its own placement on the degraded problem
    gap, oracle = _oracle_gap(s.problem, X[:, :s.problem.V], s.objective())
    assert gap <= 1e-3 + 1e-5 * abs(oracle)
    s.tick(2.0)
    s.recover_node(node)
    assert s.health.all_up
    s.defrag()
    assert s.n_live == 5                    # everyone survived the storm
    # and the recovered engine is oracle-exact on the HEALTHY problem
    gap, oracle = _oracle_gap(s.problem, s.X[:, :s.problem.V],
                              s.objective())
    assert gap <= 1e-3 + 1e-5 * abs(oracle)
    assert float(s.result.breakdown.violation) <= 1e-6


@settings(deadline=None, max_examples=4)
@given(seed=st.integers(0, 10_000))
def test_stranded_never_silently_dropped(seed):
    topo = _topo()
    s, svcs, mon = _session(topo, n=5, seed0=seed % 100)
    admitted = set(s.sids)
    src = int(svcs[0].src[0])
    hit = {i for i, sv in enumerate(svcs) if int(sv.src[0]) == src}
    s.tick(1.0)
    s.fail_node(src)
    live = set(s.sids)
    queued = set(s.engine.queued_sids)
    # every admitted service is accounted for: still live or parked
    assert live | queued == admitted
    assert hit <= queued                      # the sourced-there ones parked
    assert mon["service_stranded"] == len(queued)
    assert mon.stranded_since.keys() == queued
    s.tick(4.0)
    s.recover_node(src)
    # retry-on-recovery re-admits everyone; none vanished
    assert set(s.sids) == admitted
    assert not s.engine._queue
    assert not mon.stranded_since             # all windows closed
    assert mon.stranded_service_s >= 3.0 * len(hit) - 1e-9
    assert mon["re_embedded"] >= len(hit)


def test_no_retrace_across_same_bucket_fail_recover(topo):
    s, svcs, _ = _session(topo, n=5)
    node = _hosting_non_source(s, svcs)
    assert node is not None
    # warm cycle: compiles the eligible-masked variants once
    s.fail_node(node)
    s.recover_node(node)
    before = dict(solvers.TRACE_COUNTS)
    s.fail_node(node)
    s.recover_node(node)
    assert solvers.TRACE_COUNTS == before, \
        "same-bucket fail/recover events must not retrace solver kernels"


def test_link_failure_reroutes_traffic(topo):
    s, svcs, mon = _session(topo, n=5)
    lam = np.asarray(s.engine._state.lam)
    n = int(np.argmax(lam))
    assert lam[n] > 0
    s.tick(1.0)
    s.fail_link(n)
    assert mon["link_failed"] == 1
    if s.n_live:
        # surviving placements carry (essentially) no traffic on the cut
        assert float(np.asarray(s.engine._state.lam)[n]) <= 1e-2
    # every service is still live or parked, never dropped
    assert set(s.sids) | set(s.engine.queued_sids) == set(range(5))
    s.recover_link(n)
    assert s.health.all_up and mon["link_recovered"] == 1


def test_brownout_tightens_admission_and_restores(topo):
    s, svcs, mon = _session(topo, n=2)
    s.tick(1.0)
    s.brownout(0.0)   # nothing incremental fits a zero-watt budget
    extra = _services(topo, 1, seed0=77)[0]
    assert s.add(extra, sid=50) is None
    assert s.n_live == 2 and mon["brownout"] == 1
    assert mon["admission_rejected"] == 1
    s.tick(2.0)
    s.brownout_end()
    assert s.spec.power_budget_w is None      # restored
    assert mon["brownout_end"] == 1


# ---------------------------------------------------------------------------
# timelines: FaultEvents merged with churn
# ---------------------------------------------------------------------------

def test_fault_presets_and_merge_order(topo):
    one = dynamic.fault_preset("single_node", topo)
    assert [e.kind for e in one] == ["fail_node", "recover_node"]
    assert one[0].target == one[1].target
    storm = dynamic.fault_preset("rack_storm", topo, n_nodes=3)
    assert len(storm) == 6
    assert [e.t for e in storm] == sorted(e.t for e in storm)
    assert len({e.target for e in storm}) == 3
    day = dynamic.fault_preset("brownout_day", topo, budget_w=123.0)
    assert [e.kind for e in day] == ["brownout", "brownout_end"]
    assert day[0].value == 123.0
    with pytest.raises(ValueError):
        dynamic.fault_preset("nope", topo)
    churn = [dynamic.ServiceEvent(20.0, "arrive", 7),
             dynamic.ServiceEvent(20.0, "depart", 3)]
    merged = dynamic.merge_timelines(
        churn, [dynamic.FaultEvent(20.0, "fail_node", 2),
                dynamic.FaultEvent(20.0, "recover_node", 2)])
    # depart < fail < recover < arrive on exact time ties
    assert [e.kind for e in merged] == ["depart", "fail_node",
                                       "recover_node", "arrive"]


def test_replay_merged_timeline_closes_the_loop(topo):
    mon = PlacementMonitor()
    s = CFNSession(topo, _quick_spec(), monitor=mon)
    iot = topo.layer_indices("iot")

    def make_vsr(sid):
        return vsr.random_vsrs(1, rng=np.random.default_rng(sid), n_vms=3,
                               source_nodes=iot[:4])

    churn = [dynamic.ServiceEvent(float(i), "arrive", i) for i in range(4)]
    churn.append(dynamic.ServiceEvent(9.0, "depart", 0))
    src = int(make_vsr(1).src[0])
    faults = [dynamic.FaultEvent(5.0, "fail_node", src),
              dynamic.FaultEvent(7.0, "recover_node", src)]
    events = dynamic.merge_timelines(churn, faults)
    s.replay(events, make_vsr)
    kinds = [st_.event for st_ in s.stats]
    assert "fail_node" in kinds and "recover_node" in kinds
    assert mon["node_failed"] == 1 and mon["node_recovered"] == 1
    mon.close_strands(10.0)
    assert not mon.stranded_since
    a = mon.availability(horizon=10.0, n_services=4)
    assert 0.0 <= a < 1.0                   # some service-time was stranded
    assert mon.stranded_service_s > 0.0


# ---------------------------------------------------------------------------
# monitor: availability integral, reset, merge; heartbeat; straggler
# ---------------------------------------------------------------------------

def test_monitor_strand_unstrand_integral():
    m = PlacementMonitor()
    m.strand(1, t=2.0)
    m.strand(1, t=3.0)                      # idempotent while open
    assert m["service_stranded"] == 1
    assert not m.unstrand(9, t=5.0)         # no window: no-op
    assert m.unstrand(1, t=5.0)
    assert m.stranded_service_s == pytest.approx(3.0)
    assert m["re_embedded"] == 1
    m.strand(2, t=6.0)
    m.unstrand(2, t=8.0, re_embedded=False)   # departed while stranded
    assert m["re_embedded"] == 1
    assert m.stranded_service_s == pytest.approx(5.0)
    assert m.availability(horizon=10.0, n_services=2) == pytest.approx(0.75)


def test_monitor_reset_and_merge_ring_buffer():
    a = PlacementMonitor(max_events=4)
    b = PlacementMonitor()
    for i in range(3):
        a.count("x", detail=f"a{i}")
    for i in range(3):
        b.count("y", detail=f"b{i}")
    b.strand(7, t=1.0)
    b.stranded_service_s = 2.5
    a.strand(7, t=0.5)
    a.merge(b)
    assert a["x"] == 3 and a["y"] == 3
    assert a["service_stranded"] == 2       # counters simply add
    assert len(a.events) == 4               # ring bound survives the merge
    assert a.events[-1] == ("service_stranded", "sid=7")
    assert a.stranded_service_s == pytest.approx(2.5)
    assert a.stranded_since[7] == 0.5       # earliest open window wins
    a.reset()
    assert not a.counters and not a.events and not a.stranded_since
    assert a.stranded_service_s == 0.0
    assert a.availability(10.0, 5) == 1.0


def test_heartbeat_deregister_and_reset():
    clock = {"t": 0.0}
    m = HeartbeatMonitor(timeout_s=1.0, clock=lambda: clock["t"])
    m.register("w0")
    m.register("w1")
    clock["t"] = 5.0
    assert sorted(m.dead_workers()) == ["w0", "w1"]
    m.deregister("w0")                      # evicted: stops re-alarming
    assert m.dead_workers() == ["w1"]
    m.deregister("w0")                      # idempotent
    m.reset()
    assert m.healthy() and not m.last_beat


def test_straggler_reset_clears_history():
    t = StragglerTracker(threshold=3.0)
    for i in range(8):
        t.record(i, 1.0)
    assert t.record(8, 10.0)                # flagged vs the 1 s median
    t.reset()
    assert t.flagged_steps == [8]           # the report survives
    # post-restart steps judge against FRESH history only: a 10 s step with
    # no history cannot be flagged against pre-failure 1 s medians
    assert not t.record(9, 10.0)


# ---------------------------------------------------------------------------
# federated evacuation
# ---------------------------------------------------------------------------

def _fed_topo():
    return topology.federated_scale(n_regions=3, n_olt=1, onus_per_olt=2,
                                    iot_per_onu=2, n_core=6)


def test_federated_evacuation_and_conservation():
    ftopo = _fed_topo()
    mon = PlacementMonitor()
    fed = FederatedSession(ftopo, _quick_spec(), monitor=mon)
    srcs = [int(r.proc_ids[0]) for r in fed.partition.regions]

    def sv(seed, g):
        return vsr.random_vsrs(1, rng=np.random.default_rng(seed), n_vms=3,
                               source_nodes=[srcs[g]])

    for i, g in enumerate([0, 0, 2]):
        assert fed.add(sv(i, g), sid=i) is not None
    # a cross-hosted body: homed in region 0, explicitly placed in region 1
    assert fed.add(sv(3, 0), sid=3, region=1) is not None
    assert fed.assignment(3) == 1
    fed.tick(1.0)
    n_evac = fed.fail_region(1)
    assert n_evac == 1 and mon["evacuation"] == 1
    assert fed.assignment(3) != 1           # body left the dark region
    assert fed.down_regions == [1]
    assert set(fed.sids) == {0, 1, 2, 3}    # nobody homed there: all live
    # conservation stays f64-oracle-exact on the surviving substrate
    vs = fed._plans[fed._order[0]].vsr
    for sid in fed._order[1:]:
        vs = vs.concat(fed._plans[sid].vsr)
    bd = fed.breakdown()
    prob = power.build_problem(ftopo, vs)
    X = np.asarray(fed.X)[:vs.R, :vs.V]
    oracle = kref.placement_objective_f64(prob, X)
    assert abs(oracle - bd.objective) <= 1e-7 * max(1.0, abs(oracle))
    fed.recover_region(1)
    assert fed.down_regions == []


def test_federated_region_failure_strands_homed_services():
    ftopo = _fed_topo()
    mon = PlacementMonitor()
    fed = FederatedSession(ftopo, _quick_spec(), monitor=mon)
    srcs = [int(r.proc_ids[0]) for r in fed.partition.regions]

    def sv(seed, g):
        return vsr.random_vsrs(1, rng=np.random.default_rng(seed), n_vms=3,
                               source_nodes=[srcs[g]])

    for i, g in enumerate([0, 1, 1, 2]):
        assert fed.add(sv(i, g), sid=i) is not None
    fed.tick(2.0)
    fed.fail_region(1)
    assert set(fed.sids) == {0, 3}          # homed-in-1 services stranded
    assert mon["service_stranded"] == 2
    # arrivals for the dark region park instead of dropping
    assert fed.add(sv(9, 1), sid=9) is None
    assert mon["service_stranded"] == 3
    fed.tick(6.0)
    assert fed.recover_region(1) == 3       # everyone comes back
    assert set(fed.sids) == {0, 1, 2, 3, 9}
    assert not mon.stranded_since
    assert mon.stranded_service_s >= 4.0 * 2 - 1e-9
    # the round-trip keeps exact conservation too
    bd = fed.breakdown()
    assert float(bd.objective) > 0


def test_federated_monitor_rollup():
    ftopo = _fed_topo()
    mon = PlacementMonitor()
    fed = FederatedSession(ftopo, _quick_spec(), monitor=mon)
    regional = fed.attach_region_monitors()
    assert set(regional) == {0, 1, 2}
    srcs = [int(r.proc_ids[0]) for r in fed.partition.regions]
    for i, g in enumerate([0, 1, 2]):
        s = vsr.random_vsrs(1, rng=np.random.default_rng(i), n_vms=3,
                            source_nodes=[srcs[g]])
        assert fed.add(s, sid=i) is not None
    fed.tick(1.0)
    fed.fail_region(1)
    fed.recover_region(1)
    fleet = fed.fleet_monitor()
    # coordinator events (session monitor) and any per-region engine events
    # roll up into one snapshot; counters add across monitors
    assert fleet["region_failed"] == 1 and fleet["region_recovered"] == 1
    total = sum(m.get("service_stranded") for m in regional.values())
    total += mon.get("service_stranded")
    assert fleet["service_stranded"] == total == 1
