"""Substrate tests: data determinism, checkpoint roundtrip + async writes,
restart-from-failure with identical replay, elastic re-shard, compression."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.checkpoint import CheckpointStore
from repro.data.pipeline import DataConfig, DataIterator, make_batch
from repro.fault import (HeartbeatMonitor, ResilientTrainer, SimulatedFailure,
                         StragglerTracker)
from repro.optim import adamw
from repro.train.compress import dequantize, quantize
from repro.train.step import init_state, make_train_step


def test_data_is_deterministic_and_step_indexed():
    cfg = configs.get_smoke("qwen3-4b")
    d = DataConfig(seed=3, batch=4, seq_len=32)
    a = make_batch(cfg, d, step=5)
    b = make_batch(cfg, d, step=5)
    c = make_batch(cfg, d, step=6)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_data_iterator_resume_replays():
    cfg = configs.get_smoke("qwen3-4b")
    d = DataConfig(seed=1, batch=2, seq_len=16)
    it = DataIterator(cfg, d)
    first = [next(it) for _ in range(4)]
    st = it.state()
    rest = [next(it) for _ in range(3)]
    it2 = DataIterator.restore(cfg, d, st)
    rest2 = [next(it2) for _ in range(3)]
    for x, y in zip(rest, rest2):
        np.testing.assert_array_equal(x["tokens"], y["tokens"])


def test_data_has_learnable_structure():
    """Synthetic stream is predictable: n-gram repeats beat chance."""
    cfg = configs.get_smoke("qwen3-4b")
    d = DataConfig(seed=0, batch=8, seq_len=256, noise=0.0)
    b = make_batch(cfg, d, 0)
    toks = b["tokens"]
    # within an ngram block, token (i, i+ngram) correlation from patterns
    matches = np.mean(toks[:, :-d.ngram] == toks[:, d.ngram:])
    assert matches > 5.0 / cfg.vocab  # far above chance


def test_checkpoint_roundtrip(tmp_path):
    store = CheckpointStore(str(tmp_path))
    tree = dict(a=jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                b=[jnp.ones(4), jnp.zeros((), jnp.int32)])
    store.save(7, tree, extra=dict(data_step=7))
    store.wait()
    like = jax.tree_util.tree_map(lambda x: x, tree)
    got, extra = store.restore(None, like)
    assert extra["data_step"] == 7
    np.testing.assert_array_equal(got["a"], tree["a"])
    assert store.latest_step() == 7


def test_checkpoint_keeps_latest_pointer(tmp_path):
    store = CheckpointStore(str(tmp_path))
    t = dict(x=jnp.zeros(2))
    store.save(1, t, extra=dict(data_step=1))
    store.save(2, t, extra=dict(data_step=2))
    store.wait()
    assert store.latest_step() == 2


def _tiny_setup(tmp_path, arch="qwen3-4b"):
    cfg = dataclasses.replace(configs.get_smoke(arch), n_layers=2)
    dcfg = DataConfig(seed=0, batch=2, seq_len=16)
    step = jax.jit(make_train_step(cfg, adamw.AdamWConfig(lr=1e-3)),
                   donate_argnums=(0,))
    init_fn = lambda: init_state(cfg, jax.random.PRNGKey(0))[0]
    return cfg, dcfg, step, init_fn


@pytest.mark.slow
def test_restart_replays_identically(tmp_path):
    """Loss trajectory after a mid-run failure+restore equals the unfailed
    run (deterministic data + checkpointed state)."""
    cfg, dcfg, step, init_fn = _tiny_setup(tmp_path)
    clean = ResilientTrainer(cfg, dcfg, step, init_fn,
                             str(tmp_path / "clean"), ckpt_every=4)
    ref = clean.run(8)
    faulty = ResilientTrainer(cfg, dcfg, step, init_fn,
                              str(tmp_path / "faulty"), ckpt_every=4)
    rep = faulty.run(8, fail_at={6: SimulatedFailure("node died")})
    assert rep.restarts == 1
    # post-restart losses (steps 4..7 re-run) must match the clean run
    np.testing.assert_allclose(ref.losses[-2:], rep.losses[-2:], rtol=1e-5)
    assert rep.final_step == 8


def test_heartbeat_and_straggler():
    clock = {"t": 0.0}
    hb = HeartbeatMonitor(timeout_s=5.0, clock=lambda: clock["t"])
    hb.register("w0")
    hb.register("w1")
    clock["t"] = 3.0
    hb.beat("w0")
    clock["t"] = 7.0
    assert hb.dead_workers() == ["w1"]
    st = StragglerTracker(threshold=3.0)
    for i in range(8):
        st.record(i, 1.0)
    assert st.record(8, 10.0) is True
    assert 8 in st.flagged_steps


def test_quantize_error_feedback_contracts():
    """int8 EF quantization: dequant error bounded by scale/2 per element."""
    x = jnp.asarray(np.random.default_rng(0).standard_normal(1000),
                    jnp.float32)
    q, scale = quantize(x)
    err = x - dequantize(q, scale)
    assert float(jnp.max(jnp.abs(err))) <= float(scale) * 0.500001
    assert q.dtype == jnp.int8


def test_elastic_restore_into_fresh_state_shapes(tmp_path):
    """Checkpoint restores into an eval_shape skeleton (mesh-free case of
    the elastic path; the 8-device re-shard runs in test_multidevice)."""
    cfg, dcfg, step, init_fn = _tiny_setup(tmp_path)
    store = CheckpointStore(str(tmp_path / "ck"))
    state = init_fn()
    store.save(3, state, extra=dict(data_step=3))
    store.wait()
    like = jax.eval_shape(init_fn)
    got, extra = store.restore(None, like)
    flat_a = jax.tree_util.tree_leaves(state)
    flat_b = jax.tree_util.tree_leaves(got)
    assert all(np.asarray(x).shape == np.asarray(y).shape
               for x, y in zip(flat_a, flat_b))
    np.testing.assert_allclose(np.asarray(flat_a[0]),
                               np.asarray(flat_b[0]))
